"""TPU-side Flora: pick a mesh for a submitted workload from the dry-run
profiling trace, under current chip prices (the DESIGN.md §3 adaptation).

    PYTHONPATH=src python examples/flora_select_mesh.py \
        --report dryrun_single.json --shape decode_32k --market spot
"""
import argparse
import json
import os

from repro.core.costmodel import TpuPriceModel
from repro.core.tpu_flora import (MeshOption, TpuFlora,
                                  records_from_dryrun_report, SHAPE_CLASSES)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--report", default="dryrun_single.json")
    ap.add_argument("--shape", default="decode_32k",
                    choices=list(SHAPE_CLASSES))
    ap.add_argument("--market", default="ondemand",
                    choices=["ondemand", "spot"])
    ap.add_argument("--exclude-arch", default=None,
                    help="leave this arch's profiling data out "
                         "(the paper's no-recurrence discipline)")
    args = ap.parse_args()

    if not os.path.exists(args.report):
        raise SystemExit(f"run launch/dryrun.py first to produce "
                         f"{args.report}")
    with open(args.report) as f:
        recs = records_from_dryrun_report(json.load(f))
    meshes = sorted({r.mesh for r in recs})
    options = [MeshOption(m, "v5e", 256, (16, 16), ("data", "model"))
               for m in meshes]
    price = TpuPriceModel(args.market)
    flora = TpuFlora(options, recs, price)

    klass = SHAPE_CLASSES[args.shape]
    exclude = (args.exclude_arch,) if args.exclude_arch else ()
    print(f"workload {args.shape} -> class {klass.value} "
          f"({'state-resident' if klass.value == 'A' else 'streaming-compute'})")
    print(f"profiled records: {len(recs)}; mesh options: "
          f"{[o.name for o in options]}\n")
    for r in flora.rank(klass, exclude_archs=exclude):
        o = next(x for x in options if x.name == r.config_id)
        print(f"  {r.config_id:12s} score={r.score:8.3f} "
              f"mean_norm_cost={r.mean_norm_cost:6.3f} "
              f"({o.hourly_cost(price):7.2f} $/h)")
    pick = flora.select(args.shape, exclude_archs=exclude)
    print(f"\nFlora selects: {pick.name}")


if __name__ == "__main__":
    main()
