"""TPU-side Flora: pick a mesh for a submitted workload from the dry-run
profiling trace, under current chip prices (the DESIGN.md §3 adaptation).

    PYTHONPATH=src python examples/flora_select_mesh.py \
        --report dryrun_single.json --shape decode_32k --market spot

Selection goes through the unified :class:`repro.selector.SelectionService`
— the same stack as the GCP-side quickstart, over a
:class:`repro.selector.TpuSliceCatalog`.
"""
import argparse
import json
import os

from repro.core.costmodel import TpuPriceModel
from repro.core.tpu_flora import SHAPE_CLASSES, service_from_dryrun_report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--report", default="dryrun_single.json")
    ap.add_argument("--shape", default="decode_32k",
                    choices=list(SHAPE_CLASSES))
    ap.add_argument("--market", default="ondemand",
                    choices=["ondemand", "spot"])
    ap.add_argument("--exclude-arch", default=None,
                    help="leave this arch's profiling data out "
                         "(the paper's no-recurrence discipline)")
    args = ap.parse_args()

    if not os.path.exists(args.report):
        raise SystemExit(f"run launch/dryrun.py first to produce "
                         f"{args.report}")
    with open(args.report) as f:
        report = json.load(f)
    price = TpuPriceModel(args.market)
    service = service_from_dryrun_report(report, price)

    exclude = (args.exclude_arch,) if args.exclude_arch else ()
    decision = service.submit(args.shape, exclude_groups=exclude)
    klass = decision.job_class
    print(f"workload {args.shape} -> class {klass.value} "
          f"({'state-resident' if klass.value == 'A' else 'streaming-compute'})")
    print(f"profiled cells: {len(service.store)}; mesh options: "
          f"{service.catalog.ids()}\n")
    for r in decision.ranking:
        print(f"  {str(r.config_id):12s} score={r.score:8.3f} "
              f"mean_norm_cost={r.mean_norm_cost:6.3f} "
              f"({service.catalog.hourly_cost(r.config_id):7.2f} $/h)")
    print(f"\nFlora selects: {decision.config_id} "
          f"at {decision.hourly_cost:.2f} $/h")


if __name__ == "__main__":
    main()
