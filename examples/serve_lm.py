"""Serve a small model with batched requests through the engine.

    PYTHONPATH=src python examples/serve_lm.py --arch rwkv6-3b --requests 6

With ``--report dryrun_single.json`` the decode fleet's mesh is first
planned through the selection service (class A, state-resident), and the
engine records the placement decision.
"""
import argparse
import json
import os

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.core.costmodel import TpuPriceModel
from repro.core.tpu_flora import service_from_dryrun_report
from repro.models import build_model, count_params
from repro.serve.engine import Engine, Request, plan_decode_placement


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--report", default=None,
                    help="dry-run report: plan the decode mesh via the "
                         "selection service before serving")
    ap.add_argument("--market", default="ondemand",
                    choices=["ondemand", "spot"])
    args = ap.parse_args()

    placement = None
    if args.report and os.path.exists(args.report):
        with open(args.report) as f:
            service = service_from_dryrun_report(
                json.load(f), TpuPriceModel(args.market))
        placement = plan_decode_placement(service)
        print(f"[serve] placement: mesh {placement.config_id} "
              f"at {placement.hourly_cost:.2f} $/h "
              f"(class {placement.job_class.value})")

    cfg = configs.reduced(configs.get(args.arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"[serve] {cfg.name} (reduced): "
          f"{count_params(model.param_specs())/1e6:.1f}M params, "
          f"{args.slots} decode slots")

    eng = Engine(model, params, slots=args.slots, max_len=64,
                 placement=placement)
    key = jax.random.PRNGKey(1)
    reqs = []
    for i in range(args.requests):
        key, sub = jax.random.split(key)
        prompt = jax.random.randint(sub, (args.prompt_len,), 0,
                                    cfg.vocab_size, dtype=jnp.int32)
        reqs.append(Request(uid=i, prompt=prompt, max_new_tokens=args.max_new))
    comps = eng.serve(reqs)
    for c in sorted(comps, key=lambda c: c.uid):
        print(f"  req {c.uid}: {len(c.tokens)} tokens "
              f"(prefill {c.prefill_ms:.0f} ms, decode {c.decode_ms:.0f} ms) "
              f"-> {c.tokens[:8]}")


if __name__ == "__main__":
    main()
