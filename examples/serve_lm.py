"""Serve a small model with batched requests through the engine.

    PYTHONPATH=src python examples/serve_lm.py --arch rwkv6-3b --requests 6
"""
import argparse

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.models import build_model, count_params
from repro.serve.engine import Engine, Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    cfg = configs.reduced(configs.get(args.arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"[serve] {cfg.name} (reduced): "
          f"{count_params(model.param_specs())/1e6:.1f}M params, "
          f"{args.slots} decode slots")

    eng = Engine(model, params, slots=args.slots, max_len=64)
    key = jax.random.PRNGKey(1)
    reqs = []
    for i in range(args.requests):
        key, sub = jax.random.split(key)
        prompt = jax.random.randint(sub, (args.prompt_len,), 0,
                                    cfg.vocab_size, dtype=jnp.int32)
        reqs.append(Request(uid=i, prompt=prompt, max_new_tokens=args.max_new))
    comps = eng.serve(reqs)
    for c in sorted(comps, key=lambda c: c.uid):
        print(f"  req {c.uid}: {len(c.tokens)} tokens "
              f"(prefill {c.prefill_ms:.0f} ms, decode {c.decode_ms:.0f} ms) "
              f"-> {c.tokens[:8]}")


if __name__ == "__main__":
    main()
