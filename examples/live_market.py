"""Live price market end-to-end: feed -> ticker -> daemon -> migration.

    PYTHONPATH=src python examples/live_market.py --events 400 --seed 3

A TPU mesh universe is wrapped in a mutable
:class:`repro.selector.PriceTable`; a deterministic
:class:`repro.market.SimulatedSpotFeed` (mean-reverting spot walks plus a
scheduled v5p discount window) streams price deltas into the
:class:`repro.market.SelectionDaemon`, which serves an interleaved
submission/tick stream, repricing cached rankings incrementally
(DESIGN.md §6).  At the end, the hysteresis migration advisor decides
whether a decode fleet placed at tick 0 should move under final prices.
"""
import argparse

from repro.core.costmodel import TpuPriceModel
from repro.core.tpu_flora import MeshOption, WorkloadRecord, make_service
from repro.market import (MarketEvent, SelectionDaemon, SimulatedSpotFeed,
                          should_migrate, synthetic_stream)
from repro.selector import PriceTable


def build_service(backend=None):
    options = [
        MeshOption("v5e-dp256xtp1", "v5e", 256, (256, 1), ("data", "model")),
        MeshOption("v5e-dp16xtp16", "v5e", 256, (16, 16), ("data", "model")),
        MeshOption("v5p-dp16xtp16", "v5p", 256, (16, 16), ("data", "model")),
        MeshOption("v5p-dp64xtp4", "v5p", 256, (64, 4), ("data", "model")),
    ]
    speed = {"v5e-dp256xtp1": {"train_4k": 1.0, "decode_32k": 4.0},
             "v5e-dp16xtp16": {"train_4k": 1.5, "decode_32k": 1.0},
             "v5p-dp16xtp16": {"train_4k": 0.8, "decode_32k": 0.55},
             "v5p-dp64xtp4": {"train_4k": 0.7, "decode_32k": 0.9}}
    records = [WorkloadRecord(arch=a, shape=s, mesh=m, step_seconds=v)
               for a in ("lm-7b", "moe-30b")
               for m, shapes in speed.items()
               for s, v in shapes.items()]
    service = make_service(options, records, TpuPriceModel("spot"),
                           backend=backend)
    # swap the model source for a live quote table (same starting prices)
    service.set_price_source(PriceTable.from_catalog(
        service.catalog, TpuPriceModel("spot")))
    return service


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--events", type=int, default=400)
    ap.add_argument("--seed", type=int, default=3)
    ap.add_argument("--backend", default=None,
                    choices=["numpy", "jax", "jax_batched", "jax_sharded",
                             "jax_pallas"],
                    help="ranking backend (default: FLORA_RANK_BACKEND "
                         "env var, else numpy); jax_batched stacks every "
                         "live ranking into one batched kernel — a tick "
                         "is ONE dispatch for the whole fleet "
                         "(DESIGN.md §10)")
    ap.add_argument("--serve-top-k", type=int, default=None, metavar="K",
                    help="serve Decisions with only the top-K head of "
                         "the ranking (device-side top_k; the full "
                         "C-config sort never runs)")
    ap.add_argument("--metrics", nargs="?", const="prom", default=None,
                    choices=["prom", "json"],
                    help="dump the run's telemetry registry at exit "
                         "(DESIGN.md §12) in Prometheus text (default) "
                         "or JSON")
    args = ap.parse_args()
    if args.serve_top_k is not None and args.serve_top_k < 1:
        ap.error("--serve-top-k must be >= 1")

    service = build_service(backend=args.backend)
    service.serve_top_k = args.serve_top_k
    feed = SimulatedSpotFeed(
        dict(service.price_source.items()), seed=args.seed,
        change_fraction=0.08, volatility=0.10,
        events=[MarketEvent("europe-west3", start_tick=10, duration=25,
                            factor=0.5, kind="discount")])
    daemon = SelectionDaemon(service, feed)

    initial = service.submit("decode_32k")
    print(f"t=0 decode fleet placed on {initial.config_id} "
          f"at {initial.hourly_cost:.0f} $/h (epoch {initial.price_epoch})")

    stats = daemon.run(synthetic_stream(
        ["decode_32k", "train_4k"], args.events, seed=args.seed,
        tick_fraction=0.2))
    svc = daemon.service
    print(f"\nafter {stats.events} events: {stats.decisions} decisions, "
          f"{stats.ticks} ticks, {stats.epochs} price epochs, "
          f"{stats.deltas} deltas")
    print(f"cache: {svc.cache_hits} hits / {svc.cache_misses} misses, "
          f"{svc.reprice_refreshes} incremental refreshes in "
          f"{svc.reprice_dispatches} kernel dispatches "
          f"(epoch now {svc.price_epoch})")

    # the migration advisor below walks the ranking tail, so serve the
    # closing submission with the full list even when the tick-stream
    # Decisions were top-k heads
    service.serve_top_k = None
    final = service.submit("decode_32k")
    print(f"\ncurrent winner under live prices: {final.config_id} "
          f"at {final.hourly_cost:.0f} $/h")
    # quote savings/switch cost off the fleet's $/h under *current*
    # prices, not the rate stamped on the t=0 decision
    current_rate = service.catalog.hourly_cost(initial.config_id,
                                               service.price_source)
    advice = should_migrate(initial, final.ranking, switch_cost_hours=0.5,
                            horizon_hours=24.0,
                            current_hourly_cost=current_rate)
    verb = "MIGRATE" if advice.migrate else "STAY"
    print(f"fleet advisor: {verb} ({advice.reason})")
    if advice.migrate:
        print(f"  net saving over {advice.horizon_hours:g} h: "
              f"{advice.net_saving_usd:.2f} USD")

    journal = daemon.journal_dump().splitlines()
    print(f"\njournal: {len(journal) - 1} records "
          f"(header: {journal[0][:60]}...)")

    if args.metrics:
        # every component above (service, ticker, daemon) shares the
        # service's registry, so this is the whole run's telemetry
        print(f"\n--- metrics ({args.metrics}) ---")
        print(service.metrics.render(args.metrics), end="")


if __name__ == "__main__":
    main()
