"""Concurrent serving end-to-end: tick thread + snapshot workers + merge.

    PYTHONPATH=src python examples/serve_frontend.py --workers 4

A recorded spot market (captured from a deterministic
:class:`repro.market.SimulatedSpotFeed`) plays out on the
:class:`repro.market.ServeFrontend`'s tick thread, which owns all
repricing and publishes an immutable per-tick snapshot of every live
selection's top-k head; N workers serve submissions lock-free off the
latest snapshot while a 1 ms ``on_decision`` callback stands in for the
client-reply round-trip (DESIGN.md §11).  At the end the worker-sharded
journals are merged into one deterministic v2 journal and handed to the
unmodified :class:`repro.market.JournalReplayer` — the audit holds the
concurrent run to the same bar as the single-threaded daemon.
"""
import argparse
import time

from repro.core.trace import JobClass
from repro.market import (JournalReplayer, RecordedPriceFeed, ServeFrontend,
                          SimulatedSpotFeed, Submission, record_feed)
from repro.selector import (IdentityCatalog, PriceTable, ProfilingStore,
                            SelectionService)


def build_universe(n_jobs=12, n_cfgs=24):
    ids = [f"c{i}" for i in range(n_cfgs)]
    store = ProfilingStore(config_ids=ids)
    for j in range(n_jobs):
        klass = JobClass.A if j % 2 else JobClass.B
        for i, c in enumerate(ids):
            store.add(f"j{j}", c, 0.1 + ((j * 13 + i * 7) % 29) / 8.0,
                      job_class=klass, group=f"g{j % 4}")
    base = {c: 1.0 + (i * 11 % 17) for i, c in enumerate(ids)}
    return store, ids, base


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--submissions", type=int, default=300)
    ap.add_argument("--ticks", type=int, default=120)
    ap.add_argument("--seed", type=int, default=42)
    ap.add_argument("--backend", default=None,
                    choices=["numpy", "jax", "jax_batched", "jax_sharded",
                             "jax_pallas"],
                    help="ranking backend (default: FLORA_RANK_BACKEND "
                         "env var, else numpy)")
    args = ap.parse_args()

    store, ids, base = build_universe()
    feed = RecordedPriceFeed.loads(record_feed(
        SimulatedSpotFeed(base, seed=args.seed, change_fraction=0.5),
        args.ticks))
    service = SelectionService(IdentityCatalog(ids), store,
                               PriceTable(base), backend=args.backend,
                               serve_top_k=3)

    selections = [("j1", None), ("j2", None), ("j3", None),
                  ("j4", None), ("j1", ("g2", "g3")), ("j2", ("g1",))]
    subs = [Submission(job, exclude_groups=excl)
            for job, excl in (selections[i % len(selections)]
                              for i in range(args.submissions))]

    fe = ServeFrontend(service, feed, workers=args.workers,
                       queue_capacity=len(subs) + 1,
                       on_decision=lambda d: time.sleep(0.001))
    fe.warm(subs[:len(selections)])
    print(f"serving {len(subs)} submissions across {args.workers} "
          f"workers while {args.ticks} recorded ticks play out...")
    with fe:
        t0 = time.perf_counter()
        for sub in subs:
            fe.submit(sub)
        fe.drain()
        dt = time.perf_counter() - t0
        fe.await_ticks()

    stats = fe.stats()
    print(f"\n{stats.decisions} decisions + {stats.rejected} rejections "
          f"in {dt:.2f}s ({len(subs) / dt:.0f} subs/s), "
          f"{stats.shed} shed, {stats.forwarded} forwarded")
    print(f"market: {stats.ticks} ticks, {stats.epochs} price epochs, "
          f"{stats.snapshots} snapshots published, "
          f"{stats.feed_errors} feed errors")
    print(f"accounting closed: {stats.accounted}")

    journal = fe.journal_dump()
    replayer = JournalReplayer(store, journal)
    audit = replayer.audit()
    lag = [d.price_epoch for d in replayer.decisions()]
    print(f"\nmerged journal: {len(journal.splitlines()) - 1} records, "
          f"decisions span epochs {min(lag)}..{max(lag)}")
    print(f"audit ({replayer.backend}): "
          f"{'OK' if audit.ok else 'FAILED'} — {audit.decisions} "
          f"decisions cold-re-ranked at their stamped epochs, "
          f"{len(audit.drift)} within-contract drift records")

    # the run's telemetry, read off the shared registry (DESIGN.md §12);
    # serve spans are sampled 1-in-span_sample per worker shard
    reg = fe.metrics_registry

    def us(v):
        return "      -" if v is None else f"{v * 1e6:7.0f}"

    print(f"\ntelemetry (serve spans sampled 1/{fe.span_sample}):")
    print("  span            spans   p50 us   p99 us")
    for name in ("tick.total", "serve.worker"):
        h = reg.histogram(name)
        print(f"  {name:<13} {h.count:7d}  {us(h.quantile(0.50))}  "
              f"{us(h.quantile(0.99))}")
    offered = stats.submitted + stats.shed
    print(f"  shed rate: {stats.shed / max(offered, 1):.1%} "
          f"({stats.shed}/{offered} offered), reprice kernel dispatches: "
          f"{service.reprice_dispatches}")


if __name__ == "__main__":
    main()
