"""Deviation-vs-turbulence: how selection quality degrades as the
market gets hostile.

    PYTHONPATH=src python examples/turbulence_sweep.py
    PYTHONPATH=src python examples/turbulence_sweep.py \\
        --backends numpy jax_batched --presets calm flash_crash

How this maps to the paper
--------------------------
Fig. 2 sweeps *static* price structures and reports <6% mean deviation
from the cost-optimal configuration.  This example sweeps market
*turbulence* instead (DESIGN.md §15): the paper universe (Tables I x
II) is re-submitted against each named `TURBULENCE_PRESETS` market —
from ``calm`` (the bundled-fixture regime) through coordinated
eviction storms, correlated regional spikes and flash-crash/overshoot
regime flips, up to ``laggy_storm`` (a storm seen through a
3-tick-stale feed).  Every cell is recorded, replayed, audited under
the backend's ScoreContract, and scored two ways:

  * **journal-judged** — deviation against the per-epoch oracle at the
    prices the daemon was *shown* (what §8's harness reports);
  * **truth-judged** — the same decisions re-billed at the *unlagged*
    market state (what the cloud would actually charge).  The two
    agree exactly on honest feeds; the gap on ``laggy_storm`` is the
    real cost of feed staleness, invisible to an internally-consistent
    journal.

`benchmarks/turbulence_bench.py` runs this same sweep under CI gates
and writes the machine-readable curve to ``BENCH_turbulence.json``.
"""
import argparse
import sys

from repro.core import costmodel, spark_sim
from repro.core.evaluate import turbulence_curves
from repro.market import TURBULENCE_PRESETS, run_sweep, synthetic_stream
from repro.selector import (BACKENDS, GcpVmCatalog, PriceTable,
                            ProfilingStore, SelectionService,
                            backend_available)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--presets", nargs="+",
                    default=sorted(TURBULENCE_PRESETS,
                                   key=lambda n: TURBULENCE_PRESETS[n].level),
                    choices=sorted(TURBULENCE_PRESETS))
    ap.add_argument("--backends", nargs="+", default=["numpy"],
                    choices=list(BACKENDS))
    ap.add_argument("--events", type=int, default=400)
    ap.add_argument("--seed", type=int, default=11,
                    help="market seed (the stream seed is fixed at 3, "
                         "matching the replay harness)")
    args = ap.parse_args()

    backends = [b for b in args.backends if backend_available(b)]
    for b in args.backends:
        if b not in backends:
            print(f"skipping backend {b}: unavailable", file=sys.stderr)
    if not backends:
        print("no requested backend is available", file=sys.stderr)
        return 1

    trace = spark_sim.generate_trace(seed=0)
    store = ProfilingStore.from_trace(trace)
    catalog = GcpVmCatalog(trace.configs, costmodel.LinearPriceModel())
    base = dict(PriceTable.from_catalog(catalog).items())
    events = list(synthetic_stream([j.name for j in trace.jobs],
                                   args.events, seed=3,
                                   tick_fraction=0.15))

    def factory(backend):
        return SelectionService(catalog, store,
                                PriceTable.from_catalog(catalog),
                                backend=backend)

    points = run_sweep(factory, base, events, presets=args.presets,
                       backends=backends, seed=args.seed)
    if not all(p.audit_ok for p in points):
        for p in points:
            if not p.audit_ok:
                print(f"AUDIT FAILED: {p.preset}/{p.backend} "
                      f"({p.audit_mismatches} mismatches)",
                      file=sys.stderr)
        return 1

    print(f"deviation vs turbulence ({len(points)} cells, "
          f"{args.events} events per cell, paper's static bar: <6%):")
    for backend, curve in turbulence_curves(points).items():
        print(f"\n  backend {backend}:")
        print(f"    {'preset':<18}{'level':>6}{'journal':>10}"
              f"{'truth':>10}{'drift':>7}{'epochs':>8}")
        for p in curve:
            print(f"    {p.preset:<18}{p.level:>6.1f}"
                  f"{p.mean_deviation:>10.2%}"
                  f"{p.truth_mean_deviation:>10.2%}"
                  f"{p.audit_drift:>7d}{p.epochs:>8d}")
        lagged = [p for p in curve
                  if p.truth_mean_deviation != p.mean_deviation]
        for p in lagged:
            print(f"    ^ {p.preset}: the journal can't see feed "
                  f"staleness — the truth judge bills the same "
                  f"decisions at the unlagged market")
    return 0


if __name__ == "__main__":
    sys.exit(main())
