"""End-to-end training driver (deliverable b): data pipeline -> model ->
optimizer -> checkpoint/restart, on CPU at reduced scale.

Default: a ~20M-parameter qwen3-family model for 200 steps (finishes in a
few minutes on CPU).  ``--big`` trains a ~100M-parameter variant.  The run
checkpoints, then *simulates a node failure* by restoring from the last
checkpoint and continuing — the loss curve must line up.

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import dataclasses
import tempfile

import jax

import repro.configs as configs
from repro.data import pipeline as data_lib
from repro.models import build_model, count_params
from repro.models.types import ShapeSpec
from repro.train.checkpoint import Checkpointer
from repro.train.train_loop import (StragglerWatchdog, TrainConfig,
                                    make_train_step, train_loop)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--big", action="store_true",
                    help="~100M params instead of ~20M")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = configs.reduced(configs.get("qwen3-1.7b"),
                          d_model=256 if args.big else 128,
                          vocab=8192 if args.big else 2048)
    if args.big:
        cfg = dataclasses.replace(cfg, num_layers=12, d_ff=1024,
                                  num_heads=8, num_kv_heads=4, head_dim=32)
    model = build_model(cfg)
    n = count_params(model.param_specs())
    print(f"training {cfg.name}-reduced: {n/1e6:.1f}M params, "
          f"{args.steps} steps, batch {args.batch} x seq {args.seq}")

    shape = ShapeSpec("example", args.seq, args.batch, "train")
    stream = data_lib.for_model(cfg, shape, seed=42)
    tcfg = TrainConfig(peak_lr=3e-3, warmup_steps=20, total_steps=args.steps)
    step_fn, opt = make_train_step(model, tcfg)
    params = model.init(jax.random.PRNGKey(0))
    opt_state = opt.init(params)

    with tempfile.TemporaryDirectory() as ckpt_dir:
        ckpt = Checkpointer(ckpt_dir, keep=2)
        watchdog = StragglerWatchdog()
        half = args.steps // 2

        batches = iter(data_lib.PrefetchIterator(stream))
        params, opt_state, hist1 = train_loop(
            model, tcfg, params, opt_state, batches, steps=half,
            checkpointer=ckpt, checkpoint_every=max(10, half // 2),
            watchdog=watchdog, log_every=25, train_step=step_fn)
        ckpt.save(half, params, opt_state, block=True)

        # --- simulated node failure: restart from checkpoint ----------------
        print(f"\n-- simulated failure at step {half}; "
              "restoring and continuing --\n")
        fresh_params = model.init(jax.random.PRNGKey(0))
        fresh_opt = opt.init(fresh_params)
        tree, resumed = ckpt.restore({"params": fresh_params,
                                      "opt_state": fresh_opt})
        assert resumed == half
        batches = iter(data_lib.PrefetchIterator(stream, start_step=half))
        params, opt_state, hist2 = train_loop(
            model, tcfg, tree["params"], tree["opt_state"], batches,
            steps=args.steps, start_step=half, checkpointer=ckpt,
            checkpoint_every=max(10, half // 2), watchdog=watchdog,
            log_every=25, train_step=step_fn)
        ckpt.wait()   # join async saves before the tempdir is removed

    losses = hist1["loss"] + hist2["loss"]
    print(f"\nloss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({len(losses)} steps, resume at {half} was seamless)")
    assert losses[-1] < losses[0], "training must reduce loss"


if __name__ == "__main__":
    main()
