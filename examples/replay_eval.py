"""Replay evaluation: deviation-from-optimal under *dynamic* prices.

    PYTHONPATH=src python examples/replay_eval.py
    PYTHONPATH=src python examples/replay_eval.py --record  # refresh fixture

How this maps to the paper
--------------------------
The paper's headline result (§III-C, Fig. 2) is an evaluation metric:
over 180 Spark executions, Flora's selections deviate less than 6% on
average from the cost-optimal cluster configuration, across a sweep of
*static* price structures.  This harness re-runs that judgment under
prices that move while jobs are being submitted:

  1. the regenerated 180-execution trace (Table I x Table II) backs a
     live ``SelectionService`` whose price source is a mutable
     ``PriceTable``;
  2. a **recorded price history** (``examples/data/gcp_spot_prices.csv``,
     a captured simulation of spot walks with a discount window and an
     eviction spike — regenerate with ``--record``) streams deltas into
     the ``SelectionDaemon`` while the paper's jobs are re-submitted;
  3. the daemon's decision journal is then **replayed**:
     ``JournalReplayer.audit`` reconstructs the price epoch of every
     decision and verifies each journaled selection is bit-identical to
     a cold ``rank_dense`` at that epoch (the reprice path's end-to-end
     consistency check);
  4. ``JournalReplayer.evaluate`` scores the history: realized cost of
     each selection vs a per-epoch oracle (sees the full runtime/price
     matrix at that epoch — the moving equivalent of the paper's
     "cost-optimal configuration") and vs a static-price oracle (picked
     once under the base prices, pays the live prices — what a
     selector that ignores the market would have done).

The gap between ``mean deviation`` and ``static-oracle deviation`` is
the value of repricing: Fig. 2's x-axis varied the price *structure*
statically; here the structure varies per epoch and Flora tracks it.
"""
import argparse
import os
import sys

from repro.core import costmodel, spark_sim
from repro.core.trace import JobClass
from repro.market import (JournalReplayer, MarketEvent, RecordedPriceFeed,
                          SelectionDaemon, SimulatedSpotFeed, record_feed,
                          synthetic_stream)
from repro.selector import (GcpVmCatalog, PriceTable, ProfilingStore,
                            SelectionService)

FIXTURE = os.path.join(os.path.dirname(os.path.abspath(__file__)), "data",
                       "gcp_spot_prices.csv")


def build_service(backend=None):
    """The paper universe (Tables I x II) behind a live price table."""
    trace = spark_sim.generate_trace(seed=0)
    store = ProfilingStore.from_trace(trace)
    catalog = GcpVmCatalog(trace.configs, costmodel.LinearPriceModel())
    service = SelectionService(catalog, store,
                               PriceTable.from_catalog(catalog),
                               backend=backend)
    return trace, service


def record_fixture(service, path: str, ticks: int = 40) -> None:
    """Capture the reference simulated market to the bundled CSV."""
    base = {c: service.price_source[c] for c in service.catalog.ids()}
    sim = SimulatedSpotFeed(
        base, seed=11, change_fraction=0.25, volatility=0.08,
        events=[MarketEvent("us-central1", start_tick=8, duration=10,
                            factor=0.55, kind="discount"),
                MarketEvent("europe-west3", start_tick=20, duration=6,
                            factor=2.5, kind="eviction")])
    record_feed(sim, ticks, path)
    print(f"recorded {ticks} ticks -> {path}")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--prices", default=FIXTURE,
                    help="recorded-price CSV (default: bundled fixture)")
    ap.add_argument("--events", type=int, default=400)
    ap.add_argument("--seed", type=int, default=3)
    ap.add_argument("--record", action="store_true",
                    help="regenerate the bundled fixture and exit")
    ap.add_argument("--backend", default=None, choices=["numpy", "jax"],
                    help="ranking backend (default: FLORA_RANK_BACKEND "
                         "env var, else numpy); jax serves the "
                         "accelerator-resident float32 path and the "
                         "audit runs in tolerance mode (DESIGN.md §9)")
    args = ap.parse_args()

    trace, service = build_service(backend=args.backend)
    if args.record:
        record_fixture(service, args.prices)
        return 0

    feed = RecordedPriceFeed.load(args.prices)
    print(f"recorded history: {feed.ticks} ticks, "
          f"{len(feed.config_ids())} configs quoted")
    daemon = SelectionDaemon(service, feed)
    stats = daemon.run(synthetic_stream(
        [j.name for j in trace.jobs], args.events, seed=args.seed,
        tick_fraction=0.15))
    print(f"served {stats.events} events: {stats.decisions} decisions, "
          f"{stats.epochs} price epochs, {stats.deltas} deltas "
          f"({service.reprice_refreshes} incremental refreshes)")

    replayer = JournalReplayer(service.store, daemon.journal_dump())
    audit = replayer.audit()
    mode = "bit-identical" if audit.contract.bit_identical else \
        "within tolerance"
    print(f"\njournal audit ({replayer.backend} backend, "
          f"{'exact' if audit.contract.bit_identical else 'tolerance'} "
          f"mode): {audit.decisions} decisions re-ranked cold at "
          f"{audit.ticks} reconstructed epochs -> "
          f"{f'all {mode}' if audit.ok else 'MISMATCH'}")
    if audit.drift:
        scores = sum(1 for d in audit.drift if d.field == "score-drift")
        ties = sum(1 for d in audit.drift if d.field == "winner-tie")
        print(f"  float32 drift surfaced (within contract): "
              f"{scores} score drifts, {ties} near-tie winner swaps")
    if not audit.ok:
        for m in audit.mismatches[:5]:
            print(f"  seq {m.seq} job {m.job_id}: {m.field} journaled "
                  f"{m.journaled!r} != replayed {m.replayed!r}")
        return 1

    ev = replayer.evaluate()
    print(f"\ndeviation from the per-epoch cost optimum "
          f"({len(ev.outcomes)} decisions, paper's static bar: <6%):")
    print(f"  Flora (live repricing):  mean {ev.mean_deviation:7.2%}   "
          f"max {ev.max_deviation:7.2%}")
    print(f"  static-price oracle:     mean {ev.static_mean_deviation:7.2%}"
          f"   (picked once at base prices)")
    print(f"  realized ${ev.realized_total:.2f} vs oracle "
          f"${ev.oracle_total:.2f} vs static ${ev.static_total:.2f}")
    for klass in (JobClass.A, JobClass.B):
        devs = [o.deviation for o in ev.outcomes if o.job_class is klass]
        if devs:
            print(f"  class {klass.value}: mean "
                  f"{sum(devs) / len(devs):7.2%} over {len(devs)} decisions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
