"""Quickstart: Flora end-to-end on the regenerated GCP trace.

    PYTHONPATH=src python examples/quickstart.py

Steps 0-2 of the paper: profile (regenerate the 180-execution trace),
classify a submitted job, rank the ten cloud configurations under current
prices, and compare against the baselines of Table IV.  Selection goes
through the unified :mod:`repro.selector` API — the same
catalog/store/rank/service stack the TPU-side adaptation uses.
"""
from repro.core import costmodel, evaluate, spark_sim
from repro.core.trace import JobClass, JobSpec
from repro.selector import GcpVmCatalog, ProfilingStore, SelectionService


def main() -> None:
    # Step 0 — infrastructure profiling (regenerated offline trace)
    trace = spark_sim.generate_trace(seed=0)
    price = costmodel.LinearPriceModel()   # GCP n2, Frankfurt, 2024-12-01
    catalog = GcpVmCatalog(trace.configs, price)
    store = ProfilingStore.from_trace(trace)
    service = SelectionService(catalog, store, price)
    print(f"profiled {len(store)} executions over "
          f"{len(catalog)} configurations\n")

    # Step 1 — the user submits a job and annotates its class
    job = JobSpec("PageRank", "Graph", 150, JobClass.A)   # unseen algorithm
    print(f"submitted: {job.name}, annotated class {job.job_class.value} "
          "(memory-demanding: repeated specific data loading)")

    # Step 2 — rank configurations by summed normalized class cost
    decision = service.submit(job.name, annotation=job.job_class)
    print("\nranking (lower score = better):")
    for r in decision.ranking[:4]:
        cfg = catalog.entry(r.config_id)
        print(f"  #{cfg.index:<2d} {cfg.instance_type:15s} x{cfg.scale_out:<3d}"
              f" score={r.score:7.3f}  ({catalog.hourly_cost(r.config_id):.2f}"
              " $/h)")
    best = decision.entry
    print(f"\nFlora selects #{best.index} ({best.name}) "
          f"at {decision.hourly_cost:.2f} $/h")

    # repeat submissions of the same class are cache hits (price epoch 0)
    again = service.submit("PageRank/300GiB", annotation=JobClass.A)
    print(f"second class-A submission from cache: {again.from_cache}")

    # evaluation against the trace (Table IV)
    print("\nTable IV (mean normalized cost, 1.0 = optimal):")
    for r in evaluate.table4(trace, price):
        print(f"  {r.name:24s} {r.mean_norm_cost:.3f}")


if __name__ == "__main__":
    main()
