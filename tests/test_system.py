"""End-to-end system behaviour tests."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.data import pipeline as data_lib
from repro.models import build_model
from repro.models.types import ShapeSpec
from repro.train.checkpoint import Checkpointer
from repro.train.train_loop import TrainConfig, make_train_step


def test_train_checkpoint_restart_continues_identically(tmp_path):
    """Crash/restart produces bit-identical training to an uninterrupted
    run: same data (seekable stream), same params (checkpoint restore)."""
    cfg = C.reduced(C.get("deepseek-7b"))
    model = build_model(cfg)
    shape = ShapeSpec("sys", 32, 4, "train")
    stream = data_lib.for_model(cfg, shape, seed=7)
    tcfg = TrainConfig(peak_lr=1e-3, warmup_steps=2, total_steps=20)
    step_fn, opt = make_train_step(model, tcfg)
    step_fn = jax.jit(step_fn)

    def put(b):
        return {k: jnp.asarray(v) for k, v in b.items()}

    # uninterrupted run: 6 steps
    p = model.init(jax.random.PRNGKey(0))
    s = opt.init(p)
    for i in range(6):
        p, s, _ = step_fn(p, s, put(stream.batch_at(i)))
    ref_leaves = jax.tree_util.tree_leaves(p)

    # interrupted run: 3 steps, checkpoint, "crash", restore, 3 more
    ck = Checkpointer(str(tmp_path))
    p2 = model.init(jax.random.PRNGKey(0))
    s2 = opt.init(p2)
    for i in range(3):
        p2, s2, _ = step_fn(p2, s2, put(stream.batch_at(i)))
    ck.save(3, p2, s2, block=True)
    del p2, s2
    tree, start = ck.restore({"params": model.init(jax.random.PRNGKey(0)),
                              "opt_state": opt.init(
                                  model.init(jax.random.PRNGKey(0)))})
    p3, s3 = tree["params"], tree["opt_state"]
    for i in range(start, 6):
        p3, s3, _ = step_fn(p3, s3, put(stream.batch_at(i)))
    for a, b in zip(ref_leaves, jax.tree_util.tree_leaves(p3)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=1e-6, rtol=1e-5)


def test_data_pipeline_determinism_and_host_sharding():
    cfg = C.reduced(C.get("qwen3-1.7b"))
    shape = ShapeSpec("sys", 16, 8, "train")
    a = data_lib.for_model(cfg, shape, seed=3).batch_at(5)
    b = data_lib.for_model(cfg, shape, seed=3).batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # two hosts draw disjoint slices of the same global batch space
    h0 = data_lib.for_model(cfg, shape, seed=3, host_count=2, host_index=0)
    h1 = data_lib.for_model(cfg, shape, seed=3, host_count=2, host_index=1)
    b0, b1 = h0.batch_at(0), h1.batch_at(0)
    assert b0["tokens"].shape[0] == 4
    assert not np.array_equal(b0["tokens"], b1["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


def test_prefetch_iterator_yields_in_order():
    cfg = C.reduced(C.get("qwen3-1.7b"))
    shape = ShapeSpec("sys", 16, 4, "train")
    stream = data_lib.for_model(cfg, shape, seed=1)
    it = data_lib.PrefetchIterator(stream, start_step=2)
    got = next(it)
    expect = stream.batch_at(2)
    np.testing.assert_array_equal(np.asarray(got["tokens"]),
                                  expect["tokens"])
    it.close()


def test_dryrun_report_flows_into_flora_selection(tmp_path):
    """The launch pipeline contract: dryrun JSON -> records -> selection."""
    from repro.core.costmodel import TpuPriceModel
    from repro.core.tpu_flora import (MeshOption, TpuFlora,
                                      records_from_dryrun_report)
    report = {"cells": [
        {"arch": "a", "shape": "train_4k", "mesh": "16x16", "ok": True,
         "roofline": {"compute_s": 0.2, "memory_s": 0.1,
                      "collective_s": 0.05}},
        {"arch": "a", "shape": "train_4k", "mesh": "32x8", "ok": True,
         "roofline": {"compute_s": 0.15, "memory_s": 0.1,
                      "collective_s": 0.02}},
        {"arch": "a", "shape": "decode_32k", "mesh": "16x16", "ok": False,
         "error": "x"},
    ]}
    recs = records_from_dryrun_report(report)
    assert len(recs) == 2           # failed cells are excluded
    assert recs[0].step_seconds == pytest.approx(0.2)
    options = [MeshOption("16x16", "v5e", 256, (16, 16), ("d", "m")),
               MeshOption("32x8", "v5e", 256, (32, 8), ("d", "m"))]
    flora = TpuFlora(options, recs, TpuPriceModel())
    assert flora.select("train_4k").name == "32x8"   # faster, same price


def test_fused_vocab_chunk_loss_matches_plain():
    """The fused head+cross-entropy (vocab_chunk) equals the plain loss
    and produces matching gradients (the beyond-paper memory optimization
    of EXPERIMENTS.md §Perf)."""
    from repro.models import settings as settings_lib
    cfg = C.reduced(C.get("qwen3-1.7b"), vocab=517)   # ragged chunking
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    from repro.configs import shapes as S
    batch = S.make_batch(cfg, ShapeSpec("s", 16, 2, "train"),
                         jax.random.PRNGKey(1))
    batch["labels"] = batch["labels"].at[:, :3].set(-1)   # masked prefix
    loss_fn = lambda p: model.loss(p, batch)[0]
    base, base_g = jax.value_and_grad(loss_fn)(params)
    with settings_lib.use(vocab_chunk=128):
        fused, fused_g = jax.value_and_grad(loss_fn)(params)
    np.testing.assert_allclose(float(base), float(fused), rtol=2e-4)
    for a, b in zip(jax.tree_util.tree_leaves(base_g),
                    jax.tree_util.tree_leaves(fused_g)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-4, rtol=2e-2)
