"""Unit tests for the paper-faithful Flora core.

The hypothesis property tests for the ranking math live in
tests/test_rank_properties.py (they skip when the optional ``hypothesis``
extra is not installed; these paper-claim tests always run).
"""
import math

import pytest

from repro.core import costmodel, evaluate, spark_sim
from repro.core.flora import Flora, rank_generic
from repro.core.trace import (CloudConfig, GCP_CONFIGS, JobClass, JobSpec,
                              PAPER_JOBS, Trace)


@pytest.fixture(scope="module")
def trace():
    return spark_sim.generate_trace(seed=0)


@pytest.fixture(scope="module")
def price():
    return costmodel.LinearPriceModel()


# --- schema / universe ---------------------------------------------------------

def test_paper_universe_shapes(trace):
    assert len(GCP_CONFIGS) == 10
    assert len(PAPER_JOBS) == 18
    assert len(trace.records) == 180
    # Table I class split: 10 class A jobs, 8 class B jobs
    assert sum(j.job_class is JobClass.A for j in PAPER_JOBS) == 10
    assert sum(j.job_class is JobClass.B for j in PAPER_JOBS) == 8


def test_table2_totals():
    # spot-check Table II totals
    c9 = next(c for c in GCP_CONFIGS if c.index == 9)
    assert c9.total_cores == 64 and c9.total_mem_gib == 256
    c1 = next(c for c in GCP_CONFIGS if c.index == 1)
    assert c1.total_cores == 64 and c1.total_mem_gib == 64
    c6 = next(c for c in GCP_CONFIGS if c.index == 6)
    assert c6.total_cores == 128 and c6.total_mem_gib == 128


def test_equal_totals_equal_price(price):
    """Paper §III-D: configs with equal totals cost the same hourly."""
    by_totals = {}
    for c in GCP_CONFIGS:
        by_totals.setdefault((c.total_cores, c.total_mem_gib), []).append(c)
    for group in by_totals.values():
        prices = {round(price(c), 10) for c in group}
        assert len(prices) == 1


def test_trace_roundtrip(trace):
    clone = Trace.from_json(trace.to_json())
    assert len(clone.records) == len(trace.records)
    j, c = trace.jobs[3], trace.configs[5]
    assert clone.runtime_s(j, c) == pytest.approx(trace.runtime_s(j, c))


# --- ranking regressions ---------------------------------------------------------

def test_rank_unprofiled_config_ranks_last():
    """Regression: a config with zero profiled entries must rank last with
    score +inf, not win the argmin at the initial 0.0."""
    rt = {("j1", "c1"): 1.0, ("j1", "c2"): 2.0, ("j2", "c1"): 3.0}
    ranked = rank_generic(rt, ["j1", "j2"], ["ghost", "c1", "c2"],
                          lambda c: 1.0)
    assert ranked[0].config_id == "c1"
    assert ranked[-1].config_id == "ghost"
    assert ranked[-1].score == float("inf")
    assert ranked[-1].mean_norm_cost == float("inf")


# --- paper-claim reproduction ----------------------------------------------------

def test_flora_selects_9_for_class_a_and_1_for_class_b(trace, price):
    """§III-C: 'Flora ended up choosing configuration #9 for all jobs of
    class A' and '#1 configuration for those [class B] jobs'."""
    flora = Flora(trace, price)
    for job in trace.jobs:
        sel = flora.select_for_job(job)
        expected = 9 if job.job_class is JobClass.A else 1
        assert sel.index == expected, (job.name, sel.index)


def test_flora_mean_norm_cost_near_optimal(trace, price):
    """Paper: 1.052 mean, <1.24 max.  Regenerated trace: allow slack but
    Flora must stay near-optimal and beat every baseline."""
    results = {r.name: r for r in evaluate.table4(trace, price)}
    flora = results["Flora"]
    assert flora.mean_norm_cost < 1.15
    for name, r in results.items():
        if name != "Flora":
            assert flora.mean_norm_cost < r.mean_norm_cost, name


def test_table4_orderings(trace, price):
    """Key qualitative orderings of Table IV."""
    res = {r.name: r for r in evaluate.table4(trace, price)}
    # Flora beats Fw1C beats the static/random baselines
    assert res["Flora"].mean_norm_cost < res["Flora with one class"].mean_norm_cost
    for b in ("random selection", "minimize CPU", "minimize memory",
              "maximize CPU", "maximize memory"):
        assert res["Flora with one class"].mean_norm_cost < res[b].mean_norm_cost
    # maximize CPU gives the best runtime of the static baselines (1.346)
    assert res["maximize CPU"].mean_norm_runtime < 1.5
    # minimize CPU gives by far the worst runtime (7.837)
    assert res["minimize CPU"].mean_norm_runtime > 3.0


def test_leave_one_algorithm_out(trace, price):
    """Selection for Sort never uses Sort profiling data (§III-A)."""
    flora = Flora(trace, price)
    ranked = flora.rank(JobClass.A, exclude_algorithms=("Sort",))
    # scores must equal ranking computed on a trace with Sort removed
    pruned = Trace(trace.configs,
                   [r for r in trace.records if r.job.algorithm != "Sort"])
    ranked2 = Flora(pruned, price).rank(JobClass.A)
    assert [r.config_id for r in ranked] == [r.config_id for r in ranked2]
    for a, b in zip(ranked, ranked2):
        assert a.score == pytest.approx(b.score)


def test_misclassification_robustness(trace, price):
    """§III-E: coin-flip users still beat random selection; the crossover
    against Fw1C happens at a nonzero misclassification fraction."""
    fr = [0.0, 0.5, 1.0]
    curves = evaluate.fig3_misclassification(trace, price, fr)
    coin_flip = curves["Flora"][1]
    assert coin_flip < curves["random selection"][0]
    x = evaluate.crossover_fraction(trace, price)
    assert 0.05 < x < 0.6


def test_fig2_price_sweep_flora_wins_everywhere(trace, price):
    """§III-D: Flora adapts to changing resource cost structures."""
    ratios = [0.01, 0.1, 1.0, 10.0]
    curves = evaluate.fig2_price_sweep(trace, price, ratios)
    for i, r in enumerate(ratios):
        for name, vals in curves.items():
            if name != "Flora":
                assert curves["Flora"][i] <= vals[i] + 1e-9, (r, name)


def test_price_sensitivity_changes_selection(trace):
    """When memory is near-free, richer-memory configs should become
    (weakly) more attractive: the class-B choice must not get *smaller*
    in memory as the memory price drops to ~zero."""
    base = costmodel.LinearPriceModel()
    cheap_mem = base.with_mem_to_cpu_ratio(0.001)
    pricey_mem = base.with_mem_to_cpu_ratio(10.0)
    f_cheap = Flora(trace, cheap_mem).select(JobClass.A)
    f_pricey = Flora(trace, pricey_mem).select(JobClass.A)
    assert f_cheap.total_mem_gib >= f_pricey.total_mem_gib


# --- trace statistics vs Table III ------------------------------------------------

def test_trace_stats_magnitudes(trace, price):
    """Regenerated trace matches Table III magnitudes (documented
    deviations in EXPERIMENTS.md)."""
    st_ = trace.stats(price)
    assert 1000 < st_["runtime_s"]["mean"] < 4000        # paper: 1834.8
    assert 100 < st_["runtime_s"]["min"] < 500           # paper: 141.7
    assert 10000 < st_["runtime_s"]["max"] < 40000       # paper: 21714.7
    assert 0.7 < st_["cost_usd"]["mean"] < 3.0           # paper: 1.409
    assert 0.05 < st_["cost_usd"]["min"] < 0.5           # paper: 0.177


def test_spark_sim_calibration_pinned(trace, price):
    """Satellite (ISSUE 3): the calibration drift vs paper Table III
    (cost mean 1.861 vs 1.409 — heavy-tail thrash inflation, analyzed in
    the spark_sim module docstring) is *pinned*: moving any model
    constant now fails here, so the gap can only change deliberately —
    update both the pins and the docstring table in the same commit."""
    st_ = trace.stats(price)
    pins = {
        ("cost_usd", "mean"): 1.86134,       # paper: 1.409
        ("cost_usd", "min"): 0.114962,       # paper: 0.177
        ("runtime_s", "mean"): 2845.05,      # paper: 1834.8
        ("runtime_s", "min"): 125.882,       # paper: 141.7
        ("runtime_s", "max"): 24985.1,       # paper: 21714.7
    }
    for (table, stat), value in pins.items():
        assert st_[table][stat] == pytest.approx(value, rel=1e-4), \
            (table, stat)


def test_juggler_only_iterative_ml(trace, price):
    from repro.core.baselines import Juggler
    jug = Juggler(trace.configs, price)
    assert jug.select(JobSpec("Grep", "Text", 3010, JobClass.B)) is None
    sel = jug.select(JobSpec("KMeans", "Vector", 204, JobClass.A))
    assert sel is not None and sel.total_mem_gib >= 200
