"""Sharding rule resolution tests (mesh built from 16 CPU devices is not
needed — spec_for only reads mesh.shape, so we use a fake)."""
import types

import pytest
from jax.sharding import PartitionSpec as P

import repro.configs as C
from repro.sharding import rules as R


class FakeMesh:
    """Only `.shape` (a dict) is consulted by spec_for."""
    def __init__(self, **shape):
        self.shape = shape


MESH = FakeMesh(data=16, model=16)
MESH_MP = FakeMesh(pod=2, data=16, model=16)


def test_basic_tp_fsdp_resolution():
    rules = R.production_rules()
    # attention q projection: embed->data (FSDP), heads->model
    spec = R.spec_for((4096, 32, 128), ("embed", "heads", "head_dim"),
                      rules, MESH)
    assert spec == P("data", "model")
    # mlp weight
    assert R.spec_for((4096, 11008), ("embed", "mlp"), rules, MESH) == \
        P("data", "model")
    # moe experts 2D-sharded
    assert R.spec_for((128, 4096, 768), ("experts", "embed", "mlp"),
                      rules, MESH) == P("model", "data")


def test_divisibility_fallback_replicates():
    rules = R.production_rules()
    # llama4: 40 heads on 16-way model -> heads replicated, head_dim takes it
    spec = R.spec_for((5120, 40, 128), ("embed", "heads", "head_dim"),
                      rules, MESH)
    assert spec == P("data", None, "model")
    # 8 kv heads -> falls through to head_dim
    spec = R.spec_for((5120, 8, 128), ("embed", "kv_heads", "head_dim"),
                      rules, MESH)
    assert spec == P("data", None, "model")


def test_mesh_axis_used_once():
    rules = R.production_rules()
    # heads takes model; head_dim must NOT reuse it
    spec = R.spec_for((4096, 32, 128), (None, "heads", "head_dim"),
                      rules, MESH)
    assert spec == P(None, "model")


def test_multi_pod_batch_spans_pod_and_data():
    rules = R.production_rules(multi_pod=True)
    spec = R.spec_for((256, 4096), ("batch", "seq"), rules, MESH_MP)
    assert spec == P(("pod", "data"))
    # batch=1 (long_500k) falls back to replication
    spec = R.spec_for((1, 4096), ("batch", "seq"), rules, MESH_MP)
    assert spec == P()


def test_arch_overrides_consistency():
    # deepseek: H=G=32 -> heads sharded, head_dim off
    cfg = C.get("deepseek-7b")
    assert R.arch_overrides(cfg, 16) == {"head_dim": None}
    # qwen3: H=16 ok, G=8 not -> replicate kv for train; head_dim for decode
    cfg = C.get("qwen3-1.7b")
    assert R.arch_overrides(cfg, 16, "train") == {"head_dim": None}
    assert R.arch_overrides(cfg, 16, "decode") == \
        {"heads": None, "kv_heads": None}
    # llama4: 40 heads -> fully replicated attention on tp=16...
    cfg = C.get("llama4-maverick-400b-a17b")
    assert R.arch_overrides(cfg, 16, "train") == \
        {"heads": None, "kv_heads": None, "head_dim": None}
    # ...but clean head sharding on tp=8 (the Flora mesh-selection story)
    assert R.arch_overrides(cfg, 8, "train") == {"head_dim": None}


def test_every_arch_has_some_model_sharding():
    """On the production mesh no arch may end up fully replicated: at
    minimum the FFN/vocab dims must shard over the model axis."""
    rules = R.production_rules()
    from repro.models import build_model
    from repro.models.types import ParamSpec
    import jax
    for name in C.ARCH_NAMES:
        cfg = C.get(name)
        rules_a = rules.with_overrides(**R.arch_overrides(cfg, 16))
        specs = build_model(cfg).param_specs()
        leaves = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, ParamSpec))
        sharded = sum(
            1 for s in leaves
            if any(e is not None
                   for e in R.spec_for(s.shape, s.axes, rules_a, MESH)))
        assert sharded / len(leaves) > 0.3, name


def test_bytes_per_device_accounting():
    rules = R.production_rules()
    from repro.models.types import ParamSpec
    tree = {"w": ParamSpec((1024, 1024), ("embed", "mlp"))}   # f32
    per_dev = R.bytes_per_device(tree, rules, MESH)
    assert per_dev == 1024 * 1024 * 4 // 256
