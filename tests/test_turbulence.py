"""Turbulence harness tests (ISSUE 10).

Pins the three contracts DESIGN.md §15 rests on:

  * **byte-determinism**: every turbulence preset is a pure function of
    ``(seed, knobs)`` — two independently constructed markets agree
    event for event and quote for quote across 200 ticks, including
    through a ``record_feed`` round-trip (hypothesis property plus an
    example-based variant that runs without hypothesis);
  * **the polling adapter**: every payload shape a billing API can
    return either parses to a clean ``PriceDelta`` batch or raises a
    *typed* ``FeedError`` (timeout / malformed / partial), failures
    never advance the tick index, and the backoff counter resets after
    recovery (the ISSUE 8 regression shape, now over a polled feed);
  * **transport-independence**: the identical sweep code path over a
    ``RecordedPriceFeed`` fixture and a stubbed ``PollingPriceFeed``
    serving the same quotes produces byte-identical journals and
    identical deviation curves.
"""
import math

import pytest

from hyputil import HAVE_HYPOTHESIS, given, settings, st
from repro.core.evaluate import TurbulencePoint, turbulence_curves
from repro.core.trace import JobClass
from repro.market import (FeedError, LaggedPriceFeed, MarketEvent,
                          PollingPriceFeed, PriceDelta, RecordedPriceFeed,
                          SelectionDaemon, ServeFrontend, SimulatedSpotFeed,
                          Submission, TURBULENCE_PRESETS, Tick,
                          TurbulencePreset, correlated_spike_events,
                          eviction_storm_events, flash_crash_events,
                          make_market, record_feed, run_point, run_sweep)
from repro.market.feed import DEFAULT_REGIONS
from repro.market.turbulence import preset as resolve_preset
from repro.selector import (IdentityCatalog, PriceTable, ProfilingStore,
                            SelectionService, backend_available)

N_CFGS = 8


def _universe():
    ids = [f"c{i}" for i in range(N_CFGS)]
    store = ProfilingStore(config_ids=ids)
    for j in range(6):
        klass = JobClass.A if j % 2 else JobClass.B
        for i, c in enumerate(ids):
            store.add(f"j{j}", c,
                      0.2 + ((j * 7 + i * 5) % 13) / 6.0
                      + (0.4 if klass is JobClass.A and i % 2 == 0
                         else 0.0),
                      job_class=klass, group=f"g{j % 3}")
    base = {c: 1.0 + (i * 5 % 11) for i, c in enumerate(ids)}
    return store, ids, base


def _stream(n_ticks=30):
    for t in range(n_ticks):
        yield Tick()
        if t % 2 == 0:
            yield Submission(f"j{(t // 2) % 6}")


def _service(store, ids, base, backend="numpy"):
    return SelectionService(IdentityCatalog(ids), store, PriceTable(base),
                            backend=backend)


# --- adversarial event generators --------------------------------------------

def test_eviction_storm_covers_every_region_with_staggered_starts():
    events = eviction_storm_events(7, 100, storms=3, severity=3.0)
    assert len(events) == 3 * len(DEFAULT_REGIONS)
    assert events == eviction_storm_events(7, 100, storms=3, severity=3.0)
    by_storm = [events[i:i + len(DEFAULT_REGIONS)]
                for i in range(0, len(events), len(DEFAULT_REGIONS))]
    for storm in by_storm:
        assert {e.region for e in storm} == set(DEFAULT_REGIONS)
        starts = [e.start_tick for e in storm]
        assert max(starts) - min(starts) <= 3      # rolls, not teleports
        assert all(e.kind == "eviction" for e in storm)
        assert all(3.0 <= e.factor < 6.0 for e in storm)
        assert len({e.duration for e in storm}) == 1   # one window


def test_correlated_spikes_always_hit_at_least_two_regions_same_tick():
    events = correlated_spike_events(3, 80, spikes=5, severity=2.5)
    spikes = {}
    for e in events:
        spikes.setdefault((e.start_tick, e.duration), []).append(e)
    assert len(spikes) == 5
    for members in spikes.values():
        assert len(members) >= 2                   # the correlation bar
        regions = {e.region for e in members}
        assert set(DEFAULT_REGIONS[:2]) <= regions  # anchors always join
        assert all(e.factor >= 2.5 for e in members)


def test_flash_crash_pairs_each_crash_with_an_overshoot_recovery():
    events = flash_crash_events(9, 60, crashes=2, depth=0.25,
                                overshoot=1.8)
    assert len(events) == 2 * 2 * len(DEFAULT_REGIONS)
    crashes = [e for e in events if e.kind == "flash-crash"]
    recoveries = [e for e in events if e.kind == "recovery"]
    assert len(crashes) == len(recoveries)
    for c, r in zip(crashes, recoveries):
        assert c.factor == 0.25 and r.factor == 1.8
        assert r.start_tick == c.start_tick + c.duration  # back-to-back
        assert r.duration == max(2, c.duration // 2)


@pytest.mark.parametrize("gen", [eviction_storm_events,
                                 correlated_spike_events,
                                 flash_crash_events])
def test_generators_reject_nonpositive_horizons(gen):
    with pytest.raises(ValueError):
        gen(0, 0)


def test_flash_crash_rejects_bad_depth():
    with pytest.raises(ValueError):
        flash_crash_events(0, 50, depth=1.5)


# --- presets + markets -------------------------------------------------------

def test_preset_resolver_rejects_unknown_names():
    assert resolve_preset("calm") is TURBULENCE_PRESETS["calm"]
    custom = TurbulencePreset("mine", level=9.0)
    assert resolve_preset(custom) is custom
    with pytest.raises(ValueError, match="calm"):
        resolve_preset("hurricane")


def test_preset_levels_are_distinct_and_ordered():
    levels = [p.level for p in sorted(TURBULENCE_PRESETS.values(),
                                      key=lambda p: p.level)]
    assert levels == sorted(set(levels))
    assert levels[0] == 0.0 and TURBULENCE_PRESETS["calm"].level == 0.0


def test_lagged_feed_is_a_pure_reindexing():
    _, _, base = _universe()
    text = record_feed(SimulatedSpotFeed(base, seed=4,
                                         change_fraction=0.5), 20)
    lagged = LaggedPriceFeed(RecordedPriceFeed.loads(text), 3)
    plain = RecordedPriceFeed.loads(text)
    assert lagged.poll(0) == lagged.poll(1) == lagged.poll(2) == ()
    for t in range(3, 20):
        assert lagged.poll(t) == plain.poll(t - 3)
    with pytest.raises(ValueError):
        LaggedPriceFeed(plain, -1)
    with pytest.raises(ValueError):
        LaggedPriceFeed(plain, 1.5)


def _assert_market_determinism(name, seed, ticks=200):
    _, _, base = _universe()
    m1 = make_market(name, base, seed=seed, ticks=ticks)
    m2 = make_market(name, base, seed=seed, ticks=ticks)
    assert m1.events == m2.events          # identical MarketEvent seqs
    t1 = record_feed(m1.feed, ticks)
    assert t1 == record_feed(m2.feed, ticks)     # identical quotes
    # the round-trip: replaying the recording re-records the bytes, and
    # a third independent market agrees with the replay batch for batch
    replay = RecordedPriceFeed.loads(t1)
    assert record_feed(replay, ticks) == t1
    m3 = make_market(name, base, seed=seed, ticks=ticks)
    assert all(replay.poll(t) == m3.feed.poll(t) for t in range(ticks))


@pytest.mark.parametrize("name", sorted(TURBULENCE_PRESETS))
def test_every_preset_is_byte_deterministic(name):
    _assert_market_determinism(name, seed=23)


@settings(max_examples=12, deadline=None)
@given(name=st.sampled_from(sorted(TURBULENCE_PRESETS)),
       seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_property_presets_byte_deterministic_across_200_ticks(name, seed):
    """Hypothesis property: any (preset, seed) pair yields two
    independently constructed generators with identical MarketEvent
    sequences and byte-identical 200-tick quote streams, preserved
    across a record_feed round-trip."""
    _assert_market_determinism(name, seed)


# --- the polling adapter -----------------------------------------------------

def test_polling_feed_accepts_every_documented_payload_shape():
    expected = (PriceDelta("c0", 2.0), PriceDelta("c1", 3.5))
    for payload in (
            [{"config_id": "c0", "price": 2.0, "currency": "USD"},
             {"config_id": "c1", "price": 3.5}],
            [("c0", 2.0), ("c1", 3.5)],
            list(expected),
            {"quotes": [("c0", 2.0), ("c1", 3.5)], "next_page": None}):
        feed = PollingPriceFeed(lambda t, p=payload: p)
        assert feed.poll(0) == expected
        assert (feed.polls, feed.batches, feed.failures) == (1, 1, 0)
    empty = PollingPriceFeed(lambda t: [])
    assert empty.poll(0) == ()
    assert (empty.polls, empty.batches) == (1, 0)   # success, no batch


@pytest.mark.parametrize("payload,kind", [
    ("c0,2.0", "malformed"),                        # string, not quotes
    (None, "malformed"),
    (42, "malformed"),                              # not iterable
    ({"prices": []}, "malformed"),                  # envelope, no quotes
    ([{"price": 2.0}], "malformed"),                # entry w/o config_id
    ([("c0", 2.0, "extra")], "malformed"),          # not a pair
    ([(["c0"], 2.0)], "malformed"),                 # unhashable id
    ([("c0", "2.0")], "malformed"),                 # non-numeric price
    ([("c0", True)], "malformed"),                  # bool is not a price
    ([("c0", float("nan"))], "malformed"),
    ([("c0", -1.0)], "malformed"),
    ([("c0", 2.0), ("c0", 3.0)], "malformed"),      # duplicate config
    ([{"config_id": "c0"}], "partial"),             # price absent
    ([{"config_id": "c0", "price": None}], "partial"),
    ([("c0", None)], "partial"),
])
def test_polling_feed_failure_modes_raise_typed_feed_errors(payload, kind):
    feed = PollingPriceFeed(lambda t: payload)
    with pytest.raises(FeedError, match=kind) as exc:
        feed.poll(5)
    assert exc.value.tick == 5
    assert (feed.polls, feed.batches, feed.failures) == (0, 0, 1)


def test_polling_feed_wraps_poller_exceptions_and_times_out():
    def boom(tick):
        raise ConnectionError("socket reset")
    feed = PollingPriceFeed(boom)
    with pytest.raises(FeedError, match="ConnectionError"):
        feed.poll(0)
    assert feed.failures == 1

    clock = iter([0.0, 10.0, 20.0, 20.1]).__next__
    slow = PollingPriceFeed(lambda t: [("c0", 2.0)], timeout_s=5.0,
                            clock=clock)
    with pytest.raises(FeedError, match="timed-out"):
        slow.poll(0)                    # 10s elapsed > 5s budget
    assert slow.poll(1) == (PriceDelta("c0", 2.0),)   # 0.1s is fine
    assert (slow.polls, slow.failures) == (1, 1)
    with pytest.raises(ValueError):
        PollingPriceFeed(lambda t: [], timeout_s=0.0)


def test_polling_failures_never_advance_the_tick_index():
    """The ticker-level contract over a polled feed: a failed poll
    leaves tick_count where it was, the daemon journals a feed-error
    record, and the retry serves the *same* tick's batch."""
    store, ids, base = _universe()
    text = record_feed(SimulatedSpotFeed(base, seed=4,
                                         change_fraction=0.9), 6)
    replay = RecordedPriceFeed.loads(text)
    outages = {2: 2}                      # tick 2 fails twice

    def poller(tick):
        if outages.get(tick, 0) > 0:
            outages[tick] -= 1
            raise ConnectionError("transient outage")
        return replay.poll(tick)

    daemon = SelectionDaemon(_service(store, ids, base),
                             PollingPriceFeed(poller))
    daemon.handle(Tick())
    daemon.handle(Tick())
    assert daemon.ticker.tick_count == 2
    for _ in range(2):                    # both outage attempts
        assert daemon.handle(Tick()) is None
        assert daemon.ticker.tick_count == 2    # index not consumed
    daemon.handle(Tick())                 # retry lands tick 2 itself
    assert daemon.ticker.tick_count == 3
    assert daemon.stats.feed_errors == 2
    records = [r for r in daemon.journal_dump().splitlines()
               if '"feed-error"' in r]
    assert len(records) == 2


def test_polling_backoff_resets_after_recovery():
    """The ISSUE 8 fail-recover-fail regression shape, driven through a
    polled feed: consecutive-failure backoff doubles during an outage
    and restarts from base after the first good poll — a second outage
    never inherits the inflated delay."""
    store, ids, base = _universe()
    text = record_feed(SimulatedSpotFeed(base, seed=4,
                                         change_fraction=0.9), 5)
    replay = RecordedPriceFeed.loads(text)
    outages = {1: 2, 3: 1}

    def poller(tick):
        if outages.get(tick, 0) > 0:
            outages[tick] -= 1
            raise TimeoutError("billing API stalled")
        return replay.poll(tick)

    fe = ServeFrontend(_service(store, ids, base),
                       PollingPriceFeed(poller), workers=1,
                       backoff_base=0.01, backoff_cap=0.5)
    assert fe.step_tick() == "tick"                  # tick 0
    delays = []
    while fe.step_tick() == "feed-error":            # tick 1: outage
        delays.append(fe.backoff_delay())
    assert delays == [pytest.approx(0.01), pytest.approx(0.02)]
    assert fe.backoff_delay() == pytest.approx(0.01)  # reset on success
    assert fe.ticker.tick_count == 2
    fe.step_tick()                                   # tick 2
    assert fe.step_tick() == "feed-error"            # second outage
    assert fe.backoff_delay() == pytest.approx(0.01)  # 1 again, never 3
    assert fe.step_tick() == "tick"
    assert fe.ticker.tick_count == 4
    fe.close()


# --- transport-independence + the sweep --------------------------------------

def test_recorded_and_polled_feeds_produce_identical_journals_and_curves():
    store, ids, base = _universe()
    market = make_market("eviction_storm", base, seed=6, ticks=30)
    text = record_feed(market.raw, 30)

    d1 = SelectionDaemon(_service(store, ids, base),
                         RecordedPriceFeed.loads(text))
    d1.run(_stream(30))
    replay = RecordedPriceFeed.loads(text)
    d2 = SelectionDaemon(_service(store, ids, base),
                         PollingPriceFeed(lambda t: {"quotes": [
                             {"config_id": d.config_id, "price": d.price}
                             for d in replay.poll(t)]}))
    d2.run(_stream(30))
    assert d1.journal_dump() == d2.journal_dump()    # byte-identical

    p1 = run_point(_service(store, ids, base),
                   RecordedPriceFeed.loads(text), _stream(30),
                   preset_name="eviction_storm", level=3.0,
                   truth=RecordedPriceFeed.loads(text))
    replay2 = RecordedPriceFeed.loads(text)
    p2 = run_point(_service(store, ids, base),
                   PollingPriceFeed(lambda t: list(replay2.poll(t))),
                   _stream(30), preset_name="eviction_storm", level=3.0,
                   feed_kind="polled",
                   truth=RecordedPriceFeed.loads(text))
    assert p1.evaluation.summary() == p2.evaluation.summary()
    assert p1.mean_deviation == p2.mean_deviation
    assert p1.audit_ok and p2.audit_ok
    assert (p1.feed_kind, p2.feed_kind) == ("recorded", "polled")


def test_run_point_truth_judge_matches_journal_on_unlagged_feeds():
    store, ids, base = _universe()
    market = make_market("volatile", base, seed=2, ticks=30)
    text = record_feed(market.raw, 30)
    point = run_point(_service(store, ids, base),
                      RecordedPriceFeed.loads(text), _stream(30),
                      preset_name="volatile", level=1.0,
                      truth=RecordedPriceFeed.loads(text))
    assert point.audit_ok
    assert point.truth_mean_deviation == point.mean_deviation
    summary = point.summary()
    assert summary["preset"] == "volatile"
    assert summary["truth_mean_deviation"] == point.mean_deviation
    no_truth = run_point(_service(store, ids, base),
                         RecordedPriceFeed.loads(text), _stream(30))
    assert no_truth.truth is None
    assert math.isnan(no_truth.truth_mean_deviation)
    assert "truth_mean_deviation" not in no_truth.summary()


def test_run_sweep_orders_points_and_lag_splits_truth_from_journal():
    store, ids, base = _universe()

    def factory(backend):
        return _service(store, ids, base, backend)

    points = run_sweep(factory, base, list(_stream(30)),
                       presets=["laggy_storm", "calm", "volatile"],
                       backends=["numpy"], seed=6)
    assert [p.preset for p in points] == ["calm", "volatile",
                                          "laggy_storm"]  # level order
    assert all(isinstance(p, TurbulencePoint) for p in points)
    assert all(p.audit_ok for p in points)
    assert all(p.decisions == 15 for p in points)
    for p in points:
        if p.preset == "laggy_storm":
            # the lagged daemon is consistent but late: the journal
            # judge can't see the staleness, the truth judge can
            assert p.truth_mean_deviation != p.mean_deviation
        else:
            assert p.truth_mean_deviation == p.mean_deviation

    curves = turbulence_curves(points)
    assert sorted(curves) == ["numpy"]
    assert [p.level for p in curves["numpy"]] == [0.0, 1.0, 5.0]


@pytest.mark.parametrize("backend", ["numpy", "jax_batched"])
def test_run_sweep_audits_clean_across_backends(backend):
    if not backend_available(backend):
        pytest.skip("jax not installed")
    store, ids, base = _universe()
    points = run_sweep(lambda b: _service(store, ids, base, b), base,
                       list(_stream(20)), presets=["flash_crash"],
                       backends=[backend], seed=1)
    (point,) = points
    assert point.backend == backend and point.audit_ok
    assert point.epochs > 0 and point.decisions == 10
