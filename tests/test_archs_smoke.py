"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and absence of NaNs (deliverable f)."""
import jax
import jax.numpy as jnp
import pytest

import repro.configs as C
from repro.configs import shapes as S
from repro.models import build_model, count_params
from repro.models.types import ShapeSpec

SMOKE = ShapeSpec("smoke", 32, 2, "train")

# expected full-config parameter counts (billions), +-15%
EXPECTED_B = {
    "seamless-m4t-large-v2": 1.4,
    "llama4-maverick-400b-a17b": 400.0,
    "qwen3-moe-30b-a3b": 30.0,
    "recurrentgemma-9b": 9.0,
    "rwkv6-3b": 3.1,
    "stablelm-3b": 2.8,
    "qwen3-1.7b": 1.7,
    "granite-20b": 20.0,
    "deepseek-7b": 7.0,
    "pixtral-12b": 12.5,
}


@pytest.mark.parametrize("name", C.ARCH_NAMES)
def test_full_config_param_count(name):
    cfg = C.get(name)
    n = count_params(build_model(cfg).param_specs())
    assert n / 1e9 == pytest.approx(EXPECTED_B[name], rel=0.15), n


@pytest.mark.parametrize("name", C.ARCH_NAMES)
def test_reduced_forward_and_loss(name):
    cfg = C.reduced(C.get(name))
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = S.make_batch(cfg, SMOKE, key)
    logits, aux = model.forward(params, batch)
    T_total = SMOKE.seq_len if not cfg.is_encdec else SMOKE.seq_len // 2
    assert logits.shape == (SMOKE.global_batch, T_total, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    loss, metrics = model.loss(params, batch)
    assert loss.shape == ()
    assert not bool(jnp.isnan(loss))
    assert float(metrics["xent"]) > 0


@pytest.mark.parametrize("name", C.ARCH_NAMES)
def test_reduced_train_step_decreases_loss(name):
    """One SGD step on a fixed batch decreases loss (gradients flow)."""
    cfg = C.reduced(C.get(name))
    model = build_model(cfg)
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    batch = S.make_batch(cfg, SMOKE, key)

    @jax.jit
    def step(p):
        (loss, _), grads = jax.value_and_grad(
            lambda q: model.loss(q, batch), has_aux=True)(p)
        p2 = jax.tree_util.tree_map(lambda a, g: a - 0.5 * g, p, grads)
        return loss, p2

    l0, params = step(params)
    for _ in range(3):
        l1, params = step(params)
    assert not bool(jnp.isnan(l1))
    assert float(l1) < float(l0), (float(l0), float(l1))


@pytest.mark.parametrize("name", C.ARCH_NAMES)
def test_grads_have_no_nans_and_cover_all_params(name):
    cfg = C.reduced(C.get(name))
    model = build_model(cfg)
    key = jax.random.PRNGKey(2)
    params = model.init(key)
    batch = S.make_batch(cfg, SMOKE, key)
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    flat = jax.tree_util.tree_leaves_with_path(grads)
    assert flat
    for path, g in flat:
        assert not bool(jnp.isnan(g).any()), path
