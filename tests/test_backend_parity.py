"""Differential backend-parity suite (ISSUE 4 satellites).

Hypothesis-driven: random universes and event-bearing reprice streams
(the discount/eviction strategies from ``test_rank_properties``) assert
that the jax float32 backend — cold ``rank_dense`` and the jitted
accelerator-resident :class:`~repro.selector.JaxRankState` delta path —
picks the same winner as the numpy float64 backend (or one tied within
tolerance) and keeps every score inside the
:class:`~repro.selector.ScoreContract` envelope.

Also home to the no-jax degradation test: the selector core must import
and rank with jax uninstalled, and ``backend="jax"`` must fail with the
typed, skippable :class:`~repro.selector.BackendUnavailableError`.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.selector import (BackendUnavailableError, JaxRankState,
                            RankState, ScoreContract, SelectionService,
                            backend_available, default_backend, rank_dense,
                            score_contract)

try:        # the property half needs hypothesis; the differential
            # smoke/edge tests below run without it
    import hypothesis
    from hypothesis import given, settings, strategies as st
    from test_rank_properties import (delta_streams, event_markets,
                                      _event_feed, runtime_tables)
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

needs_jax = pytest.mark.skipif(not backend_available("jax"),
                               reason="jax not installed")

CONTRACT = score_contract("jax")


def assert_within_contract(candidate, reference,
                           contract: ScoreContract = CONTRACT):
    """``candidate`` ranking honors ``contract`` against ``reference``:
    same winner (or tied within tolerance) and every per-config score
    inside the rel/abs envelope."""
    assert [r.config_id for r in candidate] != []
    ref_score = {r.config_id: r.score for r in reference}
    assert contract.winner_matches(candidate[0].config_id, reference), (
        candidate[0], reference[0])
    for r in candidate:
        assert contract.scores_match(r.score, ref_score[r.config_id]), (
            r, ref_score[r.config_id])


# --- the contract itself -----------------------------------------------------------

def test_score_contracts_shape():
    exact = score_contract("numpy")
    assert exact.bit_identical and exact.rel_tol == exact.abs_tol == 0.0
    tol = score_contract("jax")
    assert not tol.bit_identical and tol.rel_tol > 0
    with pytest.raises(ValueError, match="unknown backend"):
        score_contract("bogus")


def test_contract_score_matching():
    exact, tol = score_contract("numpy"), score_contract("jax")
    assert exact.scores_match(1.0, 1.0)
    assert not exact.scores_match(1.0, np.nextafter(1.0, 2.0))
    assert tol.scores_match(1.0, 1.0 + 0.5 * tol.rel_tol)
    assert not tol.scores_match(1.0, 1.0 + 10 * tol.rel_tol)
    # unprofiled configs score +inf on every backend; inf ties inf
    assert exact.scores_match(float("inf"), float("inf"))
    assert tol.scores_match(float("inf"), float("inf"))


def test_contract_winner_matching():
    from repro.selector import RankedConfig
    tol = score_contract("jax")
    ranking = [RankedConfig("a", 2.0, 1.0),
               RankedConfig("b", 2.0 + 0.1 * tol.rel_tol, 1.0),
               RankedConfig("c", 3.0, 1.5)]
    assert tol.winner_matches("a", ranking)
    assert tol.winner_matches("b", ranking)          # tied within tol
    assert not tol.winner_matches("c", ranking)      # genuinely worse
    assert not tol.winner_matches("ghost", ranking)
    exact = score_contract("numpy")
    assert exact.winner_matches("a", ranking)
    assert not exact.winner_matches("b", ranking)    # ties need bits


# --- deterministic differential sweeps (run without hypothesis) --------------------

def _random_universe(seed, n_jobs, n_cfgs, partial=False):
    rng = np.random.default_rng(seed)
    hours = rng.uniform(0.01, 100.0, (n_jobs, n_cfgs))
    if partial:
        mask = rng.random((n_jobs, n_cfgs)) > 0.25
        mask[np.arange(n_jobs), rng.integers(0, n_cfgs, n_jobs)] = True
    else:
        mask = np.ones((n_jobs, n_cfgs), dtype=bool)
    prices = rng.uniform(0.1, 50.0, n_cfgs)
    ids = [f"c{i}" for i in range(n_cfgs)]
    return rng, hours, mask, prices, ids


@needs_jax
@pytest.mark.parametrize("seed", range(8))
def test_cold_jax_within_contract_of_numpy_seeded(seed):
    """Seeded differential sweep (runs with or without hypothesis):
    cold jax ranks within contract of cold numpy on random universes,
    dense and partially profiled."""
    _, hours, mask, prices, ids = _random_universe(seed, 4 + seed % 5,
                                                   3 + seed,
                                                   partial=seed % 2 == 1)
    ref = rank_dense(hours, mask, prices, ids)
    jx = rank_dense(hours, mask, prices, ids, backend="jax")
    assert_within_contract(jx, ref)


@needs_jax
@pytest.mark.parametrize("seed", range(6))
def test_jax_delta_stream_within_contract_seeded(seed):
    """Seeded reprice streams: after every tick the jitted delta path
    agrees with the float64 incremental reference AND with a cold jax
    rank at the live prices, under the contract."""
    rng, hours, mask, prices, ids = _random_universe(
        100 + seed, 5, 12 + 4 * seed, partial=seed % 2 == 0)
    jx = JaxRankState(hours, mask, prices.copy(), ids)
    ref = RankState(hours, mask, prices.copy(), ids)
    live = prices.copy()
    for _ in range(6):
        k = int(rng.integers(1, len(ids)))
        cols = rng.choice(len(ids), k, replace=False)
        deltas = {ids[c]: float(live[c] * rng.uniform(0.5, 2.0))
                  for c in cols}
        jx.reprice(deltas)
        ref.reprice(deltas)
        for c, p in deltas.items():
            live[int(c[1:])] = p
        assert_within_contract(jx.ranking(), ref.ranking())
        assert_within_contract(
            jx.ranking(),
            rank_dense(hours, mask, live, ids, backend="jax"))


@needs_jax
def test_event_market_jax_reprice_within_contract_deterministic():
    """Discount/eviction boundary re-quote bursts through the jax delta
    path stay within contract of the cold float64 rank at every tick
    (the deterministic analogue of the hypothesis event_markets sweep)."""
    from repro.market import MarketEvent, SimulatedSpotFeed
    rng, hours, mask, prices, ids = _random_universe(7, 4, 10)
    base = {c: float(p) for c, p in zip(ids, prices)}
    feed = SimulatedSpotFeed(
        base, seed=5, change_fraction=0.3, volatility=0.15,
        events=[MarketEvent("us-central1", 2, 4, 0.25, "discount"),
                MarketEvent("europe-west3", 5, 3, 4.0, "eviction")])
    state = JaxRankState(hours, mask, prices.copy(), ids)
    live = prices.copy()
    for t in range(10):
        batch = feed.poll(t)
        if not batch:
            continue
        state.reprice({d.config_id: d.price for d in batch})
        for d in batch:
            live[ids.index(d.config_id)] = d.price
        assert_within_contract(state.ranking(),
                               rank_dense(hours, mask, live, ids))


# --- hypothesis property half (skips quietly when hypothesis is absent) ------------

if HAVE_HYPOTHESIS:
    @needs_jax
    @settings(max_examples=25, deadline=None)
    @given(runtime_tables())
    def test_cold_jax_within_contract_of_numpy(table):
        jobs, cfgs, rt, prices = table
        hours = np.asarray([[rt[(j, c)] for c in cfgs] for j in jobs])
        mask = np.ones_like(hours, dtype=bool)
        pv = np.asarray([prices[c] for c in cfgs])
        ref = rank_dense(hours, mask, pv, cfgs, job_ids=jobs)
        jx = rank_dense(hours, mask, pv, cfgs, job_ids=jobs,
                        backend="jax")
        assert_within_contract(jx, ref)

    @needs_jax
    @settings(max_examples=20, deadline=None)
    @given(delta_streams())
    def test_jax_delta_stream_within_contract_of_numpy(data):
        """After every tick of any reprice stream, the jitted delta
        path agrees with the float64 incremental reference under the
        contract."""
        jobs, cfgs, rt, prices, stream = data
        hours = np.asarray([[rt[(j, c)] for c in cfgs] for j in jobs])
        mask = np.ones_like(hours, dtype=bool)
        pv = np.asarray([prices[c] for c in cfgs])
        jx = JaxRankState(hours, mask, pv, cfgs, job_ids=jobs)
        ref = RankState(hours, mask, pv.copy(), cfgs, job_ids=jobs)
        for deltas in stream:
            jx.reprice(deltas)
            ref.reprice(deltas)
            assert_within_contract(jx.ranking(), ref.ranking())

    @needs_jax
    @settings(max_examples=20, deadline=None)
    @given(delta_streams())
    def test_jax_delta_path_within_contract_of_jax_cold(data):
        """The jitted delta-update kernel vs a cold jax rank at the
        same prices: both float32, so the only divergence is the delta
        path's accumulated drift — it must stay inside the contract
        too."""
        jobs, cfgs, rt, prices, stream = data
        hours = np.asarray([[rt[(j, c)] for c in cfgs] for j in jobs])
        mask = np.ones_like(hours, dtype=bool)
        live = np.asarray([prices[c] for c in cfgs])
        jx = JaxRankState(hours, mask, live.copy(), cfgs, job_ids=jobs)
        for deltas in stream:
            jx.reprice(deltas)
            for c, p in deltas.items():
                live[cfgs.index(c)] = p
            cold = rank_dense(hours, mask, live, cfgs, job_ids=jobs,
                              backend="jax")
            assert_within_contract(jx.ranking(), cold)

    @needs_jax
    @settings(max_examples=20, deadline=None)
    @given(event_markets())
    def test_event_market_jax_reprice_within_contract(market):
        """Event-bearing markets (discount/eviction boundary re-quote
        bursts) through the jax delta path stay within contract of the
        cold float64 rank at every tick."""
        cfgs, base, events, seed, change_fraction, n_ticks, jobs, rt = \
            market
        hours = np.asarray([[rt[(j, c)] for c in cfgs] for j in jobs])
        mask = np.ones_like(hours, dtype=bool)
        live = np.asarray([base[c] for c in cfgs])
        state = JaxRankState(hours, mask, live.copy(), cfgs, job_ids=jobs)
        feed = _event_feed(base, events, seed, change_fraction)
        for t in range(n_ticks):
            batch = feed.poll(t)
            if not batch:
                continue
            state.reprice({d.config_id: d.price for d in batch})
            for d in batch:
                live[cfgs.index(d.config_id)] = d.price
            assert_within_contract(state.ranking(),
                                   rank_dense(hours, mask, live, cfgs,
                                              job_ids=jobs))

    @needs_jax
    @settings(max_examples=10, deadline=None)
    @given(event_markets(), st.integers(0, 2 ** 16))
    def test_event_market_jax_daemon_audits_within_tolerance(market,
                                                             stream_seed):
        """End-to-end: a jax-backed daemon over any event-bearing
        market journals decisions the tolerance audit confirms against
        cold float64 re-ranks."""
        from repro.core.trace import JobClass
        from repro.market import JournalReplayer, SelectionDaemon, \
            synthetic_stream
        from repro.selector import (IdentityCatalog, PriceTable,
                                    ProfilingStore)
        cfgs, base, events, seed, change_fraction, n_ticks, _, _ = market
        store = ProfilingStore(config_ids=cfgs)
        for j in range(4):
            for i, c in enumerate(cfgs):
                store.add(f"j{j}", c, 0.1 + ((j * 7 + i * 3) % 11) / 5.0,
                          job_class=JobClass.A if j % 2 else JobClass.B)
        svc = SelectionService(IdentityCatalog(cfgs), store,
                               PriceTable(base), backend="jax")
        daemon = SelectionDaemon(svc, _event_feed(base, events, seed,
                                                  change_fraction))
        daemon.run(synthetic_stream(store.job_ids, 25, seed=stream_seed,
                                    tick_fraction=0.4))
        audit = JournalReplayer(store, daemon.journal_dump()).audit()
        assert audit.ok, audit.mismatches[:3]
        assert audit.contract == CONTRACT
        assert audit.decisions == daemon.stats.decisions
else:
    @pytest.mark.skip(reason="hypothesis not installed (property half "
                             "of the parity suite)")
    def test_backend_parity_properties_skipped():
        pass  # pragma: no cover


# --- mask / unprofiled coverage ----------------------------------------------------

@needs_jax
def test_jax_state_partial_mask_and_unprofiled_columns():
    """Unprofiled columns score +inf on both backends, and partially
    masked universes reprice within contract (masked cells never leak
    into row minima)."""
    rng = np.random.default_rng(3)
    J, C = 6, 40
    hours = rng.uniform(0.05, 10.0, (J, C))
    mask = rng.random((J, C)) > 0.4
    mask[np.arange(J) % J, rng.integers(0, C - 1, J)] = True
    mask[:, C - 1] = False                      # never profiled
    prices = rng.uniform(0.5, 20.0, C)
    ids = [f"c{i}" for i in range(C)]
    jx = JaxRankState(hours, mask, prices.copy(), ids)
    ref = RankState(hours, mask, prices.copy(), ids)
    live = prices.copy()
    for t in range(8):
        cols = rng.choice(C, 5, replace=False)
        batch = {ids[c]: float(live[c] * rng.uniform(0.5, 2.0))
                 for c in cols}
        jx.reprice(batch)
        ref.reprice(batch)
        for c, p in batch.items():
            live[int(c[1:])] = p
        assert_within_contract(jx.ranking(), ref.ranking())
        unprofiled = [r for r in jx.ranking() if r.config_id == ids[C - 1]]
        assert unprofiled[0].score == float("inf")
        # the device-side winner peek agrees with the materialized list
        assert jx.winner() == jx.ranking()[0]


@needs_jax
def test_jax_state_validates_like_numpy():
    hours = np.asarray([[1.0, 2.0]])
    mask = np.ones_like(hours, dtype=bool)
    with pytest.raises(ValueError, match="shape mismatch"):
        JaxRankState(hours, mask, np.asarray([1.0]), ["a", "b"])
    with pytest.raises(ValueError, match="duplicate config ids"):
        JaxRankState(hours, mask, np.asarray([1.0, 2.0]), ["a", "a"])
    state = JaxRankState(hours, mask, np.asarray([1.0, 2.0]), ["a", "b"])
    with pytest.raises(ValueError, match="unknown config id"):
        state.reprice({"ghost": 1.0})
    with pytest.raises(ValueError, match="non-positive"):
        state.reprice({"a": -1.0})
    assert state.reprice({}) == 0
    from repro.selector import NothingRankableError
    with pytest.raises(NothingRankableError):
        JaxRankState(np.zeros((0, 2)), np.zeros((0, 2), dtype=bool),
                     np.asarray([1.0, 2.0]), ["a", "b"])


@needs_jax
def test_jax_delta_bucket_padding_is_idempotent():
    """Batch sizes that straddle the power-of-4 padding buckets (k=1,
    7, 8, 9, 32, all-C) all land within contract — the padded duplicate
    (column, price) pairs must be invisible."""
    rng = np.random.default_rng(11)
    J, C = 5, 64
    hours = rng.uniform(0.05, 10.0, (J, C))
    mask = np.ones((J, C), dtype=bool)
    prices = rng.uniform(0.5, 20.0, C)
    ids = [f"c{i}" for i in range(C)]
    jx = JaxRankState(hours, mask, prices.copy(), ids)
    live = prices.copy()
    for k in (1, 7, 8, 9, 32, C):
        cols = rng.choice(C, k, replace=False)
        batch = {ids[c]: float(live[c] * rng.uniform(0.5, 2.0))
                 for c in cols}
        jx.reprice(batch)
        for c, p in batch.items():
            live[int(c[1:])] = p
        assert_within_contract(jx.ranking(),
                               rank_dense(hours, mask, live, ids))


# --- service-level backend knob ----------------------------------------------------

@needs_jax
def test_service_backend_knob_serves_jax_states():
    from repro.core.trace import JobClass
    from repro.selector import IdentityCatalog, PriceTable, ProfilingStore
    rng = np.random.default_rng(1)
    ids = [f"c{i}" for i in range(16)]
    store = ProfilingStore(config_ids=ids)
    for j in range(4):
        for c in ids:
            store.add(f"j{j}", c, float(rng.uniform(0.1, 5.0)),
                      job_class=JobClass.A if j % 2 else JobClass.B)
    table = PriceTable({c: float(rng.uniform(1.0, 20.0)) for c in ids})
    svc = SelectionService(IdentityCatalog(ids), store, table,
                           backend="jax")
    ref = SelectionService(IdentityCatalog(ids), store,
                           PriceTable(dict(table.items())),
                           backend="numpy")
    d1 = svc.submit("j1")
    d2 = ref.submit("j1")
    assert_within_contract(list(d1.ranking), list(d2.ranking))
    # ticks run the donated-buffer delta kernel through service.reprice
    deltas = {ids[0]: 0.7, ids[5]: 9.0}
    assert svc.reprice(deltas) == 1       # the one live state refreshed
    ref.reprice(deltas)
    assert_within_contract(list(svc.submit("j1").ranking),
                           list(ref.submit("j1").ranking))
    assert svc.reprice_refreshes == 1


def test_service_rejects_unknown_backend_at_construction():
    """A misspelled backend fails when the service is built, not on the
    first submit — wiring a never-rankable service into a daemon should
    be impossible."""
    from repro.selector import IdentityCatalog, PriceTable, ProfilingStore
    store = ProfilingStore(config_ids=["a"])
    store.add("j", "a", 1.0)
    with pytest.raises(ValueError, match="unknown backend"):
        SelectionService(IdentityCatalog(["a"]), store,
                         PriceTable({"a": 1.0}), backend="torch")


def test_default_backend_resolves_env(monkeypatch):
    monkeypatch.delenv("FLORA_RANK_BACKEND", raising=False)
    assert default_backend() == "numpy"
    monkeypatch.setenv("FLORA_RANK_BACKEND", "jax")
    assert default_backend() == "jax"
    monkeypatch.setenv("FLORA_RANK_BACKEND", "torch")
    with pytest.raises(ValueError, match="unknown backend"):
        default_backend()


# --- graceful degradation with jax uninstalled (satellite fix) ---------------------

NO_JAX_PROBE = textwrap.dedent("""
    import sys
    # simulate an environment without jax: a None entry makes any
    # "import jax" raise ImportError before site-packages is consulted
    sys.modules["jax"] = None
    sys.modules["jax.numpy"] = None

    from repro.selector import (BackendUnavailableError, JaxRankState,
                                PallasBatchedRankState, SelectionService,
                                IdentityCatalog, PriceTable,
                                ProfilingStore, rank_dense)
    import repro.selector.rank as rank
    assert not rank._HAVE_JAX

    import numpy as np
    hours = np.asarray([[1.0, 2.0], [2.0, 1.0]])
    mask = np.ones_like(hours, dtype=bool)
    prices = np.asarray([3.0, 4.0])

    # the numpy path is fully functional
    ranked = rank_dense(hours, mask, prices, ["a", "b"])
    assert ranked[0].config_id == "a"
    store = ProfilingStore(config_ids=["a", "b"])
    store.add("j0", "a", 1.0); store.add("j0", "b", 2.0)
    svc = SelectionService(IdentityCatalog(["a", "b"]), store,
                           PriceTable({"a": 3.0, "b": 4.0}))
    assert svc.submit("j0").config_id == "a"

    # the jax backend fails with the *typed* skippable error everywhere
    for attempt in (
        lambda: rank_dense(hours, mask, prices, ["a", "b"],
                           backend="jax"),
        lambda: JaxRankState(hours, mask, prices, ["a", "b"]),
        lambda: PallasBatchedRankState(hours, mask, prices, ["a", "b"]),
        lambda: SelectionService(IdentityCatalog(["a", "b"]), store,
                                 PriceTable({"a": 3.0, "b": 4.0}),
                                 backend="jax"),
        lambda: SelectionService(IdentityCatalog(["a", "b"]), store,
                                 PriceTable({"a": 3.0, "b": 4.0}),
                                 backend="jax_pallas"),
    ):
        try:
            attempt()
        except BackendUnavailableError:
            pass
        else:
            raise AssertionError("expected BackendUnavailableError")
    print("NO-JAX-OK")
""")


def test_selector_core_works_with_jax_uninstalled():
    """Satellite (ISSUE 4): with jax unimportable (sys.modules guard in
    a fresh interpreter, so this process's jax state is untouched), the
    selector imports, ranks and serves on numpy, and ``backend="jax"``
    raises the typed ``BackendUnavailableError`` — previously an
    untyped ``RuntimeError``."""
    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = {**os.environ, "PYTHONPATH": os.path.join(repo_root, "src")}
    # the probe's default-backend construction must resolve to numpy —
    # don't let CI's jax matrix leg leak into the simulated jax-less box
    env.pop("FLORA_RANK_BACKEND", None)
    result = subprocess.run(
        [sys.executable, "-c", NO_JAX_PROBE],
        capture_output=True, text=True, env=env, cwd=repo_root)
    assert result.returncode == 0, result.stderr
    assert "NO-JAX-OK" in result.stdout


def test_backend_unavailable_error_is_typed():
    assert issubclass(BackendUnavailableError, RuntimeError)
    assert not issubclass(BackendUnavailableError, ValueError)
