"""Property tests for the vectorized normalized-cost ranking.

Moved out of test_flora_core.py so the paper-claim tests run without the
optional ``hypothesis`` extra (this whole module skips when it is absent).
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.selector import RankState, rank_dense, rank_pairs  # noqa: E402


@st.composite
def runtime_tables(draw):
    n_jobs = draw(st.integers(2, 6))
    n_cfgs = draw(st.integers(2, 6))
    jobs = [f"j{i}" for i in range(n_jobs)]
    cfgs = [f"c{i}" for i in range(n_cfgs)]
    rt = {(j, c): draw(st.floats(0.01, 100.0, allow_nan=False))
          for j in jobs for c in cfgs}
    prices = {c: draw(st.floats(0.1, 50.0, allow_nan=False)) for c in cfgs}
    return jobs, cfgs, rt, prices


@settings(max_examples=50, deadline=None)
@given(runtime_tables())
def test_rank_scale_invariance(table):
    """Scaling one test job's runtimes doesn't change the ranking (the
    per-job normalization makes each test job weight equal)."""
    jobs, cfgs, rt, prices = table
    base = rank_pairs(rt, jobs, cfgs, prices.__getitem__)
    scaled = dict(rt)
    for c in cfgs:
        scaled[(jobs[0], c)] = rt[(jobs[0], c)] * 37.5
    again = rank_pairs(scaled, jobs, cfgs, prices.__getitem__)
    assert [r.config_id for r in base] == [r.config_id for r in again]
    for a, b in zip(base, again):
        assert a.score == pytest.approx(b.score, rel=1e-9)


@settings(max_examples=50, deadline=None)
@given(runtime_tables())
def test_rank_price_scale_invariance(table):
    """Uniformly scaling all prices (currency change) keeps the ranking."""
    jobs, cfgs, rt, prices = table
    base = rank_pairs(rt, jobs, cfgs, prices.__getitem__)
    again = rank_pairs(rt, jobs, cfgs, lambda c: prices[c] * 0.731)
    assert [r.config_id for r in base] == [r.config_id for r in again]


@settings(max_examples=50, deadline=None)
@given(runtime_tables())
def test_rank_scores_lower_bounded(table):
    """Every score >= n_jobs (each normalized cost >= 1), and some config
    achieves score == n_jobs iff one config is optimal for every job."""
    jobs, cfgs, rt, prices = table
    ranked = rank_pairs(rt, jobs, cfgs, prices.__getitem__)
    for r in ranked:
        assert r.score >= len(jobs) - 1e-9
        assert r.mean_norm_cost >= 1 - 1e-9


@settings(max_examples=30, deadline=None)
@given(runtime_tables(), st.integers(0, 5))
def test_rank_dominated_config_never_wins(table, seed):
    """A config strictly worse than another on every job never ranks first."""
    jobs, cfgs, rt, prices = table
    dom, loser = cfgs[0], "loser"
    cfgs2 = cfgs + [loser]
    rt2 = dict(rt)
    for j in jobs:
        rt2[(j, loser)] = rt[(j, dom)] * 2.0
    prices2 = dict(prices)
    prices2[loser] = prices[dom] * 1.5
    ranked = rank_pairs(rt2, jobs, cfgs2, prices2.__getitem__)
    assert ranked[0].config_id != loser


@settings(max_examples=25, deadline=None)
@given(runtime_tables())
def test_rank_jax_backend_agrees_with_numpy(table):
    """The jitted jax kernel ranks identically to the float64 numpy path
    (scores agree to float32 precision)."""
    jobs, cfgs, rt, prices = table
    base = rank_pairs(rt, jobs, cfgs, prices.__getitem__)
    jx = rank_pairs(rt, jobs, cfgs, prices.__getitem__, backend="jax")
    for a, b in zip(base, jx):
        assert a.score == pytest.approx(b.score, rel=1e-4)


@st.composite
def delta_streams(draw):
    """A runtime table plus a stream of per-tick price-delta batches."""
    jobs, cfgs, rt, prices = draw(runtime_tables())
    n_ticks = draw(st.integers(1, 6))
    stream = []
    for _ in range(n_ticks):
        changed = draw(st.lists(st.sampled_from(cfgs), min_size=1,
                                max_size=len(cfgs), unique=True))
        stream.append({c: draw(st.floats(0.1, 50.0, allow_nan=False))
                       for c in changed})
    return jobs, cfgs, rt, prices, stream


@settings(max_examples=40, deadline=None)
@given(delta_streams())
def test_reprice_stream_equals_cold_rank_elementwise(data):
    """Streaming price semantics (DESIGN.md §6): after any sequence of
    incremental reprice ticks, the live RankState's ranking is
    element-wise equal — exact floats — to a cold rank_dense at the
    final prices."""
    import numpy as np
    jobs, cfgs, rt, prices, stream = data
    hours = np.asarray([[rt[(j, c)] for c in cfgs] for j in jobs])
    mask = np.ones_like(hours, dtype=bool)
    live = np.asarray([prices[c] for c in cfgs])
    state = RankState(hours, mask, live, cfgs, job_ids=jobs)
    for deltas in stream:
        state.reprice(deltas)
        for c, p in deltas.items():
            live[cfgs.index(c)] = p
        assert state.ranking() == rank_dense(hours, mask, live, cfgs,
                                             job_ids=jobs)


@st.composite
def event_markets(draw):
    """A config universe plus a SimulatedSpotFeed parameterization whose
    delta stream includes scheduled discount/eviction MarketEvents (the
    boundary re-quote bursts the plain delta_streams strategy never
    generates)."""
    from repro.market.feed import DEFAULT_REGIONS, MarketEvent
    n_cfgs = draw(st.integers(2, 5))
    cfgs = [f"c{i}" for i in range(n_cfgs)]
    base = {c: draw(st.floats(0.5, 20.0, allow_nan=False)) for c in cfgs}
    n_ticks = draw(st.integers(2, 10))
    events = [
        MarketEvent(draw(st.sampled_from(DEFAULT_REGIONS)),
                    start_tick=draw(st.integers(0, n_ticks - 1)),
                    duration=draw(st.integers(1, n_ticks)),
                    factor=draw(st.sampled_from([0.25, 0.5, 2.0, 4.0])),
                    kind=draw(st.sampled_from(["discount", "eviction"])))
        for _ in range(draw(st.integers(1, 3)))]
    seed = draw(st.integers(0, 2 ** 16))
    change_fraction = draw(st.floats(0.0, 1.0))
    jobs = [f"j{i}" for i in range(draw(st.integers(2, 4)))]
    rt = {(j, c): draw(st.floats(0.01, 100.0, allow_nan=False))
          for j in jobs for c in cfgs}
    return cfgs, base, events, seed, change_fraction, n_ticks, jobs, rt


def _event_feed(base, events, seed, change_fraction):
    from repro.market import SimulatedSpotFeed
    return SimulatedSpotFeed(base, seed=seed,
                             change_fraction=change_fraction,
                             volatility=0.15, events=events)


@settings(max_examples=40, deadline=None)
@given(event_markets())
def test_event_market_reprice_bit_identical(market):
    """Satellite (ISSUE 3): for any simulated market *including
    discount/eviction MarketEvents*, RankState.reprice stays bit-identical
    to a cold rank_dense at every tick — boundary re-quote bursts (every
    config of a region at once) included."""
    import numpy as np
    cfgs, base, events, seed, change_fraction, n_ticks, jobs, rt = market
    hours = np.asarray([[rt[(j, c)] for c in cfgs] for j in jobs])
    mask = np.ones_like(hours, dtype=bool)
    live = np.asarray([base[c] for c in cfgs])
    state = RankState(hours, mask, live.copy(), cfgs, job_ids=jobs)
    feed = _event_feed(base, events, seed, change_fraction)
    for t in range(n_ticks):
        batch = feed.poll(t)
        if not batch:
            continue
        state.reprice({d.config_id: d.price for d in batch})
        for d in batch:
            live[cfgs.index(d.config_id)] = d.price
        assert state.ranking() == rank_dense(hours, mask, live, cfgs,
                                             job_ids=jobs)


@settings(max_examples=15, deadline=None)
@given(event_markets(), st.integers(0, 2 ** 16))
def test_event_market_journal_audit_passes(market, stream_seed):
    """Satellite (ISSUE 3): a daemon serving any event-bearing market
    yields a journal whose every decision the JournalReplayer confirms
    bit-identical to a cold re-rank at its reconstructed epoch."""
    from repro.core.trace import JobClass
    from repro.market import JournalReplayer, SelectionDaemon, \
        synthetic_stream
    from repro.selector import (IdentityCatalog, PriceTable, ProfilingStore,
                                SelectionService)
    cfgs, base, events, seed, change_fraction, n_ticks, _, _ = market
    store = ProfilingStore(config_ids=cfgs)
    for j in range(4):
        for i, c in enumerate(cfgs):
            store.add(f"j{j}", c, 0.1 + ((j * 7 + i * 3) % 11) / 5.0,
                      job_class=JobClass.A if j % 2 else JobClass.B)
    svc = SelectionService(IdentityCatalog(cfgs), store, PriceTable(base))
    daemon = SelectionDaemon(svc, _event_feed(base, events, seed,
                                              change_fraction))
    daemon.run(synthetic_stream(store.job_ids, 30, seed=stream_seed,
                                tick_fraction=0.4))
    audit = JournalReplayer(store, daemon.journal_dump()).audit()
    assert audit.ok, audit.mismatches[:3]
    assert audit.decisions == daemon.stats.decisions
    assert audit.ticks == daemon.stats.epochs


@settings(max_examples=25, deadline=None)
@given(runtime_tables())
def test_rank_dense_equals_pairs(table):
    """Densifying by hand and calling rank_dense matches rank_pairs."""
    import numpy as np
    jobs, cfgs, rt, prices = table
    hours = np.asarray([[rt[(j, c)] for c in cfgs] for j in jobs])
    mask = np.ones_like(hours, dtype=bool)
    pv = np.asarray([prices[c] for c in cfgs])
    a = rank_dense(hours, mask, pv, cfgs, job_ids=jobs)
    b = rank_pairs(rt, jobs, cfgs, prices.__getitem__)
    assert [(r.config_id, r.score) for r in a] == \
        [(r.config_id, r.score) for r in b]
