"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU).

Sweeps shapes/dtypes per the kernel contract and asserts allclose against
ref.py; includes the model-side chunked jnp attention as a third
implementation for mutual agreement.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.rwkv6_scan import wkv6_pallas
from repro.models.layers import sdpa
from repro.models.recurrent import wkv6_scan_ref, wkv6_scan_chunked


def _qkv(key, B, Tq, Tk, H, G, D, dtype):
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, Tq, H, D), dtype)
    k = jax.random.normal(kk, (B, Tk, G, D), dtype)
    v = jax.random.normal(kv, (B, Tk, G, D), dtype)
    return q, k, v


ATTN_CASES = [
    # B, T, H, G, D, causal, window, bq, bkv
    (2, 128, 4, 4, 32, True, None, 32, 32),
    (1, 256, 4, 2, 64, True, None, 64, 64),     # GQA
    (2, 128, 8, 1, 32, True, None, 64, 32),     # MQA
    (1, 128, 2, 2, 32, False, None, 32, 64),    # bidirectional
    (1, 256, 4, 4, 32, True, 64, 64, 32),       # local window
    (1, 64, 2, 2, 128, True, None, 64, 64),     # full head dim
]


@pytest.mark.parametrize("case", ATTN_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(case, dtype):
    B, T, H, G, D, causal, window, bq, bkv = case
    q, k, v = _qkv(jax.random.PRNGKey(hash(case) % 2**31), B, T, T, H, G, D,
                   dtype)
    out = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 block_q=bq, block_kv=bkv, interpret=True)
    expect = ref.attention_ref(q, k, v, causal=causal, window=window)
    atol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=atol, rtol=1e-2)


@pytest.mark.parametrize("case", ATTN_CASES[:4])
def test_model_sdpa_matches_ref(case):
    """The model's chunked online-softmax jnp path equals the oracle."""
    B, T, H, G, D, causal, window, bq, bkv = case
    q, k, v = _qkv(jax.random.PRNGKey(7), B, T, T, H, G, D, jnp.float32)
    out = sdpa(q, k, v, causal=causal, window=window, q_chunk=32,
               kv_chunk=32)
    expect = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=3e-5, rtol=1e-3)


WKV_CASES = [
    # B, T, H, N, chunk
    (2, 64, 2, 16, 16),
    (1, 128, 4, 32, 64),
    (2, 32, 1, 64, 32),
    (1, 96, 3, 16, 32),
]


def _wkv_inputs(key, B, T, H, N, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    r = jax.random.normal(ks[0], (B, T, H, N), dtype)
    k = jax.random.normal(ks[1], (B, T, H, N), dtype)
    v = jax.random.normal(ks[2], (B, T, H, N), dtype)
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, N))) * 0.5 + 0.45
    u = (jax.random.normal(ks[4], (H, N)) * 0.5).astype(dtype)
    s0 = jnp.zeros((B, H, N, N), jnp.float32)
    return r, k, v, w.astype(dtype), u, s0


@pytest.mark.parametrize("case", WKV_CASES)
def test_wkv6_pallas_matches_ref(case):
    B, T, H, N, chunk = case
    inputs = _wkv_inputs(jax.random.PRNGKey(sum(case)), B, T, H, N)
    y, sT = wkv6_pallas(*inputs, chunk=chunk, interpret=True)
    y_ref, sT_ref = wkv6_scan_ref(*inputs)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(sT_ref),
                               atol=1e-4, rtol=1e-3)


@pytest.mark.parametrize("case", WKV_CASES[:2])
def test_wkv6_chunked_matches_ref(case):
    """The model-side chunk-remat scan equals the exact recurrence."""
    B, T, H, N, chunk = case
    inputs = _wkv_inputs(jax.random.PRNGKey(3), B, T, H, N)
    y_c, sT_c = wkv6_scan_chunked(*inputs, chunk=chunk)
    y_ref, sT_ref = wkv6_scan_ref(*inputs)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(sT_c), np.asarray(sT_ref),
                               atol=1e-4, rtol=1e-3)


def test_wkv6_state_carry():
    """Splitting a sequence across two kernel calls carries state exactly."""
    B, T, H, N = 1, 64, 2, 16
    r, k, v, w, u, s0 = _wkv_inputs(jax.random.PRNGKey(11), B, T, H, N)
    y_full, sT_full = wkv6_scan_ref(r, k, v, w, u, s0)
    half = T // 2
    y1, s_mid = wkv6_pallas(r[:, :half], k[:, :half], v[:, :half],
                            w[:, :half], u, s0, chunk=16, interpret=True)
    y2, sT = wkv6_pallas(r[:, half:], k[:, half:], v[:, half:],
                         w[:, half:], u, s_mid, chunk=16, interpret=True)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(sT_full),
                               atol=1e-4, rtol=1e-3)


def test_flash_attention_gqa_grouping_property():
    """Repeating kv heads R times and running MHA equals GQA directly."""
    B, T, H, G, D = 1, 64, 4, 2, 32
    q, k, v = _qkv(jax.random.PRNGKey(5), B, T, T, H, G, D, jnp.float32)
    out = flash_attention_pallas(q, k, v, causal=True, block_q=32,
                                 block_kv=32, interpret=True)
    k_rep = jnp.repeat(k, H // G, axis=2)
    v_rep = jnp.repeat(v, H // G, axis=2)
    out_mha = flash_attention_pallas(q, k_rep, v_rep, causal=True,
                                     block_q=32, block_kv=32, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_mha),
                               atol=1e-5, rtol=1e-4)
