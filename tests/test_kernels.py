"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU).

Sweeps shapes/dtypes per the kernel contract and asserts allclose against
ref.py; includes the model-side chunked jnp attention as a third
implementation for mutual agreement.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.rwkv6_scan import wkv6_pallas
from repro.models.layers import sdpa
from repro.models.recurrent import wkv6_scan_ref, wkv6_scan_chunked


def _qkv(key, B, Tq, Tk, H, G, D, dtype):
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, Tq, H, D), dtype)
    k = jax.random.normal(kk, (B, Tk, G, D), dtype)
    v = jax.random.normal(kv, (B, Tk, G, D), dtype)
    return q, k, v


ATTN_CASES = [
    # B, T, H, G, D, causal, window, bq, bkv
    (2, 128, 4, 4, 32, True, None, 32, 32),
    (1, 256, 4, 2, 64, True, None, 64, 64),     # GQA
    (2, 128, 8, 1, 32, True, None, 64, 32),     # MQA
    (1, 128, 2, 2, 32, False, None, 32, 64),    # bidirectional
    (1, 256, 4, 4, 32, True, 64, 64, 32),       # local window
    (1, 64, 2, 2, 128, True, None, 64, 64),     # full head dim
]


@pytest.mark.parametrize("case", ATTN_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(case, dtype):
    B, T, H, G, D, causal, window, bq, bkv = case
    q, k, v = _qkv(jax.random.PRNGKey(hash(case) % 2**31), B, T, T, H, G, D,
                   dtype)
    out = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 block_q=bq, block_kv=bkv, interpret=True)
    expect = ref.attention_ref(q, k, v, causal=causal, window=window)
    atol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=atol, rtol=1e-2)


@pytest.mark.parametrize("case", ATTN_CASES[:4])
def test_model_sdpa_matches_ref(case):
    """The model's chunked online-softmax jnp path equals the oracle."""
    B, T, H, G, D, causal, window, bq, bkv = case
    q, k, v = _qkv(jax.random.PRNGKey(7), B, T, T, H, G, D, jnp.float32)
    out = sdpa(q, k, v, causal=causal, window=window, q_chunk=32,
               kv_chunk=32)
    expect = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=3e-5, rtol=1e-3)


WKV_CASES = [
    # B, T, H, N, chunk
    (2, 64, 2, 16, 16),
    (1, 128, 4, 32, 64),
    (2, 32, 1, 64, 32),
    (1, 96, 3, 16, 32),
]


def _wkv_inputs(key, B, T, H, N, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    r = jax.random.normal(ks[0], (B, T, H, N), dtype)
    k = jax.random.normal(ks[1], (B, T, H, N), dtype)
    v = jax.random.normal(ks[2], (B, T, H, N), dtype)
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, N))) * 0.5 + 0.45
    u = (jax.random.normal(ks[4], (H, N)) * 0.5).astype(dtype)
    s0 = jnp.zeros((B, H, N, N), jnp.float32)
    return r, k, v, w.astype(dtype), u, s0


@pytest.mark.parametrize("case", WKV_CASES)
def test_wkv6_pallas_matches_ref(case):
    B, T, H, N, chunk = case
    inputs = _wkv_inputs(jax.random.PRNGKey(sum(case)), B, T, H, N)
    y, sT = wkv6_pallas(*inputs, chunk=chunk, interpret=True)
    y_ref, sT_ref = wkv6_scan_ref(*inputs)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(sT_ref),
                               atol=1e-4, rtol=1e-3)


@pytest.mark.parametrize("case", WKV_CASES[:2])
def test_wkv6_chunked_matches_ref(case):
    """The model-side chunk-remat scan equals the exact recurrence."""
    B, T, H, N, chunk = case
    inputs = _wkv_inputs(jax.random.PRNGKey(3), B, T, H, N)
    y_c, sT_c = wkv6_scan_chunked(*inputs, chunk=chunk)
    y_ref, sT_ref = wkv6_scan_ref(*inputs)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(sT_c), np.asarray(sT_ref),
                               atol=1e-4, rtol=1e-3)


def test_wkv6_state_carry():
    """Splitting a sequence across two kernel calls carries state exactly."""
    B, T, H, N = 1, 64, 2, 16
    r, k, v, w, u, s0 = _wkv_inputs(jax.random.PRNGKey(11), B, T, H, N)
    y_full, sT_full = wkv6_scan_ref(r, k, v, w, u, s0)
    half = T // 2
    y1, s_mid = wkv6_pallas(r[:, :half], k[:, :half], v[:, :half],
                            w[:, :half], u, s0, chunk=16, interpret=True)
    y2, sT = wkv6_pallas(r[:, half:], k[:, half:], v[:, half:],
                         w[:, half:], u, s_mid, chunk=16, interpret=True)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(sT_full),
                               atol=1e-4, rtol=1e-3)


def test_flash_attention_gqa_grouping_property():
    """Repeating kv heads R times and running MHA equals GQA directly."""
    B, T, H, G, D = 1, 64, 4, 2, 32
    q, k, v = _qkv(jax.random.PRNGKey(5), B, T, T, H, G, D, jnp.float32)
    out = flash_attention_pallas(q, k, v, causal=True, block_q=32,
                                 block_kv=32, interpret=True)
    k_rep = jnp.repeat(k, H // G, axis=2)
    v_rep = jnp.repeat(v, H // G, axis=2)
    out_mha = flash_attention_pallas(q, k_rep, v_rep, causal=True,
                                     block_q=32, block_kv=32, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_mha),
                               atol=1e-5, rtol=1e-4)


# --- fused delta-rank kernel (rank_delta.py) vs numpy oracle ---------------

from repro.kernels import ops
from repro.kernels import rank_delta


def _rank_universe(seed, J=16, C=24, S=5, n_changed=3):
    """A random masked universe mid-stream: settled scores + a delta."""
    rng = np.random.default_rng(seed)
    hours = rng.uniform(0.5, 4.0, (J, C)).astype(np.float32)
    mask = rng.random((J, C)) > 0.2
    hours = np.where(mask, hours, 1.0).astype(np.float32)
    oldp = rng.uniform(0.1, 2.0, (1, C)).astype(np.float32)
    newp = oldp.copy()
    cols = rng.choice(C, size=n_changed, replace=False)
    newp[0, cols] = (newp[0, cols] * rng.uniform(0.4, 1.6, n_changed)
                     ).astype(np.float32)
    changed = np.zeros((1, C), np.float32)
    changed[0, cols] = 1.0
    cost_old = np.where(mask, hours * oldp, np.inf)
    rb_old = cost_old.min(axis=1, keepdims=True).astype(np.float32)
    norm_old = np.where(mask, cost_old / rb_old, 0.0).astype(np.float32)
    rm = (rng.random((S, J)) > 0.4).astype(np.float32)
    scores = (rm @ norm_old).astype(np.float32)
    return hours, mask, oldp, newp, changed, rb_old, rm, scores


def _rank_oracle(hours, mask, oldp, newp, changed, rb_old, rm, scores):
    """The tick's float64-free numpy reference (same float32 exprs)."""
    cost_old = np.where(mask, hours * oldp, np.inf)
    cost_new = np.where(mask, hours * newp, np.inf)
    rb_new = cost_new.min(axis=1, keepdims=True).astype(np.float32)
    norm_old = np.where(mask, cost_old / rb_old, 0.0).astype(np.float32)
    norm_new = np.where(mask, cost_new / rb_new, 0.0).astype(np.float32)
    want = np.where(changed > 0, rm @ norm_new,
                    scores + rm @ (norm_new - norm_old))
    moved = int((rb_new != rb_old).sum())
    return want, rb_new, moved


@pytest.mark.parametrize("blocks", [(16, 24), (8, 24), (4, 12), (8, 8)])
def test_rank_delta_fused_matches_oracle(blocks):
    """The fused kernel == the unfused reference on every tiling,
    including multi-tile C (phase-0 min scan spans tiles)."""
    bj, bc = blocks
    u = _rank_universe(0)
    want, rb_want, moved_want = _rank_oracle(*u)
    s, rb, mv = rank_delta.fused_reprice(*u, block_j=bj, block_c=bc)
    np.testing.assert_allclose(np.asarray(s), want, rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(np.asarray(rb), rb_want)
    assert int(np.asarray(mv)[0, 0]) == moved_want


def test_rank_delta_identity_tick_is_bitwise_noop():
    """An unchanged-price tick reproduces the standing accumulators
    bit-for-bit: the in-stream recompute is deterministic IEEE, so
    norm_new - norm_old is an exact zero everywhere (DESIGN.md §14)."""
    hours, mask, oldp, _, _, rb_old, rm, scores = _rank_universe(1)
    zeros = np.zeros_like(oldp)
    s, rb, mv = rank_delta.fused_reprice(hours, mask, oldp, oldp, zeros,
                                         rb_old, rm, scores,
                                         block_j=8, block_c=24)
    assert np.array_equal(np.asarray(s), scores)
    assert np.array_equal(np.asarray(rb), rb_old)
    assert int(np.asarray(mv)[0, 0]) == 0


def test_rank_delta_fused_heads_matches_sorted_scores():
    """The in-kernel top-k tail == a stable argsort of the finalized
    masked scores (argmin first-occurrence == catalog-order ties)."""
    u = _rank_universe(2)
    hours, mask, oldp, newp, changed, rb_old, rm, scores = u
    want, _, _ = _rank_oracle(*u)
    fin = (rm @ mask.astype(np.float32)) > 0
    k = 4
    s, rb, mv, ti, tv = rank_delta.fused_reprice_heads(
        hours, mask, oldp, newp, changed, rb_old, rm, scores, fin,
        block_j=8, block_c=24, k=k)
    np.testing.assert_allclose(np.asarray(s), want, rtol=1e-4, atol=1e-6)
    masked = np.where(fin, want, np.inf)
    ti_want = np.argsort(masked, axis=1, kind="stable")[:, :k]
    assert np.array_equal(np.asarray(ti), ti_want)
    np.testing.assert_allclose(np.asarray(tv),
                               np.take_along_axis(masked, ti_want, 1),
                               rtol=1e-4, atol=1e-6)


def test_rank_delta_heads_needs_single_c_tile():
    u = _rank_universe(3)
    fin = np.ones((u[6].shape[0], u[0].shape[1]), bool)
    with pytest.raises(ValueError, match="block_c"):
        rank_delta.fused_reprice_heads(*u, fin, block_j=8, block_c=12,
                                       k=2)


def test_rank_delta_rejects_nondividing_blocks():
    u = _rank_universe(4)
    with pytest.raises(ValueError, match="block_j"):
        rank_delta.fused_reprice(*u, block_j=5, block_c=24)
    with pytest.raises(ValueError, match="block_c"):
        rank_delta.fused_reprice(*u, block_j=8, block_c=7)


# --- regression: interpret is resolved at call time, outside the trace -----

def test_interpret_flag_not_baked_into_jit_cache(monkeypatch):
    """``_interpret()`` flipping between calls must re-trace, not
    replay: pre-fix the flag was read INSIDE the traced function, so
    the second call replayed the first call's flag from the jit cache
    (keyed only on shapes/other statics) and the spy fired once."""
    traced = []

    def spy(q, k, v, **kw):
        traced.append(kw["interpret"])
        return q

    monkeypatch.setattr(ops, "flash_attention_pallas", spy)
    # odd head dim -> a fresh jit cache entry for this test alone
    q = jnp.zeros((1, 8, 2, 17), jnp.float32)
    monkeypatch.setattr(ops, "_interpret", lambda: True)
    ops.flash_attention(q, q, q)
    monkeypatch.setattr(ops, "_interpret", lambda: False)
    ops.flash_attention(q, q, q)
    assert traced == [True, False]


def test_interpret_flag_wkv6_and_rank_delta_accept_explicit(monkeypatch):
    """The explicit ``interpret=`` override is a static arg on every
    kernel wrapper: distinct values produce distinct traces."""
    traced = []

    def spy(r, k, v, w, u, s0, **kw):
        traced.append(kw["interpret"])
        return v, s0

    monkeypatch.setattr(ops, "wkv6_pallas", spy)
    r = jnp.zeros((1, 4, 1, 19), jnp.float32)
    u = jnp.zeros((1, 19), jnp.float32)
    s0 = jnp.zeros((1, 1, 19, 19), jnp.float32)
    ops.wkv6(r, r, r, r, u, s0, interpret=True)
    ops.wkv6(r, r, r, r, u, s0, interpret=False)
    assert traced == [True, False]
    # the rank_delta dispatch resolves the default the same way: its
    # jitted fns declare interpret static (a flip re-traces, never
    # replays)
    import inspect
    sig = inspect.signature(rank_delta._reprice)
    assert "interpret" in sig.parameters


# --- regression: use_pallas is a thread-safe context manager ---------------

def test_use_pallas_context_manager_restores_prior():
    """Pre-fix ``use_pallas`` returned None, so the context-manager
    form raised AttributeError and tests had to flip the raw global."""
    assert ops._FORCE_PALLAS is False
    with ops.use_pallas():
        assert ops.pallas_enabled()
        with ops.use_pallas(False):
            assert ops._FORCE_PALLAS is False
        assert ops._FORCE_PALLAS is True
    assert ops._FORCE_PALLAS is False
    # restores on the exception path too
    with pytest.raises(RuntimeError):
        with ops.use_pallas():
            raise RuntimeError("boom")
    assert ops._FORCE_PALLAS is False


def test_use_pallas_concurrent_toggles_settle_clean():
    """N threads bouncing the toggle through the context manager leave
    the flag exactly where it started (the lock serializes the
    read-modify-write the bare global raced on)."""
    import threading

    n = 16
    barrier = threading.Barrier(n)

    def worker():
        barrier.wait()
        for _ in range(50):
            with ops.use_pallas():
                pass

    threads = [threading.Thread(target=worker) for _ in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert ops._FORCE_PALLAS is False


# --- regression: lazy jitted singletons build exactly once -----------------

def _stress_first_call(monkeypatch, reset, getter, expected_jits):
    """Race ``n`` threads into a cold ``getter`` with a slowed
    ``jax.jit``: pre-fix (no lock) several threads pass the None check
    together and the build runs more than once."""
    import threading
    import time

    reset(monkeypatch)
    real_jit = jax.jit
    jits = []

    def slow_jit(*a, **kw):
        jits.append(1)
        time.sleep(0.02)
        return real_jit(*a, **kw)

    monkeypatch.setattr(jax, "jit", slow_jit)
    n = 8
    barrier = threading.Barrier(n)
    results = []

    def worker():
        barrier.wait()
        results.append(getter())

    threads = [threading.Thread(target=worker) for _ in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    monkeypatch.setattr(jax, "jit", real_jit)
    assert len(jits) == expected_jits
    assert all(r is results[0] for r in results)


def test_jax_state_fns_first_call_races_build_once(monkeypatch):
    from repro.selector import rank

    _stress_first_call(
        monkeypatch,
        lambda mp: mp.setattr(rank, "_JAX_STATE_FNS", None),
        rank._jax_state_fns, expected_jits=3)


def test_jax_topk_fn_first_call_races_build_once(monkeypatch):
    from repro.selector import rank

    _stress_first_call(
        monkeypatch,
        lambda mp: mp.setattr(rank, "_JAX_TOPK_FN", None),
        rank._jax_topk_fn, expected_jits=1)


def test_rank_delta_fns_first_call_races_build_once(monkeypatch):
    _stress_first_call(
        monkeypatch,
        lambda mp: mp.setattr(rank_delta, "_RANK_DELTA_FNS", None),
        rank_delta.rank_delta_fns, expected_jits=2)
