"""Tests for the unified repro.selector API.

Covers the tentpole acceptance criteria: old-vs-new ranking parity on the
paper's 180-cell trace, ProfilingStore JSONL round-trips, and
SelectionService cache behaviour under price changes.
"""
import pytest

from repro.core import costmodel, spark_sim
from repro.core.costmodel import TpuPriceModel
from repro.core.flora import Flora
from repro.core.tpu_flora import (MeshOption, TpuFlora, WorkloadRecord,
                                  make_service)
from repro.core.trace import JobClass
from repro.selector import (GcpVmCatalog, ProfilingStore, SelectionService,
                            TpuSliceCatalog, rank_dense, rank_pairs)


@pytest.fixture(scope="module")
def trace():
    return spark_sim.generate_trace(seed=0)


@pytest.fixture(scope="module")
def price():
    return costmodel.LinearPriceModel()


# --- the historical implementation, kept verbatim as the parity oracle ---------

def _legacy_rank_generic(runtime_hours, jobs, config_ids, hourly_cost):
    scores = {c: 0.0 for c in config_ids}
    counts = {c: 0 for c in config_ids}
    for j in jobs:
        costs = {c: runtime_hours[(j, c)] * hourly_cost(c)
                 for c in config_ids if (j, c) in runtime_hours}
        if not costs:
            continue
        best = min(costs.values())
        for c, v in costs.items():
            scores[c] += v / best
            counts[c] += 1
    order = {c: i for i, c in enumerate(config_ids)}
    ranked = [(c, scores[c],
               scores[c] / counts[c] if counts[c] else float("inf"))
              for c in config_ids]
    ranked.sort(key=lambda r: (r[1], order[r[0]]))
    return ranked


def _legacy_flora_rank(trace, price, job_class, exclude_algorithms=()):
    test_jobs = trace.filter_jobs(job_class=job_class,
                                  exclude_algorithms=exclude_algorithms)
    runtime_hours = {
        (j.name, c.index): trace.runtime_s(j, c) / 3600.0
        for j in test_jobs for c in trace.configs if trace.has(j, c)}
    by_index = {c.index: c for c in trace.configs}
    return _legacy_rank_generic(
        runtime_hours, [j.name for j in test_jobs],
        [c.index for c in trace.configs],
        lambda idx: price(by_index[idx]))


# --- old-vs-new parity on the paper's 180-cell trace (Tables IV-V) --------------

@pytest.mark.parametrize("job_class", [JobClass.A, JobClass.B, None])
def test_rank_parity_with_legacy_loop(trace, price, job_class):
    flora = Flora(trace, price, one_class=job_class is None)
    new = flora.rank(job_class if job_class else JobClass.A)
    old = _legacy_flora_rank(trace, price, job_class)
    assert [r.config_id for r in new] == [c for c, _, _ in old]
    for r, (_, score, mean) in zip(new, old):
        assert r.score == pytest.approx(score, rel=1e-12)
        assert r.mean_norm_cost == pytest.approx(mean, rel=1e-12)


def test_rank_parity_leave_one_out_all_algorithms(trace, price):
    """The argmin (and full ordering) matches the legacy path for every
    leave-one-algorithm-out submission of the evaluation (§III-A)."""
    flora = Flora(trace, price)
    for job in trace.jobs:
        new = flora.rank(job.job_class, exclude_algorithms=(job.algorithm,))
        old = _legacy_flora_rank(trace, price, job.job_class,
                                 exclude_algorithms=(job.algorithm,))
        assert [r.config_id for r in new] == [c for c, _, _ in old], job.name
    # the paper's headline picks survive the port: A -> #9, B -> #1
    for job in trace.jobs:
        sel = flora.select_for_job(job)
        assert sel.index == (9 if job.job_class is JobClass.A else 1)


def test_tpu_rank_parity_with_legacy_loop():
    options = [
        MeshOption("dp256xtp1", "v5e", 256, (256, 1), ("data", "model")),
        MeshOption("dp16xtp16", "v5e", 256, (16, 16), ("data", "model")),
        MeshOption("v5p-dp16xtp16", "v5p", 256, (16, 16), ("data", "model")),
    ]
    speed = {"dp256xtp1": 4.0, "dp16xtp16": 1.0, "v5p-dp16xtp16": 0.55}
    recs = [WorkloadRecord(arch=a, shape="decode_32k", mesh=m,
                           step_seconds=s)
            for a in ("a1", "a2") for m, s in speed.items()]
    price = TpuPriceModel("ondemand")
    flora = TpuFlora(options, recs, price)
    new = flora.rank(JobClass.A)
    rt = {(r.job_id, r.mesh): r.step_seconds / 3600.0 for r in recs}
    by_name = {o.name: o for o in options}
    old = _legacy_rank_generic(
        rt, ["a1:decode_32k", "a2:decode_32k"], [o.name for o in options],
        lambda n: by_name[n].hourly_cost(price))
    assert [r.config_id for r in new] == [c for c, _, _ in old]
    for r, (_, score, _) in zip(new, old):
        assert r.score == pytest.approx(score, rel=1e-12)


# --- ProfilingStore -------------------------------------------------------------

def test_store_jsonl_roundtrip(trace, tmp_path):
    store = ProfilingStore.from_trace(trace)
    path = str(tmp_path / "trace.jsonl")
    store.save_jsonl(path)
    clone = ProfilingStore.load_jsonl(path)
    assert clone.config_ids == store.config_ids
    assert clone.job_ids == store.job_ids
    assert len(clone) == len(store) == 180
    for j in store.job_ids[:5]:
        assert clone.meta(j) == store.meta(j)
        for c in store.config_ids:
            assert clone.runtime_hours(j, c) == store.runtime_hours(j, c)


def test_store_rejects_wrong_format(tmp_path):
    path = str(tmp_path / "bad.jsonl")
    with open(path, "w") as f:
        f.write('{"format": "something-else", "version": 1}\n')
    with pytest.raises(ValueError, match="not a profiling store"):
        ProfilingStore.load_jsonl(path)
    with open(path, "w") as f:
        f.write('{"format": "repro.selector.profiling-store", "version": 99}\n')
    with pytest.raises(ValueError, match="version"):
        ProfilingStore.load_jsonl(path)


def test_store_incremental_insert_and_partial_mask():
    store = ProfilingStore(config_ids=["c1", "c2"])
    store.add("j1", "c1", 1.0, job_class=JobClass.A, group="g1")
    store.add("j1", "c2", 2.0)
    store.add("j2", "c1", 3.0, job_class=JobClass.B, group="g2")
    store.add("j2", "c3", 4.0)          # new config appended on first sight
    assert store.config_ids == ["c1", "c2", "c3"]
    hours, mask = store.matrix()
    assert hours.shape == (2, 3)
    assert mask.tolist() == [[True, True, False], [True, False, True]]
    assert store.meta("j1").job_class is JobClass.A   # meta survives updates
    assert store.select_jobs(job_class=JobClass.B) == ["j2"]
    assert store.select_jobs(exclude_groups=("g1",)) == ["j2"]
    with pytest.raises(ValueError, match="non-positive"):
        store.add("j3", "c1", 0.0)


def test_partial_profiling_jobs_contribute_where_profiled():
    """A job profiled on a subset of configs contributes only there (the
    paper's partial re-profiling, §II-B)."""
    rt = {("j1", "c1"): 1.0, ("j1", "c2"): 4.0, ("j2", "c2"): 1.0}
    ranked = rank_pairs(rt, ["j1", "j2"], ["c1", "c2"], lambda c: 1.0)
    by_id = {r.config_id: r for r in ranked}
    assert by_id["c1"].score == pytest.approx(1.0)    # only j1's norm
    assert by_id["c2"].score == pytest.approx(5.0)    # j1: 4.0, j2: 1.0


# --- catalogs -------------------------------------------------------------------

def test_gcp_catalog_prices_match_model(trace, price):
    cat = GcpVmCatalog(trace.configs, price)
    vec = cat.price_vector()
    for i, c in enumerate(trace.configs):
        assert vec[i] == pytest.approx(price(c))
        assert cat.entry(c.index) is c
        assert cat.describe(c.index)["cores"] == c.total_cores
    with pytest.raises(ValueError, match="price source"):
        GcpVmCatalog(trace.configs).price_vector()


def test_tpu_catalog_prices_and_override():
    opts = [MeshOption("a", "v5e", 256, (256,), ("data",)),
            MeshOption("b", "v5p", 256, (256,), ("data",))]
    cat = TpuSliceCatalog(opts, TpuPriceModel("ondemand"))
    assert cat.hourly_cost("a") == pytest.approx(1.20 * 256)
    spot = cat.price_vector(TpuPriceModel("spot"))
    assert spot[1] == pytest.approx(2.10 * 256)


# --- SelectionService: caching + price invalidation ------------------------------

def _tpu_service(price):
    options = [
        MeshOption("dp256xtp1", "v5e", 256, (256, 1), ("data", "model")),
        MeshOption("dp16xtp16", "v5e", 256, (16, 16), ("data", "model")),
        MeshOption("v5p-dp16xtp16", "v5p", 256, (16, 16), ("data", "model")),
    ]
    speed = {"dp256xtp1": {"train": 1.0, "decode": 4.0},
             "dp16xtp16": {"train": 1.5, "decode": 1.0},
             "v5p-dp16xtp16": {"train": 0.8, "decode": 0.55}}
    recs = [WorkloadRecord(arch=a, shape=shape, mesh=m, step_seconds=s[kind])
            for a in ("a1", "a2")
            for shape, kind in (("train_4k", "train"),
                                ("decode_32k", "decode"))
            for m, s in speed.items()]
    return make_service(options, recs, price)


def test_service_caches_per_class_and_epoch():
    svc = _tpu_service(TpuPriceModel("ondemand"))
    d1 = svc.submit("decode_32k")
    assert not d1.from_cache and svc.cache_misses == 1
    d2 = svc.submit("decode_32k")
    assert d2.from_cache and svc.cache_hits == 1
    assert d2.config_id == d1.config_id
    svc.submit("train_4k")                    # different class: new entry
    assert svc.cache_misses == 2
    svc.submit("decode_32k", exclude_groups=("a1",))   # new exclusion key
    assert svc.cache_misses == 3


def test_service_price_change_invalidates_and_reroutes():
    """Flora's defining property end-to-end: when v5p drops to v5e prices,
    the cached v5e decision is invalidated and v5p's speed wins."""
    svc = _tpu_service(TpuPriceModel("ondemand"))
    before = svc.submit("decode_32k")
    assert before.entry.generation == "v5e"
    assert before.price_epoch == 0
    svc.set_price_source(TpuPriceModel(rates={"v5p": 1.2, "v5e": 1.2}))
    after = svc.submit("decode_32k")
    assert not after.from_cache                # cache was invalidated
    assert after.price_epoch == 1
    assert after.entry.generation == "v5p"
    again = svc.submit("decode_32k")
    assert again.from_cache                    # re-cached under new epoch


def test_service_profiled_job_gets_own_group_excluded(trace, price):
    svc = SelectionService(GcpVmCatalog(trace.configs, price),
                           ProfilingStore.from_trace(trace), price)
    job = trace.jobs[0]                        # profiled: auto-excludes own
    d = svc.submit(job.name)
    flora = Flora(trace, price)
    assert d.config_id == flora.select_for_job(job).index
    assert d.job_class is job.job_class        # class from store metadata


def test_service_empty_class_raises():
    svc = _tpu_service(TpuPriceModel())
    with pytest.raises(ValueError, match="no test jobs"):
        svc.rank(job_class=JobClass.A, exclude_groups=("a1", "a2"))


def test_service_store_insert_invalidates_cache():
    """Streamed-in profiling cells must not be masked by a stale cached
    ranking (the store's mutation counter is part of the cache key)."""
    svc = _tpu_service(TpuPriceModel("ondemand"))
    first = svc.submit("decode_32k")
    assert first.config_id == "dp16xtp16"
    # new measurements arrive: dp256xtp1 is suddenly the fastest decoder
    for arch in ("a1", "a2"):
        svc.store.add(f"{arch}:decode_32k", "dp256xtp1", 0.01 / 3600,
                      job_class=JobClass.A, group=arch)
    again = svc.submit("decode_32k")
    assert not again.from_cache
    assert again.config_id == "dp256xtp1"


def test_service_all_unprofiled_catalog_raises():
    """A catalog/store id mismatch must raise, not return an arbitrary
    first catalog entry as a confident-looking Decision."""
    opts = [MeshOption("typo-mesh", "v5e", 256, (256,), ("data",))]
    recs = [WorkloadRecord(arch="a1", shape="decode_32k",
                           mesh="real-mesh", step_seconds=1.0)]
    svc = make_service(opts, recs, TpuPriceModel())
    with pytest.raises(ValueError, match="no profiled configurations"):
        svc.submit("decode_32k")


def test_dryrun_mesh_topology_recovered():
    from repro.core.tpu_flora import service_from_dryrun_report
    report = {"cells": [
        {"arch": "a", "shape": "train_4k", "mesh": "dp16xtp16", "ok": True,
         "roofline": {"compute_s": .2, "memory_s": .1, "collective_s": .05}},
        {"arch": "a", "shape": "train_4k", "mesh": "oddname", "ok": True,
         "roofline": {"compute_s": .3, "memory_s": .1, "collective_s": .05}},
    ]}
    svc = service_from_dryrun_report(report, TpuPriceModel())
    named = svc.catalog.entry("dp16xtp16")
    assert named.mesh_shape == (16, 16)
    assert named.mesh_axes == ("data", "model")
    odd = svc.catalog.entry("oddname")
    assert odd.mesh_shape == (256,) and odd.mesh_axes == ("data",)


# --- vectorized rank error paths -------------------------------------------------

def test_rank_dense_rejects_empty_and_nonpositive():
    import numpy as np
    with pytest.raises(ValueError, match="no test jobs"):
        rank_dense(np.zeros((0, 2)), np.zeros((0, 2), bool),
                   np.ones(2), ["a", "b"])
    hours = np.asarray([[1.0, 0.0]])
    mask = np.ones_like(hours, dtype=bool)
    with pytest.raises(ValueError, match="non-positive cost for job 'j'"):
        rank_dense(hours, mask, np.ones(2), ["a", "b"], job_ids=["j"])
