"""Tests for the unified telemetry layer (repro.obs, DESIGN.md §12).

Covers the ISSUE 7 acceptance surface: the lock-free sharded registry
(order/shard-count-independent merges, pinned by a hypothesis property),
deterministic span timing over an injectable clock, the Prometheus/JSON
exports, the periodic ``"metrics"`` journal record kind (golden-pinned,
replayable through the unmodified byte-exact audit, tick-latency
percentiles recovered from the journal alone), the front-end
memory-regression fix (per-submission logs -> counters), and the
``train.step`` / ``serve.prefill`` / ``serve.decode`` span promotion.

Regenerate the metrics-journal golden after a *deliberate* schema change
with

    PYTHONPATH=src python tests/test_obs.py --regen-golden

and add a migration note to DESIGN.md §8 in the same commit.
"""
import json
import os
import tracemalloc

import pytest

from hyputil import given, settings, st
from repro.market import (JournalReplayer, SelectionDaemon, ServeFrontend,
                          Submission, Tick)
from repro.obs import (Counter, FakeClock, Gauge, Histogram, MetricsRegistry,
                       NULL_SPAN, histogram_quantile, maybe_span)
from repro.selector import IdentityCatalog, PriceTable, SelectionService
from test_frontend import _frontend, _recorded, _universe

FIXTURES = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "fixtures")
GOLDEN_METRICS = os.path.join(
    FIXTURES, "decision_journal_v2_metrics.golden.jsonl")


# --- registry primitives ---------------------------------------------------------

def test_counter_gauge_histogram_basics():
    reg = MetricsRegistry()
    c = reg.counter("a.b")
    c.inc()
    c.inc(4)
    assert c.value == 5
    c.set(2)                                 # legacy-attribute shim
    assert c.value == 2
    assert reg.counter("a.b") is c           # get-or-create

    g = reg.gauge("depth")
    g.set(3)
    assert g.value == 3.0

    h = reg.histogram("h", buckets=(0.5, 1.0))
    h.observe(0.25)
    h.observe(0.75)
    h.observe(2.0)
    assert h.count == 3
    assert h.sum == pytest.approx(3.0)
    assert h.merged()[0] == [1, 1, 1]

    with pytest.raises(TypeError):           # kind conflict
        reg.histogram("a.b")
    with pytest.raises(ValueError):          # bad metric name
        reg.counter("no spaces")
    with pytest.raises(ValueError):          # buckets must increase
        Histogram("bad", buckets=(1.0, 1.0))


def test_registry_render_prom_and_json():
    reg = MetricsRegistry()
    reg.counter("a.b").inc(2)
    reg.gauge("g").set(1.5)
    h = reg.histogram("h", buckets=(0.5, 1.0))
    h.observe(0.25)
    h.observe(2.0)
    assert reg.render() == (
        "# TYPE a_b counter\n"
        "a_b 2\n"
        "# TYPE g gauge\n"
        "g 1.5\n"
        "# TYPE h histogram\n"
        'h_bucket{le="0.5"} 1\n'
        'h_bucket{le="1.0"} 1\n'
        'h_bucket{le="+Inf"} 2\n'
        "h_sum 2.25\n"
        "h_count 2\n")
    snap = json.loads(reg.render("json"))
    assert snap["counters"] == {"a.b": 2}
    assert snap["gauges"] == {"g": 1.5}
    assert snap["histograms"]["h"] == {"le": [0.5, 1.0], "counts": [1, 0, 1],
                                       "sum": 2.25, "count": 2}
    with pytest.raises(ValueError):
        reg.render("xml")


def test_histogram_quantile():
    bounds = (1.0, 2.0, 4.0)
    assert histogram_quantile(bounds, [0, 0, 0, 0], 0.5) is None
    # linear interpolation within the winning bucket (lo = 0 for the first)
    assert histogram_quantile(bounds, [4, 0, 0, 0], 0.5) \
        == pytest.approx(0.5)
    assert histogram_quantile(bounds, [2, 2, 0, 0], 0.75) \
        == pytest.approx(1.5)
    # samples in the +Inf bucket clamp to the last finite bound
    assert histogram_quantile(bounds, [0, 0, 0, 5], 0.99) == 4.0
    with pytest.raises(ValueError):
        histogram_quantile(bounds, [1, 0, 0, 0], 1.5)


def test_spans_fake_clock_deterministic():
    """A span across k intervening clock reads is exactly (k+1) steps —
    the advance-on-read contract golden tests pin span output with."""
    def run():
        reg = MetricsRegistry(clock=FakeClock(step=0.001))
        with reg.span("tick.total"):
            pass                             # enter + exit: one step
        with reg.span("tick.total"):
            reg.clock()                      # one intervening read: two
        return reg
    reg = run()
    h = reg.histogram("tick.total")
    counts, total_ns = h.merged()
    assert h.count == 2 and total_ns == 3_000_000
    assert reg.render() == run().render()    # same ops => same bytes


def test_spans_disabled_are_free_null_spans():
    reg = MetricsRegistry(spans_enabled=False)
    assert reg.span("x") is NULL_SPAN
    with reg.span("x"):
        pass
    assert reg.snapshot()["histograms"] == {}   # not even created
    assert maybe_span(None, "x") is NULL_SPAN
    # counters stay live in both modes: they are accounting, not spans
    reg.counter("c").inc()
    assert reg.counter("c").value == 1


def test_shard_merge_deterministic_example():
    """Always-on pin of the merge property (the hypothesis sweep below
    skips when the extra is absent): bucket-edge, overflow and zero
    samples through 1, 3 and 5 cells merge to identical renders."""
    samples = [0.0, 1e-6, 2.5e-6, 9.9e-6, 1e-3, 0.42, 11.0, 1e-6, 0.0]
    for n_shards in (3, 5):
        _assert_merge_invariant(samples, n_shards)


@settings(max_examples=60, deadline=None)
@given(st.lists(st.floats(min_value=0.0, max_value=15.0, allow_nan=False),
                max_size=120),
       st.integers(min_value=1, max_value=7))
def test_shard_merge_is_order_and_shard_count_independent(samples, n_shards):
    """The tentpole determinism property: the same samples through 1
    cell or N cells — in any observation order — merge to identical
    bucket counts, ns-exact sums, and rendered output."""
    _assert_merge_invariant(samples, n_shards)


def _assert_merge_invariant(samples, n_shards):
    one = Histogram("h")
    for v in samples:
        one.cell(0).observe(v)
    many = Histogram("h")
    for i, v in enumerate(samples):
        many.cell(i % n_shards).observe(v)
    rev = Histogram("h")
    for i, v in enumerate(reversed(samples)):
        rev.cell(n_shards - 1 - (i % n_shards)).observe(v)
    assert one.dump() == many.dump() == rev.dump()

    r1, rn = MetricsRegistry(), MetricsRegistry()
    for i, v in enumerate(samples):
        r1.histogram("h").cell(0).observe(v)
        r1.counter("c").cell(0).inc(i)
        rn.histogram("h").cell(i % n_shards).observe(v)
        rn.counter("c").cell(i % n_shards).inc(i)
    assert r1.render() == rn.render()
    assert r1.render("json") == rn.render("json")


# --- the metrics journal record kind (golden + replay) ---------------------------

def metrics_golden_frontend():
    """The pinned run: everything (service, ticker, front-end) on one
    FakeClock registry, every serve span timed (span_sample=1), a
    cumulative ``metrics`` record journaled every 2 ticks."""
    store, ids, base = _universe()
    feed = _recorded(base, n_ticks=6)
    reg = MetricsRegistry(clock=FakeClock(), spans_enabled=True)
    svc = SelectionService(IdentityCatalog(ids), store, PriceTable(base),
                           backend="numpy", metrics=reg)
    fe = ServeFrontend(svc, feed, workers=2, top_k=2,
                       metrics_every=2, span_sample=1)
    return fe, store


def run_metrics_golden(fe):
    fe.warm([Submission("j1"), Submission("j2")])
    fe.submit(Submission("j1"))
    fe.submit(Submission("j2"))
    fe.step_tick()                       # tick 1
    fe.serve_queued()                    # two snapshot decisions
    fe.step_tick()                       # tick 2 -> metrics record
    fe.submit(Submission("j3"))          # unwarmed: forwarded to control
    fe.serve_queued()
    fe.step_tick()                       # tick 3 (serves the forward)
    fe.submit(Submission("j1"))
    fe.serve_queued()
    fe.step_tick()                       # tick 4 -> metrics record
    fe.step_tick()                       # tick 5
    fe.step_tick()                       # tick 6 -> metrics record
    return fe.close()


def test_metrics_journal_golden_file():
    """Pins the metrics-record schema byte-for-byte: cumulative sorted
    counters + histogram dumps, worker/tick stamps, merge placement.
    If this fails you changed the record shape — follow the regen +
    DESIGN.md §8 discipline in the module docstring."""
    fe, _ = metrics_golden_frontend()
    stats = run_metrics_golden(fe)
    assert stats.accounted and stats.shed == 0
    with open(GOLDEN_METRICS) as f:
        assert fe.journal_dump() == f.read()


def test_metrics_journal_replays_through_unmodified_audit():
    """THE ISSUE 7 acceptance criterion: a journal carrying ``metrics``
    records passes the byte-exact numpy audit unchanged, and the audit
    recovers tick-latency percentiles from the journal alone."""
    fe, store = metrics_golden_frontend()
    run_metrics_golden(fe)
    fe2, _ = metrics_golden_frontend()
    run_metrics_golden(fe2)
    text = fe.journal_dump()
    assert text == fe2.journal_dump()    # deterministic end to end

    audit = JournalReplayer(store, text).audit()
    assert audit.ok, audit.mismatches[:5]
    assert audit.contract.bit_identical and audit.drift == ()
    assert audit.metrics_records == 3
    # tick latency recovered from the last cumulative record: all 6
    # ticks, FakeClock-deterministic percentiles
    assert audit.tick_latency is not None
    assert audit.tick_latency["count"] == 6
    assert 0.0 < audit.tick_latency["p50"] <= audit.tick_latency["p99"]

    header, records = SelectionDaemon.loads_journal(text)
    mets = [r for r in records if r["kind"] == "metrics"]
    assert [m["tick"] for m in mets] == [1, 3, 5]     # ticks 2, 4, 6
    assert all(m["worker"] == 0 for m in mets)
    # cumulative, not delta: counters never decrease across records
    for a, b in zip(mets, mets[1:]):
        assert all(b["counters"][k] >= v for k, v in a["counters"].items())
    last = mets[-1]["histograms"]["tick.total"]
    assert last["count"] == 6
    assert [r["seq"] for r in records] == list(range(1, len(records) + 1))


def test_daemon_metrics_every_and_audit_accounting():
    """The single-threaded daemon journals the same record kind; the
    audit counts them and checks their stamped price epoch."""
    store, ids, base = _universe()
    svc = SelectionService(IdentityCatalog(ids), store, PriceTable(base))
    daemon = SelectionDaemon(svc, _recorded(base, n_ticks=5),
                             metrics_every=2)
    for _ in range(5):
        daemon.handle(Tick())
    daemon.handle(Submission("j1"))
    text = daemon.journal_dump()
    header, records = SelectionDaemon.loads_journal(text)
    assert [r["kind"] for r in records].count("metrics") == 2
    audit = JournalReplayer(store, text).audit()
    assert audit.ok, audit.mismatches[:5]
    assert audit.metrics_records == 2
    # last record taken after tick 4: cumulative count covers 4 ticks
    assert audit.tick_latency["count"] == 4

    with pytest.raises(ValueError):
        SelectionDaemon(svc, _recorded(base), metrics_every=0)
    with pytest.raises(ValueError):
        ServeFrontend(svc, _recorded(base), metrics_every=True)
    with pytest.raises(ValueError):
        ServeFrontend(svc, _recorded(base), span_sample=0)


def test_metrics_default_off_keeps_journals_metrics_free():
    """metrics_every=None (the default) journals no metrics records —
    the guarantee that kept the pre-obs golden journals byte-identical."""
    fe, _ = _frontend(n_ticks=4)
    fe.submit(Submission("j1"))
    fe.step_tick()
    fe.serve_queued()
    fe.step_tick()
    fe.close()
    _, records = SelectionDaemon.loads_journal(fe.journal_dump())
    assert all(r["kind"] != "metrics" for r in records)


# --- the front-end memory-regression fix -----------------------------------------

def test_frontend_shed_path_is_constant_memory():
    """The old per-submission ``_accepted_log``/``_shed_log`` deques grew
    forever on a long-running deployment; accounting is counters now.
    20k shed submissions must allocate ~nothing that survives."""
    fe, _ = _frontend(n_ticks=2)
    assert not hasattr(fe, "_accepted_log")
    assert not hasattr(fe, "_shed_log")
    fe.close()                           # closed => every submit sheds
    fe.submit(Submission("j0"))          # create the shed cell up front
    tracemalloc.start()
    before, _ = tracemalloc.get_traced_memory()
    for _ in range(20_000):
        assert fe.submit(Submission("j1")) is False
    after, _ = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    assert fe.stats().shed == 20_001
    assert after - before < 64 * 1024    # vs ~MBs for the old logs
    # the merged stats stay exact counters
    assert fe.stats().accounted


# --- span promotion: train loop + serving engine ---------------------------------

def test_train_loop_records_step_spans_and_slow_steps():
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    from repro.train.train_loop import (StragglerWatchdog, TrainConfig,
                                        train_loop)
    # scripted clock: two reads per step -> exact per-step durations,
    # with one 50x straggler the watchdog must flag
    durations = [0.001] * 6 + [0.05] + [0.001]
    reads, t = [], 0.0
    for d in durations:
        reads.append(t)
        t += d
        reads.append(t)
    reg = MetricsRegistry(clock=iter(reads).__next__)

    def fake_step(params, opt_state, batch):
        return params, opt_state, {"loss": jnp.float32(1.0),
                                   "grad_norm": jnp.float32(0.0)}

    wd = StragglerWatchdog(factor=3.0)
    _, _, history = train_loop(
        None, TrainConfig(), {"w": jnp.zeros((1,))}, {"t": jnp.zeros(())},
        iter([{}] * len(durations)), steps=len(durations), watchdog=wd,
        log_every=0, train_step=fake_step, obs=reg)
    assert history["step_time"] == pytest.approx(durations)
    assert reg.histogram("train.step").count == len(durations)
    assert len(wd.events) == 1
    assert reg.counter("train.slow_steps").value == 1


def test_engine_records_prefill_decode_spans():
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    import repro.configs as C
    from repro.models import build_model
    from repro.serve.engine import Engine, Request
    cfg = C.reduced(C.get("qwen3-1.7b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    reg = MetricsRegistry(clock=FakeClock(step=0.001))
    eng = Engine(model, params, slots=2, max_len=32, metrics=reg)
    prompt = jnp.arange(8, dtype=jnp.int32) % cfg.vocab_size
    [comp] = eng.generate_batch([Request(uid=1, prompt=prompt,
                                         max_new_tokens=2)])
    assert len(comp.tokens) == 2
    assert reg.histogram("serve.prefill").count == 1
    assert reg.histogram("serve.decode").count == 1
    # the Completion ms fields ride the same injectable clock
    assert comp.prefill_ms == pytest.approx(1.0)
    assert comp.decode_ms == pytest.approx(1.0)


if __name__ == "__main__":
    import sys
    if "--regen-golden" in sys.argv:
        fe, _ = metrics_golden_frontend()
        run_metrics_golden(fe)
        fe.save_journal(GOLDEN_METRICS)
        print(f"wrote {GOLDEN_METRICS}")
    else:
        print(__doc__)
