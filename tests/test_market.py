"""Tests for the live price market (repro.market + incremental repricing).

Covers the ISSUE 2 acceptance surface: PriceTable price sources,
RankState reprice bit-identity with the cold path, SelectionService
streaming price-epoch semantics, feed/ticker/daemon determinism, journal
round-trips, the hysteresis migration advisor, and the ProfilingStore
growth guarantee.
"""
import json

import numpy as np
import pytest

from repro.core.costmodel import TpuPriceModel
from repro.core.tpu_flora import MeshOption, WorkloadRecord, make_service
from repro.core.trace import JobClass
from repro.market import (MarketEvent, PriceDelta, PriceFeed, PriceTicker,
                          SelectionDaemon, SimulatedSpotFeed, Submission,
                          Tick, should_migrate, synthetic_stream)
from repro.selector import (PriceTable, ProfilingStore, RankState,
                            SelectionService, TpuSliceCatalog, rank_dense)


# --- shared universe ------------------------------------------------------------

MESH_OPTIONS = [
    MeshOption("dp256xtp1", "v5e", 256, (256, 1), ("data", "model")),
    MeshOption("dp16xtp16", "v5e", 256, (16, 16), ("data", "model")),
    MeshOption("v5p-dp16xtp16", "v5p", 256, (16, 16), ("data", "model")),
]
SPEED = {"dp256xtp1": {"train_4k": 1.0, "decode_32k": 4.0},
         "dp16xtp16": {"train_4k": 1.5, "decode_32k": 1.0},
         "v5p-dp16xtp16": {"train_4k": 0.8, "decode_32k": 0.55}}


def live_service() -> SelectionService:
    recs = [WorkloadRecord(arch=a, shape=s, mesh=m, step_seconds=v)
            for a in ("a1", "a2")
            for m, shapes in SPEED.items() for s, v in shapes.items()]
    svc = make_service(MESH_OPTIONS, recs, TpuPriceModel("ondemand"))
    svc.set_price_source(PriceTable.from_catalog(svc.catalog,
                                                 TpuPriceModel("ondemand")))
    return svc


def random_state(seed=0, n_jobs=20, n_cfgs=60):
    rng = np.random.default_rng(seed)
    hours = rng.uniform(0.05, 10.0, (n_jobs, n_cfgs))
    mask = rng.random((n_jobs, n_cfgs)) > 0.25
    mask[np.arange(n_jobs), rng.integers(0, n_cfgs, n_jobs)] = True
    prices = rng.uniform(0.5, 20.0, n_cfgs)
    ids = [f"c{i}" for i in range(n_cfgs)]
    return hours, mask, prices, ids, rng


# --- PriceTable -----------------------------------------------------------------

def test_price_table_snapshots_catalog_and_overrides():
    cat = TpuSliceCatalog(MESH_OPTIONS, TpuPriceModel("ondemand"))
    table = PriceTable.from_catalog(cat)
    assert table["dp256xtp1"] == pytest.approx(1.20 * 256)
    # a table source short-circuits the per-entry price model
    assert cat.hourly_cost("dp256xtp1", table) == table["dp256xtp1"]
    table.apply({"dp256xtp1": 99.0})
    assert table.version == 1
    assert cat.hourly_cost("dp256xtp1", table) == 99.0
    assert cat.price_vector(table)[0] == 99.0
    # the model default is untouched
    assert cat.hourly_cost("dp256xtp1") == pytest.approx(1.20 * 256)


def test_price_table_rejects_nonpositive():
    with pytest.raises(ValueError, match="non-positive"):
        PriceTable({"a": 0.0})
    table = PriceTable({"a": 1.0})
    with pytest.raises(ValueError, match="non-positive"):
        table.apply({"a": -2.0})
    table.apply({})                         # no-op: no epoch
    assert table.version == 0


def test_price_table_apply_is_atomic():
    """A batch with one bad quote must leave the table (and its version)
    untouched — a half-applied batch would desync prices from every
    version-keyed ranking cache."""
    table = PriceTable({"a": 1.0, "b": 2.0})
    with pytest.raises(ValueError, match="non-positive"):
        table.apply({"a": 5.0, "b": -1.0})
    assert table["a"] == 1.0 and table["b"] == 2.0
    assert table.version == 0
    table.apply({"a": 5.0, "b": 3.0})       # the good batch still lands
    assert table["a"] == 5.0 and table.version == 1


# --- RankState: incremental reprice bit-identity ---------------------------------

def test_rank_state_build_matches_rank_dense():
    hours, mask, prices, ids, _ = random_state()
    state = RankState(hours, mask, prices, ids)
    assert state.ranking() == rank_dense(hours, mask, prices, ids)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_reprice_bit_identical_to_cold_path(seed):
    """Every tick of a delta stream yields rankings element-wise equal —
    exact floats, not approx — to a cold rank_dense at the same prices."""
    hours, mask, prices, ids, rng = random_state(seed)
    state = RankState(hours, mask, prices, ids)
    live = prices.copy()
    for _ in range(30):
        cols = rng.choice(len(ids), rng.integers(1, 6), replace=False)
        deltas = {ids[c]: float(live[c] * rng.uniform(0.2, 4.0))
                  for c in cols}
        state.reprice(deltas)
        for cid, p in deltas.items():
            live[int(cid[1:])] = p
        cold = rank_dense(hours, mask, live, ids)
        assert state.ranking() == cold      # dataclass ==: ids AND scores


def test_reprice_row_min_handoff():
    """When a changed column was (or becomes) a row's masked minimum, the
    whole row renormalizes; scores still match the cold path exactly."""
    hours = np.array([[1.0, 2.0, 3.0], [5.0, 1.0, 1.5]])
    mask = np.ones_like(hours, dtype=bool)
    prices = np.array([1.0, 1.0, 1.0])
    ids = ["a", "b", "c"]
    state = RankState(hours, mask, prices, ids)
    moved = state.reprice({"a": 10.0})      # column a loses row 0's min
    assert moved == 1
    assert state.ranking() == rank_dense(
        hours, mask, np.array([10.0, 1.0, 1.0]), ids)
    moved = state.reprice({"c": 0.1})       # column c takes both row mins
    assert moved == 2
    assert state.ranking() == rank_dense(
        hours, mask, np.array([10.0, 1.0, 0.1]), ids)


def test_reprice_validation():
    hours, mask, prices, ids, _ = random_state(n_jobs=4, n_cfgs=6)
    state = RankState(hours, mask, prices, ids)
    with pytest.raises(ValueError, match="unknown config id"):
        state.reprice({"nope": 1.0})
    with pytest.raises(ValueError, match="non-positive cost"):
        state.reprice({ids[0]: 0.0})
    assert state.reprice({}) == 0
    assert state.reprices == 0


def test_rank_state_winner_matches_ranking():
    hours, mask, prices, ids, rng = random_state(3)
    state = RankState(hours, mask, prices, ids)
    assert state.winner() == state.ranking()[0]
    state.reprice({ids[0]: 0.01})
    assert state.winner() == state.ranking()[0]


# --- SelectionService.reprice: streaming price-epoch semantics -------------------

def test_service_reprice_bumps_epoch_and_stays_cached():
    svc = live_service()
    d1 = svc.submit("decode_32k")
    assert d1.config_id == "dp16xtp16" and not d1.from_cache
    refreshed = svc.reprice({"dp256xtp1": 100.0})
    assert refreshed == 1                   # one live class ranking
    d2 = svc.submit("decode_32k")
    assert d2.price_epoch == d1.price_epoch + 1
    assert d2.from_cache                    # refreshed incrementally, no miss
    assert svc.cache_misses == 1
    assert svc.reprice_refreshes == 1


def test_service_reprice_reroutes_like_cold_service():
    """Incrementally repriced decisions equal a fresh service ranked cold
    at the final prices — the streaming/cold consistency bar."""
    svc = live_service()
    svc.submit("decode_32k")
    svc.submit("train_4k")
    # v5p crashes to v5e spot rates over several ticks
    for quote in (800.0, 500.0, 250.0):
        svc.reprice({"v5p-dp16xtp16": quote})
    hot_decode = svc.submit("decode_32k")
    hot_train = svc.submit("train_4k")
    assert hot_decode.from_cache and hot_train.from_cache
    assert hot_decode.config_id == "v5p-dp16xtp16"

    cold = live_service()
    cold.price_source.apply({"v5p-dp16xtp16": 250.0})
    cold.invalidate_prices()
    for shape, hot in (("decode_32k", hot_decode), ("train_4k", hot_train)):
        d = cold.submit(shape)
        assert d.config_id == hot.config_id
        assert [(r.config_id, r.score) for r in d.ranking] == \
            [(r.config_id, r.score) for r in hot.ranking]


def test_service_reprice_rejects_unknown_ids_before_mutating():
    """A batch with an unknown config id must fail atomically — the table
    untouched, live states still in sync with it (the desync would
    otherwise cache wrong rankings on the next valid tick)."""
    svc = live_service()
    d1 = svc.submit("decode_32k")
    before = dict(svc.price_source.items())
    with pytest.raises(ValueError, match="unknown config ids"):
        svc.reprice({"dp256xtp1": 100.0, "zzz": 5.0})
    assert dict(svc.price_source.items()) == before
    assert svc.price_epoch == d1.price_epoch
    svc.reprice({"v5p-dp16xtp16": 250.0})       # next valid tick is sound
    hot = svc.submit("decode_32k")
    cold = live_service()
    cold.price_source.apply({"v5p-dp16xtp16": 250.0})
    cold.invalidate_prices()
    assert hot.config_id == cold.submit("decode_32k").config_id


def test_direct_table_apply_forces_cold_recompute():
    """Quotes applied to the table outside reprice() must not be masked
    by a stale cached ranking: the table version is part of the cache
    key, so the next submit recomputes cold at the real prices."""
    svc = live_service()
    d1 = svc.submit("decode_32k")
    assert d1.config_id == "dp16xtp16"
    svc.price_source.apply({"v5p-dp16xtp16": 120.0})    # bypasses reprice
    d2 = svc.submit("decode_32k")
    assert not d2.from_cache
    assert d2.config_id == "v5p-dp16xtp16"
    assert d2.hourly_cost == 120.0


def test_out_of_band_apply_then_reprice_is_not_served_stale():
    """The apply/reprice interleaving: a live state that missed an
    out-of-band ``table.apply`` must not be re-tagged as current by a
    later ``reprice`` touching different configs — it gets dropped and
    rebuilt cold, matching a fresh service at the same table prices."""
    svc = live_service()
    d1 = svc.submit("decode_32k")
    assert d1.config_id == "dp16xtp16"
    svc.price_source.apply({"v5p-dp16xtp16": 0.001})    # out-of-band quote
    assert svc.reprice({"dp256xtp1": 50.0}) == 0        # stale state dropped
    d2 = svc.submit("decode_32k")
    assert not d2.from_cache                            # cold rebuild
    cold = live_service()
    cold.price_source.apply({"v5p-dp16xtp16": 0.001})
    cold.price_source.apply({"dp256xtp1": 50.0})
    cold.invalidate_prices()
    d_cold = cold.submit("decode_32k")
    assert d2.config_id == d_cold.config_id == "v5p-dp16xtp16"
    assert [(r.config_id, r.score) for r in d2.ranking] == \
        [(r.config_id, r.score) for r in d_cold.ranking]


def test_cache_prunes_entries_under_dead_price_tags():
    """Out-of-band table.apply + submit cycles must not grow the ranking
    cache without bound: entries keyed on superseded table versions are
    unreachable forever and get pruned on the next miss."""
    svc = live_service()
    for i in range(5):
        svc.price_source.apply({"dp256xtp1": 50.0 + i})
        svc.submit("decode_32k")
    assert len(svc._cache) == 1             # only the current tag survives


def test_service_reprice_requires_price_table():
    recs = [WorkloadRecord(arch="a1", shape="decode_32k", mesh=m,
                           step_seconds=v["decode_32k"])
            for m, v in SPEED.items()]
    svc = make_service(MESH_OPTIONS, recs, TpuPriceModel("ondemand"))
    with pytest.raises(ValueError, match="PriceTable"):
        svc.reprice({"dp256xtp1": 1.0})


def test_service_reprice_drops_states_for_stale_trace():
    svc = live_service()
    svc.submit("decode_32k")
    svc.store.add("a1:decode_32k", "dp256xtp1", 0.001,
                  job_class=JobClass.A, group="a1")
    assert svc.reprice({"dp256xtp1": 50.0}) == 0    # stale state dropped
    d = svc.submit("decode_32k")                    # cold rebuild, new trace
    assert not d.from_cache
    assert d.config_id == "dp256xtp1"


def test_rank_cached_reports_hit_miss_explicitly():
    """Satellite: from_cache must come from the lookup itself, not from
    before/after deltas of the global hit counter."""
    svc = live_service()
    ranking, from_cache = svc.rank_cached(job_class=JobClass.A)
    assert not from_cache
    again, from_cache = svc.rank_cached(job_class=JobClass.A)
    assert from_cache and again == ranking
    # perturbing the counters cannot corrupt the reported fact
    svc.cache_hits += 100
    ranked, from_cache = svc.rank_cached(job_class=JobClass.B)
    assert not from_cache


# --- the simulated spot feed -----------------------------------------------------

def base_prices():
    cat = TpuSliceCatalog(MESH_OPTIONS, TpuPriceModel("ondemand"))
    return {o.name: cat.hourly_cost(o.name) for o in MESH_OPTIONS}


def test_feed_is_deterministic_and_protocol_shaped():
    f1 = SimulatedSpotFeed(base_prices(), seed=5, change_fraction=0.5)
    f2 = SimulatedSpotFeed(base_prices(), seed=5, change_fraction=0.5)
    assert isinstance(f1, PriceFeed)
    s1 = [f1.poll(t) for t in range(20)]
    s2 = list(f2.stream(20))
    assert s1 == s2
    assert any(s1), "a 0.5 change fraction must emit deltas"
    different = SimulatedSpotFeed(base_prices(), seed=6, change_fraction=0.5)
    assert [different.poll(t) for t in range(20)] != s1


def test_feed_prices_stay_positive_and_banded():
    base = base_prices()
    feed = SimulatedSpotFeed(base, seed=1, change_fraction=1.0,
                             volatility=0.5, band=4.0)
    for batch in feed.stream(50):
        for d in batch:
            assert base[d.config_id] / 4.0 <= d.price \
                <= base[d.config_id] * 4.0


def test_feed_discount_event_lands_at_boundary():
    base = base_prices()
    feed = SimulatedSpotFeed(
        base, seed=2, change_fraction=0.0, volatility=0.0,
        events=[MarketEvent("r0", 3, 4, factor=0.5, kind="discount")],
        regions=("r0",))                    # everything in the window
    assert feed.poll(0) == () and feed.poll(1) == () and feed.poll(2) == ()
    start = {d.config_id: d.price for d in feed.poll(3)}
    assert start and all(
        p == pytest.approx(base[c] * 0.5) for c, p in start.items())
    assert feed.poll(5) == ()               # mid-window, no re-quotes needed
    end = {d.config_id: d.price for d in feed.poll(7)}
    assert end and all(
        p == pytest.approx(base[c]) for c, p in end.items())


def test_feed_eviction_spike():
    base = base_prices()
    feed = SimulatedSpotFeed(
        base, seed=2, change_fraction=0.0, volatility=0.0,
        events=[MarketEvent("r0", 1, 2, factor=3.0, kind="eviction")],
        regions=("r0",))
    spike = {d.config_id: d.price for d in feed.poll(1)}
    assert all(p == pytest.approx(base[c] * 3.0) for c, p in spike.items())


def test_feed_rejects_bad_params():
    with pytest.raises(ValueError, match="change_fraction"):
        SimulatedSpotFeed({"a": 1.0}, change_fraction=1.5)
    with pytest.raises(ValueError, match="band"):
        SimulatedSpotFeed({"a": 1.0}, band=0.5)
    with pytest.raises(ValueError, match="non-positive"):
        SimulatedSpotFeed({"a": 0.0})


# --- ticker ----------------------------------------------------------------------

def test_ticker_drives_epochs_only_on_deltas():
    svc = live_service()
    svc.submit("decode_32k")
    quiet = SimulatedSpotFeed(dict(svc.price_source.items()), seed=0,
                              change_fraction=0.0)
    ticker = PriceTicker(quiet, svc)
    epoch = svc.price_epoch
    ticker.run(10)
    assert svc.price_epoch == epoch         # quiet market: no invalidation
    assert ticker.tick_count == 10 and ticker.epochs_driven == 0
    busy = SimulatedSpotFeed(dict(svc.price_source.items()), seed=0,
                             change_fraction=1.0)
    applied = PriceTicker(busy, svc).run(3)
    assert applied > 0
    assert svc.price_epoch > epoch
    # the service's table tracks the feed's quotes exactly
    for cid in svc.catalog.ids():
        assert svc.price_source[cid] == busy.price_of(cid)


def test_ticker_requires_price_table_source():
    recs = [WorkloadRecord(arch="a1", shape="decode_32k", mesh=m,
                           step_seconds=v["decode_32k"])
            for m, v in SPEED.items()]
    svc = make_service(MESH_OPTIONS, recs, TpuPriceModel("ondemand"))
    feed = SimulatedSpotFeed(base_prices(), seed=0)
    with pytest.raises(ValueError, match="PriceTable"):
        PriceTicker(feed, svc)


# --- daemon ----------------------------------------------------------------------

def make_daemon(seed=0, change_fraction=0.3):
    svc = live_service()
    feed = SimulatedSpotFeed(dict(svc.price_source.items()), seed=seed,
                             change_fraction=change_fraction)
    return SelectionDaemon(svc, feed)


def test_daemon_stream_is_deterministic():
    jobs = ["decode_32k", "train_4k"]
    a = make_daemon(seed=4)
    b = make_daemon(seed=4)
    sa = a.run(synthetic_stream(jobs, 500, seed=4))
    sb = b.run(synthetic_stream(jobs, 500, seed=4))
    assert a.journal_dump() == b.journal_dump()
    assert (sa.decisions, sa.ticks, sa.epochs) == \
        (sb.decisions, sb.ticks, sb.epochs)
    assert sa.decisions > 0 and sa.ticks > 0
    c = make_daemon(seed=9)
    c.run(synthetic_stream(jobs, 500, seed=9))
    assert c.journal_dump() != a.journal_dump()


def test_daemon_journal_roundtrip(tmp_path):
    daemon = make_daemon(seed=1)
    decisions = []
    for ev in synthetic_stream(["decode_32k", "train_4k"], 200, seed=1):
        d = daemon.handle(ev)
        if d is not None:
            decisions.append(d)
    path = str(tmp_path / "journal.jsonl")
    daemon.save_journal(path)
    header, records = SelectionDaemon.load_journal(path)
    assert header["format"] == "repro.market.decision-journal"
    assert header["catalog"] == [o.name for o in MESH_OPTIONS]
    decided = [r for r in records if r["kind"] == "decision"]
    assert len(decided) == len(decisions) == daemon.stats.decisions
    for rec, d in zip(decided, decisions):
        assert rec["job"] == d.job_id
        assert rec["config"] == d.config_id
        assert rec["hourly_cost"] == d.hourly_cost
        assert rec["price_epoch"] == d.price_epoch
        assert rec["from_cache"] == d.from_cache
        assert rec["score"] == d.ranking[0].score
        assert tuple(rec["exclude_groups"]) == d.exclude_groups
    seqs = [r["seq"] for r in records]
    assert seqs == sorted(seqs)


def test_daemon_rejects_foreign_journal():
    with pytest.raises(ValueError, match="not a decision journal"):
        SelectionDaemon.loads_journal(json.dumps({"format": "x"}) + "\n")
    with pytest.raises(ValueError, match="version"):
        SelectionDaemon.loads_journal(json.dumps(
            {"format": "repro.market.decision-journal", "version": 9}))


def test_daemon_journals_rejections_and_keeps_serving():
    daemon = make_daemon()
    assert daemon.handle(Submission("decode_32k",
                                    exclude_groups=("a1", "a2"))) is None
    assert daemon.stats.rejected == 1
    d = daemon.handle(Submission("decode_32k"))
    assert d is not None and d.config_id == "dp16xtp16"
    kinds = [json.loads(ln)["kind"]
             for ln in daemon.journal_dump().splitlines()[1:]]
    assert kinds == ["rejected", "decision"]


def test_daemon_feed_error_typed_path_keeps_serving():
    """Satellite (ISSUE 6): a ``feed.poll`` that raises surfaces as a
    typed :class:`FeedError` — the daemon journals an additive
    ``feed-error`` record, keeps prices at the last good epoch, keeps
    serving, and the *same* tick index is retried by the next Tick."""
    from repro.market import FeedError, JournalReplayer

    daemon = make_daemon()
    inner_poll = daemon.ticker.feed.poll
    polled = []
    fail = {"remaining": 2}

    def flaky_poll(tick):
        polled.append(tick)
        if fail["remaining"] > 0:
            fail["remaining"] -= 1
            raise ConnectionError("transient market outage")
        return inner_poll(tick)

    daemon.ticker.feed.poll = flaky_poll
    daemon.handle(Submission("decode_32k"))
    epoch_before = daemon.service.price_epoch
    assert daemon.handle(Tick()) is None          # fails...
    assert daemon.handle(Tick()) is None          # ...fails again...
    assert daemon.handle(Tick()) is None          # ...then lands
    assert polled == [0, 0, 0]                    # same tick retried
    assert daemon.stats.feed_errors == 2
    assert daemon.stats.ticks == 1
    assert daemon.handle(Submission("decode_32k")) is not None
    records = [json.loads(ln)
               for ln in daemon.journal_dump().splitlines()[1:]]
    errs = [r for r in records if r["kind"] == "feed-error"]
    assert [e["failures"] for e in errs] == [1, 2]
    assert all(e["tick"] == 0 and e["price_epoch"] == epoch_before
               for e in errs)
    assert "transient market outage" in errs[0]["error"]
    audit = JournalReplayer(daemon.service.store,
                            daemon.journal_dump()).audit()
    assert audit.ok and audit.feed_errors == 2
    # a FeedError surfaced directly still names its tick
    with pytest.raises(FeedError) as e:
        raise FeedError("boom", 7)
    assert e.value.tick == 7


def test_daemon_feed_error_failures_reset_after_recovery():
    """Satellite (ISSUE 8): the daemon's consecutive-failures counter
    restarts at 1 for a fresh outage after a successful poll — a
    fail/recover/fail sequence journals ``failures`` 1,2,1,2, never
    carrying the first outage's count into the second."""
    from repro.market import JournalReplayer

    daemon = make_daemon()
    inner_poll = daemon.ticker.feed.poll
    remaining = {0: 2, 1: 2}             # two failures at ticks 0 and 1

    def flaky_poll(tick):
        if remaining.get(tick, 0) > 0:
            remaining[tick] -= 1
            raise ConnectionError(f"transient market outage at {tick}")
        return inner_poll(tick)

    daemon.ticker.feed.poll = flaky_poll
    for _ in range(6):       # fail, fail, tick 0, fail, fail, tick 1
        daemon.handle(Tick())
    assert daemon.stats.ticks == 2
    assert daemon.stats.feed_errors == 4
    records = [json.loads(ln)
               for ln in daemon.journal_dump().splitlines()[1:]]
    errs = [r for r in records if r["kind"] == "feed-error"]
    assert [e["failures"] for e in errs] == [1, 2, 1, 2]
    assert [e["tick"] for e in errs] == [0, 0, 1, 1]
    audit = JournalReplayer(daemon.service.store,
                            daemon.journal_dump()).audit()
    assert audit.ok and audit.feed_errors == 4


def test_daemon_propagates_misconfiguration():
    """Only NothingRankableError is a routine rejection; a genuine
    misconfiguration (here: an unknown ranking backend) must propagate
    instead of being journaled as 'rejected'."""
    daemon = make_daemon()
    daemon.service.backend = "bogus"
    with pytest.raises(ValueError, match="unknown backend"):
        daemon.handle(Submission("decode_32k"))
    assert daemon.stats.rejected == 0
    assert len(daemon.journal_dump().splitlines()) == 1     # header only


def test_daemon_amortizes_submissions_through_cache():
    daemon = make_daemon(change_fraction=0.05)
    stream = [Submission("decode_32k")] * 50 + [Tick()] + \
        [Submission("decode_32k")] * 50
    daemon.run(stream)
    svc = daemon.service
    # at most one cold miss + (maybe) one incremental refresh — never 100
    assert svc.cache_misses <= 2
    assert svc.cache_hits >= 98


# --- migration advisor -----------------------------------------------------------

def decision_for(svc, shape="decode_32k"):
    return svc.submit(shape)


def test_migrate_stays_when_already_best():
    svc = live_service()
    d = decision_for(svc)
    advice = should_migrate(d, d.ranking, switch_cost_hours=1.0)
    assert not advice.migrate and advice.saving_per_hour == 0.0


def test_migrate_when_savings_beat_switch_cost():
    svc = live_service()
    before = decision_for(svc)              # v5e wins at on-demand prices
    svc.reprice({"v5p-dp16xtp16": 250.0})   # v5p now cheap AND fast
    after = decision_for(svc)
    assert after.config_id == "v5p-dp16xtp16"
    go = should_migrate(before, after.ranking, switch_cost_hours=0.5,
                        horizon_hours=24.0)
    assert go.migrate and go.net_saving_usd > 0
    # the same gap under a tiny horizon cannot amortize the switch
    stay = should_migrate(before, after.ranking, switch_cost_hours=10.0,
                          horizon_hours=0.1)
    assert not stay.migrate


def test_migrate_hysteresis_damps_marginal_wins():
    svc = live_service()
    before = decision_for(svc)
    svc.reprice({"v5p-dp16xtp16": 300.0})   # marginally better than v5e
    after = decision_for(svc)
    assert after.config_id == "v5p-dp16xtp16"
    loose = should_migrate(before, after.ranking, switch_cost_hours=0.5,
                           horizon_hours=1.0, hysteresis=1.0)
    tight = should_migrate(before, after.ranking, switch_cost_hours=0.5,
                           horizon_hours=1.0, hysteresis=100.0)
    assert loose.saving_per_hour > 0
    assert not tight.migrate                # margin demands damp the move
    with pytest.raises(ValueError, match="hysteresis"):
        should_migrate(before, after.ranking, 0.5, hysteresis=0.0)


def test_migrate_quotes_current_rate_not_stamped():
    """The advisor's dollar figures must track the market: callers pass
    the fleet's re-priced $/h, not the rate stamped on a stale Decision."""
    svc = live_service()
    before = decision_for(svc)                  # dp16xtp16 at on-demand
    stamped = before.hourly_cost
    svc.reprice({"dp16xtp16": stamped * 2})     # the fleet's own quote moves
    after = decision_for(svc)
    assert after.config_id == "v5p-dp16xtp16"
    fresh = svc.price_source["dp16xtp16"]
    advice = should_migrate(before, after.ranking, switch_cost_hours=1.0,
                            current_hourly_cost=fresh)
    assert advice.switch_cost_usd == pytest.approx(fresh)
    stale = should_migrate(before, after.ranking, switch_cost_hours=1.0)
    assert stale.switch_cost_usd == pytest.approx(stamped)
    with pytest.raises(ValueError, match="non-positive current"):
        should_migrate(before, after.ranking, 1.0, current_hourly_cost=0.0)


def test_plan_decode_placement_restamps_repriced_current_fleet():
    """When the standing fleet's own price moves and the advisor says
    stay, the returned Decision quotes today's rate, not the stale one."""
    from repro.serve.engine import plan_decode_placement
    svc = live_service()
    current = plan_decode_placement(svc)                # dp16xtp16
    svc.reprice({"dp16xtp16": 1100.0})                  # own quote spikes
    kept = plan_decode_placement(svc, current=current,
                                 switch_cost_hours=50.0, horizon_hours=0.1)
    assert kept.config_id == current.config_id          # switch unamortized
    assert kept.hourly_cost == 1100.0
    assert kept.hourly_cost != current.hourly_cost


def test_plan_decode_placement_hysteresis():
    from repro.serve.engine import plan_decode_placement
    svc = live_service()
    current = plan_decode_placement(svc)
    assert current.config_id == "dp16xtp16"
    # small wiggle: the winner flips but not by enough for a 2h switch
    svc.reprice({"v5p-dp16xtp16": 300.0})
    kept = plan_decode_placement(svc, current=current,
                                 switch_cost_hours=2.0, horizon_hours=1.0)
    assert kept.config_id == current.config_id
    assert kept.price_epoch == svc.price_epoch      # re-stamped, not stale
    assert kept.hourly_cost == svc.price_source[kept.config_id]
    # a crash makes the move worth it
    svc.reprice({"v5p-dp16xtp16": 120.0})
    moved = plan_decode_placement(svc, current=current,
                                  switch_cost_hours=2.0,
                                  horizon_hours=24.0)
    assert moved.config_id == "v5p-dp16xtp16"


# --- ProfilingStore growth guarantee ---------------------------------------------

def test_store_growth_is_amortized_doubling():
    """10k row inserts and 10k column inserts each cost O(log n)
    backing-array reallocations, not O(n)."""
    import math
    n = 10_000
    rows = ProfilingStore(config_ids=["c0"])
    for i in range(n):
        rows.add(f"j{i}", "c0", 1.0)
    assert len(rows.job_ids) == n
    assert rows.realloc_count <= 2 * math.ceil(math.log2(n)) + 2

    cols = ProfilingStore()
    for i in range(n):
        cols.add("j0", f"c{i}", 1.0)
    assert len(cols.config_ids) == n
    assert cols.realloc_count <= 2 * math.ceil(math.log2(n)) + 2
