"""Daemon soak: the repo's first end-to-end stress fixture (ISSUE 5).

Drives :class:`~repro.market.SelectionDaemon` over a *long* recorded
price history — 220 ticks of a simulated spot market with a discount
window and an eviction spike, captured through
:func:`~repro.market.record_feed` so the whole run is a pure function
of the fixture bytes — with submissions interleaved across four-plus
distinct (job class, exclusion) selections, i.e. a real fleet of live
rankings.  Five legs: the numpy backend (bit-identical audit), the
batched jax fleet backend (tolerance audit + the one-dispatch-per-tick
accounting), the batched backend serving every decision via device-side
top-k (DESIGN.md §10), and the device-sharded fleet backend
(DESIGN.md §13) with and without top-k serving — the same
one-collective-dispatch-per-tick accounting over shard_map.

Beyond "the audit passes", the soak pins the *resource* story:

  * ``JournalReplayer.audit()`` reports zero out-of-envelope drift
    (``mismatches == ()``; for numpy, zero drift records at all);
  * ``ProfilingStore.realloc_count`` stays amortized-doubling-bounded;
  * the service's reprice/cache counters stay inside pinned bounds —
    every selection cold-builds exactly once, everything else is a
    cache hit or an incremental refresh, and the batched backend spends
    exactly one kernel dispatch per price epoch regardless of fleet
    size.
"""
import math

import pytest

from repro.core.trace import JobClass
from repro.market import (JournalReplayer, MarketEvent, RecordedPriceFeed,
                          SelectionDaemon, SimulatedSpotFeed, Submission,
                          Tick, make_market, record_feed)
from repro.selector import (FLEET_BACKENDS, IdentityCatalog, PriceTable,
                            ProfilingStore, SelectionService,
                            backend_available)

N_TICKS = 220
N_JOBS = 12
N_CFGS = 24


def _soak_store():
    ids = [f"c{i}" for i in range(N_CFGS)]
    store = ProfilingStore(config_ids=ids)
    for j in range(N_JOBS):
        klass = JobClass.A if j % 2 else JobClass.B
        for i, c in enumerate(ids):
            # deterministic, positive, class-correlated runtimes
            store.add(f"j{j}", c,
                      0.1 + ((j * 13 + i * 7) % 29) / 8.0
                      + (0.5 if klass is JobClass.A and i % 3 == 0
                         else 0.0),
                      job_class=klass, group=f"g{j % 4}")
    return store, ids


#: submissions cycle through SIX distinct (class, exclusion) selections:
#: two per-class defaults (each job's own group is auto-excluded, and
#: jobs of one class share groups by construction below — j1/j3 are
#: both class A but different groups) plus explicit exclusion variants.
SOAK_SELECTIONS = [
    ("j1", None),              # class A, auto-exclude g1
    ("j2", None),              # class B, auto-exclude g2
    ("j3", None),              # class A, auto-exclude g3
    ("j4", None),              # class B, auto-exclude g0
    ("j1", ("g2", "g3")),      # class A, explicit exclusions
    ("j2", ("g1",)),           # class B, explicit exclusion
]


def _soak_stream():
    """220 ticks with submissions woven between them (~2 per 3 ticks),
    cycling the six selections."""
    s = 0
    for t in range(N_TICKS):
        yield Tick()
        if t % 3 != 2:
            job, excl = SOAK_SELECTIONS[s % len(SOAK_SELECTIONS)]
            s += 1
            yield Submission(job, exclude_groups=excl)


def _recorded_market(ids):
    """A 220-tick recorded price history with mid-stream market events,
    round-tripped through the recorded-feed CSV so the soak replays a
    fixture, not a live simulation."""
    base = {c: 1.0 + (i * 11 % 17) for i, c in enumerate(ids)}
    sim = SimulatedSpotFeed(
        base, seed=42, change_fraction=0.5, volatility=0.08,
        events=[MarketEvent("us-central1", 40, 30, 0.5, "discount"),
                MarketEvent("europe-west3", 120, 20, 3.0, "eviction")])
    text = record_feed(sim, N_TICKS)
    feed = RecordedPriceFeed.loads(text)
    assert feed.ticks == N_TICKS
    return feed, base


def _assert_soak_invariants(svc, store, daemon, stats, backend,
                            serve_top_k=None):
    """The shared soak bar: audit clean with zero out-of-envelope
    drift, store growth amortized-doubling-bounded, every selection
    cold-builds exactly once, and the fleet backends spend exactly one
    kernel dispatch per price epoch — the same invariants for the calm
    recorded market and the hostile turbulence presets."""
    # -- the audit: tolerance mode for the batched fleet, bit-identical
    #    for numpy; zero out-of-envelope drift either way
    replayer = JournalReplayer(store, daemon.journal_dump())
    assert replayer.backend == backend
    audit = replayer.audit()
    assert audit.ok, audit.mismatches[:5]
    assert audit.decisions == stats.decisions
    assert audit.ticks == stats.epochs
    if backend == "numpy":
        assert audit.drift == ()          # exact backend: no drift at all
        assert audit.contract.bit_identical
    else:
        assert not audit.contract.bit_identical
    if serve_top_k:
        served = replayer.decisions()
        assert served and all(d.served_via == "top_k" for d in served)

    # -- pinned resource bounds: the soak is a stress test, not just a
    #    correctness test
    # store growth stayed amortized-doubling (same idiom as the growth
    # test in test_market.py, both axes)
    assert store.realloc_count <= \
        2 * (math.ceil(math.log2(N_JOBS)) + math.ceil(math.log2(N_CFGS))) + 4
    # every distinct selection cold-builds exactly once; every other
    # submission is a cache hit or a lazy materialization of an
    # incrementally-refreshed state
    assert svc.cache_misses == len(SOAK_SELECTIONS)
    assert svc.cache_hits == stats.submissions - len(SOAK_SELECTIONS)
    # every epoch refreshed every live state incrementally — never a
    # drop-and-rebuild (the recorded feed applies all quotes through
    # reprice, so no state can ever miss an out-of-band apply)
    assert svc.reprice_refreshes >= stats.epochs    # fleet ramps up to 6
    if backend in FLEET_BACKENDS:
        # THE batching claim: one kernel dispatch per price epoch,
        # regardless of how many live rankings the tick refreshes (the
        # very first epoch predates the fleet — the stream opens with a
        # tick before any submission has built a state — so it spends
        # zero dispatches); for jax_sharded that dispatch is the single
        # collective shard_map step across every device
        assert stats.epochs - 1 <= svc.reprice_dispatches <= stats.epochs
        assert svc._batched.dispatches == svc.reprice_dispatches
    else:
        # per-state backends pay one update per live state per epoch
        assert svc.reprice_dispatches >= stats.epochs
    return audit


@pytest.mark.parametrize("backend,serve_top_k", [
    ("numpy", None),
    ("jax_batched", None),
    ("jax_batched", 3),
    ("jax_sharded", None),
    ("jax_sharded", 3),
])
def test_daemon_soak_long_recorded_market(backend, serve_top_k):
    if not backend_available(backend):
        pytest.skip("jax not installed")
    store, ids = _soak_store()
    feed, base = _recorded_market(ids)
    svc = SelectionService(IdentityCatalog(ids), store, PriceTable(base),
                           backend=backend, serve_top_k=serve_top_k)
    daemon = SelectionDaemon(svc, feed)
    stats = daemon.run(_soak_stream())

    # -- the stream actually stressed what it claims to stress
    assert stats.ticks == N_TICKS
    assert stats.epochs >= 180            # near-every tick moved prices
    assert stats.rejected == 0
    assert stats.decisions == stats.submissions >= 140
    if backend in FLEET_BACKENDS:
        assert svc._batched is not None
        assert svc._batched.n_active == len(SOAK_SELECTIONS)

    _assert_soak_invariants(svc, store, daemon, stats, backend,
                            serve_top_k)


@pytest.mark.parametrize("preset_name", ["eviction_storm", "flash_crash"])
@pytest.mark.parametrize("backend", ["numpy", "jax_batched"])
def test_daemon_soak_hostile_turbulent_market(preset_name, backend):
    """ISSUE 10 satellite: the 220-tick soak under the hostile
    turbulence presets — coordinated eviction storms and flash-crash/
    overshoot regime flips are exactly the markets that punish a
    selector amortizing rankings between ticks, and the soak bar
    (clean audit, pinned realloc/cache/dispatch bounds) must hold there
    too, not just under the calm recorded market."""
    if not backend_available(backend):
        pytest.skip("jax not installed")
    store, ids = _soak_store()
    base = {c: 1.0 + (i * 11 % 17) for i, c in enumerate(ids)}
    market = make_market(preset_name, base, seed=42, ticks=N_TICKS)
    feed = RecordedPriceFeed.loads(record_feed(market.raw, N_TICKS))
    assert feed.ticks == N_TICKS
    svc = SelectionService(IdentityCatalog(ids), store, PriceTable(base),
                           backend=backend)
    daemon = SelectionDaemon(svc, feed)
    stats = daemon.run(_soak_stream())

    assert stats.ticks == N_TICKS
    assert stats.epochs >= 180            # hostile != quiet: prices move
    assert stats.rejected == 0
    assert stats.decisions == stats.submissions >= 140
    _assert_soak_invariants(svc, store, daemon, stats, backend)


def test_soak_journal_is_deterministic():
    """The soak is a fixture: same recorded market + same stream =>
    byte-identical journal (the reproducibility bar every daemon
    benchmark already enforces, now over a 220-tick recorded
    history)."""
    store, ids = _soak_store()
    feed, base = _recorded_market(ids)
    svc = SelectionService(IdentityCatalog(ids), store, PriceTable(base),
                           backend="numpy")
    daemon = SelectionDaemon(svc, feed)
    daemon.run(_soak_stream())
    store2, ids2 = _soak_store()
    feed2, base2 = _recorded_market(ids2)
    svc2 = SelectionService(IdentityCatalog(ids2), store2,
                            PriceTable(base2), backend="numpy")
    daemon2 = SelectionDaemon(svc2, feed2)
    daemon2.run(_soak_stream())
    assert daemon.journal_dump() == daemon2.journal_dump()
