"""Optimizer / checkpoint / compression / fault-tolerance substrate tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hyputil import given, settings, st

import repro.configs as C
from repro.configs import shapes as S
from repro.models import build_model
from repro.models.types import ShapeSpec
from repro.train import optimizer as opt_lib
from repro.train.checkpoint import Checkpointer
from repro.train.compression import ErrorFeedback, quantise_int8, dequantise
from repro.train.train_loop import (StragglerWatchdog, TrainConfig,
                                    make_train_step)

SMOKE = ShapeSpec("smoke", 32, 2, "train")


# --- optimizer -----------------------------------------------------------------

def _numpy_adamw(p, g, m, v, t, lr, b1=0.9, b2=0.95, eps=1e-8, wd=0.1):
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * g * g
    mh = m / (1 - b1 ** t)
    vh = v / (1 - b2 ** t)
    p = p - lr * (mh / (np.sqrt(vh) + eps) + wd * p)
    return p, m, v


def test_adamw_matches_numpy_reference():
    sched = lambda step: jnp.float32(1e-2)
    opt = opt_lib.AdamW(schedule=sched, max_grad_norm=1e9)
    params = {"w": jnp.array([1.0, -2.0, 3.0]), "b": jnp.array([[0.5, 0.1]])}
    state = opt.init(params)
    np_p = {k: np.asarray(v) for k, v in params.items()}
    np_m = {k: np.zeros_like(v) for k, v in np_p.items()}
    np_v = {k: np.zeros_like(v) for k, v in np_p.items()}
    key = jax.random.PRNGKey(0)
    for t in range(1, 5):
        key, sub = jax.random.split(key)
        grads = {k: jax.random.normal(jax.random.fold_in(sub, i), v.shape)
                 for i, (k, v) in enumerate(params.items())}
        params, state, _ = opt.update(grads, state, params)
        for k in np_p:
            np_p[k], np_m[k], np_v[k] = _numpy_adamw(
                np_p[k], np.asarray(grads[k]), np_m[k], np_v[k], t, 1e-2)
    for k in np_p:
        np.testing.assert_allclose(np.asarray(params[k]), np_p[k],
                                   rtol=1e-5, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.floats(-100, 100, allow_nan=False), min_size=1,
                max_size=8),
       st.floats(0.1, 10.0))
def test_clip_by_global_norm_property(vals, max_norm):
    tree = {"x": jnp.array(vals, jnp.float32)}
    clipped, norm = opt_lib.clip_by_global_norm(tree, max_norm)
    out_norm = float(opt_lib.global_norm(clipped))
    assert out_norm <= max_norm * 1.001 + 1e-6
    if float(norm) <= max_norm:   # no-op when under the threshold
        np.testing.assert_allclose(np.asarray(clipped["x"]),
                                   np.asarray(tree["x"]), rtol=1e-6)


def test_warmup_cosine_schedule():
    s = opt_lib.WarmupCosine(peak_lr=1.0, warmup_steps=10, total_steps=100)
    assert float(s(jnp.int32(0))) == 0.0
    assert float(s(jnp.int32(10))) == pytest.approx(1.0, abs=1e-3)
    assert float(s(jnp.int32(100))) == pytest.approx(0.1, abs=1e-3)
    assert float(s(jnp.int32(55))) < 1.0


def test_adafactor_reduces_loss():
    cfg = C.reduced(C.get("deepseek-7b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = S.make_batch(cfg, SMOKE, jax.random.PRNGKey(1))
    tcfg = TrainConfig(optimizer="adafactor", peak_lr=1e-2, warmup_steps=1,
                      total_steps=100)
    step, opt = make_train_step(model, tcfg)
    step = jax.jit(step)
    opt_state = opt.init(params)
    losses = []
    for _ in range(4):
        params, opt_state, metrics = step(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0]


def test_adafactor_state_is_factored():
    opt = opt_lib.make_optimizer("adafactor")
    params = {"w": jnp.zeros((8, 16)), "b": jnp.zeros((16,))}
    state = opt.init(params)
    sizes = sum(int(np.prod(l.shape))
                for l in jax.tree_util.tree_leaves(state["f"]))
    assert sizes == 8 + 16 + 16        # vr + vc for w, v for b


# --- microbatch accumulation -------------------------------------------------------

def test_grad_accumulation_matches_full_batch():
    cfg = C.reduced(C.get("qwen3-1.7b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = S.make_batch(cfg, ShapeSpec("s", 16, 4, "train"),
                         jax.random.PRNGKey(1))
    t1 = TrainConfig(microbatches=1, peak_lr=1e-3)
    t2 = TrainConfig(microbatches=2, peak_lr=1e-3)
    s1, o1 = make_train_step(model, t1)
    s2, o2 = make_train_step(model, t2)
    p1, _, m1 = jax.jit(s1)(params, o1.init(params), batch)
    p2, _, m2 = jax.jit(s2)(params, o2.init(params), batch)
    # parameters after one step agree (loss is mean-per-token so microbatch
    # averaging matches; allow small numerical slack)
    flat1 = jax.tree_util.tree_leaves(p1)
    flat2 = jax.tree_util.tree_leaves(p2)
    for a, b in zip(flat1, flat2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-4, rtol=5e-3)


# --- checkpointing ------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    params = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
              "nested": {"b": jnp.ones((4,), jnp.bfloat16)}}
    opt_state = {"m": jax.tree_util.tree_map(jnp.zeros_like, params),
                 "count": jnp.int32(7)}
    ck.save(3, params, opt_state, block=True)
    tree, step = ck.restore({"params": params, "opt_state": opt_state})
    assert step == 3
    np.testing.assert_array_equal(np.asarray(tree["params"]["a"]),
                                  np.asarray(params["a"]))
    assert int(tree["opt_state"]["count"]) == 7


def test_checkpoint_keep_k_and_latest(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    params = {"w": jnp.zeros((2,))}
    for step in (1, 2, 3, 4):
        ck.save(step, params, block=True)
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert dirs == ["step_00000003", "step_00000004"]
    assert ck.latest_step() == 4


def test_checkpoint_atomicity_no_partial_dirs(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=3)
    params = {"w": jnp.ones((128, 128))}
    ck.save(1, params, block=True)
    leftovers = [d for d in os.listdir(tmp_path) if ".tmp" in d]
    assert not leftovers


def test_checkpoint_elastic_restore_roundtrip(tmp_path):
    """Restore works regardless of the mesh that saved (arrays are stored
    unsharded) — the elastic-restart path."""
    ck = Checkpointer(str(tmp_path))
    cfg = C.reduced(C.get("qwen3-1.7b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ck.save(10, params, block=True)
    restored, step = ck.restore({"params": params})
    for a, b in zip(jax.tree_util.tree_leaves(restored["params"]),
                    jax.tree_util.tree_leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --- gradient compression ---------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.lists(st.floats(-1e3, 1e3, allow_nan=False, allow_infinity=False),
                min_size=1, max_size=32))
def test_int8_quantisation_error_bound(vals):
    x = jnp.array(vals, jnp.float32)
    q, scale = quantise_int8(x)
    err = np.abs(np.asarray(dequantise(q, scale) - x))
    assert err.max() <= float(scale) * 0.5 + 1e-9


def test_error_feedback_preserves_gradient_sum():
    """Residual carries what quantisation dropped: across steps the sum of
    applied (dequantised) gradients tracks the sum of true gradients."""
    ef = ErrorFeedback()
    key = jax.random.PRNGKey(0)
    grads_template = {"w": jnp.zeros((64,))}
    residual = ef.init(grads_template)
    applied_sum = np.zeros((64,))
    true_sum = np.zeros((64,))
    for i in range(20):
        key, sub = jax.random.split(key)
        g = {"w": jax.random.normal(sub, (64,)) * (10.0 ** (i % 3))}
        deq, residual = ef.compress(g, residual)
        applied_sum += np.asarray(deq["w"], np.float32)
        true_sum += np.asarray(g["w"], np.float32)
    # |sum error| is bounded by the final residual, not growing with steps
    final_res = np.abs(np.asarray(residual["w"]))
    np.testing.assert_allclose(applied_sum, true_sum, atol=final_res.max()
                               + 1e-4)


def test_compressed_training_still_converges():
    cfg = C.reduced(C.get("deepseek-7b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = S.make_batch(cfg, SMOKE, jax.random.PRNGKey(1))
    ef = ErrorFeedback()
    residual = [None]

    def compress(grads):
        if residual[0] is None:
            residual[0] = ef.init(grads)
        deq, residual[0] = ef.compress(grads, residual[0])
        return deq

    tcfg = TrainConfig(peak_lr=5e-3, warmup_steps=1)
    step, opt = make_train_step(model, tcfg, compress_fn=compress)
    opt_state = opt.init(params)
    losses = []
    for _ in range(5):
        params, opt_state, m = step(params, opt_state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


# --- straggler watchdog ----------------------------------------------------------

def test_straggler_watchdog_flags_outliers():
    wd = StragglerWatchdog(factor=3.0)
    for i in range(10):
        assert not wd.observe(i, 0.1)
    assert wd.observe(10, 1.0)        # 10x median -> straggler event
    assert wd.events and wd.events[0][0] == 10
    assert not wd.observe(11, 0.11)
