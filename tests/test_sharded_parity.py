"""Differential sharded-parity suite (ISSUE 8).

The multi-device fleet kernel
(:class:`~repro.selector.ShardedBatchedRankState`, DESIGN.md §13) must
be indistinguishable — within the jax ``ScoreContract`` — from both the
single-device :class:`~repro.selector.BatchedRankState` it shards and
the cold numpy float64 rank, per tick, at device counts {1, 2, 8}
(counts above the process's device pool skip; CI's jax_sharded leg runs
with ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so all
three execute there).

Also home to the k-clamp boundary audit (ISSUE 8 satellite: k in
{C-1, C, C+1, 10·C} parity across every backend's device top-k,
boundary ties included), the sharded service/daemon integration tests,
and the bundled-fixture tolerance-mode audit for a sharded daemon.
"""
import numpy as np
import pytest

from repro.core.trace import JobClass
from repro.selector import (BatchedRankState, JaxRankState,
                            NothingRankableError, RankState,
                            ShardedBatchedRankState, backend_available,
                            rank_dense, score_contract)
from test_backend_parity import assert_within_contract
from test_batched_parity import (_fleet_service, _fleet_universe,
                                 _universe_with_ties)

try:        # the property half needs hypothesis; everything else runs
            # without it
    import hypothesis
    from hypothesis import given, settings, strategies as st
    from test_batched_parity import fleet_streams
    from test_rank_properties import event_markets, _event_feed
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

needs_jax = pytest.mark.skipif(not backend_available("jax_sharded"),
                               reason="jax not installed")

CONTRACT = score_contract("jax_sharded")

if backend_available("jax_sharded"):
    import jax
    N_DEVICES = jax.device_count()
else:  # pragma: no cover
    N_DEVICES = 0

#: the ISSUE 8 device-count matrix; counts above the process pool skip
DEVICE_COUNTS = (1, 2, 8)


def _devices_or_skip(n_dev):
    if n_dev > N_DEVICES:
        pytest.skip(f"needs {n_dev} devices, have {N_DEVICES} "
                    f"(set XLA_FLAGS=--xla_force_host_platform_"
                    f"device_count=8)")
    return n_dev


def _assert_sharded_parity(sharded, batched, members, hours, mask, live,
                           ids):
    """Every member: jax_sharded == jax_batched == numpy cold, under
    the contract; plus the sharded top-k head is element-wise identical
    to the sharded ranking head (the merge-exactness invariant)."""
    for key, rows in members.items():
        cold = rank_dense(hours[rows], mask[rows], live, ids)
        rs = sharded.ranking(key)
        assert_within_contract(rs, cold, CONTRACT)
        assert_within_contract(rs, batched.ranking(key), CONTRACT)
        k = min(3, len(ids))
        assert sharded.top_k(key, k) == rs[:k]


# --- deterministic differential sweeps ---------------------------------------------

@needs_jax
@pytest.mark.parametrize("n_dev", DEVICE_COUNTS)
@pytest.mark.parametrize("seed", range(3))
def test_sharded_fleet_within_contract_seeded(seed, n_dev):
    """Seeded fleets at every device count: after each tick, each
    sharded member matches the single-device batched state and the cold
    numpy float64 rank under the contract — one collective dispatch per
    tick.  Config counts are chosen non-divisible by the device count,
    so the pad-column tail is live in every multi-device run."""
    _devices_or_skip(n_dev)
    rng, hours, mask, prices, ids, members = _fleet_universe(
        seed, n_jobs=6 + seed, n_cfgs=13 + 4 * seed,
        partial=seed % 2 == 0)
    sharded = ShardedBatchedRankState(hours, mask, prices.copy(), ids,
                                      devices=n_dev)
    batched = BatchedRankState(hours, mask, prices.copy(), ids)
    for key, rows in members.items():
        sharded.add_state(key, rows=rows)
        batched.add_state(key, rows=rows)
    live = prices.copy()
    for _ in range(5):
        k = int(rng.integers(1, len(ids)))
        cols = rng.choice(len(ids), k, replace=False)
        deltas = {ids[c]: float(live[c] * rng.uniform(0.5, 2.0))
                  for c in cols}
        assert sharded.reprice(deltas) == batched.reprice(deltas)
        for c, p in deltas.items():
            live[int(c[1:])] = p
        _assert_sharded_parity(sharded, batched, members, hours, mask,
                               live, ids)
    # the accounting the bench gates on: ONE collective dispatch per
    # tick, independent of member and device count
    assert sharded.dispatches == sharded.reprices == 5
    assert sharded.n_active == len(members)
    assert sharded.n_devices == n_dev


@needs_jax
def test_sharded_event_market_within_contract_deterministic():
    """Discount/eviction boundary re-quote bursts through the sharded
    kernel stay within contract of cold float64 ranks for every member,
    at the full device pool."""
    from repro.market import MarketEvent, SimulatedSpotFeed
    rng, hours, mask, prices, ids, members = _fleet_universe(
        7, n_jobs=8, n_cfgs=11, partial=False)
    base = {c: float(p) for c, p in zip(ids, prices)}
    feed = SimulatedSpotFeed(
        base, seed=5, change_fraction=0.3, volatility=0.15,
        events=[MarketEvent("us-central1", 2, 4, 0.25, "discount"),
                MarketEvent("europe-west3", 5, 3, 4.0, "eviction")])
    sharded = ShardedBatchedRankState(hours, mask, prices.copy(), ids)
    batched = BatchedRankState(hours, mask, prices.copy(), ids)
    for key, rows in members.items():
        sharded.add_state(key, rows=rows)
        batched.add_state(key, rows=rows)
    live = prices.copy()
    for t in range(10):
        batch = feed.poll(t)
        if not batch:
            continue
        deltas = {d.config_id: d.price for d in batch}
        sharded.reprice(deltas)
        batched.reprice(deltas)
        for d in batch:
            live[ids.index(d.config_id)] = d.price
        _assert_sharded_parity(sharded, batched, members, hours, mask,
                               live, ids)


@needs_jax
@pytest.mark.parametrize("n_dev", DEVICE_COUNTS)
def test_sharded_states_added_retired_and_slot_reuse(n_dev):
    """Members added mid-stream sync with every prior tick; retired
    members raise the typed rankable-nothing error; a retire-all /
    re-add cycle reuses the zero-masked slots without growing capacity
    (``realloc_count`` pinned), and the revived member's scores
    bit-match a cold build."""
    _devices_or_skip(n_dev)
    rng, hours, mask, prices, ids, members = _fleet_universe(
        11, n_jobs=12, n_cfgs=17, n_members=4)
    sharded = ShardedBatchedRankState(hours, mask, prices.copy(), ids,
                                      devices=n_dev, capacity=4)
    live = prices.copy()

    def tick():
        k = int(rng.integers(1, len(ids)))
        cols = rng.choice(len(ids), k, replace=False)
        deltas = {ids[c]: float(live[c] * rng.uniform(0.5, 2.0))
                  for c in cols}
        sharded.reprice(deltas)
        for c, p in deltas.items():
            live[int(c[1:])] = p

    sharded.add_state("all", rows=members["all"])
    tick()
    sharded.add_state("m0", rows=members["m0"])     # post-tick add
    tick()
    for key in ("all", "m0"):
        cold = rank_dense(hours[members[key]], mask[members[key]], live,
                          ids)
        assert_within_contract(sharded.ranking(key), cold, CONTRACT)
    # retire-all / re-add: slots reused, capacity untouched
    assert sharded.realloc_count == 0
    for key in ("all", "m0"):
        sharded.retire_state(key)
    assert sharded.n_active == 0
    with pytest.raises(NothingRankableError, match="retired"):
        sharded.ranking("m0")
    with pytest.raises(NothingRankableError, match="retired"):
        sharded.top_k("m0", 1)
    with pytest.raises(ValueError, match="unknown member"):
        sharded.ranking("never-registered")
    for key in ("all", "m0"):
        sharded.add_state(key, rows=members[key])
    assert sharded.realloc_count == 0               # reuse, not growth
    # the revived member bit-matches a cold build at the live prices
    cold_state = ShardedBatchedRankState(hours, mask, live.copy(), ids,
                                         devices=n_dev)
    cold_state.add_state("m0", rows=members["m0"])
    assert np.array_equal(sharded.scores("m0"), cold_state.scores("m0"))
    # genuinely new concurrent members DO grow capacity (4 -> 8)
    for i in range(5):
        sharded.add_state(f"late{i}", rows=[int(r) for r in
                                            rng.choice(12, 3,
                                                       replace=False)])
    assert sharded.realloc_count == 1
    tick()
    for key in ("all", "m0"):
        cold = rank_dense(hours[members[key]], mask[members[key]], live,
                          ids)
        assert_within_contract(sharded.ranking(key), cold, CONTRACT)


@needs_jax
def test_sharded_validates_members_deltas_and_devices():
    rng, hours, mask, prices, ids, _ = _fleet_universe(3, n_jobs=4,
                                                       n_cfgs=6)
    s = ShardedBatchedRankState(hours, mask, prices, ids,
                                job_ids=[f"j{i}" for i in range(4)])
    s.add_state("a", rows=[0, 1])
    with pytest.raises(ValueError, match="duplicate member"):
        s.add_state("a", rows=[2])
    with pytest.raises(ValueError, match="exactly one of"):
        s.add_state("b", rows=[0], jobs=["j0"])
    with pytest.raises(ValueError, match="unknown job id"):
        s.add_state("b", jobs=["ghost"])
    with pytest.raises(ValueError, match="out of range"):
        s.add_state("b", rows=[99])
    with pytest.raises(ValueError, match="unknown member"):
        s.retire_state("ghost")
    with pytest.raises(ValueError, match="unknown config id"):
        s.reprice({"ghost": 1.0})
    with pytest.raises(ValueError, match="non-positive"):
        s.reprice({ids[0]: -1.0})
    assert s.reprice({}) == 0
    with pytest.raises(ValueError, match="devices"):
        ShardedBatchedRankState(hours, mask, prices, ids, devices=0)
    with pytest.raises(ValueError, match="devices"):
        ShardedBatchedRankState(hours, mask, prices, ids,
                                devices=N_DEVICES + 1)


# --- the k-clamp boundary audit (ISSUE 8 satellite) --------------------------------

def _k_boundary_cases(C):
    return (C - 1, C, C + 1, 10 * C)


@pytest.mark.parametrize("n_cfgs", [12, 13])
def test_k_boundary_parity_across_all_backends(n_cfgs):
    """k in {C-1, C, C+1, 10·C} — every backend's device/host top-k is
    clamped *before* any jitted kernel and serves exactly the head of
    its own materialized ranking, boundary ties included (the tie
    universe clones its last three profiled columns).  Cross-backend,
    the heads agree under the jax contract."""
    hours, mask, prices, ids = _universe_with_ties(n_cfgs=n_cfgs)
    C = len(ids)
    states = {"numpy": RankState(hours, mask, prices, ids)}
    if backend_available("jax"):
        states["jax"] = JaxRankState(hours, mask, prices, ids)
    heads = {}
    for k in _k_boundary_cases(C):
        for name, state in states.items():
            head = state.top_k(k)
            assert head == state.ranking()[:min(k, C)], (name, k)
            heads[(name, k)] = head
    if backend_available("jax_batched"):
        b = BatchedRankState(hours, mask, prices, ids)
        b.add_state("all", rows=list(range(hours.shape[0])))
        for k in _k_boundary_cases(C):
            head = b.top_k("all", k)
            assert head == b.ranking("all")[:min(k, C)], ("batched", k)
            heads[("jax_batched", k)] = head
    if backend_available("jax_pallas"):
        from repro.selector import PallasBatchedRankState
        p = PallasBatchedRankState(hours, mask, prices, ids)
        p.add_state("all", rows=list(range(hours.shape[0])))
        for k in _k_boundary_cases(C):
            head = p.top_k("all", k)
            assert head == p.ranking("all")[:min(k, C)], ("pallas", k)
            heads[("jax_pallas", k)] = head
    if backend_available("jax_sharded"):
        for n_dev in [n for n in DEVICE_COUNTS if n <= N_DEVICES]:
            s = ShardedBatchedRankState(hours, mask, prices, ids,
                                        devices=n_dev)
            s.add_state("all", rows=list(range(hours.shape[0])))
            for k in _k_boundary_cases(C):
                head = s.top_k("all", k)
                assert head == s.ranking("all")[:min(k, C)], \
                    ("sharded", n_dev, k)
                heads[(f"jax_sharded{n_dev}", k)] = head
    # cross-backend: every head within contract of the numpy reference,
    # and the cloned-column ties resolve in catalog order everywhere
    tol = score_contract("jax")
    clones = [ids[C - 3], ids[C - 2], ids[C - 1]]
    for (name, k), head in heads.items():
        ref = states["numpy"].ranking()
        assert_within_contract(head, ref, tol)
        got = [r.config_id for r in head if r.config_id in clones]
        assert got == clones[:len(got)], (name, k, got)


@needs_jax
@pytest.mark.parametrize("n_dev", DEVICE_COUNTS)
def test_sharded_top_k_boundary_after_ticks(n_dev):
    """The merge-exactness invariant survives repricing: after ticks
    that move the row minima, every boundary k still serves exactly the
    ranking head at every device count."""
    _devices_or_skip(n_dev)
    hours, mask, prices, ids = _universe_with_ties(n_cfgs=13)
    C = len(ids)
    s = ShardedBatchedRankState(hours, mask, prices, ids, devices=n_dev)
    s.add_state("all", rows=list(range(hours.shape[0])))
    s.add_state("head", rows=[0, 1])
    for deltas in ({ids[3]: 0.01}, {ids[7]: 40.0, ids[1]: 0.2},
                   {ids[C - 3]: 0.5, ids[C - 2]: 0.5, ids[C - 1]: 0.5}):
        s.reprice(deltas)
        for key in ("all", "head"):
            full = s.ranking(key)
            for k in (1, 3) + _k_boundary_cases(C):
                assert s.top_k(key, k) == full[:min(k, C)], (key, k)
            assert s.winner(key) == full[0]
    for bad in (0, -1, 1.5, True):
        with pytest.raises(ValueError, match="positive integer"):
            s.top_k("all", bad)


# --- hypothesis property half (ISSUE 8 satellite) ----------------------------------

if HAVE_HYPOTHESIS:
    #: hypothesis draws device counts from what this process actually
    #: has (skipping inside @given is not allowed); the deterministic
    #: half still reports counts above the pool as explicit skips
    AVAILABLE_COUNTS = [n for n in DEVICE_COUNTS if n <= N_DEVICES] or [1]

    @needs_jax
    @settings(max_examples=12, deadline=None)
    @given(fleet_streams(), st.sampled_from(AVAILABLE_COUNTS))
    def test_sharded_fleet_within_contract_property(data, n_dev):
        """For any fleet and any reprice stream: jax_sharded ==
        jax_batched == numpy cold per tick under the ScoreContract, at
        device counts {1, 2, 8}."""
        jobs, cfgs, rt, prices, stream, members = data
        hours = np.asarray([[rt[(j, c)] for c in cfgs] for j in jobs])
        mask = np.ones_like(hours, dtype=bool)
        pv = np.asarray([prices[c] for c in cfgs])
        sharded = ShardedBatchedRankState(hours, mask, pv.copy(), cfgs,
                                          devices=n_dev)
        batched = BatchedRankState(hours, mask, pv.copy(), cfgs)
        for key, rows in members.items():
            sharded.add_state(key, rows=rows)
            batched.add_state(key, rows=rows)
        live = pv.copy()
        for deltas in stream:
            sharded.reprice(deltas)
            batched.reprice(deltas)
            for c, p in deltas.items():
                live[cfgs.index(c)] = p
            _assert_sharded_parity(sharded, batched, members, hours,
                                   mask, live, cfgs)

    @needs_jax
    @settings(max_examples=10, deadline=None)
    @given(event_markets(), st.sampled_from(AVAILABLE_COUNTS))
    def test_sharded_event_market_within_contract_property(market, n_dev):
        """Event-bearing bursts (discount/eviction boundary re-quotes)
        through the sharded kernel stay within contract of the cold
        float64 rank at every device count."""
        cfgs, base, events, seed, change_fraction, n_ticks, jobs, rt = \
            market
        hours = np.asarray([[rt[(j, c)] for c in cfgs] for j in jobs])
        mask = np.ones_like(hours, dtype=bool)
        live = np.asarray([base[c] for c in cfgs])
        members = {"all": list(range(len(jobs)))}
        sharded = ShardedBatchedRankState(hours, mask, live.copy(), cfgs,
                                          devices=n_dev)
        batched = BatchedRankState(hours, mask, live.copy(), cfgs)
        for key, rows in members.items():
            sharded.add_state(key, rows=rows)
            batched.add_state(key, rows=rows)
        feed = _event_feed(base, events, seed, change_fraction)
        for t in range(n_ticks):
            batch = feed.poll(t)
            if not batch:
                continue
            deltas = {d.config_id: d.price for d in batch}
            sharded.reprice(deltas)
            batched.reprice(deltas)
            for d in batch:
                live[cfgs.index(d.config_id)] = d.price
            _assert_sharded_parity(sharded, batched, members, hours,
                                   mask, live, cfgs)
else:
    @pytest.mark.skip(reason="hypothesis not installed (property half "
                             "of the sharded parity suite)")
    def test_sharded_parity_properties_skipped():
        pass  # pragma: no cover


# --- service / daemon integration --------------------------------------------------

@needs_jax
def test_service_jax_sharded_backend_one_dispatch_per_tick():
    """A jax_sharded service stacks every live (class, exclusion)
    ranking into one ShardedBatchedRankState: a tick refreshes the
    whole fleet in ONE collective dispatch, within contract of a numpy
    reference service."""
    svc = _fleet_service("jax_sharded")
    ref = _fleet_service("numpy")
    selections = [("j1", None), ("j2", None), ("j1", ("g2",)),
                  ("j2", ("g3",))]
    for job, excl in selections:
        d = svc.submit(job, exclude_groups=excl)
        r = ref.submit(job, exclude_groups=excl)
        assert_within_contract(list(d.ranking), list(r.ranking), CONTRACT)
    assert isinstance(svc._batched, ShardedBatchedRankState)
    assert svc._batched.n_active == 4
    deltas = {f"c{i}": float(0.5 + i) for i in range(0, 16, 3)}
    assert svc.reprice(deltas) == 4          # whole fleet refreshed...
    assert svc.reprice_dispatches == 1       # ...in one collective
    assert svc._batched.dispatches == 1
    ref.reprice(deltas)
    for job, excl in selections:
        assert_within_contract(
            list(svc.submit(job, exclude_groups=excl).ranking),
            list(ref.submit(job, exclude_groups=excl).ranking), CONTRACT)
    svc.reprice({"c1": 9.0})
    assert svc.reprice_dispatches == 2
    # top-k serving through the service: the head IS the head
    d = svc.submit("j1", top_k=3)
    assert d.served_via == "top_k"
    assert tuple(d.ranking) == tuple(svc.submit("j1").ranking[:3])


@needs_jax
def test_sharded_service_survives_out_of_band_table_apply():
    """The PR-2 desync invariant holds for the sharded fleet: an
    out-of-band PriceTable.apply drops the universe for a cold rebuild
    instead of serving quotes it never saw."""
    svc = _fleet_service("jax_sharded")
    ref = _fleet_service("numpy")
    svc.submit("j1"); ref.submit("j1")
    svc.price_source.apply({"c2": 0.333})
    ref.price_source.apply({"c2": 0.333})
    deltas = {"c5": 7.7}
    assert svc.reprice(deltas) == 0          # fleet dropped, not repriced
    ref.reprice(deltas)
    assert_within_contract(list(svc.submit("j1").ranking),
                           list(ref.submit("j1").ranking), CONTRACT)


@needs_jax
def test_sharded_daemon_journal_audits_in_tolerance_mode():
    """A jax_sharded daemon stamps its backend in the journal header
    and the unmodified JournalReplayer audits it clean in tolerance
    mode — the serving-path acceptance invariant."""
    from repro.market import (JournalReplayer, SelectionDaemon,
                              SimulatedSpotFeed, synthetic_stream)
    from repro.selector import IdentityCatalog, PriceTable, ProfilingStore
    from repro.selector import SelectionService
    rng = np.random.default_rng(9)
    ids = [f"c{i}" for i in range(13)]
    store = ProfilingStore(config_ids=ids)
    for j in range(8):
        klass = JobClass.A if j % 2 else JobClass.B
        for c in ids:
            store.add(f"j{j}", c, float(rng.uniform(0.1, 5.0)),
                      job_class=klass, group=f"g{j % 4}")
    base = {c: float(rng.uniform(1.0, 20.0)) for c in ids}
    table = PriceTable(dict(base))
    svc = SelectionService(IdentityCatalog(ids), store, table,
                           backend="jax_sharded", serve_top_k=3)
    feed = SimulatedSpotFeed(base, seed=4, change_fraction=0.4)
    daemon = SelectionDaemon(svc, feed)
    for event in synthetic_stream([f"j{i}" for i in range(8)], 60,
                                  seed=7, tick_fraction=0.25):
        daemon.handle(event)
    journal = daemon.journal_dump()
    replayer = JournalReplayer(store, journal)
    assert replayer.backend == "jax_sharded"
    assert not score_contract(replayer.backend).bit_identical
    audit = replayer.audit()
    assert audit.ok, audit.mismatches[:3]
    assert audit.decisions > 0
