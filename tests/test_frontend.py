"""Tests for the concurrent serving front-end (repro.market.frontend).

Covers the ISSUE 6 acceptance surface: the tick-owned snapshot publish /
lock-free worker serving split, bounded-queue backpressure with explicit
shed and drain accounting, the typed feed-error path (serve off the last
good snapshot, retry with capped backoff), retirement + revival through
the control path, and the deterministic shard merge — pinned by a golden
journal and checked end-to-end by ``JournalReplayer.audit`` (numpy:
bit-identical; jax_batched: the ScoreContract envelope).

The inline stepping API (``step_tick``/``serve_queued``/``close``) drives
the same code paths without threads, which is what makes the golden and
the hypothesis interleave property deterministic; the threaded tests then
pin that real concurrency (workers from ``FLORA_SERVE_WORKERS``, default
2) preserves the same accounting and audit guarantees.

Regenerate the golden journal after a *deliberate* schema change with

    PYTHONPATH=src python tests/test_frontend.py --regen-golden

and add a migration note to DESIGN.md §8 in the same commit.
"""
import os
import threading
import time

import pytest

from hyputil import HAVE_HYPOTHESIS, given, settings, st
from repro.core.trace import JobClass
from repro.market import (FeedError, JournalReplayer, RecordedPriceFeed,
                          SelectionDaemon, ServeFrontend, SimulatedSpotFeed,
                          Submission, merge_shards, record_feed)
from repro.selector import (IdentityCatalog, NothingRankableError, PriceTable,
                            ProfilingStore, SelectionService,
                            backend_available)
from test_soak import SOAK_SELECTIONS, _recorded_market, _soak_store

if HAVE_HYPOTHESIS:
    from test_rank_properties import _event_feed, event_markets
else:                                       # decoration-time stand-ins only
    def event_markets():
        return None

FIXTURES = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "fixtures")
GOLDEN_FRONTEND = os.path.join(
    FIXTURES, "decision_journal_v2_frontend.golden.jsonl")

#: the CI front-end leg scales this up (FLORA_SERVE_WORKERS=4).
N_WORKERS = int(os.environ.get("FLORA_SERVE_WORKERS", "2"))


# --- shared universe ------------------------------------------------------------

def _universe():
    """Small fully-profiled identity universe: 6 jobs (classes A/B,
    groups g0-g2) x 8 configs, deterministic runtimes."""
    ids = [f"c{i}" for i in range(8)]
    store = ProfilingStore(config_ids=ids)
    for j in range(6):
        klass = JobClass.A if j % 2 else JobClass.B
        for i, c in enumerate(ids):
            store.add(f"j{j}", c, 0.2 + ((j * 5 + i * 3) % 13) / 4.0,
                      job_class=klass, group=f"g{j % 3}")
    base = {c: 1.0 + i for i, c in enumerate(ids)}
    return store, ids, base


def _recorded(base, n_ticks=12, seed=9):
    sim = SimulatedSpotFeed(base, seed=seed, change_fraction=0.5)
    return RecordedPriceFeed.loads(record_feed(sim, n_ticks))


def _frontend(backend="numpy", n_ticks=12, feed=None, **kw):
    store, ids, base = _universe()
    if feed is None:
        feed = _recorded(base, n_ticks=n_ticks)
    svc = SelectionService(IdentityCatalog(ids), store, PriceTable(base),
                           backend=backend,
                           serve_top_k=kw.pop("serve_top_k", None))
    return ServeFrontend(svc, feed, **kw), store


#: a selection whose exclusions empty the class: genuinely unrankable,
#: so its published rejection can never go stale (price-independent).
UNRANKABLE = Submission("j1", exclude_groups=("g0", "g1", "g2"))


class _FlakyFeed:
    """Recorded feed whose poll raises ``times`` times at each tick in
    ``fail_ticks`` — the transient-outage shape the typed feed-error
    path exists for.  Deterministic: same wrapper, same failures."""

    def __init__(self, inner, fail_ticks, times=2):
        self.inner = inner
        self.ticks = inner.ticks
        self._remaining = {t: times for t in fail_ticks}

    def config_ids(self):
        return self.inner.config_ids()

    def poll(self, tick):
        if self._remaining.get(tick, 0) > 0:
            self._remaining[tick] -= 1
            raise ConnectionError(f"transient market outage at {tick}")
        return self.inner.poll(tick)


# --- the golden journal (inline mode = deterministic concurrency) ----------------

def golden_frontend():
    """The pinned run: 2 workers, 5 recorded ticks with one transient
    feed failure, worker decisions + a worker-served rejection + a
    forwarded (control-path) decision interleaved across ticks."""
    store, ids, base = _universe()
    feed = _FlakyFeed(_recorded(base, n_ticks=5), fail_ticks=(2,), times=1)
    svc = SelectionService(IdentityCatalog(ids), store, PriceTable(base),
                           backend="numpy")
    return ServeFrontend(svc, feed, workers=2, top_k=2), store


def run_golden(fe):
    fe.warm([Submission("j1"), Submission("j2"), UNRANKABLE])
    fe.submit(Submission("j1"))
    fe.submit("j2")                      # bare job ids wrap to Submissions
    fe.step_tick()                       # tick 0
    fe.serve_queued()                    # two worker decisions at epoch 0
    fe.submit(UNRANKABLE)                # worker-served rejection
    fe.submit(Submission("j3"))          # unwarmed: forwarded to control
    fe.step_tick()                       # tick 1
    fe.serve_queued()
    assert fe.step_tick() == "feed-error"    # tick 2 fails once...
    assert fe.step_tick() == "tick"          # ...and the retry lands it
    fe.submit(Submission("j1"))
    fe.step_tick()                       # tick 3
    fe.serve_queued()
    fe.step_tick()                       # tick 4
    return fe.close()


def test_frontend_journal_golden_file():
    """Pins the merged front-end journal byte-for-byte: record shapes
    shared with the daemon, the additive worker/snapshot_tick/tick
    stamps, the feed-error record, and the (tick, worker, seq) merge
    order.  If this fails you changed the journal schema — follow the
    regen + DESIGN.md §8 discipline in the module docstring."""
    fe, _ = golden_frontend()
    stats = run_golden(fe)
    assert stats.accounted and stats.feed_errors == 1
    with open(GOLDEN_FRONTEND) as f:
        assert fe.journal_dump() == f.read()


def test_inline_run_is_deterministic_and_audit_clean():
    """Same submissions + same interleave => byte-identical merged
    journal (the golden's reproducibility bar), and the unmodified
    JournalReplayer audits it bit-identical — workers, forwards, the
    rejection and the feed error included."""
    fe1, store = golden_frontend()
    stats = run_golden(fe1)
    fe2, _ = golden_frontend()
    run_golden(fe2)
    assert fe1.journal_dump() == fe2.journal_dump()

    assert stats.decisions == 4 and stats.rejected == 1
    assert stats.forwarded == 1 and stats.shed == 0
    assert stats.ticks == 5 and stats.snapshots > 0
    replayer = JournalReplayer(store, fe1.journal_dump())
    audit = replayer.audit()
    assert audit.ok, audit.mismatches[:5]
    assert audit.decisions == stats.decisions
    assert audit.rejected == stats.rejected
    assert audit.feed_errors == stats.feed_errors == 1
    assert audit.contract.bit_identical and audit.drift == ()
    # every decision surfaces its serving shard and snapshot epoch
    decisions = replayer.decisions()
    assert {d.worker for d in decisions} <= {0, 1, 2}
    assert all(d.snapshot_tick is not None for d in decisions)
    assert any(d.worker and d.worker > 0 for d in decisions)     # workers
    assert any(d.worker == 0 for d in decisions)                 # control


def test_merged_journal_parses_as_v2():
    fe, _ = golden_frontend()
    run_golden(fe)
    header, records = SelectionDaemon.loads_journal(fe.journal_dump())
    assert header["backend"] == "numpy"
    kinds = [r["kind"] for r in records]
    assert {"tick", "decision", "rejected", "feed-error"} <= set(kinds)
    assert [r["seq"] for r in records] == list(range(1, len(records) + 1))
    for r in records:
        assert "worker" in r
        assert ("snapshot_tick" in r) == (r["kind"] in ("decision",
                                                        "rejected"))
        assert ("tick" in r) == (r["kind"] in ("tick", "feed-error",
                                               "metrics"))


# --- merge_shards: the total order -----------------------------------------------

def test_merge_shards_total_order_and_seq():
    """The merge sorts by (tick, worker, per-shard position) and
    renumbers seq: tick-thread records first within a tick, worker
    decisions between the tick records of their stamped epochs, and the
    result independent of the shard-list order (thread scheduling)."""
    header = '{"format": "test-header"}'
    tick0 = {"kind": "tick", "seq": 0, "worker": 0, "tick": 0}
    tick1 = {"kind": "tick", "seq": 0, "worker": 0, "tick": 1}
    d_w1_t0 = {"kind": "decision", "seq": 0, "worker": 1,
               "snapshot_tick": 0, "job": "a"}
    d_w1_t1 = {"kind": "decision", "seq": 0, "worker": 1,
               "snapshot_tick": 1, "job": "b"}
    d_w2_t0 = {"kind": "decision", "seq": 0, "worker": 2,
               "snapshot_tick": 0, "job": "c"}
    shards = [[tick0, tick1], [d_w1_t0, d_w1_t1], [d_w2_t0]]
    merged = merge_shards(header, shards)
    lines = merged.splitlines()
    assert lines[0] == header
    import json
    recs = [json.loads(ln) for ln in lines[1:]]
    assert [r["seq"] for r in recs] == [1, 2, 3, 4, 5]
    assert [(r["kind"], r["worker"]) for r in recs] == [
        ("tick", 0), ("decision", 1), ("decision", 2),   # epoch of tick 0
        ("tick", 0), ("decision", 1)]                    # epoch of tick 1
    # shard order (scheduling accident) cannot change the merged bytes
    assert merge_shards(header, list(reversed(shards))) == merged
    # seq renumbering never mutates the caller's shard records
    assert tick0["seq"] == 0


def test_merge_shards_tolerates_degenerate_shards():
    """Satellite (ISSUE 8): a worker that journaled zero records hands
    the merge an empty shard — the total order and contiguous seq
    renumbering must survive any number of empty shards in any
    position, and the all-empty merge is the header-only journal."""
    import json
    header = '{"format": "test-header"}'
    tick0 = {"kind": "tick", "seq": 0, "worker": 0, "tick": 0}
    d_w2_t0 = {"kind": "decision", "seq": 0, "worker": 2,
               "snapshot_tick": 0, "job": "a"}
    busy = [[tick0], [d_w2_t0]]
    merged = merge_shards(header, busy)
    # empty shards are inert: same bytes wherever they appear
    assert merge_shards(header, [[], *busy]) == merged
    assert merge_shards(header, [[tick0], [], [d_w2_t0], []]) == merged
    recs = [json.loads(ln) for ln in merged.splitlines()[1:]]
    assert [r["seq"] for r in recs] == [1, 2]
    # every shard empty (a frontend that served nothing): header only
    assert merge_shards(header, [[], [], []]) == header + "\n"
    assert merge_shards(header, []) == header + "\n"


def test_zero_record_worker_shard_still_audits_clean():
    """Satellite (ISSUE 8), end-to-end: with no warm-up every queued
    submission misses the snapshot and forwards to the control path, so
    *both* worker shards journal zero records; a second wave sheds 100%
    against the capacity-1 queues.  The merged journal must still be
    total-ordered with contiguous seq and pass the unmodified
    ``JournalReplayer.audit``."""
    fe, store = _frontend(workers=2, queue_capacity=1, n_ticks=4)
    assert fe.step_tick() == "tick"              # tick 0 lands
    assert fe.submit(Submission("j1"))           # -> worker 1
    assert fe.submit(Submission("j2"))           # -> worker 2
    assert fe.submit(Submission("j1")) is False  # w1 at capacity: shed
    assert fe.submit(Submission("j2")) is False  # w2 at capacity: shed
    fe.serve_queued()                # both miss the snapshot -> forward
    fe.step_tick()                   # control path serves both
    stats = fe.close()
    assert stats.forwarded == 2 and stats.decisions == 2
    assert stats.shed == 2 and stats.accounted
    _, records = SelectionDaemon.loads_journal(fe.journal_dump())
    served = [r for r in records if r["kind"] in ("decision", "rejected")]
    assert len(served) == 2
    assert all(r["worker"] == 0 for r in records)   # worker shards empty
    assert [r["seq"] for r in records] == list(range(1, len(records) + 1))
    audit = JournalReplayer(store, fe.journal_dump()).audit()
    assert audit.ok, audit.mismatches[:5]
    assert audit.decisions == 2


# --- parameter validation + submit-after-close -----------------------------------

@pytest.mark.parametrize("kw", [
    {"workers": 0}, {"workers": -1}, {"workers": True},
    {"queue_capacity": 0}, {"top_k": 0}, {"top_k": True},
])
def test_frontend_rejects_bad_params(kw):
    store, ids, base = _universe()
    svc = SelectionService(IdentityCatalog(ids), store, PriceTable(base))
    with pytest.raises(ValueError):
        ServeFrontend(svc, _recorded(base), **kw)


def test_submit_after_close_is_shed():
    fe, _ = _frontend(workers=1)
    fe.submit(Submission("j1"))
    fe.close()
    assert fe.submit(Submission("j2")) is False
    stats = fe.stats()
    assert stats.shed == 1 and stats.submitted == 1 and stats.accounted


def test_close_refuses_started_frontend():
    fe, _ = _frontend(workers=1)
    fe.start()
    try:
        with pytest.raises(RuntimeError, match="shutdown"):
            fe.close()
    finally:
        fe.shutdown()


def test_backoff_delay_is_capped_exponential():
    fe, _ = _frontend(backoff_base=0.01, backoff_cap=0.5)
    assert fe.backoff_delay(1) == pytest.approx(0.01)
    assert fe.backoff_delay(2) == pytest.approx(0.02)
    assert fe.backoff_delay(4) == pytest.approx(0.08)
    assert fe.backoff_delay(50) == 0.5          # capped, no overflow


# --- satellite: burst past queue capacity ----------------------------------------

def test_burst_ten_x_capacity_sheds_drains_and_accounts():
    """Submitting a burst of 10x the total queue capacity against slow
    consumers must shed (submit returns False) rather than deadlock or
    buffer unboundedly, drain cleanly, and account for every submission
    in the merged journal: accepted = journaled decisions, refused =
    counted shed, nothing lost, audit still clean."""
    capacity = 4
    fe, store = _frontend(workers=2, queue_capacity=capacity,
                          on_decision=lambda d: time.sleep(0.002))
    fe.warm([Submission("j1"), Submission("j2")])
    burst = [Submission("j1" if i % 2 else "j2")
             for i in range(10 * 2 * capacity)]
    accepted = []
    with fe:
        def produce(subs):
            accepted.append(sum(fe.submit(s) for s in subs))

        producers = [threading.Thread(target=produce,
                                      args=(burst[i::2],))
                     for i in range(2)]
        for t in producers:
            t.start()
        for t in producers:
            t.join()
        fe.drain(timeout=30.0)           # TimeoutError here = deadlock
        fe.await_ticks(timeout=30.0)
    stats = fe.stats()
    assert stats.submitted == sum(accepted)
    assert stats.submitted + stats.shed == len(burst)
    assert stats.shed > 0                # the burst actually overflowed
    assert stats.submitted > 0           # ...but wasn't refused outright
    assert stats.accounted and stats.rejected == 0
    # the merged journal carries exactly the accepted submissions
    _, records = SelectionDaemon.loads_journal(fe.journal_dump())
    served = [r for r in records if r["kind"] in ("decision", "rejected")]
    assert len(served) == stats.submitted
    audit = JournalReplayer(store, fe.journal_dump()).audit()
    assert audit.ok, audit.mismatches[:5]
    assert audit.decisions == stats.decisions


# --- satellite: typed feed-error path --------------------------------------------

def test_threaded_flaky_feed_keeps_serving_and_audits():
    """A feed that dies transiently mid-run: the tick thread journals
    typed ``feed-error`` records, keeps serving off the last good
    snapshot, retries the failed tick with backoff until the market
    completes — and the merged journal still audits clean."""
    store, ids, base = _universe()
    feed = _FlakyFeed(_recorded(base, n_ticks=12), fail_ticks=(3, 7),
                      times=2)
    svc = SelectionService(IdentityCatalog(ids), store, PriceTable(base))
    fe = ServeFrontend(svc, feed, workers=N_WORKERS,
                       backoff_base=0.001, backoff_cap=0.01)
    fe.warm([Submission("j1"), Submission("j2")])
    with fe:
        for i in range(30):
            assert fe.submit(Submission("j1" if i % 2 else "j2"))
            time.sleep(0.001)
        fe.await_ticks(timeout=30.0)     # all 12 ticks despite failures
        fe.drain(timeout=30.0)
    stats = fe.stats()
    assert stats.ticks == 12
    assert stats.feed_errors == 4        # two outages, two retries each
    assert stats.accounted and stats.decisions == 30
    audit = JournalReplayer(store, fe.journal_dump()).audit()
    assert audit.ok, audit.mismatches[:5]
    assert audit.feed_errors == 4
    assert audit.decisions == 30


def test_feed_error_backoff_state_resets_on_good_tick():
    store, ids, base = _universe()
    feed = _FlakyFeed(_recorded(base, n_ticks=4), fail_ticks=(1,), times=3)
    svc = SelectionService(IdentityCatalog(ids), store, PriceTable(base))
    fe = ServeFrontend(svc, feed, workers=1, backoff_base=0.01)
    assert fe.step_tick() == "tick"              # tick 0
    epoch_before = svc.price_epoch
    delays = []
    while fe.step_tick() == "feed-error":        # tick 1 fails 3x
        delays.append(fe.backoff_delay())
        assert svc.price_epoch == epoch_before   # prices stayed put
    assert delays == [pytest.approx(0.01), pytest.approx(0.02),
                      pytest.approx(0.04)]       # doubling per failure
    assert fe.backoff_delay() == pytest.approx(0.01)   # reset on success
    assert fe.ticker.tick_count == 2             # tick 1 landed on retry
    fe.close()


def test_feed_error_failures_reset_across_fail_recover_fail():
    """Satellite (ISSUE 8): the consecutive-failures counter that feeds
    both the journaled ``failures`` field and the backoff delay restarts
    from base after the *first* successful poll — a second outage
    journals failures 1,2 again (never 3,4), and the healthy feed never
    inherits the inflated delay."""
    import json
    store, ids, base = _universe()
    feed = _FlakyFeed(_recorded(base, n_ticks=5), fail_ticks=(1, 3),
                      times=2)
    svc = SelectionService(IdentityCatalog(ids), store, PriceTable(base))
    fe = ServeFrontend(svc, feed, workers=1, backoff_base=0.01)
    statuses = []
    while fe.ticker.tick_count < 5:
        statuses.append(fe.step_tick())
        if statuses[-1] == "tick":
            # first good poll after an outage: delay back at base
            assert fe.backoff_delay() == pytest.approx(0.01)
    assert statuses.count("feed-error") == 4     # two outages, 2x each
    assert statuses.count("tick") == 5
    fe.close()
    records = [json.loads(ln)
               for ln in fe.journal_dump().splitlines()[1:]]
    errs = [r for r in records if r["kind"] == "feed-error"]
    assert [e["failures"] for e in errs] == [1, 2, 1, 2]
    assert [e["tick"] for e in errs] == [1, 1, 3, 3]
    audit = JournalReplayer(store, fe.journal_dump()).audit()
    assert audit.ok, audit.mismatches[:5]
    assert audit.feed_errors == 4


# --- satellite: retirement + revival through the control path --------------------

def test_retired_selection_revives_through_control_path():
    """Retiring a live selection drops it from the snapshot and the
    service; the next submission forwards to the control path, which
    re-registers and serves it fresh — the journal shows a decision
    (never a spurious rejection), so the audit stays clean."""
    fe, store = _frontend(workers=1, n_ticks=6)
    fe.warm([Submission("j1")])
    fe.submit(Submission("j1"))
    fe.step_tick()
    fe.serve_queued()
    assert (JobClass.A, ("g1",)) in fe.snapshot.entries

    fe.retire_selection(JobClass.A, ("g1",))
    fe.step_tick()                       # control drain applies it
    assert (JobClass.A, ("g1",)) not in fe.snapshot.entries

    fe.submit(Submission("j1"))          # post-retirement: forwarded...
    fe.serve_queued()
    fe.step_tick()                       # ...revived via control path
    assert (JobClass.A, ("g1",)) in fe.snapshot.entries
    fe.submit(Submission("j1"))          # ...and worker-served again
    fe.serve_queued()
    stats = fe.close()
    assert stats.decisions == 3 and stats.rejected == 0
    assert stats.forwarded == 1
    audit = JournalReplayer(store, fe.journal_dump()).audit()
    assert audit.ok, audit.mismatches[:5]


def test_service_retire_selection_drops_caches_and_reports():
    store, ids, base = _universe()
    svc = SelectionService(IdentityCatalog(ids), store, PriceTable(base))
    svc.submit("j1")
    svc.submit("j1")
    assert svc.cache_misses == 1 and svc.cache_hits == 1
    assert svc.retire_selection(JobClass.A, ("g1",)) is True
    assert svc.retire_selection(JobClass.A, ("g1",)) is False   # idempotent
    svc.submit("j1")                     # revival = a fresh cold build
    assert svc.cache_misses == 2


def test_batched_retired_member_raises_typed_not_raw():
    """Satellite: on the batched backend a retired member surfaces as
    NothingRankableError — a typed rejection the serving layers journal
    — never a raw KeyError or a silently-masked-slot score; and a later
    submit for the same selection revives it."""
    if not backend_available("jax_batched"):
        pytest.skip("jax not installed")
    store, ids, base = _universe()
    svc = SelectionService(IdentityCatalog(ids), store, PriceTable(base),
                           backend="jax_batched")
    d1 = svc.submit("j1")
    base_key = (store.version, JobClass.A, ("g1",))
    assert svc._batched is not None and base_key in svc._batched
    assert svc.retire_selection(JobClass.A, ("g1",)) is True
    with pytest.raises(NothingRankableError, match="retired"):
        svc._batched.ranking(base_key)
    with pytest.raises(NothingRankableError, match="retired"):
        svc._batched.top_k(base_key, 1)
    d2 = svc.submit("j1")                # revival, same winner
    assert d2.config_id == d1.config_id


def test_unrankable_selection_serves_snapshot_rejections():
    """A warmed-but-unrankable selection publishes a ``head=None``
    snapshot entry: workers journal the rejection without a service
    call, and the audit confirms it as genuine (cold rank also finds
    nothing)."""
    fe, store = _frontend(workers=1, n_ticks=4)
    fe.warm([UNRANKABLE])
    route = (JobClass.A, ("g0", "g1", "g2"))
    assert fe.snapshot.entries[route].head is None
    fe.submit(UNRANKABLE)
    fe.step_tick()
    fe.serve_queued()
    stats = fe.close()
    assert stats.rejected == 1 and stats.decisions == 0
    assert stats.forwarded == 0          # served straight off the snapshot
    audit = JournalReplayer(store, fe.journal_dump()).audit()
    assert audit.ok and audit.rejected == 1


# --- satellite: hypothesis interleave property -----------------------------------

@settings(max_examples=15, deadline=None)
@given(event_markets(), st.lists(st.integers(0, 7), min_size=5,
                                 max_size=40))
def test_any_interleave_audits_bit_identical(market, program):
    """For any event-bearing market and any interleave of ticks, worker
    serves and submissions, every journaled decision's score matches a
    cold re-rank at its stamped epoch — ``JournalReplayer.audit`` in
    numpy bit-identity mode over the merged journal — and every
    accepted submission is accounted."""
    cfgs, base, events, seed, change_fraction, n_ticks, jobs, rt = market
    store = ProfilingStore(config_ids=cfgs)
    for idx, j in enumerate(jobs):
        for c in cfgs:
            store.add(j, c, rt[(j, c)],
                      job_class=JobClass.A if idx % 2 else JobClass.B)
    svc = SelectionService(IdentityCatalog(cfgs), store, PriceTable(base))
    fe = ServeFrontend(svc, _event_feed(base, events, seed,
                                        change_fraction),
                       workers=2, ticks=n_ticks)
    for op in program:
        if op == 0:
            fe.step_tick()
        elif op == 1:
            fe.serve_queued()
        else:
            fe.submit(Submission(jobs[op % len(jobs)]))
    stats = fe.close()
    assert stats.accounted and stats.shed == 0
    audit = JournalReplayer(store, fe.journal_dump()).audit()
    assert audit.ok, audit.mismatches[:3]
    assert audit.decisions == stats.decisions
    assert audit.contract.bit_identical and audit.drift == ()


# --- the threaded soak: real concurrency over the 220-tick recorded market -------

@pytest.mark.parametrize("backend", ["numpy", "jax_batched"])
def test_threaded_soak_recorded_market(backend):
    """The front-end run the CI leg soaks: N workers serving the six
    soak selections off live snapshots while the 220-tick recorded
    market plays out on the tick thread — zero shed, every submission
    accounted, the merged journal audit-clean (numpy bit-identical,
    jax_batched within the ScoreContract), and the batched backend
    still spending one kernel dispatch per price epoch."""
    if not backend_available(backend):
        pytest.skip("jax not installed")
    store, ids = _soak_store()
    feed, base = _recorded_market(ids)
    svc = SelectionService(IdentityCatalog(ids), store, PriceTable(base),
                           backend=backend, serve_top_k=3)
    fe = ServeFrontend(svc, feed, workers=N_WORKERS, queue_capacity=512,
                       tick_interval=0.001)
    warmup = [Submission(job, exclude_groups=excl)
              for job, excl in SOAK_SELECTIONS]
    assert fe.warm(warmup) == len(SOAK_SELECTIONS)
    n_subs = 150
    with fe:
        for i in range(n_subs):
            job, excl = SOAK_SELECTIONS[i % len(SOAK_SELECTIONS)]
            assert fe.submit(Submission(job, exclude_groups=excl))
            time.sleep(0.001)
        fe.await_ticks(timeout=60.0)
        fe.drain(timeout=30.0)
    stats = fe.stats()
    assert stats.ticks == 220 and stats.epochs >= 180
    assert stats.shed == 0 and stats.accounted
    assert stats.decisions == n_subs and stats.rejected == 0
    assert stats.forwarded == 0          # warm() pre-registered the fleet

    replayer = JournalReplayer(store, fe.journal_dump())
    assert replayer.backend == backend
    audit = replayer.audit()
    assert audit.ok, audit.mismatches[:5]
    assert audit.decisions == n_subs
    decisions = replayer.decisions()
    assert all(d.worker and d.worker > 0 for d in decisions)
    assert all(d.snapshot_tick is not None for d in decisions)
    if backend == "numpy":
        assert audit.contract.bit_identical and audit.drift == ()
    else:
        assert svc._batched is not None
        assert svc._batched.n_active == len(SOAK_SELECTIONS)
        # THE batching claim survives the concurrent front-end: one
        # kernel dispatch per price epoch for the whole fleet
        assert stats.epochs - 1 <= svc.reprice_dispatches <= stats.epochs
        assert all(d.served_via == "top_k" for d in decisions)


class _GatedFeed:
    """Recorded feed whose poll blocks until its tick is released —
    lets a test hold the threaded tick loop to a scripted schedule."""

    def __init__(self, inner):
        self.inner = inner
        self.ticks = inner.ticks
        self._allowed = 0
        self._cv = threading.Condition()

    def config_ids(self):
        return self.inner.config_ids()

    def allow(self, upto):
        with self._cv:
            self._allowed = upto
            self._cv.notify_all()

    def poll(self, tick):
        with self._cv:
            assert self._cv.wait_for(lambda: self._allowed > tick,
                                     timeout=30.0)
        return self.inner.poll(tick)


def _wait_snapshot(fe, tick, timeout=30.0):
    deadline = time.monotonic() + timeout
    while fe.snapshot.tick < tick:
        if time.monotonic() > deadline:
            raise TimeoutError(f"snapshot never reached tick {tick}")
        time.sleep(0.001)


def test_threaded_journal_equals_inline_journal_same_interleave():
    """Thread scheduling cannot leak into the merged bytes: a threaded
    run whose workers see the exact same (submission, snapshot-epoch)
    pairs as an inline run merges to the identical journal.  The feed
    is gated so each threaded batch drains against a pinned snapshot
    before the next tick is released."""
    n_ticks = 4

    def run(threaded):
        store, ids, base = _universe()
        # change_fraction=1.0: every tick moves prices, so every tick
        # republishes and the snapshot wait below always terminates
        sim = SimulatedSpotFeed(base, seed=9, change_fraction=1.0)
        gate = _GatedFeed(
            RecordedPriceFeed.loads(record_feed(sim, n_ticks)))
        if not threaded:
            gate.allow(n_ticks)
        svc = SelectionService(IdentityCatalog(ids), store,
                               PriceTable(base))
        fe = ServeFrontend(svc, gate, workers=2)
        fe.warm([Submission("j1"), Submission("j2")])
        if threaded:
            fe.start()
        for t in range(n_ticks):
            for s in ("j1", "j2", "j1"):
                fe.submit(Submission(s))
            if threaded:
                fe.drain(timeout=30.0)   # batch served at pinned epoch
                gate.allow(t + 1)        # release tick t...
                _wait_snapshot(fe, t)    # ...and wait for its snapshot
            else:
                fe.serve_queued()
                fe.step_tick()
        if threaded:
            fe.shutdown()
        else:
            fe.close()
        return fe.journal_dump()

    assert run(threaded=True) == run(threaded=False)


if __name__ == "__main__":
    import sys
    if "--regen-golden" in sys.argv:
        fe, _ = golden_frontend()
        run_golden(fe)
        fe.save_journal(GOLDEN_FRONTEND)
        print(f"wrote {GOLDEN_FRONTEND}")
    else:
        print(__doc__)
