"""Differential batched-parity suite (ISSUE 5 satellites).

The batched fleet kernel (:class:`~repro.selector.BatchedRankState`,
DESIGN.md §10) must be indistinguishable — within the jax
``ScoreContract`` — from the fleet it replaces: for random fleets of
(row-subset) member states, every tick of the batched state must match

  * per-state :class:`~repro.selector.JaxRankState` ticks (the PR-4
    path: one dispatch per state per tick),
  * a cold numpy float64 ``rank_dense`` at the live prices (the audit
    reference),

including event-bearing deltas (discount/eviction boundary re-quote
bursts) and members added or retired mid-stream.  A hypothesis property
half reuses the market strategies from ``test_rank_properties``; the
seeded deterministic half runs without hypothesis.

Also home to the device-side top-k serving tests (``top_k(k)`` must be
the head of the materialized ranking, ties included, on every backend)
and the ranking-memoization counter tests (the ISSUE 5 fix: repeat
``ranking()`` calls between two ticks must not re-materialize).
"""
import numpy as np
import pytest

from repro.core.trace import JobClass
from repro.selector import (BatchedRankState, IdentityCatalog, JaxRankState,
                            NothingRankableError, PriceTable, ProfilingStore,
                            RankState, SelectionService, backend_available,
                            rank_dense, score_contract)
from test_backend_parity import assert_within_contract

try:        # the property half needs hypothesis; everything else runs
            # without it
    import hypothesis
    from hypothesis import given, settings, strategies as st
    from test_rank_properties import (delta_streams, event_markets,
                                      _event_feed)
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

needs_jax = pytest.mark.skipif(not backend_available("jax_batched"),
                               reason="jax not installed")

CONTRACT = score_contract("jax_batched")


def _fleet_universe(seed, n_jobs=10, n_cfgs=24, n_members=4, partial=True):
    """Random universe plus a fleet of member row subsets (every job
    row appears in at least the 'all' member)."""
    rng = np.random.default_rng(seed)
    hours = rng.uniform(0.05, 10.0, (n_jobs, n_cfgs))
    if partial:
        mask = rng.random((n_jobs, n_cfgs)) > 0.25
        mask[np.arange(n_jobs), rng.integers(0, n_cfgs, n_jobs)] = True
    else:
        mask = np.ones((n_jobs, n_cfgs), dtype=bool)
    prices = rng.uniform(0.5, 20.0, n_cfgs)
    ids = [f"c{i}" for i in range(n_cfgs)]
    members = {"all": list(range(n_jobs))}
    for m in range(n_members - 1):
        size = int(rng.integers(1, n_jobs))
        members[f"m{m}"] = sorted(
            int(i) for i in rng.choice(n_jobs, size, replace=False))
    return rng, hours, mask, prices, ids, members


def _assert_fleet_parity(batched, members, hours, mask, live, ids,
                         refs=None):
    """Every member of ``batched`` is within contract of a cold numpy
    float64 rank over its rows (and of its per-state jax ref, when
    given)."""
    for key, rows in members.items():
        cold = rank_dense(hours[rows], mask[rows], live, ids)
        assert_within_contract(batched.ranking(key), cold, CONTRACT)
        if refs is not None:
            assert_within_contract(batched.ranking(key),
                                   refs[key].ranking(), CONTRACT)


# --- deterministic differential sweeps (run without hypothesis) --------------------

@needs_jax
@pytest.mark.parametrize("seed", range(6))
def test_batched_fleet_within_contract_seeded(seed):
    """Seeded fleets: after every tick, each batched member matches its
    per-state JaxRankState and the cold numpy float64 rank, under the
    contract — one batched dispatch per tick versus one per state."""
    rng, hours, mask, prices, ids, members = _fleet_universe(
        seed, n_jobs=6 + seed, n_cfgs=12 + 4 * seed,
        partial=seed % 2 == 0)
    batched = BatchedRankState(hours, mask, prices.copy(), ids)
    for key, rows in members.items():
        batched.add_state(key, rows=rows)
    refs = {key: JaxRankState(hours[rows], mask[rows], prices.copy(), ids)
            for key, rows in members.items()}
    live = prices.copy()
    for _ in range(6):
        k = int(rng.integers(1, len(ids)))
        cols = rng.choice(len(ids), k, replace=False)
        deltas = {ids[c]: float(live[c] * rng.uniform(0.5, 2.0))
                  for c in cols}
        batched.reprice(deltas)
        for ref in refs.values():
            ref.reprice(deltas)
        for c, p in deltas.items():
            live[int(c[1:])] = p
        _assert_fleet_parity(batched, members, hours, mask, live, ids,
                             refs)
    # the accounting the bench gates on: one dispatch per tick, fleet-wide
    assert batched.dispatches == batched.reprices == 6
    assert batched.n_active == len(members)


@needs_jax
def test_batched_event_market_within_contract_deterministic():
    """Discount/eviction boundary re-quote bursts through the batched
    kernel stay within contract of cold float64 ranks for every member
    (the deterministic analogue of the hypothesis event_markets
    sweep)."""
    from repro.market import MarketEvent, SimulatedSpotFeed
    rng, hours, mask, prices, ids, members = _fleet_universe(
        7, n_jobs=8, n_cfgs=10, partial=False)
    base = {c: float(p) for c, p in zip(ids, prices)}
    feed = SimulatedSpotFeed(
        base, seed=5, change_fraction=0.3, volatility=0.15,
        events=[MarketEvent("us-central1", 2, 4, 0.25, "discount"),
                MarketEvent("europe-west3", 5, 3, 4.0, "eviction")])
    batched = BatchedRankState(hours, mask, prices.copy(), ids)
    for key, rows in members.items():
        batched.add_state(key, rows=rows)
    live = prices.copy()
    for t in range(10):
        batch = feed.poll(t)
        if not batch:
            continue
        batched.reprice({d.config_id: d.price for d in batch})
        for d in batch:
            live[ids.index(d.config_id)] = d.price
        _assert_fleet_parity(batched, members, hours, mask, live, ids)


@needs_jax
def test_states_added_and_retired_mid_stream():
    """Members added mid-stream are in sync with every tick applied so
    far; retired members stop contributing and their slots are reused;
    capacity growth past the initial slot pool preserves every live
    member's scores."""
    rng, hours, mask, prices, ids, members = _fleet_universe(
        11, n_jobs=12, n_cfgs=16, n_members=3)
    batched = BatchedRankState(hours, mask, prices.copy(), ids,
                               capacity=2)     # force growth early
    live_members = {}
    live = prices.copy()

    def tick():
        k = int(rng.integers(1, len(ids)))
        cols = rng.choice(len(ids), k, replace=False)
        deltas = {ids[c]: float(live[c] * rng.uniform(0.5, 2.0))
                  for c in cols}
        batched.reprice(deltas)
        for c, p in deltas.items():
            live[int(c[1:])] = p

    batched.add_state("all", rows=members["all"])
    live_members["all"] = members["all"]
    tick()
    # added after a tick: must reflect the already-applied deltas
    batched.add_state("m0", rows=members["m0"])
    live_members["m0"] = members["m0"]
    tick()
    _assert_fleet_parity(batched, live_members, hours, mask, live, ids)
    # retire one, keep ticking: survivors stay in contract
    batched.retire_state("m0")
    del live_members["m0"]
    assert "m0" not in batched
    # serving a *retired* member is a typed rankable-nothing condition
    # (ISSUE 6: the service/daemon path journals a genuine rejection) —
    # a key that was never registered stays a plain ValueError
    with pytest.raises(NothingRankableError, match="retired"):
        batched.ranking("m0")
    with pytest.raises(NothingRankableError, match="retired"):
        batched.top_k("m0", 1)
    with pytest.raises(ValueError, match="unknown member"):
        batched.ranking("never-registered")
    tick()
    _assert_fleet_parity(batched, live_members, hours, mask, live, ids)
    # grow well past the starting capacity (2), reusing retired slots
    for i in range(7):
        rows = [int(r) for r in rng.choice(12, 3, replace=False)]
        batched.add_state(f"late{i}", rows=rows)
        live_members[f"late{i}"] = rows
    tick()
    _assert_fleet_parity(batched, live_members, hours, mask, live, ids)
    assert batched.n_active == len(live_members)


@needs_jax
def test_batched_retire_all_then_readd_reuses_slots():
    """ISSUE 8 satellite: a fleet whose members are all retired and
    then re-added must reuse the zero-masked slots, not double capacity
    — ``realloc_count`` is pinned across the cycle, and the revived
    member's scores bit-match a cold build at the same prices."""
    rng, hours, mask, prices, ids, members = _fleet_universe(
        13, n_jobs=10, n_cfgs=14, n_members=4)
    b = BatchedRankState(hours, mask, prices.copy(), ids, capacity=4)
    for key, rows in members.items():
        b.add_state(key, rows=rows)
    live = prices.copy()
    deltas = {ids[2]: 0.4, ids[9]: 11.0}
    b.reprice(deltas)
    for c, p in deltas.items():
        live[int(c[1:])] = p
    assert b.realloc_count == 0
    for key in list(members):
        b.retire_state(key)
    assert b.n_active == 0
    for key, rows in members.items():
        b.add_state(key, rows=rows)
    # the whole cycle reused the freed slots: no capacity doubling
    assert b.realloc_count == 0
    assert b.n_active == len(members)
    # the revived members bit-match a cold build at the live prices
    cold = BatchedRankState(hours, mask, live.copy(), ids)
    for key, rows in members.items():
        cold.add_state(key, rows=rows)
        assert np.array_equal(b.scores(key), cold.scores(key)), key
        assert b.ranking(key) == cold.ranking(key)
    # growth still happens (and is counted) for genuinely new members:
    # 4 live + 4 new overflows the 4-slot pool exactly once (4 -> 8)
    for i in range(4):
        b.add_state(f"extra{i}", rows=[0, 1])
    assert b.realloc_count == 1


@needs_jax
def test_batched_validates_members_and_deltas():
    rng, hours, mask, prices, ids, _ = _fleet_universe(3, n_jobs=4,
                                                       n_cfgs=6)
    jobs = [f"j{i}" for i in range(4)]
    b = BatchedRankState(hours, mask, prices, ids, job_ids=jobs)
    b.add_state("a", rows=[0, 1])
    with pytest.raises(ValueError, match="duplicate member"):
        b.add_state("a", rows=[2])
    with pytest.raises(ValueError, match="exactly one of"):
        b.add_state("b", rows=[0], jobs=["j0"])
    with pytest.raises(ValueError, match="exactly one of"):
        b.add_state("b")
    with pytest.raises(ValueError, match="unknown job id"):
        b.add_state("b", jobs=["ghost"])
    with pytest.raises(ValueError, match="out of range"):
        b.add_state("b", rows=[99])
    with pytest.raises(ValueError, match="duplicate rows"):
        b.add_state("b", rows=[1, 1])
    with pytest.raises(ValueError, match="unknown member"):
        b.retire_state("ghost")
    with pytest.raises(ValueError, match="unknown member"):
        b.top_k("ghost", 1)
    with pytest.raises(ValueError, match="unknown config id"):
        b.reprice({"ghost": 1.0})
    with pytest.raises(ValueError, match="non-positive"):
        b.reprice({ids[0]: -1.0})
    assert b.reprice({}) == 0
    # jobs= addressing resolves the same rows as rows=
    b.add_state("by-jobs", jobs=["j0", "j1"])
    assert b.ranking("by-jobs") == b.ranking("a")
    from repro.selector import NothingRankableError
    with pytest.raises(NothingRankableError):
        BatchedRankState(np.zeros((0, 2)), np.zeros((0, 2), dtype=bool),
                         np.asarray([1.0, 2.0]), ["a", "b"])


# --- hypothesis property half (skips quietly when hypothesis is absent) ------------

if HAVE_HYPOTHESIS:
    @st.composite
    def fleet_streams(draw):
        """A delta-stream universe plus a fleet of member row
        subsets."""
        jobs, cfgs, rt, prices, stream = draw(delta_streams())
        n_members = draw(st.integers(1, 4))
        members = {}
        for m in range(n_members):
            rows = draw(st.lists(st.integers(0, len(jobs) - 1),
                                 min_size=1, max_size=len(jobs),
                                 unique=True))
            members[f"m{m}"] = sorted(rows)
        return jobs, cfgs, rt, prices, stream, members

    @needs_jax
    @settings(max_examples=20, deadline=None)
    @given(fleet_streams())
    def test_batched_fleet_within_contract(data):
        """For any fleet of member states and any reprice stream, every
        batched tick matches per-state JaxRankState ticks and the cold
        numpy float64 rank within the contract."""
        jobs, cfgs, rt, prices, stream, members = data
        hours = np.asarray([[rt[(j, c)] for c in cfgs] for j in jobs])
        mask = np.ones_like(hours, dtype=bool)
        pv = np.asarray([prices[c] for c in cfgs])
        batched = BatchedRankState(hours, mask, pv.copy(), cfgs)
        refs = {}
        for key, rows in members.items():
            batched.add_state(key, rows=rows)
            refs[key] = JaxRankState(hours[rows], mask[rows], pv.copy(),
                                     cfgs)
        live = pv.copy()
        for deltas in stream:
            batched.reprice(deltas)
            for ref in refs.values():
                ref.reprice(deltas)
            for c, p in deltas.items():
                live[cfgs.index(c)] = p
            _assert_fleet_parity(batched, members, hours, mask, live,
                                 cfgs, refs)

    @needs_jax
    @settings(max_examples=15, deadline=None)
    @given(event_markets(), st.integers(1, 3))
    def test_batched_event_market_within_contract(market, n_members):
        """Event-bearing markets (discount/eviction boundary re-quote
        bursts) through the batched kernel stay within contract of the
        cold float64 rank for every member at every tick."""
        cfgs, base, events, seed, change_fraction, n_ticks, jobs, rt = \
            market
        hours = np.asarray([[rt[(j, c)] for c in cfgs] for j in jobs])
        mask = np.ones_like(hours, dtype=bool)
        live = np.asarray([base[c] for c in cfgs])
        members = {f"m{m}": list(range(m % len(jobs), len(jobs)))
                   for m in range(n_members)}
        batched = BatchedRankState(hours, mask, live.copy(), cfgs)
        for key, rows in members.items():
            batched.add_state(key, rows=rows)
        feed = _event_feed(base, events, seed, change_fraction)
        for t in range(n_ticks):
            batch = feed.poll(t)
            if not batch:
                continue
            batched.reprice({d.config_id: d.price for d in batch})
            for d in batch:
                live[cfgs.index(d.config_id)] = d.price
            _assert_fleet_parity(batched, members, hours, mask, live,
                                 cfgs)

    @needs_jax
    @settings(max_examples=15, deadline=None)
    @given(fleet_streams(), st.data())
    def test_batched_add_retire_mid_stream_property(data, extra):
        """Random add/retire schedules interleaved with the stream:
        surviving members always match the cold float64 rank."""
        jobs, cfgs, rt, prices, stream, members = data
        hours = np.asarray([[rt[(j, c)] for c in cfgs] for j in jobs])
        mask = np.ones_like(hours, dtype=bool)
        pv = np.asarray([prices[c] for c in cfgs])
        batched = BatchedRankState(hours, mask, pv.copy(), cfgs,
                                   capacity=1)
        pending = dict(members)
        live_members = {}
        live = pv.copy()
        for deltas in stream:
            if pending and extra.draw(st.booleans()):
                key, rows = pending.popitem()
                batched.add_state(key, rows=rows)
                live_members[key] = rows
            if len(live_members) > 1 and extra.draw(st.booleans()):
                key = extra.draw(st.sampled_from(sorted(live_members)))
                batched.retire_state(key)
                del live_members[key]
            batched.reprice(deltas)
            for c, p in deltas.items():
                live[cfgs.index(c)] = p
            _assert_fleet_parity(batched, live_members, hours, mask,
                                 live, cfgs)
else:
    @pytest.mark.skip(reason="hypothesis not installed (property half "
                             "of the batched parity suite)")
    def test_batched_parity_properties_skipped():
        pass  # pragma: no cover


# --- device-side top-k serving ------------------------------------------------------

def _universe_with_ties(n_jobs=5, n_cfgs=12, seed=2):
    """A universe whose last three profiled columns are exact clones —
    bit-equal scores on every backend, so the (score, catalog order)
    tie-break is actually exercised — plus one unprofiled column."""
    rng = np.random.default_rng(seed)
    hours = rng.uniform(0.05, 10.0, (n_jobs, n_cfgs))
    hours[:, n_cfgs - 2] = hours[:, n_cfgs - 3]
    hours[:, n_cfgs - 1] = hours[:, n_cfgs - 3]
    mask = np.ones((n_jobs, n_cfgs), dtype=bool)
    mask[:, 0] = False                               # never profiled
    prices = rng.uniform(0.5, 20.0, n_cfgs)
    prices[n_cfgs - 2] = prices[n_cfgs - 3]
    prices[n_cfgs - 1] = prices[n_cfgs - 3]
    ids = [f"c{i}" for i in range(n_cfgs)]
    return hours, mask, prices, ids


@pytest.mark.parametrize("k", [1, 3, None])          # None -> k = C
def test_numpy_top_k_is_head_of_ranking(k):
    hours, mask, prices, ids = _universe_with_ties()
    state = RankState(hours, mask, prices, ids)
    k = len(ids) if k is None else k
    assert state.top_k(k) == state.ranking()[:k]
    state.reprice({ids[3]: 0.01})
    assert state.top_k(k) == state.ranking()[:k]


@needs_jax
@pytest.mark.parametrize("k", [1, 3, None])
def test_jax_top_k_is_head_of_ranking(k):
    hours, mask, prices, ids = _universe_with_ties()
    state = JaxRankState(hours, mask, prices, ids)
    k = len(ids) if k is None else k
    assert state.top_k(k) == state.ranking()[:k]
    state.reprice({ids[3]: 0.01, ids[7]: 40.0})
    assert state.top_k(k) == state.ranking()[:k]


@needs_jax
@pytest.mark.parametrize("k", [1, 3, None])
def test_batched_top_k_is_head_of_ranking(k):
    hours, mask, prices, ids = _universe_with_ties()
    b = BatchedRankState(hours, mask, prices, ids)
    b.add_state("all", rows=list(range(hours.shape[0])))
    b.add_state("head", rows=[0, 1])
    k = len(ids) if k is None else k
    for key in ("all", "head"):
        assert b.top_k(key, k) == b.ranking(key)[:k]
        assert b.winner(key) == b.ranking(key)[0]
    b.reprice({ids[3]: 0.01})
    for key in ("all", "head"):
        assert b.top_k(key, k) == b.ranking(key)[:k]


def test_top_k_exact_ties_resolve_in_catalog_order():
    """The cloned-column ties must come back in catalog order from both
    the sorted ranking and every top-k path (ScoreContract tie
    discipline: equal scores break by catalog position)."""
    hours, mask, prices, ids = _universe_with_ties()
    C = len(ids)
    clones = [ids[C - 3], ids[C - 2], ids[C - 1]]
    state = RankState(hours, mask, prices, ids)
    ranked_ids = [r.config_id for r in state.ranking()]
    i = ranked_ids.index(clones[0])
    assert ranked_ids[i:i + 3] == clones
    assert [r.config_id for r in state.top_k(C)][i:i + 3] == clones
    if backend_available("jax"):
        jx = JaxRankState(hours, mask, prices, ids)
        assert [r.config_id for r in jx.top_k(C)][i:i + 3] == clones


def test_top_k_clamps_and_validates():
    hours, mask, prices, ids = _universe_with_ties()
    state = RankState(hours, mask, prices, ids)
    assert state.top_k(len(ids) + 50) == state.ranking()
    for bad in (0, -1, 1.5, True):
        with pytest.raises(ValueError, match="positive integer"):
            state.top_k(bad)
    if backend_available("jax"):
        jx = JaxRankState(hours, mask, prices, ids)
        assert jx.top_k(len(ids) + 50) == jx.ranking()
        with pytest.raises(ValueError, match="positive integer"):
            jx.top_k(0)


def test_top_k_unprofiled_configs_rank_last_with_inf():
    hours, mask, prices, ids = _universe_with_ties()
    state = RankState(hours, mask, prices, ids)
    full = state.top_k(len(ids))
    assert full[-1].config_id == ids[0]
    assert full[-1].score == float("inf")
    assert full[-1].mean_norm_cost == float("inf")


# --- ranking memoization (the ISSUE 5 freshness fix) --------------------------------

@needs_jax
def test_jax_ranking_memoized_until_next_tick():
    """The fix: ``JaxRankState.ranking()`` used to re-materialize (one
    device→host transfer + C-object build + host sort) on *every* call
    even when no tick had been applied — now it memoizes on the tick
    count, like the numpy state."""
    hours, mask, prices, ids = _universe_with_ties()
    state = JaxRankState(hours, mask, prices, ids)
    first = state.ranking()
    assert state.materializations == 1
    assert state.ranking() == first
    assert state.ranking() == first
    assert state.materializations == 1      # no re-materialization
    state.reprice({ids[2]: 0.5})
    assert state.materializations == 1      # reprice alone is lazy
    again = state.ranking()
    assert state.materializations == 2      # tick invalidated the memo
    assert again != first
    # the returned list is a fresh copy: callers cannot corrupt the memo
    again.reverse()
    assert state.ranking() == list(reversed(again))
    assert state.materializations == 2


def test_numpy_ranking_memoized_until_next_tick():
    hours, mask, prices, ids = _universe_with_ties()
    state = RankState(hours, mask, prices, ids)
    first = state.ranking()
    state.ranking()
    assert state.materializations == 1
    state.reprice({ids[2]: 0.5})
    assert state.ranking() != first
    assert state.materializations == 2


@needs_jax
def test_batched_ranking_memoized_per_member():
    hours, mask, prices, ids = _universe_with_ties()
    b = BatchedRankState(hours, mask, prices, ids)
    b.add_state("a", rows=[0, 1, 2])
    b.add_state("b", rows=[3, 4])
    b.ranking("a"); b.ranking("a"); b.ranking("b")
    assert b.materializations == 2          # one per member, not per call
    b.reprice({ids[2]: 0.5})
    b.ranking("a"); b.ranking("a")
    assert b.materializations == 3


# --- service-level fleet serving ----------------------------------------------------

def _fleet_service(backend, serve_top_k=None, n_cfgs=16, seed=1):
    rng = np.random.default_rng(seed)
    ids = [f"c{i}" for i in range(n_cfgs)]
    store = ProfilingStore(config_ids=ids)
    for j in range(8):
        klass = JobClass.A if j % 2 else JobClass.B
        for c in ids:
            store.add(f"j{j}", c, float(rng.uniform(0.1, 5.0)),
                      job_class=klass, group=f"g{j % 4}")
    table = PriceTable({c: float(rng.uniform(1.0, 20.0)) for c in ids})
    return SelectionService(IdentityCatalog(ids), store, table,
                            backend=backend, serve_top_k=serve_top_k)


@needs_jax
def test_service_jax_batched_backend_one_dispatch_per_tick():
    """A jax_batched service stacks every live (class, exclusion)
    ranking into one BatchedRankState: a tick refreshes the whole fleet
    in ONE kernel dispatch, and every served ranking stays within
    contract of a numpy reference service."""
    svc = _fleet_service("jax_batched")
    ref = _fleet_service("numpy")
    # four live selections: two classes x two exclusion variants
    selections = [("j1", None), ("j2", None), ("j1", ("g2",)),
                  ("j2", ("g3",))]
    for job, excl in selections:
        d = svc.submit(job, exclude_groups=excl)
        r = ref.submit(job, exclude_groups=excl)
        assert_within_contract(list(d.ranking), list(r.ranking), CONTRACT)
    assert svc._batched is not None and svc._batched.n_active == 4
    deltas = {f"c{i}": float(0.5 + i) for i in range(0, 16, 3)}
    assert svc.reprice(deltas) == 4          # whole fleet refreshed...
    assert svc.reprice_dispatches == 1       # ...in one dispatch
    ref.reprice(deltas)
    for job, excl in selections:
        assert_within_contract(
            list(svc.submit(job, exclude_groups=excl).ranking),
            list(ref.submit(job, exclude_groups=excl).ranking), CONTRACT)
    # second tick: still one dispatch per tick
    svc.reprice({"c1": 9.0})
    assert svc.reprice_dispatches == 2


@pytest.mark.parametrize("backend", ["numpy", "jax", "jax_batched",
                                     "jax_pallas"])
def test_service_top_k_decision_matches_full_serving(backend):
    """A top-k-served Decision carries the same winner, score and $/h
    as a full-ranking Decision from an identically-priced service — the
    head IS the head, on every backend."""
    if not backend_available(backend):
        pytest.skip("jax not installed")
    svc = _fleet_service(backend, serve_top_k=3)
    ref = _fleet_service(backend)
    d = svc.submit("j1")
    f = ref.submit("j1")
    assert d.served_via == "top_k" and f.served_via == "ranking"
    assert len(d.ranking) == 3 and len(f.ranking) == len(ref.catalog.ids())
    assert d.config_id == f.config_id
    assert d.ranking[0] == f.ranking[0]
    assert d.hourly_cost == f.hourly_cost
    assert tuple(d.ranking) == tuple(f.ranking[:3])
    # per-submission override beats the service default
    assert len(ref.submit("j1", top_k=2).ranking) == 2
    assert ref.submit("j1", top_k=2).served_via == "top_k"
    assert len(svc.submit("j1", top_k=5).ranking) == 5


def test_service_rank_head_caches_and_reprices():
    """Heads are cached per (tag, selection, k), refresh through the
    incremental path on ticks, and reuse a cached full ranking when one
    exists."""
    svc = _fleet_service("numpy")
    head, from_cache = svc.rank_head(job_class=JobClass.A, k=2)
    assert not from_cache and len(head) == 2
    again, from_cache = svc.rank_head(job_class=JobClass.A, k=2)
    assert from_cache and again == head
    # a different depth is its own cached head
    h3, from_cache = svc.rank_head(job_class=JobClass.A, k=3)
    assert from_cache                      # live state serves it
    assert h3[:2] == head
    # the full ranking's head agrees
    full = svc.rank(job_class=JobClass.A)
    assert tuple(full[:3]) == h3
    svc.reprice({"c0": 0.123})
    h_after, from_cache = svc.rank_head(job_class=JobClass.A, k=2)
    assert from_cache                      # incremental refresh, no rebuild
    assert h_after == tuple(svc.rank(job_class=JobClass.A)[:2])
    with pytest.raises(ValueError, match="positive integer"):
        svc.rank_head(job_class=JobClass.A, k=0)


def test_service_serve_top_k_validated_at_construction():
    with pytest.raises(ValueError, match="serve_top_k"):
        _fleet_service("numpy", serve_top_k=0)
    with pytest.raises(ValueError, match="serve_top_k"):
        _fleet_service("numpy", serve_top_k=-3)
    with pytest.raises(ValueError, match="serve_top_k"):
        _fleet_service("numpy", serve_top_k=True)


@needs_jax
def test_batched_service_survives_out_of_band_table_apply():
    """An out-of-band PriceTable.apply desyncs the shared batched
    universe: the next tick must drop and cold-rebuild it rather than
    serve quotes it never saw (the PR-2 review invariant, extended to
    the fleet)."""
    svc = _fleet_service("jax_batched")
    ref = _fleet_service("numpy")
    svc.submit("j1"); ref.submit("j1")
    svc.price_source.apply({"c2": 0.333})
    ref.price_source.apply({"c2": 0.333})
    deltas = {"c5": 7.7}
    assert svc.reprice(deltas) == 0          # fleet dropped, not repriced
    ref.reprice(deltas)
    assert_within_contract(list(svc.submit("j1").ranking),
                           list(ref.submit("j1").ranking), CONTRACT)
