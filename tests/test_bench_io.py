"""The shared bench I/O contract (ISSUE 10 satellite).

`benchmarks/_bench_io.py` now owns the gate-check/exit-nonzero logic
that ``market_bench``/``serve_bench``/``obs_bench``/``turbulence_bench``
previously copy-pasted: this pins the behavior CI's perf jobs depend on
— a failed gated claim lists itself on stderr and exits the process
with status 1, and a clean run is a silent no-op.
"""
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "benchmarks"))
from _bench_io import BenchRows, Gates, check_gates  # noqa: E402


def test_gates_collect_only_failed_claims():
    gates = Gates()
    gates.gate("row_a", "claim held", True)
    assert gates.failures == []
    gates.gate("row_b", "p50 under budget", False)
    gates.gate("row_c", "audit passes", False)
    assert gates.failures == ["row_b: p50 under budget",
                              "row_c: audit passes"]


def test_check_gates_is_a_noop_when_everything_held(capsys):
    check_gates([])          # must not raise or print
    out = capsys.readouterr()
    assert out.err == "" and out.out == ""


def test_check_gates_exits_nonzero_listing_every_failure(capsys):
    with pytest.raises(SystemExit) as exc:
        check_gates(["row_b: p50 under budget", "row_c: audit passes"])
    assert exc.value.code == 1
    err = capsys.readouterr().err
    assert "GATED CLAIMS FAILED:" in err
    assert "row_b: p50 under budget" in err
    assert "row_c: audit passes" in err


def test_benchrows_extra_fields_land_in_json_not_csv(tmp_path, capsys,
                                                     monkeypatch):
    path = tmp_path / "BENCH_x.json"
    monkeypatch.setenv("BENCH_X_JSON", str(path))
    rows = BenchRows("BENCH_X_JSON", "unused.json")
    rows.emit("point_a", 12.34, "ok=True",
              curve=[{"level": 0.0, "mean_deviation": 0.05}])
    rows.emit("point_b", 5.0, "ok=True")
    rows.write_json()
    # the CSV line is the stable three-column shape, extras JSON-only
    out = capsys.readouterr().out.splitlines()
    assert out[0] == "point_a,12.3,ok=True"
    assert "curve" not in out[0]
    data = json.loads(path.read_text())
    assert data[0]["curve"] == [{"level": 0.0, "mean_deviation": 0.05}]
    assert data[0]["us_per_call"] == 12.3
    assert "curve" not in data[1]
