"""Tests for the replay harness (repro.market.replay + dynamic evaluation).

Covers the ISSUE 3 acceptance surface: recorded-feed CSV round-trips and
malformed-input handling, the v2 decision-journal schema (golden file),
JournalReplayer's bit-identical audit (including tamper detection and the
out-of-band-mutation case it exists to catch), and the
deviation-from-optimal report under dynamic prices.

Regenerate the golden journal after a *deliberate* schema change with

    PYTHONPATH=src python tests/test_replay.py --regen-golden

and add a migration note to DESIGN.md §8 in the same commit.
"""
import json
import os

import numpy as np
import pytest

from repro.core.costmodel import TpuPriceModel
from repro.core.evaluate import dynamic_evaluation
from repro.core.tpu_flora import MeshOption, WorkloadRecord, make_service
from repro.core.trace import JobClass
from repro.market import (JournalReplayer, MarketEvent, PriceFeed,
                          RecordedPriceFeed, SelectionDaemon,
                          SimulatedSpotFeed, Submission, Tick, record_feed)
from repro.market.daemon import JOURNAL_VERSION
from repro.selector import (IdentityCatalog, PriceTable, ProfilingStore,
                            SelectionService)

FIXTURES = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "fixtures")
GOLDEN_JOURNAL = os.path.join(FIXTURES, "decision_journal_v2.golden.jsonl")
GOLDEN_JOURNAL_JAX = os.path.join(FIXTURES,
                                  "decision_journal_v2_jax.golden.jsonl")
GOLDEN_JOURNAL_TOPK = os.path.join(
    FIXTURES, "decision_journal_v2_topk.golden.jsonl")
PRICE_FIXTURE = os.path.join(os.path.dirname(FIXTURES), "..", "examples",
                             "data", "gcp_spot_prices.csv")


# --- shared universes -------------------------------------------------------------

MESH_OPTIONS = [
    MeshOption("dp256xtp1", "v5e", 256, (256, 1), ("data", "model")),
    MeshOption("dp16xtp16", "v5e", 256, (16, 16), ("data", "model")),
    MeshOption("v5p-dp16xtp16", "v5p", 256, (16, 16), ("data", "model")),
]
SPEED = {"dp256xtp1": {"train_4k": 1.0, "decode_32k": 4.0},
         "dp16xtp16": {"train_4k": 1.5, "decode_32k": 1.0},
         "v5p-dp16xtp16": {"train_4k": 0.8, "decode_32k": 0.55}}


def live_service(backend=None) -> SelectionService:
    recs = [WorkloadRecord(arch=a, shape=s, mesh=m, step_seconds=v)
            for a in ("a1", "a2")
            for m, shapes in SPEED.items() for s, v in shapes.items()]
    svc = make_service(MESH_OPTIONS, recs, TpuPriceModel("ondemand"),
                       backend=backend)
    svc.set_price_source(PriceTable.from_catalog(svc.catalog,
                                                 TpuPriceModel("ondemand")))
    return svc


def synth_service(n_jobs=6, n_cfgs=12, seed=0,
                  backend=None) -> SelectionService:
    """Identity-catalog universe with correlated per-class runtimes."""
    rng = np.random.default_rng(seed)
    ids = [f"c{i}" for i in range(n_cfgs)]
    speed = {JobClass.A: rng.uniform(0.5, 3.0, n_cfgs),
             JobClass.B: rng.uniform(0.5, 3.0, n_cfgs)}
    store = ProfilingStore(config_ids=ids)
    for j in range(n_jobs):
        klass = JobClass.A if j % 2 else JobClass.B
        scale = rng.uniform(0.2, 2.0)
        for c in range(n_cfgs):
            store.add(f"j{j}", ids[c],
                      float(scale * speed[klass][c]
                            * rng.lognormal(0.0, 0.05)),
                      job_class=klass, group=None)
    table = PriceTable({c: float(rng.uniform(1.0, 20.0)) for c in ids})
    return SelectionService(IdentityCatalog(ids), store, table,
                            backend=backend)


# --- recorded feed: round-trip ----------------------------------------------------

def sim_feed(seed=5, **kw):
    base = {"a": 2.0, "b": 5.5, "c": 0.75, 7: 12.0}   # int id round-trips too
    kw.setdefault("change_fraction", 0.5)
    return SimulatedSpotFeed(base, seed=seed, **kw)


def test_record_feed_roundtrip_identical_stream():
    """record_feed(sim) -> RecordedPriceFeed reproduces the identical tick
    stream: prices (exact floats), ordering, id types, event boundaries."""
    events = [MarketEvent("us-central1", 3, 4, factor=0.5, kind="discount"),
              MarketEvent("europe-west3", 8, 2, factor=3.0,
                          kind="eviction")]
    text = record_feed(sim_feed(events=events), 15)
    replay = RecordedPriceFeed.loads(text)
    assert isinstance(replay, PriceFeed)
    assert replay.ticks == 15
    fresh = sim_feed(events=events)
    for t in range(15):
        assert replay.poll(t) == fresh.poll(t)
    # ids keep their types through the JSON encoding
    assert {type(c) for c in replay.config_ids()} <= {str, int}
    assert any(isinstance(c, int) for c in replay.config_ids())


def test_record_feed_rerecord_is_byte_identical():
    text = record_feed(sim_feed(), 12)
    again = record_feed(RecordedPriceFeed.loads(text), 12)
    assert again == text


def test_record_feed_mid_stream_start_stays_loadable():
    """Regression: the ticks= header records the horizon (last tick + 1),
    so a recording that starts mid-stream loads and replays at its
    absolute tick indices."""
    source = sim_feed()
    for t in range(5):
        source.poll(t)                        # advance past the prefix
    tail = record_feed(source, 5, start=5)        # ticks 5-9
    feed = RecordedPriceFeed.loads(tail)
    assert feed.ticks == 10
    assert feed.poll(0) == () and feed.poll(4) == ()
    fresh = sim_feed()
    for t in range(5):
        fresh.poll(t)
    for t in range(5, 10):
        assert feed.poll(t) == fresh.poll(t)
    # re-recording over the full horizon reproduces the bytes (the
    # leading quiet ticks emit no rows)
    assert record_feed(feed, 10) == tail


def test_recorded_feed_quiet_past_the_recording():
    feed = RecordedPriceFeed.loads(record_feed(sim_feed(), 5))
    assert feed.poll(5) == () and feed.poll(999) == ()
    assert len(list(feed.stream())) == 5


def test_recorded_feed_drives_daemon_deterministically():
    """The same recording yields byte-identical journals — the
    reproducible-fixture contract that motivates recording at all."""
    from repro.market import synthetic_stream
    text = record_feed(SimulatedSpotFeed(
        {c: 10.0 + i for i, c in
         enumerate(f"c{i}" for i in range(12))}, seed=3,
        change_fraction=0.4), 20)

    def run():
        svc = synth_service()
        daemon = SelectionDaemon(svc, RecordedPriceFeed.loads(text))
        daemon.run(synthetic_stream([f"j{i}" for i in range(6)], 120,
                                    seed=1, tick_fraction=0.2))
        return daemon.journal_dump()

    assert run() == run()


# --- recorded feed: malformed input -----------------------------------------------

def good_csv():
    return record_feed(sim_feed(), 4)


@pytest.mark.parametrize("mutate,match", [
    (lambda t: t.replace("# repro.market.recorded-price-feed",
                         "# something-else"), "not a recorded price feed"),
    (lambda t: t.replace(" v1 ", " v9 "), "version"),
    (lambda t: "\n".join(["no magic"] + t.splitlines()[1:]),
     "not a recorded price feed"),
    (lambda t: t.replace("tick,config_id,price", "a,b,c"),
     "expected header"),
])
def test_malformed_feed_headers_raise(mutate, match):
    with pytest.raises(ValueError, match=match):
        RecordedPriceFeed.loads(mutate(good_csv()))


def row(csv_row: str) -> str:
    head = good_csv().splitlines()[:2]
    return "\n".join(head + [csv_row]) + "\n"


@pytest.mark.parametrize("bad,match", [
    ('0,7', "expected 3 fields"),
    ('0,7,1.0,extra', "expected 3 fields"),
    ('x,7,1.0', "not an integer"),
    ('-1,7,1.0', "negative tick"),
    ('0,7,zzz', "not a number"),
    ('0,7,-3.0', "non-positive"),
    ('0,7,0.0', "non-positive"),
    ('0,7,inf', "non-finite"),
    ('0,not-json,1.0', "not valid JSON"),
    ('0,"[1, 2]",1.0', "not hashable"),
])
def test_malformed_feed_rows_raise_with_line_numbers(bad, match):
    """A malformed row must raise, naming its line — never silently skip."""
    with pytest.raises(ValueError, match=match) as e:
        RecordedPriceFeed.loads(row(bad))
    assert "line 3" in str(e.value)


def test_out_of_order_ticks_raise():
    head = good_csv().splitlines()[:2]
    text = "\n".join(head + ["5,7,1.0", "2,7,2.0"]) + "\n"
    with pytest.raises(ValueError, match="out of order") as e:
        RecordedPriceFeed.loads(text)
    assert "line 4" in str(e.value)


def test_empty_feed_file_raises_with_line_number():
    """Satellite (ISSUE 4): an empty file is a malformed recording, not
    an empty market — it must raise, naming line 1."""
    with pytest.raises(ValueError, match="line 1.*empty"):
        RecordedPriceFeed.loads("")


@pytest.mark.parametrize("truncated", [
    '0,"c0",',          # cut mid-price (trailing comma survives)
    '0,"c0',            # cut mid-id (unterminated quote)
    '0,',               # cut after the tick
])
def test_truncated_final_row_raises_with_line_number(truncated):
    """Satellite (ISSUE 4): a recording cut off mid-row (partial write,
    truncated download) must raise at its line, never load the prefix
    silently."""
    with pytest.raises(ValueError) as e:
        RecordedPriceFeed.loads(row(truncated))
    assert "line 3" in str(e.value)


def test_duplicate_tick_quote_raises_with_line_number():
    """Satellite (ISSUE 4): two quotes for one config at one tick are
    ambiguous (which is 'the' epoch price depends on application order)
    — the load must refuse, naming the duplicate's line."""
    head = good_csv().splitlines()[:2]
    text = "\n".join(head + ['2,7,1.0', '2,8,2.0', '2,7,3.0']) + "\n"
    with pytest.raises(ValueError, match="duplicate quote") as e:
        RecordedPriceFeed.loads(text)
    assert "line 5" in str(e.value)
    # the same duplicate is rejected at construction time too
    from repro.market import PriceDelta
    with pytest.raises(ValueError, match="duplicate quote"):
        RecordedPriceFeed({0: [PriceDelta("a", 1.0), PriceDelta("a", 2.0)]})
    # distinct configs in one tick batch stay legal (that IS a batch)
    feed = RecordedPriceFeed.loads(
        "\n".join(head + ['2,7,1.0', '2,8,2.0']) + "\n")
    assert len(feed.poll(2)) == 2


# --- journal schema v2: golden files ----------------------------------------------

def golden_daemon(backend="numpy", serve_top_k=None) -> SelectionDaemon:
    # the goldens pin one journal layout per backend, so the backend is
    # explicit here — never FLORA_RANK_BACKEND-resolved
    svc = live_service(backend=backend)
    svc.serve_top_k = serve_top_k
    feed = SimulatedSpotFeed(dict(svc.price_source.items()), seed=6,
                             change_fraction=0.6)
    return SelectionDaemon(svc, feed)


GOLDEN_STREAM = [
    Submission("decode_32k"), Tick(), Submission("train_4k"), Tick(),
    Submission("decode_32k"),
    Submission("decode_32k", exclude_groups=("a1", "a2")),   # rejection
    Tick(), Submission("train_4k"),
]


def test_journal_schema_golden_file():
    """Pins the versioned-JSONL journal layout byte-for-byte.  If this
    fails you changed the journal schema: bump JOURNAL_VERSION, add a
    migration note to DESIGN.md §8, and regenerate the golden with
    ``PYTHONPATH=src python tests/test_replay.py --regen-golden`` — all
    in the same commit."""
    daemon = golden_daemon()
    daemon.run(GOLDEN_STREAM)
    with open(GOLDEN_JOURNAL) as f:
        assert daemon.journal_dump() == f.read()


def test_journal_golden_file_jax_backend():
    """Satellite (ISSUE 4): the journal layout of a jax-backed daemon is
    pinned alongside the numpy golden.  The header stamps
    ``"backend": "jax"`` so replays know the tolerance audit mode
    applies.

    The pin mirrors the backend's own contract: every record is
    compared field-for-field exactly — kinds, seqs, winners, $/h,
    epochs, deltas, the header — *except* the float32-derived ``score``
    values, which are held to the jax ``ScoreContract`` instead of
    their bytes (pyproject pins only ``jax>=0.4``; float32 XLA
    reductions have no cross-release byte-stability guarantee, unlike
    the float64 numpy golden).  Regenerate together with the numpy
    golden (same command, same commit discipline)."""
    pytest.importorskip("jax")
    from repro.selector import score_contract
    daemon = golden_daemon(backend="jax")
    daemon.run(GOLDEN_STREAM)
    header, records = SelectionDaemon.loads_journal(daemon.journal_dump())
    assert header["backend"] == "jax"
    with open(GOLDEN_JOURNAL_JAX) as f:
        g_header, g_records = SelectionDaemon.loads_journal(f.read())
    assert header == g_header
    assert len(records) == len(g_records)
    contract = score_contract("jax")
    for rec, golden in zip(records, g_records):
        assert {k: v for k, v in rec.items() if k != "score"} == \
            {k: v for k, v in golden.items() if k != "score"}
        assert ("score" in rec) == ("score" in golden)
        if "score" in golden:
            assert contract.scores_match(rec["score"], golden["score"])


def test_journal_golden_file_topk_serving():
    """Satellite (ISSUE 5): the journal layout of a batched daemon
    serving every decision via device-side top-k (DESIGN.md §10) is
    pinned alongside the other goldens.  The header stamps
    ``"backend": "jax_batched"`` and decision records carry the
    additive ``"served_via": "top_k"`` field (absent on full-ranking
    journals, so the numpy/jax goldens keep their bytes).

    Pinned with the jax golden's discipline: every field exact except
    the float32-derived ``score``, held to the ScoreContract instead
    of its bytes.  Regenerate together with the other goldens
    (``--regen-golden``, same commit discipline)."""
    pytest.importorskip("jax")
    from repro.selector import score_contract
    daemon = golden_daemon(backend="jax_batched", serve_top_k=2)
    daemon.run(GOLDEN_STREAM)
    header, records = SelectionDaemon.loads_journal(daemon.journal_dump())
    assert header["backend"] == "jax_batched"
    assert all(r["served_via"] == "top_k" for r in records
               if r["kind"] == "decision")
    with open(GOLDEN_JOURNAL_TOPK) as f:
        g_header, g_records = SelectionDaemon.loads_journal(f.read())
    assert header == g_header
    assert len(records) == len(g_records)
    contract = score_contract("jax_batched")
    for rec, golden in zip(records, g_records):
        assert {k: v for k, v in rec.items() if k != "score"} == \
            {k: v for k, v in golden.items() if k != "score"}
        assert ("score" in rec) == ("score" in golden)
        if "score" in golden:
            assert contract.scores_match(rec["score"], golden["score"])


def test_topk_served_decision_journals_identical_fields():
    """Satellite (ISSUE 5): a top-k-served Decision journals the same
    winner/score/$-per-hour fields as a full-ranking decision — the
    journal record is byte-identical on the numpy backend except for
    the additive ``served_via`` stamp.  (Head serving changes how much
    ranking tail the Decision carries, never what it decides.)"""
    full = golden_daemon()
    full.run(GOLDEN_STREAM)
    topk = golden_daemon(serve_top_k=1)
    topk.run(GOLDEN_STREAM)
    _, full_recs = SelectionDaemon.loads_journal(full.journal_dump())
    _, topk_recs = SelectionDaemon.loads_journal(topk.journal_dump())
    assert len(full_recs) == len(topk_recs)
    decisions = 0
    for f, t in zip(full_recs, topk_recs):
        if f["kind"] != "decision":
            assert f == t
            continue
        decisions += 1
        assert t.pop("served_via") == "top_k"
        assert "served_via" not in f
        assert f == t            # winner, score, $/h, epoch: identical
    assert decisions > 0
    # the replay layer surfaces the stamp (defaulting absent to full)
    store_svc = golden_daemon(serve_top_k=1)
    store_svc.run(GOLDEN_STREAM)
    rep = JournalReplayer(store_svc.service.store,
                          store_svc.journal_dump())
    assert all(d.served_via == "top_k" for d in rep.decisions())
    assert rep.audit().ok
    rep_full = JournalReplayer(full.service.store, full.journal_dump())
    assert all(d.served_via == "ranking" for d in rep_full.decisions())


def test_journal_v2_is_self_contained():
    daemon = golden_daemon()
    daemon.run(GOLDEN_STREAM)
    header, records = SelectionDaemon.loads_journal(daemon.journal_dump())
    assert header["version"] == JOURNAL_VERSION == 2
    assert header["backend"] == "numpy"
    assert [c for c, _ in header["prices"]] == header["catalog"]
    assert all(p > 0 for _, p in header["prices"])
    for rec in records:
        if rec["kind"] == "tick":
            assert len(rec["applied"]) == rec["deltas"] > 0
        elif rec["kind"] == "decision":
            assert rec["score"] > 0
            assert isinstance(rec["exclude_groups"], list)
        elif rec["kind"] == "rejected":
            assert rec["job_class"] in ("A", "B", None)
            assert isinstance(rec["exclude_groups"], list)


def test_v1_journals_rejected_with_migration_pointer():
    old = json.dumps({"format": "repro.market.decision-journal",
                      "version": 1, "catalog": []})
    with pytest.raises(ValueError, match="DESIGN.md"):
        SelectionDaemon.loads_journal(old + "\n")


# --- JournalReplayer: the consistency audit ---------------------------------------

def run_daemon(svc=None, n_events=200, seed=2, change_fraction=0.3,
               events=()):
    svc = svc or synth_service()
    feed = SimulatedSpotFeed(dict(svc.price_source.items()), seed=seed,
                             change_fraction=change_fraction,
                             events=list(events))
    daemon = SelectionDaemon(svc, feed)
    from repro.market import synthetic_stream
    daemon.run(synthetic_stream(svc.store.job_ids, n_events, seed=seed,
                                tick_fraction=0.25))
    return daemon


def test_audit_passes_on_clean_run():
    daemon = run_daemon(events=[MarketEvent("us-central1", 2, 5, 0.5),
                                MarketEvent("asia-east1", 10, 4, 2.0,
                                            "eviction")])
    audit = JournalReplayer(daemon.service.store,
                            daemon.journal_dump()).audit()
    assert audit.ok
    assert audit.decisions == daemon.stats.decisions > 0
    assert audit.ticks == daemon.stats.epochs > 0
    assert audit.rejected == daemon.stats.rejected


def test_audit_detects_tampered_selection():
    daemon = run_daemon()
    header, records = SelectionDaemon.loads_journal(daemon.journal_dump())
    victim = next(r for r in records if r["kind"] == "decision")
    other = next(c for c in header["catalog"] if c != victim["config"])
    victim["config"] = other
    audit = JournalReplayer(daemon.service.store, (header, records)).audit()
    assert not audit.ok
    fields = {m.field for m in audit.mismatches}
    assert "config" in fields
    assert all(m.seq == victim["seq"] for m in audit.mismatches)


def test_audit_detects_single_ulp_score_drift():
    # a float64 ulp is only a mismatch under the numpy bit-identity
    # contract — pin the backend so FLORA_RANK_BACKEND=jax (CI's matrix)
    # doesn't soften this audit into tolerance mode
    daemon = run_daemon(svc=synth_service(backend="numpy"))
    header, records = SelectionDaemon.loads_journal(daemon.journal_dump())
    victim = next(r for r in records if r["kind"] == "decision")
    victim["score"] = np.nextafter(victim["score"], np.inf)
    audit = JournalReplayer(daemon.service.store, (header, records)).audit()
    assert [m.field for m in audit.mismatches] == ["score"]


def test_tolerance_audit_surfaces_drift_and_bounds_it():
    """Satellite (ISSUE 4): a jax-backed journal audits in tolerance
    mode — float32 score divergence from the cold float64 re-rank is
    surfaced as ``drift`` (not a failure) while anything beyond the
    ScoreContract still fails the audit."""
    pytest.importorskip("jax")
    from repro.selector import score_contract
    daemon = run_daemon(svc=synth_service(backend="jax"))
    replayer = JournalReplayer(daemon.service.store, daemon.journal_dump())
    assert replayer.backend == "jax"
    audit = replayer.audit()
    assert audit.ok, audit.mismatches[:3]
    assert audit.contract == score_contract("jax")
    # float32 scores against float64 cold re-ranks: drift is expected
    # and must be *surfaced*, not silently absorbed
    assert any(d.field == "score-drift" for d in audit.drift)
    for d in audit.drift:
        if d.field == "score-drift":
            assert audit.contract.scores_match(d.journaled, d.replayed)
            assert d.journaled != d.replayed
    # beyond-contract tamper still fails, tolerance notwithstanding
    header, records = SelectionDaemon.loads_journal(daemon.journal_dump())
    victim = next(r for r in records if r["kind"] == "decision")
    victim["score"] *= 1.01           # 1% >> rel_tol
    bad = JournalReplayer(daemon.service.store, (header, records)).audit()
    assert not bad.ok
    assert any(m.field == "score" for m in bad.mismatches)


def test_audit_detects_dropped_tick_deltas():
    """Drop, from a tick record, the re-quote of a config that a later
    decision selected: the reconstructed quote then disagrees with the
    journaled $/h (the feed only emits *changed* prices, so the removed
    delta necessarily differs from the price before it)."""
    daemon = run_daemon()
    header, records = SelectionDaemon.loads_journal(daemon.journal_dump())
    tampered = False
    for i, rec in enumerate(records):
        if tampered or rec["kind"] != "decision":
            continue
        for tick in reversed(records[:i]):      # latest tick before it
            if tick["kind"] == "tick" and any(
                    c == rec["config"] for c, _ in tick["applied"]):
                tick["applied"] = [(c, p) for c, p in tick["applied"]
                                   if c != rec["config"]]
                tampered = True
                break
    assert tampered, "stream never repriced a selected config"
    audit = JournalReplayer(daemon.service.store, (header, records)).audit()
    assert not audit.ok


def test_audit_catches_out_of_band_price_mutation():
    """The audit's raison d'etre: a price applied to the table *behind
    the journal's back* makes later journaled decisions unexplainable
    from the journal alone — the replay must flag them, not absorb
    them."""
    svc = synth_service()
    feed = SimulatedSpotFeed(dict(svc.price_source.items()), seed=4,
                             change_fraction=0.5)
    daemon = SelectionDaemon(svc, feed)
    daemon.handle(Submission("j1"))
    daemon.handle(Tick())
    svc.price_source.apply({"c0": 0.0001})        # out-of-band, unjournaled
    daemon.handle(Submission("j1"))               # decided at secret prices
    audit = JournalReplayer(svc.store, daemon.journal_dump()).audit()
    assert not audit.ok


def test_audit_flags_spurious_rejections():
    """A journaled rejection for a (class, exclusions) that cold-ranks to
    a valid winner means the daemon silently served nothing for a
    rankable job — the audit must flag it, not count it as routine."""
    daemon = run_daemon()
    header, records = SelectionDaemon.loads_journal(daemon.journal_dump())
    victim = next(r for r in records if r["kind"] == "decision")
    fake = {"kind": "rejected", "seq": victim["seq"], "job": victim["job"],
            "job_class": victim["job_class"],
            "exclude_groups": victim["exclude_groups"],
            "price_epoch": victim["price_epoch"]}
    records[records.index(victim)] = fake
    audit = JournalReplayer(daemon.service.store, (header, records)).audit()
    assert not audit.ok
    assert any(m.field == "rejected" for m in audit.mismatches)
    # a genuine rejection (exclusions empty the class) still audits clean
    svc = live_service()
    feed = SimulatedSpotFeed(dict(svc.price_source.items()), seed=1,
                             change_fraction=0.3)
    d2 = SelectionDaemon(svc, feed)
    d2.handle(Submission("decode_32k", exclude_groups=("a1", "a2")))
    d2.handle(Submission("decode_32k"))
    audit2 = JournalReplayer(svc.store, d2.journal_dump()).audit()
    assert audit2.ok and audit2.rejected == 1 and audit2.decisions == 1


def test_record_feed_rejects_unloadable_quotes_at_capture_time():
    """A feed emitting a non-finite quote must fail the capture, not
    produce a CSV that every later load rejects."""
    class BadFeed:
        def poll(self, tick):
            from repro.market import PriceDelta
            return (PriceDelta("a", float("inf")),)

    with pytest.raises(ValueError, match="non-finite"):
        record_feed(BadFeed(), 1)
    from repro.market import PriceDelta
    with pytest.raises(ValueError, match="non-finite"):
        RecordedPriceFeed({0: [PriceDelta("a", float("nan"))]})


def test_audit_catches_drifted_trace():
    daemon = run_daemon()
    store = daemon.service.store
    # post-hoc re-profile: c0 becomes j0's runaway best, renormalizing
    # every class-B score the journaled decisions were computed from
    store.add("j0", "c0", 1e-6, job_class=JobClass.B)
    audit = JournalReplayer(store, daemon.journal_dump()).audit()
    assert not audit.ok


def test_replayer_requires_self_contained_journal():
    with pytest.raises(ValueError, match="price snapshot"):
        JournalReplayer(ProfilingStore(), ({"catalog": []}, []))


def test_replayed_decisions_reconstruct_epochs():
    daemon = run_daemon()
    replayer = JournalReplayer(daemon.service.store, daemon.journal_dump())
    decisions = replayer.decisions()
    assert len(decisions) == daemon.stats.decisions
    epochs = [d.price_epoch for d in decisions]
    assert epochs == sorted(epochs)
    # the last decision's reconstructed prices equal the live table
    final = decisions[-1].prices
    for c in daemon.service.catalog.ids():
        assert final[c] == daemon.service.price_source[c]


# --- dynamic evaluation -----------------------------------------------------------

class _D:
    """Duck-typed ReplayedDecision for hand-built evaluation checks."""

    def __init__(self, seq, job_id, config_id, prices, price_epoch=0,
                 job_class=None):
        self.seq, self.job_id, self.config_id = seq, job_id, config_id
        self.prices, self.price_epoch = prices, price_epoch
        self.job_class = job_class


def test_dynamic_evaluation_hand_computed():
    store = ProfilingStore(config_ids=["x", "y"])
    store.add("j", "x", 1.0)                     # 1 h on x
    store.add("j", "y", 3.0)                     # 3 h on y
    base = {"x": 10.0, "y": 2.0}                 # static oracle: y (6 < 10)
    moved = {"x": 4.0, "y": 2.0}                 # epoch oracle: x (4 < 6)
    ev = dynamic_evaluation(store, [_D(1, "j", "x", moved)], ["x", "y"],
                            base)
    (o,) = ev.outcomes
    assert o.realized_cost == 4.0
    assert o.oracle_config == "x" and o.oracle_cost == 4.0
    assert o.static_config == "y" and o.static_cost == 6.0
    assert o.deviation == 0.0
    assert o.static_deviation == pytest.approx(0.5)
    assert ev.mean_deviation == 0.0
    assert ev.static_mean_deviation == pytest.approx(0.5)
    assert ev.summary()["decisions"] == 1


def test_dynamic_evaluation_skips_unprofiled_selections():
    store = ProfilingStore(config_ids=["x", "y"])
    store.add("j", "x", 1.0)                     # y never profiled for j
    ev = dynamic_evaluation(
        store, [_D(1, "j", "y", {"x": 1.0, "y": 1.0})], ["x", "y"],
        {"x": 1.0, "y": 1.0})
    assert ev.outcomes == () and ev.skipped == 1


def test_dynamic_evaluation_skips_never_profiled_jobs():
    """Regression: a journaled decision for a job the store has never
    seen (the selector's green-field use case — ranked purely from
    class-mates) must count as skipped, not KeyError."""
    store = ProfilingStore(config_ids=["x"])
    store.add("j", "x", 1.0)
    ev = dynamic_evaluation(
        store, [_D(1, "ghost-job", "x", {"x": 1.0})], ["x"], {"x": 1.0})
    assert ev.outcomes == () and ev.skipped == 1


def test_evaluate_handles_green_field_submissions_end_to_end():
    """Same regression through the real pipeline: a daemon serving a
    submission that is not a profiled job (classified by annotation)
    journals a decision; audit passes and evaluate skips it."""
    svc = synth_service()
    feed = SimulatedSpotFeed(dict(svc.price_source.items()), seed=9,
                             change_fraction=0.5)
    daemon = SelectionDaemon(svc, feed)
    daemon.handle(Submission("never-profiled", annotation=JobClass.A))
    daemon.handle(Tick())
    daemon.handle(Submission("j1"))
    replayer = JournalReplayer(svc.store, daemon.journal_dump())
    assert replayer.audit().ok
    ev = replayer.evaluate()
    assert ev.skipped == 1 and len(ev.outcomes) == 1


def test_deviation_never_negative():
    daemon = run_daemon(n_events=300)
    ev = JournalReplayer(daemon.service.store,
                         daemon.journal_dump()).evaluate()
    assert ev.outcomes
    for o in ev.outcomes:
        assert o.deviation >= 0.0 and o.static_deviation >= 0.0
    assert ev.max_deviation >= ev.mean_deviation >= 0.0


# --- the bundled fixture (acceptance + CI smoke) ----------------------------------

def test_bundled_fixture_replay_end_to_end():
    """ISSUE 3 acceptance: on the bundled recorded-price fixture, the
    journal audit confirms every decision bit-identical to a cold re-rank
    at its epoch, and the harness reports deviation-from-optimal under
    dynamic prices (with live repricing beating the static-price
    oracle)."""
    from repro.core import costmodel, spark_sim
    from repro.market import synthetic_stream
    from repro.selector import GcpVmCatalog
    trace = spark_sim.generate_trace(seed=0)
    store = ProfilingStore.from_trace(trace)
    catalog = GcpVmCatalog(trace.configs, costmodel.LinearPriceModel())
    svc = SelectionService(catalog, store, PriceTable.from_catalog(catalog))
    feed = RecordedPriceFeed.load(PRICE_FIXTURE)
    assert feed.ticks == 40
    daemon = SelectionDaemon(svc, feed)
    daemon.run(synthetic_stream([j.name for j in trace.jobs], 400, seed=3,
                                tick_fraction=0.15))
    replayer = JournalReplayer(store, daemon.journal_dump())
    audit = replayer.audit()
    assert audit.ok, audit.mismatches[:3]
    assert audit.decisions > 100 and audit.ticks > 10
    ev = replayer.evaluate()
    assert 0.0 <= ev.mean_deviation < 0.25
    assert ev.mean_deviation < ev.static_mean_deviation
    assert ev.skipped == 0


def test_bundled_fixture_jax_daemon_audits_in_tolerance_mode():
    """ISSUE 4 acceptance: a *jax-backed* daemon over the same bundled
    fixture journals decisions the tolerance audit confirms against
    cold float64 re-ranks — same winners (or contract-tied), scores
    within the ScoreContract, float32 drift surfaced rather than
    silently absorbed — and the dynamic evaluation still beats the
    static-price oracle."""
    pytest.importorskip("jax")
    from repro.core import costmodel, spark_sim
    from repro.market import synthetic_stream
    from repro.selector import GcpVmCatalog, score_contract
    trace = spark_sim.generate_trace(seed=0)
    store = ProfilingStore.from_trace(trace)
    catalog = GcpVmCatalog(trace.configs, costmodel.LinearPriceModel())
    svc = SelectionService(catalog, store, PriceTable.from_catalog(catalog),
                           backend="jax")
    daemon = SelectionDaemon(svc, RecordedPriceFeed.load(PRICE_FIXTURE))
    daemon.run(synthetic_stream([j.name for j in trace.jobs], 400, seed=3,
                                tick_fraction=0.15))
    replayer = JournalReplayer(store, daemon.journal_dump())
    assert replayer.backend == "jax"
    audit = replayer.audit()
    assert audit.ok, audit.mismatches[:3]
    assert audit.contract == score_contract("jax")
    assert audit.decisions > 100 and audit.ticks > 10
    ev = replayer.evaluate()
    assert ev.summary()["backend"] == "jax"
    assert 0.0 <= ev.mean_deviation < 0.25
    assert ev.mean_deviation < ev.static_mean_deviation


def test_bundled_fixture_batched_topk_daemon_audits_in_tolerance_mode():
    """ISSUE 5 acceptance: a *batched-fleet* daemon serving every
    decision via device-side top-k over the bundled paper-universe
    fixture journals decisions the tolerance audit confirms against
    cold float64 re-ranks — one kernel dispatch per price epoch for the
    whole fleet, heads only, and the dynamic evaluation still beats the
    static-price oracle."""
    pytest.importorskip("jax")
    from repro.core import costmodel, spark_sim
    from repro.market import synthetic_stream
    from repro.selector import GcpVmCatalog, score_contract
    trace = spark_sim.generate_trace(seed=0)
    store = ProfilingStore.from_trace(trace)
    catalog = GcpVmCatalog(trace.configs, costmodel.LinearPriceModel())
    svc = SelectionService(catalog, store, PriceTable.from_catalog(catalog),
                           backend="jax_batched", serve_top_k=1)
    daemon = SelectionDaemon(svc, RecordedPriceFeed.load(PRICE_FIXTURE))
    daemon.run(synthetic_stream([j.name for j in trace.jobs], 400, seed=3,
                                tick_fraction=0.15))
    replayer = JournalReplayer(store, daemon.journal_dump())
    assert replayer.backend == "jax_batched"
    audit = replayer.audit()
    assert audit.ok, audit.mismatches[:3]
    assert audit.contract == score_contract("jax_batched")
    assert audit.decisions > 100 and audit.ticks > 10
    assert all(d.served_via == "top_k" for d in replayer.decisions())
    # one dispatch per epoch once the fleet exists
    assert audit.ticks - 1 <= svc.reprice_dispatches <= audit.ticks
    ev = replayer.evaluate()
    assert ev.summary()["backend"] == "jax_batched"
    assert 0.0 <= ev.mean_deviation < 0.25
    assert ev.mean_deviation < ev.static_mean_deviation


def test_bundled_fixture_sharded_daemon_audits_in_tolerance_mode():
    """ISSUE 8 acceptance: a *device-sharded* fleet daemon over the
    bundled ``gcp_spot_prices.csv`` fixture journals decisions the
    tolerance audit confirms against cold float64 re-ranks — the C axis
    split across every available device, one collective shard_map
    dispatch per price epoch, device-side per-shard top-k merged on the
    host — and the dynamic evaluation still beats the static-price
    oracle.  The journal stamps ``"backend": "jax_sharded"`` and the
    unmodified replayer resolves it to the tolerance contract."""
    pytest.importorskip("jax")
    from repro.core import costmodel, spark_sim
    from repro.market import synthetic_stream
    from repro.selector import GcpVmCatalog, score_contract
    trace = spark_sim.generate_trace(seed=0)
    store = ProfilingStore.from_trace(trace)
    catalog = GcpVmCatalog(trace.configs, costmodel.LinearPriceModel())
    svc = SelectionService(catalog, store, PriceTable.from_catalog(catalog),
                           backend="jax_sharded", serve_top_k=1)
    daemon = SelectionDaemon(svc, RecordedPriceFeed.load(PRICE_FIXTURE))
    daemon.run(synthetic_stream([j.name for j in trace.jobs], 400, seed=3,
                                tick_fraction=0.15))
    replayer = JournalReplayer(store, daemon.journal_dump())
    assert replayer.backend == "jax_sharded"
    audit = replayer.audit()
    assert audit.ok, audit.mismatches[:3]
    assert audit.contract == score_contract("jax_sharded")
    assert not audit.contract.bit_identical
    assert audit.decisions > 100 and audit.ticks > 10
    assert all(d.served_via == "top_k" for d in replayer.decisions())
    # one collective dispatch per epoch once the fleet exists
    assert audit.ticks - 1 <= svc.reprice_dispatches <= audit.ticks
    ev = replayer.evaluate()
    assert ev.summary()["backend"] == "jax_sharded"
    assert 0.0 <= ev.mean_deviation < 0.25
    assert ev.mean_deviation < ev.static_mean_deviation


if __name__ == "__main__":
    import sys
    if "--regen-golden" in sys.argv:
        for backend, top_k, path in (
                ("numpy", None, GOLDEN_JOURNAL),
                ("jax", None, GOLDEN_JOURNAL_JAX),
                ("jax_batched", 2, GOLDEN_JOURNAL_TOPK)):
            daemon = golden_daemon(backend=backend, serve_top_k=top_k)
            daemon.run(GOLDEN_STREAM)
            with open(path, "w") as f:
                f.write(daemon.journal_dump())
            print(f"wrote {path}")
    else:
        print(__doc__)
