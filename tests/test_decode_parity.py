"""Prefill + decode must reproduce the training-mode forward logits.

This is the strongest integration invariant the serving path has: for every
architecture family (dense attention, GQA/MQA, MoE, RG-LRU hybrid with
local-attention ring caches, RWKV, enc-dec with cross-attention caches),
token-by-token decoding against caches must match the full parallel
forward.
"""
import jax
import jax.numpy as jnp
import pytest

import repro.configs as C
from repro.configs import shapes as S
from repro.models import build_model
from repro.models.types import ShapeSpec

T_TOTAL = 12
T_PROMPT = 6
B = 2

# MoE dropping breaks exact parity for tiny capacities; bump capacity in
# reduced configs via a generous factor during this test.
PARITY_ATOL = 2e-3


def _parity_case(name):
    import dataclasses
    cfg = C.reduced(C.get(name))
    if cfg.num_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=64.0)
    return cfg


@pytest.mark.parametrize("name", [n for n in C.ARCH_NAMES
                                  if not C.get(n).is_encdec])
def test_decode_matches_forward(name):
    cfg = _parity_case(name)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)

    shape = ShapeSpec("parity", T_TOTAL, B, "train")
    batch = S.make_batch(cfg, shape, key, with_labels=False)
    full_logits, _ = model.forward(params, batch, remat=False)

    F = batch["frontend_embeds"].shape[1] if "frontend_embeds" in batch else 0
    n_text = batch["tokens"].shape[1]

    # prefill on the first T_PROMPT text tokens (plus any frontend embeds)
    prompt = dict(batch)
    prompt["tokens"] = batch["tokens"][:, :T_PROMPT]
    state = model.init_state(B, F + n_text)
    logits, state = model.prefill(params, prompt, state)
    pos0 = F + T_PROMPT
    assert jnp.allclose(logits, full_logits[:, pos0 - 1],
                        atol=PARITY_ATOL), name

    # decode the rest token by token
    for t in range(T_PROMPT, n_text):
        tok = batch["tokens"][:, t]
        logits, state = model.decode_step(params, tok,
                                          jnp.int32(F + t), state)
        err = jnp.abs(logits - full_logits[:, F + t]).max()
        assert float(err) < PARITY_ATOL, (name, t, float(err))


def test_encdec_decode_matches_forward():
    cfg = _parity_case("seamless-m4t-large-v2")
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)

    shape = ShapeSpec("parity", 2 * T_TOTAL, B, "train")
    batch = S.make_batch(cfg, shape, key, with_labels=False)
    full_logits, _ = model.forward(params, batch, remat=False)

    n_text = batch["tokens"].shape[1]
    enc_len = batch["frontend_embeds"].shape[1]
    prompt = dict(batch)
    prompt["tokens"] = batch["tokens"][:, :T_PROMPT]
    state = model.init_state(B, n_text, enc_len)
    logits, state = model.prefill(params, prompt, state)
    assert jnp.allclose(logits, full_logits[:, T_PROMPT - 1],
                        atol=PARITY_ATOL)
    for t in range(T_PROMPT, n_text):
        tok = batch["tokens"][:, t]
        logits, state = model.decode_step(params, tok, jnp.int32(t), state)
        err = jnp.abs(logits - full_logits[:, t]).max()
        assert float(err) < PARITY_ATOL, (t, float(err))


def test_window_ring_cache_parity():
    """RecurrentGemma local attention with T far beyond the window: ring
    cache decode must equal the windowed parallel forward."""
    import dataclasses
    cfg = C.reduced(C.get("recurrentgemma-9b"))
    cfg = dataclasses.replace(cfg, window=4)   # tiny window << T
    model = build_model(cfg)
    key = jax.random.PRNGKey(3)
    params = model.init(key)
    T = 14
    shape = ShapeSpec("parity", T, B, "train")
    batch = S.make_batch(cfg, shape, key, with_labels=False)
    full_logits, _ = model.forward(params, batch, remat=False)

    prompt = {"tokens": batch["tokens"][:, :T_PROMPT]}
    state = model.init_state(B, T)
    logits, state = model.prefill(params, prompt, state)
    assert jnp.allclose(logits, full_logits[:, T_PROMPT - 1], atol=PARITY_ATOL)
    for t in range(T_PROMPT, T):
        tok = batch["tokens"][:, t]
        logits, state = model.decode_step(params, tok, jnp.int32(t), state)
        err = jnp.abs(logits - full_logits[:, t]).max()
        assert float(err) < PARITY_ATOL, (t, float(err))
