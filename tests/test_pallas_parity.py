"""Differential fused-kernel parity suite (ISSUE 9).

The fused Pallas delta-rank backend
(:class:`~repro.selector.PallasBatchedRankState`, DESIGN.md §14) must
be indistinguishable — within the jax ``ScoreContract`` — from both
the XLA-delta :class:`~repro.selector.BatchedRankState` it fuses and
the cold numpy float64 rank, per tick, at the default and at tiled
``block_j``/``block_c`` layouts (the kernel runs ``interpret=True`` on
CPU).

Also home to: the dense-delta duplicate idempotency check (the fused
path carries no bucket padding — duplicates collapse by construction),
the fused reprice+top-k head checks at the k boundaries, the
jax_pallas service/daemon integration tests and the tolerance-mode
journal audit.
"""
import numpy as np
import pytest

from repro.core.trace import JobClass
from repro.selector import (BatchedRankState, NothingRankableError,
                            PallasBatchedRankState, backend_available,
                            rank_dense, score_contract)
from test_backend_parity import assert_within_contract
from test_batched_parity import (_fleet_service, _fleet_universe,
                                 _universe_with_ties)

try:        # the property half needs hypothesis; everything else runs
            # without it
    import hypothesis
    from hypothesis import given, settings, strategies as st
    from test_batched_parity import fleet_streams
    from test_rank_properties import event_markets, _event_feed
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

needs_jax = pytest.mark.skipif(not backend_available("jax_pallas"),
                               reason="jax not installed")

CONTRACT = score_contract("jax_pallas")

#: tiling legs: the default single-C-tile layout plus genuinely tiled
#: grids (multi-tile C exercises the phase-0 min scan across tiles;
#: n_cfgs in the seeded fleets is padded to keep block_c dividing)
TILINGS = ({}, {"block_j": 4}, {"block_j": 4, "block_c": 8})


def _assert_pallas_parity(fused, batched, members, hours, mask, live,
                          ids):
    """Every member: jax_pallas == jax_batched == numpy cold, under the
    contract; plus the device top-k head is element-wise identical to
    the member's own materialized ranking head."""
    for key, rows in members.items():
        cold = rank_dense(hours[rows], mask[rows], live, ids)
        rf = fused.ranking(key)
        assert_within_contract(rf, cold, CONTRACT)
        assert_within_contract(rf, batched.ranking(key), CONTRACT)
        k = min(3, len(ids))
        assert fused.top_k(key, k) == rf[:k]


# --- deterministic differential sweeps ---------------------------------------------

@needs_jax
@pytest.mark.parametrize("tiling", TILINGS)
@pytest.mark.parametrize("seed", range(3))
def test_pallas_fleet_within_contract_seeded(seed, tiling):
    """Seeded fleets at every tiling: after each tick, each fused-kernel
    member matches the XLA batched state and the cold numpy float64
    rank under the contract — one fused dispatch per tick.  Odd seeds
    use partial (masked) universes, so the masked-cell and padded-row
    handling is live."""
    rng, hours, mask, prices, ids, members = _fleet_universe(
        seed, n_jobs=6 + seed, n_cfgs=16, partial=seed % 2 == 0)
    fused = PallasBatchedRankState(hours, mask, prices.copy(), ids,
                                   **tiling)
    batched = BatchedRankState(hours, mask, prices.copy(), ids)
    for key, rows in members.items():
        fused.add_state(key, rows=rows)
        batched.add_state(key, rows=rows)
    live = prices.copy()
    for _ in range(5):
        k = int(rng.integers(1, len(ids)))
        cols = rng.choice(len(ids), k, replace=False)
        deltas = {ids[c]: float(live[c] * rng.uniform(0.5, 2.0))
                  for c in cols}
        assert fused.reprice(deltas) == batched.reprice(deltas)
        for c, p in deltas.items():
            live[int(c[1:])] = p
        _assert_pallas_parity(fused, batched, members, hours, mask,
                              live, ids)
    # the accounting the bench gates on: ONE fused kernel dispatch per
    # tick, independent of the member count
    assert fused.dispatches == fused.reprices == 5
    assert fused.n_active == len(members)


@needs_jax
def test_pallas_event_market_within_contract_deterministic():
    """Discount/eviction boundary re-quote bursts through the fused
    kernel stay within contract of cold float64 ranks for every
    member."""
    from repro.market import MarketEvent, SimulatedSpotFeed
    rng, hours, mask, prices, ids, members = _fleet_universe(
        7, n_jobs=8, n_cfgs=11, partial=False)
    base = {c: float(p) for c, p in zip(ids, prices)}
    feed = SimulatedSpotFeed(
        base, seed=5, change_fraction=0.3, volatility=0.15,
        events=[MarketEvent("us-central1", 2, 4, 0.25, "discount"),
                MarketEvent("europe-west3", 5, 3, 4.0, "eviction")])
    fused = PallasBatchedRankState(hours, mask, prices.copy(), ids)
    batched = BatchedRankState(hours, mask, prices.copy(), ids)
    for key, rows in members.items():
        fused.add_state(key, rows=rows)
        batched.add_state(key, rows=rows)
    live = prices.copy()
    for t in range(10):
        batch = feed.poll(t)
        if not batch:
            continue
        deltas = {d.config_id: d.price for d in batch}
        fused.reprice(deltas)
        batched.reprice(deltas)
        for d in batch:
            live[ids.index(d.config_id)] = d.price
        _assert_pallas_parity(fused, batched, members, hours, mask,
                              live, ids)


@needs_jax
def test_pallas_duplicate_deltas_idempotent_by_construction():
    """The fused path densifies deltas into one (1, C) price vector —
    no bucket padding exists to repeat (column, price) pairs, so a
    delta batch with duplicate config ids (last wins, like every other
    backend) and its collapsed dict form produce the SAME tick,
    bit-for-bit."""
    rng, hours, mask, prices, ids, members = _fleet_universe(
        5, n_jobs=8, n_cfgs=12)
    a = PallasBatchedRankState(hours, mask, prices.copy(), ids)
    b = PallasBatchedRankState(hours, mask, prices.copy(), ids)
    for key, rows in members.items():
        a.add_state(key, rows=rows)
        b.add_state(key, rows=rows)
    dup = [(ids[2], 9.9), (ids[5], 0.4), (ids[2], 1.1), (ids[2], 0.7)]
    collapsed = {ids[2]: 0.7, ids[5]: 0.4}
    assert a.reprice(dup) == b.reprice(collapsed)
    for key in members:
        assert np.array_equal(a.scores(key), b.scores(key))
    assert np.array_equal(a.prices, b.prices)


@needs_jax
def test_pallas_states_added_retired_and_slot_reuse():
    """Members added mid-stream sync with every prior tick; retired
    members raise the typed rankable-nothing error; a retire-all /
    re-add cycle reuses the zero-masked slots without growing capacity
    (``realloc_count`` pinned), and the revived member's scores
    bit-match a cold build."""
    rng, hours, mask, prices, ids, members = _fleet_universe(
        11, n_jobs=12, n_cfgs=17, n_members=4)
    fused = PallasBatchedRankState(hours, mask, prices.copy(), ids,
                                   capacity=4)
    live = prices.copy()

    def tick():
        k = int(rng.integers(1, len(ids)))
        cols = rng.choice(len(ids), k, replace=False)
        deltas = {ids[c]: float(live[c] * rng.uniform(0.5, 2.0))
                  for c in cols}
        fused.reprice(deltas)
        for c, p in deltas.items():
            live[int(c[1:])] = p

    fused.add_state("all", rows=members["all"])
    tick()
    fused.add_state("m0", rows=members["m0"])       # post-tick add
    tick()
    for key in ("all", "m0"):
        cold = rank_dense(hours[members[key]], mask[members[key]], live,
                          ids)
        assert_within_contract(fused.ranking(key), cold, CONTRACT)
    # retire-all / re-add: slots reused, capacity untouched
    assert fused.realloc_count == 0
    for key in ("all", "m0"):
        fused.retire_state(key)
    assert fused.n_active == 0
    with pytest.raises(NothingRankableError, match="retired"):
        fused.ranking("m0")
    with pytest.raises(NothingRankableError, match="retired"):
        fused.top_k("m0", 1)
    with pytest.raises(ValueError, match="unknown member"):
        fused.ranking("never-registered")
    for key in ("all", "m0"):
        fused.add_state(key, rows=members[key])
    assert fused.realloc_count == 0                 # reuse, not growth
    # the revived member bit-matches a cold build at the live prices
    cold_state = PallasBatchedRankState(hours, mask, live.copy(), ids)
    cold_state.add_state("m0", rows=members["m0"])
    assert np.array_equal(fused.scores("m0"), cold_state.scores("m0"))
    # genuinely new concurrent members DO grow capacity (4 -> 8)
    for i in range(5):
        fused.add_state(f"late{i}", rows=[int(r) for r in
                                          rng.choice(12, 3,
                                                     replace=False)])
    assert fused.realloc_count == 1
    tick()
    for key in ("all", "m0"):
        cold = rank_dense(hours[members[key]], mask[members[key]], live,
                          ids)
        assert_within_contract(fused.ranking(key), cold, CONTRACT)


@needs_jax
def test_pallas_validates_members_and_deltas():
    rng, hours, mask, prices, ids, _ = _fleet_universe(3, n_jobs=4,
                                                       n_cfgs=6)
    s = PallasBatchedRankState(hours, mask, prices, ids,
                               job_ids=[f"j{i}" for i in range(4)])
    s.add_state("a", rows=[0, 1])
    with pytest.raises(ValueError, match="duplicate member"):
        s.add_state("a", rows=[2])
    with pytest.raises(ValueError, match="exactly one of"):
        s.add_state("b", rows=[0], jobs=["j0"])
    with pytest.raises(ValueError, match="unknown job id"):
        s.add_state("b", jobs=["ghost"])
    with pytest.raises(ValueError, match="out of range"):
        s.add_state("b", rows=[99])
    # the padded kernel rows are a tiling artifact, never addressable:
    # row 4 is the first pad row of the 8-row kernel axis and must
    # reject exactly like any other out-of-range index
    with pytest.raises(ValueError, match="out of range"):
        s.add_state("b", rows=[4])
    with pytest.raises(ValueError, match="duplicate rows"):
        s.add_state("b", rows=[1, 1])
    with pytest.raises(ValueError, match="unknown member"):
        s.retire_state("ghost")
    with pytest.raises(ValueError, match="unknown config id"):
        s.reprice({"ghost": 1.0})
    with pytest.raises(ValueError, match="non-positive"):
        s.reprice({ids[0]: -1.0})
    assert s.reprice({}) == 0


# --- the fused reprice+top-k variant -----------------------------------------------

def _k_boundary_cases(C):
    return (C - 1, C, C + 1, 10 * C)


@needs_jax
@pytest.mark.parametrize("n_cfgs", [12, 13])
def test_pallas_top_k_boundary_with_ties(n_cfgs):
    """k in {C-1, C, C+1, 10·C} on the tie universe: the fused
    backend's top-k serves exactly the head of its own materialized
    ranking, boundary ties (cloned last-three columns) resolving in
    catalog order, within contract of the numpy reference."""
    from repro.selector import RankState
    hours, mask, prices, ids = _universe_with_ties(n_cfgs=n_cfgs)
    C = len(ids)
    s = PallasBatchedRankState(hours, mask, prices, ids)
    s.add_state("all", rows=list(range(hours.shape[0])))
    ref = RankState(hours, mask, prices, ids).ranking()
    clones = [ids[C - 3], ids[C - 2], ids[C - 1]]
    for k in _k_boundary_cases(C):
        head = s.top_k("all", k)
        assert head == s.ranking("all")[:min(k, C)], k
        assert_within_contract(head, ref, score_contract("jax"))
        got = [r.config_id for r in head if r.config_id in clones]
        assert got == clones[:len(got)], (k, got)


@needs_jax
def test_pallas_fused_heads_match_ranking_after_ticks():
    """reprice_with_heads — the tick AND every member's k-head from the
    SAME single kernel launch — equals what the two-step path (reprice,
    then top_k per member) serves, at every boundary k, including after
    ticks that move row minima and clone-column ties."""
    hours, mask, prices, ids = _universe_with_ties(n_cfgs=13)
    C = len(ids)
    s = PallasBatchedRankState(hours, mask, prices, ids)
    s.add_state("all", rows=list(range(hours.shape[0])))
    s.add_state("head", rows=[0, 1])
    ticks = ({ids[3]: 0.01}, {ids[7]: 40.0, ids[1]: 0.2},
             {ids[C - 3]: 0.5, ids[C - 2]: 0.5, ids[C - 1]: 0.5})
    for deltas, k in zip(ticks, (1, C - 1, C + 1)):
        twin = PallasBatchedRankState(hours, mask, s.prices, ids)
        twin.add_state("all", rows=list(range(hours.shape[0])))
        twin.add_state("head", rows=[0, 1])
        before = s.dispatches
        moved, heads = s.reprice_with_heads(deltas, k)
        assert moved == twin.reprice(deltas)
        assert s.dispatches == before + 1       # still one per tick
        for key in ("all", "head"):
            assert heads[key] == s.ranking(key)[:min(k, C)], (key, k)
    # the empty batch degrades to plain serving with NO dispatch
    before = s.dispatches
    moved, heads = s.reprice_with_heads({}, 3)
    assert moved == 0 and s.dispatches == before
    assert heads["all"] == s.ranking("all")[:3]
    for bad in (0, -1, 1.5, True):
        with pytest.raises(ValueError, match="positive integer"):
            s.reprice_with_heads({ids[0]: 1.0}, bad)


# --- hypothesis property half ------------------------------------------------------

if HAVE_HYPOTHESIS:
    @needs_jax
    @settings(max_examples=12, deadline=None)
    @given(fleet_streams())
    def test_pallas_fleet_within_contract_property(data):
        """For any fleet and any reprice stream: jax_pallas ==
        jax_batched == numpy cold per tick under the ScoreContract."""
        jobs, cfgs, rt, prices, stream, members = data
        hours = np.asarray([[rt[(j, c)] for c in cfgs] for j in jobs])
        mask = np.ones_like(hours, dtype=bool)
        pv = np.asarray([prices[c] for c in cfgs])
        fused = PallasBatchedRankState(hours, mask, pv.copy(), cfgs)
        batched = BatchedRankState(hours, mask, pv.copy(), cfgs)
        for key, rows in members.items():
            fused.add_state(key, rows=rows)
            batched.add_state(key, rows=rows)
        live = pv.copy()
        for deltas in stream:
            fused.reprice(deltas)
            batched.reprice(deltas)
            for c, p in deltas.items():
                live[cfgs.index(c)] = p
            _assert_pallas_parity(fused, batched, members, hours, mask,
                                  live, cfgs)

    @needs_jax
    @settings(max_examples=10, deadline=None)
    @given(event_markets())
    def test_pallas_event_market_within_contract_property(market):
        """Event-bearing bursts (discount/eviction boundary re-quotes)
        through the fused kernel stay within contract of the cold
        float64 rank."""
        cfgs, base, events, seed, change_fraction, n_ticks, jobs, rt = \
            market
        hours = np.asarray([[rt[(j, c)] for c in cfgs] for j in jobs])
        mask = np.ones_like(hours, dtype=bool)
        live = np.asarray([base[c] for c in cfgs])
        members = {"all": list(range(len(jobs)))}
        fused = PallasBatchedRankState(hours, mask, live.copy(), cfgs)
        batched = BatchedRankState(hours, mask, live.copy(), cfgs)
        for key, rows in members.items():
            fused.add_state(key, rows=rows)
            batched.add_state(key, rows=rows)
        feed = _event_feed(base, events, seed, change_fraction)
        for t in range(n_ticks):
            batch = feed.poll(t)
            if not batch:
                continue
            deltas = {d.config_id: d.price for d in batch}
            fused.reprice(deltas)
            batched.reprice(deltas)
            for d in batch:
                live[cfgs.index(d.config_id)] = d.price
            _assert_pallas_parity(fused, batched, members, hours, mask,
                                  live, cfgs)
else:
    @pytest.mark.skip(reason="hypothesis not installed (property half "
                             "of the pallas parity suite)")
    def test_pallas_parity_properties_skipped():
        pass  # pragma: no cover


# --- service / daemon integration --------------------------------------------------

@needs_jax
def test_service_jax_pallas_backend_one_dispatch_per_tick():
    """A jax_pallas service stacks every live (class, exclusion)
    ranking into one PallasBatchedRankState: a tick refreshes the whole
    fleet in ONE fused kernel dispatch, within contract of a numpy
    reference service."""
    svc = _fleet_service("jax_pallas")
    ref = _fleet_service("numpy")
    selections = [("j1", None), ("j2", None), ("j1", ("g2",)),
                  ("j2", ("g3",))]
    for job, excl in selections:
        d = svc.submit(job, exclude_groups=excl)
        r = ref.submit(job, exclude_groups=excl)
        assert_within_contract(list(d.ranking), list(r.ranking), CONTRACT)
    assert isinstance(svc._batched, PallasBatchedRankState)
    assert svc._batched.n_active == 4
    deltas = {f"c{i}": float(0.5 + i) for i in range(0, 16, 3)}
    assert svc.reprice(deltas) == 4          # whole fleet refreshed...
    assert svc.reprice_dispatches == 1       # ...in one fused kernel
    assert svc._batched.dispatches == 1
    ref.reprice(deltas)
    for job, excl in selections:
        assert_within_contract(
            list(svc.submit(job, exclude_groups=excl).ranking),
            list(ref.submit(job, exclude_groups=excl).ranking), CONTRACT)
    svc.reprice({"c1": 9.0})
    assert svc.reprice_dispatches == 2
    # top-k serving through the service: the head IS the head
    d = svc.submit("j1", top_k=3)
    assert d.served_via == "top_k"
    assert tuple(d.ranking) == tuple(svc.submit("j1").ranking[:3])


@needs_jax
def test_pallas_service_survives_out_of_band_table_apply():
    """The PR-2 desync invariant holds for the fused fleet: an
    out-of-band PriceTable.apply drops the universe for a cold rebuild
    instead of serving quotes it never saw."""
    svc = _fleet_service("jax_pallas")
    ref = _fleet_service("numpy")
    svc.submit("j1"); ref.submit("j1")
    svc.price_source.apply({"c2": 0.333})
    ref.price_source.apply({"c2": 0.333})
    deltas = {"c5": 7.7}
    assert svc.reprice(deltas) == 0          # fleet dropped, not repriced
    ref.reprice(deltas)
    assert_within_contract(list(svc.submit("j1").ranking),
                           list(ref.submit("j1").ranking), CONTRACT)


@needs_jax
def test_pallas_daemon_journal_audits_in_tolerance_mode():
    """A jax_pallas daemon stamps its backend in the journal header and
    the unmodified JournalReplayer audits it clean in tolerance mode —
    the fused kernel inherits the jax contract, so the audit surface
    carries over with zero changes (DESIGN.md §14)."""
    from repro.market import (JournalReplayer, SelectionDaemon,
                              SimulatedSpotFeed, synthetic_stream)
    from repro.selector import IdentityCatalog, PriceTable, ProfilingStore
    from repro.selector import SelectionService
    rng = np.random.default_rng(9)
    ids = [f"c{i}" for i in range(13)]
    store = ProfilingStore(config_ids=ids)
    for j in range(8):
        klass = JobClass.A if j % 2 else JobClass.B
        for c in ids:
            store.add(f"j{j}", c, float(rng.uniform(0.1, 5.0)),
                      job_class=klass, group=f"g{j % 4}")
    base = {c: float(rng.uniform(1.0, 20.0)) for c in ids}
    table = PriceTable(dict(base))
    svc = SelectionService(IdentityCatalog(ids), store, table,
                           backend="jax_pallas", serve_top_k=3)
    feed = SimulatedSpotFeed(base, seed=4, change_fraction=0.4)
    daemon = SelectionDaemon(svc, feed)
    for event in synthetic_stream([f"j{i}" for i in range(8)], 60,
                                  seed=7, tick_fraction=0.25):
        daemon.handle(event)
    journal = daemon.journal_dump()
    replayer = JournalReplayer(store, journal)
    assert replayer.backend == "jax_pallas"
    assert not score_contract(replayer.backend).bit_identical
    audit = replayer.audit()
    assert audit.ok, audit.mismatches[:3]
    assert audit.decisions > 0
