"""Serving engine + TPU-side Flora selection tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.core.costmodel import TpuPriceModel
from repro.core.tpu_flora import (MeshOption, TpuFlora, WorkloadRecord,
                                  classify_workload, SHAPE_CLASSES)
from repro.core.trace import JobClass
from repro.models import build_model
from repro.serve.engine import Engine, Request


def _engine(name="qwen3-1.7b", slots=2, max_len=32):
    cfg = C.reduced(C.get(name))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return Engine(model, params, slots=slots, max_len=max_len), cfg


def test_engine_greedy_matches_manual_decode():
    eng, cfg = _engine()
    prompt = jnp.arange(8, dtype=jnp.int32) % cfg.vocab_size
    [comp] = eng.generate_batch([Request(uid=1, prompt=prompt,
                                         max_new_tokens=5)])
    assert len(comp.tokens) == 5
    # manual greedy rollout
    model, params = eng.model, eng.params
    state = model.init_state(eng.slots, eng.max_len)
    batch = {"tokens": jnp.stack([prompt, prompt])}
    logits, state = model.prefill(params, batch, state)
    toks = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for step in range(5):
        toks.append(int(tok[0]))
        logits, state = model.decode_step(params, tok,
                                          jnp.int32(8 + step), state)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    assert comp.tokens == toks


def test_engine_waves_cover_all_requests():
    eng, cfg = _engine(slots=2)
    reqs = [Request(uid=i, prompt=jnp.arange(4, dtype=jnp.int32),
                    max_new_tokens=2) for i in range(5)]
    comps = eng.serve(reqs)
    assert sorted(c.uid for c in comps) == [0, 1, 2, 3, 4]
    assert all(len(c.tokens) == 2 for c in comps)


def test_engine_eos_stops_early():
    eng, cfg = _engine()
    prompt = jnp.arange(4, dtype=jnp.int32)
    state = eng.model.init_state(eng.slots, eng.max_len)
    logits, _ = eng.model.prefill(
        eng.params, {"tokens": jnp.stack([prompt, prompt])}, state)
    first = int(jnp.argmax(logits, -1)[0])
    [comp] = eng.generate_batch([Request(uid=1, prompt=prompt,
                                         max_new_tokens=8, eos_id=first)])
    assert comp.tokens == [first]


# --- TPU Flora ---------------------------------------------------------------------

def _mesh_options():
    return [
        MeshOption("dp256xtp1", "v5e", 256, (256, 1), ("data", "model")),
        MeshOption("dp32xtp8", "v5e", 256, (32, 8), ("data", "model")),
        MeshOption("dp16xtp16", "v5e", 256, (16, 16), ("data", "model")),
        MeshOption("v5p-dp16xtp16", "v5p", 256, (16, 16), ("data", "model")),
    ]


def _records():
    """Synthetic profiled trace: decode jobs (class A) run best on high-TP
    splits; train jobs (class B) on high-DP splits; v5p is faster but 3.5x
    the price."""
    recs = []
    speed = {"dp256xtp1": {"train": 1.0, "decode": 4.0},
             "dp32xtp8": {"train": 1.2, "decode": 1.5},
             "dp16xtp16": {"train": 1.5, "decode": 1.0},
             "v5p-dp16xtp16": {"train": 0.8, "decode": 0.55}}
    for arch in ("a1", "a2", "a3"):
        for shape, kind in (("train_4k", "train"), ("decode_32k", "decode")):
            for mesh, s in speed.items():
                recs.append(WorkloadRecord(arch=arch, shape=shape,
                                           mesh=mesh,
                                           step_seconds=s[kind]))
    return recs


def test_classification_defaults_and_annotation():
    assert classify_workload("train_4k") is JobClass.B
    assert classify_workload("decode_32k") is JobClass.A
    assert classify_workload("train_4k", JobClass.A) is JobClass.A


def test_tpu_flora_selects_per_class():
    flora = TpuFlora(_mesh_options(), _records(), TpuPriceModel("ondemand"))
    train_pick = flora.select("train_4k")
    decode_pick = flora.select("decode_32k")
    assert train_pick.name == "dp256xtp1"     # cheapest for class B jobs
    assert decode_pick.name == "dp16xtp16"    # v5e high-TP wins on $ for A


def test_tpu_flora_reacts_to_price_change():
    """Flora's defining property: the selection tracks current prices.
    If v5p drops to v5e prices, its speed advantage wins."""
    cheap_v5p = TpuPriceModel(rates={"v5p": 1.2, "v5e": 1.2})
    flora = TpuFlora(_mesh_options(), _records(), cheap_v5p)
    assert flora.select("decode_32k").generation == "v5p"


def test_tpu_flora_leave_arch_out():
    recs = _records()
    flora = TpuFlora(_mesh_options(), recs, TpuPriceModel())
    pick = flora.select("decode_32k", exclude_archs=("a1",))
    assert pick.name == "dp16xtp16"


def test_tpu_flora_one_class_blends():
    flora1 = TpuFlora(_mesh_options(), _records(), TpuPriceModel(),
                      one_class=True)
    ranked = flora1.rank(JobClass.B)   # class ignored
    # the blended optimum sits between the per-class extremes
    assert ranked[0].config_id in ("dp32xtp8", "dp16xtp16", "dp256xtp1")
    two = TpuFlora(_mesh_options(), _records(), TpuPriceModel())
    per_class_cost = (two.rank(JobClass.B)[0].mean_norm_cost
                      + two.rank(JobClass.A)[0].mean_norm_cost)
    blended_cost = (flora1.rank(JobClass.B)[0].mean_norm_cost * 2)
    assert per_class_cost <= blended_cost + 1e-9
