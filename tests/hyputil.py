"""Optional-hypothesis shim for mixed test modules.

``hypothesis`` is a test-only extra (see pyproject.toml).  Modules whose
tests are *all* property-based guard themselves with a module-level
``pytest.importorskip("hypothesis")``; mixed modules import the decorators
from here instead, so their example-based tests still run when hypothesis
is absent and only the property tests skip (via ``pytest.importorskip``
at call time).
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for ``strategies`` at decoration time only."""

        def __getattr__(self, name):
            return self

        def __call__(self, *args, **kwargs):
            return self

    st = _AnyStrategy()

    def settings(*args, **kwargs):
        return lambda fn: fn

    def given(*args, **kwargs):
        def deco(fn):
            # No functools.wraps: the wrapper must expose a zero-arg
            # signature, or pytest would resolve the strategy parameters
            # as fixtures.
            def wrapper():
                pytest.importorskip("hypothesis")
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco
