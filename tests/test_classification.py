"""Automatic classification (§V future work) recovers the expert labels."""
import pytest

from repro.core.classification import (Classification, JobProfile,
                                       StageProfile, auto_class, classify)
from repro.core.trace import JobClass, PAPER_JOBS


def test_auto_classification_matches_expert_labels():
    """Minimal-profiling classification reproduces Table I for every one
    of the paper's nine algorithms."""
    expert = {j.algorithm: j.job_class for j in PAPER_JOBS}
    for algo, klass in expert.items():
        assert auto_class(algo) is klass, algo


def test_mixed_stage_job_advises_split():
    """The paper's select-where-order-by case: a B-dominated job with a
    significant A stage should advise stage splitting (§II-C)."""
    prof = JobProfile("SelectWhereOrderBy-highhit", stages=(
        StageProfile("select-where", 1.0, 0.0, weight=0.5),
        StageProfile("order-by", 2.5, 0.6, random_access=True, weight=0.5),
    ))
    c = classify(prof)
    assert c.advise_split and not c.confident


def test_single_pass_large_retention_is_still_b():
    """Retaining data without re-reading it doesn't pay for memory:
    one pass -> class B even with a big working set."""
    prof = JobProfile("one-pass-agg", stages=(
        StageProfile("agg", 1.0, 0.9),))
    assert classify(prof).job_class is JobClass.B


def test_iterative_small_state_is_b():
    """Many passes over a tiny working set (streaming stats) -> B."""
    prof = JobProfile("stream-stats", stages=(
        StageProfile("iter", 10.0, 0.01),))
    assert classify(prof).job_class is JobClass.B


def test_flora_with_auto_classes_matches_expert_flora():
    """End-to-end: Flora driven by auto-classification equals Flora driven
    by expert labels on the regenerated trace."""
    from repro.core import costmodel, spark_sim
    from repro.core.flora import Flora
    trace = spark_sim.generate_trace(seed=0)
    flora = Flora(trace, costmodel.LinearPriceModel())
    for job in trace.jobs:
        expert_pick = flora.select_for_job(job)
        auto_pick = flora.select_for_job(
            job, annotated_class=auto_class(job.algorithm))
        assert expert_pick.index == auto_pick.index, job.name
