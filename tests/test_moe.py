"""MoE dispatch correctness: the sort-based capacity dispatch must equal a
naive dense mixture when capacity is unconstrained, and degrade by
*dropping* (never corrupting) tokens when it is."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hyputil import given, settings, st

import repro.configs as C
from repro.models import layers as L
from repro.models.types import init_params


def _moe_cfg(E=8, K=2, cap=64.0):
    cfg = C.reduced(C.get("qwen3-moe-30b-a3b"))
    return dataclasses.replace(cfg, num_experts=E, experts_per_token=K,
                               capacity_factor=cap)


def _dense_reference(p, cfg, x):
    """Naive: every expert on every token, combine with top-k gates."""
    B, T, d = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    logits = jnp.einsum("btd,de->bte", x, p["router"]).astype(jnp.float32)
    probs = jax.nn.sigmoid(logits) if K == 1 else jax.nn.softmax(logits, -1)
    gates, idx = jax.lax.top_k(probs, K)
    if K > 1:
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    g = jnp.einsum("btd,edf->btef", x, p["w_gate"])
    u = jnp.einsum("btd,edf->btef", x, p["w_up"])
    h = jax.nn.silu(g) * u
    y_all = jnp.einsum("btef,efd->bted", h, p["w_down"])
    onehot = jax.nn.one_hot(idx, E)                       # (B,T,K,E)
    w = (onehot * gates[..., None]).sum(2)                # (B,T,E)
    y = jnp.einsum("bted,bte->btd", y_all, w)
    if cfg.shared_expert:
        y = y + L.mlp_apply(p["shared"], cfg, x)
    return y


@pytest.mark.parametrize("K", [1, 2, 4])
def test_moe_matches_dense_reference(K):
    cfg = _moe_cfg(E=8, K=K, cap=64.0)   # capacity >> tokens: no drops
    specs = L.moe_specs(cfg)
    p = init_params(specs, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y, aux = L.moe_apply(p, cfg, x)
    y_ref = _dense_reference(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-3)
    assert float(aux) > 0


def test_moe_capacity_drops_are_partial_not_corrupt():
    """With capacity 1 token/expert, outputs are a subset of the dense
    reference contributions: every nonzero token output appears in the
    reference, dropped tokens are exactly zero (before shared expert)."""
    cfg = _moe_cfg(E=4, K=1, cap=0.0801)   # tiny capacity
    specs = L.moe_specs(cfg)
    p = init_params(specs, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 50, cfg.d_model))
    y, _ = L.moe_apply(p, cfg, x)
    y_ref = _dense_reference(p, cfg, x)
    y_np, ref_np = np.asarray(y), np.asarray(y_ref)
    kept = np.abs(y_np).sum(-1) > 1e-9
    assert kept.sum() > 0 and (~kept).sum() > 0   # some kept, some dropped
    np.testing.assert_allclose(y_np[kept], ref_np[kept], atol=1e-4,
                               rtol=1e-3)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 200), st.integers(2, 16))
def test_positions_in_expert_property(n, E):
    """Ranks are a valid arrival order: within each expert, positions are
    0..count-1 exactly once."""
    key = jax.random.PRNGKey(n * 31 + E)
    ids = jax.random.randint(key, (n,), 0, E, dtype=jnp.int32)
    pos = np.asarray(L._positions_in_expert(ids))
    ids = np.asarray(ids)
    for e in range(E):
        got = sorted(pos[ids == e].tolist())
        assert got == list(range(len(got)))


def test_moe_load_balance_loss_behaviour():
    """Aux loss is ~1 for uniform routing and >1 for collapsed routing."""
    cfg = _moe_cfg(E=8, K=2)
    specs = L.moe_specs(cfg)
    p = init_params(specs, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 64, cfg.d_model))
    _, aux_uniform = L.moe_apply(p, cfg, x)
    # collapse the router onto one expert
    p2 = dict(p)
    router = np.zeros(p["router"].shape, np.float32)
    router[:, 0] = 10.0
    p2["router"] = jnp.asarray(router)
    _, aux_collapsed = L.moe_apply(p2, cfg, x)
    assert float(aux_collapsed) > float(aux_uniform) > 0.5
