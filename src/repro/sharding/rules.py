"""Logical-axis -> mesh-axis sharding rules.

Every parameter/state/activation dimension carries a *logical* axis name
(see ParamSpec.axes).  A :class:`Rules` table maps logical names onto mesh
axes; resolution is divisibility-safe: if a dimension is not divisible by
the mapped mesh axes' total size, it falls back to replication (this is
what makes e.g. llama4's 40 heads work on a 16-way model axis — attention
weights replicate, experts/FFN still shard; the roofline analysis then
shows the replicated-compute cost honestly).

Parallelism coverage:
  DP  — "batch" over ("pod", "data")
  FSDP— "embed" over "data" (ZeRO-3 parameter/optimizer sharding)
  TP  — "heads"/"kv_heads"/"mlp"/"vocab" over "model" (Megatron-style)
  EP  — "experts" over "model"
  SP  — "seq" over "data" (sequence sharding for long activations)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Mapping, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.types import ParamSpec

AxisTarget = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class Rules:
    table: Mapping[str, AxisTarget]

    def target(self, logical: Optional[str]) -> AxisTarget:
        if logical is None:
            return None
        return self.table.get(logical)

    def with_overrides(self, **kv: AxisTarget) -> "Rules":
        t = dict(self.table)
        t.update(kv)
        return Rules(t)


def production_rules(*, multi_pod: bool = False, fsdp: bool = True) -> Rules:
    batch: AxisTarget = ("pod", "data") if multi_pod else ("data",)
    return Rules({
        "batch": batch,
        "seq": None,
        "embed": ("data",) if fsdp else None,
        "heads": ("model",),
        "kv_heads": ("model",),
        # fallback TP axis: shards attention when head counts do not divide
        # the model axis (e.g. llama4's 40 heads on 16-way TP) — the
        # used-once + divisibility logic in spec_for makes this automatic.
        "head_dim": ("model",),
        # rwkv time-mix keeps head-aligned channels replicated (40 heads x 64
        # channels do not align with a 16-way split); channel-mix shards.
        "heads_flat": None,
        "mlp": ("model",),
        "vocab": ("model",),
        "experts": ("model",),
        "layers": None,
    })


def arch_overrides(cfg, tp: int, kind: str = "train") -> dict:
    """Per-architecture rule overrides for a consistent attention scheme.

    The generic divisibility fallback resolves each tensor independently,
    which can leave q sharded on heads while k/v fall back to head_dim —
    a per-layer resharding storm.  This chooses ONE scheme per arch:

    * H % tp == 0 and G % tp == 0  -> shard heads (Megatron); head_dim off.
    * H % tp == 0, G % tp != 0     -> shard q heads, REPLICATE kv
      (classic MQA tensor-parallel) for train/prefill.  For decode the
      replicated KV cache would blow HBM, so decode switches the whole
      attention to head_dim sharding (scores psum per step instead).
    * H % tp != 0 (e.g. llama4's 40 heads on tp=16) -> attention fully
      replicated over the model axis (weights stay FSDP-sharded over data);
      FFN/MoE/vocab still shard.  The roofline shows the duplicated-compute
      cost honestly; the Flora mesh selector discovers that such archs
      prefer a dp32xtp8 split (40 % 8 == 0) — see EXPERIMENTS.md §Perf.
    """
    H, G, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    if H % tp == 0 and G % tp == 0:
        return {"head_dim": None}
    if H % tp == 0:
        if kind == "decode" and D % tp == 0:
            return {"heads": None, "kv_heads": None}
        return {"head_dim": None}
    if D % tp == 0 and kind == "decode":
        return {"heads": None, "kv_heads": None}
    return {"heads": None, "kv_heads": None, "head_dim": None}


def _axes_size(mesh: Mesh, target: AxisTarget) -> int:
    if target is None:
        return 1
    names = (target,) if isinstance(target, str) else tuple(target)
    size = 1
    for n in names:
        size *= mesh.shape[n]
    return size


def spec_for(shape: Sequence[int], axes: Sequence[Optional[str]],
             rules: Rules, mesh: Mesh) -> P:
    """PartitionSpec for one tensor, with divisibility fallback and
    one-mesh-axis-used-once enforcement."""
    used: set = set()
    entries = []
    for dim, logical in zip(shape, axes):
        target = rules.target(logical)
        if target is None:
            entries.append(None)
            continue
        names = (target,) if isinstance(target, str) else tuple(target)
        names = tuple(n for n in names if n in mesh.shape and n not in used)
        size = 1
        for n in names:
            size *= mesh.shape[n]
        if not names or size <= 1 or dim % size != 0:
            entries.append(None)
            continue
        used.update(names)
        entries.append(names[0] if len(names) == 1 else names)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def sharding_for_spec(spec: ParamSpec, rules: Rules, mesh: Mesh
                      ) -> NamedSharding:
    return NamedSharding(mesh, spec_for(spec.shape, spec.axes, rules, mesh))


def tree_shardings(spec_tree: Any, rules: Rules, mesh: Mesh) -> Any:
    """NamedSharding tree parallel to a ParamSpec tree."""
    return jax.tree_util.tree_map(
        lambda s: sharding_for_spec(s, rules, mesh),
        spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec))


def batch_shardings(batch_specs: Mapping[str, jax.ShapeDtypeStruct],
                    rules: Rules, mesh: Mesh) -> Dict[str, NamedSharding]:
    """Shardings for input batches: leading dim = batch, rest replicated
    (sequence sharding is opt-in via rules["seq"])."""
    out = {}
    for name, s in batch_specs.items():
        if s.ndim == 0:
            out[name] = NamedSharding(mesh, P())
            continue
        axes: list = ["batch"] + [None] * (s.ndim - 1)
        if s.ndim >= 2 and rules.target("seq") is not None:
            axes[1] = "seq"
        out[name] = NamedSharding(mesh, spec_for(s.shape, axes, rules, mesh))
    return out


def describe(spec_tree: Any, rules: Rules, mesh: Mesh, *, max_rows: int = 0
             ) -> str:
    """Human-readable table of resolved shardings (debugging aid)."""
    rows = []
    leaves = jax.tree_util.tree_leaves_with_path(
        spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec))
    for path, s in leaves:
        p = spec_for(s.shape, s.axes, rules, mesh)
        rows.append(f"{jax.tree_util.keystr(path):60s} {str(s.shape):24s} {p}")
    if max_rows:
        rows = rows[:max_rows]
    return "\n".join(rows)


def bytes_per_device(spec_tree: Any, rules: Rules, mesh: Mesh) -> int:
    """Parameter bytes resident per device under the resolved shardings."""
    total = 0
    leaves = jax.tree_util.tree_leaves(
        spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec))
    for s in leaves:
        p = spec_for(s.shape, s.axes, rules, mesh)
        shard = 1
        for entry in p:
            shard *= _axes_size(mesh, entry)
        n = int(np.prod(s.shape)) // max(shard, 1)
        total += n * jnp_dtype_size(s.dtype)
    return total


def jnp_dtype_size(dtype) -> int:
    return np.dtype(dtype).itemsize if not hasattr(dtype, "dtype") \
        else np.dtype(dtype.dtype).itemsize
