"""Sharding context: logical activation constraints inside model code.

GSPMD propagation alone mis-shards loop bodies (it replicated batch dims
inside the attention fori_loop — observed as 'Involuntary full
rematerialization' warnings and ~100 GB/device temps on the first dry-run).
The fix, as in MaxText: the model annotates activations with *logical*
axes, and a thread-local (rules, mesh) context resolves them to
``with_sharding_constraint`` calls.  Without a context (CPU smoke tests)
annotation is a no-op.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Tuple

import jax

_TLS = threading.local()


def current():
    return getattr(_TLS, "ctx", None)


@contextlib.contextmanager
def use(rules, mesh):
    old = current()
    _TLS.ctx = (rules, mesh)
    try:
        yield
    finally:
        _TLS.ctx = old


def constrain(x: jax.Array, axes: Sequence[Optional[str]]) -> jax.Array:
    """Annotate activation ``x`` with logical axes (no-op without context)."""
    ctx = current()
    if ctx is None:
        return x
    rules, mesh = ctx
    from jax.sharding import NamedSharding
    from repro.sharding.rules import spec_for
    spec = spec_for(x.shape, tuple(axes), rules, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
