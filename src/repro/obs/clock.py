"""Injectable monotonic clocks for the telemetry layer (DESIGN.md §12).

Every timing site in the pipeline reads time through its registry's
``clock`` attribute instead of calling :func:`time.perf_counter`
directly.  Production registries default to ``perf_counter``; tests and
golden-journal runs inject a :class:`FakeClock` so span durations — and
therefore the ``"metrics"`` journal records built from them — are
byte-reproducible, exactly like the decision journals themselves.
"""
from __future__ import annotations

import time

#: The production clock: monotonic, float seconds, ~tens of ns per call.
SYSTEM_CLOCK = time.perf_counter


class FakeClock:
    """Deterministic monotonic clock: every call advances by ``step``.

    The advance-on-read convention means a ``t1 - t0`` span measured
    across k intervening clock reads is exactly ``(k + 1) * step`` —
    fully determined by the code path, never by the wall clock.  Use
    :meth:`advance` to model explicit elapsed time between reads.
    """

    def __init__(self, start: float = 0.0, step: float = 0.001) -> None:
        self.now = float(start)
        self.step = float(step)

    def __call__(self) -> float:
        self.now += self.step
        return self.now

    def advance(self, dt: float) -> None:
        """Move the clock forward by ``dt`` seconds without a read."""
        self.now += float(dt)
