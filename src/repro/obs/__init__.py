"""repro.obs — unified low-overhead telemetry (DESIGN.md §12).

One :class:`MetricsRegistry` carries every counter, gauge and span
histogram in the system — market ticks, serving workers, training steps
and decode engines all export through the same three paths:

  * :meth:`MetricsRegistry.render` — Prometheus text or JSON dump;
  * periodic additive ``"metrics"`` journal records (schema-v2
    amendment, DESIGN.md §8) that :class:`repro.market.JournalReplayer`
    accounts and recovers tick-latency percentiles from;
  * the ``BENCH_obs.json`` overhead-gate artifact
    (``benchmarks/obs_bench.py``).

Metrics are sharded per writer thread with single-writer cells, so the
serve hot path never takes a lock; merges are exact integer sums and
therefore deterministic regardless of shard count (the property pinned
by ``tests/test_obs.py``).
"""
from repro.obs.clock import FakeClock, SYSTEM_CLOCK
from repro.obs.registry import (Counter, DEFAULT_LATENCY_BUCKETS, Gauge,
                                Histogram, MetricsRegistry, NULL_SPAN,
                                histogram_quantile, maybe_span)

#: Histogram fed by the whole-tick span; the name the journal metrics
#: records (and ReplayAudit.tick_latency) key their percentiles on.
TICK_SPAN = "tick.total"

#: Histogram fed by one turbulence-sweep point (daemon run + journal
#: audit + dynamic eval, DESIGN.md §15); the ``sweep.points`` /
#: ``sweep.decisions`` counters ride the same registry.
SWEEP_SPAN = "sweep.point"

__all__ = [
    "Counter", "DEFAULT_LATENCY_BUCKETS", "FakeClock", "Gauge", "Histogram",
    "MetricsRegistry", "NULL_SPAN", "SWEEP_SPAN", "SYSTEM_CLOCK",
    "TICK_SPAN", "histogram_quantile", "maybe_span",
]
