"""Lock-free sharded metrics: counters, gauges, histograms, spans.

The registry follows the same single-writer discipline as the serving
front-end's worker shards (DESIGN.md §11/§12): every metric is a bag of
*cells*, one per writer thread, created lazily on first write.  A cell
is only ever mutated by the thread that owns it, so the hot path is a
plain attribute increment — no locks, no atomics, no contention.  Reads
merge all cells; because counter values and histogram bucket counts are
integers (histogram sums are quantized to integer nanoseconds before
accumulation), the merge is exact integer addition and therefore
independent of shard count and merge order: recording the same samples
through 1 cell or N cells renders byte-identical output.

Locks appear in exactly two cold places: metric/cell creation (once per
name per thread) and merge-on-read snapshots (which copy the cell list
under the lock, then sum without it).
"""
from __future__ import annotations

import json
import re
import threading
from bisect import bisect_left
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

from repro.obs.clock import SYSTEM_CLOCK

_NAME_RE = re.compile(r"^[A-Za-z_][A-Za-z0-9_.]*$")

#: Default span buckets: geometric ~1 us .. 10 s upper bounds (seconds).
#: The implicit +Inf overflow bucket is always appended.
DEFAULT_LATENCY_BUCKETS: Tuple[float, ...] = (
    1e-6, 2.5e-6, 5e-6,
    1e-5, 2.5e-5, 5e-5,
    1e-4, 2.5e-4, 5e-4,
    1e-3, 2.5e-3, 5e-3,
    1e-2, 2.5e-2, 5e-2,
    1e-1, 2.5e-1, 5e-1,
    1.0, 2.5, 5.0, 10.0,
)


def _check_name(name: str) -> str:
    if not _NAME_RE.match(name):
        raise ValueError(f"invalid metric name {name!r}")
    return name


def _prom_name(name: str) -> str:
    return name.replace(".", "_")


class _CounterCell:
    """Single-writer tally; ``n`` is mutated only by the owning thread."""

    __slots__ = ("n",)

    def __init__(self) -> None:
        self.n = 0

    def inc(self, n: int = 1) -> None:
        self.n += n


class _HistCell:
    """Single-writer histogram shard: integer bucket counts + ns sum."""

    __slots__ = ("counts", "total_ns", "_bounds")

    def __init__(self, bounds: Tuple[float, ...]) -> None:
        self.counts = [0] * (len(bounds) + 1)
        self.total_ns = 0
        self._bounds = bounds

    def observe(self, v: float) -> None:
        # ``le`` semantics: v lands in the first bucket whose upper
        # bound is >= v; beyond the last bound it lands in +Inf.
        self.counts[bisect_left(self._bounds, v)] += 1
        self.total_ns += int(round(v * 1e9))


class _Sharded:
    """Cell bag shared by Counter/Histogram: lock-free get, locked create."""

    def __init__(self) -> None:
        self._cells: Dict[int, object] = {}
        self._lock = threading.Lock()

    def _new_cell(self) -> object:  # pragma: no cover - overridden
        raise NotImplementedError

    def cell(self, key: Optional[int] = None):
        """The calling thread's cell (or the cell for explicit ``key``).

        Single-writer rule: a cell must only ever be written by the one
        thread (or the one logical shard, for explicit keys) it was
        created for.  Hot paths cache the returned cell and mutate it
        directly, skipping the per-call dict lookup.
        """
        k = threading.get_ident() if key is None else key
        c = self._cells.get(k)
        if c is None:
            with self._lock:
                c = self._cells.setdefault(k, self._new_cell())
        return c

    def _merged_cells(self) -> List[object]:
        with self._lock:
            return list(self._cells.values())


class Counter(_Sharded):
    def __init__(self, name: str) -> None:
        super().__init__()
        self.name = _check_name(name)

    def _new_cell(self) -> _CounterCell:
        return _CounterCell()

    def inc(self, n: int = 1) -> None:
        self.cell().inc(n)

    @property
    def value(self) -> int:
        return sum(c.n for c in self._merged_cells())

    def set(self, value: int) -> None:
        """Force the merged value to ``value`` by adjusting the calling
        thread's cell.  Compatibility shim for legacy attribute writes
        (``svc.cache_hits += 100``); only safe while other writers are
        quiescent."""
        self.cell().inc(int(value) - self.value)


class Gauge:
    """Last-written value; not sharded (one logical writer, atomic set)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str) -> None:
        self.name = _check_name(name)
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram(_Sharded):
    def __init__(self, name: str,
                 buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS) -> None:
        super().__init__()
        self.name = _check_name(name)
        bounds = tuple(float(b) for b in buckets)
        if not bounds or any(b2 <= b1 for b1, b2 in zip(bounds, bounds[1:])):
            raise ValueError(f"histogram {name!r}: buckets must be "
                             f"non-empty and strictly increasing")
        self.bounds = bounds

    def _new_cell(self) -> _HistCell:
        return _HistCell(self.bounds)

    def observe(self, v: float) -> None:
        self.cell().observe(v)

    def merged(self) -> Tuple[List[int], int]:
        """(per-bucket counts incl. +Inf, total nanoseconds) over cells."""
        counts = [0] * (len(self.bounds) + 1)
        total_ns = 0
        for c in self._merged_cells():
            for i, n in enumerate(c.counts):
                counts[i] += n
            total_ns += c.total_ns
        return counts, total_ns

    @property
    def count(self) -> int:
        return sum(self.merged()[0])

    @property
    def sum(self) -> float:
        return self.merged()[1] / 1e9

    def quantile(self, q: float) -> Optional[float]:
        counts, _ = self.merged()
        return histogram_quantile(self.bounds, counts, q)

    def dump(self) -> Dict[str, object]:
        counts, total_ns = self.merged()
        return {"le": list(self.bounds), "counts": counts,
                "sum": total_ns / 1e9, "count": sum(counts)}


def histogram_quantile(bounds: Sequence[float], counts: Sequence[int],
                       q: float) -> Optional[float]:
    """Prometheus-style quantile from cumulative-by-bucket counts.

    ``counts`` is per-bucket (not cumulative) with the +Inf overflow
    last.  Linear interpolation within the winning bucket; samples in
    the overflow bucket clamp to the last finite bound.  Pure integer
    walk + one float interpolation, so the result is deterministic for
    a given (bounds, counts, q).  Returns None for an empty histogram.
    """
    if not 0.0 <= q <= 1.0:
        raise ValueError(f"quantile {q} outside [0, 1]")
    total = sum(counts)
    if total == 0:
        return None
    rank = q * total
    cum = 0
    for i, n in enumerate(counts):
        prev = cum
        cum += n
        if cum >= rank and n > 0:
            if i >= len(bounds):  # overflow bucket
                return float(bounds[-1])
            lo = bounds[i - 1] if i > 0 else 0.0
            hi = bounds[i]
            return lo + (hi - lo) * ((rank - prev) / n)
    return float(bounds[-1])  # pragma: no cover - rank <= total always hits


class _Span:
    """Context manager timing one block into a histogram."""

    __slots__ = ("_hist", "_clock", "_t0")

    def __init__(self, hist: Histogram, clock: Callable[[], float]) -> None:
        self._hist = hist
        self._clock = clock

    def __enter__(self) -> "_Span":
        self._t0 = self._clock()
        return self

    def __exit__(self, *exc) -> bool:
        self._hist.observe(self._clock() - self._t0)
        return False


class _NullSpan:
    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


NULL_SPAN = _NullSpan()


def maybe_span(registry: Optional["MetricsRegistry"], name: str):
    """``registry.span(name)`` when a registry is wired, else a no-op."""
    return NULL_SPAN if registry is None else registry.span(name)


class MetricsRegistry:
    """Named counters/gauges/histograms plus the ``span`` timing API.

    ``spans_enabled=False`` turns every ``span()`` into a shared no-op
    object — no clock reads, no histogram writes — which is the
    uninstrumented leg of the overhead gate (``benchmarks/obs_bench.py``).
    Counters stay live in both modes; they are the accounting the rest
    of the system reads back (cache hits, shed, reallocs, ...).
    """

    def __init__(self, clock: Optional[Callable[[], float]] = None,
                 spans_enabled: bool = True) -> None:
        self.clock = SYSTEM_CLOCK if clock is None else clock
        self.spans_enabled = spans_enabled
        self._metrics: Dict[str, object] = {}
        self._lock = threading.Lock()

    def _get_or_create(self, name: str, cls, factory):
        m = self._metrics.get(name)
        if m is None:
            with self._lock:
                m = self._metrics.get(name)
                if m is None:
                    m = factory()
                    self._metrics[name] = m
        if not isinstance(m, cls):
            raise TypeError(f"metric {name!r} is {type(m).__name__}, "
                            f"not {cls.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get_or_create(name, Counter, lambda: Counter(name))

    def gauge(self, name: str) -> Gauge:
        return self._get_or_create(name, Gauge, lambda: Gauge(name))

    def histogram(self, name: str,
                  buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS
                  ) -> Histogram:
        return self._get_or_create(name, Histogram,
                                   lambda: Histogram(name, buckets))

    def span(self, name: str,
             buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS):
        """Time a ``with`` block into histogram ``name`` (no-op when
        spans are disabled).  Hot paths that cannot afford the context
        manager read ``clock``/``spans_enabled`` and observe into a
        cached ``histogram(name).cell()`` directly."""
        if not self.spans_enabled:
            return NULL_SPAN
        return _Span(self.histogram(name, buckets), self.clock)

    # -- merge-on-read export ------------------------------------------

    def _sorted_metrics(self) -> List[Tuple[str, object]]:
        with self._lock:
            items = list(self._metrics.items())
        return sorted(items)

    def snapshot(self) -> Dict[str, Dict[str, object]]:
        """Deterministic merged dump: sorted names, integer-exact counts."""
        out: Dict[str, Dict[str, object]] = {
            "counters": {}, "gauges": {}, "histograms": {}}
        for name, m in self._sorted_metrics():
            if isinstance(m, Counter):
                out["counters"][name] = m.value
            elif isinstance(m, Gauge):
                out["gauges"][name] = m.value
            else:
                out["histograms"][name] = m.dump()
        return out

    def render(self, fmt: str = "prom") -> str:
        """Export the registry: Prometheus text (default) or JSON."""
        if fmt == "json":
            return json.dumps(self.snapshot(), sort_keys=True)
        if fmt != "prom":
            raise ValueError(f"unknown metrics format {fmt!r}")
        lines: List[str] = []
        for name, m in self._sorted_metrics():
            p = _prom_name(name)
            if isinstance(m, Counter):
                lines.append(f"# TYPE {p} counter")
                lines.append(f"{p} {m.value}")
            elif isinstance(m, Gauge):
                lines.append(f"# TYPE {p} gauge")
                lines.append(f"{p} {m.value}")
            else:
                counts, total_ns = m.merged()
                lines.append(f"# TYPE {p} histogram")
                cum = 0
                for bound, n in zip(m.bounds, counts):
                    cum += n
                    lines.append(f'{p}_bucket{{le="{bound}"}} {cum}')
                cum += counts[-1]
                lines.append(f'{p}_bucket{{le="+Inf"}} {cum}')
                lines.append(f"{p}_sum {total_ns / 1e9}")
                lines.append(f"{p}_count {cum}")
        return "\n".join(lines) + "\n"
