"""ServeFrontend: concurrent serving off immutable per-tick snapshots.

The :class:`~repro.market.SelectionDaemon` serializes every tick and
submission on one thread, so one slow submission (a client round-trip, a
placement call) stalls the whole fleet's repricing.  This module is the
concurrency layer on top of the exact same service/journal machinery
(DESIGN.md §11):

  * **one tick thread owns all mutable selection state.**  It is the only
    thread that touches the :class:`~repro.selector.SelectionService`
    (and through it the shared :class:`~repro.selector.BatchedRankState`
    delta refresh).  Per tick it polls the feed, applies the deltas, and
    publishes an immutable :class:`Snapshot`: the tick id, the price
    epoch, the price-table version, and the top-k head of every
    registered (class, exclusion) selection — pulled through
    ``SelectionService.rank_head``, i.e. the device-side ``top_k`` on
    the jax backends.
  * **N submission workers serve lock-free.**  A worker resolves its
    submission's (class, exclusion) route (memoized, read-only), reads
    ``self._snapshot`` — a single reference load of an object that is
    never mutated after publication — and builds the
    :class:`~repro.selector.Decision` straight from the snapshot entry.
    No locks, no service calls, no shared mutable state on this path.
    A route the snapshot does not carry is *forwarded* to the tick
    thread's control queue, which serves it through the full
    ``service.submit`` path, registers the selection, and republishes —
    so each selection forwards only until its first snapshot.
  * **bounded queues, explicit shed.**  :meth:`submit` round-robins
    submissions across per-worker queues and *refuses* (returns False,
    counts a shed) when the target queue is at capacity or the front-end
    is closed — backpressure is a visible outcome, never an unbounded
    buffer.  Every submission is accounted: accepted ones end as exactly
    one journaled decision or rejection, refused ones as exactly one
    shed.
  * **worker-sharded journals, deterministic merge.**  Each thread
    appends records to its own shard (no contention); every record
    carries the tick it was served under (``snapshot_tick`` on
    decisions/rejections, ``tick`` on tick/feed-error records) and its
    shard's ``worker`` id.  :meth:`journal_dump` merges shards by the
    total order ``(tick, worker, per-shard seq)`` — tick-thread records
    first within a tick — and renumbers ``seq``, which lands every
    decision between the tick records of its stamped epoch: the merged
    journal replays through the unmodified
    :class:`~repro.market.JournalReplayer` byte/tolerance-clean.
  * **typed feed failures.**  A ``feed.poll`` that raises surfaces as
    :class:`~repro.market.FeedError`; the tick thread journals a
    ``feed-error`` record, keeps serving off the last good snapshot,
    and retries the same tick with capped exponential backoff.

Thread model: ``submit`` may be called from any number of producer
threads; everything else that mutates state runs on the tick thread or
on exactly one worker.  The inline stepping API (:meth:`step_tick`,
:meth:`serve_queued`) drives the same code paths without threads, which
is what makes deterministic golden tests of a concurrent subsystem
possible: same submissions, same interleave, same merged bytes.
"""
from __future__ import annotations

import dataclasses
import itertools
import json
import queue
import threading
import time
from types import MappingProxyType
from typing import (Any, Dict, Hashable, Iterable, List, Mapping, Optional,
                    Sequence, Tuple, Union)

from repro.core.trace import JobClass
from repro.obs import MetricsRegistry, TICK_SPAN
from repro.selector import (Decision, NothingRankableError, RankedConfig,
                            SelectionService)
from repro.market.daemon import (JOURNAL_FORMAT, JOURNAL_VERSION, Submission,
                                 decision_record, feed_error_record,
                                 metrics_record, rejection_record,
                                 tick_record)
from repro.market.feed import FeedError, PriceFeed
from repro.market.ticker import PriceTicker

#: worker-queue poison pill (shutdown drains, then stops the worker).
_SENTINEL = object()

#: route key: the (class, effective-exclusions) a submission ranks under.
Route = Tuple[Optional[JobClass], Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class SnapshotEntry:
    """One selection's published serving state.

    ``head is None`` marks a selection known to be unrankable (no
    profiled configurations) — workers serve those as journaled
    rejections without a service call.  Unrankability is
    price-independent (it is a property of the trace/catalog overlap),
    so a published rejection can never go stale within a run.
    """

    job_class: Optional[JobClass]
    exclude_groups: Tuple[str, ...]
    head: Optional[Tuple[RankedConfig, ...]]
    entry: Any = None               # the winner's native catalog object
    hourly_cost: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class Snapshot:
    """What the tick thread publishes and workers serve from.

    Immutable by construction: frozen dataclass, read-only ``entries``
    mapping, tuple heads.  Publication is a single reference store to
    ``ServeFrontend._snapshot`` and consumption a single reference load,
    so workers always see a complete snapshot — never a half-updated
    one — without any lock (DESIGN.md §11).
    """

    tick: int                       # last applied tick index (-1 = none)
    price_epoch: int
    table_version: int
    k: int                          # head depth the entries carry
    entries: Mapping[Route, SnapshotEntry]


@dataclasses.dataclass
class FrontendStats:
    submitted: int = 0          # accepted into a worker queue
    shed: int = 0               # refused at enqueue (full queue / closed)
    decisions: int = 0          # journaled decisions (workers + control)
    rejected: int = 0           # journaled rejections
    forwarded: int = 0          # worker misses routed to the tick thread
    ticks: int = 0              # mirrors PriceTicker.tick_count
    deltas: int = 0             # mirrors PriceTicker.deltas_applied
    epochs: int = 0             # mirrors PriceTicker.epochs_driven
    feed_errors: int = 0        # polls that raised (tick retried)
    snapshots: int = 0          # snapshots published
    callback_errors: int = 0    # on_decision callbacks that raised

    @property
    def accounted(self) -> bool:
        """Every accepted submission ended as exactly one journaled
        decision or rejection (refused ones as exactly one shed) — the
        drain-accounting invariant the overflow tests pin."""
        return self.submitted == self.decisions + self.rejected


def merge_shards(header_line: str,
                 shards: Sequence[Sequence[Dict[str, Any]]]) -> str:
    """Merge per-thread journal shards into one v2 journal (text).

    Every sharded record is self-describing: decisions/rejections carry
    ``snapshot_tick`` and ``worker``, tick/feed-error/metrics records
    ``tick`` and ``worker``.  The merge sorts by the total order
    ``(tick, worker, position-in-shard)`` — unique per record, so the
    result is deterministic for given shard contents regardless of how
    thread scheduling interleaved the appends — then renumbers ``seq``
    in merged order.  Tick-thread records (worker 0) sort first within
    a tick, which places every worker decision *after* the tick record
    of the epoch it was served under and *before* the next one: exactly
    the ordering :class:`~repro.market.JournalReplayer` needs to
    reconstruct each decision's prices.
    """
    items: List[Tuple[int, int, int, Dict[str, Any]]] = []
    for shard in shards:
        for pos, rec in enumerate(shard):
            tick = rec["snapshot_tick"] if "snapshot_tick" in rec \
                else rec["tick"]
            items.append((tick, rec["worker"], pos, rec))
    items.sort(key=lambda it: it[:3])
    lines = [header_line]
    for seq, (_, _, _, rec) in enumerate(items, start=1):
        rec = dict(rec)
        rec["seq"] = seq
        lines.append(json.dumps(rec))
    return "\n".join(lines) + "\n"


class ServeFrontend:
    """Tick-owned repricing + N lock-free snapshot-serving workers.

    Threaded use::

        fe = ServeFrontend(service, feed, workers=4, queue_capacity=256)
        fe.warm(submissions)        # optional: pre-register selections
        fe.start()
        for sub in submissions:
            fe.submit(sub)          # False = shed (queue full)
        fe.drain(); stats = fe.shutdown()
        audit = JournalReplayer(store, fe.journal_dump()).audit()

    Inline (no threads — deterministic tests and goldens)::

        fe.submit(sub); fe.step_tick(); fe.serve_queued(); fe.close()

    ``on_decision`` is invoked (on the serving thread) with every
    :class:`~repro.selector.Decision` — the reply hook where a real
    deployment answers the client; a slow callback stalls only its own
    worker, never the tick thread's repricing.
    """

    def __init__(self, service: SelectionService, feed: PriceFeed, *,
                 workers: int = 2, queue_capacity: int = 64,
                 top_k: Optional[int] = None,
                 ticks: Optional[int] = None,
                 tick_interval: float = 0.0,
                 idle_sleep: float = 0.001,
                 backoff_base: float = 0.01, backoff_cap: float = 1.0,
                 on_decision: Optional[Any] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 metrics_every: Optional[int] = None,
                 span_sample: int = 32):
        if not isinstance(workers, int) or isinstance(workers, bool) \
                or workers < 1:
            raise ValueError(f"workers must be a positive int, "
                             f"got {workers!r}")
        if not isinstance(queue_capacity, int) or queue_capacity < 1:
            raise ValueError(f"queue_capacity must be a positive int, "
                             f"got {queue_capacity!r}")
        if top_k is None:
            top_k = service.serve_top_k if service.serve_top_k else 3
        if not isinstance(top_k, int) or isinstance(top_k, bool) \
                or top_k < 1:
            raise ValueError(f"top_k must be a positive int, "
                             f"got {top_k!r}")
        if metrics_every is not None and (
                not isinstance(metrics_every, int)
                or isinstance(metrics_every, bool) or metrics_every < 1):
            raise ValueError(f"metrics_every must be a positive int or "
                             f"None, got {metrics_every!r}")
        if not isinstance(span_sample, int) or isinstance(span_sample, bool) \
                or span_sample < 1:
            raise ValueError(f"span_sample must be a positive int, "
                             f"got {span_sample!r}")
        self.service = service
        #: the telemetry registry (DESIGN.md §12); defaults to the
        #: service's so ticks, repricing and serving export as one.
        #: :meth:`metrics` renders it; ``metrics_every`` journals it.
        self.metrics_registry = \
            metrics if metrics is not None else service.metrics
        #: journal a cumulative ``"metrics"`` record (shard 0) every N
        #: successful ticks; ``None`` (default) journals none, keeping
        #: pre-obs golden journals byte-identical.
        self.metrics_every = metrics_every
        #: the worker serve span ("serve.worker") times every
        #: ``span_sample``-th submission per shard (first included) —
        #: the sampling that keeps instrumentation under the <3%
        #: hot-path overhead budget (benchmarks/obs_bench.py); 1 = time
        #: every serve (golden runs).  All *counters* stay exact.
        self.span_sample = span_sample
        self.ticker = PriceTicker(feed, service,
                                  metrics=self.metrics_registry)
        self.workers = workers
        self.queue_capacity = queue_capacity
        self.top_k = top_k
        #: tick budget: the tick loop stops polling past it (``None``
        #: = the feed's recorded horizon when it has one, else
        #: unlimited); control traffic is processed either way.
        self.ticks = ticks if ticks is not None \
            else getattr(feed, "ticks", None)
        self.tick_interval = tick_interval
        self.idle_sleep = idle_sleep
        self.backoff_base = backoff_base
        self.backoff_cap = backoff_cap
        self.on_decision = on_decision

        epoch, prices = service.price_snapshot()
        self._header_line = json.dumps({
            "format": JOURNAL_FORMAT, "version": JOURNAL_VERSION,
            "backend": service.backend,
            "catalog": list(service.catalog.ids()),
            "price_epoch": epoch,
            "prices": [[c, p] for c, p in prices]})

        # shard 0 = tick thread; shards 1..N = workers (append-only
        # lists, one writer each; list.append is atomic under the GIL)
        self._shards: List[List[Dict[str, Any]]] = \
            [[] for _ in range(workers + 1)]
        # per-shard registry cells (the frontend's old private _Counters,
        # migrated onto the registry): cell s is written only by the
        # thread serving shard s — worker s, or the tick thread for 0 —
        # the same single-writer discipline as the journal shards, so
        # increments stay plain int adds with no synchronization.
        reg = self.metrics_registry
        shard_cells = lambda name: [reg.counter(name).cell(s)
                                    for s in range(workers + 1)]
        self._cell_decisions = shard_cells("frontend.decisions")
        self._cell_rejected = shard_cells("frontend.rejected")
        self._cell_forwarded = shard_cells("frontend.forwarded")
        self._cell_cb_errors = shard_cells("frontend.callback_errors")
        self._cell_journal = shard_cells("journal.appends")
        self._c_decisions = reg.counter("frontend.decisions")
        self._c_rejected = reg.counter("frontend.rejected")
        self._c_forwarded = reg.counter("frontend.forwarded")
        self._c_cb_errors = reg.counter("frontend.callback_errors")
        self._c_feed_errors = reg.counter("frontend.feed_errors")
        self._c_snapshots = reg.counter("frontend.snapshots")
        # producer-side accounting: counters, not logs — submit() is
        # called for every submission of a long-running deployment, so
        # anything that grows per call (the old _accepted_log/_shed_log
        # deques) is an unbounded-memory bug, pinned by the memory
        # regression test.  Producer threads each write their own
        # thread-keyed cell.
        self._c_submitted = reg.counter("frontend.submitted")
        self._c_shed = reg.counter("frontend.shed")
        # per-shard serve-span state: countdown-to-next-sample counters
        # (0 = sample now) + bound cells.  spans_enabled/clock are
        # cached as plain attributes — the per-serve cost of sampling
        # must be a couple of list/attribute ops, not registry lookups
        # (the <3% budget is measured, not assumed: obs_bench gates it).
        self._spans_enabled = reg.spans_enabled
        self._clock = reg.clock
        self._span_left = [0] * (workers + 1)
        self._h_serve = [reg.histogram("serve.worker").cell(s)
                         for s in range(workers + 1)]
        self._h_fwd_rtt = reg.histogram("serve.forward_rtt")
        self._queues: List["queue.SimpleQueue"] = \
            [queue.SimpleQueue() for _ in range(workers)]
        self._control: "queue.SimpleQueue" = queue.SimpleQueue()
        self._rr = itertools.count()
        self._route_memo: Dict[Tuple, Route] = {}
        #: registered selections (tick-thread-owned; insertion-ordered,
        #: so snapshot iteration — and with it the journal — is
        #: deterministic).
        self._selections: Dict[Route, bool] = {}
        self._last_tick = -1
        self._feed_failures = 0
        self._closed = False
        self._stop_ticks = False
        self._started = False
        self._thread_errors: List[Tuple[int, BaseException]] = []
        self._tick_thread: Optional[threading.Thread] = None
        self._worker_threads: List[threading.Thread] = []
        self._snapshot: Snapshot = self._build_snapshot()

    # -- snapshot publication (tick thread only) -----------------------------
    def _build_snapshot(self) -> Snapshot:
        svc = self.service
        entries: Dict[Route, SnapshotEntry] = {}
        for route in self._selections:
            klass, excl = route
            try:
                head, _ = svc.rank_head(klass, excl, k=self.top_k)
            except NothingRankableError:
                entries[route] = SnapshotEntry(klass, excl, None)
                continue
            if head[0].score == float("inf"):
                # every catalog entry unprofiled for this selection —
                # same check service.submit applies (DESIGN.md §10)
                entries[route] = SnapshotEntry(klass, excl, None)
                continue
            win = head[0].config_id
            entries[route] = SnapshotEntry(
                klass, excl, tuple(head), svc.catalog.entry(win),
                svc.catalog.hourly_cost(win, svc.price_source))
        return Snapshot(tick=self._last_tick, price_epoch=svc.price_epoch,
                        table_version=svc.price_source.version,
                        k=self.top_k,
                        entries=MappingProxyType(entries))

    def _publish(self) -> None:
        with self.metrics_registry.span("snapshot.build"):
            snap = self._build_snapshot()
        # a single reference store: workers reading self._snapshot see
        # either the old snapshot or the new one, never a mix
        self._snapshot = snap
        self._c_snapshots.inc()

    @property
    def snapshot(self) -> Snapshot:
        """The latest published snapshot (what workers serve from)."""
        return self._snapshot

    # -- routing (read-only, memoized, any thread) ---------------------------
    def _route(self, sub: Submission) -> Route:
        key = (sub.job_id, sub.annotation, sub.exclude_groups)
        hit = self._route_memo.get(key)
        if hit is None:
            klass = self.service.classify(sub.job_id, sub.annotation)
            excl = self.service.effective_exclusions(sub.job_id,
                                                     sub.exclude_groups)
            hit = (klass, tuple(excl))
            self._route_memo[key] = hit
        return hit

    # -- producer side -------------------------------------------------------
    def submit(self, submission: Union[Submission, Hashable]) -> bool:
        """Enqueue a submission; returns False when it was shed (the
        target worker queue is at capacity, or the front-end is closed).
        Callable from any thread.  The capacity check is approximate
        under concurrent producers (``SimpleQueue.qsize`` races by at
        most the producer count) — the bound it enforces is explicit
        backpressure, not an exact high-water mark."""
        if not isinstance(submission, Submission):
            submission = Submission(submission)
        if self._closed:
            self._c_shed.inc()
            return False
        w = next(self._rr) % self.workers
        q = self._queues[w]
        if q.qsize() >= self.queue_capacity:
            self._c_shed.inc()
            return False
        q.put(submission)
        self._c_submitted.inc()
        return True

    def retire_selection(self, job_class: Optional[JobClass] = None,
                         exclude_groups: Sequence[str] = ()) -> None:
        """Ask the tick thread to retire a (class, exclusion) selection:
        it is dropped from the snapshot and retired in the service
        (batched backend: the shared state's member slot is freed).  A
        later submission for it re-registers through the control path —
        or journals a genuine rejection if it is unrankable."""
        self._control.put(("retire", job_class, tuple(exclude_groups)))

    # -- serving (worker w, or inline) ---------------------------------------
    def _serve_one(self, w: int, sub: Submission, t0: float = -1.0) -> None:
        # the lock-free hot path: spans here are hand-rolled (no context
        # manager allocation) and sampled 1-in-span_sample per shard —
        # the <3% overhead budget of DESIGN.md §12.  The serve loops own
        # the sampling countdown (plain local ints; see serve_queued /
        # _worker_loop) and pass ``t0 >= 0`` only for a sampled serve;
        # the default means "not timing this one".  Counters are always
        # exact regardless.
        snap = self._snapshot            # one atomic reference load
        route = self._route(sub)
        entry = snap.entries.get(route)
        if entry is None:
            # selection not published yet (or just retired): the tick
            # thread owns the service, so the miss path goes to it.
            # Stamp the forward time so the control thread can observe
            # the full queue round-trip ("serve.forward_rtt").
            if self._spans_enabled:
                self._control.put(("fwd", sub, self._clock()))
            else:
                self._control.put(sub)
            self._cell_forwarded[w].inc()
            return
        if entry.head is None:
            rec = rejection_record(0, sub.job_id, route[0], route[1],
                                   snap.price_epoch)
            rec["worker"] = w
            rec["snapshot_tick"] = snap.tick
            self._shards[w].append(rec)
            self._cell_journal[w].inc()
            self._cell_rejected[w].inc()
            if t0 >= 0.0:
                self._h_serve[w].observe(self._clock() - t0)
            return
        decision = Decision(
            job_id=sub.job_id, job_class=route[0],
            config_id=entry.head[0].config_id, entry=entry.entry,
            hourly_cost=entry.hourly_cost, ranking=entry.head,
            from_cache=True, price_epoch=snap.price_epoch,
            exclude_groups=route[1], served_via="top_k")
        rec = decision_record(0, decision)
        rec["worker"] = w
        rec["snapshot_tick"] = snap.tick
        self._shards[w].append(rec)
        self._cell_journal[w].inc()
        self._cell_decisions[w].inc()
        if t0 >= 0.0:
            # serve latency proper: snapshot load -> journaled decision,
            # excluding the client-reply callback below (whose cost is
            # the deployment's, not the front-end's)
            self._h_serve[w].observe(self._clock() - t0)
        if self.on_decision is not None:
            try:
                self.on_decision(decision)
            except Exception:
                self._cell_cb_errors[w].inc()

    def serve_queued(self, worker: Optional[int] = None) -> int:
        """Inline mode: serve everything currently queued for ``worker``
        (1-based; ``None`` = every worker, in worker order) on the
        calling thread.  Returns the number of submissions served."""
        served = 0
        spans, clock = self._spans_enabled, self._clock
        stride = self.span_sample
        ws = range(1, self.workers + 1) if worker is None else [worker]
        for w in ws:
            q = self._queues[w - 1]
            left = self._span_left[w]    # sampling countdown, 0 = now
            while True:
                try:
                    sub = q.get_nowait()
                except queue.Empty:
                    break
                if sub is _SENTINEL:
                    continue
                if spans:
                    left -= 1
                    if left < 0:
                        left = stride - 1
                        self._serve_one(w, sub, clock())
                    else:
                        self._serve_one(w, sub)
                else:
                    self._serve_one(w, sub)
                served += 1
            self._span_left[w] = left
        return served

    # -- the tick side (tick thread, or inline) ------------------------------
    def _serve_control(self, sub: Submission) -> int:
        """Serve one forwarded submission through the full service path;
        returns 1 when it registered a new selection."""
        route = self._route(sub)
        fresh = route not in self._selections
        if fresh:
            self._selections[route] = True
        try:
            decision = self.service.submit(
                sub.job_id, annotation=sub.annotation,
                exclude_groups=sub.exclude_groups, top_k=self.top_k)
        except NothingRankableError:
            rec = rejection_record(0, sub.job_id, route[0], route[1],
                                   self.service.price_epoch)
            rec["worker"] = 0
            rec["snapshot_tick"] = self._last_tick
            self._shards[0].append(rec)
            self._cell_journal[0].inc()
            self._cell_rejected[0].inc()
            return 1 if fresh else 0
        rec = decision_record(0, decision)
        rec["worker"] = 0
        rec["snapshot_tick"] = self._last_tick
        self._shards[0].append(rec)
        self._cell_journal[0].inc()
        self._cell_decisions[0].inc()
        if self.on_decision is not None:
            try:
                self.on_decision(decision)
            except Exception:
                self._cell_cb_errors[0].inc()
        return 1 if fresh else 0

    def _drain_control(self) -> int:
        """Process every queued control item; returns the number of
        selection-set changes (registrations + retirements)."""
        changed = 0
        m = self.metrics_registry
        while True:
            try:
                item = self._control.get_nowait()
            except queue.Empty:
                return changed
            if isinstance(item, tuple) and item and item[0] == "retire":
                _, klass, excl = item
                route = (klass, excl)
                if self._selections.pop(route, None) is not None:
                    changed += 1
                self.service.retire_selection(klass, excl)
                continue
            if isinstance(item, tuple) and item and item[0] == "fwd":
                # a worker miss with its forward timestamp: serve it,
                # then observe the whole forwarded round-trip (enqueue
                # -> control drain -> full service path)
                _, sub, t_fwd = item
                changed += self._serve_control(sub)
                if m.spans_enabled:
                    self._h_fwd_rtt.observe(m.clock() - t_fwd)
                continue
            changed += self._serve_control(item)

    def step_tick(self) -> str:
        """One tick-loop iteration: drain control traffic, poll/apply
        one tick (inside the budget), republish the snapshot when
        anything moved.  Returns ``"tick"``, ``"feed-error"`` or
        ``"idle"`` — the threaded loop keys its sleeps off this, and
        inline tests drive it directly for deterministic interleaves."""
        changed = self._drain_control()
        status = "idle"
        deltas = ()
        m = self.metrics_registry
        t0 = -1.0
        if self.ticks is None or self.ticker.tick_count < self.ticks:
            if m.spans_enabled:
                t0 = m.clock()
            try:
                deltas = self.ticker.tick()
            except FeedError as exc:
                self._c_feed_errors.inc()
                self._feed_failures += 1
                rec = feed_error_record(0, exc.tick, str(exc),
                                        self._feed_failures,
                                        self.service.price_epoch)
                rec["worker"] = 0
                rec["tick"] = exc.tick
                self._shards[0].append(rec)
                self._cell_journal[0].inc()
                if changed:
                    self._publish()
                return "feed-error"
            self._feed_failures = 0
            self._last_tick = self.ticker.tick_count - 1
            status = "tick"
            if deltas:
                rec = tick_record(0, deltas, self.service.price_epoch)
                rec["worker"] = 0
                rec["tick"] = self._last_tick
                self._shards[0].append(rec)
                self._cell_journal[0].inc()
        if deltas or changed:
            self._publish()
        if status == "tick":
            if t0 >= 0.0:
                # whole-tick latency, snapshot publication included —
                # successful ticks only (feed errors returned above)
                m.histogram(TICK_SPAN).observe(m.clock() - t0)
            if self.metrics_every is not None and \
                    self.ticker.tick_count % self.metrics_every == 0:
                rec = metrics_record(0, self._last_tick,
                                     self.service.price_epoch, m)
                rec["worker"] = 0
                self._shards[0].append(rec)
                self._cell_journal[0].inc()
        return status

    def backoff_delay(self, failures: Optional[int] = None) -> float:
        """Capped exponential backoff after consecutive feed failures."""
        n = self._feed_failures if failures is None else failures
        return min(self.backoff_cap,
                   self.backoff_base * (2 ** max(0, n - 1)))

    # -- threads -------------------------------------------------------------
    def _tick_loop(self) -> None:
        try:
            while not self._stop_ticks:
                status = self.step_tick()
                if status == "feed-error":
                    # keep serving off the last good snapshot; retry the
                    # same tick after a capped exponential backoff
                    time.sleep(self.backoff_delay())
                elif status == "idle":
                    time.sleep(self.idle_sleep)
                elif self.tick_interval:
                    time.sleep(self.tick_interval)
            # workers are already joined when shutdown flips the flag:
            # anything still in the control queue is the final drain
            self._drain_control()
        except BaseException as exc:          # pragma: no cover - guard
            self._thread_errors.append((0, exc))

    def _worker_loop(self, w: int) -> None:
        q = self._queues[w - 1]
        spans, clock = self._spans_enabled, self._clock
        stride = self.span_sample
        left = self._span_left[w]        # sampling countdown, 0 = now
        try:
            while True:
                try:
                    item = q.get(timeout=0.05)
                except queue.Empty:
                    continue
                if item is _SENTINEL:
                    # drain whatever raced in behind the sentinel, then
                    # exit — nothing accepted is ever dropped
                    while True:
                        try:
                            tail = q.get_nowait()
                        except queue.Empty:
                            break
                        if tail is not _SENTINEL:
                            self._serve_one(w, tail)
                    return
                if spans:
                    left -= 1
                    if left < 0:
                        left = stride - 1
                        self._serve_one(w, item, clock())
                        continue
                self._serve_one(w, item)
        except BaseException as exc:          # pragma: no cover - guard
            self._thread_errors.append((w, exc))

    def warm(self, submissions: Iterable[Union[Submission, Hashable]]
             ) -> int:
        """Pre-register the selections a submission stream will route to
        and publish them, so workers hit the snapshot from the first
        submission.  Call before :meth:`start` (or from the tick
        thread's context).  Returns the registered-selection count."""
        for sub in submissions:
            if not isinstance(sub, Submission):
                sub = Submission(sub)
            self._selections[self._route(sub)] = True
        self._publish()
        return len(self._selections)

    def start(self) -> "ServeFrontend":
        if self._started:
            raise RuntimeError("front-end already started")
        self._started = True
        self._tick_thread = threading.Thread(
            target=self._tick_loop, name="flora-tick", daemon=True)
        self._worker_threads = [
            threading.Thread(target=self._worker_loop, args=(w,),
                             name=f"flora-worker-{w}", daemon=True)
            for w in range(1, self.workers + 1)]
        self._tick_thread.start()
        for t in self._worker_threads:
            t.start()
        return self

    def await_ticks(self, n: Optional[int] = None,
                    timeout: float = 30.0) -> None:
        """Block until the tick thread has consumed ``n`` ticks
        (default: the whole tick budget).  Serving continues off
        intermediate snapshots the whole time — this only waits for
        the market to finish playing out."""
        target = self.ticks if n is None else n
        if target is None:
            raise ValueError("await_ticks needs n= when the front-end "
                             "has no tick budget")
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            if self.ticker.tick_count >= target:
                return
            time.sleep(0.001)
        raise TimeoutError(
            f"tick thread consumed {self.ticker.tick_count}/{target} "
            f"ticks within {timeout}s")

    def drain(self, timeout: float = 30.0) -> None:
        """Block until every accepted submission has been journaled (as
        a decision or a rejection).  Raises ``TimeoutError`` otherwise —
        a deadlocked queue must fail the caller, not hang it."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            if self._drained():
                return
            time.sleep(0.001)
        raise TimeoutError(
            f"front-end failed to drain within {timeout}s: "
            f"{self._c_submitted.value} accepted, "
            f"{self._served_total()} served")

    def _served_total(self) -> int:
        return self._c_decisions.value + self._c_rejected.value

    def _drained(self) -> bool:
        return self._served_total() >= self._c_submitted.value

    def close(self) -> FrontendStats:
        """Inline-mode shutdown: stop accepting, serve every queued
        submission and control item on the calling thread, return
        stats."""
        if self._started:
            raise RuntimeError("close() is the inline-mode drain; a "
                               "started front-end shuts down via "
                               "shutdown()")
        self._closed = True
        while not self._drained():
            before = self._served_total()
            self.serve_queued()
            self._drain_control()
            if self._served_total() == before:  # pragma: no cover
                raise RuntimeError("inline drain made no progress")
        return self.stats()

    def shutdown(self, timeout: float = 30.0) -> FrontendStats:
        """Graceful threaded drain: stop accepting, let every worker
        empty its queue, then let the tick thread serve the remaining
        control traffic, join everything, and surface any thread
        death.  All submitted-or-shed work is accounted for in the
        merged journal afterwards."""
        if not self._started:
            return self.close()
        self._closed = True
        for q in self._queues:
            q.put(_SENTINEL)
        hung = []
        for t in self._worker_threads:
            t.join(timeout)
            if t.is_alive():
                hung.append(t.name)
        self._stop_ticks = True
        assert self._tick_thread is not None
        self._tick_thread.join(timeout)
        if self._tick_thread.is_alive():
            hung.append(self._tick_thread.name)
        if hung:
            raise TimeoutError(f"threads failed to stop: {hung}")
        if self._thread_errors:
            w, exc = self._thread_errors[0]
            raise RuntimeError(
                f"serving thread {w} died: {exc!r}") from exc
        return self.stats()

    def __enter__(self) -> "ServeFrontend":
        return self.start()

    def __exit__(self, exc_type, exc, tb) -> None:
        self.shutdown()

    # -- stats + metrics + journal -------------------------------------------
    def stats(self) -> FrontendStats:
        return FrontendStats(
            submitted=self._c_submitted.value,
            shed=self._c_shed.value,
            decisions=self._c_decisions.value,
            rejected=self._c_rejected.value,
            forwarded=self._c_forwarded.value,
            ticks=self.ticker.tick_count,
            deltas=self.ticker.deltas_applied,
            epochs=self.ticker.epochs_driven,
            feed_errors=self._c_feed_errors.value,
            snapshots=self._c_snapshots.value,
            callback_errors=self._c_cb_errors.value)

    def metrics(self, fmt: str = "prom") -> str:
        """Render the front-end's registry: the merged counters and span
        histograms of the whole tick/serve pipeline, as Prometheus text
        (default) or ``fmt="json"`` (DESIGN.md §12).  Safe to call from
        any thread on a live front-end — merge-on-read never blocks the
        writers."""
        return self.metrics_registry.render(fmt)

    def shard_records(self, worker: int) -> List[Dict[str, Any]]:
        """One shard's records (journal order = append order).  Shard 0
        is the tick thread's (ticks, feed errors, control-path
        decisions); shards 1..N belong to the workers."""
        return [dict(rec) for rec in self._shards[worker]]

    def journal_dump(self) -> str:
        """The merged deterministic journal (see :func:`merge_shards`).
        Meaningful after :meth:`shutdown`/:meth:`close`; calling it on a
        live front-end merges whatever has been journaled so far."""
        return merge_shards(self._header_line,
                            [list(shard) for shard in self._shards])

    def save_journal(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.journal_dump())
