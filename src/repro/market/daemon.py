"""SelectionDaemon: continuous selection over an interleaved event stream.

The production shape of the selector (ROADMAP north star): submissions
and price ticks arrive interleaved; the daemon routes each submission
through ``SelectionService.submit`` — same-class submissions between two
ticks are amortized into one ranking by the service's cache, and each
tick refreshes rankings incrementally instead of recomputing — and
journals every :class:`~repro.selector.Decision` to versioned JSONL
(header line + one record per event, mirroring ``ProfilingStore``'s
schema).  Everything downstream of the seed is deterministic: the same
event stream against the same universe yields a byte-identical journal,
which is the reproducibility bar the benchmarks enforce.
"""
from __future__ import annotations

import dataclasses
import json
from typing import (Any, Dict, Hashable, Iterable, Iterator, List, Optional,
                    Sequence, Tuple, Union)

from repro.core.trace import JobClass
from repro.obs import MetricsRegistry, TICK_SPAN
from repro.selector import Decision, NothingRankableError, SelectionService
from repro.market.feed import FeedError, PriceDelta, PriceFeed, hash_uniform
from repro.market.ticker import PriceTicker

JOURNAL_FORMAT = "repro.market.decision-journal"
#: v2 makes the journal *self-contained* for replay (DESIGN.md §8): the
#: header snapshots the starting prices and price epoch, tick records
#: carry the applied deltas, decision records carry the winner's score
#: and the effective exclusion set.  Within v2, the header also stamps
#: the service's ranking ``backend`` — replays pick their audit mode
#: from it (numpy: bit-identical; jax/jax_batched/jax_sharded/
#: jax_pallas: the tolerance contract, DESIGN.md §9-§10, §13-§14);
#: journals written before
#: the stamp read as numpy.  New backend names are additive: the stamp
#: is data, and consumers resolve it through ``score_contract``.  Decision records served via device-side top-k carry an
#: additive ``served_via`` field (absent = full-ranking serving); a
#: feed that raises mid-tick journals an additive ``feed-error`` record
#: kind (the tick is retried; prices stay at the last good epoch); and
#: journals merged from the concurrent front-end
#: (:mod:`repro.market.frontend`) stamp decisions/rejections with
#: additive ``worker`` / ``snapshot_tick`` fields and tick/feed-error
#: records with ``worker`` / ``tick`` — consumers skip unknown fields
#: and record kinds, so none of these bump the version.
#: Every version bump MUST add a migration note to the table in
#: DESIGN.md §8.
JOURNAL_VERSION = 2


# -- shared record builders --------------------------------------------------
# The daemon and the concurrent front-end (repro.market.frontend)
# journal the *same* record shapes — built here once, so the
# byte-exactness contract (numpy journals golden-file identical) can
# never fork between the two serving layers.

def tick_record(seq: int, deltas: Sequence[PriceDelta],
                price_epoch: int) -> Dict[str, Any]:
    return {"kind": "tick", "seq": seq, "deltas": len(deltas),
            "applied": [[d.config_id, d.price] for d in deltas],
            "price_epoch": price_epoch}


def decision_record(seq: int, decision: Decision) -> Dict[str, Any]:
    rec = {
        "kind": "decision", "seq": seq,
        "job": decision.job_id,
        "job_class": (decision.job_class.value
                      if decision.job_class else None),
        "config": decision.config_id,
        "hourly_cost": decision.hourly_cost,
        "score": decision.ranking[0].score,
        "exclude_groups": list(decision.exclude_groups),
        "from_cache": decision.from_cache,
        "price_epoch": decision.price_epoch,
    }
    if decision.served_via != "ranking":
        # additive field (DESIGN.md §8): stamped only for decisions
        # served without a full ranking materialization (top-k head
        # serving, §10) — absence means full-ranking serving, so
        # journals from full-serving daemons keep their bytes
        rec["served_via"] = decision.served_via
    return rec


def rejection_record(seq: int, job_id: Hashable,
                     job_class: Optional[JobClass],
                     exclude_groups: Sequence[str],
                     price_epoch: int) -> Dict[str, Any]:
    return {"kind": "rejected", "seq": seq, "job": job_id,
            "job_class": job_class.value if job_class else None,
            "exclude_groups": list(exclude_groups),
            "price_epoch": price_epoch}


def feed_error_record(seq: int, tick: int, error: str, failures: int,
                      price_epoch: int) -> Dict[str, Any]:
    """Additive record kind (DESIGN.md §8): ``feed.poll`` raised at
    ``tick`` (the tick is being retried; ``failures`` counts the
    consecutive failures so far) and prices stayed at ``price_epoch``.
    Replay consumers skip unknown kinds, so audits are unchanged."""
    return {"kind": "feed-error", "seq": seq, "tick": tick,
            "error": error, "failures": failures,
            "price_epoch": price_epoch}


def metrics_record(seq: int, tick: int, price_epoch: int,
                   registry: MetricsRegistry) -> Dict[str, Any]:
    """Additive record kind (DESIGN.md §8/§12): a cumulative telemetry
    snapshot taken after tick ``tick`` — every counter plus every span
    histogram (bucket bounds, per-bucket counts, ns-exact sum) from the
    serving registry, names sorted.  Cumulative-not-delta means a
    consumer can recover rates between any two records and the *last*
    record alone carries whole-run percentiles
    (:meth:`repro.market.JournalReplayer.audit` surfaces ``tick.total``
    as ``ReplayAudit.tick_latency``).  Gauges are excluded: they are
    instantaneous reads, not mergeable accounting.  Replay consumers
    that predate the kind skip it, so audits stay byte-exact."""
    snap = registry.snapshot()
    return {"kind": "metrics", "seq": seq, "tick": tick,
            "price_epoch": price_epoch,
            "counters": snap["counters"],
            "histograms": snap["histograms"]}


@dataclasses.dataclass(frozen=True)
class Submission:
    """A job submission event in the daemon stream."""

    job_id: Hashable
    annotation: Optional[JobClass] = None
    exclude_groups: Optional[Tuple[str, ...]] = None


@dataclasses.dataclass(frozen=True)
class Tick:
    """A price-tick event: poll the feed once."""


Event = Union[Submission, Tick]


@dataclasses.dataclass
class DaemonStats:
    events: int = 0
    submissions: int = 0
    decisions: int = 0
    rejected: int = 0           # submissions with nothing rankable
    ticks: int = 0              # mirrors PriceTicker.tick_count
    deltas: int = 0             # mirrors PriceTicker.deltas_applied
    epochs: int = 0             # mirrors PriceTicker.epochs_driven
    feed_errors: int = 0        # polls that raised (tick retried)


class SelectionDaemon:
    """Consume events, decide, journal.  One instance = one journal."""

    def __init__(self, service: SelectionService, feed: PriceFeed,
                 metrics: Optional[MetricsRegistry] = None,
                 metrics_every: Optional[int] = None):
        self.service = service
        #: telemetry registry; defaults to the service's so the whole
        #: tick/serve pipeline exports as one (DESIGN.md §12).
        self.metrics = metrics if metrics is not None else service.metrics
        #: journal a cumulative ``"metrics"`` record every N successful
        #: ticks (``None`` — the default — journals none, keeping
        #: pre-obs journals byte-identical).
        if metrics_every is not None and (
                not isinstance(metrics_every, int)
                or isinstance(metrics_every, bool) or metrics_every < 1):
            raise ValueError(f"metrics_every must be a positive int or "
                             f"None, got {metrics_every!r}")
        self.metrics_every = metrics_every
        self.ticker = PriceTicker(feed, service, metrics=self.metrics)
        self._c_journal = self.metrics.counter("journal.appends")
        self.stats = DaemonStats()
        epoch, prices = service.price_snapshot()
        self._journal: List[str] = [json.dumps({
            "format": JOURNAL_FORMAT, "version": JOURNAL_VERSION,
            "backend": service.backend,
            "catalog": list(service.catalog.ids()),
            "price_epoch": epoch,
            # (config_id, $/h) pairs, not an object: JSON objects force
            # string keys, which would corrupt non-string config ids
            "prices": [[c, p] for c, p in prices]})]
        self._seq = 0
        self._feed_failures = 0     # consecutive; resets on a good tick

    # -- event handling ------------------------------------------------------
    def handle(self, event: Event) -> Optional[Decision]:
        """Process one event; returns the Decision for submissions."""
        self.stats.events += 1
        if isinstance(event, Tick):
            m = self.metrics
            t0 = m.clock() if m.spans_enabled else None
            try:
                deltas = self.ticker.tick()
            except FeedError as exc:
                # typed failure path: the feed died mid-tick, the tick
                # index was not consumed (the next Tick retries it) and
                # prices stayed at the last good epoch — journal the
                # event and keep serving instead of dying
                self.stats.feed_errors += 1
                self._feed_failures += 1
                self._record(feed_error_record(
                    self._next_seq(), exc.tick, str(exc),
                    self._feed_failures, self.service.price_epoch))
                return None
            self._feed_failures = 0
            # the ticker owns the tick bookkeeping; mirror, don't re-count
            self.stats.ticks = self.ticker.tick_count
            self.stats.deltas = self.ticker.deltas_applied
            self.stats.epochs = self.ticker.epochs_driven
            if deltas:
                self._record(tick_record(self._next_seq(), deltas,
                                         self.service.price_epoch))
            if t0 is not None:
                # successful ticks only; a FeedError tick returned above
                m.histogram(TICK_SPAN).observe(m.clock() - t0)
            if self.metrics_every is not None and \
                    self.ticker.tick_count % self.metrics_every == 0:
                self._record(metrics_record(
                    self._next_seq(), self.ticker.tick_count,
                    self.service.price_epoch, m))
            return None
        self.stats.submissions += 1
        try:
            with self.metrics.span("serve.submit"):
                decision = self.service.submit(
                    event.job_id, annotation=event.annotation,
                    exclude_groups=event.exclude_groups)
        except NothingRankableError:
            # nothing rankable for this submission (empty class, id
            # mismatch, retired member): journal the rejection, keep
            # serving — any other ValueError is misconfiguration and
            # propagates
            self.stats.rejected += 1
            klass = self.service.classify(event.job_id, event.annotation)
            excl = self.service.effective_exclusions(event.job_id,
                                                     event.exclude_groups)
            self._record(rejection_record(
                self._next_seq(), event.job_id, klass, excl,
                self.service.price_epoch))
            return None
        self.stats.decisions += 1
        self._record(decision_record(self._next_seq(), decision))
        return decision

    def run(self, events: Iterable[Event]) -> DaemonStats:
        for event in events:
            self.handle(event)
        return self.stats

    def _next_seq(self) -> int:
        self._seq += 1
        return self._seq

    def _record(self, rec: Dict[str, Any]) -> None:
        self._journal.append(json.dumps(rec))
        self._c_journal.inc()

    # -- versioned JSONL journal ---------------------------------------------
    def journal_dump(self) -> str:
        return "\n".join(self._journal) + "\n"

    def save_journal(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.journal_dump())

    @staticmethod
    def loads_journal(text: str) -> Tuple[Dict[str, Any],
                                          List[Dict[str, Any]]]:
        """Parse a journal: (header, records).  Rejects foreign formats."""
        lines = [ln for ln in text.splitlines() if ln.strip()]
        if not lines:
            raise ValueError("empty decision journal")
        header = json.loads(lines[0])
        if header.get("format") != JOURNAL_FORMAT:
            raise ValueError(f"not a decision journal: {header!r}")
        if header.get("version") != JOURNAL_VERSION:
            raise ValueError(
                f"unsupported journal version {header.get('version')!r} "
                f"(current {JOURNAL_VERSION}; migration notes in "
                f"DESIGN.md §8)")
        return header, [json.loads(ln) for ln in lines[1:]]

    @classmethod
    def load_journal(cls, path: str) -> Tuple[Dict[str, Any],
                                              List[Dict[str, Any]]]:
        with open(path) as f:
            return cls.loads_journal(f.read())


def synthetic_stream(job_ids: Sequence[Hashable], n_events: int, *,
                     seed: int = 0, tick_fraction: float = 0.1
                     ) -> Iterator[Event]:
    """A deterministic interleaved submission/tick stream.

    Event kinds and job picks are hash-seeded (same discipline as
    :class:`SimulatedSpotFeed`), so ``(job_ids, n_events, seed)`` fully
    determines the stream — the determinism bar for daemon benchmarks.
    """
    if not job_ids:
        raise ValueError("no job ids to submit")
    for i in range(n_events):
        if hash_uniform(seed, "kind", i) < tick_fraction:
            yield Tick()
        else:
            yield Submission(job_ids[int(hash_uniform(seed, "job", i)
                                         * len(job_ids))])
