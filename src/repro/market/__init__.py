"""The live price market: streaming feeds, repricing, continuous selection.

The paper applies *current* hourly costs at selection time (§II-D); this
package makes "current" a live property instead of a one-shot argument
(DESIGN.md §6).  Data flow:

  feed      -- :class:`PriceFeed` / :class:`SimulatedSpotFeed`: a
               deterministic spot market emitting :class:`PriceDelta`
               batches per tick (seeded mean-reverting walks, regional
               multipliers, scheduled discount/eviction events);
  ticker    -- :class:`PriceTicker`: applies each batch to the service's
               :class:`~repro.selector.PriceTable` and drives price
               epochs through ``SelectionService.reprice`` (the
               incremental :class:`~repro.selector.RankState` path);
  daemon    -- :class:`SelectionDaemon`: consumes an interleaved stream
               of submissions and price ticks, amortizes same-class
               submissions through the ranking cache, and journals every
               :class:`~repro.selector.Decision` to versioned JSONL;
  migration -- :func:`should_migrate`: hysteresis advisor so a running
               fleet only moves when projected savings beat the switch
               cost (wired into ``serve.engine.plan_decode_placement``);
  frontend  -- :class:`ServeFrontend`: the concurrent serving layer —
               one tick thread owns the repricing and publishes an
               immutable :class:`Snapshot` (per-selection top-k heads)
               per tick; N workers serve :class:`~repro.selector.Decision`\\ s
               lock-free off the latest snapshot, with bounded queues,
               explicit shed, and worker-sharded journals merged into
               one deterministic, audit-clean journal (DESIGN.md §11);
  replay    -- :class:`RecordedPriceFeed` / :func:`record_feed`: price
               histories as replayable CSV fixtures, and
               :class:`JournalReplayer`: audit a decision journal against
               cold re-ranks at each reconstructed price epoch, then
               score it against per-epoch and static-price oracles
               (DESIGN.md §8);
  polling   -- :class:`PollingPriceFeed`: the live billing-API adapter —
               any ``poller(tick) -> payload`` callable behind the typed
               :class:`FeedError`/backoff path, with ``record_feed``
               turning any poll into a replayable fixture
               (DESIGN.md §15);
  turbulence-- adversarial market generators (coordinated eviction
               storms, correlated regional spikes, flash-crash-and-
               recover), named :data:`TURBULENCE_PRESETS`, and the
               deviation-vs-turbulence sweep driver
               (:func:`run_point` / :func:`run_sweep`, DESIGN.md §15).
"""
from repro.market.daemon import (DaemonStats, SelectionDaemon, Submission,
                                 Tick, metrics_record, synthetic_stream)
from repro.market.feed import (FeedError, MarketEvent, PriceDelta, PriceFeed,
                               SimulatedSpotFeed)
from repro.market.frontend import (FrontendStats, ServeFrontend, Snapshot,
                                   SnapshotEntry, merge_shards)
from repro.market.migration import MigrationAdvice, should_migrate
from repro.market.polling import PollingPriceFeed
from repro.market.replay import (JournalReplayer, RecordedPriceFeed,
                                 ReplayAudit, ReplayMismatch,
                                 ReplayedDecision, record_feed)
from repro.market.ticker import PriceTicker
from repro.market.turbulence import (LaggedPriceFeed, TURBULENCE_PRESETS,
                                     TurbulencePreset, TurbulentMarket,
                                     correlated_spike_events,
                                     eviction_storm_events,
                                     flash_crash_events, make_market,
                                     run_point, run_sweep)

__all__ = [
    "DaemonStats", "FeedError", "FrontendStats", "JournalReplayer",
    "LaggedPriceFeed", "MarketEvent", "MigrationAdvice", "PollingPriceFeed",
    "PriceDelta", "PriceFeed", "PriceTicker", "RecordedPriceFeed",
    "ReplayAudit", "ReplayMismatch", "ReplayedDecision", "SelectionDaemon",
    "ServeFrontend", "SimulatedSpotFeed", "Snapshot", "SnapshotEntry",
    "Submission", "TURBULENCE_PRESETS", "Tick", "TurbulencePreset",
    "TurbulentMarket", "correlated_spike_events", "eviction_storm_events",
    "flash_crash_events", "make_market", "merge_shards", "metrics_record",
    "record_feed", "run_point", "run_sweep", "should_migrate",
    "synthetic_stream",
]
