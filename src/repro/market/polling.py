"""PollingPriceFeed: the live billing-API adapter shape (DESIGN.md §15).

A real deployment gets quotes by polling a cloud billing API.  That
call can time out, return garbage, or return a page with half the
fields missing — and none of those may kill the serving loop or move
the price table.  :class:`PollingPriceFeed` wraps any billing-API-style
callable behind the market's existing typed failure path: every
failure mode raises :class:`~repro.market.FeedError`, which the ticker
surfaces *before* the tick index is consumed, so the daemon/front-end
journal a ``feed-error`` record, keep serving off the last good epoch,
and retry the same tick with capped backoff (DESIGN.md §6/§11).

The network is the caller's problem by design: the adapter takes a
``poller(tick) -> payload`` callable, so tests stub it with canned
payloads and production wraps an HTTP client.  Payloads accepted:

  * an iterable of quote mappings — ``{"config_id": ..., "price": ...}``
    (the REST-page shape); extra keys are ignored;
  * an iterable of ``(config_id, price)`` pairs or
    :class:`~repro.market.PriceDelta`\\ s;
  * a mapping with a ``"quotes"`` key holding either of the above
    (the enveloped-response shape).

Everything else — a string, a non-iterable, an entry that is neither
mapping nor pair — is *malformed* and raises.  A quote entry whose
``price`` is absent or ``None`` is a *partial* response (the API
answered but the page is incomplete) and raises.  A quote that parses
but could never be recorded — non-positive, non-finite, duplicate
config in one batch, unhashable id — raises, because
:func:`~repro.market.record_feed` would refuse it at capture time and
a feed that cannot be recorded cannot be replayed or audited.

A successful poll is exactly a :class:`~repro.market.PriceDelta` batch,
so :func:`~repro.market.record_feed` turns any poll into a replayable
CSV fixture and the identical sweep code path
(:func:`repro.market.turbulence.run_point`) runs over recorded and
polled feeds, producing identical curves for identical quote streams.
"""
from __future__ import annotations

import time
from typing import (Any, Callable, Hashable, Mapping, Optional, Set, Tuple)

import numpy as np

from repro.market.feed import FeedError, PriceDelta


def _fail(tick: int, kind: str, detail: str) -> "FeedError":
    return FeedError(f"{kind} poll response at tick {tick}: {detail}",
                     tick)


def _parse_entry(entry: Any, tick: int) -> PriceDelta:
    """One quote entry -> PriceDelta; typed FeedError on anything else."""
    if isinstance(entry, PriceDelta):
        config_id, price = entry.config_id, entry.price
    elif isinstance(entry, Mapping):
        if "config_id" not in entry:
            raise _fail(tick, "malformed",
                        f"quote entry without config_id: {entry!r}")
        if "price" not in entry or entry["price"] is None:
            # the API answered, but this quote is incomplete — a
            # partial page must be retried whole, never half-applied
            raise _fail(tick, "partial",
                        f"quote for {entry['config_id']!r} has no price")
        config_id, price = entry["config_id"], entry["price"]
    elif isinstance(entry, (tuple, list)) and len(entry) == 2:
        config_id, price = entry
        if price is None:
            raise _fail(tick, "partial",
                        f"quote for {config_id!r} has no price")
    else:
        raise _fail(tick, "malformed",
                    f"quote entry is not a mapping or pair: {entry!r}")
    if isinstance(config_id, (list, dict, set)):
        raise _fail(tick, "malformed",
                    f"config_id {config_id!r} is not hashable")
    if isinstance(price, bool) or not isinstance(price, (int, float)):
        raise _fail(tick, "malformed",
                    f"price {price!r} for {config_id!r} is not a number")
    price = float(price)
    if not np.isfinite(price) or not price > 0:
        raise _fail(tick, "malformed",
                    f"non-positive or non-finite price {price!r} for "
                    f"{config_id!r}")
    return PriceDelta(config_id, price)


class PollingPriceFeed:
    """A :class:`~repro.market.PriceFeed` over a billing-API callable.

    ``poller(tick)`` produces the raw response for one tick; this class
    owns validation and the typed failure contract.  An optional
    ``timeout_s`` budget turns slow responses into the timeout failure
    mode (measured on ``clock``, injectable so tests need no real
    waiting) — the response is *discarded* even though it arrived:
    a quote slower than the tick cadence is stale by definition.

    Failures never advance anything: the tick index lives in the
    ticker, which only consumes it after a successful poll, and this
    adapter's own :attr:`polls`/:attr:`batches` accounting moves only
    on success (:attr:`failures` counts the raises).  Retrying the same
    tick after a transient outage is therefore exactly a fresh call.
    """

    def __init__(self, poller: Callable[[int], Any], *,
                 timeout_s: Optional[float] = None,
                 clock: Callable[[], float] = time.monotonic):
        if timeout_s is not None and not timeout_s > 0:
            raise ValueError(f"timeout_s must be positive, got {timeout_s}")
        self._poller = poller
        self.timeout_s = timeout_s
        self._clock = clock
        #: successful polls (and how many returned a non-empty batch).
        self.polls = 0
        self.batches = 0
        #: polls that raised a FeedError (timeout/malformed/partial/...).
        self.failures = 0

    def poll(self, tick: int) -> Tuple[PriceDelta, ...]:
        t0 = self._clock()
        try:
            payload = self._poller(tick)
        except FeedError:
            self.failures += 1
            raise
        except Exception as exc:
            self.failures += 1
            raise FeedError(
                f"poll failed at tick {tick}: "
                f"{type(exc).__name__}: {exc}", tick) from exc
        if self.timeout_s is not None and \
                self._clock() - t0 > self.timeout_s:
            self.failures += 1
            raise _fail(tick, "timed-out",
                        f"response exceeded the {self.timeout_s:g}s "
                        f"budget (stale by definition)")
        try:
            deltas = self._validate(payload, tick)
        except FeedError:
            self.failures += 1
            raise
        self.polls += 1
        if deltas:
            self.batches += 1
        return deltas

    @staticmethod
    def _validate(payload: Any, tick: int) -> Tuple[PriceDelta, ...]:
        if isinstance(payload, Mapping):
            if "quotes" not in payload:
                raise _fail(tick, "malformed",
                            f"response object without 'quotes': "
                            f"{sorted(payload)!r}")
            payload = payload["quotes"]
        if payload is None or isinstance(payload, (str, bytes)):
            raise _fail(tick, "malformed",
                        f"response is not a quote list: {payload!r}")
        try:
            entries = list(payload)
        except TypeError:
            raise _fail(tick, "malformed",
                        f"response is not iterable: {payload!r}")
        deltas = []
        seen: Set[Hashable] = set()
        for entry in entries:
            d = _parse_entry(entry, tick)
            if d.config_id in seen:
                # ambiguous: which quote is "the" price depends on
                # application order, which replay must not guess
                # (mirrors RecordedPriceFeed.loads)
                raise _fail(tick, "malformed",
                            f"duplicate quote for {d.config_id!r}")
            seen.add(d.config_id)
            deltas.append(d)
        return tuple(deltas)
