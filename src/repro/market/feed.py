"""Streaming price feeds: the market side of live selection (DESIGN.md §6).

A :class:`PriceFeed` emits batches of :class:`PriceDelta` — absolute
re-quotes, never relative adjustments, so replaying a batch is idempotent
and a dropped batch cannot silently skew later prices.

:class:`SimulatedSpotFeed` is the deterministic reference market used by
the benchmarks, tests and examples.  It follows the repo's hash-seeding
discipline (:mod:`repro.core.spark_sim`): every random draw is a pure
function of ``(seed, purpose, config, tick)`` through md5.  The walk
itself is stateful (each quote reverts from the *current* price), so
determinism means: two independently constructed feeds with the same
seed, polled with the same in-order tick sequence from fresh state,
agree batch-for-batch — which is what the ticker does and the daemon
benchmark enforces.  Polling out of order or resuming mid-stream is
path-dependent and yields different quotes.  The dynamics:

  * **mean-reverting log walks** — each config's log-price reverts to its
    (event-adjusted) target with rate ``reversion`` under per-tick
    ``volatility`` shocks, clamped to a band around base — the standard
    spot-market shape: wanders, occasionally spikes, never runs away;
  * **regional multipliers** — configs hash into regions; scheduled
    :class:`MarketEvent` windows (``discount`` or ``eviction`` spikes)
    shift a whole region's reversion target for their duration, and every
    config of the region re-quotes at the window boundaries so the shift
    lands immediately;
  * **sparse ticks** — outside event boundaries only ``change_fraction``
    of configs re-quote per tick (hash-selected), which is exactly the
    regime the incremental ``reprice`` path is built for.
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
from typing import (Dict, Hashable, Iterator, Mapping, Protocol, Sequence,
                    Tuple, runtime_checkable)


def hash_digest(seed: int, *key: object) -> int:
    """64-bit digest of ``seed|key`` — the repo's hash-seeding discipline
    (:mod:`repro.core.spark_sim`): every draw is a pure function of its
    key, shared by the feed and the daemon's synthetic stream so their
    determinism contracts can never drift apart."""
    raw = "|".join(str(k) for k in (seed,) + key).encode()
    return int.from_bytes(hashlib.md5(raw).digest()[:8], "big")


def hash_uniform(seed: int, *key: object) -> float:
    """Deterministic uniform draw in (0, 1) from :func:`hash_digest`."""
    return (hash_digest(seed, *key) + 1) / (2 ** 64 + 2)


class FeedError(RuntimeError):
    """A feed failed to produce its batch for a tick.

    Raised by :class:`~repro.market.PriceTicker` when ``feed.poll``
    raises (a live billing API timing out, a recording truncated
    mid-read); the original exception rides along as ``__cause__`` and
    :attr:`tick` names the tick that failed.  Typed so serving layers
    can journal a ``feed-error`` record and keep serving off the last
    good price epoch — the failed tick index was *not* consumed, so the
    next poll retries the same tick — instead of dying mid-stream.
    """

    def __init__(self, message: str, tick: int):
        super().__init__(message)
        #: the tick index whose poll failed (and will be retried).
        self.tick = tick


@dataclasses.dataclass(frozen=True)
class PriceDelta:
    """One absolute re-quote: ``config_id`` now costs ``price`` $/h."""

    config_id: Hashable
    price: float


@runtime_checkable
class PriceFeed(Protocol):
    """A source of per-tick price-delta batches."""

    def poll(self, tick: int) -> Tuple[PriceDelta, ...]:
        """The (possibly empty) batch of re-quotes at ``tick``."""
        ...


@dataclasses.dataclass(frozen=True)
class MarketEvent:
    """A scheduled regional price regime: discount window or eviction spike.

    For ``start_tick <= tick < start_tick + duration`` the region's
    reversion target is ``base * factor`` (``factor`` < 1 models a
    committed-use / off-peak discount, > 1 a spot eviction-pressure
    spike).
    """

    region: str
    start_tick: int
    duration: int
    factor: float
    kind: str = "discount"      # "discount" | "eviction" (labeling only)

    def active(self, tick: int) -> bool:
        return self.start_tick <= tick < self.start_tick + self.duration

    def boundary(self, tick: int) -> bool:
        return tick == self.start_tick or tick == self.start_tick + \
            self.duration


DEFAULT_REGIONS = ("us-central1", "europe-west3", "asia-east1")


class SimulatedSpotFeed:
    """Deterministic seeded spot market over a fixed config universe."""

    def __init__(self, base_prices: Mapping[Hashable, float], *,
                 seed: int = 0, change_fraction: float = 0.01,
                 reversion: float = 0.15, volatility: float = 0.06,
                 band: float = 8.0,
                 regions: Sequence[str] = DEFAULT_REGIONS,
                 events: Sequence[MarketEvent] = ()):
        if not 0.0 <= change_fraction <= 1.0:
            raise ValueError(f"change_fraction {change_fraction} not in "
                             f"[0, 1]")
        if band <= 1.0:
            raise ValueError("band must exceed 1 (price clamp base*[1/b, b])")
        self.seed = seed
        self.change_fraction = change_fraction
        self.reversion = reversion
        self.volatility = volatility
        self.band = band
        self.events = tuple(events)
        self._base: Dict[Hashable, float] = {}
        self._price: Dict[Hashable, float] = {}
        self._region: Dict[Hashable, str] = {}
        for cid, price in base_prices.items():
            if not price > 0:
                raise ValueError(f"non-positive base price for {cid!r}")
            self._base[cid] = float(price)
            self._price[cid] = float(price)
            self._region[cid] = regions[self._digest("region", cid)
                                        % len(regions)]

    # -- deterministic randomness (spark_sim hash-seeding style) ------------
    def _digest(self, *key: object) -> int:
        return hash_digest(self.seed, *key)

    def _uniform(self, *key: object) -> float:
        return hash_uniform(self.seed, *key)

    def _gauss(self, *key: object) -> float:
        u1 = self._uniform(*key, "u1")
        u2 = self._uniform(*key, "u2")
        return math.sqrt(-2.0 * math.log(u1)) * math.cos(2 * math.pi * u2)

    # -- market state -------------------------------------------------------
    def region_of(self, config_id: Hashable) -> str:
        return self._region[config_id]

    def price_of(self, config_id: Hashable) -> float:
        """The feed's current quote (last emitted, or base)."""
        return self._price[config_id]

    def _region_factor(self, region: str, tick: int) -> float:
        factor = 1.0
        for ev in self.events:
            if ev.region == region and ev.active(tick):
                factor *= ev.factor
        return factor

    def _boundary_regions(self, tick: int) -> Tuple[str, ...]:
        return tuple(ev.region for ev in self.events if ev.boundary(tick))

    # -- the feed protocol --------------------------------------------------
    def poll(self, tick: int) -> Tuple[PriceDelta, ...]:
        """Re-quotes at ``tick`` (insertion-ordered, deterministic)."""
        boundary = set(self._boundary_regions(tick))
        deltas = []
        for cid, current in self._price.items():
            region = self._region[cid]
            forced = region in boundary
            if not forced and \
                    self._uniform("sel", cid, tick) >= self.change_fraction:
                continue
            target = self._base[cid] * self._region_factor(region, tick)
            if forced:
                # regime change: snap to the new target (plus shock) so the
                # discount/eviction lands at the boundary, not 1/reversion
                # ticks later
                new = target * math.exp(
                    self.volatility * self._gauss("walk", cid, tick))
            else:
                step = self.reversion * (math.log(target)
                                         - math.log(current)) \
                    + self.volatility * self._gauss("walk", cid, tick)
                new = current * math.exp(step)
            lo = self._base[cid] / self.band
            hi = self._base[cid] * self.band
            new = min(max(new, lo), hi)
            if new != current:
                self._price[cid] = new
                deltas.append(PriceDelta(cid, new))
        return tuple(deltas)

    def stream(self, ticks: int, start: int = 0
               ) -> Iterator[Tuple[PriceDelta, ...]]:
        """Convenience: successive ``poll`` batches for ``ticks`` ticks."""
        for t in range(start, start + ticks):
            yield self.poll(t)
