"""Market-turbulence evaluation: adversarial markets + deviation sweeps.

The paper's headline claim (<6% mean deviation from cost-optimal,
Fig. 2) is judged against one *static* price-ratio axis, and the replay
harness (DESIGN.md §8) likewise judges exactly one recorded 40-tick
history.  This module asks the live-repricing selector the question
neither answers: **how does selection quality degrade as the market
gets hostile?**  (DESIGN.md §15.)

Three pieces:

  * **adversarial market generators** — seed-deterministic families of
    :class:`~repro.market.MarketEvent` schedules layered on the
    :class:`~repro.market.SimulatedSpotFeed` walk knobs (volatility,
    change fraction, reversion):

      - :func:`eviction_storm_events`: coordinated eviction storms —
        every region spikes inside one window, starts staggered by a
        few ticks, magnitudes drawn per region;
      - :func:`correlated_spike_events`: correlated regional price
        spikes — a subset of >=2 regions spikes *on the same tick*;
      - :func:`flash_crash_events`: flash-crash-and-recover — all
        regions collapse together for a few ticks, then overshoot
        above base on the recovery before reverting.

    Every draw goes through the repo's hash-seeding discipline
    (:func:`repro.market.feed.hash_uniform`): a generator is a pure
    function of ``(seed, ticks, knobs)``, so two independently
    constructed markets with the same preset and seed agree event for
    event and quote for quote, byte for byte — including across a
    :func:`~repro.market.record_feed` round-trip (the property pinned
    by ``tests/test_turbulence.py``).

  * **presets** — :data:`TURBULENCE_PRESETS` names the grid axis: a
    monotone ``level`` from ``calm`` (the bundled-fixture regime of
    ``examples/data/gcp_spot_prices.csv`` — ``make_market("calm", base,
    seed=11, ticks=40)`` regenerates that fixture byte-for-byte, which
    ``benchmarks/turbulence_bench.py`` gates) up through ``volatile``,
    ``correlated_spikes``, ``eviction_storm``, ``flash_crash`` and
    ``laggy_storm`` (an eviction storm seen through a stale feed —
    the ``feed_latency`` knob wraps the market in
    :class:`LaggedPriceFeed`).

  * **the sweep driver** — :func:`run_point` drives a
    :class:`~repro.market.SelectionDaemon` over one (market, backend)
    cell, audits the journal under the backend's
    :class:`~repro.selector.ScoreContract`
    (:meth:`~repro.market.JournalReplayer.audit`) and scores it with
    :func:`repro.core.evaluate.dynamic_evaluation`;  :func:`run_sweep`
    spans the preset x backend grid, replaying every generated market
    through a :func:`~repro.market.record_feed` round-trip so each
    point is a fixture, not a live simulation.  ``run_point`` takes
    *any* :class:`~repro.market.PriceFeed` — the identical code path
    runs over a :class:`~repro.market.RecordedPriceFeed` fixture and a
    stubbed :class:`~repro.market.PollingPriceFeed`
    (:mod:`repro.market.polling`), and identical quote streams produce
    identical curves (the ISSUE 10 acceptance bar).

Latency and the truth judge: a lagged feed shows the daemon a delayed
market, and the journal — which is internally consistent by
construction — can only judge the daemon against the prices it was
shown.  ``run_point(truth=...)`` therefore also re-judges every
decision against the *unlagged* market state at its tick (the price the
cloud would actually have billed), surfacing the real cost of feed
staleness; for an unlagged feed the truth judge and the journal judge
are the same numbers exactly, which the tests pin.
"""
from __future__ import annotations

import dataclasses
from typing import (Dict, Hashable, Iterable, List, Mapping, Optional,
                    Sequence, Tuple)

from repro.core.evaluate import TurbulencePoint, dynamic_evaluation
from repro.market.daemon import Event, SelectionDaemon, Submission, Tick
from repro.market.feed import (DEFAULT_REGIONS, MarketEvent, PriceDelta,
                               PriceFeed, SimulatedSpotFeed, hash_uniform)
from repro.market.replay import JournalReplayer, RecordedPriceFeed, record_feed
from repro.obs import SWEEP_SPAN
from repro.selector import SelectionService


# --- adversarial event generators --------------------------------------------
# All draws are pure functions of (seed, purpose, indices) through
# hash_uniform — the SimulatedSpotFeed discipline — so a schedule is
# byte-reproducible from its arguments alone.

def eviction_storm_events(seed: int, ticks: int, *,
                          storms: int = 3, severity: float = 3.0,
                          regions: Sequence[str] = DEFAULT_REGIONS
                          ) -> Tuple[MarketEvent, ...]:
    """Coordinated eviction storms: every region spikes in one window.

    Each storm picks a start and width, then *every* region raises an
    eviction event inside it — starts staggered by 0-3 ticks (capacity
    crunches roll across regions, they don't teleport), magnitudes
    drawn per region in ``[severity, 2 * severity)``.
    """
    if ticks < 1:
        raise ValueError(f"ticks must be positive, got {ticks}")
    events: List[MarketEvent] = []
    span = max(1, ticks - 24)
    for i in range(storms):
        start = 4 + int(hash_uniform(seed, "storm-start", i) * span)
        width = 8 + int(hash_uniform(seed, "storm-width", i) * 8)
        for region in regions:
            stagger = int(hash_uniform(seed, "storm-lag", i, region) * 4)
            factor = severity * (
                1.0 + hash_uniform(seed, "storm-mag", i, region))
            events.append(MarketEvent(region, start + stagger, width,
                                      factor, "eviction"))
    return tuple(events)


def correlated_spike_events(seed: int, ticks: int, *,
                            spikes: int = 4, severity: float = 2.5,
                            regions: Sequence[str] = DEFAULT_REGIONS
                            ) -> Tuple[MarketEvent, ...]:
    """Correlated regional price spikes: >=2 regions jump on one tick.

    Each spike draws a start/duration, then every region independently
    joins with probability 0.75 — and the first two regions are always
    in, so no spike ever degenerates to a single-region blip (the
    correlation is the point: a selector that just shifts load to the
    cheapest region must find *both* escape hatches shut).
    """
    if ticks < 1:
        raise ValueError(f"ticks must be positive, got {ticks}")
    events: List[MarketEvent] = []
    span = max(1, ticks - 12)
    for i in range(spikes):
        start = 2 + int(hash_uniform(seed, "spike-start", i) * span)
        duration = 3 + int(hash_uniform(seed, "spike-width", i) * 6)
        for r, region in enumerate(regions):
            if r >= 2 and hash_uniform(seed, "spike-join", i,
                                       region) >= 0.75:
                continue
            factor = severity * (
                1.0 + 0.5 * hash_uniform(seed, "spike-mag", i, region))
            events.append(MarketEvent(region, start, duration, factor,
                                      "eviction"))
    return tuple(events)


def flash_crash_events(seed: int, ticks: int, *,
                       crashes: int = 2, depth: float = 0.25,
                       overshoot: float = 1.8,
                       regions: Sequence[str] = DEFAULT_REGIONS
                       ) -> Tuple[MarketEvent, ...]:
    """Flash-crash-and-recover: everything collapses, then overshoots.

    Each crash drops *every* region to ``depth`` of base for a short
    window (3-6 ticks), immediately followed by a recovery overshoot to
    ``overshoot`` of base for half as long, then reversion to base.
    The crash and its recovery share boundaries, so the regime flips
    land on consecutive ticks — the worst case for a selector that
    amortizes rankings between ticks.
    """
    if ticks < 1:
        raise ValueError(f"ticks must be positive, got {ticks}")
    if not 0.0 < depth < 1.0:
        raise ValueError(f"depth must be in (0, 1), got {depth}")
    events: List[MarketEvent] = []
    span = max(1, ticks - 16)
    for i in range(crashes):
        start = 2 + int(hash_uniform(seed, "crash-start", i) * span)
        duration = 3 + int(hash_uniform(seed, "crash-width", i) * 4)
        recover = max(2, duration // 2)
        for region in regions:
            events.append(MarketEvent(region, start, duration, depth,
                                      "flash-crash"))
            events.append(MarketEvent(region, start + duration, recover,
                                      overshoot, "recovery"))
    return tuple(events)


# --- the feed-latency knob ---------------------------------------------------

class LaggedPriceFeed:
    """A feed seen through a stale pipe: ``poll(t)`` is the wrapped
    feed's batch from ``lag`` ticks ago (empty while the pipe fills).

    Models billing-API propagation delay without touching the wrapped
    feed's determinism: the lagged stream is a pure reindexing of the
    underlying one, so recordings and replays stay byte-exact.  The
    daemon served through a lagged feed is still *internally*
    consistent — its journal audits clean — it is just consistently
    late, which is exactly what the sweep's truth judge measures
    (:func:`run_point` ``truth=``).
    """

    def __init__(self, feed: PriceFeed, lag: int):
        if not (isinstance(lag, int) and lag >= 0):
            raise ValueError(f"lag must be a non-negative int, got {lag!r}")
        self.feed = feed
        self.lag = lag

    def poll(self, tick: int) -> Tuple[PriceDelta, ...]:
        if tick < self.lag:
            return ()
        return self.feed.poll(tick - self.lag)


# --- presets -----------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TurbulencePreset:
    """One named point on the turbulence axis (DESIGN.md §15).

    Walk knobs (``volatility``, ``change_fraction``, ``reversion``,
    ``band``) parameterize the :class:`SimulatedSpotFeed` directly;
    ``storms``/``spikes``/``crashes`` + ``severity`` drive the
    adversarial generators above; ``fixed_events`` pins an explicit
    schedule (the calm preset reproduces the bundled fixture's two
    windows); ``feed_latency`` wraps the market in
    :class:`LaggedPriceFeed`.  ``level`` orders presets on the
    deviation-vs-turbulence x-axis — it is a label, not a knob.
    """

    name: str
    level: float
    volatility: float = 0.08
    change_fraction: float = 0.25
    reversion: float = 0.15
    band: float = 8.0
    storms: int = 0
    spikes: int = 0
    crashes: int = 0
    severity: float = 2.5
    feed_latency: int = 0
    fixed_events: Tuple[MarketEvent, ...] = ()

    def events(self, seed: int, ticks: int) -> Tuple[MarketEvent, ...]:
        """The preset's full event schedule — a pure function of
        ``(seed, ticks)`` plus the preset's own knobs."""
        events = list(self.fixed_events)
        if self.storms:
            events.extend(eviction_storm_events(
                seed, ticks, storms=self.storms, severity=self.severity))
        if self.spikes:
            events.extend(correlated_spike_events(
                seed, ticks, spikes=self.spikes, severity=self.severity))
        if self.crashes:
            events.extend(flash_crash_events(seed, ticks,
                                             crashes=self.crashes))
        return tuple(events)


#: The named turbulence axis, calm -> hostile.  ``calm`` is the exact
#: regime of the bundled ``gcp_spot_prices.csv`` fixture (knobs and
#: fixed events from ``examples/replay_eval.py --record``), so the
#: sweep's baseline point is the recorded 6.4%-mean-deviation market —
#: and regenerating it byte-identical is a bench gate.
TURBULENCE_PRESETS: Dict[str, TurbulencePreset] = {
    p.name: p for p in (
        TurbulencePreset(
            "calm", level=0.0, volatility=0.08, change_fraction=0.25,
            fixed_events=(
                MarketEvent("us-central1", start_tick=8, duration=10,
                            factor=0.55, kind="discount"),
                MarketEvent("europe-west3", start_tick=20, duration=6,
                            factor=2.5, kind="eviction"))),
        TurbulencePreset("volatile", level=1.0, volatility=0.22,
                         change_fraction=0.40, reversion=0.10),
        TurbulencePreset("correlated_spikes", level=2.0, volatility=0.10,
                         change_fraction=0.30, spikes=4, severity=2.5),
        TurbulencePreset("eviction_storm", level=3.0, volatility=0.12,
                         change_fraction=0.35, storms=3, severity=3.0),
        TurbulencePreset("flash_crash", level=4.0, volatility=0.10,
                         change_fraction=0.40, crashes=2),
        TurbulencePreset("laggy_storm", level=5.0, volatility=0.12,
                         change_fraction=0.35, storms=3, severity=3.0,
                         feed_latency=3),
    )
}


def preset(name_or_preset: "str | TurbulencePreset") -> TurbulencePreset:
    """Resolve a preset by name (or pass a custom one through)."""
    if isinstance(name_or_preset, TurbulencePreset):
        return name_or_preset
    try:
        return TURBULENCE_PRESETS[name_or_preset]
    except KeyError:
        raise ValueError(
            f"unknown turbulence preset {name_or_preset!r} (have "
            f"{sorted(TURBULENCE_PRESETS)})")


@dataclasses.dataclass(frozen=True)
class TurbulentMarket:
    """One generated market: the feed plus everything that made it.

    ``feed`` is the daemon-facing side (lag-wrapped when the preset has
    ``feed_latency``); ``raw`` is the unlagged walk — the *true* market
    the truth judge bills against.  Both are fresh stateful feeds:
    construct a new market (or go through a ``record_feed`` round-trip,
    as :func:`run_sweep` does) rather than re-polling one mid-stream.
    """

    preset: TurbulencePreset
    seed: int
    ticks: int
    events: Tuple[MarketEvent, ...]
    feed: PriceFeed
    raw: SimulatedSpotFeed


def make_market(name_or_preset: "str | TurbulencePreset",
                base_prices: Mapping[Hashable, float], *,
                seed: int, ticks: int) -> TurbulentMarket:
    """Build one seed-deterministic adversarial market from a preset.

    Two calls with equal arguments yield markets whose event schedules
    are equal and whose quote streams agree batch for batch — the
    byte-determinism contract every preset inherits from
    :class:`SimulatedSpotFeed` and the hash-seeded generators.
    """
    p = preset(name_or_preset)
    events = p.events(seed, ticks)
    raw = SimulatedSpotFeed(
        base_prices, seed=seed, change_fraction=p.change_fraction,
        reversion=p.reversion, volatility=p.volatility, band=p.band,
        events=events)
    feed: PriceFeed = raw if p.feed_latency == 0 else \
        LaggedPriceFeed(raw, p.feed_latency)
    return TurbulentMarket(preset=p, seed=seed, ticks=ticks,
                           events=events, feed=feed, raw=raw)


# --- the sweep driver --------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _TruthDecision:
    """A journaled decision re-keyed to the *true* market's prices."""

    seq: int
    job_id: Hashable
    job_class: object
    config_id: Hashable
    price_epoch: int
    prices: Mapping[Hashable, float]


def run_point(service: SelectionService, feed: PriceFeed,
              events: Iterable[Event], *,
              preset_name: str = "", level: float = 0.0,
              feed_kind: str = "recorded",
              truth: Optional[RecordedPriceFeed] = None
              ) -> TurbulencePoint:
    """Drive one sweep cell: daemon -> journal audit -> dynamic eval.

    The code path is feed-agnostic — a :class:`RecordedPriceFeed`
    fixture and a stubbed :class:`~repro.market.PollingPriceFeed`
    serving the same quotes produce byte-identical journals and hence
    identical curves.  The journal is audited under the backend's
    :class:`~repro.selector.ScoreContract` before it is scored; the
    returned point carries both outcomes (a point whose audit failed is
    not evidence about the selector, and the bench gates on it).

    ``truth`` re-judges each decision against the unlagged market: the
    price state after *every* batch the true market emitted up to the
    decision's tick, not just the ones a lagged feed had delivered.
    For an unlagged feed the two judgments are identical.
    """
    metrics = service.metrics
    c_points = metrics.counter("sweep.points")
    c_decisions = metrics.counter("sweep.decisions")
    base_prices = {c: float(p) for c, p in service.price_snapshot()[1]}
    daemon = SelectionDaemon(service, feed)
    truth_decisions: List[_TruthDecision] = []
    truth_prices: Mapping[Hashable, float] = dict(base_prices)
    truth_tick = 0
    with metrics.span(SWEEP_SPAN):
        for event in events:
            decision = daemon.handle(event)
            if truth is not None and isinstance(event, Tick):
                # the daemon's ticker consumed one tick (unless the
                # poll raised — then the true market didn't move past
                # it either, because the tick index will be retried)
                while truth_tick < daemon.ticker.tick_count:
                    batch = truth.poll(truth_tick)
                    truth_tick += 1
                    if batch:
                        advanced = dict(truth_prices)
                        for d in batch:
                            advanced[d.config_id] = d.price
                        truth_prices = advanced
            if decision is not None:
                c_decisions.inc()
                if truth is not None:
                    truth_decisions.append(_TruthDecision(
                        seq=daemon.stats.decisions,
                        job_id=decision.job_id,
                        job_class=decision.job_class,
                        config_id=decision.config_id,
                        price_epoch=decision.price_epoch,
                        prices=truth_prices))
    replayer = JournalReplayer(service.store, daemon.journal_dump())
    audit = replayer.audit()
    evaluation = replayer.evaluate()
    truth_eval = None
    if truth is not None:
        truth_eval = dynamic_evaluation(
            service.store, truth_decisions, replayer.catalog_ids,
            base_prices, backend=service.backend)
    c_points.inc()
    return TurbulencePoint(
        preset=preset_name, level=level, backend=service.backend,
        feed_kind=feed_kind, evaluation=evaluation, truth=truth_eval,
        audit_ok=audit.ok, audit_mismatches=len(audit.mismatches),
        audit_drift=len(audit.drift), decisions=audit.decisions,
        epochs=audit.ticks, feed_errors=audit.feed_errors)


def run_sweep(service_factory, base_prices: Mapping[Hashable, float],
              events: Sequence[Event], *,
              presets: Optional[Sequence["str | TurbulencePreset"]] = None,
              backends: Sequence[str] = ("numpy",),
              seed: int = 0) -> List[TurbulencePoint]:
    """The turbulence grid: every preset x every backend, one point each.

    ``service_factory(backend)`` must return a *fresh*
    :class:`~repro.selector.SelectionService` (each point mutates its
    price table); ``events`` is the shared daemon stream — the same
    submissions hit every cell, so the only thing that varies along a
    curve is the market.  Each generated market is recorded and
    replayed through :class:`RecordedPriceFeed` (lag applies *before*
    the recording, so the replay is exactly what the daemon saw), while
    the unlagged recording feeds the truth judge.  Points come back
    level-ordered per backend — ready for
    :func:`repro.core.evaluate.turbulence_curves`.
    """
    chosen = [preset(p) for p in (presets if presets is not None
                                  else sorted(TURBULENCE_PRESETS.values(),
                                              key=lambda p: p.level))]
    events = list(events)
    ticks = sum(1 for e in events if isinstance(e, Tick))
    points: List[TurbulencePoint] = []
    for p in sorted(chosen, key=lambda q: q.level):
        market = make_market(p, base_prices, seed=seed, ticks=ticks)
        raw_text = record_feed(market.raw, ticks)
        lagged_text = raw_text if p.feed_latency == 0 else \
            record_feed(LaggedPriceFeed(
                RecordedPriceFeed.loads(raw_text), p.feed_latency), ticks)
        for backend in backends:
            points.append(run_point(
                service_factory(backend),
                RecordedPriceFeed.loads(lagged_text), events,
                preset_name=p.name, level=p.level, feed_kind="recorded",
                truth=RecordedPriceFeed.loads(raw_text)))
    return points
