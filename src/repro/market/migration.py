"""Hysteresis migration advisor: move a running fleet only when it pays.

A submission is a green-field decision; a *running* fleet is not — moving
it costs real money (drain + dual-running during cutover) and a spot
price that dips for one tick will dip back.  ``should_migrate`` therefore
demands that the projected savings over a planning horizon beat the
switch cost by a hysteresis margin before advising a move (DESIGN.md §6).

The cost model: ``mean_norm_cost`` is the fleet's ×-optimal cost factor
for its class, so retargeting from the current config to the ranking's
winner scales the fleet's spend rate by ``mnc(best) / mnc(current)`` at
constant throughput.  Savings are quoted off the current fleet's $/h
under *current* prices (callers with a live price source re-price the
current config and pass it in); the switch itself is priced as
``switch_cost_hours`` of dual-running (old fleet drains while the new
one warms).
"""
from __future__ import annotations

import dataclasses
from typing import Hashable, Optional, Sequence

from repro.selector import Decision, RankedConfig


@dataclasses.dataclass(frozen=True)
class MigrationAdvice:
    """The advisor's verdict for one (placement, ranking) pair."""

    migrate: bool
    current_config_id: Hashable
    target_config_id: Hashable
    saving_per_hour: float      # projected $/h saved after the move
    switch_cost_usd: float      # one-off cost of moving
    horizon_hours: float
    reason: str

    @property
    def net_saving_usd(self) -> float:
        return self.saving_per_hour * self.horizon_hours \
            - self.switch_cost_usd


def should_migrate(current_placement: Decision,
                   ranking: Sequence[RankedConfig],
                   switch_cost_hours: float, *,
                   horizon_hours: float = 24.0,
                   hysteresis: float = 1.25,
                   current_hourly_cost: Optional[float] = None
                   ) -> MigrationAdvice:
    """Advise whether a running fleet should move to the ranking's winner.

    ``hysteresis`` > 1 demands the projected horizon savings exceed the
    switch cost by that margin — the damper that keeps a fleet from
    ping-ponging between two near-equal configs on every price wiggle.

    ``current_hourly_cost`` is the fleet's $/h *under current prices*;
    callers holding a live price source should re-price the current
    config and pass it (as :func:`repro.serve.engine.plan_decode_placement`
    does) so the quoted dollar figures track the market.  It defaults to
    the rate stamped on ``current_placement``, which may predate any
    number of price moves.
    """
    if not ranking:
        raise ValueError("empty ranking")
    if switch_cost_hours < 0 or horizon_hours <= 0 or hysteresis <= 0:
        raise ValueError("switch_cost_hours must be >= 0, horizon_hours "
                         "and hysteresis > 0")
    current_id = current_placement.config_id
    best = ranking[0]
    rate = current_hourly_cost if current_hourly_cost is not None \
        else current_placement.hourly_cost
    if not rate > 0:
        raise ValueError(f"non-positive current hourly cost {rate!r}")
    switch_cost = switch_cost_hours * rate

    if best.config_id == current_id:
        return MigrationAdvice(
            False, current_id, current_id, 0.0, switch_cost, horizon_hours,
            "current placement is already the ranking winner")

    current_rank: Optional[RankedConfig] = next(
        (r for r in ranking if r.config_id == current_id), None)
    if current_rank is None or \
            current_rank.mean_norm_cost == float("inf"):
        # the fleet sits on something the selector can no longer rank
        # (deprovisioned entry, trace rebuilt) — always move
        return MigrationAdvice(
            True, current_id, best.config_id, 0.0, switch_cost,
            horizon_hours, "current placement is no longer rankable")

    ratio = best.mean_norm_cost / current_rank.mean_norm_cost
    saving_per_hour = rate * (1.0 - ratio)
    if saving_per_hour * horizon_hours > hysteresis * switch_cost:
        return MigrationAdvice(
            True, current_id, best.config_id, saving_per_hour, switch_cost,
            horizon_hours,
            f"projected {saving_per_hour * horizon_hours:.2f} USD over "
            f"{horizon_hours:g} h beats {hysteresis:g}x switch cost "
            f"{switch_cost:.2f} USD")
    return MigrationAdvice(
        False, current_id, best.config_id, saving_per_hour, switch_cost,
        horizon_hours,
        f"projected {saving_per_hour * horizon_hours:.2f} USD over "
        f"{horizon_hours:g} h does not beat {hysteresis:g}x switch cost "
        f"{switch_cost:.2f} USD")
