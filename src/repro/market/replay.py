"""Replay: recorded price feeds and decision-journal consumers (DESIGN.md §8).

PR 2 built the live market; this module closes its loop.  Three pieces:

  * :class:`RecordedPriceFeed` — a :class:`~repro.market.feed.PriceFeed`
    over a recorded price history (CSV).  Unlike the stateful
    :class:`~repro.market.feed.SimulatedSpotFeed`, a recording is a pure
    function of the tick, so replays are byte-deterministic by
    construction: the same file yields the same batches in the same
    order, forever.
  * :func:`record_feed` — capture *any* feed to that CSV format, turning
    a one-off simulation (or, later, a live billing API poll) into a
    reproducible fixture.  Recording a recording is the identity on the
    bytes.
  * :class:`JournalReplayer` — re-read a version-2 decision journal (the
    header snapshots the starting prices; tick records carry the applied
    deltas), reconstruct the price epoch at every decision, and
    :meth:`~JournalReplayer.audit` each journaled selection against a
    cold :func:`~repro.selector.rank_dense` at that epoch, under the
    :class:`~repro.selector.ScoreContract` of the backend stamped in
    the header — **bit-identical** for numpy journals, tolerance mode
    (same winner or contract-tied, scores in envelope, float32 drift
    surfaced in :attr:`ReplayAudit.drift`) for jax journals
    (DESIGN.md §9) — an end-to-end consistency check of the whole
    feed → ticker → incremental-reprice → cache → decision path.
    :meth:`~JournalReplayer.evaluate` then scores the history against
    per-epoch and static-price oracles
    (:func:`repro.core.evaluate.dynamic_evaluation`).

The CSV format (version 1):

    # repro.market.recorded-price-feed v1 ticks=40
    tick,config_id,price
    0,"\"n2-4x16\"",12.79
    ...

``tick`` is a non-decreasing integer; ``config_id`` is JSON-encoded (so
int and str ids round-trip with their types); ``price`` is ``repr(float)``
(round-trips to the exact same double).  Malformed rows raise
``ValueError`` with the offending line number — a price history that
parses partially is worse than one that fails loudly.
"""
from __future__ import annotations

import csv
import dataclasses
import io
import json
from typing import (Any, Dict, Hashable, Iterator, List, Mapping, Optional,
                    Sequence, Tuple, Union)

import numpy as np

from repro.core.trace import JobClass
from repro.market.daemon import SelectionDaemon
from repro.obs import TICK_SPAN, histogram_quantile
from repro.market.feed import PriceDelta, PriceFeed
from repro.selector import (NothingRankableError, ProfilingStore,
                            ScoreContract, rank_dense, score_contract)

FEED_FORMAT = "repro.market.recorded-price-feed"
FEED_VERSION = 1
_CSV_COLUMNS = ("tick", "config_id", "price")


# --- recorded feeds --------------------------------------------------------------

def _check_price(delta: PriceDelta, tick: int) -> None:
    """Reject quotes ``loads`` would refuse *at capture time* — a
    recording that cannot be loaded back is worse than a failed
    capture."""
    if not np.isfinite(delta.price) or not delta.price > 0:
        raise ValueError(
            f"non-positive or non-finite price {delta.price!r} for "
            f"{delta.config_id!r} at tick {tick}")


class RecordedPriceFeed:
    """Replays a recorded price history; a pure function of the tick.

    ``poll(t)`` returns the batch recorded at tick ``t`` (``()`` for
    quiet ticks and for ticks beyond the recording — past the end the
    market is simply flat).  :attr:`ticks` is the recorded horizon, so
    harnesses can size their event streams to consume the whole history.
    """

    def __init__(self, batches: Mapping[int, Sequence[PriceDelta]],
                 ticks: Optional[int] = None):
        self._batches: Dict[int, Tuple[PriceDelta, ...]] = {}
        for t, batch in batches.items():
            if not (isinstance(t, int) and t >= 0):
                raise ValueError(f"bad tick index {t!r}")
            seen = set()
            for d in batch:
                _check_price(d, t)
                if d.config_id in seen:
                    raise ValueError(f"duplicate quote for "
                                     f"{d.config_id!r} at tick {t}")
                seen.add(d.config_id)
            self._batches[t] = tuple(batch)
        last = max(self._batches) + 1 if self._batches else 0
        #: recorded horizon: polls at ``tick >= ticks`` are beyond the
        #: recording (always empty).
        self.ticks = last if ticks is None else ticks
        if self.ticks < last:
            raise ValueError(f"ticks={self.ticks} shorter than the last "
                             f"recorded batch (tick {last - 1})")

    # -- the feed protocol --------------------------------------------------
    def poll(self, tick: int) -> Tuple[PriceDelta, ...]:
        return self._batches.get(tick, ())

    def stream(self, ticks: Optional[int] = None, start: int = 0
               ) -> Iterator[Tuple[PriceDelta, ...]]:
        n = self.ticks if ticks is None else ticks
        for t in range(start, start + n):
            yield self.poll(t)

    def config_ids(self) -> List[Hashable]:
        """Every config id quoted anywhere in the recording (first-seen
        order)."""
        seen: Dict[Hashable, None] = {}
        for t in sorted(self._batches):
            for d in self._batches[t]:
                seen.setdefault(d.config_id, None)
        return list(seen)

    # -- CSV parsing --------------------------------------------------------
    @classmethod
    def loads(cls, text: str) -> "RecordedPriceFeed":
        lines = text.splitlines()
        if not lines:
            raise ValueError(
                "line 1: empty recorded price feed (expected the "
                f"'# {FEED_FORMAT} v{FEED_VERSION}' magic line)")
        if not lines[0].startswith("#"):
            raise ValueError(
                f"not a recorded price feed (missing '# {FEED_FORMAT} "
                f"v{FEED_VERSION}' magic line)")
        magic = lines[0].lstrip("#").split()
        if not magic or magic[0] != FEED_FORMAT:
            raise ValueError(f"not a recorded price feed: {lines[0]!r}")
        if len(magic) < 2 or magic[1] != f"v{FEED_VERSION}":
            raise ValueError(
                f"unsupported recorded-feed version in {lines[0]!r} "
                f"(current v{FEED_VERSION})")
        ticks = None
        for field in magic[2:]:
            if field.startswith("ticks="):
                try:
                    ticks = int(field[len("ticks="):])
                except ValueError:
                    raise ValueError(f"bad ticks= field in {lines[0]!r}")
        if len(lines) < 2 or \
                tuple(lines[1].strip().split(",")) != _CSV_COLUMNS:
            raise ValueError(
                f"line 2: expected header '{','.join(_CSV_COLUMNS)}', "
                f"got {lines[1].strip() if len(lines) > 1 else ''!r}")
        batches: Dict[int, List[PriceDelta]] = {}
        prev_tick = -1
        for lineno, row in zip(
                range(3, len(lines) + 1),
                csv.reader(lines[2:], lineterminator="\n")):
            if not row:
                continue                      # blank trailing line
            if len(row) != 3:
                raise ValueError(
                    f"line {lineno}: expected 3 fields "
                    f"(tick,config_id,price), got {len(row)}: {row!r}")
            try:
                tick = int(row[0])
            except ValueError:
                raise ValueError(
                    f"line {lineno}: tick {row[0]!r} is not an integer")
            if tick < prev_tick:
                raise ValueError(
                    f"line {lineno}: tick {tick} out of order "
                    f"(after {prev_tick})")
            if tick < 0:
                raise ValueError(f"line {lineno}: negative tick {tick}")
            prev_tick = tick
            try:
                config_id = json.loads(row[1])
            except json.JSONDecodeError:
                raise ValueError(
                    f"line {lineno}: config_id {row[1]!r} is not valid "
                    f"JSON")
            if isinstance(config_id, (list, dict)):
                raise ValueError(
                    f"line {lineno}: config_id {row[1]!r} is not hashable")
            try:
                price = float(row[2])
            except ValueError:
                raise ValueError(
                    f"line {lineno}: price {row[2]!r} is not a number")
            if not np.isfinite(price) or not price > 0:
                raise ValueError(
                    f"line {lineno}: non-positive or non-finite price "
                    f"{price!r} for {config_id!r}")
            batch = batches.setdefault(tick, [])
            if any(d.config_id == config_id for d in batch):
                # two quotes for one config in one tick are ambiguous —
                # which is "the" price of the epoch depends on
                # application order, which replay must not guess
                raise ValueError(
                    f"line {lineno}: duplicate quote for {config_id!r} "
                    f"at tick {tick}")
            batch.append(PriceDelta(config_id, price))
        return cls(batches, ticks=ticks)

    @classmethod
    def load(cls, path: str) -> "RecordedPriceFeed":
        with open(path) as f:
            return cls.loads(f.read())


def record_feed(feed: PriceFeed, ticks: int, path: Optional[str] = None,
                start: int = 0) -> str:
    """Drive ``feed.poll`` for ``ticks`` ticks, capturing every batch as
    recorded-feed CSV; returns the text (and writes ``path`` if given).

    Prices are serialized with ``repr`` and config ids as JSON, so
    ``RecordedPriceFeed.loads(record_feed(feed, n))`` replays the exact
    batches (same floats, same ordering), and re-recording a recording
    reproduces the bytes.
    """
    buf = io.StringIO()
    # the header records the *horizon* (last tick + 1), not the batch
    # count, so recordings that start mid-stream stay loadable
    buf.write(f"# {FEED_FORMAT} v{FEED_VERSION} ticks={start + ticks}\n")
    writer = csv.writer(buf, lineterminator="\n")
    writer.writerow(_CSV_COLUMNS)
    for t in range(start, start + ticks):
        for d in feed.poll(t):
            _check_price(d, t)
            writer.writerow([t, json.dumps(d.config_id),
                             repr(float(d.price))])
    text = buf.getvalue()
    if path is not None:
        with open(path, "w") as f:
            f.write(text)
    return text


# --- journal replay --------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ReplayedDecision:
    """One journaled decision with its reconstructed price epoch."""

    seq: int
    job_id: Hashable
    job_class: Optional[JobClass]
    config_id: Hashable
    hourly_cost: float
    score: float
    price_epoch: int
    exclude_groups: Tuple[str, ...]
    #: the full ``{config_id: $/h}`` quote state at this decision
    #: (shared between decisions of the same epoch).
    prices: Mapping[Hashable, float]
    #: how the daemon served the decision's ranking: ``"ranking"`` (the
    #: default — full materialized list, and what journals without the
    #: additive field mean) or ``"top_k"`` (device-side head serving,
    #: DESIGN.md §10).  The audit treats both identically: a journaled
    #: decision carries exactly the winner/score/$-per-hour fields either
    #: way, and those are what the cold re-rank is held against.
    served_via: str = "ranking"
    #: additive front-end provenance (DESIGN.md §8/§11): the serving
    #: shard (0 = the tick thread's control path, 1..N = snapshot
    #: workers) and the tick of the snapshot the decision was served
    #: off.  ``None`` for single-threaded daemon journals — the audit
    #: ignores both either way (the stamped price epoch is what the
    #: cold re-rank is pinned to).
    worker: Optional[int] = None
    snapshot_tick: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class ReplayMismatch:
    """One field where the journal and the cold recompute disagree."""

    seq: int
    job_id: Hashable
    field: str
    journaled: Any
    replayed: Any


@dataclasses.dataclass(frozen=True)
class ReplayAudit:
    """Outcome of one :meth:`JournalReplayer.audit` pass.

    ``mismatches`` are contract violations (the audit failed);
    ``drift`` surfaces within-contract float32 divergence when auditing
    in tolerance mode — journaled scores that differ from the cold
    float64 value by accumulated delta-update ulps (field
    ``"score-drift"``, typically handoff-row renormalization), and
    near-tie winner swaps the contract accepts (field ``"winner-tie"``).
    Drift never fails the audit; it is the visibility the float32
    contract owes its consumers (DESIGN.md §9).
    """

    decisions: int
    ticks: int
    rejected: int
    mismatches: Tuple[ReplayMismatch, ...]
    #: within-contract divergences (tolerance mode only; empty for numpy)
    drift: Tuple[ReplayMismatch, ...] = ()
    #: the contract the audit ran under (None = pre-contract caller)
    contract: Optional[ScoreContract] = None
    #: ``feed-error`` records walked past (additive kind, DESIGN.md §8):
    #: ticks whose poll raised and was retried — prices never moved, so
    #: they are provenance, not a failure condition.
    feed_errors: int = 0
    #: ``metrics`` records walked past (additive kind, DESIGN.md §8/§12):
    #: periodic cumulative telemetry exports.  Like feed errors they are
    #: provenance, not selections — only their stamped price epoch is
    #: verified against the reconstructed one.
    metrics_records: int = 0
    #: tick latency recovered from the journal alone: ``{"p50": s,
    #: "p99": s, "count": n}`` from the *last* ``metrics`` record's
    #: cumulative ``tick.total`` histogram (records are cumulative, so
    #: the last one covers the whole run).  ``None`` when the journal
    #: carries no metrics records or no tick spans were observed.
    tick_latency: Optional[Mapping[str, float]] = None

    @property
    def ok(self) -> bool:
        return not self.mismatches


class JournalReplayer:
    """Re-reads a v2 decision journal against the profiling store.

    The journal is self-contained on the *price* side (header snapshot +
    per-tick deltas); the runtime side comes from ``store``, which must
    hold the same trace the daemon served from — that is the point: the
    audit detects *any* divergence between what the daemon journaled and
    what a cold ranking at the reconstructed epoch says, whether the
    cause is an incremental-reprice bug, an out-of-band price mutation
    the journal never saw, or a drifted trace.
    """

    def __init__(self, store: ProfilingStore,
                 journal: Union[str, Tuple[Dict[str, Any],
                                           List[Dict[str, Any]]]]):
        if isinstance(journal, str):
            header, records = SelectionDaemon.loads_journal(journal)
        else:
            header, records = journal
        if "prices" not in header:
            raise ValueError("journal header has no price snapshot "
                             "(pre-v2 journal?)")
        self.store = store
        self.header = header
        self.records = list(records)
        self.catalog_ids: List[Hashable] = list(header["catalog"])
        #: ranking backend the daemon served with (stamped in the header
        #: since the jax path landed; older v2 journals read as numpy —
        #: they could only have been written by the numpy path).
        self.backend: str = header.get("backend", "numpy")

    @classmethod
    def load(cls, store: ProfilingStore, path: str) -> "JournalReplayer":
        return cls(store, SelectionDaemon.load_journal(path))

    # -- price-state reconstruction -----------------------------------------
    def walk(self) -> Iterator[Tuple[Dict[str, Any], int,
                                     Mapping[Hashable, float]]]:
        """Yield ``(record, epoch, prices)`` with the price state *after*
        applying the record (ticks mutate it; everything else reads it).
        A fresh mapping is created per tick, so yielded snapshots stay
        valid after the walk moves on."""
        epoch = int(self.header.get("price_epoch", 0))
        prices: Dict[Hashable, float] = {c: float(p)
                                         for c, p in self.header["prices"]}
        for rec in self.records:
            if rec.get("kind") == "tick":
                prices = dict(prices)
                for config_id, price in rec["applied"]:
                    prices[config_id] = float(price)
                epoch += 1
            yield rec, epoch, prices

    def decisions(self) -> List[ReplayedDecision]:
        out = []
        for rec, epoch, prices in self.walk():
            if rec.get("kind") != "decision":
                continue
            klass = JobClass(rec["job_class"]) if rec.get("job_class") \
                else None
            out.append(ReplayedDecision(
                seq=rec["seq"], job_id=rec["job"], job_class=klass,
                config_id=rec["config"], hourly_cost=rec["hourly_cost"],
                score=rec["score"], price_epoch=rec["price_epoch"],
                exclude_groups=tuple(rec.get("exclude_groups", ())),
                prices=prices,
                served_via=rec.get("served_via", "ranking"),
                worker=rec.get("worker"),
                snapshot_tick=rec.get("snapshot_tick")))
        return out

    # -- the consistency audit ----------------------------------------------
    def _rank_cold(self, job_class: Optional[JobClass],
                   exclude_groups: Sequence[str],
                   prices: Mapping[Hashable, float]):
        jobs = self.store.select_jobs(job_class=job_class,
                                      exclude_groups=exclude_groups)
        if not jobs:
            raise NothingRankableError("no test jobs to learn from")
        hours, mask = self.store.matrix(job_ids=jobs,
                                        config_ids=self.catalog_ids)
        vec = np.asarray([prices[c] for c in self.catalog_ids],
                         dtype=np.float64)
        return rank_dense(hours, mask, vec, self.catalog_ids, job_ids=jobs)

    def audit(self, contract: Optional[ScoreContract] = None
              ) -> ReplayAudit:
        """Verify every journaled selection against a cold
        :func:`rank_dense` (numpy/float64) at its reconstructed epoch,
        under the journal's :class:`~repro.selector.ScoreContract`.

        ``contract`` defaults to the backend stamped in the journal
        header (``score_contract(self.backend)``):

        * **numpy** — bit-identical: the winning config id, its score,
          the stamped $/h against the reconstructed quote, and the
          stamped price epoch are compared with exact equality.  JSON
          floats round-trip through ``repr``, so one ulp of drift
          anywhere in the reprice path surfaces here.
        * **jax / jax_batched / jax_sharded / jax_pallas** — tolerance
          mode: the journaled winner
          must be the cold winner or tied with it within the contract,
          and the journaled score must be within rel/abs tolerance of
          that config's cold score.  Within-contract divergence —
          float32 delta-accumulation drift (handoff-row renormalization
          above all) and accepted near-tie winner swaps — is surfaced
          in :attr:`ReplayAudit.drift`, never silently absorbed.  The
          $/h and price-epoch comparisons stay exact: quotes flow
          through the float64 :class:`~repro.selector.PriceTable` on
          every backend.

        Top-k-served decisions (``"served_via": "top_k"``, DESIGN.md
        §10) audit through the same path with no special casing: the
        journal record carries exactly the winner/score/$-per-hour
        fields regardless of how much ranking tail the daemon
        materialized, so the comparison against the cold re-rank is
        unchanged.

        Rejections are audited identically in both modes: a journaled
        rejection whose (class, exclusions) re-ranks cold to a *valid*
        winner means the daemon silently served nothing for a rankable
        job — that is a mismatch, not bookkeeping.

        Decisions between the same two ticks with the same
        (class, exclusions) share identical rank inputs, so the cold
        ranking is memoized per ``(epoch, class, exclusions)`` — the
        audit costs O(epochs x distinct selections), not O(decisions).
        """
        if contract is None:
            contract = score_contract(self.backend)
        n_dec = n_tick = n_rej = n_feed = n_met = 0
        last_metrics: Optional[Dict[str, Any]] = None
        mismatches: List[ReplayMismatch] = []
        drift: List[ReplayMismatch] = []
        rank_memo: Dict[Tuple, Any] = {}

        def differ(seq, job, field, journaled, replayed):
            mismatches.append(ReplayMismatch(seq, job, field, journaled,
                                             replayed))

        def ranked_at(rec, epoch, prices):
            """Memoized cold ranking (None when nothing is rankable)."""
            klass = JobClass(rec["job_class"]) if rec.get("job_class") \
                else None
            excl = tuple(rec.get("exclude_groups", ()))
            key = (epoch, klass, excl)
            if key in rank_memo:
                return rank_memo[key]
            try:
                ranking = self._rank_cold(klass, excl, prices)
            except NothingRankableError:
                ranking = None
            if ranking is not None and \
                    ranking[0].score == float("inf"):
                ranking = None
            rank_memo[key] = ranking
            return ranking

        for rec, epoch, prices in self.walk():
            kind = rec.get("kind")
            if kind == "tick":
                n_tick += 1
                if rec["price_epoch"] != epoch:
                    differ(rec["seq"], None, "price_epoch",
                           rec["price_epoch"], epoch)
                continue
            if kind == "feed-error":
                # additive kind: a poll that raised and was retried —
                # no price movement, nothing to verify beyond the epoch
                n_feed += 1
                if rec["price_epoch"] != epoch:
                    differ(rec["seq"], None, "price_epoch",
                           rec["price_epoch"], epoch)
                continue
            if kind == "metrics":
                # additive kind: cumulative telemetry export — verify
                # the stamped epoch and keep the last record, whose
                # cumulative tick.total histogram covers the whole run
                n_met += 1
                if rec["price_epoch"] != epoch:
                    differ(rec["seq"], None, "price_epoch",
                           rec["price_epoch"], epoch)
                last_metrics = rec
                continue
            seq, job = rec.get("seq"), rec.get("job")
            if kind == "rejected":
                n_rej += 1
                if rec["price_epoch"] != epoch:
                    differ(seq, job, "price_epoch", rec["price_epoch"],
                           epoch)
                ranking = ranked_at(rec, epoch, prices)
                if ranking is not None:
                    differ(seq, job, "rejected", None,
                           ranking[0].config_id)
                continue
            if kind != "decision":
                continue
            n_dec += 1
            if rec["price_epoch"] != epoch:
                differ(seq, job, "price_epoch", rec["price_epoch"], epoch)
            ranking = ranked_at(rec, epoch, prices)
            if ranking is None:
                differ(seq, job, "rankable", rec["config"], None)
                continue
            winner = ranking[0]
            if not contract.winner_matches(rec["config"], ranking):
                differ(seq, job, "config", rec["config"],
                       winner.config_id)
            else:
                # the cold score the journaled score answers to: the
                # journaled config's own (identical to the winner's
                # except on an accepted near-tie swap)
                cold = winner if rec["config"] == winner.config_id else \
                    next(r for r in ranking
                         if r.config_id == rec["config"])
                if cold is not winner:
                    drift.append(ReplayMismatch(
                        seq, job, "winner-tie", rec["config"],
                        winner.config_id))
                if not contract.scores_match(rec["score"], cold.score):
                    differ(seq, job, "score", rec["score"], cold.score)
                elif rec["score"] != cold.score:
                    drift.append(ReplayMismatch(
                        seq, job, "score-drift", rec["score"],
                        cold.score))
            quote = prices.get(rec["config"])
            if rec["hourly_cost"] != quote:
                differ(seq, job, "hourly_cost", rec["hourly_cost"], quote)
        tick_latency = None
        if last_metrics is not None:
            h = last_metrics.get("histograms", {}).get(TICK_SPAN)
            if h and h.get("count"):
                tick_latency = {
                    "p50": histogram_quantile(h["le"], h["counts"], 0.50),
                    "p99": histogram_quantile(h["le"], h["counts"], 0.99),
                    "count": int(h["count"]),
                }
        return ReplayAudit(decisions=n_dec, ticks=n_tick, rejected=n_rej,
                           mismatches=tuple(mismatches),
                           drift=tuple(drift), contract=contract,
                           feed_errors=n_feed, metrics_records=n_met,
                           tick_latency=tick_latency)

    # -- dynamic-price evaluation -------------------------------------------
    def evaluate(self, base_prices: Optional[Mapping[Hashable, float]]
                 = None):
        """Score the journaled history against per-epoch and static-price
        oracles; see :func:`repro.core.evaluate.dynamic_evaluation`.

        ``base_prices`` defaults to the header snapshot (the static
        oracle then models a selector that never saw a price move).
        """
        from repro.core.evaluate import dynamic_evaluation
        if base_prices is None:
            base_prices = {c: float(p) for c, p in self.header["prices"]}
        return dynamic_evaluation(self.store, self.decisions(),
                                  self.catalog_ids, base_prices,
                                  backend=self.backend)
