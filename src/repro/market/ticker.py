"""PriceTicker: the loop that turns a feed into service price epochs.

One tick = one ``feed.poll`` batch pushed through
``SelectionService.reprice``: the service applies the deltas to its
:class:`~repro.selector.PriceTable` (the single source of truth for cold
recomputes), bumps the price epoch, and refreshes every live ranking
through the incremental :class:`~repro.selector.RankState` path
(DESIGN.md §6).  An empty batch is a no-op — no epoch bump, caches stay
hot — so quiet markets cost nothing.
"""
from __future__ import annotations

from typing import Dict, Hashable, Optional, Tuple

from repro.obs import MetricsRegistry
from repro.selector import PriceTable, SelectionService
from repro.market.feed import FeedError, PriceDelta, PriceFeed


class PriceTicker:
    """Applies feed batches to a service's live price table."""

    def __init__(self, feed: PriceFeed, service: SelectionService,
                 metrics: Optional[MetricsRegistry] = None):
        if not isinstance(service.price_source, PriceTable):
            raise ValueError(
                "PriceTicker needs a service with a PriceTable price "
                "source (use PriceTable.from_catalog to snapshot one)")
        self.feed = feed
        self.service = service
        #: telemetry: defaults to the service's registry so tick spans
        #: land next to the reprice/serve counters (DESIGN.md §12).
        self.metrics = metrics if metrics is not None else service.metrics
        self._c_ticks = self.metrics.counter("tick.count")
        self._c_deltas = self.metrics.counter("tick.deltas")
        #: next tick index handed to ``feed.poll``.
        self.tick_count = 0
        self.deltas_applied = 0
        self.epochs_driven = 0

    def tick(self) -> Tuple[PriceDelta, ...]:
        """Poll one batch and apply it; returns the batch.

        A ``feed.poll`` that raises surfaces as a typed
        :class:`~repro.market.FeedError` (original exception as
        ``__cause__``) **before** the tick index is consumed, so the
        next :meth:`tick` retries the same tick — prices stay at the
        last good epoch, never half-applied.  Errors from applying a
        successfully polled batch (``reprice``) are service
        misconfiguration and propagate untyped.
        """
        try:
            with self.metrics.span("tick.poll"):
                deltas = self.feed.poll(self.tick_count)
        except Exception as exc:
            raise FeedError(
                f"feed.poll failed at tick {self.tick_count}: "
                f"{type(exc).__name__}: {exc}", self.tick_count) from exc
        self.tick_count += 1
        self._c_ticks.inc()
        if deltas:
            table: Dict[Hashable, float] = {d.config_id: d.price
                                            for d in deltas}
            with self.metrics.span("tick.reprice"):
                self.service.reprice(table)
            self._c_deltas.inc(len(deltas))
            self.deltas_applied += len(deltas)
            self.epochs_driven += 1
        return deltas

    def run(self, ticks: int) -> int:
        """Drive ``ticks`` ticks; returns total deltas applied."""
        applied = 0
        for _ in range(ticks):
            applied += len(self.tick())
        return applied
