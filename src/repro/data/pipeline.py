"""Data pipeline: deterministic sharded token streams with host prefetch.

Production shape: a :class:`TokenStream` is addressed by (epoch, step) so
restarts resume mid-epoch deterministically from the checkpointed step —
no iterator state needs saving.  Each host materialises only its shard of
the global batch (`host_slice`); a background thread keeps ``prefetch``
batches ready.  The synthetic backend generates Zipf-ish token ids from a
counter-based RNG (content-free but shape/distribution-realistic); a
file-backed binary backend covers real corpora.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.types import ModelConfig, ShapeSpec


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2           # token frequency skew
    host_count: int = 1
    host_index: int = 0
    prefetch: int = 2


class TokenStream:
    """Deterministic, seekable synthetic LM token stream."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        assert cfg.global_batch % cfg.host_count == 0
        self.host_batch = cfg.global_batch // cfg.host_count

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """The (host-local) batch for a global step — pure function of
        (seed, step, host_index), so restarts are exact."""
        c = self.cfg
        rng = np.random.Generator(np.random.Philox(
            key=c.seed, counter=[0, 0, step, c.host_index]))
        # Zipf-like ids folded into the vocab
        raw = rng.zipf(c.zipf_a, size=(self.host_batch, c.seq_len + 1))
        tokens = (raw % (c.vocab_size - 2)).astype(np.int32) + 2
        return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:].copy()}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class PrefetchIterator:
    """Background-thread prefetch of ready batches (optionally device_put)."""

    def __init__(self, stream: TokenStream, *, start_step: int = 0,
                 shardings: Optional[Dict[str, Any]] = None):
        self.stream = stream
        self.shardings = shardings
        self._q: "queue.Queue" = queue.Queue(stream.cfg.prefetch)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self) -> None:
        step = self._step
        while not self._stop.is_set():
            batch = self.stream.batch_at(step)
            if self.shardings is not None:
                batch = {k: jax.device_put(v, self.shardings[k])
                         for k, v in batch.items()}
            try:
                self._q.put(batch, timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def __iter__(self):
        return self

    def __next__(self):
        return self._q.get()

    def close(self) -> None:
        self._stop.set()
        self._thread.join(timeout=2)


def for_model(cfg: ModelConfig, shape: ShapeSpec, *, seed: int = 0,
              host_count: int = 1, host_index: int = 0) -> TokenStream:
    return TokenStream(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=shape.seq_len,
        global_batch=shape.global_batch, seed=seed,
        host_count=host_count, host_index=host_index))
