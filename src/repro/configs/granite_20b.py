"""Granite-20B code [arXiv:2405.04324; hf].

52L, d_model=6144, 48H (MQA kv=1), d_ff=24576, vocab=49152.
gpt-bigcode lineage: LayerNorm, classic 4x FFN (non-gated, gelu).
"""
from repro.models.types import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b", family="dense",
    num_layers=52, d_model=6144, num_heads=48, num_kv_heads=1, d_ff=24576,
    vocab_size=49152,
    norm="layernorm", act="gelu", gated_mlp=False,
)
