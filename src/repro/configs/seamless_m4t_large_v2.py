"""SeamlessM4T-large-v2 backbone [arXiv:2308.11596; hf].

Enc-dec multimodal: 24 encoder + 24 decoder layers, d_model=1024, 16H
(GQA kv=16 = MHA), d_ff=8192, vocab=256206.  Speech frontend is a stub
(precomputed frame embeddings feed the encoder).
"""
from repro.models.types import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="encdec",
    num_layers=24, encoder_layers=24,
    d_model=1024, num_heads=16, num_kv_heads=16, d_ff=8192,
    vocab_size=256206,
    norm="layernorm", act="gelu", gated_mlp=False,
    tie_embeddings=True, frontend="audio", frontend_len=4096,
)
