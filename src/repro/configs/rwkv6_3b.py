"""RWKV-6 "Finch" 3B [arXiv:2404.05892; hf].

32L, d_model=2560 (40 heads x 64), channel-mix d_ff=8960, vocab=65536.
Attention-free data-dependent-decay linear recurrence; O(1) decode state
-> runs the long_500k shape.
"""
from repro.models.types import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="ssm",
    num_layers=32, d_model=2560, num_heads=40, num_kv_heads=40, d_ff=8960,
    vocab_size=65536,
    block_pattern=("rwkv",), rwkv_head_dim=64, norm="layernorm",
)
