"""RecurrentGemma-9B (Griffin) [arXiv:2402.19427; unverified].

38L, d_model=4096, 16H (GQA kv=1 on attention layers), d_ff=12288,
vocab=256000.  Block pattern 2 recurrent (RG-LRU) : 1 local attention
(window 2048); 38 = 12 cycles of 3 + 2 remainder recurrent layers.
Sub-quadratic -> runs the long_500k shape.
"""
from repro.models.types import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1, d_ff=12288,
    vocab_size=256000,
    block_pattern=("rec", "rec", "attn"), window=2048, lru_width=4096,
    conv_width=4, act="gelu", tie_embeddings=True,
)
