"""Llama-4 Maverick 400B-A17B [hf:meta-llama; unverified].

48L, d_model=5120, 40H (GQA kv=8), d_ff=8192, vocab=202048.
MoE: 128 experts, top-1 routing, shared expert, dense/MoE layers
alternating (period 2) -> ~400B total / ~17B active parameters.
"""
from repro.models.types import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8, d_ff=8192,
    vocab_size=202048,
    num_experts=128, experts_per_token=1, moe_period=2, shared_expert=True,
    rope_theta=500000.0,
)
