"""Pixtral-12B [hf:mistralai/Pixtral-12B-2409; unverified].

40L, d_model=5120, 32H (GQA kv=8), d_ff=14336, vocab=131072.
Vision frontend (pixtral ViT) is a stub: batches carry precomputed patch
embeddings prepended to the text sequence.
"""
from repro.models.types import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b", family="vlm",
    num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8, d_ff=14336,
    vocab_size=131072,
    rope_theta=1000000.0, frontend="vision", frontend_len=1024,
)
