"""Qwen3-30B-A3B [hf:Qwen/Qwen3-30B-A3B; hf].

48L, d_model=2048, 32H (GQA kv=4), vocab=151936.
MoE on every layer: 128 experts, top-8, expert d_ff=768.
"""
from repro.models.types import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=4, d_ff=768,
    vocab_size=151936,
    num_experts=128, experts_per_token=8, moe_period=1, moe_d_ff=768,
    qk_norm=True, rope_theta=1000000.0,
)
