"""Assigned input shapes and ShapeDtypeStruct builders for every cell.

The four shapes (seq_len x global_batch) are fixed by the assignment:

    train_4k      4,096 x 256   (training)
    prefill_32k  32,768 x 32    (inference prefill)
    decode_32k   32,768 x 128   (inference decode: 1 token vs KV cache)
    long_500k   524,288 x 1     (long-context decode)

``decode_*``/``long_*`` lower ``serve_step``, not ``train_step``.
``long_500k`` requires sub-quadratic state and therefore only runs for the
SSM/hybrid families (rwkv6-3b, recurrentgemma-9b); it is skipped — and the
skip recorded — for pure full-attention archs (DESIGN.md §5).
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.models.types import ModelConfig, ShapeSpec

SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def applicable(cfg: ModelConfig, shape: ShapeSpec) -> bool:
    if shape.name == "long_500k":
        return cfg.family in SUBQUADRATIC_FAMILIES
    return True


def skip_reason(cfg: ModelConfig, shape: ShapeSpec) -> Optional[str]:
    if not applicable(cfg, shape):
        return (f"{cfg.name} is pure full attention; a {shape.seq_len}-token "
                "dense KV cache is not a meaningful configuration "
                "(DESIGN.md §5)")
    return None


def cells(cfg: ModelConfig) -> List[ShapeSpec]:
    return [s for s in SHAPES.values() if applicable(cfg, s)]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def batch_specs(cfg: ModelConfig, shape: ShapeSpec, *,
                with_labels: bool) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStructs for a train/prefill batch of this cell."""
    B, T = shape.global_batch, shape.seq_len
    emb_dtype = cfg.compute_dtype
    if cfg.is_encdec:
        # source frames and target tokens split the budget evenly
        F = Tt = T // 2
        out = {
            "frontend_embeds": _sds((B, F, cfg.d_model), emb_dtype),
            "tokens": _sds((B, Tt), jnp.int32),
        }
        if with_labels:
            out["labels"] = _sds((B, Tt), jnp.int32)
        return out
    if cfg.frontend == "vision":
        F = min(cfg.frontend_len, T // 4)
        out = {
            "frontend_embeds": _sds((B, F, cfg.d_model), emb_dtype),
            "tokens": _sds((B, T - F), jnp.int32),
        }
        if with_labels:
            out["labels"] = _sds((B, T), jnp.int32)
        return out
    out = {"tokens": _sds((B, T), jnp.int32)}
    if with_labels:
        out["labels"] = _sds((B, T), jnp.int32)
    return out


def decode_specs(cfg: ModelConfig, shape: ShapeSpec):
    """(token, pos) ShapeDtypeStructs for a decode step of this cell."""
    B = shape.global_batch
    return {
        "token": _sds((B,), jnp.int32),
        "pos": _sds((), jnp.int32),
    }


def make_batch(cfg: ModelConfig, shape: ShapeSpec, key: jax.Array, *,
               with_labels: bool = True) -> Dict[str, jax.Array]:
    """Concrete random batch matching batch_specs (smoke tests/examples)."""
    specs = batch_specs(cfg, shape, with_labels=with_labels)
    out = {}
    for name, s in specs.items():
        key, sub = jax.random.split(key)
        if s.dtype == jnp.int32:
            out[name] = jax.random.randint(sub, s.shape, 0, cfg.vocab_size,
                                           dtype=jnp.int32)
        else:
            out[name] = jax.random.normal(sub, s.shape, s.dtype) * 0.02
    return out
