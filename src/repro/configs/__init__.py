"""Architecture configuration registry.

``get(name)`` returns the exact published config; ``reduced(cfg)`` returns
a same-family shrunken variant for CPU smoke tests (small width/depth, few
experts, tiny vocab).  Full configs are only exercised via the dry-run
(ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses
import importlib
import math
from typing import Dict, List

from repro.models.types import ModelConfig

_MODULES = {
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "rwkv6-3b": "rwkv6_3b",
    "stablelm-3b": "stablelm_3b",
    "qwen3-1.7b": "qwen3_1_7b",
    "granite-20b": "granite_20b",
    "deepseek-7b": "deepseek_7b",
    "pixtral-12b": "pixtral_12b",
}

ARCH_NAMES: List[str] = list(_MODULES)


def get(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_NAMES}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {n: get(n) for n in ARCH_NAMES}


def reduced(cfg: ModelConfig, *, d_model: int = 64,
            vocab: int = 512) -> ModelConfig:
    """Same-family shrunken config for CPU smoke tests."""
    period = cfg.moe_period if cfg.num_experts else 1
    cyc = math.lcm(len(cfg.block_pattern), period)
    rem = 1 if cfg.num_layers % cyc else 0
    heads = 4
    kv = max(1, heads * cfg.num_kv_heads // cfg.num_heads)
    changes = dict(
        num_layers=2 * cyc + rem,
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=d_model // heads,
        d_ff=4 * d_model if cfg.moe_d_ff is None else 2 * d_model,
        vocab_size=vocab,
        dtype="float32",
    )
    if cfg.num_experts:
        changes.update(num_experts=8,
                       experts_per_token=min(cfg.experts_per_token, 2),
                       moe_d_ff=(2 * d_model if cfg.moe_d_ff is not None
                                 else None))
    if cfg.window:
        changes.update(window=16)
    if cfg.family in ("hybrid",):
        changes.update(lru_width=d_model)
    if cfg.family == "ssm":
        changes.update(rwkv_head_dim=16, num_heads=d_model // 16,
                       num_kv_heads=d_model // 16, head_dim=16)
    if cfg.encoder_layers:
        changes.update(encoder_layers=2)
    if cfg.frontend_len:
        changes.update(frontend_len=8)
    return dataclasses.replace(cfg, **changes)
