"""Fused Pallas delta-rank reprice kernel (the ``jax_pallas`` backend).

The XLA delta step (:func:`repro.selector.rank._delta_universe_update`
plus the batched score fold) is ~5 streamed passes over the (J x C)
universe per tick: gather/scatter the changed cost columns, a full
``cost.min(axis=1)``, a full renormalization, and two matmuls over
J x C operands — each materializing an intermediate in HBM between XLA
fusions.  This module fuses the whole tick into **one**
``pl.pallas_call`` over the (S x J x C)-tiled universe:

* **changed-column score re-reduction** — every member's score on a
  changed column is re-reduced from scratch (``P = row_masks @
  norm_new`` restricted to changed columns), the ``.set`` semantics the
  ScoreContract's drift story depends on;
* **masked row-min handoff detection** — the fresh masked row minimum
  falls out of the same streamed tiles (see below), and the handoff
  count (#rows whose minimum moved) is accumulated into a scalar
  output;
* **accumulator score updates** — unchanged columns fold
  ``D = row_masks @ (norm_new - norm_old)`` into the standing
  accumulators; rows whose minimum did not move contribute *exact*
  zeros (see the recompute identity below), so a no-handoff tick is
  drift-free, exactly like the XLA path.

**Why the handoff-row min needs no second pass over universe state**
(DESIGN.md §14): the kernel keeps *no* resident cost or norm matrix.
Both are recomputed in-stream from the read-only ``hours``/``mask``
residents and the price vectors — float32 elementwise multiply and
divide are deterministic IEEE ops, so an unchanged column's
recomputed cost is bit-identical to what a stored matrix would hold,
and ``norm_new - norm_old`` is an exact ``0.0`` wherever nothing
moved.  The fresh row minimum is therefore a byproduct of the same
tile stream (phase 0 of the grid), not a second pass over a
delta-patched cost matrix; resident per-tick state shrinks to the
price vector, the row minima and the score accumulators.

**Tiling.**  The grid is ``(2, C//block_c, J//block_j)``: phase 0
sweeps the tiles accumulating the masked row minima of the *new* cost
into a ``(J, 1)`` VMEM scratch; phase 1 recomputes both norms per tile
and accumulates the two member matmuls (``S x block_j @ block_j x
block_c``).  The j axis is innermost so each ``(S, block_c)`` output
block sees its accumulation visits consecutively (the Pallas
revisiting rule); with the default single C tile the input blocks keep
their index across the phase boundary, so HBM streams ``hours``/
``mask`` once per tick.  The member axis S rides whole in every block.

Like the other kernels in this package the Pallas body runs natively on
TPU and under ``interpret=True`` on CPU; ``interpret`` is a *static*
argument resolved at call time (never baked into a jit trace — the
regression the ops.py wrappers fixed).  The lazy jitted dispatch is
built under a lock: the serving front-end first-calls from N worker
threads plus the tick thread concurrently.
"""
from __future__ import annotations

import threading
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ops import _interpret

__all__ = ["fused_reprice", "fused_reprice_heads", "rank_delta_fns"]


def _make_kernel(block_j: int, n_j_tiles: int, n_c_tiles: int,
                 heads: Optional[int]):
    """The fused kernel body; ``heads=k`` adds the in-kernel top-k tail
    (requires a single C tile — the final scores must be resident)."""

    def kernel(hours_ref, mask_ref, oldp_ref, newp_ref, chg_ref,
               rb_in_ref, rm_ref, scores_in_ref, *refs):
        if heads is None:
            scores_out_ref, rb_out_ref, moved_ref = refs[:3]
            rb_scr, p_acc = refs[3:]
        else:
            fin_ref = refs[0]
            scores_out_ref, rb_out_ref, moved_ref = refs[1:4]
            ti_ref, tv_ref = refs[4:6]
            rb_scr, p_acc = refs[6:]
        p = pl.program_id(0)
        c = pl.program_id(1)
        j = pl.program_id(2)
        jsl = pl.ds(j * block_j, block_j)
        hours = hours_ref[...]                        # (Jt, Ct)
        mask = mask_ref[...]
        # the new cost tile, recomputed in-stream: unchanged columns
        # reproduce the old cost bit-for-bit (deterministic IEEE mul),
        # so no resident cost matrix — and no second pass over one —
        # is needed to find the fresh masked row minima
        cost_new = jnp.where(mask, hours * newp_ref[...], jnp.inf)

        @pl.when((p == 0) & (c == 0) & (j == 0))
        def _init():
            moved_ref[...] = jnp.zeros_like(moved_ref)

        @pl.when(p == 0)
        def _min_scan():
            # phase 0: running masked row minima across the C tiles
            tile_min = jnp.min(cost_new, axis=1, keepdims=True)

            @pl.when(c == 0)
            def _():
                rb_scr[jsl, :] = tile_min

            @pl.when(c > 0)
            def _():
                rb_scr[jsl, :] = jnp.minimum(rb_scr[jsl, :], tile_min)

        @pl.when(p == 1)
        def _fold():
            # phase 1: both norms recomputed per tile, two member
            # matmuls accumulated, handoffs counted — rb_scr is final
            # (phase 0 swept every tile before phase 1 starts)
            cost_old = jnp.where(mask, hours * oldp_ref[...], jnp.inf)
            rb_old = rb_in_ref[jsl, :]                # (Jt, 1)
            fresh = rb_scr[jsl, :]
            norm_old = jnp.where(mask, cost_old / rb_old, 0.0)
            norm_new = jnp.where(mask, cost_new / fresh, 0.0)
            rm = rm_ref[...]                          # (S, Jt)
            dims = (((1,), (0,)), ((), ()))
            re_reduce = jax.lax.dot_general(
                rm, norm_new, dims, preferred_element_type=jnp.float32)
            delta = jax.lax.dot_general(
                rm, norm_new - norm_old, dims,
                preferred_element_type=jnp.float32)

            @pl.when(j == 0)
            def _():
                scores_out_ref[...] = delta
                p_acc[...] = re_reduce

            @pl.when(j > 0)
            def _():
                scores_out_ref[...] += delta
                p_acc[...] += re_reduce

            @pl.when(c == 0)
            def _():
                # handoff detection + the fresh minima, once per j tile
                rb_out_ref[jsl, :] = fresh
                moved_ref[0, 0] += jnp.sum(
                    (fresh != rb_old).astype(jnp.int32))

            @pl.when(j == n_j_tiles - 1)
            def _combine():
                # changed columns: re-set from the scratch re-reduction;
                # unchanged: fold the (exact-zero-for-unmoved-rows)
                # delta into the standing accumulators
                chg = chg_ref[...] > 0                # (1, Ct)
                scores_out_ref[...] = jnp.where(
                    chg, p_acc[...],
                    scores_in_ref[...] + scores_out_ref[...])
                if heads is not None:
                    # the fused top-k tail: iterative masked argmin
                    # over the just-finalized resident scores —
                    # jnp.argmin's first-occurrence tie-break IS the
                    # catalog-order tie-break of _materialize
                    masked = jnp.where(fin_ref[...], scores_out_ref[...],
                                       jnp.inf)
                    cols2 = jax.lax.broadcasted_iota(
                        jnp.int32, masked.shape, 1)
                    for t in range(heads):
                        tv_ref[:, t] = jnp.min(masked, axis=1)
                        idx = jnp.argmin(masked, axis=1)
                        ti_ref[:, t] = idx.astype(jnp.int32)
                        masked = jnp.where(cols2 == idx[:, None],
                                           jnp.inf, masked)

    return kernel


def _check_tiling(shape_j: int, shape_c: int, block_j: int,
                  block_c: int) -> Tuple[int, int]:
    if block_j < 1 or shape_j % block_j:
        raise ValueError(f"block_j={block_j} must divide the (padded) "
                         f"job axis {shape_j}")
    if block_c < 1 or shape_c % block_c:
        raise ValueError(f"block_c={block_c} must divide the config "
                         f"axis {shape_c}")
    return shape_j // block_j, shape_c // block_c


def _fused_call(hours, mask, old_prices, new_prices, changed, row_best,
                row_masks, scores, finite, *, block_j, block_c, heads,
                interpret):
    """Build and invoke the single fused ``pallas_call`` for one tick."""
    J, C = hours.shape
    S = row_masks.shape[0]
    nj, nc = _check_tiling(J, C, block_j, block_c)
    if heads is not None and nc != 1:
        raise ValueError("the fused reprice+top-k variant needs the "
                         "final scores resident: use block_c == C")
    kernel = _make_kernel(block_j, nj, nc, heads)
    vec = lambda p, c, j: (0, c)                     # (1, Ct) vectors
    tile = lambda p, c, j: (j, c)                    # (Jt, Ct) tiles
    whole = lambda p, c, j: (0, 0)                   # resident blocks
    in_specs = [
        pl.BlockSpec((block_j, block_c), tile),      # hours
        pl.BlockSpec((block_j, block_c), tile),      # mask
        pl.BlockSpec((1, block_c), vec),             # old prices
        pl.BlockSpec((1, block_c), vec),             # new prices
        pl.BlockSpec((1, block_c), vec),             # changed columns
        pl.BlockSpec((J, 1), whole),                 # row_best in
        pl.BlockSpec((S, block_j), lambda p, c, j: (0, j)),  # row masks
        pl.BlockSpec((S, block_c), vec),             # scores in
    ]
    args = [hours, mask, old_prices, new_prices, changed, row_best,
            row_masks, scores]
    out_specs = [
        pl.BlockSpec((S, block_c), vec),             # scores out
        pl.BlockSpec((J, 1), whole),                 # row_best out
        pl.BlockSpec((1, 1), whole),                 # handoff count
    ]
    out_shape = [
        jax.ShapeDtypeStruct((S, C), jnp.float32),
        jax.ShapeDtypeStruct((J, 1), jnp.float32),
        jax.ShapeDtypeStruct((1, 1), jnp.int32),
    ]
    if heads is not None:
        in_specs.insert(8, pl.BlockSpec((S, block_c), vec))  # finite
        args.insert(8, finite)
        out_specs += [pl.BlockSpec((S, heads), whole),
                      pl.BlockSpec((S, heads), whole)]
        out_shape += [jax.ShapeDtypeStruct((S, heads), jnp.int32),
                      jax.ShapeDtypeStruct((S, heads), jnp.float32)]
    return pl.pallas_call(
        kernel,
        grid=(2, nc, nj),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((J, 1), jnp.float32),
                        pltpu.VMEM((S, block_c), jnp.float32)],
        interpret=interpret,
    )(*args)


def _reprice(hours, mask, old_prices, new_prices, changed, row_best,
             row_masks, scores, *, block_j, block_c, interpret):
    return _fused_call(hours, mask, old_prices, new_prices, changed,
                       row_best, row_masks, scores, None,
                       block_j=block_j, block_c=block_c, heads=None,
                       interpret=interpret)


def _reprice_heads(hours, mask, old_prices, new_prices, changed,
                   row_best, row_masks, scores, finite, *, block_j,
                   block_c, k, interpret):
    return _fused_call(hours, mask, old_prices, new_prices, changed,
                       row_best, row_masks, scores, finite,
                       block_j=block_j, block_c=block_c, heads=k,
                       interpret=interpret)


# the lazy jitted dispatch, built once under a lock (double-checked):
# the serving front-end first-calls from N snapshot workers plus the
# tick thread concurrently, the same hazard the rank.py singletons fix
_RANK_DELTA_FNS: Optional[Tuple[Any, Any]] = None
_RANK_DELTA_LOCK = threading.Lock()


def rank_delta_fns() -> Tuple[Any, Any]:
    """``(reprice, reprice_heads)`` jitted fused kernels, built once on
    first use (importing the package never initializes a backend).
    ``interpret`` is a static jit argument — callers resolve it at call
    time, so a backend change re-traces instead of replaying a stale
    flag from the jit cache."""
    global _RANK_DELTA_FNS
    if _RANK_DELTA_FNS is None:
        with _RANK_DELTA_LOCK:
            if _RANK_DELTA_FNS is None:
                _RANK_DELTA_FNS = (
                    jax.jit(_reprice,
                            static_argnames=("block_j", "block_c",
                                             "interpret")),
                    jax.jit(_reprice_heads,
                            static_argnames=("block_j", "block_c", "k",
                                             "interpret")),
                )
    return _RANK_DELTA_FNS


def fused_reprice(hours, mask, old_prices, new_prices, changed,
                  row_best, row_masks, scores, *, block_j: int,
                  block_c: int, interpret: Optional[bool] = None):
    """One fused tick: ``(scores, row_best, moved)`` from the streamed
    universe.  ``interpret=None`` resolves from the current default
    backend at call time (interpreted everywhere but TPU)."""
    if interpret is None:
        interpret = _interpret()
    return rank_delta_fns()[0](
        hours, mask, old_prices, new_prices, changed, row_best,
        row_masks, scores, block_j=block_j, block_c=block_c,
        interpret=interpret)


def fused_reprice_heads(hours, mask, old_prices, new_prices, changed,
                        row_best, row_masks, scores, finite, *,
                        block_j: int, block_c: int, k: int,
                        interpret: Optional[bool] = None):
    """The fused reprice+top-k variant: additionally returns every
    member's k best ``(indices, values)`` computed in-kernel from the
    just-finalized scores (single C tile only)."""
    if interpret is None:
        interpret = _interpret()
    return rank_delta_fns()[1](
        hours, mask, old_prices, new_prices, changed, row_best,
        row_masks, scores, finite, block_j=block_j, block_c=block_c,
        k=k, interpret=interpret)
