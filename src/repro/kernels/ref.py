"""Pure-jnp oracles for the Pallas kernels."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

# the exact sequential recurrence is the model-side reference already
from repro.models.recurrent import wkv6_scan_ref  # noqa: F401  (re-export)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True,
                  window: Optional[int] = None) -> jax.Array:
    """Naive softmax attention with GQA.  q: (B,Tq,H,D); k/v: (B,Tk,G,D)."""
    B, Tq, H, D = q.shape
    Tk, G = k.shape[1], k.shape[2]
    R = H // G
    qg = q.reshape(B, Tq, G, R, D).astype(jnp.float32) / math.sqrt(D)
    s = jnp.einsum("btgrd,bsgd->bgrts", qg, k.astype(jnp.float32))
    qpos = jnp.arange(Tq)[:, None]
    kpos = jnp.arange(Tk)[None, :]
    mask = jnp.ones((Tq, Tk), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= qpos - kpos < window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgrts,bsgd->btgrd", p, v.astype(jnp.float32))
    return o.reshape(B, Tq, H, D).astype(v.dtype)
