"""Pallas TPU kernel for the RWKV-6 (Finch) WKV recurrence.

TPU adaptation: the recurrence is O(1)-state sequential in T, so the grid
parallelises over (batch, head) and each program streams its time series
through VMEM while the (N, N) state matrix stays resident in VMEM scratch
— the same structure Mamba/linear-attention TPU kernels use.  N = 64
(rwkv6) keeps the state tile MXU/VREG-friendly; the T-loop body is pure
VPU elementwise + rank-1 updates.

    y_t = r_t^T (s_{t-1} + (u * k_t) outer v_t)
    s_t = diag(w_t) s_{t-1} + k_t outer v_t

Oracle: repro.models.recurrent.wkv6_scan_ref (re-exported in ref.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sT_ref, *,
            chunk: int):
    """One (b, h) stream.  r/k/v/w refs: (1, T, 1, N); u: (1, N);
    s0/sT: (1, 1, N, N); y: (1, T, 1, N)."""
    T, N = r_ref.shape[1], r_ref.shape[3]
    # index the loaded arrays, not the refs: scalar-int ref indices are
    # unsupported by interpret-mode discharge in this pallas version
    u = u_ref[...][0].astype(jnp.float32)                # (N,)
    s = s0_ref[...][0, 0].astype(jnp.float32)            # (N, N) rows=k, cols=v

    nchunks = T // chunk

    def chunk_body(c, s):
        t0 = c * chunk
        def tchunk(ref):
            return pl.load(ref, (pl.dslice(0, 1), pl.dslice(t0, chunk),
                                 pl.dslice(0, 1), slice(None))
                           )[0, :, 0].astype(jnp.float32)

        r, k, v, w = tchunk(r_ref), tchunk(k_ref), tchunk(v_ref), \
            tchunk(w_ref)

        def step(t, carry):
            s, ys = carry
            rt, kt, vt, wt = r[t], k[t], v[t], w[t]      # (N,)
            kv = kt[:, None] * vt[None, :]               # (N, N)
            y = ((s + u[:, None] * kv) * rt[:, None]).sum(axis=0)
            s = wt[:, None] * s + kv
            ys = ys.at[t].set(y)
            return s, ys

        ys0 = jnp.zeros((chunk, N), jnp.float32)
        s, ys = lax.fori_loop(0, chunk, step, (s, ys0))
        pl.store(y_ref, (pl.dslice(0, 1), pl.dslice(t0, chunk),
                         pl.dslice(0, 1), slice(None)),
                 ys.astype(y_ref.dtype)[None, :, None])
        return s

    s = lax.fori_loop(0, nchunks, chunk_body, s)
    sT_ref[...] = s.astype(sT_ref.dtype)[None, None]


def wkv6_pallas(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
                u: jax.Array, s0: jax.Array, *, chunk: int = 64,
                interpret: bool = False):
    """r,k,v,w: (B,T,H,N); u: (H,N); s0: (B,H,N,N) -> (y, s_T)."""
    B, T, H, N = r.shape
    chunk = min(chunk, T)
    assert T % chunk == 0, (T, chunk)
    grid = (B, H)
    io_spec = pl.BlockSpec((1, T, 1, N), lambda b, h: (b, 0, h, 0))
    y, sT = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[io_spec, io_spec, io_spec, io_spec,
                  pl.BlockSpec((1, N), lambda b, h: (h, 0)),
                  pl.BlockSpec((1, 1, N, N), lambda b, h: (b, h, 0, 0))],
        out_specs=[io_spec,
                   pl.BlockSpec((1, 1, N, N), lambda b, h: (b, h, 0, 0))],
        out_shape=[jax.ShapeDtypeStruct((B, T, H, N), r.dtype),
                   jax.ShapeDtypeStruct((B, H, N, N), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, s0)
    return y, sT
