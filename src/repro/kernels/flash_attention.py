"""Pallas TPU flash-attention kernel (causal / windowed, GQA).

TPU-native adaptation of the flash-attention blocking: one grid program
owns a (batch, head, q-block) tile; K/V stream through VMEM in
``block_kv``-sized slices with an online-softmax accumulator held in VMEM
scratch.  Block shapes are MXU-aligned (q/kv blocks multiples of 128 at
production sizes; the ``interpret=True`` CPU tests also sweep ragged
sizes).  GQA is expressed in the index maps — q heads map onto their
kv-head group, so KV tiles are fetched once per group, not per q head.

The pure-jnp oracle is ``repro.kernels.ref.attention_ref``; the jitted
dispatch wrapper is ``repro.kernels.ops.flash_attention``.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float, causal: bool,
            window: Optional[int], block_kv: int, seq_k: int):
    """One (b, h, iq) tile.  q_ref: (1,1,bq,D); k_ref/v_ref: (1,1,Sk,D)."""
    bq, D = q_ref.shape[2], q_ref.shape[3]
    iq = pl.program_id(2)
    # index the loaded array, not the ref: scalar-int ref indices are
    # unsupported by interpret-mode discharge in this pallas version
    q = q_ref[...][0, 0].astype(jnp.float32) * scale

    nkv = seq_k // block_kv
    q0 = iq * bq
    # block range this q tile can see (dynamic fori bounds are fine)
    if causal:
        hi = jnp.minimum((q0 + bq + block_kv - 1) // block_kv, nkv)
    else:
        hi = nkv
    lo = 0
    if window is not None:
        lo = jnp.maximum(0, (q0 - window) // block_kv)

    def body(j, carry):
        acc, m, l = carry
        k = pl.load(k_ref, (pl.dslice(0, 1), pl.dslice(0, 1),
                            pl.dslice(j * block_kv, block_kv),
                            slice(None)))[0, 0].astype(jnp.float32)
        v = pl.load(v_ref, (pl.dslice(0, 1), pl.dslice(0, 1),
                            pl.dslice(j * block_kv, block_kv),
                            slice(None)))[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        qpos = q0 + lax.broadcasted_iota(jnp.int32, (bq, block_kv), 0)
        kpos = j * block_kv + lax.broadcasted_iota(jnp.int32,
                                                   (bq, block_kv), 1)
        mask = jnp.ones((bq, block_kv), jnp.bool_)
        if causal:
            mask &= qpos >= kpos
        if window is not None:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, NEG_INF)
        m_b = jnp.max(s, axis=1)
        m_new = jnp.maximum(m, m_b)
        p = jnp.exp(s - m_new[:, None])
        c = jnp.exp(m - m_new)
        l_new = l * c + jnp.sum(p, axis=1)
        acc_new = acc * c[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return acc_new, m_new, l_new

    acc0 = jnp.zeros((bq, D), jnp.float32)
    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc, m, l = lax.fori_loop(lo, hi, body, (acc0, m0, l0))
    out = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)
    o_ref[...] = out[None, None]


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True,
                           window: Optional[int] = None,
                           block_q: int = 128, block_kv: int = 128,
                           interpret: bool = False) -> jax.Array:
    """q: (B, Tq, H, D); k, v: (B, Tk, G, D); H = G * R.  Returns (B,Tq,H,D).

    Grid: (B, H, Tq/block_q).  KV index maps route q head h to kv head
    h // R (GQA sharing).
    """
    B, Tq, H, D = q.shape
    Tk, G = k.shape[1], k.shape[2]
    R = H // G
    block_q = min(block_q, Tq)
    block_kv = min(block_kv, Tk)
    assert Tq % block_q == 0 and Tk % block_kv == 0, (Tq, Tk)
    scale = 1.0 / math.sqrt(D)

    # layout: put head next to batch so each tile is a contiguous 2D slab
    qt = q.transpose(0, 2, 1, 3)          # (B, H, Tq, D)
    kt = k.transpose(0, 2, 1, 3)          # (B, G, Tk, D)
    vt = v.transpose(0, 2, 1, 3)

    grid = (B, H, Tq // block_q)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal,
                          window=window, block_kv=block_kv, seq_k=Tk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D),
                         lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, Tk, D),
                         lambda b, h, i, R=R: (b, h // R, 0, 0)),
            pl.BlockSpec((1, 1, Tk, D),
                         lambda b, h, i, R=R: (b, h // R, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Tq, D), q.dtype),
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
