"""Jitted dispatch wrappers for the Pallas kernels.

On TPU the Pallas kernels run natively; on CPU (this container) they run
under ``interpret=True`` for correctness tests, while the model layers use
their pure-jnp paths by default.  ``use_pallas(True)`` flips model-side
dispatch (repro.models reads this at trace time); it also works as a
context manager — ``with use_pallas(): ...`` — which restores the prior
value on exit and is the form tests should use.

Two hot-path rules this module enforces (regression-tested in
``tests/test_kernels.py``):

* ``interpret`` is a **static jit argument resolved at call time**, never
  read inside a traced function.  A trace-time read bakes the flag into
  the jit cache, which is keyed only by shapes/static args — if
  ``jax.default_backend()`` changes after the first call (or a test
  forces a platform), the stale flag would silently replay.
* the ``use_pallas`` toggle is guarded by a lock: the serving front-end
  traces from N worker threads plus the tick thread concurrently, so a
  bare global read-modify-write races.
"""
from __future__ import annotations

import functools
import threading
from typing import Optional

import jax

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.rwkv6_scan import wkv6_pallas

_FORCE_PALLAS = False
_FORCE_LOCK = threading.Lock()


class _PallasToggle:
    """Returned by :func:`use_pallas`: the flag is already set (so the
    bare-call form keeps working); used as a context manager it restores
    the value that was live when :func:`use_pallas` was called."""

    def __init__(self, prior: bool):
        self._prior = prior

    def __enter__(self) -> "_PallasToggle":
        return self

    def __exit__(self, *exc) -> None:
        global _FORCE_PALLAS
        with _FORCE_LOCK:
            _FORCE_PALLAS = self._prior


def use_pallas(on: bool = True) -> _PallasToggle:
    """Force model-side Pallas dispatch on/off (thread-safe).  Use the
    context-manager form in tests — ``with use_pallas(): ...`` — so the
    prior value is restored however the block exits."""
    global _FORCE_PALLAS
    with _FORCE_LOCK:
        prior = _FORCE_PALLAS
        _FORCE_PALLAS = on
    return _PallasToggle(prior)


def pallas_enabled() -> bool:
    with _FORCE_LOCK:
        forced = _FORCE_PALLAS
    return forced or jax.default_backend() == "tpu"


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_kv", "interpret"))
def _flash_attention_jit(q, k, v, *, causal, window, block_q, block_kv,
                         interpret):
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  block_q=block_q, block_kv=block_kv,
                                  interpret=interpret)


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    block_q: int = 128, block_kv: int = 128,
                    interpret: Optional[bool] = None):
    """Flash attention (Pallas), interpreted on CPU.  ``interpret=None``
    resolves from the *current* default backend, outside the trace, so
    the jit cache keys on it (a backend change re-traces instead of
    replaying the first call's flag)."""
    if interpret is None:
        interpret = _interpret()
    return _flash_attention_jit(q, k, v, causal=causal, window=window,
                                block_q=block_q, block_kv=block_kv,
                                interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def _wkv6_jit(r, k, v, w, u, s0, *, chunk, interpret):
    return wkv6_pallas(r, k, v, w, u, s0, chunk=chunk,
                       interpret=interpret)


def wkv6(r, k, v, w, u, s0, *, chunk: int = 64,
         interpret: Optional[bool] = None):
    """RWKV-6 recurrence (Pallas), interpreted on CPU; ``interpret`` is
    resolved at call time like :func:`flash_attention`."""
    if interpret is None:
        interpret = _interpret()
    return _wkv6_jit(r, k, v, w, u, s0, chunk=chunk, interpret=interpret)
