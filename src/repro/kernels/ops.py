"""Jitted dispatch wrappers for the Pallas kernels.

On TPU the Pallas kernels run natively; on CPU (this container) they run
under ``interpret=True`` for correctness tests, while the model layers use
their pure-jnp paths by default.  ``use_pallas(True)`` flips model-side
dispatch (repro.models reads this at trace time).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.rwkv6_scan import wkv6_pallas

_FORCE_PALLAS = False


def use_pallas(on: bool = True) -> None:
    global _FORCE_PALLAS
    _FORCE_PALLAS = on


def pallas_enabled() -> bool:
    return _FORCE_PALLAS or jax.default_backend() == "tpu"


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_kv"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    block_q: int = 128, block_kv: int = 128):
    """Flash attention (Pallas), interpreted on CPU."""
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  block_q=block_q, block_kv=block_kv,
                                  interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("chunk",))
def wkv6(r, k, v, w, u, s0, *, chunk: int = 64):
    """RWKV-6 recurrence (Pallas), interpreted on CPU."""
    return wkv6_pallas(r, k, v, w, u, s0, chunk=chunk,
                       interpret=_interpret())
