"""Fault-tolerant checkpointing: atomic, async, resharding-on-restore.

Design (no orbax dependency):

* **Layout** — one directory per step: ``step_000123/arrays.npz`` +
  ``manifest.json`` (step, pytree structure, logical axes, mesh shape).
* **Atomicity** — write to ``step_N.tmp-<pid>``, fsync, ``os.rename``;
  a crashed save can never be mistaken for a complete one.  A ``LATEST``
  file is updated (also via rename) after the directory lands.
* **Async** — ``save()`` snapshots arrays to host (device_get) then hands
  the file I/O to a background thread, so the train loop only blocks for
  the host copy.  ``wait()`` joins outstanding saves (called before exit
  and before starting a save for the same step dir).
* **Keep-k GC** — oldest checkpoints beyond ``keep`` are deleted after a
  successful save.
* **Elastic restore** — arrays are saved *unsharded* (gathered); restore
  takes the current mesh + rules and re-shards onto them, so a job may
  restart on a different mesh shape (e.g. 256 -> 128 chips after a pod
  failure).  This is the 'elastic scaling' path exercised in tests.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any) -> Tuple[List[Tuple[str, Any]], Any]:
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(p), v) for p, v in leaves], treedef


class Checkpointer:
    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, params: Any, opt_state: Any = None,
             extra: Optional[Dict[str, Any]] = None,
             block: bool = False) -> None:
        self.wait()
        tree = {"params": params}
        if opt_state is not None:
            tree["opt_state"] = opt_state
        flat, _ = _flatten(tree)
        # host snapshot (gather across shards) happens synchronously so the
        # training step may safely donate/overwrite device buffers next step
        host = [(k, np.asarray(jax.device_get(v))) for k, v in flat]
        manifest = {
            "step": step,
            "keys": [k for k, _ in host],
            "time": time.time(),
            "extra": extra or {},
        }
        t = threading.Thread(target=self._write, args=(step, host, manifest),
                             daemon=True)
        self._thread = t
        t.start()
        if block:
            self.wait()

    def _write(self, step: int, host, manifest) -> None:
        try:
            final = os.path.join(self.directory, f"step_{step:08d}")
            tmp = f"{final}.tmp-{os.getpid()}"
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "arrays.npz"),
                     **{k: v for k, v in host})
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            latest_tmp = os.path.join(self.directory, f".LATEST.tmp-{os.getpid()}")
            with open(latest_tmp, "w") as f:
                f.write(os.path.basename(final))
            os.rename(latest_tmp, os.path.join(self.directory, "LATEST"))
            self._gc()
        except BaseException as e:   # surfaced on next wait()
            self._error = e

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    def _gc(self) -> None:
        steps = sorted(d for d in os.listdir(self.directory)
                       if d.startswith("step_") and ".tmp" not in d)
        for d in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, d),
                          ignore_errors=True)

    # -- restore -------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        path = os.path.join(self.directory, "LATEST")
        if not os.path.exists(path):
            return None
        with open(path) as f:
            name = f.read().strip()
        if not os.path.exists(os.path.join(self.directory, name,
                                           "manifest.json")):
            return None
        return int(name.split("_")[1])

    def restore(self, template: Any, *, step: Optional[int] = None,
                shardings: Any = None) -> Tuple[Any, int]:
        """Restore into the structure of ``template``.

        ``shardings``: optional NamedSharding tree (same structure) — arrays
        are placed onto it (the elastic-restart path: the current mesh may
        differ from the one that saved).  Without it arrays load as numpy.
        Returns (tree, step).
        """
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoint in {self.directory}")
        d = os.path.join(self.directory, f"step_{step:08d}")
        with np.load(os.path.join(d, "arrays.npz")) as z:
            data = {k: z[k] for k in z.files}
        flat, treedef = _flatten(template)
        sh_flat = None
        if shardings is not None:
            sh_flat = [v for _, v in _flatten(shardings)[0]]
        out = []
        for i, (k, tmpl) in enumerate(flat):
            if k not in data:
                raise KeyError(f"checkpoint missing leaf {k}")
            arr = data[k]
            if tuple(arr.shape) != tuple(tmpl.shape):
                raise ValueError(
                    f"shape mismatch for {k}: ckpt {arr.shape} vs "
                    f"template {tmpl.shape}")
            if sh_flat is not None:
                arr = jax.device_put(arr, sh_flat[i])
            out.append(arr)
        tree = jax.tree_util.tree_unflatten(treedef, out)
        return tree, step
