"""Optimizers (AdamW, Adafactor) and schedules in pure JAX pytree form.

No optax dependency.  Optimizer state mirrors the parameter tree so the
same sharding rules apply leaf-for-leaf (ZeRO-style: moments shard exactly
like their parameters).  Moment dtype is configurable — bf16 moments halve
optimizer HBM for the 400B-class configs (see EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.types import ParamSpec, SpecTree


# --- schedules -----------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class WarmupCosine:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    final_frac: float = 0.1

    def __call__(self, step: jax.Array) -> jax.Array:
        step = step.astype(jnp.float32)
        warm = self.peak_lr * step / max(1, self.warmup_steps)
        progress = jnp.clip((step - self.warmup_steps)
                            / max(1, self.total_steps - self.warmup_steps),
                            0.0, 1.0)
        cos = self.final_frac + (1 - self.final_frac) * 0.5 * (
            1 + jnp.cos(jnp.pi * progress))
        return jnp.where(step < self.warmup_steps, warm, self.peak_lr * cos)


# --- global-norm clipping ---------------------------------------------------------

def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(tree: Any, max_norm: float) -> Tuple[Any, jax.Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree), norm


# --- AdamW --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AdamW:
    schedule: Callable[[jax.Array], jax.Array]
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moment_dtype: Any = jnp.float32
    max_grad_norm: float = 1.0

    def init(self, params: Any) -> Dict[str, Any]:
        zeros = lambda p: jnp.zeros(p.shape, self.moment_dtype)
        return {
            "m": jax.tree_util.tree_map(zeros, params),
            "v": jax.tree_util.tree_map(zeros, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def state_specs(self, param_specs: SpecTree) -> Dict[str, Any]:
        """ParamSpec tree for the optimizer state (same logical axes)."""
        def mom(s: ParamSpec) -> ParamSpec:
            return ParamSpec(s.shape, s.axes, init="zeros",
                             dtype=self.moment_dtype)
        as_spec = lambda: jax.tree_util.tree_map(
            mom, param_specs, is_leaf=lambda x: isinstance(x, ParamSpec))
        return {"m": as_spec(), "v": as_spec(),
                "count": ParamSpec((), (), init="zeros", dtype=jnp.int32)}

    def update(self, grads: Any, state: Dict[str, Any], params: Any
               ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
        grads, gnorm = clip_by_global_norm(grads, self.max_grad_norm)
        count = state["count"] + 1
        b1c = 1 - self.b1 ** count.astype(jnp.float32)
        b2c = 1 - self.b2 ** count.astype(jnp.float32)
        lr = self.schedule(count)

        def upd(p, g, m, v):
            g32 = g.astype(jnp.float32)
            m32 = self.b1 * m.astype(jnp.float32) + (1 - self.b1) * g32
            v32 = self.b2 * v.astype(jnp.float32) + (1 - self.b2) * g32 * g32
            mhat = m32 / b1c
            vhat = v32 / b2c
            step = mhat / (jnp.sqrt(vhat) + self.eps)
            step = step + self.weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - lr * step
            return (new_p.astype(p.dtype), m32.astype(self.moment_dtype),
                    v32.astype(self.moment_dtype))

        out = jax.tree_util.tree_map(upd, params, grads,
                                     state["m"], state["v"])
        # unzip the 3-tuples
        new_params = jax.tree_util.tree_map(
            lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree_util.tree_map(
            lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree_util.tree_map(
            lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
        new_state = {"m": new_m, "v": new_v, "count": count}
        return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


# --- Adafactor (factored second moment: O(n+m) state for (n,m) matrices) ----------

@dataclasses.dataclass(frozen=True)
class Adafactor:
    schedule: Callable[[jax.Array], jax.Array]
    decay: float = 0.99
    eps: float = 1e-30
    clip_threshold: float = 1.0
    weight_decay: float = 0.0
    max_grad_norm: float = 1.0

    def _factored(self, shape) -> bool:
        return len(shape) >= 2

    # the factored state is a *list* of per-leaf dicts in tree_flatten
    # order of the parameter tree (shapes differ per leaf, so the state
    # cannot mirror the parameter tree structure leaf-for-leaf).
    def init(self, params: Any) -> Dict[str, Any]:
        leaves = jax.tree_util.tree_leaves(params)
        f = []
        for p in leaves:
            if self._factored(p.shape):
                f.append({"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                          "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                          jnp.float32)})
            else:
                f.append({"v": jnp.zeros(p.shape, jnp.float32)})
        return {"f": f, "count": jnp.zeros((), jnp.int32)}

    def state_specs(self, param_specs: SpecTree) -> Dict[str, Any]:
        leaves = jax.tree_util.tree_leaves(
            param_specs, is_leaf=lambda x: isinstance(x, ParamSpec))
        f = []
        for s in leaves:
            if self._factored(s.shape):
                f.append({"vr": ParamSpec(s.shape[:-1], s.axes[:-1],
                                          init="zeros", dtype=jnp.float32),
                          "vc": ParamSpec(s.shape[:-2] + s.shape[-1:],
                                          s.axes[:-2] + s.axes[-1:],
                                          init="zeros", dtype=jnp.float32)})
            else:
                f.append({"v": ParamSpec(s.shape, s.axes, init="zeros",
                                         dtype=jnp.float32)})
        return {"f": f, "count": ParamSpec((), (), init="zeros",
                                           dtype=jnp.int32)}

    def update(self, grads: Any, state: Dict[str, Any], params: Any):
        grads, gnorm = clip_by_global_norm(grads, self.max_grad_norm)
        count = state["count"] + 1
        lr = self.schedule(count)
        beta = self.decay

        def upd(p, g, f):
            g32 = g.astype(jnp.float32)
            g2 = g32 * g32 + self.eps
            if self._factored(p.shape):
                vr = beta * f["vr"] + (1 - beta) * g2.mean(-1)
                vc = beta * f["vc"] + (1 - beta) * g2.mean(-2)
                denom = (vr[..., None] / jnp.maximum(
                    vr.mean(-1, keepdims=True)[..., None], self.eps)) * \
                    vc[..., None, :]
                step = g32 * jax.lax.rsqrt(jnp.maximum(denom, self.eps))
                nf = {"vr": vr, "vc": vc}
            else:
                v = beta * f["v"] + (1 - beta) * g2
                step = g32 * jax.lax.rsqrt(jnp.maximum(v, self.eps))
                nf = {"v": v}
            rms = jnp.sqrt(jnp.mean(step * step) + 1e-12)
            step = step / jnp.maximum(1.0, rms / self.clip_threshold)
            new_p = p.astype(jnp.float32) - lr * (
                step + self.weight_decay * p.astype(jnp.float32))
            return new_p.astype(p.dtype), nf

        p_leaves, treedef = jax.tree_util.tree_flatten(params)
        g_leaves = jax.tree_util.tree_leaves(grads)
        outs = [upd(p, g, f)
                for p, g, f in zip(p_leaves, g_leaves, state["f"])]
        new_params = jax.tree_util.tree_unflatten(
            treedef, [o[0] for o in outs])
        new_f = [o[1] for o in outs]
        return new_params, {"f": new_f, "count": count}, \
            {"grad_norm": gnorm, "lr": lr}


def make_optimizer(kind: str = "adamw", *, peak_lr: float = 3e-4,
                   total_steps: int = 10000, warmup_steps: int = 100,
                   moment_dtype=jnp.float32, weight_decay: float = 0.1):
    sched = WarmupCosine(peak_lr=peak_lr, warmup_steps=warmup_steps,
                         total_steps=total_steps)
    if kind == "adamw":
        return AdamW(schedule=sched, moment_dtype=moment_dtype,
                     weight_decay=weight_decay)
    if kind == "adafactor":
        return Adafactor(schedule=sched, weight_decay=weight_decay)
    raise ValueError(kind)
