"""Training step factory + host-side training loop.

``make_train_step`` builds the jittable (params, opt_state, batch) ->
(params, opt_state, metrics) function with optional microbatch gradient
accumulation (lax.scan) and optional int8 error-feedback gradient
compression on the data-parallel all-reduce.  The host loop adds
fault-tolerance: periodic async checkpoints, preemption-signal checkpoint,
and a straggler watchdog.
"""
from __future__ import annotations

import dataclasses
import signal
import threading
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.obs import MetricsRegistry
from repro.train import optimizer as opt_lib


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: str = "adamw"
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    weight_decay: float = 0.1
    moment_dtype: str = "float32"      # "bfloat16" halves optimizer HBM
    microbatches: int = 1              # gradient accumulation
    remat: bool = True
    grad_compression: bool = False     # int8 error-feedback DP all-reduce

    def make_optimizer(self):
        mdt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[self.moment_dtype]
        return opt_lib.make_optimizer(
            self.optimizer, peak_lr=self.peak_lr,
            total_steps=self.total_steps, warmup_steps=self.warmup_steps,
            moment_dtype=mdt, weight_decay=self.weight_decay)


def _split_microbatches(batch: Dict[str, jax.Array], n: int
                        ) -> Dict[str, jax.Array]:
    """Reshape leading batch dim B -> (n, B//n)."""
    def rs(x):
        return x.reshape((n, x.shape[0] // n) + x.shape[1:])
    return jax.tree_util.tree_map(rs, batch)


def make_train_step(model, tcfg: TrainConfig,
                    compress_fn: Optional[Callable] = None):
    """Returns (train_step, optimizer).

    train_step(params, opt_state, batch) -> (params, opt_state, metrics).
    """
    opt = tcfg.make_optimizer()

    def loss_fn(params, batch):
        return model.loss(params, batch, remat=tcfg.remat)

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        metrics = dict(metrics, loss=loss)
        return grads, metrics

    def train_step(params, opt_state, batch):
        if tcfg.microbatches > 1:
            mb = _split_microbatches(batch, tcfg.microbatches)

            def body(carry, mb_i):
                acc, _ = carry
                g, m = grads_of(params, mb_i)
                acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(a.dtype), acc, g)
                return (acc, m), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            m0 = {"loss": jnp.zeros((), jnp.float32),
                  "xent": jnp.zeros((), jnp.float32),
                  "z_loss": jnp.zeros((), jnp.float32),
                  "aux": jnp.zeros((), jnp.float32),
                  "tokens": jnp.zeros((), jnp.float32)}
            (gsum, metrics), _ = jax.lax.scan(body, (zeros, m0), mb)
            grads = jax.tree_util.tree_map(
                lambda g: g / tcfg.microbatches, gsum)
        else:
            grads, metrics = grads_of(params, batch)

        if compress_fn is not None:
            grads = compress_fn(grads)

        params, opt_state, opt_metrics = opt.update(grads, opt_state, params)
        metrics.update(opt_metrics)
        return params, opt_state, metrics

    return train_step, opt


# ---------------------------------------------------------------------------
# host-side loop with fault-tolerance hooks
# ---------------------------------------------------------------------------

class StragglerWatchdog:
    """Flags steps exceeding ``factor`` x the rolling median step time.

    On a real cluster the flag feeds the job controller (restart the slow
    host / exclude it on the next elastic resize); here it records events
    so tests and the example driver can observe mitigation decisions.
    """

    def __init__(self, factor: float = 3.0, history: int = 32):
        self.factor = factor
        self.history = history
        self.times: list = []
        self.events: list = []

    def observe(self, step: int, seconds: float) -> bool:
        slow = False
        if len(self.times) >= 5:
            med = sorted(self.times)[len(self.times) // 2]
            if seconds > self.factor * med:
                self.events.append((step, seconds, med))
                slow = True
        self.times.append(seconds)
        if len(self.times) > self.history:
            self.times.pop(0)
        return slow


class PreemptionHandler:
    """SIGTERM -> request a checkpoint at the next step boundary."""

    def __init__(self):
        self.requested = threading.Event()
        try:
            signal.signal(signal.SIGTERM, self._on_signal)
        except ValueError:
            pass   # not the main thread (tests)

    def _on_signal(self, signum, frame):
        self.requested.set()


def train_loop(model, tcfg: TrainConfig, params, opt_state, batches, *,
               steps: int, checkpointer=None, checkpoint_every: int = 100,
               watchdog: Optional[StragglerWatchdog] = None,
               log_every: int = 10, start_step: int = 0,
               train_step=None,
               obs: Optional[MetricsRegistry] = None
               ) -> Tuple[Any, Any, Dict[str, list]]:
    """Simple host loop: step, log, checkpoint, watch for stragglers.

    ``batches`` is an iterator of ready (sharded) batches.  With ``obs``
    wired (a :class:`~repro.obs.MetricsRegistry`), each step's wall time
    lands in the ``train.step`` histogram and every watchdog flag in the
    ``train.slow_steps`` counter — the same registry/export format as
    the serving pipeline (DESIGN.md §12), so one ``render()`` covers the
    whole job.  Timing uses the registry's injectable clock, so tests
    can pin step latencies with a fake clock.
    """
    if train_step is None:
        train_step, _ = make_train_step(model, tcfg)
    train_step = jax.jit(train_step, donate_argnums=(0, 1))
    preempt = PreemptionHandler()
    history: Dict[str, list] = {"loss": [], "step_time": []}
    clock = obs.clock if obs is not None else time.perf_counter
    h_step = obs.histogram("train.step") if obs is not None else None
    c_slow = obs.counter("train.slow_steps") if obs is not None else None

    step = start_step
    for step in range(start_step, steps):
        batch = next(batches)
        t0 = clock()
        params, opt_state, metrics = train_step(params, opt_state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = clock() - t0
        history["loss"].append(float(metrics["loss"]))
        history["step_time"].append(dt)
        if h_step is not None:
            h_step.observe(dt)
        if watchdog is not None:
            if watchdog.observe(step, dt) and c_slow is not None:
                c_slow.inc()
        if log_every and step % log_every == 0:
            print(f"step {step:6d} loss {float(metrics['loss']):.4f} "
                  f"grad_norm {float(metrics['grad_norm']):.3f} "
                  f"{dt*1e3:.1f} ms")
        want_ckpt = checkpointer is not None and (
            (step + 1) % checkpoint_every == 0 or preempt.requested.is_set())
        if want_ckpt:
            checkpointer.save(step + 1, params, opt_state)
            if preempt.requested.is_set():
                print(f"preemption checkpoint at step {step + 1}; exiting")
                break
    return params, opt_state, history
