"""Int8 error-feedback gradient compression for the DP all-reduce.

Distributed-optimization trick for bandwidth-bound scale-out: gradients are
quantised to int8 with a per-tensor scale before the data-parallel
reduction, and the quantisation residual is fed back into the next step
(error feedback preserves convergence; Karimireddy et al., 2019).

Under pjit the all-reduce is implicit (XLA inserts it where gradients
combine), so the compression point is expressed with shard_map: gradients
are quantised per shard, all-reduced in int32 across the "data"/"pod"
axes, and rescaled.  ``compressed_psum_grads`` is the shard_map version
used when a mesh is active; ``ErrorFeedback`` carries the residual state
and works in single-process tests too.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def quantise_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantisation.  Returns (q, scale)."""
    x32 = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x32))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantise(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


@dataclasses.dataclass
class ErrorFeedback:
    """Residual state + compress step (pure; state is a grad-shaped tree)."""

    def init(self, grads_template: Any) -> Any:
        return jax.tree_util.tree_map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads_template)

    def compress(self, grads: Any, residual: Any) -> Tuple[Any, Any]:
        """Quantise (grads + residual); return (dequantised, new residual)."""
        def leaf(g, r):
            x = g.astype(jnp.float32) + r
            q, s = quantise_int8(x)
            deq = dequantise(q, s)
            return deq.astype(g.dtype), x - deq
        out = jax.tree_util.tree_map(leaf, grads, residual)
        deq = jax.tree_util.tree_map(lambda t: t[0], out,
                                     is_leaf=lambda x: isinstance(x, tuple))
        res = jax.tree_util.tree_map(lambda t: t[1], out,
                                     is_leaf=lambda x: isinstance(x, tuple))
        return deq, res


def compressed_psum(x: jax.Array, axis_names) -> jax.Array:
    """Quantise-then-psum: int8 payload on the wire, f32 result.

    Per-shard scales are reduced with a max so the dequantisation is
    consistent; payload = int8 tensor + one f32 scalar.
    """
    q, scale = quantise_int8(x)
    scale = jax.lax.pmax(scale, axis_names)
    q32 = jax.lax.psum(q.astype(jnp.int32), axis_names)
    return q32.astype(jnp.float32) * scale


def make_compressed_allreduce(mesh: Mesh, axis_names=("data",)):
    """shard_map'd gradient all-reduce with int8 payload.

    Gradients arrive sharded over the model axis (TP) and replicated over
    data after jax's grad; in the compressed variant the train step keeps
    per-data-shard partial gradients (microbatch split) and reduces them
    here explicitly.
    """
    def allreduce(grads_tree):
        def per_shard(*leaves_in):
            return tuple(compressed_psum(l, axis_names) for l in leaves_in)

        leaves, treedef = jax.tree_util.tree_flatten(grads_tree)
        specs = tuple(P() for _ in leaves)   # replicated view per leaf
        fn = shard_map(per_shard, mesh=mesh, in_specs=specs,
                       out_specs=specs, check_rep=False)
        out = fn(*leaves)
        return jax.tree_util.tree_unflatten(treedef, list(out))
    return allreduce
