"""Batched serving engine: prefill + decode with continuous slot reuse.

The engine keeps a fixed decode batch of ``slots``; finished sequences free
their slot, which the admission loop refills from the request queue
(continuous batching at slot granularity).  All sequences in a decode batch
share the position counter — a slot admitted mid-stream left-pads so its
cache lines up (the standard static-batching trade-off; per-slot position
tensors are a documented extension).

``serve_step`` — one token for the whole batch against the KV/recurrent
state — is the unit the dry-run lowers for the ``decode_*`` cells.

Fleet placement: :func:`plan_decode_placement` asks a
:class:`repro.selector.SelectionService` which profiled mesh the decode
fleet should run on under current chip prices (DESIGN.md §3); the
resulting :class:`repro.selector.Decision` can be attached to the engine
as ``placement`` so serving metadata records where (and at what $/h) the
batch is meant to run.
"""
from __future__ import annotations

import dataclasses
import queue
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.types import ModelConfig
from repro.obs import MetricsRegistry
from repro.selector import Decision, SelectionService


def plan_decode_placement(service: SelectionService,
                          shape_name: str = "decode_32k",
                          *, annotation=None,
                          exclude_archs: Tuple[str, ...] = (),
                          current: Optional[Decision] = None,
                          switch_cost_hours: float = 0.25,
                          horizon_hours: float = 24.0,
                          hysteresis: float = 1.25) -> Decision:
    """Pick the mesh for a decode fleet via the selection service.

    ``shape_name`` is the workload cell the fleet serves (class A,
    state-resident, unless annotated otherwise); the service ranks every
    profiled mesh option by summed normalized cost under current prices.

    With ``current`` (the fleet's standing placement decision), the
    hysteresis advisor (:func:`repro.market.should_migrate`, DESIGN.md
    §6) gates the move: a running fleet only switches mesh when projected
    savings over ``horizon_hours`` beat ``hysteresis`` times the
    ``switch_cost_hours`` of dual-running during cutover.  When the
    advisor says stay, the returned Decision keeps the current mesh but
    is re-stamped with today's ranking, $/h and price epoch.
    """
    decision = service.submit(shape_name, annotation=annotation,
                              exclude_groups=exclude_archs)
    if current is None or decision.config_id == current.config_id:
        return decision
    from repro.market.migration import should_migrate
    try:
        # quote savings/switch cost off today's rate, not the $/h stamped
        # when `current` was decided (which may predate any price move)
        current_rate: Optional[float] = service.catalog.hourly_cost(
            current.config_id, service.price_source)
    except KeyError:
        # deprovisioned entry: the advisor sees it as unrankable and
        # forces the move off the stamped rate
        current_rate = None
    advice = should_migrate(current, decision.ranking, switch_cost_hours,
                            horizon_hours=horizon_hours,
                            hysteresis=hysteresis,
                            current_hourly_cost=current_rate)
    if advice.migrate:
        return decision
    return dataclasses.replace(
        decision, config_id=current.config_id,
        entry=service.catalog.entry(current.config_id),
        hourly_cost=current_rate)


@dataclasses.dataclass
class Request:
    uid: int
    prompt: jax.Array              # (T,) int32
    max_new_tokens: int = 32
    eos_id: Optional[int] = None


@dataclasses.dataclass
class Completion:
    uid: int
    tokens: List[int]
    prefill_ms: float
    decode_ms: float


class Engine:
    """Greedy-decoding engine over a fixed slot batch."""

    def __init__(self, model, params, *, slots: int, max_len: int,
                 enc_len: int = 0, placement: Optional[Decision] = None,
                 metrics: Optional[MetricsRegistry] = None):
        self.model = model
        self.cfg: ModelConfig = model.cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.enc_len = enc_len
        #: where this fleet is meant to run (selector decision), if planned.
        self.placement = placement
        #: telemetry (DESIGN.md §12): per-wave ``serve.prefill`` /
        #: ``serve.decode`` histograms next to the Completion ms fields,
        #: timed off the registry's injectable clock.
        self.metrics = metrics
        self._clock = metrics.clock if metrics is not None \
            else time.perf_counter
        self._h_prefill = metrics.histogram("serve.prefill") \
            if metrics is not None else None
        self._h_decode = metrics.histogram("serve.decode") \
            if metrics is not None else None

        self._prefill = jax.jit(
            lambda p, b, s: model.prefill(p, b, s))
        self._decode = jax.jit(
            lambda p, t, pos, s: model.decode_step(p, t, pos, s))

    def _init_state(self):
        if self.cfg.is_encdec:
            return self.model.init_state(self.slots, self.max_len,
                                         self.enc_len)
        return self.model.init_state(self.slots, self.max_len)

    def generate_batch(self, requests: List[Request]) -> List[Completion]:
        """Serve a wave of requests of equal prompt length (greedy)."""
        assert 0 < len(requests) <= self.slots
        reqs = list(requests)
        while len(reqs) < self.slots:       # pad with a copy; discarded later
            reqs.append(dataclasses.replace(reqs[-1], uid=-1))
        prompts = jnp.stack([r.prompt for r in reqs])
        t0 = self._clock()
        state = self._init_state()
        batch = {"tokens": prompts}
        logits, state = self._prefill(self.params, batch, state)
        jax.block_until_ready(logits)
        t1 = self._clock()
        if self._h_prefill is not None:
            self._h_prefill.observe(t1 - t0)

        T_p = prompts.shape[1]
        max_new = max(r.max_new_tokens for r in reqs)
        out_tokens = [[] for _ in reqs]
        done = [False] * len(reqs)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        for step in range(max_new):
            for i, r in enumerate(reqs):
                t = int(tok[i])
                if not done[i]:
                    out_tokens[i].append(t)
                    if (r.eos_id is not None and t == r.eos_id) or \
                            len(out_tokens[i]) >= r.max_new_tokens:
                        done[i] = True
            if all(done):
                break
            pos = jnp.int32(T_p + step)
            if int(pos) >= self.max_len:
                break
            logits, state = self._decode(self.params, tok, pos, state)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        t2 = self._clock()
        if self._h_decode is not None:
            self._h_decode.observe(t2 - t1)
        return [Completion(uid=r.uid, tokens=out_tokens[i],
                           prefill_ms=(t1 - t0) * 1e3,
                           decode_ms=(t2 - t1) * 1e3)
                for i, r in enumerate(reqs) if r.uid >= 0]

    def serve(self, requests: List[Request]) -> List[Completion]:
        """Continuous admission: waves of up to ``slots`` requests."""
        out: List[Completion] = []
        pending = queue.SimpleQueue()
        for r in requests:
            pending.put(r)
        while not pending.empty():
            wave = []
            while len(wave) < self.slots and not pending.empty():
                wave.append(pending.get())
            out.extend(self.generate_batch(wave))
        return out


def make_serve_step(model) -> Callable:
    """The unit the dry-run lowers for decode cells."""
    def serve_step(params, token, pos, state):
        return model.decode_step(params, token, pos, state)
    return serve_step
