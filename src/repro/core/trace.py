"""Trace dataset schema for Flora.

A *trace* is the output of the infrastructure-profiling step (Step 0 in the
paper): for every (test job, cluster configuration) pair, the measured
runtime.  The paper's own trace — 18 Spark jobs x 10 GCP configurations =
180 executions — is regenerated offline by :mod:`repro.core.spark_sim` with
the exact job list (Table I) and configuration list (Table II).
"""
from __future__ import annotations

import dataclasses
import enum
import json
from typing import Callable, Dict, Iterable, List, Mapping, Optional, Sequence, Tuple


class JobClass(enum.Enum):
    """Data-access-pattern classes (paper §II-C)."""

    A = "A"  # repeated specific data loading -> memory-demanding
    B = "B"  # single parallelisable data loading -> memory-yielding

    def flipped(self) -> "JobClass":
        return JobClass.B if self is JobClass.A else JobClass.A


@dataclasses.dataclass(frozen=True)
class CloudConfig:
    """One selectable cluster resource configuration (paper Table II)."""

    index: int                 # 1-based id, as in the paper
    instance_type: str         # e.g. "n2-highmem-8"
    scale_out: int             # number of nodes
    cores_per_node: int
    mem_per_node_gib: float

    @property
    def total_cores(self) -> int:
        return self.scale_out * self.cores_per_node

    @property
    def total_mem_gib(self) -> float:
        return self.scale_out * self.mem_per_node_gib

    @property
    def name(self) -> str:
        return f"#{self.index} {self.instance_type} x{self.scale_out}"


@dataclasses.dataclass(frozen=True)
class JobSpec:
    """A data processing job: algorithm + implementation + input dataset."""

    algorithm: str             # e.g. "Sort"
    data_type: str             # "Text" | "Vector" | "Tabular"
    dataset_gib: float
    job_class: JobClass        # expert ground-truth class (Table I)

    @property
    def name(self) -> str:
        return f"{self.algorithm}/{self.dataset_gib:g}GiB"


@dataclasses.dataclass(frozen=True)
class ExecutionRecord:
    """One profiled execution: job x config -> runtime."""

    job: JobSpec
    config_index: int
    runtime_s: float


class Trace:
    """Profiling trace: runtimes for (job, config) pairs.

    Pure-python container with the access patterns Flora needs: filter by
    class, exclude an algorithm (leave-one-algorithm-out evaluation), look
    up a runtime.
    """

    def __init__(self, configs: Sequence[CloudConfig],
                 records: Iterable[ExecutionRecord]):
        self.configs: List[CloudConfig] = list(configs)
        self.records: List[ExecutionRecord] = list(records)
        self._by_index: Dict[int, CloudConfig] = {c.index: c
                                                  for c in self.configs}
        self._by_key: Dict[Tuple[str, int], float] = {}
        self._jobs: Dict[str, JobSpec] = {}
        for r in self.records:
            self._by_key[(r.job.name, r.config_index)] = r.runtime_s
            self._jobs[r.job.name] = r.job

    # -- basic accessors ---------------------------------------------------
    @property
    def jobs(self) -> List[JobSpec]:
        return list(self._jobs.values())

    def config(self, index: int) -> CloudConfig:
        return self._by_index[index]

    def runtime_s(self, job: JobSpec, config: CloudConfig) -> float:
        return self._by_key[(job.name, config.index)]

    def has(self, job: JobSpec, config: CloudConfig) -> bool:
        return (job.name, config.index) in self._by_key

    # -- filters used by the selector ---------------------------------------
    def filter_jobs(self, *, job_class: Optional[JobClass] = None,
                    exclude_algorithms: Sequence[str] = ()) -> List[JobSpec]:
        out = []
        for j in self.jobs:
            if job_class is not None and j.job_class is not job_class:
                continue
            if j.algorithm in exclude_algorithms:
                continue
            out.append(j)
        return out

    # -- (de)serialisation ---------------------------------------------------
    def to_json(self) -> str:
        return json.dumps({
            "configs": [dataclasses.asdict(c) for c in self.configs],
            "records": [{
                "algorithm": r.job.algorithm,
                "data_type": r.job.data_type,
                "dataset_gib": r.job.dataset_gib,
                "job_class": r.job.job_class.value,
                "config_index": r.config_index,
                "runtime_s": r.runtime_s,
            } for r in self.records],
        }, indent=1)

    @classmethod
    def from_json(cls, text: str) -> "Trace":
        raw = json.loads(text)
        configs = [CloudConfig(**c) for c in raw["configs"]]
        records = []
        for r in raw["records"]:
            job = JobSpec(algorithm=r["algorithm"], data_type=r["data_type"],
                          dataset_gib=r["dataset_gib"],
                          job_class=JobClass(r["job_class"]))
            records.append(ExecutionRecord(job=job,
                                           config_index=r["config_index"],
                                           runtime_s=r["runtime_s"]))
        return cls(configs, records)

    # -- summary statistics (paper Table III) --------------------------------
    def stats(self, hourly_cost: Callable[[CloudConfig], float]) -> Mapping[str, Mapping[str, float]]:
        costs, runtimes = [], []
        for r in self.records:
            c = self.config(r.config_index)
            runtimes.append(r.runtime_s)
            costs.append(r.runtime_s / 3600.0 * hourly_cost(c))
        def describe(xs: List[float]) -> Mapping[str, float]:
            xs = sorted(xs)
            n = len(xs)
            mean = sum(xs) / n
            var = sum((x - mean) ** 2 for x in xs) / (n - 1)
            def q(p: float) -> float:
                # linear-interpolated quantile, matches numpy default
                idx = p * (n - 1)
                lo = int(idx)
                hi = min(lo + 1, n - 1)
                return xs[lo] + (xs[hi] - xs[lo]) * (idx - lo)
            return {"mean": mean, "std": var ** 0.5, "min": xs[0],
                    "25%": q(.25), "50%": q(.5), "75%": q(.75), "max": xs[-1],
                    "count": float(n)}
        return {"cost_usd": describe(costs), "runtime_s": describe(runtimes)}


# --- The paper's evaluation universe (Tables I & II) -------------------------

#: Table II — the ten GCP configurations.
GCP_CONFIGS: Tuple[CloudConfig, ...] = (
    CloudConfig(1, "n2-highcpu-8", 8, 8, 8),
    CloudConfig(2, "n2-standard-8", 8, 8, 32),
    CloudConfig(3, "n2-highmem-8", 8, 8, 64),
    CloudConfig(4, "n2-highmem-4", 4, 4, 32),
    CloudConfig(5, "n2-standard-8", 4, 8, 32),
    CloudConfig(6, "n2-highcpu-32", 4, 32, 32),
    CloudConfig(7, "n2-highmem-8", 2, 8, 64),
    CloudConfig(8, "n2-standard-4", 8, 4, 16),
    CloudConfig(9, "n2-standard-4", 16, 4, 16),
    CloudConfig(10, "n2-highcpu-8", 16, 8, 8),
)

#: Table I — 9 algorithms x 2 dataset sizes, with expert classes.
PAPER_JOBS: Tuple[JobSpec, ...] = (
    JobSpec("Grep", "Text", 3010, JobClass.B),
    JobSpec("Grep", "Text", 6020, JobClass.B),
    JobSpec("Sort", "Text", 94, JobClass.A),
    JobSpec("Sort", "Text", 188, JobClass.A),
    JobSpec("WordCount", "Text", 39, JobClass.B),
    JobSpec("WordCount", "Text", 77, JobClass.B),
    JobSpec("KMeans", "Vector", 102, JobClass.A),
    JobSpec("KMeans", "Vector", 204, JobClass.A),
    JobSpec("LinearRegression", "Vector", 229, JobClass.A),
    JobSpec("LinearRegression", "Vector", 459, JobClass.A),
    JobSpec("LogisticRegression", "Vector", 210, JobClass.A),
    JobSpec("LogisticRegression", "Vector", 420, JobClass.A),
    JobSpec("Join", "Tabular", 85, JobClass.A),
    JobSpec("Join", "Tabular", 172, JobClass.A),
    JobSpec("GroupByCount", "Tabular", 280, JobClass.B),
    JobSpec("GroupByCount", "Tabular", 560, JobClass.B),
    JobSpec("SelectWhereOrderBy", "Tabular", 92, JobClass.B),
    JobSpec("SelectWhereOrderBy", "Tabular", 185, JobClass.B),
)
