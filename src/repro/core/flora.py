"""Flora — the paper's selector (§II), as an adapter over repro.selector.

Given (i) an infrastructure-profiling trace, (ii) the submitted job's class
annotation, and (iii) *current* hourly prices, rank every cluster
configuration by the sum of per-test-job-normalized predicted costs and
pick the argmin:

    c* = argmin_c  sum_{j in P_K}  cost(j, c) / min_{c'} cost(j, c')
    cost(j, c) = runtime_in_hours(j, c) * current_hourly_cost(c)

The ranking math, profiling storage and caching live in
:mod:`repro.selector` (catalog / store / rank / service); this module keeps
the paper-faithful GCP-VM entry point and the historical ``rank_generic``
signature as a thin shim over the vectorized :func:`repro.selector.rank.rank_pairs`.
"""
from __future__ import annotations

from typing import (Callable, Hashable, List, Mapping, Optional, Sequence,
                    Tuple)

from repro.core import costmodel
from repro.core.trace import CloudConfig, JobClass, JobSpec, Trace
from repro.selector import (GcpVmCatalog, ProfilingStore, RankedConfig,
                            SelectionService, rank_pairs)

__all__ = ["Flora", "RankedConfig", "rank_generic"]


def rank_generic(
    runtime_hours: Mapping[Tuple[Hashable, Hashable], float],
    jobs: Sequence[Hashable],
    config_ids: Sequence[Hashable],
    hourly_cost: Callable[[Hashable], float],
) -> List[RankedConfig]:
    """Rank configurations by summed normalized cost over ``jobs``.

    .. deprecated:: use :func:`repro.selector.rank.rank_pairs` (sparse) or
       :func:`repro.selector.rank.rank_dense` (dense matrices) directly.
       This shim densifies and delegates; configurations with no profiled
       entries rank last (score ``+inf``), they no longer win at 0.0.
    """
    return rank_pairs(runtime_hours, jobs, config_ids, hourly_cost)


class Flora:
    """The paper's approach: classify, then rank by class-mates' costs."""

    def __init__(self, trace: Trace,
                 price: costmodel.LinearPriceModel,
                 *, one_class: bool = False):
        """``one_class=True`` gives the Fw1C baseline (skip Step 1)."""
        self.trace = trace
        self.price = price
        self.one_class = one_class
        # the paper-table reproduction is definitionally the float64
        # bit-stable contract (legacy dict-loop parity at 1e-12), so the
        # adapter pins numpy regardless of FLORA_RANK_BACKEND
        self.service = SelectionService(
            GcpVmCatalog(trace.configs, price),
            ProfilingStore.from_trace(trace), price, backend="numpy")

    # -- Step 2: ranking ------------------------------------------------------
    def rank(self, annotated_class: JobClass,
             exclude_algorithms: Sequence[str] = ()) -> List[RankedConfig]:
        job_class = None if self.one_class else annotated_class
        return list(self.service.rank(job_class=job_class,
                                      exclude_groups=tuple(exclude_algorithms)))

    def select(self, annotated_class: JobClass,
               exclude_algorithms: Sequence[str] = ()) -> CloudConfig:
        ranked = self.rank(annotated_class, exclude_algorithms)
        return self.trace.config(ranked[0].config_id)

    # -- convenience: full pipeline for a submitted job -----------------------
    def select_for_job(self, job: JobSpec, *,
                       annotated_class: Optional[JobClass] = None,
                       assume_unique: bool = True) -> CloudConfig:
        """Select a config for ``job``.

        ``annotated_class`` models the user annotation of Step 1; defaults
        to the expert class.  ``assume_unique`` enforces the paper's
        leave-one-algorithm-out discipline: profiling data from the same
        underlying algorithm is never used for the job itself (§III-A).
        """
        klass = annotated_class if annotated_class is not None else job.job_class
        exclude = (job.algorithm,) if assume_unique else ()
        return self.select(klass, exclude_algorithms=exclude)
