"""Flora — the paper's selector (§II).

Given (i) an infrastructure-profiling trace, (ii) the submitted job's class
annotation, and (iii) *current* hourly prices, rank every cluster
configuration by the sum of per-test-job-normalized predicted costs and
pick the argmin:

    c* = argmin_c  sum_{j in P_K}  cost(j, c) / min_{c'} cost(j, c')
    cost(j, c) = runtime_in_hours(j, c) * current_hourly_cost(c)

The ranking core is written generically over (job, config, runtime-hours)
triples so the TPU-side adaptation (:mod:`repro.core.tpu_flora`) reuses it
unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Hashable, List, Mapping, Optional, Sequence, Tuple

from repro.core import costmodel
from repro.core.trace import CloudConfig, JobClass, JobSpec, Trace


@dataclasses.dataclass(frozen=True)
class RankedConfig:
    config_id: Hashable
    score: float          # sum of normalized costs; lower is better
    mean_norm_cost: float  # score / number of test jobs


def rank_generic(
    runtime_hours: Mapping[Tuple[Hashable, Hashable], float],
    jobs: Sequence[Hashable],
    config_ids: Sequence[Hashable],
    hourly_cost: Callable[[Hashable], float],
) -> List[RankedConfig]:
    """Rank configurations by summed normalized cost over ``jobs``.

    ``runtime_hours[(job, config)]`` is the profiled runtime.  Jobs with a
    missing entry for some config contribute only over the configs they
    were profiled on (the paper's trace is complete, so this only matters
    for partial re-profiling, §II-B).
    """
    if not jobs:
        raise ValueError("no test jobs to learn from")
    scores: Dict[Hashable, float] = {c: 0.0 for c in config_ids}
    counts: Dict[Hashable, int] = {c: 0 for c in config_ids}
    for j in jobs:
        costs = {c: runtime_hours[(j, c)] * hourly_cost(c)
                 for c in config_ids if (j, c) in runtime_hours}
        if not costs:
            continue
        best = min(costs.values())
        if best <= 0:
            raise ValueError(f"non-positive cost for job {j!r}")
        for c, v in costs.items():
            scores[c] += v / best
            counts[c] += 1
    ranked = [RankedConfig(c, scores[c],
                           scores[c] / counts[c] if counts[c] else float("inf"))
              for c in config_ids]
    # deterministic: sort by score then by stable config order
    order = {c: i for i, c in enumerate(config_ids)}
    ranked.sort(key=lambda r: (r.score, order[r.config_id]))
    return ranked


class Flora:
    """The paper's approach: classify, then rank by class-mates' costs."""

    def __init__(self, trace: Trace,
                 price: costmodel.LinearPriceModel,
                 *, one_class: bool = False):
        """``one_class=True`` gives the Fw1C baseline (skip Step 1)."""
        self.trace = trace
        self.price = price
        self.one_class = one_class

    # -- Step 2: ranking ------------------------------------------------------
    def rank(self, annotated_class: JobClass,
             exclude_algorithms: Sequence[str] = ()) -> List[RankedConfig]:
        job_class = None if self.one_class else annotated_class
        test_jobs = self.trace.filter_jobs(
            job_class=job_class, exclude_algorithms=exclude_algorithms)
        runtime_hours = {
            (j.name, c.index): self.trace.runtime_s(j, c) / 3600.0
            for j in test_jobs for c in self.trace.configs
            if self.trace.has(j, c)}
        by_index = {c.index: c for c in self.trace.configs}
        return rank_generic(
            runtime_hours,
            [j.name for j in test_jobs],
            [c.index for c in self.trace.configs],
            lambda idx: self.price(by_index[idx]),
        )

    def select(self, annotated_class: JobClass,
               exclude_algorithms: Sequence[str] = ()) -> CloudConfig:
        ranked = self.rank(annotated_class, exclude_algorithms)
        return self.trace.config(ranked[0].config_id)

    # -- convenience: full pipeline for a submitted job -----------------------
    def select_for_job(self, job: JobSpec, *,
                       annotated_class: Optional[JobClass] = None,
                       assume_unique: bool = True) -> CloudConfig:
        """Select a config for ``job``.

        ``annotated_class`` models the user annotation of Step 1; defaults
        to the expert class.  ``assume_unique`` enforces the paper's
        leave-one-algorithm-out discipline: profiling data from the same
        underlying algorithm is never used for the job itself (§III-A).
        """
        klass = annotated_class if annotated_class is not None else job.job_class
        exclude = (job.algorithm,) if assume_unique else ()
        return self.select(klass, exclude_algorithms=exclude)
