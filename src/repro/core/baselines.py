"""Baseline resource-selection approaches from the paper's evaluation (§III-B).

Every approach implements ``select(job) -> CloudConfig | None`` (``None``
means "not applicable to this job", e.g. Juggler on non-iterative jobs) or
``expected_norm_cost`` for the random baseline.
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
from typing import List, Optional, Sequence

from repro.core import costmodel, spark_sim
from repro.core.flora import Flora
from repro.core.trace import CloudConfig, JobClass, JobSpec, Trace

ITERATIVE_ML = ("KMeans", "LinearRegression", "LogisticRegression")


class Approach:
    name: str = "abstract"

    def select(self, job: JobSpec) -> Optional[CloudConfig]:
        raise NotImplementedError


# --- static baselines ---------------------------------------------------------

@dataclasses.dataclass
class StaticResource(Approach):
    """min/max CPU or memory baselines.

    Tie-breaks (several configs share the extreme total): minimising
    approaches prefer the smallest scale-out; maximising approaches prefer
    the largest scale-out; remaining ties break on the paper's config index.
    """

    configs: Sequence[CloudConfig]
    resource: str      # "cpu" | "mem"
    maximize: bool

    def __post_init__(self):
        self.name = ("maximize " if self.maximize else "minimize ") + (
            "CPU" if self.resource == "cpu" else "memory")

    def select(self, job: JobSpec) -> CloudConfig:
        def amount(c: CloudConfig) -> float:
            return c.total_cores if self.resource == "cpu" else c.total_mem_gib
        best = max(amount(c) for c in self.configs) if self.maximize \
            else min(amount(c) for c in self.configs)
        ties = [c for c in self.configs if amount(c) == best]
        ties.sort(key=lambda c: (-c.scale_out if self.maximize else c.scale_out,
                                 c.index))
        return ties[0]


@dataclasses.dataclass
class RandomSelection(Approach):
    """Expected result of a uniform random choice (evaluated in closed form)."""

    configs: Sequence[CloudConfig]
    name: str = "random selection"

    def select(self, job: JobSpec) -> None:  # evaluated via expectation
        return None


# --- profiling-based state-of-the-art baselines -------------------------------

def _unit_noise(tag: str, job: JobSpec, sigma: float) -> float:
    key = f"{tag}|{job.algorithm}|{job.dataset_gib}".encode()
    h = hashlib.md5(key).digest()
    u1 = (int.from_bytes(h[:8], "big") + 1) / (2 ** 64 + 2)
    u2 = (int.from_bytes(h[8:16], "big") + 1) / (2 ** 64 + 2)
    z = math.sqrt(-2.0 * math.log(u1)) * math.cos(2 * math.pi * u2)
    return math.exp(sigma * z)


@dataclasses.dataclass
class Juggler(Approach):
    """Juggler [9]: size total cluster memory to fit the cached dataset.

    From a brief profiling run it measures the cache-to-input ratio, then
    picks the cheapest (hourly) configuration whose total memory fits the
    estimate.  Applicable to iterative ML workloads only.
    """

    configs: Sequence[CloudConfig]
    price: costmodel.LinearPriceModel
    estimate_sigma: float = 0.08
    name: str = "Juggler"

    def select(self, job: JobSpec) -> Optional[CloudConfig]:
        if job.algorithm not in ITERATIVE_ML:
            return None
        kappa = spark_sim.ALGO_PARAMS[job.algorithm].kappa
        need = kappa * job.dataset_gib * _unit_noise("juggler", job,
                                                     self.estimate_sigma)
        fitting = [c for c in self.configs if c.total_mem_gib >= need]
        if not fitting:   # nothing fits: fall back to max memory
            return max(self.configs, key=lambda c: (c.total_mem_gib, c.index))
        fitting.sort(key=lambda c: (self.price(c), -c.cores_per_node, c.index))
        return fitting[0]


@dataclasses.dataclass
class Crispy(Approach):
    """Crispy [11]: extrapolate peak memory from profiling; cost-estimate.

    Estimates the job's full-scale memory footprint (with extrapolation
    error), filters configurations that fit it, and among those picks the
    minimum of a naive predicted cost: profiled unit work scaled linearly
    with total cores (the straightforward scale-out assumption the Crispy
    paper relies on), times the current hourly price.
    """

    configs: Sequence[CloudConfig]
    price: costmodel.LinearPriceModel
    estimate_sigma: float = 0.35
    name: str = "Crispy"

    def select(self, job: JobSpec) -> CloudConfig:
        p = spark_sim.ALGO_PARAMS[job.algorithm]
        need = (p.kappa_peak * job.dataset_gib
                * _unit_noise("crispy-mem", job, self.estimate_sigma))
        fitting = [c for c in self.configs if c.total_mem_gib >= need]
        if not fitting:
            return max(self.configs, key=lambda c: (c.total_mem_gib, c.index))
        # naive cost model: runtime ~ unit_work / total_cores
        unit_work = (p.parse_w + p.w * p.iters) * job.dataset_gib
        unit_work *= _unit_noise("crispy-rt", job, self.estimate_sigma)

        def predicted_cost(c: CloudConfig) -> float:
            t_hours = unit_work / c.total_cores / 3600.0
            return t_hours * self.price(c)
        fitting.sort(key=lambda c: (predicted_cost(c), c.index))
        return fitting[0]


# --- Flora wrappers ------------------------------------------------------------

@dataclasses.dataclass
class FloraApproach(Approach):
    """Flora (or Fw1C with ``one_class=True``) with leave-one-algorithm-out.

    Thin adapter: selection routes through the shared
    :class:`repro.selector.SelectionService` (via :class:`Flora`), so the
    per-(class, exclusion, price-epoch) ranking caches are shared across
    the evaluation's 18 leave-one-out submissions.
    """

    trace: Trace
    price: costmodel.LinearPriceModel
    one_class: bool = False
    #: class-annotation override for the misclassification experiment.
    flip_class: bool = False

    def __post_init__(self):
        self.name = "Flora with one class" if self.one_class else "Flora"
        self._flora = Flora(self.trace, self.price, one_class=self.one_class)

    @property
    def service(self):
        """The underlying :class:`repro.selector.SelectionService`."""
        return self._flora.service

    def select(self, job: JobSpec) -> CloudConfig:
        klass = job.job_class.flipped() if self.flip_class else job.job_class
        return self._flora.select_for_job(job, annotated_class=klass)


def standard_approaches(trace: Trace, price: costmodel.LinearPriceModel
                        ) -> List[Approach]:
    """All approaches of the paper's Table IV, in one list."""
    cfgs = trace.configs
    return [
        StaticResource(cfgs, "cpu", maximize=False),
        RandomSelection(cfgs),
        StaticResource(cfgs, "mem", maximize=False),
        StaticResource(cfgs, "cpu", maximize=True),
        StaticResource(cfgs, "mem", maximize=True),
        FloraApproach(trace, price, one_class=True),
        Juggler(cfgs, price),
        Crispy(cfgs, price),
        FloraApproach(trace, price),
    ]
