"""Automatic job classification (the paper's §V future work).

The paper assigns classes by user annotation and sketches automating it
via "static code analysis and minimal profiling".  This module implements
the minimal-profiling half: given a :class:`JobProfile` (obtainable from a
tiny sample run or static inspection of the job's operators), apply the
paper's §II-C decision rule:

  class A (memory-demanding)  — repeated/specific data loading: the job
      re-reads a cached working set (iterations > 1) or does random access
      over a non-negligible fraction of the input;
  class B (memory-yielding)   — single parallelisable loading: at most a
      few sequential passes and a small retained working set.

Multi-stage jobs are classified by their most significant stage, and the
module reports when *splitting* stages would be advisable (the paper's
select-where-order-by discussion).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

from repro.core.trace import JobClass


@dataclasses.dataclass(frozen=True)
class StageProfile:
    """One stage's data-access characteristics."""

    name: str
    passes_over_input: float     # how often the stage reads its input
    retained_fraction: float     # working set it keeps resident / input
    random_access: bool = False  # state-dependent sample access
    weight: float = 1.0          # share of the job's work in this stage


@dataclasses.dataclass(frozen=True)
class JobProfile:
    algorithm: str
    stages: Tuple[StageProfile, ...]


#: thresholds of the §II-C rule
RETAINED_THRESHOLD = 0.25   # "non-negligibly small" working set
PASSES_THRESHOLD = 2.0      # "at most a few" sequential passes


def classify_stage(s: StageProfile) -> JobClass:
    if s.random_access and s.retained_fraction >= RETAINED_THRESHOLD:
        return JobClass.A
    if s.passes_over_input > PASSES_THRESHOLD \
            and s.retained_fraction >= RETAINED_THRESHOLD:
        return JobClass.A
    return JobClass.B


@dataclasses.dataclass(frozen=True)
class Classification:
    job_class: JobClass
    per_stage: Tuple[Tuple[str, JobClass], ...]
    advise_split: bool           # stages disagree and both are significant

    @property
    def confident(self) -> bool:
        return not self.advise_split


def classify(profile: JobProfile) -> Classification:
    per_stage = tuple((s.name, classify_stage(s)) for s in profile.stages)
    # most significant stage decides (paper: "categorized based on their
    # most significant stage")
    top = max(profile.stages, key=lambda s: s.weight)
    job_class = classify_stage(top)
    significant = [s for s in profile.stages if s.weight >= 0.25]
    classes = {classify_stage(s) for s in significant}
    return Classification(job_class=job_class, per_stage=per_stage,
                          advise_split=len(classes) > 1)


# --- profiles of the paper's test-job algorithms (from spark_sim params) -----

def profile_from_algo(algorithm: str) -> JobProfile:
    """Derive a JobProfile from the simulator's workload parameters — the
    'minimal profiling' stand-in: a sample run measures exactly these."""
    from repro.core.spark_sim import ALGO_PARAMS
    p = ALGO_PARAMS[algorithm]
    stages: List[StageProfile] = [StageProfile(
        name="main", passes_over_input=float(p.iters),
        retained_fraction=p.kappa,
        random_access=(p.storage == "mem" and p.iters > 1),
    )]
    # sort-like second stage for jobs that shuffle heavily with retention
    if p.shuffle >= 1.0 and p.kappa > 0:
        stages = [
            StageProfile("scan", 1.0, 0.0, weight=1.0 - min(p.kappa, 0.9)),
            StageProfile("sort", 2.0, min(p.kappa, 1.0), random_access=True,
                         weight=min(p.kappa, 0.9)),
        ]
    return JobProfile(algorithm=algorithm, stages=tuple(stages))


def auto_class(algorithm: str) -> JobClass:
    return classify(profile_from_algo(algorithm)).job_class
