"""Evaluation harness reproducing the paper's §III experiments.

All experiments follow the paper's protocol:

* selections are simulated, then judged against the trace itself;
* per-job normalization: 1.0 = the best (cheapest / fastest) value any
  configuration achieved for that job (§III-C);
* leave-one-algorithm-out: an approach selecting for ``Sort/188GiB`` never
  sees profiling data of *any* Sort job (§III-A) — enforced inside
  :class:`repro.core.baselines.FloraApproach` for Flora/Fw1C (the other
  baselines do not read the trace at all).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core import costmodel
from repro.core.baselines import (Approach, FloraApproach, RandomSelection,
                                  standard_approaches)
from repro.core.trace import CloudConfig, JobClass, JobSpec, Trace
from repro.selector import GcpVmCatalog, ProfilingStore


@dataclasses.dataclass(frozen=True)
class JobResult:
    job: JobSpec
    selection: Optional[CloudConfig]
    norm_cost: float
    norm_runtime: float


@dataclasses.dataclass(frozen=True)
class ApproachResult:
    name: str
    per_job: Tuple[JobResult, ...]
    mean_norm_cost: float
    mean_norm_runtime: float


def _job_cost(trace: Trace, job: JobSpec, config: CloudConfig,
              price: costmodel.LinearPriceModel) -> float:
    return costmodel.execution_cost(trace.runtime_s(job, config), config, price)


def _best_per_job(trace: Trace, price: costmodel.LinearPriceModel
                  ) -> Mapping[str, Tuple[float, float]]:
    """job name -> (min cost, min runtime) over all configs, vectorized.

    One (job x config) matrix from :class:`repro.selector.ProfilingStore`
    replaces the historical per-(job, config) python loops (the paper's
    trace is dense, so the mask is all-true; partial traces min over
    profiled cells only).
    """
    store = ProfilingStore.from_trace(trace)
    catalog = GcpVmCatalog(trace.configs, price)
    hours, mask = store.matrix(config_ids=catalog.ids())
    cost = np.where(mask, hours * catalog.price_vector()[None, :], np.inf)
    runtime = np.where(mask, hours * 3600.0, np.inf)
    best_cost = cost.min(axis=1)
    best_rt = runtime.min(axis=1)
    return {j: (float(best_cost[i]), float(best_rt[i]))
            for i, j in enumerate(store.job_ids)}


def evaluate_approach(trace: Trace, price: costmodel.LinearPriceModel,
                      approach: Approach,
                      jobs: Optional[Sequence[JobSpec]] = None
                      ) -> ApproachResult:
    jobs = list(jobs) if jobs is not None else trace.jobs
    best = _best_per_job(trace, price)
    per_job: List[JobResult] = []
    for job in jobs:
        best_cost, best_rt = best[job.name]
        if isinstance(approach, RandomSelection):
            # closed-form expectation over a uniform choice
            ncost = sum(_job_cost(trace, job, c, price) / best_cost
                        for c in trace.configs) / len(trace.configs)
            nrt = sum(trace.runtime_s(job, c) / best_rt
                      for c in trace.configs) / len(trace.configs)
            per_job.append(JobResult(job, None, ncost, nrt))
            continue
        sel = approach.select(job)
        if sel is None:       # not applicable (e.g. Juggler on a scan)
            continue
        ncost = _job_cost(trace, job, sel, price) / best_cost
        nrt = trace.runtime_s(job, sel) / best_rt
        per_job.append(JobResult(job, sel, ncost, nrt))
    if not per_job:
        return ApproachResult(approach.name, (), math.nan, math.nan)
    mean_c = sum(r.norm_cost for r in per_job) / len(per_job)
    mean_r = sum(r.norm_runtime for r in per_job) / len(per_job)
    return ApproachResult(approach.name, tuple(per_job), mean_c, mean_r)


# --- Table IV -------------------------------------------------------------------

def table4(trace: Trace, price: costmodel.LinearPriceModel
           ) -> List[ApproachResult]:
    results = [evaluate_approach(trace, price, a)
               for a in standard_approaches(trace, price)]
    results.sort(key=lambda r: -r.mean_norm_cost)
    return results


# --- Table V --------------------------------------------------------------------

def table5(trace: Trace, price: costmodel.LinearPriceModel
           ) -> Mapping[str, ApproachResult]:
    wanted = ("Crispy", "Juggler", "Flora with one class", "Flora")
    out: Dict[str, ApproachResult] = {}
    for a in standard_approaches(trace, price):
        if a.name in wanted:
            out[a.name] = evaluate_approach(trace, price, a)
    return out


# --- Fig. 2: price-structure sweep -----------------------------------------------

def fig2_price_sweep(trace: Trace, base: costmodel.LinearPriceModel,
                     ratios: Sequence[float]) -> Mapping[str, List[float]]:
    """Mean normalized cost per approach, as mem/CPU price ratio varies.

    ``ratios[i]`` = hourly cost of 1 GiB expressed in vCPU-hours (the
    paper's Fig. 2 x-axis, 10^-2 .. 10^1).
    """
    curves: Dict[str, List[float]] = {}
    for r in ratios:
        price = base.with_mem_to_cpu_ratio(r)
        for res in table4(trace, price):
            curves.setdefault(res.name, []).append(res.mean_norm_cost)
    return curves


# --- Fig. 3: misclassification sweep ----------------------------------------------

def fig3_misclassification(trace: Trace, price: costmodel.LinearPriceModel,
                           fractions: Sequence[float]
                           ) -> Mapping[str, List[float]]:
    """Expected mean normalized cost when a fraction of given jobs is
    misclassified by the user (test-job labels stay expert-correct, §III-E).

    Computed in closed form: each job contributes
    ``(1-f) * cost(correct class) + f * cost(flipped class)``.
    """
    correct = evaluate_approach(trace, price, FloraApproach(trace, price))
    flipped = evaluate_approach(
        trace, price, FloraApproach(trace, price, flip_class=True))
    fw1c = evaluate_approach(
        trace, price, FloraApproach(trace, price, one_class=True))
    rnd = evaluate_approach(trace, price, RandomSelection(trace.configs))
    flora_curve = [
        (1 - f) * correct.mean_norm_cost + f * flipped.mean_norm_cost
        for f in fractions]
    return {
        "Flora": flora_curve,
        "Flora with one class": [fw1c.mean_norm_cost] * len(fractions),
        "random selection": [rnd.mean_norm_cost] * len(fractions),
    }


# --- Fig. 2 under *dynamic* prices: replayed-journal evaluation (DESIGN.md §8) ---

@dataclasses.dataclass(frozen=True)
class DecisionOutcome:
    """One journaled decision judged against the oracles at its epoch."""

    seq: int
    job_id: object
    job_class: object                  # Optional[JobClass]
    config_id: object                  # the journaled selection
    price_epoch: int
    realized_cost: float               # hours(job, sel) * price_e(sel)
    oracle_config: object              # argmin under the epoch's prices
    oracle_cost: float
    static_config: object              # argmin under the *base* prices...
    static_cost: float                 # ...paying the epoch's price

    @property
    def deviation(self) -> float:
        """Fractional deviation from the per-epoch optimum (>= 0)."""
        return self.realized_cost / self.oracle_cost - 1.0

    @property
    def static_deviation(self) -> float:
        """What a static-price selector would have deviated instead."""
        return self.static_cost / self.oracle_cost - 1.0


@dataclasses.dataclass(frozen=True)
class DynamicEvaluation:
    """Deviation-from-optimal over a whole journaled price history.

    The paper's headline metric (mean deviation from the cost-optimal
    configuration, §III-C) generalized to *moving* prices: every decision
    is judged against the oracle that sees the full runtime matrix under
    the prices of that decision's epoch, and against a static-price
    oracle that picked once under the base prices and never moved.
    """

    outcomes: Tuple[DecisionOutcome, ...]
    #: journaled selections whose (job, config) cell is unprofiled — the
    #: realized cost is unknowable from the trace, so they are excluded
    #: from the means but never silently dropped.
    skipped: int
    #: ranking backend that produced the judged decisions ("numpy" |
    #: "jax") — consumers of the report need to know which
    #: :class:`repro.selector.ScoreContract` the journal was audited
    #: under before trusting per-decision scores (DESIGN.md §9).
    backend: str = "numpy"

    def _mean(self, values: Sequence[float]) -> float:
        return sum(values) / len(values) if values else math.nan

    @property
    def mean_deviation(self) -> float:
        return self._mean([o.deviation for o in self.outcomes])

    @property
    def max_deviation(self) -> float:
        return max((o.deviation for o in self.outcomes), default=math.nan)

    @property
    def static_mean_deviation(self) -> float:
        return self._mean([o.static_deviation for o in self.outcomes])

    @property
    def realized_total(self) -> float:
        return sum(o.realized_cost for o in self.outcomes)

    @property
    def oracle_total(self) -> float:
        return sum(o.oracle_cost for o in self.outcomes)

    @property
    def static_total(self) -> float:
        return sum(o.static_cost for o in self.outcomes)

    def summary(self) -> Dict[str, float]:
        """The machine-readable report (``BENCH_replay.json`` payload)."""
        return {
            "backend": self.backend,
            "decisions": len(self.outcomes),
            "skipped": self.skipped,
            "epochs": len({o.price_epoch for o in self.outcomes}),
            "mean_deviation": self.mean_deviation,
            "max_deviation": self.max_deviation,
            "static_mean_deviation": self.static_mean_deviation,
            "realized_total_usd": self.realized_total,
            "oracle_total_usd": self.oracle_total,
            "static_total_usd": self.static_total,
        }


def dynamic_evaluation(store: ProfilingStore, decisions: Sequence,
                       config_ids: Sequence,
                       base_prices: Mapping,
                       backend: str = "numpy") -> DynamicEvaluation:
    """Judge replayed decisions against per-epoch and static oracles.

    ``decisions`` are duck-typed (``repro.market.replay.ReplayedDecision``
    shaped): each carries ``seq``/``job_id``/``job_class``/``config_id``/
    ``price_epoch`` and the full ``prices`` mapping of its epoch.  Both
    oracles see the *full* runtime/price matrix — no leave-one-out — so
    the deviation measures distance from the true optimum, exactly like
    the paper's static-price evaluation (the selector itself never saw
    its own group's data; the judge may).

    The oracles themselves always run in float64 on the host (they are
    per-decision argmins over a C-vector — there is nothing to
    accelerate); ``backend`` stamps which ranking backend *produced* the
    judged decisions, so the report is self-describing about the
    :class:`repro.selector.ScoreContract` its journal was audited under.
    """
    config_ids = list(config_ids)
    base_vec = np.asarray([base_prices[c] for c in config_ids],
                          dtype=np.float64)
    known_jobs = set(store.job_ids)
    hours_cache: Dict[object, Tuple[np.ndarray, np.ndarray]] = {}
    # decisions of one epoch share one prices mapping (walk() copies per
    # tick), so the vector conversion is paid once per epoch, not per
    # decision
    vec_cache: Dict[int, np.ndarray] = {}
    pos = {c: i for i, c in enumerate(config_ids)}
    outcomes: List[DecisionOutcome] = []
    skipped = 0
    for d in decisions:
        if d.job_id not in known_jobs:
            # a decision for a never-profiled submission (ranked from its
            # class-mates): its realized cost is unknowable from the trace
            skipped += 1
            continue
        row = hours_cache.get(d.job_id)
        if row is None:
            h, m = store.matrix(job_ids=[d.job_id], config_ids=config_ids)
            row = (h[0], m[0])
            hours_cache[d.job_id] = row
        hours, mask = row
        sel = pos.get(d.config_id)
        if sel is None or not mask[sel]:
            skipped += 1
            continue
        live = vec_cache.get(id(d.prices))
        if live is None:
            live = np.asarray([d.prices[c] for c in config_ids],
                              dtype=np.float64)
            vec_cache[id(d.prices)] = live
        cost = np.where(mask, hours * live, np.inf)
        oracle_idx = int(np.argmin(cost))
        static_idx = int(np.argmin(np.where(mask, hours * base_vec,
                                            np.inf)))
        outcomes.append(DecisionOutcome(
            seq=d.seq, job_id=d.job_id, job_class=d.job_class,
            config_id=d.config_id, price_epoch=d.price_epoch,
            realized_cost=float(cost[sel]),
            oracle_config=config_ids[oracle_idx],
            oracle_cost=float(cost[oracle_idx]),
            static_config=config_ids[static_idx],
            static_cost=float(cost[static_idx])))
    return DynamicEvaluation(outcomes=tuple(outcomes), skipped=skipped,
                             backend=backend)


# --- deviation vs turbulence: the dynamic analogue of Fig. 2's x-axis --------

@dataclasses.dataclass(frozen=True)
class TurbulencePoint:
    """One cell of the turbulence sweep: (preset, backend) -> deviation.

    Produced by :func:`repro.market.turbulence.run_point`: a daemon run
    over one adversarial market, its journal audited under the
    backend's :class:`~repro.selector.ScoreContract`, then scored by
    :func:`dynamic_evaluation`.  ``evaluation`` judges decisions
    against the prices the daemon was shown (the journal view);
    ``truth``, when present, re-judges them against the *unlagged*
    market — identical for a zero-latency feed, strictly harsher when
    the preset's ``feed_latency`` delayed the quotes.  A point whose
    ``audit_ok`` is false carries no evidence about the selector (the
    serving path itself diverged) and the bench gates on it.
    """

    preset: str
    level: float
    backend: str
    #: how the daemon got its quotes: "recorded" | "polled" |
    #: "simulated" — identical quote streams must produce identical
    #: curves regardless (the ISSUE 10 acceptance bar).
    feed_kind: str
    evaluation: DynamicEvaluation
    truth: Optional[DynamicEvaluation]
    audit_ok: bool
    audit_mismatches: int
    audit_drift: int
    decisions: int
    epochs: int
    feed_errors: int = 0

    @property
    def mean_deviation(self) -> float:
        return self.evaluation.mean_deviation

    @property
    def truth_mean_deviation(self) -> float:
        return self.truth.mean_deviation if self.truth is not None \
            else math.nan

    def summary(self) -> Dict[str, object]:
        """One ``BENCH_turbulence.json`` curve row."""
        out: Dict[str, object] = {
            "preset": self.preset,
            "level": self.level,
            "backend": self.backend,
            "feed_kind": self.feed_kind,
            "audit_ok": self.audit_ok,
            "audit_mismatches": self.audit_mismatches,
            "audit_drift": self.audit_drift,
            "epochs": self.epochs,
            "feed_errors": self.feed_errors,
        }
        out.update(self.evaluation.summary())
        if self.truth is not None:
            out["truth_mean_deviation"] = self.truth.mean_deviation
            out["truth_max_deviation"] = self.truth.max_deviation
        return out


def turbulence_curves(points: Sequence[TurbulencePoint]
                      ) -> Mapping[str, List[TurbulencePoint]]:
    """Group sweep points into per-backend deviation-vs-turbulence
    curves, level-ordered — the dynamic analogue of Fig. 2's per-
    approach lines over the price-ratio axis.  Points that share a
    (backend, level) stay in input order (e.g. a recorded point next
    to its polled twin)."""
    curves: Dict[str, List[TurbulencePoint]] = {}
    for p in points:
        curves.setdefault(p.backend, []).append(p)
    for backend in curves:
        curves[backend].sort(key=lambda p: p.level)
    return curves


def crossover_fraction(trace: Trace, price: costmodel.LinearPriceModel,
                       steps: int = 200) -> float:
    """Misclassification fraction beyond which Fw1C beats two-class Flora."""
    fractions = [i / steps for i in range(steps + 1)]
    curves = fig3_misclassification(trace, price, fractions)
    fw1c = curves["Flora with one class"][0]
    for f, v in zip(fractions, curves["Flora"]):
        if v > fw1c:
            return f
    return 1.0
