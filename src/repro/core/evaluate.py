"""Evaluation harness reproducing the paper's §III experiments.

All experiments follow the paper's protocol:

* selections are simulated, then judged against the trace itself;
* per-job normalization: 1.0 = the best (cheapest / fastest) value any
  configuration achieved for that job (§III-C);
* leave-one-algorithm-out: an approach selecting for ``Sort/188GiB`` never
  sees profiling data of *any* Sort job (§III-A) — enforced inside
  :class:`repro.core.baselines.FloraApproach` for Flora/Fw1C (the other
  baselines do not read the trace at all).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core import costmodel
from repro.core.baselines import (Approach, FloraApproach, RandomSelection,
                                  standard_approaches)
from repro.core.trace import CloudConfig, JobClass, JobSpec, Trace
from repro.selector import GcpVmCatalog, ProfilingStore


@dataclasses.dataclass(frozen=True)
class JobResult:
    job: JobSpec
    selection: Optional[CloudConfig]
    norm_cost: float
    norm_runtime: float


@dataclasses.dataclass(frozen=True)
class ApproachResult:
    name: str
    per_job: Tuple[JobResult, ...]
    mean_norm_cost: float
    mean_norm_runtime: float


def _job_cost(trace: Trace, job: JobSpec, config: CloudConfig,
              price: costmodel.LinearPriceModel) -> float:
    return costmodel.execution_cost(trace.runtime_s(job, config), config, price)


def _best_per_job(trace: Trace, price: costmodel.LinearPriceModel
                  ) -> Mapping[str, Tuple[float, float]]:
    """job name -> (min cost, min runtime) over all configs, vectorized.

    One (job x config) matrix from :class:`repro.selector.ProfilingStore`
    replaces the historical per-(job, config) python loops (the paper's
    trace is dense, so the mask is all-true; partial traces min over
    profiled cells only).
    """
    store = ProfilingStore.from_trace(trace)
    catalog = GcpVmCatalog(trace.configs, price)
    hours, mask = store.matrix(config_ids=catalog.ids())
    cost = np.where(mask, hours * catalog.price_vector()[None, :], np.inf)
    runtime = np.where(mask, hours * 3600.0, np.inf)
    best_cost = cost.min(axis=1)
    best_rt = runtime.min(axis=1)
    return {j: (float(best_cost[i]), float(best_rt[i]))
            for i, j in enumerate(store.job_ids)}


def evaluate_approach(trace: Trace, price: costmodel.LinearPriceModel,
                      approach: Approach,
                      jobs: Optional[Sequence[JobSpec]] = None
                      ) -> ApproachResult:
    jobs = list(jobs) if jobs is not None else trace.jobs
    best = _best_per_job(trace, price)
    per_job: List[JobResult] = []
    for job in jobs:
        best_cost, best_rt = best[job.name]
        if isinstance(approach, RandomSelection):
            # closed-form expectation over a uniform choice
            ncost = sum(_job_cost(trace, job, c, price) / best_cost
                        for c in trace.configs) / len(trace.configs)
            nrt = sum(trace.runtime_s(job, c) / best_rt
                      for c in trace.configs) / len(trace.configs)
            per_job.append(JobResult(job, None, ncost, nrt))
            continue
        sel = approach.select(job)
        if sel is None:       # not applicable (e.g. Juggler on a scan)
            continue
        ncost = _job_cost(trace, job, sel, price) / best_cost
        nrt = trace.runtime_s(job, sel) / best_rt
        per_job.append(JobResult(job, sel, ncost, nrt))
    if not per_job:
        return ApproachResult(approach.name, (), math.nan, math.nan)
    mean_c = sum(r.norm_cost for r in per_job) / len(per_job)
    mean_r = sum(r.norm_runtime for r in per_job) / len(per_job)
    return ApproachResult(approach.name, tuple(per_job), mean_c, mean_r)


# --- Table IV -------------------------------------------------------------------

def table4(trace: Trace, price: costmodel.LinearPriceModel
           ) -> List[ApproachResult]:
    results = [evaluate_approach(trace, price, a)
               for a in standard_approaches(trace, price)]
    results.sort(key=lambda r: -r.mean_norm_cost)
    return results


# --- Table V --------------------------------------------------------------------

def table5(trace: Trace, price: costmodel.LinearPriceModel
           ) -> Mapping[str, ApproachResult]:
    wanted = ("Crispy", "Juggler", "Flora with one class", "Flora")
    out: Dict[str, ApproachResult] = {}
    for a in standard_approaches(trace, price):
        if a.name in wanted:
            out[a.name] = evaluate_approach(trace, price, a)
    return out


# --- Fig. 2: price-structure sweep -----------------------------------------------

def fig2_price_sweep(trace: Trace, base: costmodel.LinearPriceModel,
                     ratios: Sequence[float]) -> Mapping[str, List[float]]:
    """Mean normalized cost per approach, as mem/CPU price ratio varies.

    ``ratios[i]`` = hourly cost of 1 GiB expressed in vCPU-hours (the
    paper's Fig. 2 x-axis, 10^-2 .. 10^1).
    """
    curves: Dict[str, List[float]] = {}
    for r in ratios:
        price = base.with_mem_to_cpu_ratio(r)
        for res in table4(trace, price):
            curves.setdefault(res.name, []).append(res.mean_norm_cost)
    return curves


# --- Fig. 3: misclassification sweep ----------------------------------------------

def fig3_misclassification(trace: Trace, price: costmodel.LinearPriceModel,
                           fractions: Sequence[float]
                           ) -> Mapping[str, List[float]]:
    """Expected mean normalized cost when a fraction of given jobs is
    misclassified by the user (test-job labels stay expert-correct, §III-E).

    Computed in closed form: each job contributes
    ``(1-f) * cost(correct class) + f * cost(flipped class)``.
    """
    correct = evaluate_approach(trace, price, FloraApproach(trace, price))
    flipped = evaluate_approach(
        trace, price, FloraApproach(trace, price, flip_class=True))
    fw1c = evaluate_approach(
        trace, price, FloraApproach(trace, price, one_class=True))
    rnd = evaluate_approach(trace, price, RandomSelection(trace.configs))
    flora_curve = [
        (1 - f) * correct.mean_norm_cost + f * flipped.mean_norm_cost
        for f in fractions]
    return {
        "Flora": flora_curve,
        "Flora with one class": [fw1c.mean_norm_cost] * len(fractions),
        "random selection": [rnd.mean_norm_cost] * len(fractions),
    }


def crossover_fraction(trace: Trace, price: costmodel.LinearPriceModel,
                       steps: int = 200) -> float:
    """Misclassification fraction beyond which Fw1C beats two-class Flora."""
    fractions = [i / steps for i in range(steps + 1)]
    curves = fig3_misclassification(trace, price, fractions)
    fw1c = curves["Flora with one class"][0]
    for f, v in zip(fractions, curves["Flora"]):
        if v > fw1c:
            return f
    return 1.0
