"""Analytical Spark-on-GCP performance model -> regenerated trace dataset.

The paper's trace (github.com/dos-group/flora, 180 executions) is not
reachable offline, so we regenerate an equivalent dataset: the exact job
list (Table I) x the exact configuration list (Table II), with runtimes
from a calibrated analytical model of Spark execution on GCP n2 VMs.

The model captures the effects the paper's evaluation hinges on:

* **Object-store I/O** — GCS bandwidth per node grows with vCPUs (GCP caps
  network egress per vCPU) up to a per-node cap, and sub-linearly with node
  count (shared-tenancy contention, stragglers):
  ``bw_total = bw_node(k) * n^0.85``.  At fixed total cores, more smaller
  nodes therefore read faster — the paper's #9-over-#2 observation.
* **Shuffle / local disk** — NIC and pd throughput have per-node floors, so
  scale-out buys aggregate shuffle bandwidth.
* **CPU scaling** — parallel work over total cores, mild per-core
  efficiency bonus on narrow nodes (less memory-bandwidth contention).
* **Memory (the paper's main axis)** — class A jobs cache a working set
  ``kappa * dataset``; usable cache is ``0.58 * (node_mem - 2 GiB)`` per
  node (Spark memory fraction + runtime overhead).  Misses trigger
  per-iteration reloads (re-read + re-parse for MEMORY_ONLY; spill/merge
  traffic for MEMORY_AND_DISK) with *superlinear* GC/eviction thrash in the
  miss fraction: a small shortfall is benign (LRU keeps the hot set), a
  large one is catastrophic — which is exactly why the paper's class-A jobs
  prefer 256 GiB clusters over both 64 GiB (thrash) and 512 GiB (price).
* **JVM heap penalty** — oversized heaps pay superlinear GC cost on
  cache-heavy jobs (many small executors beat few big ones at equal totals).

A deterministic log-normal noise term models shared-tenancy variance; the
paper ran each cell once, so noise stays in the trace (cf. §III-A "may make
this measured test job data somewhat vulnerable to outliers").

**Calibration status vs paper Table III** (seed=0; pinned by
``tests/test_flora_core.py::test_spark_sim_calibration_pinned``):

    ==============  ========  ===========
    statistic       paper     regenerated
    ==============  ========  ===========
    cost mean $     1.409     1.861
    cost min $      0.177     0.115
    runtime mean s  1834.8    2845.1
    runtime min s   141.7     125.9
    runtime max s   21714.7   24985.1
    ==============  ========  ===========

The drift is a heavy-tail artifact: the model's cache-thrash blowup
(``THRASH_CPU_FACTOR * miss_frac**4``) inflates the worst class-A cells
more than the paper's measured cluster did, dragging the means up while
the mins sit *below* paper (our startup/IO floors are slightly
optimistic).  A uniform runtime rescale cannot close it — matching the
cost mean (x0.757) pushes runtime min to 95 s, far under Table III's
141.7 s, and any *non*-uniform re-fit moves the per-job normalized
ranking the paper-claim tests pin (uniform scaling is
ranking-invariant; per-cell changes are not).  Every qualitative claim
the evaluation depends on (class A -> #9, class B -> #1, Table IV/V
orderings, Fig. 2/3 shapes) reproduces despite the gap, so the
constants stay as calibrated and the pinned test makes any further
drift a deliberate, reviewed change instead of a silent one.
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
from typing import Dict, Iterable, Mapping, Optional, Sequence

from repro.core.trace import (CloudConfig, ExecutionRecord, GCP_CONFIGS,
                              JobSpec, PAPER_JOBS, Trace)

# --- machine model constants (calibrated against paper Tables III-V) ---------

GCS_BW_PER_CORE = 0.030    # GiB/s object-store bandwidth per vCPU...
GCS_BW_CORE_CAP = 8        # ...capped per node (practical GCS throughput)
GCS_BW_NODE_BASE = 0.050
GCS_BW_CLUSTER_CAP = 2.2   # GiB/s regional object-store contention cap
DISK_BW_PER_CORE = 0.015   # GiB/s local pd throughput per vCPU
DISK_BW_NODE_BASE = 0.090  # pd throughput floor per node
NET_BW_PER_CORE = 0.020    # GiB/s shuffle network bandwidth per vCPU
NET_BW_NODE_BASE = 0.110   # NIC floor per node
CLUSTER_SCALING = 0.85     # bw_total ~ n^CLUSTER_SCALING
CACHE_FRACTION = 0.58      # usable cache fraction of (node_mem - overhead)
NODE_MEM_OVERHEAD_GIB = 2.0
GC_HEAP_KNEE_GIB = 16.0    # heaps beyond this pay GC penalty on cache-heavy jobs
GC_PENALTY_PER_GIB = 0.002
CORE_EFF_EXPONENT = 0.06   # cpu_eff = (8 / cores_per_node) ** exponent
STARTUP_BASE_S = 70.0
STARTUP_PER_NODE_S = 0.5
THRASH_CPU_FACTOR = 6.0    # cpu *= 1 + f * miss_frac**4 (MEMORY_ONLY)
SPILL_CPU_FACTOR = 1.0     # cpu *= 1 + f * miss_frac**2 (MEMORY_AND_DISK)
SPILL_IO_PASSES = 4.0      # write + read-back + merge traffic per spilled GiB
REPARSE_FACTOR = 1.5       # recompute costs 1.5x the initial parse
NOISE_SIGMA = 0.08


@dataclasses.dataclass(frozen=True)
class AlgoParams:
    """Per-algorithm workload parameters."""

    w: float            # CPU core-seconds per GiB per pass
    parse_w: float      # one-time parse/deserialise core-seconds per GiB
    iters: int          # passes over the cached working set
    kappa: float        # cached working set / input size
    shuffle: float      # shuffle volume / input size
    out: float          # output volume / input size
    storage: str        # "mem" (MEMORY_ONLY), "disk" (MEMORY_AND_DISK), "none"
    kappa_peak: float   # peak memory / input (what Crispy-style tools measure)


ALGO_PARAMS: Mapping[str, AlgoParams] = {
    "Grep":               AlgoParams(8, 6, 1, 0.00, 0.002, 0.010, "none", 0.08),
    "Sort":               AlgoParams(22, 8, 1, 1.05, 2.000, 1.000, "disk", 1.20),
    "WordCount":          AlgoParams(100, 10, 1, 0.00, 0.050, 0.020, "none", 0.25),
    "KMeans":             AlgoParams(32, 16, 10, 1.10, 0.010, 0.001, "mem", 1.15),
    "LinearRegression":   AlgoParams(20, 16, 8, 0.55, 0.010, 0.001, "mem", 0.60),
    "LogisticRegression": AlgoParams(22, 16, 9, 0.65, 0.010, 0.001, "mem", 0.70),
    "Join":               AlgoParams(24, 8, 1, 0.75, 2.200, 0.300, "disk", 0.90),
    "GroupByCount":       AlgoParams(30, 8, 1, 0.00, 0.020, 0.001, "none", 0.20),
    "SelectWhereOrderBy": AlgoParams(18, 8, 1, 0.04, 0.040, 0.030, "disk", 0.12),
}


def _noise(job: JobSpec, config: CloudConfig, seed: int, sigma: float) -> float:
    """Deterministic log-normal multiplier per (job, config, seed)."""
    key = f"{job.algorithm}|{job.dataset_gib}|{config.index}|{seed}".encode()
    h = hashlib.md5(key).digest()
    u1 = (int.from_bytes(h[:8], "big") + 1) / (2 ** 64 + 2)
    u2 = (int.from_bytes(h[8:16], "big") + 1) / (2 ** 64 + 2)
    z = math.sqrt(-2.0 * math.log(u1)) * math.cos(2 * math.pi * u2)
    return math.exp(sigma * z)


def _gcs_bw(config: CloudConfig) -> float:
    node = GCS_BW_PER_CORE * min(config.cores_per_node, GCS_BW_CORE_CAP) \
        + GCS_BW_NODE_BASE
    return min(node * config.scale_out ** CLUSTER_SCALING, GCS_BW_CLUSTER_CAP)


def _disk_bw(config: CloudConfig) -> float:
    node = DISK_BW_PER_CORE * config.cores_per_node + DISK_BW_NODE_BASE
    return node * config.scale_out ** CLUSTER_SCALING


def _net_bw(config: CloudConfig) -> float:
    node = NET_BW_PER_CORE * config.cores_per_node + NET_BW_NODE_BASE
    return node * config.scale_out ** CLUSTER_SCALING


def usable_cache_gib(config: CloudConfig) -> float:
    per_node = max(0.0, config.mem_per_node_gib - NODE_MEM_OVERHEAD_GIB)
    return CACHE_FRACTION * per_node * config.scale_out


def runtime_s(job: JobSpec, config: CloudConfig, *, seed: int = 0,
              noise_sigma: float = NOISE_SIGMA) -> float:
    """Modelled wall-clock runtime of ``job`` on ``config`` in seconds."""
    p = ALGO_PARAMS[job.algorithm]
    s = job.dataset_gib
    n, k = config.scale_out, config.cores_per_node

    gcs, disk, net = _gcs_bw(config), _disk_bw(config), _net_bw(config)
    cores_eff = config.total_cores * (8.0 / k) ** CORE_EFF_EXPONENT
    heap = max(1.0, config.mem_per_node_gib - NODE_MEM_OVERHEAD_GIB)
    gc = 1.0
    if p.kappa > 0:
        gc += GC_PENALTY_PER_GIB * max(0.0, heap - GC_HEAP_KNEE_GIB)

    t = STARTUP_BASE_S + STARTUP_PER_NODE_S * n
    t += s / gcs                                   # input read
    t += p.out * s / gcs                           # output write
    if p.shuffle > 0:                              # shuffle: net + write-back
        t += p.shuffle * s / net + 0.5 * p.shuffle * s / disk

    cpu = (p.parse_w * s + p.w * s * p.iters) / cores_eff

    # memory behaviour: cache miss -> reloads + thrash
    need = p.kappa * s
    if need > 0:
        avail = usable_cache_gib(config)
        miss = max(0.0, need - avail)
        mf = miss / need
        reload_passes = max(0, p.iters - 1)
        if p.storage == "mem" and miss > 0:
            # MEMORY_ONLY: evicted partitions are recomputed from source.
            # LRU keeps the hot set, so effective reload volume ~ miss * mf.
            vol = miss * mf * reload_passes
            t += vol / gcs
            cpu += vol * REPARSE_FACTOR * p.parse_w / cores_eff
            cpu *= 1.0 + THRASH_CPU_FACTOR * mf ** 4
        elif p.storage == "disk" and miss > 0:
            # MEMORY_AND_DISK: spill to local disk, read back, merge.
            vol = miss * mf * SPILL_IO_PASSES * max(1, reload_passes)
            t += vol / disk
            cpu *= 1.0 + SPILL_CPU_FACTOR * mf ** 2
    t += cpu * gc

    return t * _noise(job, config, seed, noise_sigma)


def generate_trace(*, seed: int = 0, noise_sigma: float = NOISE_SIGMA,
                   jobs: Sequence[JobSpec] = PAPER_JOBS,
                   configs: Sequence[CloudConfig] = GCP_CONFIGS) -> Trace:
    """Regenerate the 180-execution evaluation trace (Tables I x II)."""
    records = [
        ExecutionRecord(job=j, config_index=c.index,
                        runtime_s=runtime_s(j, c, seed=seed,
                                            noise_sigma=noise_sigma))
        for j in jobs for c in configs
    ]
    return Trace(configs, records)
