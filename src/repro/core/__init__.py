"""Flora core: the paper's contribution (cloud resource selection) plus the
TPU-side adaptation (mesh/slice selection for JAX workloads).

The substrate-agnostic selection machinery (catalogs, profiling store,
vectorized ranking, selection service) lives in :mod:`repro.selector`;
the modules here are the paper-faithful entry points and adapters
(DESIGN.md §2).

Layers:
  trace       -- profiling-trace schema + the paper's evaluation universe
  costmodel   -- per-resource (GCP) and per-chip (TPU) price models
  flora       -- the selector: classify -> rank by normalized class cost
  baselines   -- Fw1C, Juggler, Crispy, static and random baselines
  spark_sim   -- calibrated analytical Spark model regenerating the trace
  evaluate    -- paper §III experiments (Tables III-V, Figs. 2-3)
  tpu_flora   -- Flora over TPU mesh configurations (dry-run profiled)
"""
from repro.core.trace import (CloudConfig, ExecutionRecord, GCP_CONFIGS,
                              JobClass, JobSpec, PAPER_JOBS, Trace)
from repro.core.costmodel import LinearPriceModel, TpuPriceModel

#: lazily re-exported so that repro.selector (imported by repro.core.flora)
#: can itself import repro.core.trace/costmodel without a package cycle.
_LAZY = {"Flora", "RankedConfig", "rank_generic"}


def __getattr__(name: str):
    if name in _LAZY:
        from repro.core import flora
        return getattr(flora, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
