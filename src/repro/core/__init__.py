"""Flora core: the paper's contribution (cloud resource selection) plus the
TPU-side adaptation (mesh/slice selection for JAX workloads).

Layers:
  trace       -- profiling-trace schema + the paper's evaluation universe
  costmodel   -- per-resource (GCP) and per-chip (TPU) price models
  flora       -- the selector: classify -> rank by normalized class cost
  baselines   -- Fw1C, Juggler, Crispy, static and random baselines
  spark_sim   -- calibrated analytical Spark model regenerating the trace
  evaluate    -- paper §III experiments (Tables III-V, Figs. 2-3)
  tpu_flora   -- Flora over TPU mesh configurations (dry-run profiled)
"""
from repro.core.trace import (CloudConfig, ExecutionRecord, GCP_CONFIGS,
                              JobClass, JobSpec, PAPER_JOBS, Trace)
from repro.core.costmodel import LinearPriceModel, TpuPriceModel
from repro.core.flora import Flora, RankedConfig, rank_generic
