"""Flora over TPU mesh configurations — the framework integration.

The paper's pipeline maps 1:1 onto TPU cluster selection (DESIGN.md §3):

* a *cloud configuration* is a :class:`MeshOption` — a TPU slice (chip
  count, generation, $/chip-hour market) plus the mesh split (data vs
  model parallel axes);
* a *test job* is an (architecture x input shape) workload whose "runtime"
  is the roofline-model step time derived from the compiled dry-run
  artifact (this container has no TPU, so the dry-run IS the profiler;
  on real hardware the same trace would hold measured step times);
* *job classes* follow the paper's data-access-pattern split:
  class A (**memory-demanding / state-resident**): decode and long-context
  serving, whose KV-cache/recurrent state must stay HBM-resident;
  class B (**memory-yielding / streaming-compute**): training and prefill,
  which stream activations through the MXU.

Selection routes through :mod:`repro.selector` — the same
catalog/store/rank/service stack as the GCP side (the paper's
normalized-cost ranking is class- and substrate-agnostic), via a
:class:`repro.selector.TpuSliceCatalog` over the mesh options.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import List, Mapping, Optional, Sequence, Tuple

from repro.core.costmodel import TpuPriceModel
from repro.core.trace import JobClass
from repro.selector import (ProfilingStore, RankedConfig, SelectionService,
                            TpuSliceCatalog)


@dataclasses.dataclass(frozen=True)
class MeshOption:
    """One selectable TPU deployment: slice size x mesh split."""

    name: str               # e.g. "v5e-256 dp16xtp16"
    generation: str         # "v5e" | "v5p"
    chips: int
    mesh_shape: Tuple[int, ...]
    mesh_axes: Tuple[str, ...]

    def hourly_cost(self, price: TpuPriceModel) -> float:
        return price.slice_hour(self.generation, self.chips)


#: Which shapes belong to which class (user-overridable, like the paper's
#: user annotation step).
SHAPE_CLASSES: Mapping[str, JobClass] = {
    "train_4k": JobClass.B,      # streaming compute: FLOP-bound
    "prefill_32k": JobClass.B,   # streaming compute: FLOP-bound
    "decode_32k": JobClass.A,    # state-resident: KV-cache bandwidth-bound
    "long_500k": JobClass.A,     # state-resident: long-context decode
}


def classify_workload(shape_name: str,
                      annotation: Optional[JobClass] = None) -> JobClass:
    """Step 1 — classification.  ``annotation`` models the user label."""
    if annotation is not None:
        return annotation
    return SHAPE_CLASSES[shape_name]


@dataclasses.dataclass(frozen=True)
class WorkloadRecord:
    """One profiled cell: (arch, shape) on a mesh option -> step seconds."""

    arch: str
    shape: str
    mesh: str
    step_seconds: float     # roofline step time (or measured, on hardware)
    steps: int = 1          # steps per job (scales runtime, not ranking)

    @property
    def job_id(self) -> str:
        return f"{self.arch}:{self.shape}"

    @property
    def job_class(self) -> JobClass:
        return SHAPE_CLASSES[self.shape]


def make_service(options: Sequence[MeshOption],
                 records: Sequence[WorkloadRecord],
                 price: TpuPriceModel,
                 backend: Optional[str] = None) -> SelectionService:
    """Wire catalog + store + price into a TPU-side selection service.

    ``backend`` selects the ranking backend (``None`` resolves via
    :func:`repro.selector.default_backend`)."""
    return SelectionService(
        TpuSliceCatalog(options, price),
        ProfilingStore.from_workload_records(
            records, config_ids=[o.name for o in options]),
        price, classifier=lambda shape: classify_workload(str(shape)),
        backend=backend)


class TpuFlora:
    """Flora Steps 0-2 over TPU mesh options (adapter over the service)."""

    def __init__(self, options: Sequence[MeshOption],
                 records: Sequence[WorkloadRecord],
                 price: TpuPriceModel, *, one_class: bool = False):
        self.options = list(options)
        self.records = list(records)
        self.price = price
        self.one_class = one_class
        self._by_name = {o.name: o for o in self.options}
        # paper-faithful adapter: pinned to the float64 bit-stable
        # backend (legacy-loop parity), like repro.core.flora.Flora
        self.service = make_service(self.options, self.records, price,
                                    backend="numpy")

    def rank(self, job_class: JobClass,
             exclude_archs: Sequence[str] = ()) -> List[RankedConfig]:
        klass = None if self.one_class else job_class
        return list(self.service.rank(job_class=klass,
                                      exclude_groups=tuple(exclude_archs)))

    def select(self, shape_name: str, *,
               annotation: Optional[JobClass] = None,
               exclude_archs: Sequence[str] = ()) -> MeshOption:
        """Full pipeline for a submitted (new) workload.

        ``exclude_archs`` enforces the paper's no-recurrence discipline:
        the submitted architecture's own profiling data is not consulted.
        """
        decision = self.service.submit(
            shape_name,
            annotation=annotation if not self.one_class else None,
            exclude_groups=tuple(exclude_archs),
            one_class=self.one_class)
        return self._by_name[decision.config_id]


# --- trace I/O (written by launch/dryrun.py, read by launch/train.py) ---------

def records_from_dryrun_report(report: Mapping) -> List[WorkloadRecord]:
    """Convert a dryrun.py JSON report into profiling records.

    The roofline step time is ``max(compute, memory, collective)`` seconds
    per step — the bound the compiled artifact proves.
    """
    out = []
    for cell in report.get("cells", []):
        if not cell.get("ok"):
            continue
        roof = cell["roofline"]
        step = max(roof["compute_s"], roof["memory_s"], roof["collective_s"])
        out.append(WorkloadRecord(arch=cell["arch"], shape=cell["shape"],
                                  mesh=cell["mesh"], step_seconds=step))
    return out


def load_records(path: str) -> List[WorkloadRecord]:
    with open(path) as f:
        return records_from_dryrun_report(json.load(f))


def _mesh_topology(name: str, chips: int) -> Tuple[Tuple[int, ...],
                                                   Tuple[str, ...]]:
    """Recover (shape, axes) from a ``dp{N}xtp{M}`` mesh name
    (the convention of :func:`repro.launch.mesh.mesh_options`); fall back
    to a pure data-parallel topology for unrecognized names."""
    m = re.fullmatch(r"dp(\d+)xtp(\d+)", name)
    if m:
        return (int(m.group(1)), int(m.group(2))), ("data", "model")
    return (chips,), ("data",)


def service_from_dryrun_report(report: Mapping, price: TpuPriceModel,
                               *, generation: str = "v5e", chips: int = 256
                               ) -> SelectionService:
    """One-call bridge: dryrun JSON -> catalog + store -> service.

    Mesh options are synthesised from the mesh names present in the report
    (the dry-run profiled exactly those splits); their topology is
    recovered from the ``dp{N}xtp{M}`` naming convention where possible.
    """
    recs = records_from_dryrun_report(report)
    meshes = sorted({r.mesh for r in recs})
    options = [MeshOption(m, generation, chips, *_mesh_topology(m, chips))
               for m in meshes]
    return make_service(options, recs, price)
