"""Flora over TPU mesh configurations — the framework integration.

The paper's pipeline maps 1:1 onto TPU cluster selection (DESIGN.md §3):

* a *cloud configuration* is a :class:`MeshOption` — a TPU slice (chip
  count, generation, $/chip-hour market) plus the mesh split (data vs
  model parallel axes);
* a *test job* is an (architecture x input shape) workload whose "runtime"
  is the roofline-model step time derived from the compiled dry-run
  artifact (this container has no TPU, so the dry-run IS the profiler;
  on real hardware the same trace would hold measured step times);
* *job classes* follow the paper's data-access-pattern split:
  class A (**memory-demanding / state-resident**): decode and long-context
  serving, whose KV-cache/recurrent state must stay HBM-resident;
  class B (**memory-yielding / streaming-compute**): training and prefill,
  which stream activations through the MXU.

Selection reuses :func:`repro.core.flora.rank_generic` verbatim — the
paper's normalized-cost ranking is class- and substrate-agnostic.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Hashable, List, Mapping, Optional, Sequence, Tuple

from repro.core.costmodel import TpuPriceModel
from repro.core.flora import RankedConfig, rank_generic
from repro.core.trace import JobClass


@dataclasses.dataclass(frozen=True)
class MeshOption:
    """One selectable TPU deployment: slice size x mesh split."""

    name: str               # e.g. "v5e-256 dp16xtp16"
    generation: str         # "v5e" | "v5p"
    chips: int
    mesh_shape: Tuple[int, ...]
    mesh_axes: Tuple[str, ...]

    def hourly_cost(self, price: TpuPriceModel) -> float:
        return price.slice_hour(self.generation, self.chips)


#: Which shapes belong to which class (user-overridable, like the paper's
#: user annotation step).
SHAPE_CLASSES: Mapping[str, JobClass] = {
    "train_4k": JobClass.B,      # streaming compute: FLOP-bound
    "prefill_32k": JobClass.B,   # streaming compute: FLOP-bound
    "decode_32k": JobClass.A,    # state-resident: KV-cache bandwidth-bound
    "long_500k": JobClass.A,     # state-resident: long-context decode
}


def classify_workload(shape_name: str,
                      annotation: Optional[JobClass] = None) -> JobClass:
    """Step 1 — classification.  ``annotation`` models the user label."""
    if annotation is not None:
        return annotation
    return SHAPE_CLASSES[shape_name]


@dataclasses.dataclass(frozen=True)
class WorkloadRecord:
    """One profiled cell: (arch, shape) on a mesh option -> step seconds."""

    arch: str
    shape: str
    mesh: str
    step_seconds: float     # roofline step time (or measured, on hardware)
    steps: int = 1          # steps per job (scales runtime, not ranking)

    @property
    def job_id(self) -> str:
        return f"{self.arch}:{self.shape}"

    @property
    def job_class(self) -> JobClass:
        return SHAPE_CLASSES[self.shape]


class TpuFlora:
    """Flora Steps 0-2 over TPU mesh options."""

    def __init__(self, options: Sequence[MeshOption],
                 records: Sequence[WorkloadRecord],
                 price: TpuPriceModel, *, one_class: bool = False):
        self.options = list(options)
        self.records = list(records)
        self.price = price
        self.one_class = one_class
        self._by_name = {o.name: o for o in self.options}

    def rank(self, job_class: JobClass,
             exclude_archs: Sequence[str] = ()) -> List[RankedConfig]:
        runtime_hours: Dict[Tuple[Hashable, Hashable], float] = {}
        jobs: List[str] = []
        for r in self.records:
            if not self.one_class and r.job_class is not job_class:
                continue
            if r.arch in exclude_archs:
                continue
            runtime_hours[(r.job_id, r.mesh)] = r.step_seconds * r.steps / 3600.0
            if r.job_id not in jobs:
                jobs.append(r.job_id)
        return rank_generic(
            runtime_hours, jobs, [o.name for o in self.options],
            lambda name: self._by_name[name].hourly_cost(self.price))

    def select(self, shape_name: str, *,
               annotation: Optional[JobClass] = None,
               exclude_archs: Sequence[str] = ()) -> MeshOption:
        """Full pipeline for a submitted (new) workload.

        ``exclude_archs`` enforces the paper's no-recurrence discipline:
        the submitted architecture's own profiling data is not consulted.
        """
        klass = classify_workload(shape_name, annotation)
        ranked = self.rank(klass, exclude_archs=exclude_archs)
        return self._by_name[ranked[0].config_id]


# --- trace I/O (written by launch/dryrun.py, read by launch/train.py) ---------

def records_from_dryrun_report(report: Mapping) -> List[WorkloadRecord]:
    """Convert a dryrun.py JSON report into profiling records.

    The roofline step time is ``max(compute, memory, collective)`` seconds
    per step — the bound the compiled artifact proves.
    """
    out = []
    for cell in report.get("cells", []):
        if not cell.get("ok"):
            continue
        roof = cell["roofline"]
        step = max(roof["compute_s"], roof["memory_s"], roof["collective_s"])
        out.append(WorkloadRecord(arch=cell["arch"], shape=cell["shape"],
                                  mesh=cell["mesh"], step_seconds=step))
    return out


def load_records(path: str) -> List[WorkloadRecord]:
    with open(path) as f:
        return records_from_dryrun_report(json.load(f))
