"""Resource cost models.

Flora applies *current* hourly resource costs to historical runtimes
(paper §II-D).  Two families of cost model live here:

* :class:`LinearPriceModel` — per-resource (vCPU-hour, GiB-hour) pricing as
  used for GCP n2 VMs in the paper's evaluation (§III-C notes that configs
  with equal total cores and total memory cost the same regardless of
  scale-out, i.e. pricing is linear in the resource totals).
* :class:`TpuPriceModel` — $/chip-hour pricing for TPU slices, used by the
  TPU-side adaptation (mesh selection; see DESIGN.md §3).

Both are plain callables so the selector can be handed a time-varying price
source (spot market, carbon intensity) without code changes.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Optional

from repro.core.trace import CloudConfig

# GCP n2 predefined-VM resource rates, europe-west3 (Frankfurt),
# on-demand, as of 2024-12-01 (USD).  The paper's evaluation date.
GCP_N2_FRANKFURT_CPU_HOUR = 0.03805
GCP_N2_FRANKFURT_GIB_HOUR = 0.00510


@dataclasses.dataclass(frozen=True)
class LinearPriceModel:
    """hourly_cost(c) = total_cores * cpu_rate + total_mem_gib * mem_rate."""

    cpu_core_hour: float = GCP_N2_FRANKFURT_CPU_HOUR
    mem_gib_hour: float = GCP_N2_FRANKFURT_GIB_HOUR
    #: multiplier for, e.g., spot discount or carbon-intensity scaling.
    multiplier: float = 1.0

    def __call__(self, config: CloudConfig) -> float:
        return self.multiplier * (
            config.total_cores * self.cpu_core_hour
            + config.total_mem_gib * self.mem_gib_hour)

    def with_mem_to_cpu_ratio(self, ratio: float) -> "LinearPriceModel":
        """Price model where 1 GiB-hour costs ``ratio`` vCPU-hours.

        This is the x-axis of the paper's Fig. 2 (10^-2 .. 10^1): the CPU
        rate is held fixed and the memory rate is set relative to it.
        """
        return LinearPriceModel(cpu_core_hour=self.cpu_core_hour,
                                mem_gib_hour=ratio * self.cpu_core_hour,
                                multiplier=self.multiplier)


def execution_cost(runtime_s: float, config: CloudConfig,
                   price: LinearPriceModel) -> float:
    """cost(j, c) = runtime_in_hours(j, c) * current_hourly_cost(c)."""
    return runtime_s / 3600.0 * price(config)


# --- TPU-side pricing (framework integration) --------------------------------

# Public list prices, USD per chip-hour (us-central, on-demand / spot),
# indicative as of 2024: v5e on-demand 1.2 / spot ~0.72; v5p 4.2 / ~2.1.
TPU_CHIP_HOUR = {
    ("v5e", "ondemand"): 1.20,
    ("v5e", "spot"): 0.72,
    ("v5p", "ondemand"): 4.20,
    ("v5p", "spot"): 2.10,
}


@dataclasses.dataclass(frozen=True)
class TpuPriceModel:
    """$/hour for a whole slice: chips * chip_hour(generation, market)."""

    market: str = "ondemand"
    #: optional override table, e.g. live spot quotes per generation.
    rates: Optional[Mapping[str, float]] = None

    def chip_hour(self, generation: str) -> float:
        if self.rates is not None and generation in self.rates:
            return self.rates[generation]
        return TPU_CHIP_HOUR[(generation, self.market)]

    def slice_hour(self, generation: str, chips: int) -> float:
        return self.chip_hour(generation) * chips
