"""ProfilingStore: dense (job x config) runtime matrices with persistence.

The store subsumes the two ad-hoc profiling containers the repo grew —
:class:`repro.core.trace.Trace` (GCP, JSON blob) and the
``WorkloadRecord`` lists of :mod:`repro.core.tpu_flora` (TPU, dry-run
JSON) — behind one schema:

  * rows are *jobs* (hashable id + optional class + optional group for
    leave-one-group-out evaluation),
  * columns are catalog entry ids,
  * cells are runtime **hours**; missing cells (partial profiling, §II-B)
    are masked, not imputed;
  * inserts are incremental (rows/columns appended on first sight, the
    backing array grows amortized-doubling), so a live profiler can stream
    measurements in;
  * persistence is versioned JSONL — a header line then one record per
    profiled cell — replacing the two incompatible JSON formats.
"""
from __future__ import annotations

import dataclasses
import json
from typing import (Dict, Hashable, Iterable, List, Optional, Sequence,
                    Tuple)

import numpy as np

from repro.core.trace import JobClass, Trace
from repro.obs import MetricsRegistry

JSONL_FORMAT = "repro.selector.profiling-store"
JSONL_VERSION = 1


@dataclasses.dataclass(frozen=True)
class JobMeta:
    """Per-job metadata the selector filters on."""

    job_id: Hashable
    job_class: Optional[JobClass] = None
    #: exclusion group (algorithm / architecture) for the paper's
    #: leave-one-out discipline (§III-A).
    group: Optional[str] = None


class ProfilingStore:
    """Dense runtime-hours matrix over (job, config) with partial masks."""

    def __init__(self, config_ids: Sequence[Hashable] = (),
                 metrics: Optional[MetricsRegistry] = None):
        self._config_ids: List[Hashable] = []
        self._config_pos: Dict[Hashable, int] = {}
        self._job_ids: List[Hashable] = []
        self._job_pos: Dict[Hashable, int] = {}
        self._meta: Dict[Hashable, JobMeta] = {}
        self._hours = np.full((0, 0), np.nan)
        #: mutation counter; consumers (SelectionService) key caches on it
        #: so streamed-in cells invalidate stale rankings.
        self.version = 0
        #: telemetry (DESIGN.md §12); pass a shared registry to export
        #: store counters alongside service/frontend metrics.
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._c_realloc = self.metrics.counter("store.reallocs")
        for c in config_ids:
            self._add_config(c)

    @property
    def realloc_count(self) -> int:
        """Backing-array reallocations; rows and columns both grow by
        amortized doubling, so this stays O(log rows + log cols) —
        asserted by the growth test in tests/test_market.py."""
        return self._c_realloc.value

    # -- growth ------------------------------------------------------------
    def _grown(self, rows: int, cols: int) -> np.ndarray:
        new = np.full((max(rows, 1), max(cols, 1)), np.nan)
        r, c = self._hours.shape
        new[:r, :c] = self._hours
        self._c_realloc.inc()
        return new

    def _add_config(self, config_id: Hashable) -> int:
        pos = self._config_pos.get(config_id)
        if pos is not None:
            return pos
        pos = len(self._config_ids)
        self._config_ids.append(config_id)
        self._config_pos[config_id] = pos
        if pos >= self._hours.shape[1]:
            self._hours = self._grown(self._hours.shape[0],
                                      max(2 * self._hours.shape[1], pos + 1))
        return pos

    def _add_job(self, job_id: Hashable, job_class: Optional[JobClass],
                 group: Optional[str]) -> int:
        pos = self._job_pos.get(job_id)
        if pos is None:
            pos = len(self._job_ids)
            self._job_ids.append(job_id)
            self._job_pos[job_id] = pos
            self._meta[job_id] = JobMeta(job_id, job_class, group)
            if pos >= self._hours.shape[0]:
                self._hours = self._grown(max(2 * self._hours.shape[0],
                                              pos + 1),
                                          self._hours.shape[1])
        elif job_class is not None or group is not None:
            old = self._meta[job_id]
            self._meta[job_id] = JobMeta(
                job_id, job_class if job_class is not None else old.job_class,
                group if group is not None else old.group)
        return pos

    # -- inserts -----------------------------------------------------------
    def add(self, job_id: Hashable, config_id: Hashable,
            runtime_hours: float, *, job_class: Optional[JobClass] = None,
            group: Optional[str] = None) -> None:
        """Record one profiled cell (overwrites re-profiled cells)."""
        if not runtime_hours > 0:
            raise ValueError(
                f"non-positive runtime for {job_id!r} on {config_id!r}")
        r = self._add_job(job_id, job_class, group)
        c = self._add_config(config_id)
        self._hours[r, c] = runtime_hours
        self.version += 1

    # -- accessors ---------------------------------------------------------
    @property
    def config_ids(self) -> List[Hashable]:
        return list(self._config_ids)

    @property
    def job_ids(self) -> List[Hashable]:
        return list(self._job_ids)

    def meta(self, job_id: Hashable) -> JobMeta:
        return self._meta[job_id]

    def has(self, job_id: Hashable, config_id: Hashable) -> bool:
        r = self._job_pos.get(job_id)
        c = self._config_pos.get(config_id)
        return (r is not None and c is not None
                and not np.isnan(self._hours[r, c]))

    def runtime_hours(self, job_id: Hashable, config_id: Hashable) -> float:
        v = self._hours[self._job_pos[job_id], self._config_pos[config_id]]
        if np.isnan(v):
            raise KeyError((job_id, config_id))
        return float(v)

    def __len__(self) -> int:
        """Number of profiled cells."""
        j, c = len(self._job_ids), len(self._config_ids)
        return int(np.count_nonzero(~np.isnan(self._hours[:j, :c])))

    # -- selector-facing views ----------------------------------------------
    def select_jobs(self, *, job_class: Optional[JobClass] = None,
                    exclude_groups: Sequence[str] = ()) -> List[Hashable]:
        """Jobs usable as test jobs for a submission (ordered by insert)."""
        out = []
        for j in self._job_ids:
            m = self._meta[j]
            if job_class is not None and m.job_class is not job_class:
                continue
            if m.group is not None and m.group in exclude_groups:
                continue
            out.append(j)
        return out

    def matrix(self, job_ids: Optional[Sequence[Hashable]] = None,
               config_ids: Optional[Sequence[Hashable]] = None
               ) -> Tuple[np.ndarray, np.ndarray]:
        """(runtime-hours, profiled-mask) matrices, rows/cols as requested.

        Unprofiled cells hold ``nan`` in the hours matrix and ``False`` in
        the mask; callers must never read an unmasked ``nan``.
        """
        jobs = self._job_ids if job_ids is None else list(job_ids)
        cfgs = self._config_ids if config_ids is None else list(config_ids)
        rows = [self._job_pos[j] for j in jobs]
        cols = [self._config_pos.get(c, -1) for c in cfgs]
        hours = np.full((len(rows), len(cols)), np.nan)
        known = [i for i, c in enumerate(cols) if c >= 0]
        if rows and known:
            sub = self._hours[np.ix_(rows, [cols[i] for i in known])]
            hours[:, known] = sub
        mask = ~np.isnan(hours)
        return hours, mask

    # -- versioned JSONL persistence -----------------------------------------
    def dump_jsonl(self) -> str:
        header = {"format": JSONL_FORMAT, "version": JSONL_VERSION,
                  "config_ids": self._config_ids}
        lines = [json.dumps(header)]
        j, c = len(self._job_ids), len(self._config_ids)
        for r in range(j):
            meta = self._meta[self._job_ids[r]]
            for k in range(c):
                v = self._hours[r, k]
                if np.isnan(v):
                    continue
                lines.append(json.dumps({
                    "job": self._job_ids[r],
                    "config": self._config_ids[k],
                    "runtime_hours": float(v),
                    "job_class": (meta.job_class.value
                                  if meta.job_class else None),
                    "group": meta.group,
                }))
        return "\n".join(lines) + "\n"

    def save_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.dump_jsonl())

    @classmethod
    def loads_jsonl(cls, text: str) -> "ProfilingStore":
        lines = [ln for ln in text.splitlines() if ln.strip()]
        if not lines:
            raise ValueError("empty profiling store file")
        header = json.loads(lines[0])
        if header.get("format") != JSONL_FORMAT:
            raise ValueError(f"not a profiling store: {header!r}")
        if header.get("version") != JSONL_VERSION:
            raise ValueError(
                f"unsupported store version {header.get('version')!r}")
        store = cls(config_ids=header.get("config_ids", ()))
        for ln in lines[1:]:
            rec = json.loads(ln)
            klass = (JobClass(rec["job_class"])
                     if rec.get("job_class") else None)
            store.add(rec["job"], rec["config"], rec["runtime_hours"],
                      job_class=klass, group=rec.get("group"))
        return store

    @classmethod
    def load_jsonl(cls, path: str) -> "ProfilingStore":
        with open(path) as f:
            return cls.loads_jsonl(f.read())

    # -- converters from the legacy containers --------------------------------
    @classmethod
    def from_trace(cls, trace: Trace) -> "ProfilingStore":
        """Adapt a GCP :class:`Trace` (runtime seconds -> hours)."""
        store = cls(config_ids=[c.index for c in trace.configs])
        for r in trace.records:
            store.add(r.job.name, r.config_index, r.runtime_s / 3600.0,
                      job_class=r.job.job_class, group=r.job.algorithm)
        return store

    @classmethod
    def from_workload_records(cls, records: Iterable,
                              config_ids: Sequence[Hashable] = ()
                              ) -> "ProfilingStore":
        """Adapt TPU ``WorkloadRecord`` lists (step seconds x steps)."""
        store = cls(config_ids=config_ids)
        for r in records:
            store.add(r.job_id, r.mesh, r.step_seconds * r.steps / 3600.0,
                      job_class=r.job_class, group=r.arch)
        return store
