"""SelectionService: the submit -> Decision facade over catalog + store.

The service owns the pieces a deployed selector needs around the ranking
math itself:

  * **price epochs** — prices change while the trace does not (§II-D);
    swapping the price source bumps an epoch counter and invalidates every
    cached ranking;
  * **ranking caches** — rankings depend only on (job class, exclusion
    set, price epoch), so repeat submissions of same-class jobs are O(1)
    dictionary hits (the serving-scale path: one ranking amortized over
    thousands of submissions);
  * **classification** — `submit` resolves the job's class from, in
    order: the explicit annotation, the injected classifier, the store's
    job metadata (Step 1 of the paper).
"""
from __future__ import annotations

import dataclasses
from typing import (Any, Callable, Dict, Hashable, Optional, Sequence,
                    Tuple)

from repro.core.trace import JobClass
from repro.selector.catalog import BaseCatalog
from repro.selector.rank import RankedConfig, rank_dense
from repro.selector.store import ProfilingStore


@dataclasses.dataclass(frozen=True)
class Decision:
    """The outcome of one submission."""

    job_id: Hashable
    job_class: Optional[JobClass]
    config_id: Hashable
    entry: Any                          # native config object
    hourly_cost: float
    ranking: Tuple[RankedConfig, ...]
    from_cache: bool
    price_epoch: int


class SelectionService:
    """Serving facade: ``submit(job, annotation) -> Decision``."""

    def __init__(self, catalog: BaseCatalog, store: ProfilingStore,
                 price_source: Optional[Any] = None,
                 classifier: Optional[Callable[[Hashable],
                                               JobClass]] = None,
                 backend: str = "numpy"):
        self.catalog = catalog
        self.store = store
        self.classifier = classifier
        self.backend = backend
        self._price_source = price_source
        self._price_epoch = 0
        self._cache: Dict[Tuple, Tuple[RankedConfig, ...]] = {}
        self.cache_hits = 0
        self.cache_misses = 0

    # -- price management ---------------------------------------------------
    @property
    def price_epoch(self) -> int:
        return self._price_epoch

    @property
    def price_source(self) -> Any:
        return self._price_source

    def set_price_source(self, price_source: Any) -> None:
        """Swap in current prices; invalidates all cached rankings."""
        self._price_source = price_source
        self.invalidate_prices()

    def invalidate_prices(self) -> None:
        """Bump the price epoch (e.g. the same mutable source re-quoted)."""
        self._price_epoch += 1
        self._cache.clear()

    # -- ranking (cached) ----------------------------------------------------
    def rank(self, job_class: Optional[JobClass] = None,
             exclude_groups: Sequence[str] = ()
             ) -> Tuple[RankedConfig, ...]:
        """Rank the whole catalog for a class (``None`` = all classes)."""
        key = (self._price_epoch, self.store.version, job_class,
               tuple(sorted(exclude_groups)))
        hit = self._cache.get(key)
        if hit is not None:
            self.cache_hits += 1
            return hit
        self.cache_misses += 1
        jobs = self.store.select_jobs(job_class=job_class,
                                      exclude_groups=exclude_groups)
        if not jobs:
            raise ValueError("no test jobs to learn from")
        config_ids = self.catalog.ids()
        hours, mask = self.store.matrix(job_ids=jobs, config_ids=config_ids)
        prices = self.catalog.price_vector(self._price_source)
        ranking = tuple(rank_dense(hours, mask, prices, config_ids,
                                   job_ids=jobs, backend=self.backend))
        self._cache[key] = ranking
        return ranking

    # -- the paper pipeline for one submitted job -----------------------------
    def classify(self, job_id: Hashable,
                 annotation: Optional[JobClass] = None
                 ) -> Optional[JobClass]:
        if annotation is not None:
            return annotation
        if self.classifier is not None:
            return self.classifier(job_id)
        if job_id in self.store.job_ids:
            return self.store.meta(job_id).job_class
        return None

    def submit(self, job_id: Hashable, *,
               annotation: Optional[JobClass] = None,
               exclude_groups: Optional[Sequence[str]] = None,
               one_class: bool = False) -> Decision:
        """Classify, rank under current prices, pick the argmin.

        ``exclude_groups`` defaults to the job's own group when the job is
        already profiled (the paper's no-recurrence discipline, §III-A).
        """
        klass = None if one_class else self.classify(job_id, annotation)
        if exclude_groups is None:
            exclude_groups = ()
            if job_id in self.store.job_ids:
                own = self.store.meta(job_id).group
                if own is not None:
                    exclude_groups = (own,)
        before = self.cache_hits
        ranking = self.rank(job_class=klass,
                            exclude_groups=tuple(exclude_groups))
        winner = ranking[0]
        if winner.score == float("inf"):
            # every catalog entry is unprofiled for this selection
            # (catalog/store id mismatch, or a fully-masked trace) —
            # an arbitrary pick must never look like a decision.
            raise ValueError(
                f"no profiled configurations to rank for job {job_id!r} "
                f"(class {klass})")
        return Decision(
            job_id=job_id, job_class=klass, config_id=winner.config_id,
            entry=self.catalog.entry(winner.config_id),
            hourly_cost=self.catalog.hourly_cost(winner.config_id,
                                                 self._price_source),
            ranking=ranking, from_cache=self.cache_hits > before,
            price_epoch=self._price_epoch)
