"""SelectionService: the submit -> Decision facade over catalog + store.

The service owns the pieces a deployed selector needs around the ranking
math itself:

  * **price epochs** — prices change while the trace does not (§II-D);
    swapping the price source bumps an epoch counter and invalidates every
    cached ranking;
  * **incremental repricing** — when the price source is a mutable
    :class:`~repro.selector.catalog.PriceTable` driven by a market feed,
    :meth:`reprice` applies per-config deltas to the live
    :class:`~repro.selector.rank.RankState` of every cached ranking
    instead of recomputing from scratch (DESIGN.md §6);
  * **ranking caches** — rankings depend only on (job class, exclusion
    set, price epoch), so repeat submissions of same-class jobs are O(1)
    dictionary hits (the serving-scale path: one ranking amortized over
    thousands of submissions);
  * **classification** — `submit` resolves the job's class from, in
    order: the explicit annotation, the injected classifier, the store's
    job metadata (Step 1 of the paper).
"""
from __future__ import annotations

import dataclasses
from typing import (Any, Callable, Dict, Hashable, Mapping, Optional,
                    Sequence, Tuple)

from repro.core.trace import JobClass
from repro.obs import MetricsRegistry
from repro.selector.catalog import BaseCatalog, PriceTable
from repro.selector.rank import (BACKENDS, FLEET_BACKENDS,
                                 BackendUnavailableError,
                                 BatchedRankState, JaxRankState,
                                 NothingRankableError, RankedConfig,
                                 RankState, backend_available,
                                 default_backend)
from repro.selector.pallas_rank import PallasBatchedRankState
from repro.selector.sharded import ShardedBatchedRankState
from repro.selector.store import ProfilingStore


@dataclasses.dataclass(frozen=True)
class Decision:
    """The outcome of one submission."""

    job_id: Hashable
    job_class: Optional[JobClass]
    config_id: Hashable
    entry: Any                          # native config object
    hourly_cost: float
    ranking: Tuple[RankedConfig, ...]
    from_cache: bool
    price_epoch: int
    #: the *effective* exclusion set the ranking was computed under
    #: (explicit argument, or the job's own group by default) — journal
    #: consumers need it to recompute the ranking cold (DESIGN.md §8).
    exclude_groups: Tuple[str, ...] = ()
    #: how :attr:`ranking` was produced: ``"ranking"`` — the full sorted
    #: list; ``"top_k"`` — only the head of the ranking was served
    #: (device-side partial selection, DESIGN.md §10), so :attr:`ranking`
    #: holds the first k entries and nothing below them.  The winner,
    #: score and $/h fields are identical either way — journal audits
    #: hold top-k-served decisions to the same contract (§8).
    served_via: str = "ranking"


class SelectionService:
    """Serving facade: ``submit(job, annotation) -> Decision``."""

    def __init__(self, catalog: BaseCatalog, store: ProfilingStore,
                 price_source: Optional[Any] = None,
                 classifier: Optional[Callable[[Hashable],
                                               JobClass]] = None,
                 backend: Optional[str] = None,
                 serve_top_k: Optional[int] = None,
                 metrics: Optional[MetricsRegistry] = None):
        self.catalog = catalog
        self.store = store
        self.classifier = classifier
        #: the service's telemetry registry (DESIGN.md §12).  Every
        #: counter below lives on it; the market layer (ticker, daemon,
        #: front-end) adopts it by default so one registry carries the
        #: whole tick/serve pipeline.  Inject a shared registry to merge
        #: with store/train/engine telemetry.
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        #: ``None`` resolves via :func:`repro.selector.default_backend`
        #: (the ``FLORA_RANK_BACKEND`` env var — CI's backend matrix),
        #: else "numpy".  "numpy" serves the bit-identical float64
        #: contract; "jax" the accelerator-resident float32 tolerance
        #: contract (DESIGN.md §9); "jax_batched" the same contract with
        #: every live (class, exclusion) ranking stacked into one
        #: :class:`BatchedRankState` — a tick is one kernel dispatch for
        #: the whole fleet (DESIGN.md §10); "jax_sharded" the batched
        #: fleet with its config axis sharded across every local device
        #: (:class:`ShardedBatchedRankState`) — a tick is one
        #: *collective* dispatch (DESIGN.md §13).
        self.backend = backend if backend is not None else default_backend()
        # fail at construction, not first submit: a service that can
        # never rank is misconfiguration the caller should see now
        if self.backend not in BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r} "
                             f"(expected one of {BACKENDS})")
        if not backend_available(self.backend):
            # typed, so harnesses can skip instead of dying
            raise BackendUnavailableError(
                f"backend={self.backend!r} requested but its runtime "
                f"dependency is not installed")
        #: default serving depth: ``None`` serves full rankings
        #: (``Decision.served_via == "ranking"``); a positive int makes
        #: ``submit`` serve only the top-k head of the ranking — the
        #: full C-config materialize/sort never runs (DESIGN.md §10).
        #: Overridable per submission via ``submit(..., top_k=)``.
        if serve_top_k is not None and (
                not isinstance(serve_top_k, int)
                or isinstance(serve_top_k, bool) or serve_top_k < 1):
            raise ValueError(f"serve_top_k must be a positive int or "
                             f"None, got {serve_top_k!r}")
        self.serve_top_k = serve_top_k
        self._price_source = price_source
        self._price_epoch = 0
        self._cache: Dict[Tuple, Tuple[RankedConfig, ...]] = {}
        #: top-k heads served without a full materialization, keyed like
        #: the ranking cache plus the depth k.
        self._head_cache: Dict[Tuple, Tuple[RankedConfig, ...]] = {}
        #: live incremental states, keyed like the cache but without the
        #: price tag — a reprice mutates them in place across epochs.
        #: Unused by the fleet backends ("jax_batched"/"jax_sharded"),
        #: whose fleet lives inside the one shared :attr:`_batched`
        #: state instead.
        self._states: Dict[Tuple, RankState] = {}
        #: price tag each state was last (re)priced under; a state is only
        #: served when its tag matches the current one.
        self._state_tags: Dict[Tuple, Tuple] = {}
        # the fleet backends' universe: one BatchedRankState (or its
        # sharded counterpart) over the full store, members keyed by
        # base_key, plus the tag/store version it is in sync with
        self._batched: Optional[BatchedRankState] = None
        self._batched_tag: Optional[Tuple] = None
        self._batched_store_version: Optional[int] = None
        # the scattered ad-hoc counters of PR 1-6 migrated onto the
        # registry; the attribute names below are pinned by the soak
        # suite and stay as read-only properties.
        self._c_hits = self.metrics.counter("service.cache_hits")
        self._c_misses = self.metrics.counter("service.cache_misses")
        self._c_refreshes = self.metrics.counter("service.reprice_refreshes")
        self._c_dispatches = self.metrics.counter(
            "service.reprice_dispatches")

    @property
    def cache_hits(self) -> int:
        return self._c_hits.value

    @cache_hits.setter
    def cache_hits(self, v: int) -> None:
        self._c_hits.set(v)

    @property
    def cache_misses(self) -> int:
        return self._c_misses.value

    @cache_misses.setter
    def cache_misses(self, v: int) -> None:
        self._c_misses.set(v)

    @property
    def reprice_refreshes(self) -> int:
        """Rankings refreshed via the incremental path (not recomputes)."""
        return self._c_refreshes.value

    @property
    def reprice_dispatches(self) -> int:
        """Kernel dispatches spent repricing: one per live state per tick
        for the per-state backends, exactly one per tick for the fleet
        backends ("jax_batched"/"jax_sharded") regardless of fleet size
        (the soak/bench gate)."""
        return self._c_dispatches.value

    # -- price management ---------------------------------------------------
    @property
    def price_epoch(self) -> int:
        return self._price_epoch

    @property
    def price_source(self) -> Any:
        return self._price_source

    def set_price_source(self, price_source: Any) -> None:
        """Swap in current prices; invalidates all cached rankings."""
        self._price_source = price_source
        self.invalidate_prices()

    def invalidate_prices(self) -> None:
        """Bump the price epoch (e.g. the same mutable source re-quoted)."""
        self._price_epoch += 1
        self._cache.clear()
        self._head_cache.clear()
        self._states.clear()
        self._state_tags.clear()
        self._batched = None
        self._batched_tag = None
        self._batched_store_version = None

    def price_snapshot(self) -> Tuple[int, Tuple[Tuple[Hashable, float],
                                                 ...]]:
        """``(price_epoch, ((config_id, $/h), ...))`` in catalog order —
        the self-contained state a journal consumer needs to reconstruct
        this service's prices at a later time (DESIGN.md §8).  Works for
        any price source; for a :class:`PriceTable` it is the table's
        current quotes."""
        prices = self.catalog.price_vector(self._price_source)
        return self._price_epoch, tuple(
            (c, float(p)) for c, p in zip(self.catalog.ids(), prices))

    def _price_tag(self) -> Tuple:
        """What cached rankings are keyed on: the epoch, plus the table
        version for :class:`PriceTable` sources — so quotes applied to
        the table *outside* :meth:`reprice` can never serve a stale
        cached ranking (they force a cold recompute instead)."""
        src = self._price_source
        return (self._price_epoch,
                src.version if isinstance(src, PriceTable) else None)

    def reprice(self, deltas: Mapping[Hashable, float]) -> int:
        """Apply ``{config_id: new $/h}`` quotes incrementally.

        Requires the price source to be a :class:`PriceTable` (the table
        is the single source of truth for cold recomputes; applying deltas
        anywhere else would let an incremental ranking and a later cold
        ranking disagree within one epoch).  Delta ids are validated
        against the catalog *before* the table mutates, so a bad batch
        cannot desync live states from the table.  The table is updated,
        the epoch bumps, and every live :class:`RankState` that was in
        sync with the table before this tick is repriced in place (a
        state that missed an out-of-band ``table.apply`` is dropped and
        rebuilt cold); refreshed rankings materialize lazily on the next
        ``rank``/``submit`` (building and sorting the ranking list costs
        more than the incremental update itself at 10k configs — no point
        paying it per tick for classes nobody submits).  Returns the
        number of states repriced incrementally.
        """
        if not isinstance(self._price_source, PriceTable):
            raise ValueError(
                "reprice requires a PriceTable price source; use "
                "set_price_source/invalidate_prices for model sources")
        deltas = dict(deltas)
        if not deltas:
            return 0
        with self.metrics.span("reprice.validate"):
            unknown = [c for c in deltas if c not in self.catalog]
        if unknown:
            raise ValueError(
                f"unknown config ids in price deltas: {unknown[:3]!r}")
        prev_tag = self._price_tag()
        self._price_source.apply(deltas)
        self._price_epoch += 1
        self._cache.clear()
        self._head_cache.clear()
        tag = self._price_tag()
        refreshed = 0
        with self.metrics.span("reprice.dispatch"):
            if self.backend in FLEET_BACKENDS:
                # the whole fleet refreshes in ONE (possibly
                # collective) kernel dispatch
                if self._batched is not None and (
                        self._batched_store_version != self.store.version
                        or self._batched_tag != prev_tag):
                    # stale trace, or a universe that missed an out-of-band
                    # table.apply before this tick: repricing it would
                    # serve quotes it never saw — drop it, rebuild cold on
                    # demand
                    self._batched = None
                    self._batched_tag = None
                    self._batched_store_version = None
                if self._batched is not None:
                    self._batched.reprice(deltas)
                    self._batched_tag = tag
                    self._c_dispatches.inc()
                    refreshed = self._batched.n_active
            else:
                for key, state in list(self._states.items()):
                    store_version = key[0]
                    if store_version != self.store.version or \
                            self._state_tags.get(key) != prev_tag:
                        # stale trace, or a state that missed an
                        # out-of-band table.apply before this tick:
                        # repricing it would serve quotes it never saw —
                        # drop it, rebuild cold on demand
                        del self._states[key]
                        self._state_tags.pop(key, None)
                        continue
                    state.reprice(deltas)
                    self._state_tags[key] = tag
                    self._c_dispatches.inc()
                    refreshed += 1
        self._c_refreshes.inc(refreshed)
        return refreshed

    # -- fleet management ----------------------------------------------------
    def retire_selection(self, job_class: Optional[JobClass] = None,
                         exclude_groups: Sequence[str] = ()) -> bool:
        """Retire a live (class, exclusion) selection: drop its cached
        rankings/heads and its live state (batched backend: the member is
        retired from the shared :class:`BatchedRankState`, so any stale
        closure still bound to it raises
        :class:`~repro.selector.NothingRankableError` — a typed
        rejection, never a raw ``KeyError`` or a masked-slot score).

        Retirement is *serving-state* hygiene, not a ban: a later submit
        for the same selection rebuilds it cold and serves normally —
        the journal only records a rejection when the selection is
        genuinely unrankable.  Returns True when anything was dropped.
        """
        base_key = (self.store.version, job_class,
                    tuple(sorted(exclude_groups)))
        retired = False
        for cache in (self._cache, self._head_cache):
            for key in [k for k in cache if k[2:5] == base_key]:
                del cache[key]
                retired = True
        if self._states.pop(base_key, None) is not None:
            self._state_tags.pop(base_key, None)
            retired = True
        if self._batched is not None and base_key in self._batched:
            self._batched.retire_state(base_key)
            retired = True
        return retired

    # -- ranking (cached) ----------------------------------------------------
    def _live_serving(self, base_key: Tuple, tag: Tuple
                      ) -> Optional[Tuple[Callable[[], Sequence[RankedConfig]],
                                          Callable[[int],
                                                   Sequence[RankedConfig]]]]:
        """``(ranking_fn, top_k_fn)`` bound to an in-sync live state for
        ``base_key`` (repriced incrementally on the last tick — serving
        from it is a cache hit, no ranking recompute happened), or
        ``None`` when the selection must be built cold."""
        if self.backend in FLEET_BACKENDS:
            b = self._batched
            if b is not None and self._batched_tag == tag and \
                    self._batched_store_version == self.store.version \
                    and base_key in b:
                return (lambda: b.ranking(base_key),
                        lambda k: b.top_k(base_key, k))
            return None
        state = self._states.get(base_key)
        if state is not None and self._state_tags.get(base_key) == tag:
            return state.ranking, state.top_k
        return None

    def _build_serving(self, base_key: Tuple, tag: Tuple,
                       job_class: Optional[JobClass],
                       exclude_groups: Sequence[str]
                       ) -> Tuple[Callable[[], Sequence[RankedConfig]],
                                  Callable[[int], Sequence[RankedConfig]]]:
        """Cold-build the live state serving ``base_key`` and return its
        ``(ranking_fn, top_k_fn)``.  Per-state backends build one
        RankState/JaxRankState over the selection's rows; the fleet
        backends register the selection as a member of the one shared
        :class:`BatchedRankState` (or, for "jax_sharded", the
        multi-device :class:`ShardedBatchedRankState`) over the full
        store (building that universe first if the trace or price tag
        moved on)."""
        jobs = self.store.select_jobs(job_class=job_class,
                                      exclude_groups=exclude_groups)
        if not jobs:
            raise NothingRankableError("no test jobs to learn from")
        config_ids = self.catalog.ids()
        prices = self.catalog.price_vector(self._price_source)
        if self.backend in FLEET_BACKENDS:
            b = self._batched
            if b is None or \
                    self._batched_store_version != self.store.version \
                    or self._batched_tag != tag:
                all_jobs = self.store.job_ids
                hours, mask = self.store.matrix(job_ids=all_jobs,
                                                config_ids=config_ids)
                fleet_cls = {
                    "jax_batched": BatchedRankState,
                    "jax_sharded": ShardedBatchedRankState,
                    "jax_pallas": PallasBatchedRankState,
                }[self.backend]
                b = fleet_cls(hours, mask, prices, config_ids,
                              job_ids=all_jobs,
                              metrics=self.metrics)
                self._batched = b
                self._batched_tag = tag
                self._batched_store_version = self.store.version
            if base_key not in b:
                b.add_state(base_key, jobs=jobs)
            return (lambda: b.ranking(base_key),
                    lambda k: b.top_k(base_key, k))
        hours, mask = self.store.matrix(job_ids=jobs, config_ids=config_ids)
        # build through a live state so later reprices are incremental:
        # RankState's arithmetic is the cold numpy path verbatim
        # (bit-identical); JaxRankState serves the accelerator-resident
        # float32 tolerance contract (DESIGN.md §9).
        if self.backend == "numpy":
            state_cls = RankState
        elif self.backend == "jax":
            state_cls = JaxRankState
        else:
            raise ValueError(f"unknown backend {self.backend!r} "
                             f"(expected one of {BACKENDS})")
        for stale in [k for k in self._states
                      if k[0] != self.store.version]:
            del self._states[stale]
            self._state_tags.pop(stale, None)
        state = state_cls(hours, mask, prices, config_ids, job_ids=jobs,
                          metrics=self.metrics)
        self._states[base_key] = state
        self._state_tags[base_key] = tag
        return state.ranking, state.top_k

    def _prune_caches(self, tag: Tuple) -> None:
        # a miss means the tag (or trace) moved on; entries under dead
        # tags or store versions are unreachable forever (epoch, table
        # version and store version are all monotonic) — prune them so
        # out-of-band table.apply + submit cycles don't grow the caches
        # without bound
        for cache in (self._cache, self._head_cache):
            for stale in [k for k in cache
                          if k[:2] != tag or k[2] != self.store.version]:
                del cache[stale]

    def rank_cached(self, job_class: Optional[JobClass] = None,
                    exclude_groups: Sequence[str] = ()
                    ) -> Tuple[Tuple[RankedConfig, ...], bool]:
        """Rank the catalog for a class; returns ``(ranking, from_cache)``.

        The hit/miss fact is returned explicitly (not inferred from
        counter deltas, which misreport under reentrant or concurrent
        ``rank`` calls).  ``from_cache`` is also True when the ranking
        materializes from a live, already-repriced :class:`RankState`
        (the incremental path: no ranking recompute happened).
        """
        base_key = (self.store.version, job_class,
                    tuple(sorted(exclude_groups)))
        tag = self._price_tag()
        key = tag + base_key
        hit = self._cache.get(key)
        if hit is not None:
            self._c_hits.inc()
            return hit, True
        live = self._live_serving(base_key, tag)
        if live is not None:
            # repriced incrementally on the last tick; materialize lazily
            ranking = tuple(live[0]())
            self._cache[key] = ranking
            self._c_hits.inc()
            return ranking, True
        self._c_misses.inc()
        self._prune_caches(tag)
        with self.metrics.span("rank.build"):
            serving = self._build_serving(base_key, tag, job_class,
                                          exclude_groups)
        ranking = tuple(serving[0]())
        self._cache[key] = ranking
        return ranking, False

    def rank_head(self, job_class: Optional[JobClass] = None,
                  exclude_groups: Sequence[str] = (), *, k: int
                  ) -> Tuple[Tuple[RankedConfig, ...], bool]:
        """The top-``k`` head of the ranking for a class; returns
        ``(head, from_cache)`` — the lazy serving path (DESIGN.md §10):
        when only the head is needed, the full C-config ranking is never
        materialized.  A cached full ranking is reused when present
        (its head is free); otherwise the head comes straight off the
        live state's score buffer (``jax.lax.top_k`` on the jax-family
        backends, a partial selection on numpy) and is cached per
        ``(tag, selection, k)``."""
        if not isinstance(k, int) or isinstance(k, bool) or k < 1:
            raise ValueError(f"rank_head needs a positive integer k, "
                             f"got {k!r}")
        base_key = (self.store.version, job_class,
                    tuple(sorted(exclude_groups)))
        tag = self._price_tag()
        key = tag + base_key
        full = self._cache.get(key)
        if full is not None:
            self._c_hits.inc()
            return full[:k], True
        head_key = key + (k,)
        hit = self._head_cache.get(head_key)
        if hit is not None:
            self._c_hits.inc()
            return hit, True
        live = self._live_serving(base_key, tag)
        if live is not None:
            head = tuple(live[1](k))
            self._head_cache[head_key] = head
            self._c_hits.inc()
            return head, True
        self._c_misses.inc()
        self._prune_caches(tag)
        with self.metrics.span("rank.build"):
            serving = self._build_serving(base_key, tag, job_class,
                                          exclude_groups)
        head = tuple(serving[1](k))
        self._head_cache[head_key] = head
        return head, False

    def rank(self, job_class: Optional[JobClass] = None,
             exclude_groups: Sequence[str] = ()
             ) -> Tuple[RankedConfig, ...]:
        """Rank the whole catalog for a class (``None`` = all classes)."""
        return self.rank_cached(job_class, exclude_groups)[0]

    # -- the paper pipeline for one submitted job -----------------------------
    def classify(self, job_id: Hashable,
                 annotation: Optional[JobClass] = None
                 ) -> Optional[JobClass]:
        if annotation is not None:
            return annotation
        if self.classifier is not None:
            return self.classifier(job_id)
        if job_id in self.store.job_ids:
            return self.store.meta(job_id).job_class
        return None

    def effective_exclusions(self, job_id: Hashable,
                             exclude_groups: Optional[Sequence[str]] = None
                             ) -> Tuple[str, ...]:
        """The exclusion set a submission actually ranks under: the
        explicit argument, else the job's own group when the job is
        already profiled (the paper's no-recurrence discipline, §III-A).
        Exposed so journal writers can record the effective set even for
        submissions that never produce a Decision (rejections)."""
        if exclude_groups is not None:
            return tuple(exclude_groups)
        if job_id in self.store.job_ids:
            own = self.store.meta(job_id).group
            if own is not None:
                return (own,)
        return ()

    def submit(self, job_id: Hashable, *,
               annotation: Optional[JobClass] = None,
               exclude_groups: Optional[Sequence[str]] = None,
               one_class: bool = False,
               top_k: Optional[int] = None) -> Decision:
        """Classify, rank under current prices, pick the argmin.

        ``exclude_groups`` defaults to the job's own group when the job is
        already profiled (see :meth:`effective_exclusions`).

        ``top_k`` (default: the service's :attr:`serve_top_k`) switches
        the Decision to head-only serving: its ``ranking`` holds the
        first k entries (``served_via == "top_k"``) and the full sorted
        list is never materialized.  Winner, score and $/h are identical
        to full-ranking serving by construction (DESIGN.md §10).
        """
        klass = None if one_class else self.classify(job_id, annotation)
        exclude_groups = self.effective_exclusions(job_id, exclude_groups)
        k = top_k if top_k is not None else self.serve_top_k
        if k is None:
            ranking, from_cache = self.rank_cached(
                job_class=klass, exclude_groups=tuple(exclude_groups))
            served_via = "ranking"
        else:
            ranking, from_cache = self.rank_head(
                job_class=klass, exclude_groups=tuple(exclude_groups),
                k=k)
            served_via = "top_k"
        winner = ranking[0]
        if winner.score == float("inf"):
            # every catalog entry is unprofiled for this selection
            # (catalog/store id mismatch, or a fully-masked trace) —
            # an arbitrary pick must never look like a decision.
            raise NothingRankableError(
                f"no profiled configurations to rank for job {job_id!r} "
                f"(class {klass})")
        return Decision(
            job_id=job_id, job_class=klass, config_id=winner.config_id,
            entry=self.catalog.entry(winner.config_id),
            hourly_cost=self.catalog.hourly_cost(winner.config_id,
                                                 self._price_source),
            ranking=ranking, from_cache=from_cache,
            price_epoch=self._price_epoch,
            exclude_groups=tuple(exclude_groups),
            served_via=served_via)
