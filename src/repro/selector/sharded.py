"""Multi-device sharded fleet repricing (the ``"jax_sharded"`` backend).

:class:`ShardedBatchedRankState` is :class:`~repro.selector.rank.
BatchedRankState` with the config (C) axis sharded across a 1-D device
mesh via ``jax.experimental.shard_map`` (DESIGN.md §13).  Catalogs of
100k+ configs (multi-region × multi-cloud × spot/on-demand) no longer
need to fit one device: every C-extent buffer — hours, mask, cost,
normalized cost, prices, and the S×C member score accumulators — lives
in contiguous per-device column blocks, and a price tick is ONE
collective dispatch in which each shard replays the familiar delta
step on its own columns, with exactly two cross-device collectives:

* ``lax.psum`` of the per-shard "my row minimum may have moved" flags
  (handoff detection must see every shard's columns), and
* ``lax.pmin`` of the per-shard masked row minima (the global row-min
  that every shard's normalization divides by).

Both collectives combine *exact* values (booleans; an elementwise
float min), so the arithmetic per cell is the same float32 expression
as the single-device batched kernel and the ``jax_batched``
ScoreContract envelope carries over unchanged.

**Serving** keeps the catalog-order tie-break exact without gathering
the score row: each shard runs ``lax.top_k`` over its local columns
(which breaks score ties by lower *local* index), local indices are
lifted to global catalog positions (``shard offset + local index`` —
monotone within a shard, so the within-shard order is already the
global ``(score, catalog position)`` order), and the host merges the
``devices × k_local`` candidates by ``(score, global index)``.  The
merged head is element-wise identical to ``ranking()[:k]``, ties
included, so journals audit unchanged.

**Delta routing**: a tick's changed columns are routed to their owning
shard on the host (owner = column // shard width) and padded to a
power-of-4 bucket *per shard*, so the collective step compiles
O(log C) shape variants exactly like the single-device states.  Shards
with no changed column this tick receive an idempotent no-op pair
(their local column 0 re-set to its current price).

Like the rest of the jax family, importing this module never
initializes a backend; kernels compile on first use, per device count.
"""
from __future__ import annotations

import threading
from typing import (Any, Dict, Hashable, List, Mapping, Optional,
                    Sequence, Tuple, Union)

import numpy as np

from repro.obs import MetricsRegistry, maybe_span

from .rank import (SCORE_CONTRACTS, BackendUnavailableError,
                   NothingRankableError, RankedConfig,
                   _bucket_size, _canonicalize_universe, _check_k,
                   _materialize, _position_index, _validated_deltas,
                   _HAVE_JAX)

if _HAVE_JAX:
    import jax
    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

#: span names the sharded tick emits when a MetricsRegistry is wired in
STEP_SPAN = "shard.step"
MERGE_SPAN = "shard.merge"

# jitted-kernel caches, keyed per device count (the mesh is part of the
# shard_map closure).  k in the top-k kernel is additionally static,
# like the single-device top_k — one compile per (device count, depth).
# Builds run under the lock (double-checked, like the rank.py
# singletons): concurrent first-calls from the serving front-end's
# workers would otherwise build the same mesh kernels twice.
_FNS: "Dict[int, Tuple[Any, Any, Any]]" = {}
_TOPK: "Dict[Tuple[int, int, int], Any]" = {}
_SHARDED_LOCK = threading.Lock()


def _mesh(n_dev: int) -> "Mesh":
    return Mesh(np.asarray(jax.devices()[:n_dev]), ("c",))


def _sharded_fns(n_dev: int) -> Tuple[Any, Any, Any]:
    """``(cold, step, member_scores)`` jitted collective kernels for an
    ``n_dev``-device mesh, built once per device count.

    Per-shard shapes: every C-extent axis holds ``C_pad / n_dev``
    columns; the member axis (S), job axis (J) and row-min vector are
    replicated.  The step is the batched delta step with the row-min
    handoff test and the fresh row minima lifted to collectives — see
    the module docstring for why that preserves the single-device
    arithmetic per cell.
    """
    cached = _FNS.get(n_dev)
    if cached is not None:
        return cached
    with _SHARDED_LOCK:
        cached = _FNS.get(n_dev)
        if cached is not None:
            return cached
        return _build_sharded_fns(n_dev)


def _build_sharded_fns(n_dev: int) -> Tuple[Any, Any, Any]:
    mesh = _mesh(n_dev)
    spec_c = P(None, "c")   # (rows, C_pad) matrices, C sharded
    spec_v = P("c")         # (C_pad,) vectors
    spec_r = P()            # replicated

    def cold_local(hours, mask, prices):
        cost = jnp.where(mask, hours * prices[None, :], jnp.inf)
        row_best = jax.lax.pmin(cost.min(axis=1), "c")
        norm = jnp.where(mask, cost / row_best[:, None], 0.0)
        return cost, row_best, norm

    def step_local(prices, cost, row_best, norm, scores, hours, mask,
                   row_masks, cols, new_prices):
        # the routed delta arrays arrive stacked (n_dev, bucket); each
        # shard sees its own (1, bucket) slice
        cols = cols[0]
        new_prices = new_prices[0]
        # -- local half: identical to _delta_universe_update on this
        #    shard's columns
        sub_mask = mask[:, cols]
        new_cost = jnp.where(sub_mask,
                             hours[:, cols] * new_prices[None, :],
                             jnp.inf)
        old_cost = cost[:, cols]
        prices = prices.at[cols].set(new_prices)
        cost = cost.at[:, cols].set(new_cost)
        was_min = old_cost.min(axis=1) == row_best
        undercut = new_cost.min(axis=1) < row_best
        # -- collective half: a row's minimum may live on any shard, so
        #    the handoff test and the fresh minima are fleet-wide
        need = jax.lax.psum((was_min | undercut).astype(jnp.int32),
                            "c") > 0
        gmin = jax.lax.pmin(cost.min(axis=1), "c")
        fresh = jnp.where(need, gmin, row_best)
        moved = fresh != row_best
        row_best = fresh
        # -- consumer half: same two matmuls as the batched kernel,
        #    each shard refreshing its own score columns
        fresh_rows = jnp.where(mask, cost / row_best[:, None], 0.0)
        col_norm = jnp.where(sub_mask,
                             cost[:, cols] / row_best[:, None], 0.0)
        row_delta = jnp.where(moved[:, None], fresh_rows - norm, 0.0)
        scores = scores + row_masks @ row_delta
        norm = jnp.where(moved[:, None], fresh_rows, norm)
        norm = norm.at[:, cols].set(col_norm)
        scores = scores.at[:, cols].set(row_masks @ col_norm)
        return prices, cost, row_best, norm, scores, moved.sum()

    def member_local(norm, row_mask):
        # a new member's accumulators from the current shared norm
        return row_mask @ norm

    donate = () if jax.default_backend() == "cpu" else (0, 1, 2, 3, 4)
    cold = jax.jit(shard_map(
        cold_local, mesh=mesh,
        in_specs=(spec_c, spec_c, spec_v),
        out_specs=(spec_c, spec_r, spec_c),
        check_rep=False))
    step = jax.jit(shard_map(
        step_local, mesh=mesh,
        in_specs=(spec_v, spec_c, spec_r, spec_c, spec_c, spec_c,
                  spec_c, spec_r, P("c", None), P("c", None)),
        out_specs=(spec_v, spec_c, spec_r, spec_c, spec_c, spec_r),
        check_rep=False), donate_argnums=donate)
    member = jax.jit(shard_map(
        member_local, mesh=mesh,
        in_specs=(spec_c, spec_r),
        out_specs=spec_v,
        check_rep=False))
    _FNS[n_dev] = (cold, step, member)
    return _FNS[n_dev]


def _sharded_topk_fn(n_dev: int, k_loc: int, c_loc: int) -> Any:
    """Per-shard head extraction: each shard top-k's its own columns of
    one member's score row and lifts local indices to global catalog
    positions.  The member slot is a *traced* scalar, so serving a
    different member never recompiles; ``k_loc`` is static like every
    other top-k depth.  Returns the stacked ``(n_dev * k_loc,)``
    candidate ``(global index, score)`` arrays the host merge sorts.
    The shard width ``c_loc`` is baked into the index lift, so it is
    part of the cache key — states over different catalogs sharing a
    device count and depth must not share a kernel."""
    key = (n_dev, k_loc, c_loc)
    cached = _TOPK.get(key)
    if cached is not None:
        return cached
    with _SHARDED_LOCK:
        cached = _TOPK.get(key)
        if cached is not None:
            return cached
        return _build_sharded_topk_fn(key)


def _build_sharded_topk_fn(key: Tuple[int, int, int]) -> Any:
    n_dev, k_loc, c_loc = key
    mesh = _mesh(n_dev)

    def topk_local(scores, finite, slot):
        row = scores[slot]
        masked = jnp.where(finite[slot], row, jnp.inf)
        # ascending rank via negation; lax.top_k breaks ties by lower
        # local index == lower global index within the shard block
        neg, idx = jax.lax.top_k(-masked, k_loc)
        gidx = jax.lax.axis_index("c") * c_loc + idx
        return gidx, -neg

    fn = jax.jit(shard_map(
        topk_local, mesh=mesh,
        in_specs=(P(None, "c"), P(None, "c"), P()),
        out_specs=(P("c"), P("c")),
        check_rep=False))
    _TOPK[key] = fn
    return fn


class ShardedBatchedRankState:
    """A :class:`~repro.selector.rank.BatchedRankState` whose config
    axis is sharded across a 1-D device mesh — one *collective* kernel
    dispatch per tick refreshes every member ranking at catalogs no
    single device holds (DESIGN.md §13).

    The member API is the batched state's: :meth:`add_state` /
    :meth:`retire_state` over slot tables with doubling capacity and
    slot reuse, :meth:`reprice` applying one delta batch fleet-wide,
    :meth:`ranking` / :meth:`top_k` / :meth:`winner` serving per
    member.  ``dispatches`` counts collective dispatches (one per
    tick); ``realloc_count`` counts capacity doublings.

    ``devices`` selects how many local devices to shard over (default:
    all).  ``C`` is padded up to a multiple of the device count with
    unprofiled, never-winning pad columns; all padding is invisible at
    the API surface.

    **Contract** (:data:`SCORE_CONTRACTS` ``["jax_sharded"]``): the
    ``jax_batched`` float32 envelope — the collectives combine exact
    values, so sharding relocates arithmetic without changing it.
    """

    backend = "jax_sharded"
    contract = SCORE_CONTRACTS["jax_sharded"]
    _BUCKET_BASE = 8
    _CAPACITY_BASE = 8

    def __init__(self, hours: np.ndarray, mask: np.ndarray,
                 prices: np.ndarray, config_ids: Sequence[Hashable],
                 job_ids: Optional[Sequence[Hashable]] = None,
                 capacity: Optional[int] = None,
                 devices: Optional[int] = None,
                 metrics: Optional[MetricsRegistry] = None):
        if not _HAVE_JAX:
            raise BackendUnavailableError(
                "ShardedBatchedRankState requires jax; use RankState "
                "(numpy) when it is not installed")
        avail = jax.device_count()
        n_dev = avail if devices is None else int(devices)
        if not 1 <= n_dev <= avail:
            raise ValueError(f"devices={devices!r} not in [1, {avail}] "
                             f"(local device count)")
        self.n_devices = n_dev
        self.config_ids = list(config_ids)
        self.job_ids = list(job_ids) if job_ids is not None else None
        self._metrics = metrics
        self._c_mat = (None if metrics is None
                       else metrics.counter("rank.materializations"))
        hours, mask, prices = _canonicalize_universe(hours, mask, prices,
                                                     self.job_ids)
        self._pos = _position_index(self.config_ids)
        self._job_pos = (None if self.job_ids is None else
                         {j: i for i, j in enumerate(self.job_ids)})
        self._mask = mask                     # host copy: member counts
        self._n_jobs = hours.shape[0]
        n_cfgs = len(self.config_ids)
        # contiguous block layout: shard d owns global columns
        # [d*C_loc, (d+1)*C_loc); the last block may be pure padding
        # tail (mask False -> cost +inf -> never wins, filtered from
        # every head by global index >= C)
        self._c_loc = -(-n_cfgs // n_dev)
        self._c_pad = self._c_loc * n_dev
        pad = self._c_pad - n_cfgs

        self._cold, self._step, self._member_scores = _sharded_fns(n_dev)
        self._mesh_obj = _mesh(n_dev)
        self._spec_c = NamedSharding(self._mesh_obj, P(None, "c"))
        self._spec_v = NamedSharding(self._mesh_obj, P("c"))
        self._spec_r = NamedSharding(self._mesh_obj, P())
        self._spec_d = NamedSharding(self._mesh_obj, P("c", None))

        def padded(x, fill):
            if pad == 0:
                return x
            width = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
            return np.pad(x, width, constant_values=fill)

        hours32 = padded(hours.astype(np.float32), 1.0)
        mask_p = padded(mask, False)
        prices32 = padded(prices.astype(np.float32), 1.0)
        # host float32 mirror of the device price vector: the source of
        # the idempotent no-op pair routed to shards with no delta this
        # tick (must be the *kernel's* float32 quote, so the re-set is
        # an exact no-op on device)
        self._price_mirror = prices32.copy()

        self.d_hours = jax.device_put(hours32, self._spec_c)
        self.d_mask = jax.device_put(mask_p, self._spec_c)
        self.d_prices = jax.device_put(prices32, self._spec_v)
        self.d_cost, self.d_row_best, self.d_norm = self._cold(
            self.d_hours, self.d_mask, self.d_prices)

        cap = self._CAPACITY_BASE if capacity is None else max(1, capacity)
        self._capacity = cap
        self._slots: "dict[Hashable, int]" = {}
        self._retired: "set" = set()
        self._free: List[int] = list(range(cap - 1, -1, -1))
        self.d_row_masks = jax.device_put(
            np.zeros((cap, self._n_jobs), np.float32), self._spec_r)
        self.d_scores = jax.device_put(
            np.zeros((cap, self._c_pad), np.float32), self._spec_c)
        self._counts = np.zeros((cap, n_cfgs), dtype=np.int64)
        self._d_finite = jax.device_put(
            np.zeros((cap, self._c_pad), bool), self._spec_c)
        self.reprices = 0
        #: collective dispatches; one tick == one collective dispatch
        #: regardless of member or device count (the benchmark's
        #: ``one_dispatch_per_tick`` gate reads this).
        self.dispatches = 0
        self.realloc_count = 0
        self.materializations = 0
        self._ranking_memo: "dict[Hashable, Tuple[int, List[RankedConfig]]]" = {}

    # -- member management (same surface as BatchedRankState) ---------------
    def __contains__(self, key: Hashable) -> bool:
        return key in self._slots

    @property
    def n_active(self) -> int:
        """Live member count (what one collective dispatch refreshes)."""
        return len(self._slots)

    def keys(self) -> List[Hashable]:
        return list(self._slots)

    def _slot_of(self, key: Hashable) -> int:
        try:
            return self._slots[key]
        except KeyError:
            if key in self._retired:
                raise NothingRankableError(
                    f"member state {key!r} was retired")
            raise ValueError(f"unknown member state {key!r}")

    def _grow(self) -> None:
        cap = self._capacity * 2
        row_masks = np.zeros((cap, self._n_jobs), np.float32)
        row_masks[:self._capacity] = np.asarray(self.d_row_masks)
        scores = np.zeros((cap, self._c_pad), np.float32)
        scores[:self._capacity] = np.asarray(self.d_scores)
        finite = np.zeros((cap, self._c_pad), bool)
        finite[:self._capacity] = np.asarray(self._d_finite)
        self.d_row_masks = jax.device_put(row_masks, self._spec_r)
        self.d_scores = jax.device_put(scores, self._spec_c)
        self._d_finite = jax.device_put(finite, self._spec_c)
        counts = np.zeros((cap, len(self.config_ids)), dtype=np.int64)
        counts[:self._capacity] = self._counts
        self._counts = counts
        self._free.extend(range(cap - 1, self._capacity - 1, -1))
        self._capacity = cap
        self.realloc_count += 1

    def _rows_of(self, rows: Optional[Sequence[int]],
                 jobs: Optional[Sequence[Hashable]]) -> np.ndarray:
        if (rows is None) == (jobs is None):
            raise ValueError("pass exactly one of rows= or jobs=")
        if jobs is not None:
            if self._job_pos is None:
                raise ValueError(
                    "jobs= needs a state constructed with job_ids")
            try:
                rows = [self._job_pos[j] for j in jobs]
            except KeyError as e:
                raise ValueError(f"unknown job id {e.args[0]!r}")
        idx = np.asarray(list(rows), dtype=np.intp)
        if idx.size and (idx.min() < 0 or idx.max() >= self._n_jobs):
            raise ValueError(f"row index out of range for "
                             f"{self._n_jobs} jobs")
        if np.unique(idx).size != idx.size:
            raise ValueError("duplicate rows in member selection")
        return idx

    def add_state(self, key: Hashable, *,
                  rows: Optional[Sequence[int]] = None,
                  jobs: Optional[Sequence[Hashable]] = None) -> None:
        """Register a member ranking over a subset of the job axis; its
        accumulators come from the *current* shared (sharded) norm, so
        a member added mid-stream is in sync with every tick so far.
        Retired slots are reused before capacity grows."""
        if key in self._slots:
            raise ValueError(f"duplicate member state {key!r}")
        self._retired.discard(key)      # re-registering revives the key
        idx = self._rows_of(rows, jobs)
        if not self._free:
            self._grow()
        slot = self._free.pop()
        row_mask = np.zeros(self._n_jobs, dtype=np.float32)
        row_mask[idx] = 1.0
        counts = self._mask[idx].sum(axis=0) if idx.size else \
            np.zeros(len(self.config_ids), dtype=np.int64)
        d_row = jax.device_put(row_mask, self._spec_r)
        member_row = self._member_scores(self.d_norm, d_row)
        self.d_row_masks = jax.device_put(
            self.d_row_masks.at[slot].set(d_row), self._spec_r)
        self.d_scores = jax.device_put(
            self.d_scores.at[slot].set(member_row), self._spec_c)
        self._counts[slot] = counts
        finite = np.zeros(self._c_pad, bool)
        finite[:len(self.config_ids)] = counts > 0
        self._d_finite = jax.device_put(
            self._d_finite.at[slot].set(jax.device_put(
                finite, self._spec_v)), self._spec_c)
        self._slots[key] = slot

    def retire_state(self, key: Hashable) -> None:
        """Drop a member: its slot is zero-masked and reused by the
        next :meth:`add_state`; serving it afterwards raises
        :class:`NothingRankableError` (same semantics as the
        single-device batched state)."""
        slot = self._slots.pop(key, None)
        if slot is None:
            raise ValueError(f"unknown member state {key!r}")
        self.d_row_masks = jax.device_put(
            self.d_row_masks.at[slot].set(
                jnp.zeros(self._n_jobs, jnp.float32)), self._spec_r)
        self.d_scores = jax.device_put(
            self.d_scores.at[slot].set(jax.device_put(
                np.zeros(self._c_pad, np.float32), self._spec_v)),
            self._spec_c)
        self._counts[slot] = 0
        self._d_finite = jax.device_put(
            self._d_finite.at[slot].set(jax.device_put(
                np.zeros(self._c_pad, bool), self._spec_v)),
            self._spec_c)
        self._ranking_memo.pop(key, None)
        self._retired.add(key)
        self._free.append(slot)

    # -- the collective tick ------------------------------------------------
    @property
    def prices(self) -> np.ndarray:
        """Current per-config $/h as seen by the kernel (float32 quotes
        lifted to a host float64 vector; padding dropped)."""
        return np.asarray(self.d_prices,
                          dtype=np.float64)[:len(self.config_ids)]

    def scores(self, key: Hashable) -> np.ndarray:
        """A member's score accumulators on the host (float64 lift;
        padding dropped)."""
        return np.asarray(self.d_scores[self._slot_of(key)],
                          dtype=np.float64)[:len(self.config_ids)]

    def counts(self, key: Hashable) -> np.ndarray:
        """A member's per-config contributing-cell counts."""
        return self._counts[self._slot_of(key)].copy()

    def _route_deltas(self, cols: np.ndarray, new_prices: np.ndarray
                      ) -> Tuple[np.ndarray, np.ndarray]:
        """Host-side shard routing: owner = column // shard width,
        local index = column % shard width; each shard's batch is
        padded to the shared power-of-4 bucket by repeating its first
        (column, price) pair (idempotent under the kernel's ``.set``).
        A shard with no delta this tick gets its local column 0 re-set
        to the current float32 quote — an exact device no-op."""
        n_dev, c_loc = self.n_devices, self._c_loc
        owner = cols // c_loc
        local = (cols % c_loc).astype(np.int32)
        per = [np.flatnonzero(owner == d) for d in range(n_dev)]
        bucket = _bucket_size(max(1, max(len(p) for p in per)),
                              self._BUCKET_BASE)
        cols_sh = np.zeros((n_dev, bucket), np.int32)
        newp_sh = np.empty((n_dev, bucket), np.float32)
        for d, idx in enumerate(per):
            if len(idx):
                n = len(idx)
                cols_sh[d, :n] = local[idx]
                newp_sh[d, :n] = new_prices[idx]
                cols_sh[d, n:] = local[idx[0]]
                newp_sh[d, n:] = new_prices[idx[0]]
            else:
                newp_sh[d, :] = self._price_mirror[d * c_loc]
        # keep the mirror current *after* building the no-op pads
        self._price_mirror[cols] = new_prices.astype(np.float32)
        return cols_sh, newp_sh

    def reprice(self, deltas: Union[Mapping[Hashable, float],
                                    Sequence[Tuple[Hashable, float]]]
                ) -> int:
        """Apply ``{config_id: new $/h}`` deltas to the sharded
        universe and refresh **every** member's accumulators in one
        collective dispatch; returns #rows whose masked row-minimum
        handed off (synced to host, so a return means the tick's
        collective has completed on every device)."""
        validated = _validated_deltas(self._pos, deltas)
        if validated is None:
            return 0
        cols, new_prices = validated
        with maybe_span(self._metrics, STEP_SPAN):
            cols_sh, newp_sh = self._route_deltas(cols, new_prices)
            (self.d_prices, self.d_cost, self.d_row_best, self.d_norm,
             self.d_scores, moved) = self._step(
                self.d_prices, self.d_cost, self.d_row_best,
                self.d_norm, self.d_scores, self.d_hours, self.d_mask,
                self.d_row_masks,
                jax.device_put(cols_sh, self._spec_d),
                jax.device_put(newp_sh, self._spec_d))
            moved = int(moved)
        self.reprices += 1
        self.dispatches += 1
        return moved

    # -- per-member serving -------------------------------------------------
    def ranking(self, key: Hashable) -> List[RankedConfig]:
        """A member's full sorted ranking under the tolerance contract
        (memoized on the tick count; a fresh list copy per call)."""
        memo = self._ranking_memo.get(key)
        if memo is None or memo[0] != self.reprices:
            slot = self._slot_of(key)
            self.materializations += 1
            if self._c_mat is not None:
                self._c_mat.inc()
            with maybe_span(self._metrics, "rank.materialize"):
                memo = (self.reprices,
                        _materialize(self.scores(key),
                                     self._counts[slot],
                                     self.config_ids))
            self._ranking_memo[key] = memo
        return list(memo[1])

    def top_k(self, key: Hashable, k: int) -> List[RankedConfig]:
        """The head of a member's ranking via per-shard ``lax.top_k``
        plus a deterministic host merge by ``(score, global index)`` —
        element-wise identical to ``ranking(key)[:k]``, ties included
        (DESIGN.md §13 has the argument).

        k is clamped to the catalog size *before* the jitted kernel
        (`k > C` is a serving convenience, never a crash or a
        recompile storm); the per-shard depth is further clamped to
        the shard width, which still guarantees >= k real candidates
        after the merge."""
        slot = self._slot_of(key)
        n_cfgs = len(self.config_ids)
        k = _check_k(k, n_cfgs)
        k_loc = min(k, self._c_loc)
        fn = _sharded_topk_fn(self.n_devices, k_loc, self._c_loc)
        gidx, vals = fn(self.d_scores, self._d_finite,
                        jnp.asarray(slot, dtype=jnp.int32))
        with maybe_span(self._metrics, MERGE_SPAN):
            gidx = np.asarray(gidx)
            vals = np.asarray(vals, dtype=np.float64)
            keep = gidx < n_cfgs           # drop pad-tail candidates
            gidx, vals = gidx[keep], vals[keep]
            order = np.lexsort((gidx, vals))[:k]
        counts = self._counts[slot]
        out = []
        for j in order:
            i = int(gidx[j])
            n = int(counts[i])
            out.append(RankedConfig(
                self.config_ids[i],
                float(vals[j]) if n else float("inf"),
                float(vals[j]) / n if n else float("inf")))
        return out

    def winner(self, key: Hashable) -> RankedConfig:
        """The member's top pick — ``top_k(key, 1)`` on device."""
        return self.top_k(key, 1)[0]
