"""Unified, substrate-agnostic resource selection (the Flora pipeline).

This package is the single public API for cloud/accelerator resource
selection.  The paper's insight — normalized-cost ranking over a profiling
trace is substrate-agnostic (§II) — is realised as four layers:

  catalog  -- :class:`ResourceCatalog`: the ordered universe of selectable
              configurations (GCP VM clusters, TPU slices, ...), each with
              an id, resource totals and an hourly cost under a price source;
  store    -- :class:`ProfilingStore`: dense (job x config) runtime-hours
              matrices with incremental insert, partial-profiling masks and
              versioned JSONL persistence;
  rank     -- :func:`rank_dense`: the vectorized normalized-cost ranking
              (runtime matrix x price vector, row-normalize, column-sum);
              :class:`RankState` keeps the intermediates alive for
              incremental repricing under streaming price deltas;
  service  -- :class:`SelectionService`: ``submit(job, annotation) ->
              Decision`` with per-(class, price-epoch) ranking caches and
              ``reprice(deltas)`` for live :class:`PriceTable` sources.

The live-market layer on top of this package — streaming price feeds,
the tick loop, the continuous selection daemon and the migration advisor
— lives in :mod:`repro.market` (DESIGN.md §6).

The legacy entry points (:class:`repro.core.flora.Flora`,
:class:`repro.core.tpu_flora.TpuFlora`) remain as thin adapters over this
package; new substrates should implement :class:`ResourceCatalog` directly.
See DESIGN.md for the full architecture.
"""
from repro.selector.catalog import (BaseCatalog, GcpVmCatalog,
                                    IdentityCatalog, PriceTable,
                                    ResourceCatalog, TpuSliceCatalog)
from repro.selector.rank import (BACKEND_ENV_VAR, BACKENDS,
                                 FLEET_BACKENDS,
                                 BackendUnavailableError, BatchedRankState,
                                 JaxRankState, NothingRankableError,
                                 RankedConfig, RankState, SCORE_CONTRACTS,
                                 ScoreContract, backend_available,
                                 default_backend, rank_dense, rank_pairs,
                                 score_contract)
from repro.selector.pallas_rank import PallasBatchedRankState
from repro.selector.sharded import ShardedBatchedRankState
from repro.selector.store import ProfilingStore
from repro.selector.service import Decision, SelectionService

__all__ = [
    "BACKEND_ENV_VAR", "BACKENDS", "BackendUnavailableError", "BaseCatalog",
    "BatchedRankState", "Decision", "FLEET_BACKENDS", "GcpVmCatalog",
    "IdentityCatalog", "JaxRankState",
    "NothingRankableError", "PallasBatchedRankState", "PriceTable",
    "ProfilingStore", "RankState",
    "RankedConfig", "ResourceCatalog", "SCORE_CONTRACTS", "ScoreContract",
    "SelectionService", "ShardedBatchedRankState", "TpuSliceCatalog",
    "backend_available",
    "default_backend", "rank_dense", "rank_pairs", "score_contract",
]
