"""Fleet repricing through the fused Pallas delta-rank kernel
(the ``jax_pallas`` backend).

:class:`PallasBatchedRankState` serves the same fleet API as
:class:`~repro.selector.rank.BatchedRankState` — member slots,
one dispatch per tick, per-member serving — but the tick itself is ONE
``pl.pallas_call`` (:mod:`repro.kernels.rank_delta`) instead of the
two-matmul + separate mask/min/norm XLA sequence.  The resident
universe shrinks accordingly (DESIGN.md §14): no cost or norm matrix
lives on device — both are recomputed in-stream from the read-only
``hours``/``mask`` residents and the price vector, which float32 IEEE
elementwise ops make bit-identical to what a stored matrix would hold.
Per-tick state is the price vector, the masked row minima and the
member score accumulators.

Two structural differences from the XLA delta path, both
simplifications:

* **no delta bucketing** — the kernel streams the whole universe every
  tick anyway, so deltas arrive as a dense ``(1, C)`` price vector plus
  a changed-column mask: one compiled shape total (vs O(log C)
  buckets), and duplicate deltas are idempotent *by construction*
  rather than by ``.set`` semantics;
* **padded job axis** — J is padded host-side to the tile size with
  ``mask=False`` rows (invisible: masked cells normalize to 0 and an
  all-``inf`` row minimum never registers as a handoff), so the kernel
  grid divides evenly.

The contract story carries over unchanged: ``jax_pallas`` registers
the same float32 tolerance envelope as the jax family
(:data:`~repro.selector.rank.SCORE_CONTRACTS`), so journals written
under it replay through the unmodified ``JournalReplayer.audit``
tolerance mode.

:meth:`PallasBatchedRankState.reprice_with_heads` exposes the fused
reprice+top-k variant — the tick *and* every member's k-head in a
single kernel launch (single C tile only).
"""
from __future__ import annotations

import threading
from typing import Any, Dict, Hashable, List, Mapping, Optional, \
    Sequence, Tuple, Union

import numpy as np

from repro.selector.rank import (
    _HAVE_JAX,
    BackendUnavailableError,
    BatchedRankState,
    RankedConfig,
    SCORE_CONTRACTS,
    _canonicalize_universe,
    _check_k,
    _position_index,
    _validated_deltas,
)
from repro.obs import MetricsRegistry

if _HAVE_JAX:
    import jax
    import jax.numpy as jnp

    from repro.kernels.rank_delta import fused_reprice, fused_reprice_heads

__all__ = ["PallasBatchedRankState"]


if _HAVE_JAX:
    # small off-hot-path helpers (cold row minima, a new member's
    # accumulators), jitted once under a lock — the same double-checked
    # discipline as the rank.py singletons and rank_delta_fns()
    _HELPER_FNS: Optional[Tuple[Any, Any]] = None
    _HELPER_LOCK = threading.Lock()

    def _helper_fns() -> Tuple[Any, Any]:
        global _HELPER_FNS
        if _HELPER_FNS is None:
            with _HELPER_LOCK:
                if _HELPER_FNS is None:
                    def cold_row_best(hours, mask, prices):
                        cost = jnp.where(mask, hours * prices, jnp.inf)
                        return jnp.min(cost, axis=1, keepdims=True)

                    def member_scores(hours, mask, prices, row_best,
                                      row_mask):
                        # the member's accumulators from the *implied*
                        # norm matrix — recomputed exactly as the fused
                        # kernel recomputes it in-stream
                        norm = jnp.where(mask, (hours * prices) / row_best,
                                         0.0)
                        return row_mask @ norm

                    _HELPER_FNS = (jax.jit(cold_row_best),
                                   jax.jit(member_scores))
        return _HELPER_FNS


class PallasBatchedRankState(BatchedRankState):
    """One *fused-kernel* dispatch per tick for a whole fleet.

    Drop-in for :class:`~repro.selector.rank.BatchedRankState` (same
    member management, serving and validation surface — inherited), but
    :meth:`reprice` runs :func:`repro.kernels.rank_delta.fused_reprice`
    and the resident universe is the reduced set described in the
    module docstring.  ``block_j``/``block_c`` pick the kernel tiling
    (defaults: 8-row job tiles, a single C tile); the job axis is
    padded to a ``block_j`` multiple with masked-off rows.

    **Contract** (:data:`SCORE_CONTRACTS` ``["jax_pallas"]``): the jax
    float32 tolerance envelope.  The fused kernel's changed-column
    re-reductions and unchanged-column delta folds reorder float32 sums
    relative to the XLA path, which is exactly the drift source the
    rel/abs tolerances already cover — and a tick with no handoffs is
    drift-free here for the same exact-zero reason (DESIGN.md §14).
    """

    backend = "jax_pallas"
    contract = SCORE_CONTRACTS["jax_pallas"]
    _BLOCK_J = 8

    def __init__(self, hours: np.ndarray, mask: np.ndarray,
                 prices: np.ndarray, config_ids: Sequence[Hashable],
                 job_ids: Optional[Sequence[Hashable]] = None,
                 capacity: Optional[int] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 block_j: Optional[int] = None,
                 block_c: Optional[int] = None):
        if not _HAVE_JAX:
            raise BackendUnavailableError(
                "PallasBatchedRankState requires jax; use RankState "
                "(numpy) when it is not installed")
        self.config_ids = list(config_ids)
        self.job_ids = list(job_ids) if job_ids is not None else None
        self._metrics = metrics
        self._c_mat = (None if metrics is None
                       else metrics.counter("rank.materializations"))
        hours, mask, prices = _canonicalize_universe(hours, mask, prices,
                                                     self.job_ids)
        self._pos = _position_index(self.config_ids)
        self._job_pos = (None if self.job_ids is None else
                         {j: i for i, j in enumerate(self.job_ids)})
        self._mask = mask                     # host copy: member counts
        n_cfgs = len(self.config_ids)
        #: true (unpadded) job count — what ``rows=`` validates against
        self._n_true_jobs = hours.shape[0]
        self._block_j = self._BLOCK_J if block_j is None else block_j
        self._block_c = n_cfgs if block_c is None else block_c
        # pad the job axis to a block_j multiple with invisible rows:
        # mask=False everywhere, so their cells normalize to 0 and the
        # all-inf row minimum can never register as a handoff
        pad = (-self._n_true_jobs) % self._block_j
        if pad:
            hours = np.concatenate(
                [hours, np.ones((pad, n_cfgs), hours.dtype)])
            mask = np.concatenate(
                [mask, np.zeros((pad, n_cfgs), bool)])
        #: padded job count — the kernel-facing row axis (the inherited
        #: slot machinery sizes row masks off ``_n_jobs``)
        self._n_jobs = hours.shape[0]
        # read-only residents (uploaded once)
        self.d_hours = jnp.asarray(hours, dtype=jnp.float32)
        self.d_mask = jnp.asarray(mask)
        # per-tick resident state: prices, row minima, accumulators —
        # no cost/norm matrix (recomputed in-stream, DESIGN.md §14).
        # The host float32 price mirror builds each tick's dense price
        # vector without a device readback; float32 so host and device
        # quotes can never disagree by a rounding.
        self._host_prices = np.asarray(prices,
                                       dtype=np.float32).reshape(1, -1)
        self.d_prices = jnp.asarray(self._host_prices)
        self.d_row_best = _helper_fns()[0](self.d_hours, self.d_mask,
                                           self.d_prices)
        # the member axis: slot tables + batched accumulators (the
        # inherited add/retire/grow machinery manages these)
        cap = self._CAPACITY_BASE if capacity is None else max(1, capacity)
        self._capacity = cap
        self._slots: "dict[Hashable, int]" = {}
        self._retired: "set" = set()
        self._free: List[int] = list(range(cap - 1, -1, -1))
        self.d_row_masks = jnp.zeros((cap, self._n_jobs),
                                     dtype=jnp.float32)
        self.d_scores = jnp.zeros((cap, n_cfgs), dtype=jnp.float32)
        self._counts = np.zeros((cap, n_cfgs), dtype=np.int64)
        self._d_finite = jnp.zeros((cap, n_cfgs), dtype=bool)
        self.reprices = 0
        self.dispatches = 0
        self.realloc_count = 0
        self.materializations = 0
        self._ranking_memo: "dict[Hashable, Tuple[int, List[RankedConfig]]]" = {}

    # -- member management (only the pieces the padding touches) ------------
    def _rows_of(self, rows, jobs) -> np.ndarray:
        if (rows is None) == (jobs is None):
            raise ValueError("pass exactly one of rows= or jobs=")
        if jobs is not None:
            if self._job_pos is None:
                raise ValueError(
                    "jobs= needs a state constructed with job_ids")
            try:
                rows = [self._job_pos[j] for j in jobs]
            except KeyError as e:
                raise ValueError(f"unknown job id {e.args[0]!r}")
        idx = np.asarray(list(rows), dtype=np.intp)
        # validate against the TRUE job count — the padded rows are a
        # kernel-tiling artifact, never addressable by members
        if idx.size and (idx.min() < 0 or idx.max() >= self._n_true_jobs):
            raise ValueError(f"row index out of range for "
                             f"{self._n_true_jobs} jobs")
        if np.unique(idx).size != idx.size:
            raise ValueError("duplicate rows in member selection")
        return idx

    def add_state(self, key: Hashable, *,
                  rows: Optional[Sequence[int]] = None,
                  jobs: Optional[Sequence[Hashable]] = None) -> None:
        """Register a member ranking over a subset of the job axis; the
        accumulators come from the *implied* current norm matrix
        (recomputed from the residents exactly as the kernel streams
        it), so a mid-stream add is immediately in sync."""
        if key in self._slots:
            raise ValueError(f"duplicate member state {key!r}")
        self._retired.discard(key)
        idx = self._rows_of(rows, jobs)
        if not self._free:
            self._grow()
        slot = self._free.pop()
        row_mask = np.zeros(self._n_jobs, dtype=np.float32)
        row_mask[idx] = 1.0
        counts = self._mask[idx].sum(axis=0) if idx.size else \
            np.zeros(len(self.config_ids), dtype=np.int64)
        d_row = jnp.asarray(row_mask)
        self.d_row_masks = self.d_row_masks.at[slot].set(d_row)
        self.d_scores = self.d_scores.at[slot].set(
            _helper_fns()[1](self.d_hours, self.d_mask, self.d_prices,
                             self.d_row_best, d_row))
        self._counts[slot] = counts
        self._d_finite = self._d_finite.at[slot].set(
            jnp.asarray(counts > 0))
        self._slots[key] = slot

    # -- the fused tick -----------------------------------------------------
    @property
    def prices(self) -> np.ndarray:
        """Current per-config $/h (float32 quotes lifted to float64)."""
        return self._host_prices[0].astype(np.float64)

    def _dense_tick(self, deltas) -> Optional[Tuple[np.ndarray,
                                                    np.ndarray]]:
        """Validate a delta batch and densify it: the fused kernel takes
        the full ``(1, C)`` new-price vector plus a changed-column mask
        (one compiled shape; duplicates idempotent by construction)."""
        validated = _validated_deltas(self._pos, deltas)
        if validated is None:
            return None
        cols, new_prices = validated
        newp = self._host_prices.copy()
        newp[0, cols] = new_prices.astype(np.float32)
        changed = np.zeros_like(newp)
        changed[0, cols] = 1.0
        return newp, changed

    def reprice(self, deltas: Union[Mapping[Hashable, float],
                                    Sequence[Tuple[Hashable, float]]]
                ) -> int:
        """Apply ``{config_id: new $/h}`` deltas with ONE fused Pallas
        kernel launch refreshing every member; returns #rows whose
        masked row-minimum handed off (synced to host, so a return
        means the tick's kernel has completed)."""
        dense = self._dense_tick(deltas)
        if dense is None:
            return 0
        newp, changed = dense
        d_newp = jnp.asarray(newp)
        self.d_scores, self.d_row_best, moved = fused_reprice(
            self.d_hours, self.d_mask, self.d_prices, d_newp,
            jnp.asarray(changed), self.d_row_best, self.d_row_masks,
            self.d_scores, block_j=self._block_j, block_c=self._block_c)
        self.d_prices = d_newp
        self._host_prices = newp
        self.reprices += 1
        self.dispatches += 1
        return int(np.asarray(moved)[0, 0])

    def reprice_with_heads(self, deltas: Union[Mapping[Hashable, float],
                                               Sequence[Tuple[Hashable,
                                                              float]]],
                           k: int
                           ) -> Tuple[int, Dict[Hashable,
                                                List[RankedConfig]]]:
        """The fused reprice+top-k tick: apply the deltas AND serve
        every live member's ``k``-head from the same single kernel
        launch (``(moved, {key: [RankedConfig]})``).  Requires the
        single-C-tile layout (``block_c == C``); an empty delta batch
        degrades to plain :meth:`top_k` serving with no dispatch."""
        k = _check_k(k, len(self.config_ids))
        dense = self._dense_tick(deltas)
        if dense is None:
            return 0, {key: self.top_k(key, k) for key in self._slots}
        newp, changed = dense
        d_newp = jnp.asarray(newp)
        (self.d_scores, self.d_row_best, moved,
         ti, tv) = fused_reprice_heads(
            self.d_hours, self.d_mask, self.d_prices, d_newp,
            jnp.asarray(changed), self.d_row_best, self.d_row_masks,
            self.d_scores, self._d_finite, block_j=self._block_j,
            block_c=self._block_c, k=k)
        self.d_prices = d_newp
        self._host_prices = newp
        self.reprices += 1
        self.dispatches += 1
        ti_h = np.asarray(ti)
        tv_h = np.asarray(tv, dtype=np.float64)
        heads: Dict[Hashable, List[RankedConfig]] = {}
        for key, slot in self._slots.items():
            counts = self._counts[slot]
            out = []
            for i, s in zip(ti_h[slot], tv_h[slot]):
                n = int(counts[i])
                out.append(RankedConfig(
                    self.config_ids[int(i)],
                    float(s) if n else float("inf"),
                    float(s) / n if n else float("inf")))
            heads[key] = out
        return int(np.asarray(moved)[0, 0]), heads
