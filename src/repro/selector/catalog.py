"""Resource catalogs: the selectable-configuration universe per substrate.

A :class:`ResourceCatalog` is an *ordered* collection of selectable
configurations.  Order matters: it is the deterministic tie-break of the
ranking, and it fixes the column order of every runtime/price matrix the
selector builds.  Each entry exposes

  * a hashable ``id`` (the paper's config index, a mesh name, ...),
  * resource totals (``describe``) for capacity-style baselines, and
  * an ``hourly_cost`` under the *current* price source (§II-D: prices are
    applied at selection time, never baked into the trace).

Two implementations ship here — GCP VM clusters (paper Table II) and TPU
slices (DESIGN.md §3) — but anything with ids and prices fits: GPU fleets,
spot markets, on-prem partitions.
"""
from __future__ import annotations

from typing import (Any, Dict, Hashable, Iterable, Iterator, List, Mapping,
                    Optional, Protocol, Sequence, Tuple, runtime_checkable)

import numpy as np

from repro.core.costmodel import LinearPriceModel, TpuPriceModel
from repro.core.trace import CloudConfig


class PriceTable:
    """Mutable per-entry $/h quotes — the live-market price source.

    Model-based sources (:class:`LinearPriceModel`, :class:`TpuPriceModel`)
    derive an entry's price from its resources; a ``PriceTable`` instead
    holds one *current* quote per entry id, so a streaming market feed can
    move a single spot price without touching the rest of the universe
    (DESIGN.md §6).  Every :class:`BaseCatalog` resolves it transparently
    via :meth:`BaseCatalog.hourly_cost`.

    Mutation goes through :meth:`apply` (absolute re-quotes, never
    relative), which bumps :attr:`version`.  ``SelectionService`` keys
    its ranking caches on that version, so quotes applied directly to a
    service-owned table are never masked by a stale cached ranking —
    they force a cold recompute; routing them through
    ``SelectionService.reprice`` instead gets the incremental path.
    """

    def __init__(self, prices: Mapping[Hashable, float]):
        #: bumped on every :meth:`apply` (consumers key caches on it).
        self.version = 0
        self._prices: Dict[Hashable, float] = self._validated(prices)

    @classmethod
    def from_catalog(cls, catalog: "BaseCatalog",
                     price_source: Optional[Any] = None) -> "PriceTable":
        """Snapshot a catalog's current prices as the mutable base quotes."""
        return cls({e: catalog.hourly_cost(e, price_source)
                    for e in catalog.ids()})

    @staticmethod
    def _validated(prices: Mapping[Hashable, float]) -> Dict[Hashable, float]:
        out: Dict[Hashable, float] = {}
        for entry_id, price in prices.items():
            if not price > 0:
                raise ValueError(
                    f"non-positive price {price!r} for {entry_id!r}")
            out[entry_id] = float(price)
        return out

    def apply(self, deltas: Mapping[Hashable, float]) -> None:
        """Apply absolute re-quotes ``{entry_id: new $/h}``; one epoch.

        All-or-nothing: the whole batch is validated before any entry is
        assigned, so a bad quote can never leave the table (and its
        version) half-updated against version-keyed ranking caches.
        """
        if not deltas:
            return
        self._prices.update(self._validated(deltas))
        self.version += 1

    def __getitem__(self, entry_id: Hashable) -> float:
        return self._prices[entry_id]

    def __contains__(self, entry_id: Hashable) -> bool:
        return entry_id in self._prices

    def __len__(self) -> int:
        return len(self._prices)

    def __iter__(self) -> Iterator[Hashable]:
        return iter(self._prices)

    def items(self) -> Iterable[Tuple[Hashable, float]]:
        return self._prices.items()


@runtime_checkable
class ResourceCatalog(Protocol):
    """Substrate-agnostic view of the selectable configurations."""

    def ids(self) -> Sequence[Hashable]:
        """Stable, ordered entry ids (ranking tie-break order)."""
        ...

    def entry(self, entry_id: Hashable) -> Any:
        """The native configuration object behind ``entry_id``."""
        ...

    def describe(self, entry_id: Hashable) -> Mapping[str, float]:
        """Resource totals, e.g. ``{"cores": 64, "mem_gib": 256}``."""
        ...

    def hourly_cost(self, entry_id: Hashable,
                    price_source: Optional[Any] = None) -> float:
        """Current $/h for the entry under ``price_source`` (or the
        catalog's default)."""
        ...


class BaseCatalog:
    """Shared plumbing: ordered id index + vectorized price lookup."""

    def __init__(self, entry_ids: Sequence[Hashable],
                 default_price_source: Optional[Any] = None):
        self._ids: List[Hashable] = list(entry_ids)
        if len(set(self._ids)) != len(self._ids):
            raise ValueError("duplicate catalog entry ids")
        self._pos = {e: i for i, e in enumerate(self._ids)}
        self.default_price_source = default_price_source

    def ids(self) -> Sequence[Hashable]:
        return list(self._ids)

    def __len__(self) -> int:
        return len(self._ids)

    def __contains__(self, entry_id: Hashable) -> bool:
        return entry_id in self._pos

    def position(self, entry_id: Hashable) -> int:
        return self._pos[entry_id]

    def _price(self, price_source: Optional[Any]) -> Any:
        src = price_source if price_source is not None \
            else self.default_price_source
        if src is None:
            raise ValueError("no price source given and no catalog default")
        return src

    def price_vector(self, price_source: Optional[Any] = None) -> np.ndarray:
        """$/h for every entry, aligned with :meth:`ids` (float64)."""
        src = self._price(price_source)
        return np.asarray([self.hourly_cost(e, src) for e in self._ids],
                          dtype=np.float64)

    def hourly_cost(self, entry_id: Hashable,
                    price_source: Optional[Any] = None) -> float:
        """Current $/h: a :class:`PriceTable` source is resolved directly
        (live-market quotes); anything else goes through the substrate's
        :meth:`_entry_cost` model."""
        src = self._price(price_source)
        if isinstance(src, PriceTable):
            return src[entry_id]
        return self._entry_cost(entry_id, src)

    # subclass responsibility
    def entry(self, entry_id: Hashable) -> Any:
        raise NotImplementedError

    def describe(self, entry_id: Hashable) -> Mapping[str, float]:
        raise NotImplementedError

    def _entry_cost(self, entry_id: Hashable, price_source: Any) -> float:
        """Model-based $/h for ``entry_id`` under a resolved source."""
        raise NotImplementedError


class IdentityCatalog(BaseCatalog):
    """Entries are their own ids; pricing comes from the price source
    (typically a :class:`PriceTable`).  The minimal catalog for synthetic
    universes — benchmarks, replay harnesses, property tests."""

    def entry(self, entry_id: Hashable) -> Hashable:
        return entry_id

    def describe(self, entry_id: Hashable) -> Mapping[str, float]:
        return {}


class GcpVmCatalog(BaseCatalog):
    """GCP VM cluster configurations (paper Table II) priced per resource."""

    def __init__(self, configs: Sequence[CloudConfig],
                 price: Optional[LinearPriceModel] = None):
        super().__init__([c.index for c in configs],
                         default_price_source=price)
        self._configs = {c.index: c for c in configs}

    def entry(self, entry_id: Hashable) -> CloudConfig:
        return self._configs[entry_id]

    def describe(self, entry_id: Hashable) -> Mapping[str, float]:
        c = self._configs[entry_id]
        return {"cores": float(c.total_cores),
                "mem_gib": float(c.total_mem_gib),
                "nodes": float(c.scale_out)}

    def _entry_cost(self, entry_id: Hashable,
                    price_source: LinearPriceModel) -> float:
        return price_source(self._configs[entry_id])


class TpuSliceCatalog(BaseCatalog):
    """TPU slice x mesh-split options priced per chip-hour (DESIGN.md §3).

    Entries are duck-typed :class:`repro.core.tpu_flora.MeshOption`-likes:
    anything with ``.name``, ``.chips`` and ``.hourly_cost(price_model)``.
    """

    def __init__(self, options: Sequence[Any],
                 price: Optional[TpuPriceModel] = None):
        super().__init__([o.name for o in options],
                         default_price_source=price)
        self._options = {o.name: o for o in options}

    def entry(self, entry_id: Hashable) -> Any:
        return self._options[entry_id]

    def describe(self, entry_id: Hashable) -> Mapping[str, float]:
        o = self._options[entry_id]
        return {"chips": float(o.chips)}

    def _entry_cost(self, entry_id: Hashable,
                    price_source: TpuPriceModel) -> float:
        return self._options[entry_id].hourly_cost(price_source)
