"""Vectorized normalized-cost ranking (paper §II, step 2).

The ranking is one matrix computation instead of a per-pair dict loop:

    cost   = runtime_hours (J x C)  *  price_vector (C,)     # broadcast
    norm   = cost / row-min(cost over profiled cells)        # row-normalize
    score  = column-sum of norm over profiled cells          # per config

A config with **zero** profiled cells scores ``+inf`` and therefore ranks
last (an unprofiled config must never win by default — the historical dict
loop left it at 0.0, i.e. argmin).

Two backends:

  * ``"numpy"`` (default): float64, bit-stable with the historical
    per-pair arithmetic — used for the paper-table reproductions;
  * ``"jax"``: a jitted ``jax.numpy`` kernel (float32 on CPU/TPU) that
    fuses the whole ranking into one XLA computation — the serving-scale
    path for 10k+ (job x config) cells, benchmarked in
    ``benchmarks/rank_bench.py``.
"""
from __future__ import annotations

import dataclasses
from typing import (Callable, Hashable, List, Mapping, Optional, Sequence,
                    Tuple, Union)

import numpy as np

try:  # accelerator path; the selector core works without jax installed
    import jax
    import jax.numpy as jnp
    _HAVE_JAX = True
except ImportError:  # pragma: no cover
    _HAVE_JAX = False


class NothingRankableError(ValueError):
    """The selection has no rankable universe — an empty job selection or
    an entirely-unprofiled catalog.  A routine per-submission outcome
    (e.g. an exclusion set that empties a class), distinct from the other
    ``ValueError``\\ s raised here, which indicate misconfiguration (shape
    mismatches, missing price sources, broken traces) and should never be
    swallowed as a rejection."""


@dataclasses.dataclass(frozen=True)
class RankedConfig:
    config_id: Hashable
    score: float           # sum of normalized costs; lower is better
    mean_norm_cost: float  # score / number of contributing test jobs


def _scores_numpy(hours: np.ndarray, mask: np.ndarray, prices: np.ndarray
                  ) -> Tuple[np.ndarray, np.ndarray]:
    cost = np.where(mask, hours * prices[None, :], np.inf)
    row_best = np.min(cost, axis=1, initial=np.inf)
    with np.errstate(invalid="ignore"):
        norm = np.where(mask, cost / row_best[:, None], 0.0)
    return norm.sum(axis=0), mask.sum(axis=0)


def _materialize(scores: np.ndarray, counts: np.ndarray,
                 config_ids: Sequence[Hashable]) -> List[RankedConfig]:
    """Scores/counts -> sorted RankedConfig list (shared by the cold and
    incremental paths so their rankings are identical by construction)."""
    ranked = [
        RankedConfig(
            c,
            float(scores[i]) if counts[i] else float("inf"),
            float(scores[i] / counts[i]) if counts[i] else float("inf"))
        for i, c in enumerate(config_ids)]
    order = {c: i for i, c in enumerate(config_ids)}
    ranked.sort(key=lambda r: (r.score, order[r.config_id]))
    return ranked


if _HAVE_JAX:
    @jax.jit
    def _scores_jax(hours, mask, prices):
        cost = jnp.where(mask, hours * prices[None, :], jnp.inf)
        row_best = jnp.min(cost, axis=1)
        norm = jnp.where(mask, cost / row_best[:, None], 0.0)
        return norm.sum(axis=0), mask.sum(axis=0)


def rank_dense(hours: np.ndarray, mask: np.ndarray, prices: np.ndarray,
               config_ids: Sequence[Hashable],
               job_ids: Optional[Sequence[Hashable]] = None,
               backend: str = "numpy") -> List[RankedConfig]:
    """Rank configs from dense (J x C) runtime-hours + profiled-mask.

    ``prices`` is the current $/h per config, aligned with ``config_ids``.
    Raises on an empty job axis and on non-positive profiled costs (both
    indicate a broken trace, not a rankable universe).
    """
    hours = np.asarray(hours, dtype=np.float64)
    mask = np.asarray(mask, dtype=bool)
    prices = np.asarray(prices, dtype=np.float64)
    if hours.shape != mask.shape or hours.shape[1] != prices.shape[0]:
        raise ValueError(f"shape mismatch: hours {hours.shape}, "
                         f"mask {mask.shape}, prices {prices.shape}")
    if hours.shape[0] == 0:
        raise NothingRankableError("no test jobs to learn from")
    bad = mask & ~((hours * prices[None, :]) > 0)
    if bad.any():
        row = int(np.argwhere(bad)[0][0])
        job = job_ids[row] if job_ids is not None else row
        raise ValueError(f"non-positive cost for job {job!r}")
    if backend == "jax":
        if not _HAVE_JAX:
            raise RuntimeError("jax backend requested but jax is missing")
        scores, counts = (np.asarray(x) for x in _scores_jax(
            jnp.asarray(hours), jnp.asarray(mask), jnp.asarray(prices)))
    elif backend == "numpy":
        scores, counts = _scores_numpy(hours, mask, prices)
    else:
        raise ValueError(f"unknown backend {backend!r}")
    return _materialize(scores, counts, config_ids)


def rank_pairs(
    runtime_hours: Mapping[Tuple[Hashable, Hashable], float],
    jobs: Sequence[Hashable],
    config_ids: Sequence[Hashable],
    hourly_cost: Union[Callable[[Hashable], float], Mapping[Hashable, float]],
    backend: str = "numpy",
) -> List[RankedConfig]:
    """Rank from sparse ``{(job, config): hours}`` pairs (legacy shape).

    Densifies and dispatches to :func:`rank_dense`; kept so existing
    callers of ``repro.core.flora.rank_generic`` keep one code path.
    """
    if not jobs:
        raise NothingRankableError("no test jobs to learn from")
    price_of = hourly_cost if callable(hourly_cost) else hourly_cost.__getitem__
    hours = np.zeros((len(jobs), len(config_ids)))
    mask = np.zeros_like(hours, dtype=bool)
    for r, j in enumerate(jobs):
        for k, c in enumerate(config_ids):
            v = runtime_hours.get((j, c))
            if v is not None:
                hours[r, k] = v
                mask[r, k] = True
    prices = np.asarray([price_of(c) for c in config_ids], dtype=np.float64)
    return rank_dense(hours, mask, prices, config_ids, job_ids=list(jobs),
                      backend=backend)


class RankState:
    """Incremental repricing over a fixed (job x config) runtime matrix.

    The live-market path (DESIGN.md §6): when only k of C prices move in a
    tick, a full :func:`rank_dense` recomputes every intermediate from
    scratch — cost broadcast, row-min, normalize, sum, plus building and
    sorting C ``RankedConfig`` objects.  ``RankState`` instead keeps the
    dense intermediates (cost, row-min, normalized-cost matrices) alive and
    on :meth:`reprice` touches only

      * the k changed cost/norm columns, and
      * the rows whose masked row-minimum was or becomes a changed column
        (every cell of those rows renormalizes).

    **Bit-identity contract**: scores after any ``reprice`` sequence are
    bit-identical to a cold ``rank_dense`` at the same prices.  Updated
    cells are recomputed with the exact elementwise arithmetic of the cold
    path, and scores are reduced with the same full ``norm.sum(axis=0)``
    (numpy's pairwise summation is *not* decomposable, so per-column delta
    updates would drift by ulps — the one full pass over the norm matrix is
    the price of exactness, and it is still ~100x cheaper than the cold
    path at 10k configs; see ``benchmarks/market_bench.py``).

    numpy/float64 only — the jax backend's float32 kernel has no exact
    incremental counterpart.
    """

    def __init__(self, hours: np.ndarray, mask: np.ndarray,
                 prices: np.ndarray, config_ids: Sequence[Hashable],
                 job_ids: Optional[Sequence[Hashable]] = None):
        self.hours = np.asarray(hours, dtype=np.float64)
        self.mask = np.asarray(mask, dtype=bool)
        self.prices = np.array(prices, dtype=np.float64)
        self.config_ids = list(config_ids)
        self.job_ids = list(job_ids) if job_ids is not None else None
        if self.hours.shape != self.mask.shape or \
                self.hours.shape[1] != self.prices.shape[0]:
            raise ValueError(f"shape mismatch: hours {self.hours.shape}, "
                             f"mask {self.mask.shape}, "
                             f"prices {self.prices.shape}")
        if self.hours.shape[0] == 0:
            raise NothingRankableError("no test jobs to learn from")
        self._pos = {c: i for i, c in enumerate(self.config_ids)}
        if len(self._pos) != len(self.config_ids):
            raise ValueError("duplicate config ids")
        self._check_positive(self.mask, self.hours * self.prices[None, :])
        #: ticks applied since construction (diagnostics, cache keys).
        self.reprices = 0
        self._rebuild()

    def _check_positive(self, mask: np.ndarray, cost: np.ndarray) -> None:
        bad = mask & ~(cost > 0)
        if bad.any():
            row = int(np.argwhere(bad)[0][0])
            job = self.job_ids[row] if self.job_ids is not None else row
            raise ValueError(f"non-positive cost for job {job!r}")

    def _rebuild(self) -> None:
        # the cold-path arithmetic, verbatim (bit-identity anchor)
        self.cost = np.where(self.mask, self.hours * self.prices[None, :],
                             np.inf)
        self.row_best = np.min(self.cost, axis=1, initial=np.inf)
        with np.errstate(invalid="ignore"):
            self.norm = np.where(self.mask,
                                 self.cost / self.row_best[:, None], 0.0)
        self.scores = self.norm.sum(axis=0)
        self.counts = self.mask.sum(axis=0)

    def reprice(self, deltas: Union[Mapping[Hashable, float],
                                    Sequence[Tuple[Hashable, float]]]) -> int:
        """Apply ``{config_id: new $/h}`` deltas; returns #rows whose
        masked row-minimum moved (the expensive case)."""
        table = deltas if isinstance(deltas, Mapping) else dict(deltas)
        if not table:
            return 0
        try:
            cols = np.asarray([self._pos[c] for c in table], dtype=np.intp)
        except KeyError as e:
            raise ValueError(f"unknown config id in deltas: {e.args[0]!r}")
        new_prices = np.asarray(list(table.values()), dtype=np.float64)
        # same elementwise ops as the cold broadcast -> bit-identical cells
        new_cost = np.where(self.mask[:, cols],
                            self.hours[:, cols] * new_prices[None, :],
                            np.inf)
        self._check_positive(self.mask[:, cols], new_cost)
        old_cost = self.cost[:, cols]
        self.prices[cols] = new_prices
        self.cost[:, cols] = new_cost
        # rows whose masked minimum was in a changed column, or where a
        # changed column undercuts the old minimum, need a fresh row-min
        was_min = old_cost.min(axis=1, initial=np.inf) == self.row_best
        undercut = new_cost.min(axis=1, initial=np.inf) < self.row_best
        candidates = np.flatnonzero(was_min | undercut)
        moved = np.array([], dtype=np.intp)
        if candidates.size:
            fresh = np.min(self.cost[candidates, :], axis=1, initial=np.inf)
            changed = fresh != self.row_best[candidates]
            moved = candidates[changed]
            self.row_best[moved] = fresh[changed]
        with np.errstate(invalid="ignore"):
            self.norm[:, cols] = np.where(
                self.mask[:, cols],
                self.cost[:, cols] / self.row_best[:, None], 0.0)
            if moved.size:
                self.norm[moved, :] = np.where(
                    self.mask[moved, :],
                    self.cost[moved, :] / self.row_best[moved, None], 0.0)
        # full-matrix reduction, identical to the cold path (see docstring)
        self.scores = self.norm.sum(axis=0)
        self.reprices += 1
        return int(moved.size)

    def ranking(self) -> List[RankedConfig]:
        """The full sorted ranking (bit-identical to ``rank_dense``)."""
        return _materialize(self.scores, self.counts, self.config_ids)

    def winner(self) -> RankedConfig:
        """argmin only — O(C), no list build/sort.  A cheap peek for
        callers that only need the top pick; the serving path proper goes
        through :meth:`ranking`, since a ``Decision`` always carries the
        full sorted list."""
        finite = self.counts > 0
        if not finite.any():
            i = 0
        else:
            masked = np.where(finite, self.scores, np.inf)
            i = int(np.argmin(masked))
        c = self.config_ids[i]
        s = float(self.scores[i]) if self.counts[i] else float("inf")
        m = float(self.scores[i] / self.counts[i]) if self.counts[i] \
            else float("inf")
        return RankedConfig(c, s, m)
