"""Vectorized normalized-cost ranking (paper §II, step 2).

The ranking is one matrix computation instead of a per-pair dict loop:

    cost   = runtime_hours (J x C)  *  price_vector (C,)     # broadcast
    norm   = cost / row-min(cost over profiled cells)        # row-normalize
    score  = column-sum of norm over profiled cells          # per config

A config with **zero** profiled cells scores ``+inf`` and therefore ranks
last (an unprofiled config must never win by default — the historical dict
loop left it at 0.0, i.e. argmin).

Two backends:

  * ``"numpy"`` (default): float64, bit-stable with the historical
    per-pair arithmetic — used for the paper-table reproductions;
  * ``"jax"``: a jitted ``jax.numpy`` kernel (float32 on CPU/TPU) that
    fuses the whole ranking into one XLA computation — the serving-scale
    path for 10k+ (job x config) cells, benchmarked in
    ``benchmarks/rank_bench.py``.
"""
from __future__ import annotations

import dataclasses
from typing import (Callable, Hashable, List, Mapping, Optional, Sequence,
                    Tuple, Union)

import numpy as np

try:  # accelerator path; the selector core works without jax installed
    import jax
    import jax.numpy as jnp
    _HAVE_JAX = True
except ImportError:  # pragma: no cover
    _HAVE_JAX = False


@dataclasses.dataclass(frozen=True)
class RankedConfig:
    config_id: Hashable
    score: float           # sum of normalized costs; lower is better
    mean_norm_cost: float  # score / number of contributing test jobs


def _scores_numpy(hours: np.ndarray, mask: np.ndarray, prices: np.ndarray
                  ) -> Tuple[np.ndarray, np.ndarray]:
    cost = np.where(mask, hours * prices[None, :], np.inf)
    row_best = np.min(cost, axis=1, initial=np.inf)
    with np.errstate(invalid="ignore"):
        norm = np.where(mask, cost / row_best[:, None], 0.0)
    return norm.sum(axis=0), mask.sum(axis=0)


if _HAVE_JAX:
    @jax.jit
    def _scores_jax(hours, mask, prices):
        cost = jnp.where(mask, hours * prices[None, :], jnp.inf)
        row_best = jnp.min(cost, axis=1)
        norm = jnp.where(mask, cost / row_best[:, None], 0.0)
        return norm.sum(axis=0), mask.sum(axis=0)


def rank_dense(hours: np.ndarray, mask: np.ndarray, prices: np.ndarray,
               config_ids: Sequence[Hashable],
               job_ids: Optional[Sequence[Hashable]] = None,
               backend: str = "numpy") -> List[RankedConfig]:
    """Rank configs from dense (J x C) runtime-hours + profiled-mask.

    ``prices`` is the current $/h per config, aligned with ``config_ids``.
    Raises on an empty job axis and on non-positive profiled costs (both
    indicate a broken trace, not a rankable universe).
    """
    hours = np.asarray(hours, dtype=np.float64)
    mask = np.asarray(mask, dtype=bool)
    prices = np.asarray(prices, dtype=np.float64)
    if hours.shape != mask.shape or hours.shape[1] != prices.shape[0]:
        raise ValueError(f"shape mismatch: hours {hours.shape}, "
                         f"mask {mask.shape}, prices {prices.shape}")
    if hours.shape[0] == 0:
        raise ValueError("no test jobs to learn from")
    bad = mask & ~((hours * prices[None, :]) > 0)
    if bad.any():
        row = int(np.argwhere(bad)[0][0])
        job = job_ids[row] if job_ids is not None else row
        raise ValueError(f"non-positive cost for job {job!r}")
    if backend == "jax":
        if not _HAVE_JAX:
            raise RuntimeError("jax backend requested but jax is missing")
        scores, counts = (np.asarray(x) for x in _scores_jax(
            jnp.asarray(hours), jnp.asarray(mask), jnp.asarray(prices)))
    elif backend == "numpy":
        scores, counts = _scores_numpy(hours, mask, prices)
    else:
        raise ValueError(f"unknown backend {backend!r}")
    ranked = [
        RankedConfig(
            c,
            float(scores[i]) if counts[i] else float("inf"),
            float(scores[i] / counts[i]) if counts[i] else float("inf"))
        for i, c in enumerate(config_ids)]
    order = {c: i for i, c in enumerate(config_ids)}
    ranked.sort(key=lambda r: (r.score, order[r.config_id]))
    return ranked


def rank_pairs(
    runtime_hours: Mapping[Tuple[Hashable, Hashable], float],
    jobs: Sequence[Hashable],
    config_ids: Sequence[Hashable],
    hourly_cost: Union[Callable[[Hashable], float], Mapping[Hashable, float]],
    backend: str = "numpy",
) -> List[RankedConfig]:
    """Rank from sparse ``{(job, config): hours}`` pairs (legacy shape).

    Densifies and dispatches to :func:`rank_dense`; kept so existing
    callers of ``repro.core.flora.rank_generic`` keep one code path.
    """
    if not jobs:
        raise ValueError("no test jobs to learn from")
    price_of = hourly_cost if callable(hourly_cost) else hourly_cost.__getitem__
    hours = np.zeros((len(jobs), len(config_ids)))
    mask = np.zeros_like(hours, dtype=bool)
    for r, j in enumerate(jobs):
        for k, c in enumerate(config_ids):
            v = runtime_hours.get((j, c))
            if v is not None:
                hours[r, k] = v
                mask[r, k] = True
    prices = np.asarray([price_of(c) for c in config_ids], dtype=np.float64)
    return rank_dense(hours, mask, prices, config_ids, job_ids=list(jobs),
                      backend=backend)
