"""Vectorized normalized-cost ranking (paper §II, step 2).

The ranking is one matrix computation instead of a per-pair dict loop:

    cost   = runtime_hours (J x C)  *  price_vector (C,)     # broadcast
    norm   = cost / row-min(cost over profiled cells)        # row-normalize
    score  = column-sum of norm over profiled cells          # per config

A config with **zero** profiled cells scores ``+inf`` and therefore ranks
last (an unprofiled config must never win by default — the historical dict
loop left it at 0.0, i.e. argmin).

Two backends:

  * ``"numpy"`` (default): float64, bit-stable with the historical
    per-pair arithmetic — used for the paper-table reproductions;
  * ``"jax"``: a jitted ``jax.numpy`` kernel (float32 on CPU/TPU) that
    fuses the whole ranking into one XLA computation — the serving-scale
    path for 10k+ (job x config) cells, benchmarked in
    ``benchmarks/rank_bench.py``.

Each backend carries an explicit :class:`ScoreContract` (DESIGN.md §9):
numpy guarantees bit-identity between the incremental and cold paths;
jax is float32 and guarantees the same winner (or a winner tied within
tolerance) with scores inside a rel/abs envelope.  Incremental repricing
lives in :class:`RankState` (numpy) and :class:`JaxRankState` (the
accelerator-resident jitted delta-update kernel with donated buffers).
:class:`BatchedRankState` stacks a whole fleet of (class, exclusion)
rankings over one shared device-resident hours matrix, so a price tick
is a *single* dispatch for every live ranking (DESIGN.md §10); the
``"jax_batched"`` backend name selects it at the service level.
``"jax_sharded"`` (:mod:`repro.selector.sharded`) shards that batched
universe's config axis across every local device, so one *collective*
dispatch per tick reprices the fleet at catalogs no single device holds
(DESIGN.md §13).  ``"jax_pallas"``
(:mod:`repro.selector.pallas_rank`) replaces the batched tick's
two-matmul + mask/min/norm XLA sequence with ONE fused Pallas kernel
over the tiled universe (:mod:`repro.kernels.rank_delta`, DESIGN.md
§14).  Every state also serves :meth:`top_k` — the head of
the ranking without materializing and sorting all C configs
(``jax.lax.top_k`` on device for the jax-family states, a partial
selection on numpy).
"""
from __future__ import annotations

import dataclasses
import os
import threading
from typing import (Any, Callable, Hashable, List, Mapping, Optional,
                    Sequence, Tuple, Union)

import numpy as np

from repro.obs import MetricsRegistry, maybe_span

try:  # accelerator path; the selector core works without jax installed
    import jax
    import jax.numpy as jnp
    _HAVE_JAX = True
except ImportError:  # pragma: no cover
    _HAVE_JAX = False

#: the knob CI's backend matrix turns; resolved by :func:`default_backend`.
BACKEND_ENV_VAR = "FLORA_RANK_BACKEND"
#: ``"jax_batched"`` shares the jax cold kernel and ScoreContract but
#: makes the *service* stack every live (class, exclusion) ranking into
#: one :class:`BatchedRankState` — one dispatch per tick for the fleet.
#: ``"jax_sharded"`` additionally shards the config axis of that fleet
#: universe across every local device
#: (:class:`~repro.selector.sharded.ShardedBatchedRankState`) — one
#: *collective* dispatch per tick for catalogs too large for one
#: device (DESIGN.md §13).  ``"jax_pallas"``
#: (:class:`~repro.selector.pallas_rank.PallasBatchedRankState`) runs
#: the batched tick as ONE fused Pallas kernel
#: (:mod:`repro.kernels.rank_delta`) instead of the two-matmul +
#: mask/min/norm XLA sequence — native on TPU, ``interpret=True``
#: elsewhere (DESIGN.md §14).
BACKENDS = ("numpy", "jax", "jax_batched", "jax_sharded", "jax_pallas")
#: the fleet backends: a SelectionService on one of these stacks every
#: live (class, exclusion) ranking into a single shared state, so a
#: price tick is one (possibly collective) kernel dispatch fleet-wide.
FLEET_BACKENDS = ("jax_batched", "jax_sharded", "jax_pallas")
#: backends whose runtime dependency is jax.
_JAX_FAMILY = ("jax", "jax_batched", "jax_sharded", "jax_pallas")


class BackendUnavailableError(RuntimeError):
    """A ranking backend was requested whose runtime dependency is not
    installed (today: ``backend="jax"`` without jax).  Typed so callers —
    and test harnesses — can skip rather than die: distinguishable from
    both misconfiguration ``ValueError``\\ s (unknown backend names) and
    genuine crashes."""


def default_backend() -> str:
    """The backend used when a :class:`~repro.selector.SelectionService`
    is built without an explicit ``backend=``: the ``FLORA_RANK_BACKEND``
    env var, else ``"numpy"``.  ``rank_dense`` itself always defaults to
    numpy — the float64 bit-stable reference that replay audits re-rank
    against must not move under the env var."""
    backend = os.environ.get(BACKEND_ENV_VAR, "numpy")
    if backend not in BACKENDS:
        raise ValueError(
            f"unknown backend {backend!r} in ${BACKEND_ENV_VAR} "
            f"(expected one of {BACKENDS})")
    return backend


@dataclasses.dataclass(frozen=True)
class ScoreContract:
    """What a backend promises about incremental-vs-cold score equality.

    * numpy/float64: **bit-identical** — the incremental
      :class:`RankState` recomputes updated cells with the cold path's
      exact elementwise arithmetic and re-reduces scores with the same
      full ``norm.sum(axis=0)``, so any reprice sequence equals a cold
      ``rank_dense`` down to the last ulp (``rel_tol == abs_tol == 0``).
    * jax/float32: **same-winner-or-tied within tolerance** — float32
      has no bit-identity story for delta updates (DESIGN.md §9): the
      jitted kernel folds per-tick deltas into standing score
      accumulators, so scores drift by ulps per tick, and two configs
      whose true scores are closer than the drift may swap.  The
      contract is that every score lies within ``rel_tol``/``abs_tol``
      of the cold value and the reported winner is either identical to
      the cold winner or tied with it within the same envelope.
    """

    backend: str
    bit_identical: bool
    rel_tol: float = 0.0
    abs_tol: float = 0.0

    def scores_match(self, a: float, b: float) -> bool:
        """Are two scores equal under this contract?  (``inf == inf``
        counts: unprofiled configs score ``+inf`` on every backend.)"""
        if a == b:
            return True
        if self.bit_identical:
            return False
        return abs(a - b) <= self.abs_tol + self.rel_tol * max(abs(a),
                                                               abs(b))

    def winner_matches(self, config_id: Hashable,
                       ranking: Sequence["RankedConfig"]) -> bool:
        """Is ``config_id`` an acceptable winner against a cold
        ``ranking``?  Identical to the cold winner always qualifies; a
        tolerance backend also accepts a config whose *cold* score ties
        the cold winner's within the contract (float32 drift can swap
        near-ties, never separated configs)."""
        if not ranking:
            return False
        if config_id == ranking[0].config_id:
            return True
        if self.bit_identical:
            return False
        for r in ranking:
            if r.config_id == config_id:
                return self.scores_match(r.score, ranking[0].score)
        return False


#: Per-backend contracts.  The jax tolerances cover float32 rounding of
#: the inputs (~1e-7 relative) plus delta-accumulation drift across
#: ticks, with two orders of magnitude of headroom (DESIGN.md §9).
SCORE_CONTRACTS: Mapping[str, ScoreContract] = {
    "numpy": ScoreContract("numpy", bit_identical=True),
    "jax": ScoreContract("jax", bit_identical=False,
                         rel_tol=1e-4, abs_tol=1e-6),
    # same float32 physics as "jax" (shared row-min/norm intermediates,
    # delta-folded accumulators); batching adds no new drift source —
    # member scores are re-reduced per changed column like the per-state
    # kernel, so the envelope is identical (DESIGN.md §10).
    "jax_batched": ScoreContract("jax_batched", bit_identical=False,
                                 rel_tol=1e-4, abs_tol=1e-6),
    # sharding the C axis changes *where* each column's arithmetic runs,
    # not the arithmetic: per-shard row minima combine through
    # `lax.pmin` (exact on floats), and every norm/score term is the
    # same float32 expression as "jax_batched", so the envelope is
    # again identical (DESIGN.md §13).
    "jax_sharded": ScoreContract("jax_sharded", bit_identical=False,
                                 rel_tol=1e-4, abs_tol=1e-6),
    # the fused Pallas kernel recomputes cost/norm in-stream from the
    # same float32 elementwise expressions (deterministic IEEE ops ->
    # bit-identical cells), re-reduces changed columns from scratch and
    # delta-folds handoff rows exactly like the XLA step — only matmul
    # reduction *order* differs, which the shared rel/abs envelope
    # already covers, so journals and tolerance-mode audits carry over
    # unchanged (DESIGN.md §14).
    "jax_pallas": ScoreContract("jax_pallas", bit_identical=False,
                                rel_tol=1e-4, abs_tol=1e-6),
}


def backend_available(backend: str) -> bool:
    """Can ``backend`` actually run here?  ``"numpy"`` always; the
    jax-family backends (``"jax"``, ``"jax_batched"``,
    ``"jax_sharded"``, ``"jax_pallas"``) only when jax imports.
    Unknown names are *not*
    an error from this predicate (they fail later with ``ValueError``
    at dispatch)."""
    return backend not in _JAX_FAMILY or _HAVE_JAX


def score_contract(backend: str) -> ScoreContract:
    """The :class:`ScoreContract` for ``backend`` (raises on unknown)."""
    try:
        return SCORE_CONTRACTS[backend]
    except KeyError:
        raise ValueError(f"unknown backend {backend!r} "
                         f"(expected one of {BACKENDS})")


class NothingRankableError(ValueError):
    """The selection has no rankable universe — an empty job selection or
    an entirely-unprofiled catalog.  A routine per-submission outcome
    (e.g. an exclusion set that empties a class), distinct from the other
    ``ValueError``\\ s raised here, which indicate misconfiguration (shape
    mismatches, missing price sources, broken traces) and should never be
    swallowed as a rejection."""


@dataclasses.dataclass(frozen=True)
class RankedConfig:
    config_id: Hashable
    score: float           # sum of normalized costs; lower is better
    mean_norm_cost: float  # score / number of contributing test jobs


def _canonicalize_universe(
        hours: np.ndarray, mask: np.ndarray, prices: np.ndarray,
        job_ids: Optional[Sequence[Hashable]]
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Shared input validation for every dense entry point
    (:func:`rank_dense`, :class:`RankState`, :class:`JaxRankState`):
    canonicalize dtypes, check shapes, reject empty job axes and
    non-positive profiled costs (both indicate a broken trace, not a
    rankable universe)."""
    hours = np.asarray(hours, dtype=np.float64)
    mask = np.asarray(mask, dtype=bool)
    prices = np.asarray(prices, dtype=np.float64)
    if hours.shape != mask.shape or hours.shape[1] != prices.shape[0]:
        raise ValueError(f"shape mismatch: hours {hours.shape}, "
                         f"mask {mask.shape}, prices {prices.shape}")
    if hours.shape[0] == 0:
        raise NothingRankableError("no test jobs to learn from")
    bad = mask & ~((hours * prices[None, :]) > 0)
    if bad.any():
        row = int(np.argwhere(bad)[0][0])
        job = job_ids[row] if job_ids is not None else row
        raise ValueError(f"non-positive cost for job {job!r}")
    return hours, mask, prices


def _position_index(config_ids: Sequence[Hashable]
                    ) -> "dict[Hashable, int]":
    """Config id -> column position; rejects duplicates (the states key
    reprice deltas on it, so a duplicate would silently alias columns)."""
    pos = {c: i for i, c in enumerate(config_ids)}
    if len(pos) != len(config_ids):
        raise ValueError("duplicate config ids")
    return pos


def _scores_numpy(hours: np.ndarray, mask: np.ndarray, prices: np.ndarray
                  ) -> Tuple[np.ndarray, np.ndarray]:
    cost = np.where(mask, hours * prices[None, :], np.inf)
    row_best = np.min(cost, axis=1, initial=np.inf)
    with np.errstate(invalid="ignore"):
        norm = np.where(mask, cost / row_best[:, None], 0.0)
    return norm.sum(axis=0), mask.sum(axis=0)


def _materialize(scores: np.ndarray, counts: np.ndarray,
                 config_ids: Sequence[Hashable]) -> List[RankedConfig]:
    """Scores/counts -> sorted RankedConfig list (shared by the cold and
    incremental paths so their rankings are identical by construction)."""
    ranked = [
        RankedConfig(
            c,
            float(scores[i]) if counts[i] else float("inf"),
            float(scores[i] / counts[i]) if counts[i] else float("inf"))
        for i, c in enumerate(config_ids)]
    order = {c: i for i, c in enumerate(config_ids)}
    ranked.sort(key=lambda r: (r.score, order[r.config_id]))
    return ranked


def _check_k(k: int, n_cfgs: int) -> int:
    """Validate a top-k depth; clamps to the universe size (asking for
    more head than exists is a serving convenience, not an error)."""
    if not isinstance(k, int) or isinstance(k, bool) or k < 1:
        raise ValueError(f"top_k needs a positive integer k, got {k!r}")
    return min(k, n_cfgs)


def _top_k_numpy(scores: np.ndarray, counts: np.ndarray,
                 config_ids: Sequence[Hashable], k: int
                 ) -> List[RankedConfig]:
    """The head of :func:`_materialize`'s ranking without building and
    sorting all C ``RankedConfig``\\ s: partial-select the k best scores,
    then order only the boundary candidates by the same (score, catalog
    position) key — element-wise identical to ``_materialize(...)[:k]``
    by construction, ties included."""
    k = _check_k(k, len(config_ids))
    eff = np.where(counts > 0, scores, np.inf)
    kth = np.partition(eff, k - 1)[k - 1]
    # every config strictly better than the k-th plus the whole tie at
    # the boundary: ordering those few by (score, position) reproduces
    # the full sort's head even when the boundary is a multi-way tie
    cand = np.flatnonzero(eff <= kth)
    cand = cand[np.lexsort((cand, eff[cand]))][:k]
    return [
        RankedConfig(
            config_ids[i],
            float(scores[i]) if counts[i] else float("inf"),
            float(scores[i] / counts[i]) if counts[i] else float("inf"))
        for i in cand]


if _HAVE_JAX:
    @jax.jit
    def _scores_jax(hours, mask, prices):
        cost = jnp.where(mask, hours * prices[None, :], jnp.inf)
        row_best = jnp.min(cost, axis=1)
        norm = jnp.where(mask, cost / row_best[:, None], 0.0)
        return norm.sum(axis=0), mask.sum(axis=0)


def rank_dense(hours: np.ndarray, mask: np.ndarray, prices: np.ndarray,
               config_ids: Sequence[Hashable],
               job_ids: Optional[Sequence[Hashable]] = None,
               backend: str = "numpy") -> List[RankedConfig]:
    """Rank configs from dense (J x C) runtime-hours + profiled-mask.

    ``prices`` is the current $/h per config, aligned with ``config_ids``.
    Raises on an empty job axis and on non-positive profiled costs (both
    indicate a broken trace, not a rankable universe).
    """
    hours, mask, prices = _canonicalize_universe(hours, mask, prices,
                                                 job_ids)
    if backend in _JAX_FAMILY:
        # batching/sharding is a *serving* distinction (how live states
        # share a tick dispatch); a cold full rank is the same fused
        # kernel
        if not _HAVE_JAX:
            raise BackendUnavailableError(
                f"backend={backend!r} requested but jax is not installed "
                "(the numpy backend needs no extras)")
        scores, counts = (np.asarray(x) for x in _scores_jax(
            jnp.asarray(hours), jnp.asarray(mask), jnp.asarray(prices)))
    elif backend == "numpy":
        scores, counts = _scores_numpy(hours, mask, prices)
    else:
        raise ValueError(f"unknown backend {backend!r}")
    return _materialize(scores, counts, config_ids)


def rank_pairs(
    runtime_hours: Mapping[Tuple[Hashable, Hashable], float],
    jobs: Sequence[Hashable],
    config_ids: Sequence[Hashable],
    hourly_cost: Union[Callable[[Hashable], float], Mapping[Hashable, float]],
    backend: str = "numpy",
) -> List[RankedConfig]:
    """Rank from sparse ``{(job, config): hours}`` pairs (legacy shape).

    Densifies and dispatches to :func:`rank_dense`; kept so existing
    callers of ``repro.core.flora.rank_generic`` keep one code path.
    """
    if not jobs:
        raise NothingRankableError("no test jobs to learn from")
    price_of = hourly_cost if callable(hourly_cost) else hourly_cost.__getitem__
    hours = np.zeros((len(jobs), len(config_ids)))
    mask = np.zeros_like(hours, dtype=bool)
    for r, j in enumerate(jobs):
        for k, c in enumerate(config_ids):
            v = runtime_hours.get((j, c))
            if v is not None:
                hours[r, k] = v
                mask[r, k] = True
    prices = np.asarray([price_of(c) for c in config_ids], dtype=np.float64)
    return rank_dense(hours, mask, prices, config_ids, job_ids=list(jobs),
                      backend=backend)


class RankState:
    """Incremental repricing over a fixed (job x config) runtime matrix.

    The live-market path (DESIGN.md §6): when only k of C prices move in a
    tick, a full :func:`rank_dense` recomputes every intermediate from
    scratch — cost broadcast, row-min, normalize, sum, plus building and
    sorting C ``RankedConfig`` objects.  ``RankState`` instead keeps the
    dense intermediates (cost, row-min, normalized-cost matrices) alive and
    on :meth:`reprice` touches only

      * the k changed cost/norm columns, and
      * the rows whose masked row-minimum was or becomes a changed column
        (every cell of those rows renormalizes).

    **Bit-identity contract**: scores after any ``reprice`` sequence are
    bit-identical to a cold ``rank_dense`` at the same prices.  Updated
    cells are recomputed with the exact elementwise arithmetic of the cold
    path, and scores are reduced with the same full ``norm.sum(axis=0)``
    (numpy's pairwise summation is *not* decomposable, so per-column delta
    updates would drift by ulps — the one full pass over the norm matrix is
    the price of exactness, and it is still ~100x cheaper than the cold
    path at 10k configs; see ``benchmarks/market_bench.py``).

    numpy/float64 only — float32 has no exact incremental story, so the
    jax backend's accelerator-resident counterpart,
    :class:`JaxRankState`, serves a *tolerance* contract instead
    (same winner or tied within tolerance; see :class:`ScoreContract`
    and DESIGN.md §9).
    """

    def __init__(self, hours: np.ndarray, mask: np.ndarray,
                 prices: np.ndarray, config_ids: Sequence[Hashable],
                 job_ids: Optional[Sequence[Hashable]] = None,
                 metrics: Optional[MetricsRegistry] = None):
        self.config_ids = list(config_ids)
        self.job_ids = list(job_ids) if job_ids is not None else None
        # optional shared telemetry (DESIGN.md §12): host materializations
        # tick an aggregate counter + span on the injected registry; the
        # plain per-state ``materializations`` int stays authoritative for
        # the freshness tests.
        self._metrics = metrics
        self._c_mat = (None if metrics is None
                       else metrics.counter("rank.materializations"))
        self.hours, self.mask, self.prices = _canonicalize_universe(
            hours, mask, prices, self.job_ids)
        self.prices = self.prices.copy()        # mutated by reprice
        self._pos = _position_index(self.config_ids)
        #: ticks applied since construction (diagnostics, cache keys).
        self.reprices = 0
        #: full-ranking sorts actually performed (the memoization
        #: counter the freshness tests assert on).
        self.materializations = 0
        self._ranking_memo: Optional[Tuple[int, List[RankedConfig]]] = None
        self._rebuild()

    def _check_positive(self, mask: np.ndarray, cost: np.ndarray) -> None:
        bad = mask & ~(cost > 0)
        if bad.any():
            row = int(np.argwhere(bad)[0][0])
            job = self.job_ids[row] if self.job_ids is not None else row
            raise ValueError(f"non-positive cost for job {job!r}")

    def _rebuild(self) -> None:
        # the cold-path arithmetic, verbatim (bit-identity anchor)
        self.cost = np.where(self.mask, self.hours * self.prices[None, :],
                             np.inf)
        self.row_best = np.min(self.cost, axis=1, initial=np.inf)
        with np.errstate(invalid="ignore"):
            self.norm = np.where(self.mask,
                                 self.cost / self.row_best[:, None], 0.0)
        self.scores = self.norm.sum(axis=0)
        self.counts = self.mask.sum(axis=0)

    def reprice(self, deltas: Union[Mapping[Hashable, float],
                                    Sequence[Tuple[Hashable, float]]]) -> int:
        """Apply ``{config_id: new $/h}`` deltas; returns #rows whose
        masked row-minimum moved (the expensive case)."""
        table = deltas if isinstance(deltas, Mapping) else dict(deltas)
        if not table:
            return 0
        try:
            cols = np.asarray([self._pos[c] for c in table], dtype=np.intp)
        except KeyError as e:
            raise ValueError(f"unknown config id in deltas: {e.args[0]!r}")
        new_prices = np.asarray(list(table.values()), dtype=np.float64)
        # same elementwise ops as the cold broadcast -> bit-identical cells
        new_cost = np.where(self.mask[:, cols],
                            self.hours[:, cols] * new_prices[None, :],
                            np.inf)
        self._check_positive(self.mask[:, cols], new_cost)
        old_cost = self.cost[:, cols]
        self.prices[cols] = new_prices
        self.cost[:, cols] = new_cost
        # rows whose masked minimum was in a changed column, or where a
        # changed column undercuts the old minimum, need a fresh row-min
        was_min = old_cost.min(axis=1, initial=np.inf) == self.row_best
        undercut = new_cost.min(axis=1, initial=np.inf) < self.row_best
        candidates = np.flatnonzero(was_min | undercut)
        moved = np.array([], dtype=np.intp)
        if candidates.size:
            fresh = np.min(self.cost[candidates, :], axis=1, initial=np.inf)
            changed = fresh != self.row_best[candidates]
            moved = candidates[changed]
            self.row_best[moved] = fresh[changed]
        with np.errstate(invalid="ignore"):
            self.norm[:, cols] = np.where(
                self.mask[:, cols],
                self.cost[:, cols] / self.row_best[:, None], 0.0)
            if moved.size:
                self.norm[moved, :] = np.where(
                    self.mask[moved, :],
                    self.cost[moved, :] / self.row_best[moved, None], 0.0)
        # full-matrix reduction, identical to the cold path (see docstring)
        self.scores = self.norm.sum(axis=0)
        self.reprices += 1
        return int(moved.size)

    def ranking(self) -> List[RankedConfig]:
        """The full sorted ranking (bit-identical to ``rank_dense``),
        memoized on the state's tick count: repeat calls between two
        reprices reuse the last sort instead of re-materializing all C
        ``RankedConfig``\\ s (a fresh list copy is returned each call, so
        callers may not corrupt the memo)."""
        if self._ranking_memo is None or \
                self._ranking_memo[0] != self.reprices:
            self.materializations += 1
            if self._c_mat is not None:
                self._c_mat.inc()
            with maybe_span(self._metrics, "rank.materialize"):
                self._ranking_memo = (
                    self.reprices,
                    _materialize(self.scores, self.counts,
                                 self.config_ids))
        return list(self._ranking_memo[1])

    def top_k(self, k: int) -> List[RankedConfig]:
        """The first ``k`` entries of :meth:`ranking` without building
        and sorting all C configs — a partial selection over the score
        vector, element-wise identical to ``ranking()[:k]`` (same
        (score, catalog-order) tie-break)."""
        return _top_k_numpy(self.scores, self.counts, self.config_ids, k)

    def winner(self) -> RankedConfig:
        """argmin only — O(C), no list build/sort.  A cheap peek for
        callers that only need the top pick; the serving path proper goes
        through :meth:`ranking`, since a ``Decision`` always carries the
        full sorted list."""
        finite = self.counts > 0
        if not finite.any():
            i = 0
        else:
            masked = np.where(finite, self.scores, np.inf)
            i = int(np.argmin(masked))
        c = self.config_ids[i]
        s = float(self.scores[i]) if self.counts[i] else float("inf")
        m = float(self.scores[i] / self.counts[i]) if self.counts[i] \
            else float("inf")
        return RankedConfig(c, s, m)


# --- the accelerator-resident incremental path (jax backend) ----------------------

def _validated_deltas(pos: Mapping[Hashable, int],
                      deltas: Union[Mapping[Hashable, float],
                                    Sequence[Tuple[Hashable, float]]]
                      ) -> Optional[Tuple[np.ndarray, np.ndarray]]:
    """Validate a delta batch for the jitted jax states: resolve config
    ids to column positions and reject non-positive / non-finite prices.
    Returns unpadded ``(cols, new_prices)`` or ``None`` for an empty
    batch.  (Bucket padding is the caller's concern — the single-device
    states pad the whole batch, the sharded state routes columns to
    their owning shard first and pads per shard.)"""
    table = deltas if isinstance(deltas, Mapping) else dict(deltas)
    if not table:
        return None
    try:
        cols = np.asarray([pos[c] for c in table], dtype=np.int32)
    except KeyError as e:
        raise ValueError(f"unknown config id in deltas: {e.args[0]!r}")
    new_prices = np.asarray(list(table.values()), dtype=np.float64)
    bad = ~(np.isfinite(new_prices) & (new_prices > 0))
    if bad.any():
        offender = list(table)[int(np.flatnonzero(bad)[0])]
        raise ValueError(f"non-positive or non-finite price for "
                         f"config {offender!r}")
    return cols, new_prices


def _bucket_size(n: int, bucket_base: int) -> int:
    """Next power-of-4 bucket >= ``n`` (starting at ``bucket_base``), so
    the jitted steps compile O(log C) shape variants."""
    bucket = bucket_base
    while bucket < n:
        bucket *= 4
    return bucket


def _validated_delta_cols(pos: Mapping[Hashable, int],
                          deltas: Union[Mapping[Hashable, float],
                                        Sequence[Tuple[Hashable, float]]],
                          bucket_base: int
                          ) -> Optional[Tuple[np.ndarray, np.ndarray]]:
    """Shared delta-batch preparation for the jitted jax states
    (:class:`JaxRankState`, :class:`BatchedRankState`): validate ids and
    prices (:func:`_validated_deltas`), then pad ``(cols, new_prices)``
    to the next power-of-4 column-count bucket so the jitted step
    compiles O(log C) shape variants.  Padding repeats the first
    (column, price) pair, which every kernel op treats idempotently.
    Returns ``None`` for an empty batch."""
    validated = _validated_deltas(pos, deltas)
    if validated is None:
        return None
    cols, new_prices = validated
    k = cols.shape[0]
    bucket = _bucket_size(k, bucket_base)
    if bucket > k:
        cols = np.concatenate(
            [cols, np.full(bucket - k, cols[0], dtype=np.int32)])
        new_prices = np.concatenate(
            [new_prices, np.full(bucket - k, new_prices[0])])
    return cols, new_prices


if _HAVE_JAX:
    _JAX_STATE_FNS: Optional[Tuple[Any, Any, Any]] = None
    _JAX_TOPK_FN: Optional[Any] = None
    #: guards every lazy jitted-kernel singleton below (double-checked
    #: locking): the serving front-end first-calls from N snapshot
    #: workers plus the tick thread concurrently, and an unlocked
    #: check-then-build can build twice and interleave partially-
    #: initialized reads (regression-stressed in tests/test_kernels.py)
    _JAX_FNS_LOCK = threading.Lock()

    def _delta_universe_update(prices, cost, row_best, hours, mask,
                               cols, new_prices):
        """The shared universe half of every jitted delta step (traced
        inside both the per-state and the batched kernels, so the two
        backends can never silently diverge on the numerically critical
        logic):

        * changed columns: gather, recompute cells, scatter back;
        * min-handoff rows: the masked row-minimum was in a changed
          column, or a changed column undercuts it — those rows get a
          fresh minimum;
        * ``fresh_rows`` renormalizes the whole matrix at the new
          minima (consumers select only the ``moved`` rows from it);
        * ``col_norm`` re-derives the changed columns' normalized
          costs, idempotent under the duplicate indices the power-of-4
          bucket padding introduces.
        """
        sub_mask = mask[:, cols]
        new_cost = jnp.where(sub_mask,
                             hours[:, cols] * new_prices[None, :],
                             jnp.inf)
        old_cost = cost[:, cols]
        prices = prices.at[cols].set(new_prices)
        cost = cost.at[:, cols].set(new_cost)
        was_min = old_cost.min(axis=1) == row_best
        undercut = new_cost.min(axis=1) < row_best
        fresh = jnp.where(was_min | undercut, cost.min(axis=1),
                          row_best)
        moved = fresh != row_best
        row_best = fresh
        fresh_rows = jnp.where(mask, cost / row_best[:, None], 0.0)
        col_norm = jnp.where(sub_mask,
                             cost[:, cols] / row_best[:, None], 0.0)
        return prices, cost, row_best, fresh_rows, moved, col_norm

    def _jax_topk_fn() -> Any:
        """``topk(scores, finite, k)`` — ``jax.lax.top_k`` over the
        (possibly batched) score buffer with unprofiled configs masked
        to ``+inf``.  Scores rank ascending (lower is better), so the
        kernel negates; ``lax.top_k`` breaks value ties by lower index,
        which after negation is exactly the catalog-order tie-break of
        :func:`_materialize`.  ``k`` is static — one compile per
        requested depth, the same O(distinct shapes) discipline as the
        delta buckets."""
        global _JAX_TOPK_FN
        if _JAX_TOPK_FN is None:
            with _JAX_FNS_LOCK:
                if _JAX_TOPK_FN is None:
                    def topk(scores, finite, k):
                        masked = jnp.where(finite, scores, jnp.inf)
                        neg, idx = jax.lax.top_k(-masked, k)
                        return idx, -neg
                    _JAX_TOPK_FN = jax.jit(topk, static_argnums=2)
        return _JAX_TOPK_FN

    def _jax_state_fns() -> Tuple[Any, Any, Any]:
        """``(cold, step, winner)`` jitted kernels, built once on first
        use (so importing the selector never initializes an accelerator
        backend).  The step donates its five state buffers — a tick
        updates the resident arrays in place instead of allocating a
        fresh universe — except on CPU, whose client cannot donate and
        would warn on every call site."""
        global _JAX_STATE_FNS
        if _JAX_STATE_FNS is not None:
            return _JAX_STATE_FNS
        with _JAX_FNS_LOCK:
            if _JAX_STATE_FNS is not None:
                return _JAX_STATE_FNS
            return _build_jax_state_fns()

    def _build_jax_state_fns() -> Tuple[Any, Any, Any]:
        global _JAX_STATE_FNS

        def cold(hours, mask, prices):
            # the cold-path arithmetic (float32): the state a delta
            # stream starts from
            cost = jnp.where(mask, hours * prices[None, :], jnp.inf)
            row_best = jnp.min(cost, axis=1)
            norm = jnp.where(mask, cost / row_best[:, None], 0.0)
            return cost, row_best, norm, norm.sum(axis=0)

        def step(prices, cost, row_best, norm, scores, hours, mask,
                 cols, new_prices):
            (prices, cost, row_best, fresh_rows, moved,
             col_norm) = _delta_universe_update(prices, cost, row_best,
                                                hours, mask, cols,
                                                new_prices)
            # handed-off rows renormalize whole rows; the delta folds
            # into the standing score accumulators — the per-tick ulp
            # drift the jax ScoreContract tolerances cover (DESIGN.md §9)
            scores = scores + jnp.where(moved[:, None],
                                        fresh_rows - norm, 0.0).sum(axis=0)
            norm = jnp.where(moved[:, None], fresh_rows, norm)
            # changed columns re-sum from scratch with a .set — the
            # duplicate indices bucket padding introduces are idempotent
            # under .set (a .add of deltas would double-count them)
            norm = norm.at[:, cols].set(col_norm)
            scores = scores.at[cols].set(col_norm.sum(axis=0))
            return prices, cost, row_best, norm, scores, moved.sum()

        def winner(scores, finite):
            masked = jnp.where(finite, scores, jnp.inf)
            i = jnp.argmin(masked)
            return i, scores[i]

        donate = () if jax.default_backend() == "cpu" else (0, 1, 2, 3, 4)
        _JAX_STATE_FNS = (jax.jit(cold),
                          jax.jit(step, donate_argnums=donate),
                          jax.jit(winner))
        return _JAX_STATE_FNS


class JaxRankState:
    """Accelerator-resident incremental repricing (the jax backend).

    The float32 counterpart of :class:`RankState` for serving-scale
    universes: the runtime matrix, mask and every intermediate (cost,
    row-min, normalized-cost, score accumulators) live as device arrays,
    and :meth:`reprice` runs one jitted delta-update kernel whose state
    buffers are donated — a tick updates the universe in place, touching
    only the changed cost/norm columns plus the rows whose masked
    row-minimum handed off, with per-column score re-sums for changed
    columns and delta-folds for handed-off rows.  Host traffic per tick
    is the delta batch in and one scalar (the handoff count) out; a cold
    ``rank_dense(backend="jax")`` instead re-uploads the whole float64
    universe and re-materializes every ranking
    (``benchmarks/market_bench.py`` quantifies the gap).

    **Tolerance contract** (:data:`SCORE_CONTRACTS` ``["jax"]``): float32
    sums are not decomposable, and the delta-folded score accumulators
    drift by ulps per tick, so — unlike :class:`RankState` — rankings
    are *not* bit-identical to a cold re-rank.  The contract is
    same-winner-or-tied-within-tolerance, scores inside the rel/abs
    envelope; ``JournalReplayer.audit`` verifies journals produced
    through this path in exactly those terms (DESIGN.md §9).

    Delta batches are padded to power-of-4 column-count buckets so the
    jitted step compiles O(log C) shape variants, not one per batch
    size; padding repeats the first (column, price) pair, which every
    kernel op treats idempotently.
    """

    backend = "jax"
    contract = SCORE_CONTRACTS["jax"]
    _BUCKET_BASE = 8

    def __init__(self, hours: np.ndarray, mask: np.ndarray,
                 prices: np.ndarray, config_ids: Sequence[Hashable],
                 job_ids: Optional[Sequence[Hashable]] = None,
                 metrics: Optional[MetricsRegistry] = None):
        if not _HAVE_JAX:
            raise BackendUnavailableError(
                "JaxRankState requires jax; use RankState (numpy) "
                "when it is not installed")
        self.config_ids = list(config_ids)
        self.job_ids = list(job_ids) if job_ids is not None else None
        self._metrics = metrics
        self._c_mat = (None if metrics is None
                       else metrics.counter("rank.materializations"))
        hours, mask, prices = _canonicalize_universe(hours, mask, prices,
                                                     self.job_ids)
        self._pos = _position_index(self.config_ids)
        cold, self._step, self._winner_fn = _jax_state_fns()
        # read-only residents (uploaded once, never donated)
        self.d_hours = jnp.asarray(hours, dtype=jnp.float32)
        self.d_mask = jnp.asarray(mask)
        self.counts = mask.sum(axis=0)
        self._d_finite = jnp.asarray(self.counts > 0)
        # the donated state buffers
        self.d_prices = jnp.asarray(prices, dtype=jnp.float32)
        (self.d_cost, self.d_row_best, self.d_norm,
         self.d_scores) = cold(self.d_hours, self.d_mask, self.d_prices)
        #: ticks applied since construction (diagnostics, cache keys).
        self.reprices = 0
        #: host materializations actually performed: :meth:`ranking` is
        #: memoized on ``reprices``, so repeat calls between two ticks —
        #: previously a fresh device→host transfer + C-object build +
        #: sort *every call* — reuse the last sort (the counter the
        #: freshness regression test asserts on).
        self.materializations = 0
        self._ranking_memo: Optional[Tuple[int, List[RankedConfig]]] = None

    @property
    def prices(self) -> np.ndarray:
        """Current per-config $/h as seen by the kernel (float32 quotes
        lifted to a host float64 vector)."""
        return np.asarray(self.d_prices, dtype=np.float64)

    @property
    def scores(self) -> np.ndarray:
        """Current score accumulators on the host (float64 lift)."""
        return np.asarray(self.d_scores, dtype=np.float64)

    def reprice(self, deltas: Union[Mapping[Hashable, float],
                                    Sequence[Tuple[Hashable, float]]]
                ) -> int:
        """Apply ``{config_id: new $/h}`` deltas on device; returns
        #rows whose masked row-minimum handed off (synced to host, so a
        return means the tick's kernel has completed)."""
        prepared = _validated_delta_cols(self._pos, deltas,
                                         self._BUCKET_BASE)
        if prepared is None:
            return 0
        cols, new_prices = prepared
        (self.d_prices, self.d_cost, self.d_row_best, self.d_norm,
         self.d_scores, moved) = self._step(
            self.d_prices, self.d_cost, self.d_row_best, self.d_norm,
            self.d_scores, self.d_hours, self.d_mask,
            jnp.asarray(cols), jnp.asarray(new_prices, dtype=jnp.float32))
        self.reprices += 1
        return int(moved)

    def ranking(self) -> List[RankedConfig]:
        """The full sorted ranking under the tolerance contract: one
        device→host score transfer, then the same materialization as
        every other path (ties broken by catalog order).  Memoized on
        the state's tick count — the host sort used to re-run on *every*
        call even when no tick had been applied since the last
        materialization (the dominant serving cost at 10k configs); a
        fresh list copy is returned each call so the memo stays
        pristine."""
        if self._ranking_memo is None or \
                self._ranking_memo[0] != self.reprices:
            self.materializations += 1
            if self._c_mat is not None:
                self._c_mat.inc()
            with maybe_span(self._metrics, "rank.materialize"):
                self._ranking_memo = (
                    self.reprices,
                    _materialize(self.scores, self.counts,
                                 self.config_ids))
        return list(self._ranking_memo[1])

    def top_k(self, k: int) -> List[RankedConfig]:
        """The first ``k`` entries of :meth:`ranking` served from the
        device: ``jax.lax.top_k`` over the resident score buffer, then
        an O(k) readback — the full C-config materialize/sort never
        happens.  Tie-break (catalog order on equal scores) matches the
        materialized ranking; see :func:`_jax_topk_fn`."""
        k = _check_k(k, len(self.config_ids))
        idx, vals = _jax_topk_fn()(self.d_scores, self._d_finite, k)
        idx = np.asarray(idx)
        out = []
        for i, s in zip(idx, np.asarray(vals, dtype=np.float64)):
            n = int(self.counts[i])
            out.append(RankedConfig(
                self.config_ids[int(i)],
                float(s) if n else float("inf"),
                float(s) / n if n else float("inf")))
        return out

    def winner(self) -> RankedConfig:
        """argmin on device — only two scalars cross to the host."""
        i, s = self._winner_fn(self.d_scores, self._d_finite)
        i = int(i)
        c = self.config_ids[i]
        if not self.counts[i]:
            return RankedConfig(c, float("inf"), float("inf"))
        return RankedConfig(c, float(s), float(s) / int(self.counts[i]))


# --- batched multi-state repricing (jax_batched backend) --------------------------

if _HAVE_JAX:
    _JAX_BATCHED_FNS: Optional[Tuple[Any, Any]] = None

    def _jax_batched_fns() -> Tuple[Any, Any]:
        """``(step, member_scores)`` jitted kernels for
        :class:`BatchedRankState`, built once on first use.

        The key observation that makes batching cheap (DESIGN.md §10):
        every member state shares the store's profiled mask, so the
        masked row-minimum — and therefore the whole normalized-cost
        matrix — is *identical* across members.  A member's scores are
        just a row-masked column reduction of the one shared norm
        matrix:

            scores[s, c] = Σ_j row_masks[s, j] · norm[j, c]

        so the per-tick step updates the shared cost/row-min/norm
        buffers exactly like :class:`JaxRankState`'s kernel and then
        refreshes *all* member accumulators with two small matmuls
        (handed-off-row deltas folded in; changed columns re-reduced
        from scratch) — one dispatch for the whole fleet, independent
        of the member count."""
        global _JAX_BATCHED_FNS
        if _JAX_BATCHED_FNS is not None:
            return _JAX_BATCHED_FNS
        with _JAX_FNS_LOCK:
            if _JAX_BATCHED_FNS is not None:
                return _JAX_BATCHED_FNS
            return _build_jax_batched_fns()

    def _build_jax_batched_fns() -> Tuple[Any, Any]:
        global _JAX_BATCHED_FNS

        def step(prices, cost, row_best, norm, scores, hours, mask,
                 row_masks, cols, new_prices):
            # the universe half is the SAME traced helper as the
            # per-state kernel — the backends cannot diverge on it
            (prices, cost, row_best, fresh_rows, moved,
             col_norm) = _delta_universe_update(prices, cost, row_best,
                                                hours, mask, cols,
                                                new_prices)
            # -- handed-off rows: fold the renormalization delta into
            #    every member's standing accumulators at once (S×J @
            #    J×C; rows that did not move contribute exact zeros, so
            #    a tick with no handoffs is drift-free here)
            row_delta = jnp.where(moved[:, None], fresh_rows - norm, 0.0)
            scores = scores + row_masks @ row_delta
            norm = jnp.where(moved[:, None], fresh_rows, norm)
            # -- changed columns: re-reduce every member from scratch
            #    with a .set — idempotent under the duplicate indices
            #    the power-of-4 bucket padding introduces
            norm = norm.at[:, cols].set(col_norm)
            scores = scores.at[:, cols].set(row_masks @ col_norm)
            return prices, cost, row_best, norm, scores, moved.sum()

        def member_scores(norm, row_mask):
            # a new member's accumulators from the current shared norm
            return row_mask @ norm

        donate = () if jax.default_backend() == "cpu" else (0, 1, 2, 3, 4)
        _JAX_BATCHED_FNS = (jax.jit(step, donate_argnums=donate),
                            jax.jit(member_scores))
        return _JAX_BATCHED_FNS


class BatchedRankState:
    """One device dispatch per tick for a whole fleet of rankings.

    The serving problem this solves (DESIGN.md §10): a live
    :class:`~repro.selector.SelectionService` holds one ranking state
    per (job class, exclusion set) — a *fleet* of states over the same
    profiling store.  With per-state :class:`JaxRankState`\\ s a price
    tick is one kernel dispatch *per state*; ``BatchedRankState`` stacks
    the fleet over a single shared device-resident universe — hours,
    profiled mask, cost, row-min and normalized-cost buffers are stored
    **once** (they are member-independent: every member shares the
    store's mask, so the masked row minima are identical) — with the
    per-member structure reduced to a row-mask matrix (S×J) and a score
    accumulator matrix (S×C), both carrying the member axis in front.
    :meth:`reprice` then runs one batched jitted delta-update kernel
    (donated state buffers, the same power-of-4 delta bucketing as
    :class:`JaxRankState`) that refreshes every member's scores in the
    same dispatch.

    Members are added (:meth:`add_state`) and retired
    (:meth:`retire_state`) mid-stream; slot capacity grows by doubling,
    so the step kernel compiles O(log S) member-axis variants, and
    retired slots are zero-masked (they contribute nothing and are
    reused by later adds).

    Serving is per member: :meth:`ranking` materializes the full sorted
    list (memoized on the tick count), :meth:`top_k` serves the head of
    the ranking straight from the device score buffer
    (``jax.lax.top_k`` + an O(k) readback — the C-object build/sort
    never happens), and :meth:`winner` is ``top_k(1)``.

    **Contract** (:data:`SCORE_CONTRACTS` ``["jax_batched"]``): same
    float32 tolerance envelope as the per-state jax kernel — batching
    adds no drift source beyond the member-axis reduction order, which
    the shared rel/abs tolerances already cover (DESIGN.md §10).
    """

    backend = "jax_batched"
    contract = SCORE_CONTRACTS["jax_batched"]
    _BUCKET_BASE = 8
    _CAPACITY_BASE = 8

    def __init__(self, hours: np.ndarray, mask: np.ndarray,
                 prices: np.ndarray, config_ids: Sequence[Hashable],
                 job_ids: Optional[Sequence[Hashable]] = None,
                 capacity: Optional[int] = None,
                 metrics: Optional[MetricsRegistry] = None):
        if not _HAVE_JAX:
            raise BackendUnavailableError(
                "BatchedRankState requires jax; use RankState (numpy) "
                "when it is not installed")
        self.config_ids = list(config_ids)
        self.job_ids = list(job_ids) if job_ids is not None else None
        self._metrics = metrics
        self._c_mat = (None if metrics is None
                       else metrics.counter("rank.materializations"))
        hours, mask, prices = _canonicalize_universe(hours, mask, prices,
                                                     self.job_ids)
        self._pos = _position_index(self.config_ids)
        self._job_pos = (None if self.job_ids is None else
                         {j: i for i, j in enumerate(self.job_ids)})
        self._mask = mask                     # host copy: member counts
        self._n_jobs = hours.shape[0]
        cold = _jax_state_fns()[0]
        self._step, self._member_scores = _jax_batched_fns()
        # shared read-only residents (uploaded once, never donated)
        self.d_hours = jnp.asarray(hours, dtype=jnp.float32)
        self.d_mask = jnp.asarray(mask)
        # shared donated state buffers (the universe)
        self.d_prices = jnp.asarray(prices, dtype=jnp.float32)
        (self.d_cost, self.d_row_best, self.d_norm,
         _) = cold(self.d_hours, self.d_mask, self.d_prices)
        # the member axis: slot tables + batched accumulators
        cap = self._CAPACITY_BASE if capacity is None else max(1, capacity)
        self._capacity = cap
        self._slots: "dict[Hashable, int]" = {}
        #: keys retired via :meth:`retire_state`; serving one raises
        #: :class:`NothingRankableError` (a never-registered key stays a
        #: plain ``ValueError`` — that is caller misconfiguration).
        self._retired: "set" = set()
        self._free: List[int] = list(range(cap - 1, -1, -1))
        self.d_row_masks = jnp.zeros((cap, self._n_jobs),
                                     dtype=jnp.float32)
        self.d_scores = jnp.zeros((cap, len(self.config_ids)),
                                  dtype=jnp.float32)
        self._counts = np.zeros((cap, len(self.config_ids)),
                                dtype=np.int64)
        self._d_finite = jnp.zeros((cap, len(self.config_ids)),
                                   dtype=bool)
        #: ticks applied since construction; one tick == one kernel
        #: dispatch regardless of the member count (the benchmark's
        #: ``one_dispatch_per_tick`` gate reads this).
        self.reprices = 0
        #: alias making the dispatch accounting explicit at call sites.
        self.dispatches = 0
        #: capacity doublings since construction.  A retire-all /
        #: re-add cycle must reuse the zero-masked slots and leave this
        #: untouched (regression-pinned) — growth is for genuinely new
        #: concurrent members only.
        self.realloc_count = 0
        self.materializations = 0
        self._ranking_memo: "dict[Hashable, Tuple[int, List[RankedConfig]]]" = {}

    # -- member management --------------------------------------------------
    def __contains__(self, key: Hashable) -> bool:
        return key in self._slots

    @property
    def n_active(self) -> int:
        """Live member count (what one tick dispatch refreshes)."""
        return len(self._slots)

    def keys(self) -> List[Hashable]:
        return list(self._slots)

    def _slot_of(self, key: Hashable) -> int:
        try:
            return self._slots[key]
        except KeyError:
            if key in self._retired:
                # a member that *was* live and has been retired: serving
                # it is a rankable-nothing condition, not a caller bug —
                # typed so the service/daemon path journals a genuine
                # rejection instead of dying on the masked slot
                raise NothingRankableError(
                    f"member state {key!r} was retired")
            raise ValueError(f"unknown member state {key!r}")

    def _grow(self) -> None:
        cap = self._capacity * 2
        self.d_row_masks = jnp.zeros(
            (cap, self._n_jobs), dtype=jnp.float32
        ).at[:self._capacity].set(self.d_row_masks)
        self.d_scores = jnp.zeros(
            (cap, len(self.config_ids)), dtype=jnp.float32
        ).at[:self._capacity].set(self.d_scores)
        self._d_finite = jnp.zeros(
            (cap, len(self.config_ids)), dtype=bool
        ).at[:self._capacity].set(self._d_finite)
        counts = np.zeros((cap, len(self.config_ids)), dtype=np.int64)
        counts[:self._capacity] = self._counts
        self._counts = counts
        self._free.extend(range(cap - 1, self._capacity - 1, -1))
        self._capacity = cap
        self.realloc_count += 1

    def _rows_of(self, rows: Optional[Sequence[int]],
                 jobs: Optional[Sequence[Hashable]]) -> np.ndarray:
        if (rows is None) == (jobs is None):
            raise ValueError("pass exactly one of rows= or jobs=")
        if jobs is not None:
            if self._job_pos is None:
                raise ValueError(
                    "jobs= needs a state constructed with job_ids")
            try:
                rows = [self._job_pos[j] for j in jobs]
            except KeyError as e:
                raise ValueError(f"unknown job id {e.args[0]!r}")
        idx = np.asarray(list(rows), dtype=np.intp)
        if idx.size and (idx.min() < 0 or idx.max() >= self._n_jobs):
            raise ValueError(f"row index out of range for "
                             f"{self._n_jobs} jobs")
        if np.unique(idx).size != idx.size:
            raise ValueError("duplicate rows in member selection")
        return idx

    def add_state(self, key: Hashable, *,
                  rows: Optional[Sequence[int]] = None,
                  jobs: Optional[Sequence[Hashable]] = None) -> None:
        """Register a member ranking over a subset of the job axis
        (``rows`` indices, or ``jobs`` ids when the state was built with
        ``job_ids``).  The member's accumulators are computed from the
        *current* shared norm matrix, so a member added mid-stream is
        immediately in sync with every tick applied so far."""
        if key in self._slots:
            raise ValueError(f"duplicate member state {key!r}")
        self._retired.discard(key)      # re-registering revives the key
        idx = self._rows_of(rows, jobs)
        if not self._free:
            self._grow()
        slot = self._free.pop()
        row_mask = np.zeros(self._n_jobs, dtype=np.float32)
        row_mask[idx] = 1.0
        counts = self._mask[idx].sum(axis=0) if idx.size else \
            np.zeros(len(self.config_ids), dtype=np.int64)
        d_row = jnp.asarray(row_mask)
        self.d_row_masks = self.d_row_masks.at[slot].set(d_row)
        self.d_scores = self.d_scores.at[slot].set(
            self._member_scores(self.d_norm, d_row))
        self._counts[slot] = counts
        self._d_finite = self._d_finite.at[slot].set(
            jnp.asarray(counts > 0))
        self._slots[key] = slot

    def retire_state(self, key: Hashable) -> None:
        """Drop a member: its slot is zero-masked (contributes nothing
        to later ticks) and reused by the next :meth:`add_state`.
        Serving a retired key afterwards raises
        :class:`NothingRankableError` — never a raw ``KeyError`` or a
        masked-slot score — so service/daemon callers journal a genuine
        rejection (DESIGN.md §10)."""
        slot = self._slots.pop(key, None)
        if slot is None:
            raise ValueError(f"unknown member state {key!r}")
        zeros_j = jnp.zeros(self._n_jobs, dtype=jnp.float32)
        self.d_row_masks = self.d_row_masks.at[slot].set(zeros_j)
        self.d_scores = self.d_scores.at[slot].set(
            jnp.zeros(len(self.config_ids), dtype=jnp.float32))
        self._counts[slot] = 0
        self._d_finite = self._d_finite.at[slot].set(
            jnp.zeros(len(self.config_ids), dtype=bool))
        self._ranking_memo.pop(key, None)
        self._retired.add(key)
        self._free.append(slot)

    # -- the batched tick ---------------------------------------------------
    @property
    def prices(self) -> np.ndarray:
        """Current per-config $/h as seen by the kernel (float32 quotes
        lifted to a host float64 vector)."""
        return np.asarray(self.d_prices, dtype=np.float64)

    def scores(self, key: Hashable) -> np.ndarray:
        """A member's score accumulators on the host (float64 lift)."""
        return np.asarray(self.d_scores[self._slot_of(key)],
                          dtype=np.float64)

    def counts(self, key: Hashable) -> np.ndarray:
        """A member's per-config contributing-cell counts."""
        return self._counts[self._slot_of(key)].copy()

    def reprice(self, deltas: Union[Mapping[Hashable, float],
                                    Sequence[Tuple[Hashable, float]]]
                ) -> int:
        """Apply ``{config_id: new $/h}`` deltas to the shared universe
        and refresh **every** member's accumulators in one batched
        kernel dispatch; returns #rows whose masked row-minimum handed
        off (synced to host, so a return means the tick's kernel has
        completed)."""
        prepared = _validated_delta_cols(self._pos, deltas,
                                         self._BUCKET_BASE)
        if prepared is None:
            return 0
        cols, new_prices = prepared
        (self.d_prices, self.d_cost, self.d_row_best, self.d_norm,
         self.d_scores, moved) = self._step(
            self.d_prices, self.d_cost, self.d_row_best, self.d_norm,
            self.d_scores, self.d_hours, self.d_mask, self.d_row_masks,
            jnp.asarray(cols), jnp.asarray(new_prices, dtype=jnp.float32))
        self.reprices += 1
        self.dispatches += 1
        return int(moved)

    # -- per-member serving -------------------------------------------------
    def ranking(self, key: Hashable) -> List[RankedConfig]:
        """A member's full sorted ranking under the tolerance contract
        (memoized on the tick count, like the other states; a fresh
        list copy is returned each call)."""
        memo = self._ranking_memo.get(key)
        if memo is None or memo[0] != self.reprices:
            slot = self._slot_of(key)
            self.materializations += 1
            if self._c_mat is not None:
                self._c_mat.inc()
            with maybe_span(self._metrics, "rank.materialize"):
                memo = (self.reprices,
                        _materialize(self.scores(key), self._counts[slot],
                                     self.config_ids))
            self._ranking_memo[key] = memo
        return list(memo[1])

    def top_k(self, key: Hashable, k: int) -> List[RankedConfig]:
        """The head of a member's ranking served from the device score
        buffer: ``jax.lax.top_k`` on the member's row plus an O(k)
        readback — no C-object materialization, same catalog-order
        tie-break as :meth:`ranking` (see :func:`_jax_topk_fn`)."""
        slot = self._slot_of(key)
        k = _check_k(k, len(self.config_ids))
        idx, vals = _jax_topk_fn()(self.d_scores[slot],
                                   self._d_finite[slot], k)
        counts = self._counts[slot]
        out = []
        for i, s in zip(np.asarray(idx), np.asarray(vals,
                                                    dtype=np.float64)):
            n = int(counts[i])
            out.append(RankedConfig(
                self.config_ids[int(i)],
                float(s) if n else float("inf"),
                float(s) / n if n else float("inf")))
        return out

    def winner(self, key: Hashable) -> RankedConfig:
        """The member's top pick — ``top_k(key, 1)`` on device."""
        return self.top_k(key, 1)[0]
