"""Vectorized normalized-cost ranking (paper §II, step 2).

The ranking is one matrix computation instead of a per-pair dict loop:

    cost   = runtime_hours (J x C)  *  price_vector (C,)     # broadcast
    norm   = cost / row-min(cost over profiled cells)        # row-normalize
    score  = column-sum of norm over profiled cells          # per config

A config with **zero** profiled cells scores ``+inf`` and therefore ranks
last (an unprofiled config must never win by default — the historical dict
loop left it at 0.0, i.e. argmin).

Two backends:

  * ``"numpy"`` (default): float64, bit-stable with the historical
    per-pair arithmetic — used for the paper-table reproductions;
  * ``"jax"``: a jitted ``jax.numpy`` kernel (float32 on CPU/TPU) that
    fuses the whole ranking into one XLA computation — the serving-scale
    path for 10k+ (job x config) cells, benchmarked in
    ``benchmarks/rank_bench.py``.

Each backend carries an explicit :class:`ScoreContract` (DESIGN.md §9):
numpy guarantees bit-identity between the incremental and cold paths;
jax is float32 and guarantees the same winner (or a winner tied within
tolerance) with scores inside a rel/abs envelope.  Incremental repricing
lives in :class:`RankState` (numpy) and :class:`JaxRankState` (the
accelerator-resident jitted delta-update kernel with donated buffers).
"""
from __future__ import annotations

import dataclasses
import os
from typing import (Any, Callable, Hashable, List, Mapping, Optional,
                    Sequence, Tuple, Union)

import numpy as np

try:  # accelerator path; the selector core works without jax installed
    import jax
    import jax.numpy as jnp
    _HAVE_JAX = True
except ImportError:  # pragma: no cover
    _HAVE_JAX = False

#: the knob CI's backend matrix turns; resolved by :func:`default_backend`.
BACKEND_ENV_VAR = "FLORA_RANK_BACKEND"
BACKENDS = ("numpy", "jax")


class BackendUnavailableError(RuntimeError):
    """A ranking backend was requested whose runtime dependency is not
    installed (today: ``backend="jax"`` without jax).  Typed so callers —
    and test harnesses — can skip rather than die: distinguishable from
    both misconfiguration ``ValueError``\\ s (unknown backend names) and
    genuine crashes."""


def default_backend() -> str:
    """The backend used when a :class:`~repro.selector.SelectionService`
    is built without an explicit ``backend=``: the ``FLORA_RANK_BACKEND``
    env var, else ``"numpy"``.  ``rank_dense`` itself always defaults to
    numpy — the float64 bit-stable reference that replay audits re-rank
    against must not move under the env var."""
    backend = os.environ.get(BACKEND_ENV_VAR, "numpy")
    if backend not in BACKENDS:
        raise ValueError(
            f"unknown backend {backend!r} in ${BACKEND_ENV_VAR} "
            f"(expected one of {BACKENDS})")
    return backend


@dataclasses.dataclass(frozen=True)
class ScoreContract:
    """What a backend promises about incremental-vs-cold score equality.

    * numpy/float64: **bit-identical** — the incremental
      :class:`RankState` recomputes updated cells with the cold path's
      exact elementwise arithmetic and re-reduces scores with the same
      full ``norm.sum(axis=0)``, so any reprice sequence equals a cold
      ``rank_dense`` down to the last ulp (``rel_tol == abs_tol == 0``).
    * jax/float32: **same-winner-or-tied within tolerance** — float32
      has no bit-identity story for delta updates (DESIGN.md §9): the
      jitted kernel folds per-tick deltas into standing score
      accumulators, so scores drift by ulps per tick, and two configs
      whose true scores are closer than the drift may swap.  The
      contract is that every score lies within ``rel_tol``/``abs_tol``
      of the cold value and the reported winner is either identical to
      the cold winner or tied with it within the same envelope.
    """

    backend: str
    bit_identical: bool
    rel_tol: float = 0.0
    abs_tol: float = 0.0

    def scores_match(self, a: float, b: float) -> bool:
        """Are two scores equal under this contract?  (``inf == inf``
        counts: unprofiled configs score ``+inf`` on every backend.)"""
        if a == b:
            return True
        if self.bit_identical:
            return False
        return abs(a - b) <= self.abs_tol + self.rel_tol * max(abs(a),
                                                               abs(b))

    def winner_matches(self, config_id: Hashable,
                       ranking: Sequence["RankedConfig"]) -> bool:
        """Is ``config_id`` an acceptable winner against a cold
        ``ranking``?  Identical to the cold winner always qualifies; a
        tolerance backend also accepts a config whose *cold* score ties
        the cold winner's within the contract (float32 drift can swap
        near-ties, never separated configs)."""
        if not ranking:
            return False
        if config_id == ranking[0].config_id:
            return True
        if self.bit_identical:
            return False
        for r in ranking:
            if r.config_id == config_id:
                return self.scores_match(r.score, ranking[0].score)
        return False


#: Per-backend contracts.  The jax tolerances cover float32 rounding of
#: the inputs (~1e-7 relative) plus delta-accumulation drift across
#: ticks, with two orders of magnitude of headroom (DESIGN.md §9).
SCORE_CONTRACTS: Mapping[str, ScoreContract] = {
    "numpy": ScoreContract("numpy", bit_identical=True),
    "jax": ScoreContract("jax", bit_identical=False,
                         rel_tol=1e-4, abs_tol=1e-6),
}


def backend_available(backend: str) -> bool:
    """Can ``backend`` actually run here?  ``"numpy"`` always; ``"jax"``
    only when jax imports.  Unknown names are *not* an error from this
    predicate (they fail later with ``ValueError`` at dispatch)."""
    return backend != "jax" or _HAVE_JAX


def score_contract(backend: str) -> ScoreContract:
    """The :class:`ScoreContract` for ``backend`` (raises on unknown)."""
    try:
        return SCORE_CONTRACTS[backend]
    except KeyError:
        raise ValueError(f"unknown backend {backend!r} "
                         f"(expected one of {BACKENDS})")


class NothingRankableError(ValueError):
    """The selection has no rankable universe — an empty job selection or
    an entirely-unprofiled catalog.  A routine per-submission outcome
    (e.g. an exclusion set that empties a class), distinct from the other
    ``ValueError``\\ s raised here, which indicate misconfiguration (shape
    mismatches, missing price sources, broken traces) and should never be
    swallowed as a rejection."""


@dataclasses.dataclass(frozen=True)
class RankedConfig:
    config_id: Hashable
    score: float           # sum of normalized costs; lower is better
    mean_norm_cost: float  # score / number of contributing test jobs


def _canonicalize_universe(
        hours: np.ndarray, mask: np.ndarray, prices: np.ndarray,
        job_ids: Optional[Sequence[Hashable]]
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Shared input validation for every dense entry point
    (:func:`rank_dense`, :class:`RankState`, :class:`JaxRankState`):
    canonicalize dtypes, check shapes, reject empty job axes and
    non-positive profiled costs (both indicate a broken trace, not a
    rankable universe)."""
    hours = np.asarray(hours, dtype=np.float64)
    mask = np.asarray(mask, dtype=bool)
    prices = np.asarray(prices, dtype=np.float64)
    if hours.shape != mask.shape or hours.shape[1] != prices.shape[0]:
        raise ValueError(f"shape mismatch: hours {hours.shape}, "
                         f"mask {mask.shape}, prices {prices.shape}")
    if hours.shape[0] == 0:
        raise NothingRankableError("no test jobs to learn from")
    bad = mask & ~((hours * prices[None, :]) > 0)
    if bad.any():
        row = int(np.argwhere(bad)[0][0])
        job = job_ids[row] if job_ids is not None else row
        raise ValueError(f"non-positive cost for job {job!r}")
    return hours, mask, prices


def _position_index(config_ids: Sequence[Hashable]
                    ) -> "dict[Hashable, int]":
    """Config id -> column position; rejects duplicates (the states key
    reprice deltas on it, so a duplicate would silently alias columns)."""
    pos = {c: i for i, c in enumerate(config_ids)}
    if len(pos) != len(config_ids):
        raise ValueError("duplicate config ids")
    return pos


def _scores_numpy(hours: np.ndarray, mask: np.ndarray, prices: np.ndarray
                  ) -> Tuple[np.ndarray, np.ndarray]:
    cost = np.where(mask, hours * prices[None, :], np.inf)
    row_best = np.min(cost, axis=1, initial=np.inf)
    with np.errstate(invalid="ignore"):
        norm = np.where(mask, cost / row_best[:, None], 0.0)
    return norm.sum(axis=0), mask.sum(axis=0)


def _materialize(scores: np.ndarray, counts: np.ndarray,
                 config_ids: Sequence[Hashable]) -> List[RankedConfig]:
    """Scores/counts -> sorted RankedConfig list (shared by the cold and
    incremental paths so their rankings are identical by construction)."""
    ranked = [
        RankedConfig(
            c,
            float(scores[i]) if counts[i] else float("inf"),
            float(scores[i] / counts[i]) if counts[i] else float("inf"))
        for i, c in enumerate(config_ids)]
    order = {c: i for i, c in enumerate(config_ids)}
    ranked.sort(key=lambda r: (r.score, order[r.config_id]))
    return ranked


if _HAVE_JAX:
    @jax.jit
    def _scores_jax(hours, mask, prices):
        cost = jnp.where(mask, hours * prices[None, :], jnp.inf)
        row_best = jnp.min(cost, axis=1)
        norm = jnp.where(mask, cost / row_best[:, None], 0.0)
        return norm.sum(axis=0), mask.sum(axis=0)


def rank_dense(hours: np.ndarray, mask: np.ndarray, prices: np.ndarray,
               config_ids: Sequence[Hashable],
               job_ids: Optional[Sequence[Hashable]] = None,
               backend: str = "numpy") -> List[RankedConfig]:
    """Rank configs from dense (J x C) runtime-hours + profiled-mask.

    ``prices`` is the current $/h per config, aligned with ``config_ids``.
    Raises on an empty job axis and on non-positive profiled costs (both
    indicate a broken trace, not a rankable universe).
    """
    hours, mask, prices = _canonicalize_universe(hours, mask, prices,
                                                 job_ids)
    if backend == "jax":
        if not _HAVE_JAX:
            raise BackendUnavailableError(
                "backend='jax' requested but jax is not installed "
                "(the numpy backend needs no extras)")
        scores, counts = (np.asarray(x) for x in _scores_jax(
            jnp.asarray(hours), jnp.asarray(mask), jnp.asarray(prices)))
    elif backend == "numpy":
        scores, counts = _scores_numpy(hours, mask, prices)
    else:
        raise ValueError(f"unknown backend {backend!r}")
    return _materialize(scores, counts, config_ids)


def rank_pairs(
    runtime_hours: Mapping[Tuple[Hashable, Hashable], float],
    jobs: Sequence[Hashable],
    config_ids: Sequence[Hashable],
    hourly_cost: Union[Callable[[Hashable], float], Mapping[Hashable, float]],
    backend: str = "numpy",
) -> List[RankedConfig]:
    """Rank from sparse ``{(job, config): hours}`` pairs (legacy shape).

    Densifies and dispatches to :func:`rank_dense`; kept so existing
    callers of ``repro.core.flora.rank_generic`` keep one code path.
    """
    if not jobs:
        raise NothingRankableError("no test jobs to learn from")
    price_of = hourly_cost if callable(hourly_cost) else hourly_cost.__getitem__
    hours = np.zeros((len(jobs), len(config_ids)))
    mask = np.zeros_like(hours, dtype=bool)
    for r, j in enumerate(jobs):
        for k, c in enumerate(config_ids):
            v = runtime_hours.get((j, c))
            if v is not None:
                hours[r, k] = v
                mask[r, k] = True
    prices = np.asarray([price_of(c) for c in config_ids], dtype=np.float64)
    return rank_dense(hours, mask, prices, config_ids, job_ids=list(jobs),
                      backend=backend)


class RankState:
    """Incremental repricing over a fixed (job x config) runtime matrix.

    The live-market path (DESIGN.md §6): when only k of C prices move in a
    tick, a full :func:`rank_dense` recomputes every intermediate from
    scratch — cost broadcast, row-min, normalize, sum, plus building and
    sorting C ``RankedConfig`` objects.  ``RankState`` instead keeps the
    dense intermediates (cost, row-min, normalized-cost matrices) alive and
    on :meth:`reprice` touches only

      * the k changed cost/norm columns, and
      * the rows whose masked row-minimum was or becomes a changed column
        (every cell of those rows renormalizes).

    **Bit-identity contract**: scores after any ``reprice`` sequence are
    bit-identical to a cold ``rank_dense`` at the same prices.  Updated
    cells are recomputed with the exact elementwise arithmetic of the cold
    path, and scores are reduced with the same full ``norm.sum(axis=0)``
    (numpy's pairwise summation is *not* decomposable, so per-column delta
    updates would drift by ulps — the one full pass over the norm matrix is
    the price of exactness, and it is still ~100x cheaper than the cold
    path at 10k configs; see ``benchmarks/market_bench.py``).

    numpy/float64 only — float32 has no exact incremental story, so the
    jax backend's accelerator-resident counterpart,
    :class:`JaxRankState`, serves a *tolerance* contract instead
    (same winner or tied within tolerance; see :class:`ScoreContract`
    and DESIGN.md §9).
    """

    def __init__(self, hours: np.ndarray, mask: np.ndarray,
                 prices: np.ndarray, config_ids: Sequence[Hashable],
                 job_ids: Optional[Sequence[Hashable]] = None):
        self.config_ids = list(config_ids)
        self.job_ids = list(job_ids) if job_ids is not None else None
        self.hours, self.mask, self.prices = _canonicalize_universe(
            hours, mask, prices, self.job_ids)
        self.prices = self.prices.copy()        # mutated by reprice
        self._pos = _position_index(self.config_ids)
        #: ticks applied since construction (diagnostics, cache keys).
        self.reprices = 0
        self._rebuild()

    def _check_positive(self, mask: np.ndarray, cost: np.ndarray) -> None:
        bad = mask & ~(cost > 0)
        if bad.any():
            row = int(np.argwhere(bad)[0][0])
            job = self.job_ids[row] if self.job_ids is not None else row
            raise ValueError(f"non-positive cost for job {job!r}")

    def _rebuild(self) -> None:
        # the cold-path arithmetic, verbatim (bit-identity anchor)
        self.cost = np.where(self.mask, self.hours * self.prices[None, :],
                             np.inf)
        self.row_best = np.min(self.cost, axis=1, initial=np.inf)
        with np.errstate(invalid="ignore"):
            self.norm = np.where(self.mask,
                                 self.cost / self.row_best[:, None], 0.0)
        self.scores = self.norm.sum(axis=0)
        self.counts = self.mask.sum(axis=0)

    def reprice(self, deltas: Union[Mapping[Hashable, float],
                                    Sequence[Tuple[Hashable, float]]]) -> int:
        """Apply ``{config_id: new $/h}`` deltas; returns #rows whose
        masked row-minimum moved (the expensive case)."""
        table = deltas if isinstance(deltas, Mapping) else dict(deltas)
        if not table:
            return 0
        try:
            cols = np.asarray([self._pos[c] for c in table], dtype=np.intp)
        except KeyError as e:
            raise ValueError(f"unknown config id in deltas: {e.args[0]!r}")
        new_prices = np.asarray(list(table.values()), dtype=np.float64)
        # same elementwise ops as the cold broadcast -> bit-identical cells
        new_cost = np.where(self.mask[:, cols],
                            self.hours[:, cols] * new_prices[None, :],
                            np.inf)
        self._check_positive(self.mask[:, cols], new_cost)
        old_cost = self.cost[:, cols]
        self.prices[cols] = new_prices
        self.cost[:, cols] = new_cost
        # rows whose masked minimum was in a changed column, or where a
        # changed column undercuts the old minimum, need a fresh row-min
        was_min = old_cost.min(axis=1, initial=np.inf) == self.row_best
        undercut = new_cost.min(axis=1, initial=np.inf) < self.row_best
        candidates = np.flatnonzero(was_min | undercut)
        moved = np.array([], dtype=np.intp)
        if candidates.size:
            fresh = np.min(self.cost[candidates, :], axis=1, initial=np.inf)
            changed = fresh != self.row_best[candidates]
            moved = candidates[changed]
            self.row_best[moved] = fresh[changed]
        with np.errstate(invalid="ignore"):
            self.norm[:, cols] = np.where(
                self.mask[:, cols],
                self.cost[:, cols] / self.row_best[:, None], 0.0)
            if moved.size:
                self.norm[moved, :] = np.where(
                    self.mask[moved, :],
                    self.cost[moved, :] / self.row_best[moved, None], 0.0)
        # full-matrix reduction, identical to the cold path (see docstring)
        self.scores = self.norm.sum(axis=0)
        self.reprices += 1
        return int(moved.size)

    def ranking(self) -> List[RankedConfig]:
        """The full sorted ranking (bit-identical to ``rank_dense``)."""
        return _materialize(self.scores, self.counts, self.config_ids)

    def winner(self) -> RankedConfig:
        """argmin only — O(C), no list build/sort.  A cheap peek for
        callers that only need the top pick; the serving path proper goes
        through :meth:`ranking`, since a ``Decision`` always carries the
        full sorted list."""
        finite = self.counts > 0
        if not finite.any():
            i = 0
        else:
            masked = np.where(finite, self.scores, np.inf)
            i = int(np.argmin(masked))
        c = self.config_ids[i]
        s = float(self.scores[i]) if self.counts[i] else float("inf")
        m = float(self.scores[i] / self.counts[i]) if self.counts[i] \
            else float("inf")
        return RankedConfig(c, s, m)


# --- the accelerator-resident incremental path (jax backend) ----------------------

if _HAVE_JAX:
    _JAX_STATE_FNS: Optional[Tuple[Any, Any, Any]] = None

    def _jax_state_fns() -> Tuple[Any, Any, Any]:
        """``(cold, step, winner)`` jitted kernels, built once on first
        use (so importing the selector never initializes an accelerator
        backend).  The step donates its five state buffers — a tick
        updates the resident arrays in place instead of allocating a
        fresh universe — except on CPU, whose client cannot donate and
        would warn on every call site."""
        global _JAX_STATE_FNS
        if _JAX_STATE_FNS is not None:
            return _JAX_STATE_FNS

        def cold(hours, mask, prices):
            # the cold-path arithmetic (float32): the state a delta
            # stream starts from
            cost = jnp.where(mask, hours * prices[None, :], jnp.inf)
            row_best = jnp.min(cost, axis=1)
            norm = jnp.where(mask, cost / row_best[:, None], 0.0)
            return cost, row_best, norm, norm.sum(axis=0)

        def step(prices, cost, row_best, norm, scores, hours, mask,
                 cols, new_prices):
            # -- changed columns: gather, recompute cells, scatter back
            sub_mask = mask[:, cols]
            new_cost = jnp.where(sub_mask,
                                 hours[:, cols] * new_prices[None, :],
                                 jnp.inf)
            old_cost = cost[:, cols]
            prices = prices.at[cols].set(new_prices)
            cost = cost.at[:, cols].set(new_cost)
            # -- min-handoff rows: the masked row-minimum was in a
            #    changed column, or a changed column undercuts it
            was_min = old_cost.min(axis=1) == row_best
            undercut = new_cost.min(axis=1) < row_best
            fresh = jnp.where(was_min | undercut, cost.min(axis=1),
                              row_best)
            moved = fresh != row_best
            row_best = fresh
            # handed-off rows renormalize whole rows; the delta folds
            # into the standing score accumulators — the per-tick ulp
            # drift the jax ScoreContract tolerances cover (DESIGN.md §9)
            fresh_rows = jnp.where(mask, cost / row_best[:, None], 0.0)
            scores = scores + jnp.where(moved[:, None],
                                        fresh_rows - norm, 0.0).sum(axis=0)
            norm = jnp.where(moved[:, None], fresh_rows, norm)
            # changed columns re-sum from scratch with a .set — the
            # duplicate indices bucket padding introduces are idempotent
            # under .set (a .add of deltas would double-count them)
            col_norm = jnp.where(sub_mask,
                                 cost[:, cols] / row_best[:, None], 0.0)
            norm = norm.at[:, cols].set(col_norm)
            scores = scores.at[cols].set(col_norm.sum(axis=0))
            return prices, cost, row_best, norm, scores, moved.sum()

        def winner(scores, finite):
            masked = jnp.where(finite, scores, jnp.inf)
            i = jnp.argmin(masked)
            return i, scores[i]

        donate = () if jax.default_backend() == "cpu" else (0, 1, 2, 3, 4)
        _JAX_STATE_FNS = (jax.jit(cold),
                          jax.jit(step, donate_argnums=donate),
                          jax.jit(winner))
        return _JAX_STATE_FNS


class JaxRankState:
    """Accelerator-resident incremental repricing (the jax backend).

    The float32 counterpart of :class:`RankState` for serving-scale
    universes: the runtime matrix, mask and every intermediate (cost,
    row-min, normalized-cost, score accumulators) live as device arrays,
    and :meth:`reprice` runs one jitted delta-update kernel whose state
    buffers are donated — a tick updates the universe in place, touching
    only the changed cost/norm columns plus the rows whose masked
    row-minimum handed off, with per-column score re-sums for changed
    columns and delta-folds for handed-off rows.  Host traffic per tick
    is the delta batch in and one scalar (the handoff count) out; a cold
    ``rank_dense(backend="jax")`` instead re-uploads the whole float64
    universe and re-materializes every ranking
    (``benchmarks/market_bench.py`` quantifies the gap).

    **Tolerance contract** (:data:`SCORE_CONTRACTS` ``["jax"]``): float32
    sums are not decomposable, and the delta-folded score accumulators
    drift by ulps per tick, so — unlike :class:`RankState` — rankings
    are *not* bit-identical to a cold re-rank.  The contract is
    same-winner-or-tied-within-tolerance, scores inside the rel/abs
    envelope; ``JournalReplayer.audit`` verifies journals produced
    through this path in exactly those terms (DESIGN.md §9).

    Delta batches are padded to power-of-4 column-count buckets so the
    jitted step compiles O(log C) shape variants, not one per batch
    size; padding repeats the first (column, price) pair, which every
    kernel op treats idempotently.
    """

    backend = "jax"
    contract = SCORE_CONTRACTS["jax"]
    _BUCKET_BASE = 8

    def __init__(self, hours: np.ndarray, mask: np.ndarray,
                 prices: np.ndarray, config_ids: Sequence[Hashable],
                 job_ids: Optional[Sequence[Hashable]] = None):
        if not _HAVE_JAX:
            raise BackendUnavailableError(
                "JaxRankState requires jax; use RankState (numpy) "
                "when it is not installed")
        self.config_ids = list(config_ids)
        self.job_ids = list(job_ids) if job_ids is not None else None
        hours, mask, prices = _canonicalize_universe(hours, mask, prices,
                                                     self.job_ids)
        self._pos = _position_index(self.config_ids)
        cold, self._step, self._winner_fn = _jax_state_fns()
        # read-only residents (uploaded once, never donated)
        self.d_hours = jnp.asarray(hours, dtype=jnp.float32)
        self.d_mask = jnp.asarray(mask)
        self.counts = mask.sum(axis=0)
        self._d_finite = jnp.asarray(self.counts > 0)
        # the donated state buffers
        self.d_prices = jnp.asarray(prices, dtype=jnp.float32)
        (self.d_cost, self.d_row_best, self.d_norm,
         self.d_scores) = cold(self.d_hours, self.d_mask, self.d_prices)
        #: ticks applied since construction (diagnostics, cache keys).
        self.reprices = 0

    @property
    def prices(self) -> np.ndarray:
        """Current per-config $/h as seen by the kernel (float32 quotes
        lifted to a host float64 vector)."""
        return np.asarray(self.d_prices, dtype=np.float64)

    @property
    def scores(self) -> np.ndarray:
        """Current score accumulators on the host (float64 lift)."""
        return np.asarray(self.d_scores, dtype=np.float64)

    def reprice(self, deltas: Union[Mapping[Hashable, float],
                                    Sequence[Tuple[Hashable, float]]]
                ) -> int:
        """Apply ``{config_id: new $/h}`` deltas on device; returns
        #rows whose masked row-minimum handed off (synced to host, so a
        return means the tick's kernel has completed)."""
        table = deltas if isinstance(deltas, Mapping) else dict(deltas)
        if not table:
            return 0
        try:
            cols = np.asarray([self._pos[c] for c in table],
                              dtype=np.int32)
        except KeyError as e:
            raise ValueError(f"unknown config id in deltas: {e.args[0]!r}")
        new_prices = np.asarray(list(table.values()), dtype=np.float64)
        bad = ~(np.isfinite(new_prices) & (new_prices > 0))
        if bad.any():
            offender = list(table)[int(np.flatnonzero(bad)[0])]
            raise ValueError(f"non-positive or non-finite price for "
                             f"config {offender!r}")
        k = cols.shape[0]
        bucket = self._BUCKET_BASE
        while bucket < k:
            bucket *= 4
        if bucket > k:        # pad with an idempotent repeat (see class doc)
            cols = np.concatenate(
                [cols, np.full(bucket - k, cols[0], dtype=np.int32)])
            new_prices = np.concatenate(
                [new_prices, np.full(bucket - k, new_prices[0])])
        (self.d_prices, self.d_cost, self.d_row_best, self.d_norm,
         self.d_scores, moved) = self._step(
            self.d_prices, self.d_cost, self.d_row_best, self.d_norm,
            self.d_scores, self.d_hours, self.d_mask,
            jnp.asarray(cols), jnp.asarray(new_prices, dtype=jnp.float32))
        self.reprices += 1
        return int(moved)

    def ranking(self) -> List[RankedConfig]:
        """The full sorted ranking under the tolerance contract: one
        device→host score transfer, then the same materialization as
        every other path (ties broken by catalog order)."""
        return _materialize(self.scores, self.counts, self.config_ids)

    def winner(self) -> RankedConfig:
        """argmin on device — only two scalars cross to the host."""
        i, s = self._winner_fn(self.d_scores, self._d_finite)
        i = int(i)
        c = self.config_ids[i]
        if not self.counts[i]:
            return RankedConfig(c, float("inf"), float("inf"))
        return RankedConfig(c, float(s), float(s) / int(self.counts[i]))
