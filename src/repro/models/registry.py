"""Model construction from configs."""
from __future__ import annotations

from repro.models.encdec import EncDec
from repro.models.lm import LM
from repro.models.types import ModelConfig


def build_model(cfg: ModelConfig):
    """LM for decoder-only families; EncDec when encoder_layers > 0."""
    return EncDec(cfg) if cfg.is_encdec else LM(cfg)
