"""Core neural layers: norms, linears, RoPE, attention, MLP, MoE.

Pure functions over explicit parameter dicts.  Every ``*_specs`` function
returns a tree of :class:`ParamSpec` whose logical axes drive sharding
(`repro.sharding.rules`).  Attention uses a chunked online-softmax
formulation (flash-attention structure in pure jnp) so 32k-token prefills
never materialise a full T x T score matrix; the Pallas kernel in
`repro.kernels.flash_attention` is the TPU-optimized version of the same
contract and is dispatched via `repro.kernels.ops` when enabled.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.types import ModelConfig, ParamSpec
from repro.models import settings as settings_lib
from repro.sharding.ctx import constrain

# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def norm_specs(cfg: ModelConfig, dim: Optional[int] = None) -> Dict[str, ParamSpec]:
    d = dim if dim is not None else cfg.d_model
    specs = {"scale": ParamSpec((d,), (None,), init="ones")}
    if cfg.norm == "layernorm":
        specs["bias"] = ParamSpec((d,), (None,), init="zeros")
    return specs


def norm_apply(p, x: jax.Array, kind: str = "rmsnorm", eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    if kind == "layernorm":
        x = x - x.mean(-1, keepdims=True)
    var = (x * x).mean(-1, keepdims=True)
    x = x * lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    if kind == "layernorm":
        x = x + p["bias"].astype(jnp.float32)
    return x.astype(dtype)


def rms_norm_1d(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Headwise RMS norm (qk-norm), f32 internals."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = (x * x).mean(-1, keepdims=True)
    return (x * lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------

def embed_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    specs = {"embedding": ParamSpec((cfg.vocab_size, cfg.d_model),
                                    ("vocab", "embed"), scale=0.02)}
    if not cfg.tie_embeddings:
        specs["head"] = ParamSpec((cfg.d_model, cfg.vocab_size),
                                  ("embed", "vocab"))
    return specs


def embed_apply(p, cfg: ModelConfig, tokens: jax.Array) -> jax.Array:
    emb = p["embedding"].astype(cfg.compute_dtype)
    return constrain(jnp.take(emb, tokens, axis=0), ("batch", "seq", None))


def head_apply(p, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        w = p["embedding"].astype(cfg.compute_dtype).T
    else:
        w = p["head"].astype(cfg.compute_dtype)
    logits = jnp.einsum("btd,dv->btv", x, w)
    return constrain(logits, ("batch", "seq", "vocab"))


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, *, theta: float,
         fraction: float = 1.0) -> jax.Array:
    """Rotary embedding on the last dim.  x: (B, T, H, D), positions: (B, T)."""
    d = x.shape[-1]
    rot = int(d * fraction)
    rot -= rot % 2
    if rot == 0:
        return x
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    half = rot // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[:, :, None].astype(jnp.float32) * freqs  # (B, T, half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x_rot[..., :half], x_rot[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)
    return jnp.concatenate([out, x_pass], axis=-1) if rot < d else out


# ---------------------------------------------------------------------------
# scaled-dot-product attention (chunked online softmax)
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _block_attn(q: jax.Array, k: jax.Array, v: jax.Array,
                mask: Optional[jax.Array]) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One (q-chunk x kv-chunk) block.  q: (B,Tq,G,R,D), k/v: (B,Tk,G,D).

    Returns (unnormalised out, row max m, row sum l)."""
    s = jnp.einsum("btgrd,bsgd->bgrts", q, k,
                   preferred_element_type=jnp.float32)
    if mask is not None:
        s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, axis=-1)                          # (B,G,R,Tq)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bgrts,bsgd->btgrd", p.astype(v.dtype), v)
    return o, m, l


def sdpa(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool,
         window: Optional[int] = None, q_chunk: Optional[int] = None,
         kv_chunk: Optional[int] = None) -> jax.Array:
    """Chunked attention.  q: (B,Tq,H,D); k,v: (B,Tk,G,D) with H = G*R.

    Causal assumes q and k cover the same positions (Tq == Tk).  The python
    loop over q chunks is static; each q chunk runs a fori_loop over only
    the kv chunks it can attend to (no masked-out FLOPs beyond the diagonal
    blocks), carrying online-softmax statistics (m, l, acc).
    """
    st = settings_lib.get()
    q_chunk = q_chunk if q_chunk is not None else st.q_chunk
    kv_chunk = kv_chunk if kv_chunk is not None else st.kv_chunk
    B, Tq, H, D = q.shape
    Tk, G = k.shape[1], k.shape[2]
    R = H // G
    scale = 1.0 / math.sqrt(D)
    q = (q * scale).reshape(B, Tq, G, R, D)

    q_chunk = min(q_chunk, Tq)
    kv_chunk = min(kv_chunk, Tk)
    nq = -(-Tq // q_chunk)

    outs = []
    for i in range(nq):
        q0, q1 = i * q_chunk, min((i + 1) * q_chunk, Tq)
        qi = q[:, q0:q1]
        cq = q1 - q0
        # kv range this q chunk may attend to
        hi = min(q1, Tk) if causal else Tk
        lo = 0
        if window is not None:
            lo = max(0, q0 - window)
        lo_c, hi_c = lo // kv_chunk, -(-hi // kv_chunk)

        def body(j, carry, qi=qi, q0=q0, cq=cq):
            acc, m, l = carry
            k0 = j * kv_chunk
            kj = lax.dynamic_slice_in_dim(k, k0, kv_chunk, axis=1)
            vj = lax.dynamic_slice_in_dim(v, k0, kv_chunk, axis=1)
            qpos = q0 + jnp.arange(cq)
            kpos = k0 + jnp.arange(kv_chunk)
            mask = jnp.ones((cq, kv_chunk), dtype=bool)
            if causal:
                mask &= qpos[:, None] >= kpos[None, :]
            if window is not None:
                mask &= qpos[:, None] - kpos[None, :] < window
            mask &= (kpos < Tk)[None, :]
            o_b, m_b, l_b = _block_attn(qi, kj, vj, mask[None, None, None])
            m_new = jnp.maximum(m, m_b)
            c_old = jnp.exp(m - m_new)
            c_new = jnp.exp(m_b - m_new)
            acc = acc * c_old[..., None].astype(acc.dtype) \
                + o_b.transpose(0, 2, 3, 1, 4) * c_new[..., None].astype(acc.dtype)
            l = l * c_old + l_b * c_new
            return acc, m_new, l

        acc0 = jnp.zeros((B, G, R, cq, D), jnp.float32)
        m0 = jnp.full((B, G, R, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, G, R, cq), jnp.float32)
        if st.unroll_attn or hi_c - lo_c <= 2:
            carry = (acc0, m0, l0)
            for j in range(lo_c, hi_c):
                carry = body(j, carry)
            acc, m, l = carry
        else:
            acc, m, l = lax.fori_loop(lo_c, hi_c, body, (acc0, m0, l0))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        outs.append(out.transpose(0, 3, 1, 2, 4).reshape(B, cq, H, D))
    return jnp.concatenate(outs, axis=1).astype(v.dtype) if len(outs) > 1 \
        else outs[0].astype(v.dtype)


def sdpa_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                valid: jax.Array) -> jax.Array:
    """Single-token attention over a cache.

    q: (B,1,H,D); caches: (B,S,G,D); valid: (S,) bool mask of live entries.
    """
    B, _, H, D = q.shape
    S, G = k_cache.shape[1], k_cache.shape[2]
    R = H // G
    qg = (q * (1.0 / math.sqrt(D))).reshape(B, 1, G, R, D)
    s = jnp.einsum("btgrd,bsgd->bgrts", qg, k_cache,
                   preferred_element_type=jnp.float32)
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgrts,bsgd->btgrd", p.astype(v_cache.dtype), v_cache)
    return o.reshape(B, 1, H, D)


def _cache_write_prefill(cache: jax.Array, k: jax.Array) -> jax.Array:
    """Write a T-token prefill into a cache of S slots.

    S >= T: plain write at offset 0.  S < T (ring/window cache): keep the
    last S tokens at their ring slots (slot = position % S)."""
    S, T = cache.shape[1], k.shape[1]
    k = k.astype(cache.dtype)
    if T <= S:
        return lax.dynamic_update_slice_in_dim(cache, k, 0, axis=1)
    tail = k[:, T - S:]
    slots = (jnp.arange(T - S, T)) % S
    return cache.at[:, slots].set(tail)


# ---------------------------------------------------------------------------
# attention layer (projections + rope + qk-norm + cache plumbing)
# ---------------------------------------------------------------------------

def attn_specs(cfg: ModelConfig, *, cross: bool = False) -> Dict[str, ParamSpec]:
    d, H, G, D = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    specs = {
        "wq": ParamSpec((d, H, D), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, G, D), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, G, D), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((H, D, d), ("heads", "head_dim", "embed"),
                        scale=1.0 / math.sqrt(H * D)),
    }
    if cfg.qk_norm and not cross:
        specs["q_norm"] = ParamSpec((D,), (None,), init="ones")
        specs["k_norm"] = ParamSpec((D,), (None,), init="ones")
    return specs


def _project_q(p, cfg, x, positions, *, use_rope=True):
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"].astype(x.dtype))
    q = constrain(q, ("batch", "seq", "heads", "head_dim"))
    if cfg.qk_norm and "q_norm" in p:
        q = rms_norm_1d(q, p["q_norm"])
    if use_rope and positions is not None:
        q = rope(q, positions, theta=cfg.rope_theta, fraction=cfg.rope_fraction)
        q = constrain(q, ("batch", "seq", "heads", "head_dim"))
    return q


def _project_kv(p, cfg, x, positions, *, use_rope=True):
    k = jnp.einsum("btd,dgk->btgk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("btd,dgk->btgk", x, p["wv"].astype(x.dtype))
    k = constrain(k, ("batch", "seq", "kv_heads", "head_dim"))
    v = constrain(v, ("batch", "seq", "kv_heads", "head_dim"))
    if cfg.qk_norm and "k_norm" in p:
        k = rms_norm_1d(k, p["k_norm"])
    if use_rope and positions is not None:
        k = rope(k, positions, theta=cfg.rope_theta, fraction=cfg.rope_fraction)
        k = constrain(k, ("batch", "seq", "kv_heads", "head_dim"))
    return k, v


def attn_apply(p, cfg: ModelConfig, x: jax.Array, *, mode: str,
               positions: Optional[jax.Array] = None,
               window: Optional[int] = None,
               cache: Optional[Dict[str, jax.Array]] = None,
               pos: Optional[jax.Array] = None,
               kv_x: Optional[jax.Array] = None,
               use_rope: bool = True,
               ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Attention layer.

    mode: "causal" (train/prefill), "full" (encoder), "cross"
    (decoder->encoder), "decode" (one token against cache),
    "cross_decode" (one token against precomputed cross kv cache).
    Returns (output, new_cache).
    """
    if mode == "causal":
        q = _project_q(p, cfg, x, positions, use_rope=use_rope)
        k, v = _project_kv(p, cfg, x, positions, use_rope=use_rope)
        o = sdpa(q, k, v, causal=True, window=window)
        new_cache = None
        if cache is not None:   # prefill: write into the cache
            new_cache = {
                "k": _cache_write_prefill(cache["k"], k),
                "v": _cache_write_prefill(cache["v"], v),
            }
    elif mode == "full":
        q = _project_q(p, cfg, x, positions, use_rope=use_rope)
        k, v = _project_kv(p, cfg, x, positions, use_rope=use_rope)
        o = sdpa(q, k, v, causal=False, window=None)
        new_cache = None
    elif mode == "cross":
        q = _project_q(p, cfg, x, None, use_rope=False)
        k, v = _project_kv(p, cfg, kv_x, None, use_rope=False)
        o = sdpa(q, k, v, causal=False, window=None)
        new_cache = {"k": k, "v": v}
    elif mode == "cross_decode":
        q = _project_q(p, cfg, x, None, use_rope=False)
        o = sdpa(q, cache["k"], cache["v"], causal=False, window=None)
        new_cache = cache
    elif mode == "decode":
        q = _project_q(p, cfg, x, positions, use_rope=use_rope)
        k, v = _project_kv(p, cfg, x, positions, use_rope=use_rope)
        # write the new token at index `pos` (scalar; engine keeps
        # sequences aligned — see repro.serve for the batching contract).
        # Window caches are ring buffers of size `window`: slot = pos % S.
        S = cache["k"].shape[1]
        write_idx = pos % S
        k_cache = lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), write_idx, axis=1)
        v_cache = lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), write_idx, axis=1)
        kpos = jnp.arange(S)
        # ring: slot s last written at pos - ((pos - s) mod S); valid if >= 0.
        # linear (S covers the full sequence): valid iff s <= pos.
        valid = (pos - (pos - kpos) % S) >= 0
        if window is not None:
            valid &= (pos - kpos) % S < window
        o = sdpa_decode(q, k_cache, v_cache, valid)
        new_cache = {"k": k_cache, "v": v_cache}
    else:
        raise ValueError(mode)
    o = constrain(o, ("batch", "seq", "heads", "head_dim"))
    y = jnp.einsum("bthk,hkd->btd", o, p["wo"].astype(o.dtype))
    y = constrain(y, ("batch", "seq", None))
    return y, new_cache


def kv_cache_shape(cfg: ModelConfig, batch: int, max_len: int
                   ) -> Tuple[Tuple[int, ...], Tuple[Optional[str], ...]]:
    """Shape + logical axes of one direction (k or v) of a layer cache."""
    eff = min(max_len, cfg.window) if cfg.window else max_len
    return ((batch, eff, cfg.num_kv_heads, cfg.head_dim),
            ("batch", None, "kv_heads", "head_dim"))


# ---------------------------------------------------------------------------
# MLP (gated / classic)
# ---------------------------------------------------------------------------

def mlp_specs(cfg: ModelConfig, *, d_ff: Optional[int] = None,
              gated: bool = True) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    f = d_ff if d_ff is not None else cfg.d_ff
    specs = {
        "w_up": ParamSpec((d, f), ("embed", "mlp")),
        "w_down": ParamSpec((f, d), ("mlp", "embed")),
    }
    if gated:
        specs["w_gate"] = ParamSpec((d, f), ("embed", "mlp"))
    return specs


def _act(x: jax.Array, kind: str) -> jax.Array:
    return jax.nn.silu(x) if kind == "silu" else jax.nn.gelu(x)


def mlp_apply(p, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    h = jnp.einsum("btd,df->btf", x, p["w_up"].astype(x.dtype))
    h = constrain(h, ("batch", "seq", "mlp"))
    if "w_gate" in p:
        g = jnp.einsum("btd,df->btf", x, p["w_gate"].astype(x.dtype))
        g = constrain(g, ("batch", "seq", "mlp"))
        h = _act(g, cfg.act) * h
    else:
        h = _act(h, cfg.act)
    y = jnp.einsum("btf,fd->btd", h, p["w_down"].astype(x.dtype))
    return constrain(y, ("batch", "seq", None))


# ---------------------------------------------------------------------------
# Mixture of Experts (sort-based dispatch with capacity, EP-shardable)
# ---------------------------------------------------------------------------

def moe_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    f = cfg.moe_d_ff if cfg.moe_d_ff is not None else cfg.d_ff
    E = cfg.num_experts
    specs = {
        "router": ParamSpec((d, E), ("embed", None), scale=0.02),
        "w_gate": ParamSpec((E, d, f), ("experts", "embed", "mlp")),
        "w_up": ParamSpec((E, d, f), ("experts", "embed", "mlp")),
        "w_down": ParamSpec((E, f, d), ("experts", "mlp", "embed")),
    }
    if cfg.shared_expert:
        specs["shared"] = mlp_specs(cfg, d_ff=f, gated=True)
    return specs


def _positions_in_expert(expert_flat: jax.Array) -> jax.Array:
    """Rank of each (token, k) slot within its expert's arrival order.

    expert_flat: (N,) int32 expert ids.  Returns (N,) int32 positions,
    computed with an argsort + segmented-iota (O(N log N), no (N, E)
    one-hot tensors).
    """
    n = expert_flat.shape[0]
    order = jnp.argsort(expert_flat, stable=True)
    sorted_e = expert_flat[order]
    iota = jnp.arange(n, dtype=jnp.int32)
    seg_start = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_e[1:] != sorted_e[:-1]])
    start_iota = jnp.where(seg_start, iota, 0)
    run_start = lax.cummax(start_iota)
    pos_sorted = iota - run_start
    return jnp.zeros((n,), jnp.int32).at[order].set(pos_sorted)


def moe_apply(p, cfg: ModelConfig, x: jax.Array
              ) -> Tuple[jax.Array, jax.Array]:
    """Top-k MoE with per-sequence-group capacity.  Returns (y, aux_loss)."""
    B, T, d = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    f = cfg.moe_d_ff if cfg.moe_d_ff is not None else cfg.d_ff
    C = max(1, int(math.ceil(T * K / E * cfg.capacity_factor)))

    logits = jnp.einsum("btd,de->bte", x, p["router"].astype(x.dtype))
    logits = logits.astype(jnp.float32)
    if K == 1:   # sigmoid router (llama4-style top-1 + shared expert)
        probs = jax.nn.sigmoid(logits)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = lax.top_k(probs, K)                      # (B, T, K)
    if K > 1:
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    idx_flat = idx.reshape(B, T * K)
    pos = jax.vmap(_positions_in_expert)(idx_flat)        # (B, T*K)
    keep = pos < C
    slot = jnp.where(keep, idx_flat * C + pos, E * C)     # overflow bucket

    x_tk = jnp.repeat(x, K, axis=1)                       # (B, T*K, d)

    def scatter_row(slots_r, x_r):
        return jnp.zeros((E * C + 1, d), x.dtype).at[slots_r].add(x_r)
    xe = jax.vmap(scatter_row)(slot, x_tk)[:, :E * C]     # (B, E*C, d)
    xe = xe.reshape(B, E, C, d)
    xe = constrain(xe, ("batch", "experts", None, None))

    g = jnp.einsum("becd,edf->becf", xe, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("becd,edf->becf", xe, p["w_up"].astype(x.dtype))
    h = _act(g, cfg.act) * u
    h = constrain(h, ("batch", "experts", None, "mlp"))
    ye = jnp.einsum("becf,efd->becd", h, p["w_down"].astype(x.dtype))
    ye = constrain(ye, ("batch", "experts", None, None))

    ye_flat = jnp.concatenate(
        [ye.reshape(B, E * C, d),
         jnp.zeros((B, 1, d), ye.dtype)], axis=1)          # (B, E*C+1, d)
    y_tk = jnp.take_along_axis(ye_flat, slot[..., None], axis=1)
    w = (gates.reshape(B, T * K) * keep).astype(x.dtype)
    y = (y_tk * w[..., None]).reshape(B, T, K, d).sum(axis=2)

    if cfg.shared_expert:
        y = y + mlp_apply(p["shared"], cfg, x)

    # Switch-style load-balance auxiliary loss
    me = jax.nn.softmax(logits, axis=-1).mean(axis=(0, 1))           # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[idx_flat.reshape(-1)].add(
        1.0 / (B * T * K))
    aux = E * jnp.sum(me * ce)
    return y, aux
