"""Encoder-decoder model (SeamlessM4T-v2 text/speech backbone).

The assignment specifies the transformer backbone only: the speech
frontend (conformer feature extractor) is a stub — batches carry
precomputed frame embeddings ``(B, F, d_model)`` which feed the encoder.
The decoder is a standard causal stack with cross-attention; decoding
maintains a self-attention KV cache plus per-layer cross-attention caches
computed once at prefill.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.lm import (LayerPlan, Z_LOSS_WEIGHT, _stack_apply,
                             _stack_cache_specs, _stack_specs, layer_plans)
from repro.models.types import ModelConfig, ParamSpec, SpecTree, init_params


class EncDec:
    """Encoder-decoder LM.  cfg.encoder_layers > 0; cfg.num_layers = decoder."""

    def __init__(self, cfg: ModelConfig):
        assert cfg.encoder_layers > 0
        self.cfg = cfg
        self.enc_plans = [LayerPlan(kind="attn") for _ in range(cfg.encoder_layers)]
        self.dec_plans = layer_plans(cfg, cross=True)

    def param_specs(self) -> SpecTree:
        import dataclasses
        cfg = self.cfg
        enc_cfg = dataclasses.replace(cfg, num_layers=cfg.encoder_layers,
                                      encoder_layers=0)
        return {
            "embed": L.embed_specs(cfg),
            "enc_stack": _stack_specs(enc_cfg, self.enc_plans),
            "enc_norm": L.norm_specs(cfg),
            "dec_stack": _stack_specs(cfg, self.dec_plans),
            "final_norm": L.norm_specs(cfg),
        }

    def state_specs(self, batch: int, max_len: int, enc_len: int) -> SpecTree:
        return _stack_cache_specs(self.cfg, self.dec_plans, batch, max_len,
                                  enc_len)

    def init(self, key: jax.Array):
        return init_params(self.param_specs(), key, self.cfg.compute_dtype)

    def init_state(self, batch: int, max_len: int, enc_len: int):
        return init_params(self.state_specs(batch, max_len, enc_len),
                           jax.random.PRNGKey(0))

    # -- encoder -------------------------------------------------------------
    def encode(self, params, frames: jax.Array, *, remat: bool = True):
        cfg = self.cfg
        x = frames.astype(cfg.compute_dtype)
        B, F = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(F, dtype=jnp.int32), (B, F))
        x, _, _ = _stack_apply(cfg, self.enc_plans, params["enc_stack"], x,
                               mode="encode", positions=positions, remat=remat)
        return L.norm_apply(params["enc_norm"], x, cfg.norm)

    # -- train ---------------------------------------------------------------
    def forward(self, params, batch: Dict[str, jax.Array], *,
                remat: bool = True):
        cfg = self.cfg
        enc_out = self.encode(params, batch["frontend_embeds"], remat=remat)
        x = L.embed_apply(params["embed"], cfg, batch["tokens"])
        x = x * math.sqrt(cfg.d_model)
        B, T = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
        x, aux, _ = _stack_apply(cfg, self.dec_plans, params["dec_stack"], x,
                                 mode="train", positions=positions,
                                 enc_out=enc_out, remat=remat)
        x = L.norm_apply(params["final_norm"], x, cfg.norm)
        logits = L.head_apply(params["embed"], cfg, x)
        return logits, aux

    def loss(self, params, batch: Dict[str, jax.Array], *, remat: bool = True):
        logits, aux = self.forward(params, batch, remat=remat)
        labels = batch["labels"]
        logits = logits.astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(
            logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
        mask = (labels >= 0).astype(jnp.float32)
        denom = jnp.maximum(mask.sum(), 1.0)
        xent = jnp.sum((lse - ll) * mask) / denom
        z_loss = Z_LOSS_WEIGHT * jnp.sum(jnp.square(lse) * mask) / denom
        total = xent + z_loss
        return total, {"xent": xent, "z_loss": z_loss, "aux": aux,
                       "tokens": mask.sum()}

    # -- serving ---------------------------------------------------------------
    def prefill(self, params, batch: Dict[str, jax.Array], state):
        """Encode the source and run the target prompt, filling caches."""
        cfg = self.cfg
        enc_out = self.encode(params, batch["frontend_embeds"], remat=False)
        x = L.embed_apply(params["embed"], cfg, batch["tokens"])
        x = x * math.sqrt(cfg.d_model)
        B, T = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
        x, _, new_state = _stack_apply(cfg, self.dec_plans,
                                       params["dec_stack"], x,
                                       mode="prefill", positions=positions,
                                       caches=state, enc_out=enc_out,
                                       remat=False)
        x = L.norm_apply(params["final_norm"], x[:, -1:], cfg.norm)
        logits = L.head_apply(params["embed"], cfg, x)
        return logits[:, 0], new_state

    def decode_step(self, params, token: jax.Array, pos: jax.Array, state):
        cfg = self.cfg
        x = L.embed_apply(params["embed"], cfg, token[:, None])
        x = x * math.sqrt(cfg.d_model)
        B = x.shape[0]
        positions = jnp.broadcast_to(pos[None, None], (B, 1)).astype(jnp.int32)
        x, _, new_state = _stack_apply(cfg, self.dec_plans,
                                       params["dec_stack"], x,
                                       mode="decode", positions=positions,
                                       caches=state, pos=pos, remat=False)
        x = L.norm_apply(params["final_norm"], x, cfg.norm)
        logits = L.head_apply(params["embed"], cfg, x)
        return logits[:, 0], new_state
