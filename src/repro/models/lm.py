"""Decoder-only language model covering dense / MoE / hybrid / SSM / VLM
families, assembled from repro.models.layers + repro.models.recurrent.

Design notes
------------
* **Scan over layer cycles.**  The stack is grouped into its smallest
  repeating cycle (lcm of the block pattern and the MoE period); parameters
  are stacked with a leading ``(n_cycles,)`` dim and the forward pass is a
  single ``lax.scan`` — HLO size is O(cycle), not O(depth), which keeps
  512-device dry-run compiles fast for 48-layer models.  Remainder layers
  (e.g. RecurrentGemma's 38 = 12*3 + 2) run unscanned.
* **Three entry modes.**  ``train`` (causal, no cache), ``prefill``
  (causal, writes KV/recurrent state), ``decode`` (one token, reads+writes
  state).  States are specified as ParamSpec trees so the dry-run can build
  shardings without allocating.
* **Frontends are stubs** per the assignment: VLM/audio batches carry
  precomputed patch/frame embeddings which are concatenated (VLM) or fed to
  the encoder (audio enc-dec, see repro.models.encdec).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models import recurrent as R
from repro.models import settings as settings_lib
from repro.sharding.ctx import constrain
from repro.models.types import ModelConfig, ParamSpec, SpecTree, init_params

AUX_LOSS_WEIGHT = 0.01
Z_LOSS_WEIGHT = 1e-4


@dataclasses.dataclass(frozen=True)
class LayerPlan:
    kind: str                   # "attn" | "rec" | "rwkv"
    moe: bool = False
    window: Optional[int] = None
    cross: bool = False         # decoder layer with cross-attention


def layer_plans(cfg: ModelConfig, *, cross: bool = False) -> List[LayerPlan]:
    plans = []
    for i in range(cfg.num_layers):
        kind = cfg.block_kind(i)
        window = cfg.window if (kind == "attn" and cfg.window) else None
        plans.append(LayerPlan(kind=kind, moe=cfg.is_moe_layer(i),
                               window=window, cross=cross))
    return plans


def _cycle_len(cfg: ModelConfig) -> int:
    period = cfg.moe_period if cfg.num_experts else 1
    return math.lcm(len(cfg.block_pattern), period)


# ---------------------------------------------------------------------------
# per-layer specs / apply
# ---------------------------------------------------------------------------

def block_specs(cfg: ModelConfig, plan: LayerPlan) -> SpecTree:
    s: Dict[str, Any] = {"ln1": L.norm_specs(cfg), "ln2": L.norm_specs(cfg)}
    if plan.kind == "attn":
        s["attn"] = L.attn_specs(cfg)
    elif plan.kind == "rec":
        s["rec"] = R.rglru_block_specs(cfg)
    elif plan.kind == "rwkv":
        s["tm"] = R.rwkv_time_mix_specs(cfg)
        s["cm"] = R.rwkv_channel_mix_specs(cfg)
    else:
        raise ValueError(plan.kind)
    if plan.cross:
        s["ln_cross"] = L.norm_specs(cfg)
        s["cross"] = L.attn_specs(cfg, cross=True)
    if plan.kind != "rwkv":
        if plan.moe:
            s["moe"] = L.moe_specs(cfg)
        else:
            s["mlp"] = L.mlp_specs(cfg, gated=cfg.gated_mlp)
    return s


def block_cache_specs(cfg: ModelConfig, plan: LayerPlan, batch: int,
                      max_len: int, enc_len: int = 0) -> SpecTree:
    """ParamSpec tree for this layer's decode state."""
    s: Dict[str, Any] = {}
    cdt = cfg.compute_dtype
    if plan.kind == "attn":
        shape, axes = L.kv_cache_shape(cfg, batch, max_len)
        s["k"] = ParamSpec(shape, axes, init="zeros", dtype=cdt)
        s["v"] = ParamSpec(shape, axes, init="zeros", dtype=cdt)
    elif plan.kind == "rec":
        shapes = R.rglru_state_shapes(cfg, batch)
        s["h"] = ParamSpec(shapes["h"][0], shapes["h"][1], init="zeros",
                           dtype=jnp.float32)
        s["conv"] = ParamSpec(shapes["conv"][0], shapes["conv"][1],
                              init="zeros", dtype=cdt)
    elif plan.kind == "rwkv":
        shapes = R.rwkv_state_shapes(cfg, batch)
        s["tm_shift"] = ParamSpec(shapes["tm_shift"][0], shapes["tm_shift"][1],
                                  init="zeros", dtype=cdt)
        s["wkv"] = ParamSpec(shapes["wkv"][0], shapes["wkv"][1], init="zeros",
                             dtype=jnp.float32)
        s["cm_shift"] = ParamSpec(shapes["cm_shift"][0], shapes["cm_shift"][1],
                                  init="zeros", dtype=cdt)
    if plan.cross:
        xshape = (batch, enc_len, cfg.num_kv_heads, cfg.head_dim)
        xaxes = ("batch", None, "kv_heads", "head_dim")
        s["xk"] = ParamSpec(xshape, xaxes, init="zeros", dtype=cdt)
        s["xv"] = ParamSpec(xshape, xaxes, init="zeros", dtype=cdt)
    return s


def block_apply(cfg: ModelConfig, plan: LayerPlan, p, x, *, mode: str,
                positions=None, cache=None, pos=None, enc_out=None):
    """Returns (x, aux_loss, new_cache)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache: Dict[str, Any] = {}
    cache = cache or {}
    norm_kind = cfg.norm

    if plan.kind == "attn":
        h = L.norm_apply(p["ln1"], x, norm_kind)
        if mode in ("train", "prefill"):
            attn_cache = {"k": cache["k"], "v": cache["v"]} if "k" in cache else None
            y, nc = L.attn_apply(p["attn"], cfg, h, mode="causal",
                                 positions=positions, window=plan.window,
                                 cache=attn_cache)
            if nc is not None:
                new_cache.update(nc)
        elif mode == "encode":
            y, _ = L.attn_apply(p["attn"], cfg, h, mode="full",
                                positions=positions)
        else:  # decode
            y, nc = L.attn_apply(p["attn"], cfg, h, mode="decode",
                                 positions=positions, window=plan.window,
                                 cache={"k": cache["k"], "v": cache["v"]},
                                 pos=pos)
            new_cache.update(nc)
        x = x + y
    elif plan.kind == "rec":
        h = L.norm_apply(p["ln1"], x, norm_kind)
        state = None
        if "h" in cache:
            state = {"h": cache["h"], "conv": cache["conv"]}
        y, ns = R.rglru_block_apply(p["rec"], cfg, h, state=state)
        if ns is not None:
            new_cache.update(ns)
        x = x + y
    elif plan.kind == "rwkv":
        h = L.norm_apply(p["ln1"], x, "layernorm")
        st = {"shift": cache["tm_shift"], "wkv": cache["wkv"]} \
            if "wkv" in cache else None
        y, ns = R.rwkv_time_mix_apply(p["tm"], cfg, h, state=st)
        if ns is not None:
            new_cache["tm_shift"] = ns["shift"]
            new_cache["wkv"] = ns["wkv"]
        x = x + y
        h = L.norm_apply(p["ln2"], x, "layernorm")
        st = {"shift": cache["cm_shift"]} if "cm_shift" in cache else None
        y, ns = R.rwkv_channel_mix_apply(p["cm"], cfg, h, state=st)
        if ns is not None:
            new_cache["cm_shift"] = ns["shift"]
        x = x + y
        return x, aux, new_cache

    if plan.cross:
        h = L.norm_apply(p["ln_cross"], x, norm_kind)
        if mode in ("train", "prefill"):
            y, nc = L.attn_apply(p["cross"], cfg, h, mode="cross",
                                 kv_x=enc_out)
            if mode == "prefill":
                new_cache["xk"], new_cache["xv"] = nc["k"], nc["v"]
        else:
            y, _ = L.attn_apply(p["cross"], cfg, h, mode="cross_decode",
                                cache={"k": cache["xk"], "v": cache["xv"]})
            new_cache["xk"], new_cache["xv"] = cache["xk"], cache["xv"]
        x = x + y

    h = L.norm_apply(p["ln2"], x, norm_kind)
    if plan.moe:
        y, aux = L.moe_apply(p["moe"], cfg, h)
    else:
        y = L.mlp_apply(p["mlp"], cfg, h)
    x = x + y
    return x, aux, new_cache


# ---------------------------------------------------------------------------
# the model
# ---------------------------------------------------------------------------

def _stack_specs(cfg: ModelConfig, plans: List[LayerPlan]) -> SpecTree:
    cyc = _cycle_len(cfg)
    n_cycles, rem = divmod(len(plans), cyc)

    def stacked(spec: ParamSpec) -> ParamSpec:
        return ParamSpec((n_cycles,) + spec.shape, ("layers",) + spec.axes,
                         init=spec.init, scale=spec.scale, dtype=spec.dtype)

    tree: Dict[str, Any] = {"cycles": {}, "rem": {}}
    if n_cycles:
        for i in range(cyc):
            spec = block_specs(cfg, plans[i])
            tree["cycles"][f"b{i}"] = jax.tree_util.tree_map(
                stacked, spec, is_leaf=lambda s: isinstance(s, ParamSpec))
    for j in range(rem):
        tree["rem"][f"r{j}"] = block_specs(cfg, plans[n_cycles * cyc + j])
    return tree


def _stack_cache_specs(cfg: ModelConfig, plans: List[LayerPlan], batch: int,
                       max_len: int, enc_len: int = 0) -> SpecTree:
    cyc = _cycle_len(cfg)
    n_cycles, rem = divmod(len(plans), cyc)

    def stacked(spec: ParamSpec) -> ParamSpec:
        return ParamSpec((n_cycles,) + spec.shape, ("layers",) + spec.axes,
                         init="zeros", dtype=spec.dtype)

    tree: Dict[str, Any] = {"cycles": {}, "rem": {}}
    if n_cycles:
        for i in range(cyc):
            spec = block_cache_specs(cfg, plans[i], batch, max_len, enc_len)
            tree["cycles"][f"b{i}"] = jax.tree_util.tree_map(
                stacked, spec, is_leaf=lambda s: isinstance(s, ParamSpec))
    for j in range(rem):
        tree["rem"][f"r{j}"] = block_cache_specs(
            cfg, plans[n_cycles * cyc + j], batch, max_len, enc_len)
    return tree


def _stack_apply(cfg: ModelConfig, plans: List[LayerPlan], params, x, *,
                 mode: str, positions=None, caches=None, pos=None,
                 enc_out=None, remat: bool = True):
    """Run the layer stack.  Returns (x, aux_sum, new_caches)."""
    cyc = _cycle_len(cfg)
    n_cycles, rem = divmod(len(plans), cyc)
    aux_total = jnp.zeros((), jnp.float32)
    new_caches: Dict[str, Any] = {"cycles": {}, "rem": {}}

    if n_cycles:
        has_cache = caches is not None
        xs_cache = caches["cycles"] if has_cache else {
            f"b{i}": {} for i in range(cyc)}

        def cycle_body(carry, xs):
            xc, aux = carry
            p_cyc, c_cyc = xs
            outs = {}
            for i in range(cyc):
                xc, aux_i, nc = block_apply(
                    cfg, plans[i], p_cyc[f"b{i}"], xc, mode=mode,
                    positions=positions, cache=c_cyc[f"b{i}"] or None,
                    pos=pos, enc_out=enc_out)
                xc = constrain(xc, ("batch", "seq", None))
                aux = aux + aux_i
                outs[f"b{i}"] = nc
            return (xc, aux), outs

        if mode == "train" and remat:
            cycle_body = jax.checkpoint(
                cycle_body, policy=jax.checkpoint_policies.nothing_saveable)
        (x, aux_total), cyc_caches = lax.scan(
            cycle_body, (x, aux_total), (params["cycles"], xs_cache),
            unroll=min(settings_lib.get().layer_unroll, n_cycles))
        new_caches["cycles"] = cyc_caches

    for j in range(rem):
        plan = plans[n_cycles * cyc + j]
        cache_j = caches["rem"][f"r{j}"] if caches is not None else None
        x, aux_j, nc = block_apply(cfg, plan, params["rem"][f"r{j}"], x,
                                   mode=mode, positions=positions,
                                   cache=cache_j, pos=pos, enc_out=enc_out)
        aux_total = aux_total + aux_j
        new_caches["rem"][f"r{j}"] = nc
    return x, aux_total, new_caches


def fused_xent(params_embed, cfg: ModelConfig, x: jax.Array,
               labels: jax.Array, chunk: int):
    """Fused head-matmul + cross-entropy over vocab chunks.

    Computes per-token (logsumexp, label-logit) without materialising the
    (B, T, V) f32 logits tensor: each chunk's logits live only inside a
    rematerialised scan step.  Returns (lse, ll) as (B, T) f32.
    """
    if cfg.tie_embeddings:
        w = params_embed["embedding"].astype(cfg.compute_dtype).T
    else:
        w = params_embed["head"].astype(cfg.compute_dtype)
    V = w.shape[1]
    chunk = min(chunk, V)
    n = -(-V // chunk)
    pad = n * chunk - V
    if pad:
        w = jnp.pad(w, ((0, 0), (0, pad)))
    w_chunks = w.reshape(w.shape[0], n, chunk).transpose(1, 0, 2)
    # keep the vocab sharding through the reshape (chunk dim still shards)
    w_chunks = constrain(w_chunks, (None, "embed", "vocab"))

    @jax.checkpoint
    def step(carry, inp):
        m, s, ll = carry
        w_c, idx = inp                               # (d, chunk), chunk id
        logits = jnp.einsum("btd,dv->btv", x, w_c).astype(jnp.float32)
        logits = constrain(logits, ("batch", "seq", "vocab"))
        vpos = idx * chunk + jnp.arange(chunk)
        logits = jnp.where((vpos < V)[None, None, :], logits, -1e30)
        m_c = logits.max(-1)
        m_new = jnp.maximum(m, m_c)
        s = s * jnp.exp(m - m_new) + jnp.exp(logits - m_new[..., None]).sum(-1)
        in_chunk = (labels >= idx * chunk) & (labels < (idx + 1) * chunk)
        local = jnp.clip(labels - idx * chunk, 0, chunk - 1)
        picked = jnp.take_along_axis(logits, local[..., None], axis=-1)[..., 0]
        ll = jnp.where(in_chunk, picked, ll)
        return (m_new, s, ll), None

    B, T = labels.shape
    m0 = jnp.full((B, T), -1e30, jnp.float32)
    s0 = jnp.zeros((B, T), jnp.float32)
    ll0 = jnp.zeros((B, T), jnp.float32)
    # analysis passes unroll so HloCostAnalysis sees every chunk (§Dry-run)
    unroll = n if settings_lib.get().unroll_attn else 1
    (m, s, ll), _ = lax.scan(step, (m0, s0, ll0),
                             (w_chunks, jnp.arange(n)), unroll=unroll)
    lse = m + jnp.log(jnp.maximum(s, 1e-30))
    return lse, ll


class LM:
    """Decoder-only LM (dense / MoE / hybrid / SSM / VLM backbones)."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.plans = layer_plans(cfg)

    # -- specs -----------------------------------------------------------------
    def param_specs(self) -> SpecTree:
        cfg = self.cfg
        return {
            "embed": L.embed_specs(cfg),
            "final_norm": L.norm_specs(cfg),
            "stack": _stack_specs(cfg, self.plans),
        }

    def state_specs(self, batch: int, max_len: int) -> SpecTree:
        return _stack_cache_specs(self.cfg, self.plans, batch, max_len)

    def init(self, key: jax.Array):
        return init_params(self.param_specs(), key, self.cfg.compute_dtype)

    def init_state(self, batch: int, max_len: int):
        return init_params(self.state_specs(batch, max_len),
                           jax.random.PRNGKey(0))

    # -- embedding (with optional frontend embeds prepended) --------------------
    def _embed(self, params, batch: Dict[str, jax.Array]) -> jax.Array:
        cfg = self.cfg
        x = L.embed_apply(params["embed"], cfg, batch["tokens"])
        x = x * math.sqrt(cfg.d_model)
        if cfg.frontend and "frontend_embeds" in batch:
            fe = batch["frontend_embeds"].astype(x.dtype)
            x = jnp.concatenate([fe, x], axis=1)
        return x

    # -- train forward + loss ----------------------------------------------------
    def forward(self, params, batch: Dict[str, jax.Array], *,
                remat: bool = True) -> Tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        x = self._embed(params, batch)
        B, T = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
        x, aux, _ = _stack_apply(cfg, self.plans, params["stack"], x,
                                 mode="train", positions=positions,
                                 remat=remat)
        x = L.norm_apply(params["final_norm"], x, cfg.norm)
        logits = L.head_apply(params["embed"], cfg, x)
        return logits, aux

    def loss(self, params, batch: Dict[str, jax.Array], *,
             remat: bool = True) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """batch["labels"]: (B, T_total) int32, -1 = masked position."""
        cfg = self.cfg
        labels = batch["labels"]
        vchunk = settings_lib.get().vocab_chunk
        if vchunk:
            # fused path: never materialise (B, T, V) logits
            x = self._embed(params, batch)
            B, T = x.shape[0], x.shape[1]
            positions = jnp.broadcast_to(
                jnp.arange(T, dtype=jnp.int32), (B, T))
            x, aux, _ = _stack_apply(cfg, self.plans, params["stack"], x,
                                     mode="train", positions=positions,
                                     remat=remat)
            x = L.norm_apply(params["final_norm"], x, cfg.norm)
            lse, ll = fused_xent(params["embed"], cfg, x,
                                 jnp.maximum(labels, 0), vchunk)
        else:
            logits, aux = self.forward(params, batch, remat=remat)
            logits = logits.astype(jnp.float32)
            lse = jax.scipy.special.logsumexp(logits, axis=-1)
            ll = jnp.take_along_axis(
                logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
        mask = (labels >= 0).astype(jnp.float32)
        denom = jnp.maximum(mask.sum(), 1.0)
        xent = jnp.sum((lse - ll) * mask) / denom
        z_loss = Z_LOSS_WEIGHT * jnp.sum(jnp.square(lse) * mask) / denom
        total = xent + z_loss + AUX_LOSS_WEIGHT * aux
        return total, {"xent": xent, "z_loss": z_loss, "aux": aux,
                       "tokens": mask.sum()}

    # -- serving ------------------------------------------------------------------
    def prefill(self, params, batch: Dict[str, jax.Array], state):
        """Run the prompt through the stack, filling caches.

        Returns (last-position logits, new state)."""
        cfg = self.cfg
        x = self._embed(params, batch)
        B, T = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
        x, _, new_state = _stack_apply(cfg, self.plans, params["stack"], x,
                                       mode="prefill", positions=positions,
                                       caches=state, remat=False)
        x = L.norm_apply(params["final_norm"], x[:, -1:], cfg.norm)
        logits = L.head_apply(params["embed"], cfg, x)
        return logits[:, 0], new_state

    def decode_step(self, params, token: jax.Array, pos: jax.Array, state):
        """One decode step.  token: (B,) int32; pos: scalar int32 index at
        which the new token is written (cache entries [0, pos] valid)."""
        cfg = self.cfg
        x = L.embed_apply(params["embed"], cfg, token[:, None])
        x = x * math.sqrt(cfg.d_model)
        B = x.shape[0]
        positions = jnp.broadcast_to(pos[None, None], (B, 1)).astype(jnp.int32)
        x, _, new_state = _stack_apply(cfg, self.plans, params["stack"], x,
                                       mode="decode", positions=positions,
                                       caches=state, pos=pos, remat=False)
        x = L.norm_apply(params["final_norm"], x, cfg.norm)
        logits = L.head_apply(params["embed"], cfg, x)
        return logits[:, 0], new_state
