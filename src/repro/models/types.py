"""Model configuration and parameter-spec types.

Parameters are plain nested dicts of ``jnp`` arrays.  A parallel tree of
:class:`ParamSpec` carries shapes, dtypes, initialiser kinds and — crucially
for the distribution layer — *logical axis names* per dimension, which
:mod:`repro.sharding.rules` maps onto mesh axes.  Specs allow the dry-run to
build shardings and ``jax.eval_shape`` parameter stand-ins without ever
materialising a 400B-parameter model.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Shape + logical axes + initialiser for one parameter tensor."""

    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]   # logical axis name per dim (None = replicated)
    init: str = "normal"              # normal | zeros | ones | uniform
    scale: Optional[float] = None     # stddev override (default: 1/sqrt(fan_in))
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)

    def initialise(self, key: jax.Array, compute_dtype: Any) -> jax.Array:
        dtype = compute_dtype if self.dtype is None else self.dtype
        if self.init == "zeros":
            return jnp.zeros(self.shape, dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, dtype)
        if self.init == "uniform":
            return jax.random.uniform(key, self.shape, dtype, -1.0, 1.0)
        fan_in = self.shape[0] if len(self.shape) > 1 else self.shape[-1]
        scale = self.scale if self.scale is not None else 1.0 / np.sqrt(fan_in)
        return (jax.random.normal(key, self.shape) * scale).astype(dtype)


SpecTree = Dict[str, Any]   # nested dict of ParamSpec leaves


def init_params(specs: SpecTree, key: jax.Array,
                compute_dtype: Any = jnp.float32) -> Dict[str, Any]:
    """Materialise a parameter pytree from a spec tree (deterministic)."""
    leaves, treedef = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(leaves))
    arrs = [spec.initialise(k, compute_dtype) for spec, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, arrs)


def param_shapes(specs: SpecTree) -> Dict[str, Any]:
    """ShapeDtypeStruct tree (for jax.eval_shape / dry-run lowering)."""
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def count_params(specs: SpecTree) -> int:
    leaves = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    return sum(int(np.prod(s.shape)) for s in leaves)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One configuration covering all assigned architecture families."""

    name: str
    family: str                 # dense | moe | hybrid | ssm | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_period: int = 1         # MoE layer every k-th layer (llama4: 2)
    moe_d_ff: Optional[int] = None
    shared_expert: bool = False
    capacity_factor: float = 1.25

    # attention details
    qk_norm: bool = False
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0  # stablelm partial rotary
    window: Optional[int] = None  # local-attention window

    # layer pattern for hybrid/ssm stacks; cycled over the depth.
    # entries: "attn" | "rec" (RG-LRU) | "rwkv"
    block_pattern: Tuple[str, ...] = ("attn",)

    # recurrent blocks
    lru_width: Optional[int] = None    # RG-LRU width (default d_model)
    conv_width: int = 4
    rwkv_head_dim: int = 64

    # encoder-decoder
    encoder_layers: int = 0            # 0 = decoder-only

    # modality frontend stubs (embeddings supplied by input pipeline)
    frontend: Optional[str] = None     # None | "audio" | "vision"
    frontend_len: int = 0              # frames/patches prepended or encoded

    norm: str = "rmsnorm"              # rmsnorm | layernorm
    act: str = "silu"                  # silu | gelu
    gated_mlp: bool = True             # SwiGLU/GeGLU vs classic 2-matrix FFN
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.family in ("hybrid",) and self.lru_width is None:
            object.__setattr__(self, "lru_width", self.d_model)

    @property
    def compute_dtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.dtype]

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    def block_kind(self, layer_idx: int) -> str:
        """Kind of decoder layer ``layer_idx`` (cycled block pattern)."""
        return self.block_pattern[layer_idx % len(self.block_pattern)]

    def is_moe_layer(self, layer_idx: int) -> bool:
        if self.num_experts == 0:
            return False
        # MoE on every moe_period-th layer, starting so the LAST layer is MoE
        return (layer_idx % self.moe_period) == (self.moe_period - 1)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input shape (workload geometry)."""

    name: str
    seq_len: int
    global_batch: int
    kind: str      # "train" | "prefill" | "decode"

    @property
    def tokens_per_step(self) -> int:
        if self.kind == "decode":
            return self.global_batch   # one new token per sequence
        return self.seq_len * self.global_batch
