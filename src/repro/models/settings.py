"""Thread-local model execution settings.

The dry-run sets ``unroll_layers``/``unroll_attn`` so XLA's cost analysis
sees straight-line HLO: while-loop bodies are counted ONCE by
HloCostAnalysis (verified empirically: a 10-iteration scan of a matmul
reports the same flops as one matmul), so loops would silently undercount
FLOPs/bytes/collectives in the roofline.  Training/serving keep compact
loop HLO (fast compiles); only the analysis path unrolls.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading


@dataclasses.dataclass(frozen=True)
class Settings:
    layer_unroll: int = 1         # lax.scan unroll factor over layer cycles
    unroll_attn: bool = False     # python loop instead of fori over kv chunks
    q_chunk: int = 512
    kv_chunk: int = 512
    wkv_chunk: int = 128
    #: fused cross-entropy: compute head matmul + logsumexp over vocab
    #: chunks so the (B, T, V) f32 logits tensor never materialises.
    vocab_chunk: int = 0          # 0 = disabled (plain head + loss)


_TLS = threading.local()
_DEFAULT = Settings()


def get() -> Settings:
    return getattr(_TLS, "settings", _DEFAULT)


@contextlib.contextmanager
def use(**kwargs):
    old = get()
    _TLS.settings = dataclasses.replace(old, **kwargs)
    try:
        yield
    finally:
        _TLS.settings = old
