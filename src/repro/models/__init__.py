"""Model zoo: functional JAX implementations of the assigned architectures."""
from repro.models.types import ModelConfig, ParamSpec, ShapeSpec, count_params
from repro.models.registry import build_model
