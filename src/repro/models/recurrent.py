"""Recurrent sequence-mixing blocks: RG-LRU (RecurrentGemma) and RWKV-6.

Both are linear recurrences with O(1) decode state — which is exactly why
the `long_500k` assigned shape runs on these two families only (DESIGN.md
§5).  Training uses parallel forms (associative scan for RG-LRU; a
chunk-rematerialised scan for RWKV-6); decoding is a single-step state
update.  The RWKV-6 inner recurrence has a Pallas TPU kernel
(`repro.kernels.rwkv6_scan`) with this module's `wkv6_scan_ref`-equivalent
as its oracle.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.types import ModelConfig, ParamSpec
from repro.models.layers import _act, norm_specs
from repro.models import settings as settings_lib
from repro.sharding.ctx import constrain

# ---------------------------------------------------------------------------
# RG-LRU (Griffin / RecurrentGemma recurrent block)
# ---------------------------------------------------------------------------

RGLRU_C = 8.0


def rglru_block_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, w = cfg.d_model, cfg.lru_width
    return {
        # two input branches: gate (gelu) and recurrent
        "w_in_gate": ParamSpec((d, w), ("embed", "mlp")),
        "w_in_rec": ParamSpec((d, w), ("embed", "mlp")),
        # temporal conv over the recurrent branch (depthwise)
        "conv_w": ParamSpec((cfg.conv_width, w), (None, "mlp"), scale=0.1),
        "conv_b": ParamSpec((w,), ("mlp",), init="zeros"),
        # RG-LRU gates
        "w_a": ParamSpec((w, w), ("mlp", None)),
        "b_a": ParamSpec((w,), (None,), init="zeros"),
        "w_x": ParamSpec((w, w), ("mlp", None)),
        "b_x": ParamSpec((w,), (None,), init="zeros"),
        "lam": ParamSpec((w,), (None,), init="uniform"),
        "w_out": ParamSpec((w, d), ("mlp", "embed")),
    }


def _rglru_gates(p, xc: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """log a_t (per channel) and gated input, both f32."""
    x32 = xc.astype(jnp.float32)
    r = jax.nn.sigmoid(x32 @ p["w_a"].astype(jnp.float32) + p["b_a"])
    i = jax.nn.sigmoid(x32 @ p["w_x"].astype(jnp.float32) + p["b_x"])
    log_a = -RGLRU_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    gated = i * x32
    return log_a, gated


def _depthwise_conv(p, x: jax.Array, state: Optional[jax.Array]
                    ) -> Tuple[jax.Array, jax.Array]:
    """Causal depthwise temporal conv, width W.  x: (B,T,w).

    state: (B, W-1, w) past inputs (decode) or None (train: zero history).
    Returns (y, new_state)."""
    W = p["conv_w"].shape[0]
    B, T, w = x.shape
    if state is None:
        state = jnp.zeros((B, W - 1, w), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)            # (B, T+W-1, w)
    y = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(W):
        y = y + xp[:, i:i + T].astype(jnp.float32) * p["conv_w"][i].astype(jnp.float32)
    y = (y + p["conv_b"].astype(jnp.float32)).astype(x.dtype)
    new_state = xp[:, T:]                               # last W-1 inputs
    return y, new_state


def rglru_scan(log_a: jax.Array, gated: jax.Array,
               h0: Optional[jax.Array]) -> Tuple[jax.Array, jax.Array]:
    """h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * gated_t, via associative scan.

    log_a, gated: (B, T, w) f32.  h0: (B, w) initial state or None.
    Returns (h (B,T,w), final state (B,w))."""
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    a_s, h = lax.associative_scan(combine, (a, b), axis=1)
    return h, h[:, -1]


def rglru_block_apply(p, cfg: ModelConfig, x: jax.Array, *,
                      state: Optional[Dict[str, jax.Array]] = None
                      ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """The full RecurrentGemma recurrent block.  x: (B,T,d).

    state = {"h": (B,w), "conv": (B,conv_width-1,w)} for decode, else None.
    """
    gate = _act(jnp.einsum("btd,dw->btw", x, p["w_in_gate"].astype(x.dtype)),
                "gelu")
    gate = constrain(gate, ("batch", "seq", "mlp"))
    rec = jnp.einsum("btd,dw->btw", x, p["w_in_rec"].astype(x.dtype))
    rec = constrain(rec, ("batch", "seq", "mlp"))
    conv_state = state["conv"] if state is not None else None
    rec, new_conv = _depthwise_conv(p, rec, conv_state)
    log_a, gated = _rglru_gates(p, rec)
    h0 = state["h"] if state is not None else None
    h, h_last = rglru_scan(log_a, gated, h0)
    y = (h.astype(x.dtype) * gate)
    y = jnp.einsum("btw,wd->btd", y, p["w_out"].astype(x.dtype))
    new_state = {"h": h_last, "conv": new_conv} if state is not None else None
    return y, new_state


def rglru_state_shapes(cfg: ModelConfig, batch: int) -> Dict[str, Tuple]:
    w = cfg.lru_width
    return {
        "h": ((batch, w), ("batch", "mlp"), jnp.float32),
        "conv": ((batch, cfg.conv_width - 1, w), ("batch", None, "mlp"), None),
    }


# ---------------------------------------------------------------------------
# RWKV-6 ("Finch"): data-dependent decay linear attention
# ---------------------------------------------------------------------------

def rwkv_time_mix_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    N = cfg.rwkv_head_dim
    H = d // N
    lora = 32
    return {
        # data-dependent token-shift (ddlerp) parameters
        "maa_x": ParamSpec((d,), (None,), init="zeros"),
        "maa_wkvrg": ParamSpec((5, d), (None, None), init="zeros"),
        "tm_w1": ParamSpec((d, 5 * lora), ("embed", None), scale=0.02),
        "tm_w2": ParamSpec((5, lora, d), (None, None, "embed"), scale=0.02),
        # data-dependent decay
        "decay_base": ParamSpec((d,), (None,), init="uniform"),
        "td_w1": ParamSpec((d, 64), ("embed", None), scale=0.02),
        "td_w2": ParamSpec((64, d), (None, "embed"), scale=0.02),
        # per-(head,channel) bonus for the current token
        "u": ParamSpec((H, N), ("heads", None), scale=0.5),
        "wr": ParamSpec((d, d), ("embed", "heads_flat")),
        "wk": ParamSpec((d, d), ("embed", "heads_flat")),
        "wv": ParamSpec((d, d), ("embed", "heads_flat")),
        "wg": ParamSpec((d, d), ("embed", "heads_flat")),
        "wo": ParamSpec((d, d), ("heads_flat", "embed")),
        "ln_scale": ParamSpec((d,), (None,), init="ones"),
        "ln_bias": ParamSpec((d,), (None,), init="zeros"),
    }


def _token_shift(x: jax.Array, prev: Optional[jax.Array]) -> jax.Array:
    """x_{t-1} per position; `prev` is the carried last token (decode)."""
    B, T, d = x.shape
    if prev is None:
        prev = jnp.zeros((B, d), x.dtype)
    return jnp.concatenate([prev[:, None, :], x[:, :-1]], axis=1)


def _ddlerp(p, x: jax.Array, x_prev: jax.Array):
    """RWKV-6 data-dependent interpolation producing 5 mixed inputs."""
    diff = x_prev - x
    xx = x + diff * p["maa_x"].astype(x.dtype)
    lora = jnp.einsum("btd,dk->btk", xx, p["tm_w1"].astype(x.dtype))
    B, T, _ = x.shape
    lora = jnp.tanh(lora.reshape(B, T, 5, -1))
    mix = jnp.einsum("btfk,fkd->btfd", lora, p["tm_w2"].astype(x.dtype))
    mix = mix + p["maa_wkvrg"].astype(x.dtype)[None, None]
    return x[:, :, None, :] + diff[:, :, None, :] * mix   # (B,T,5,d)


def wkv6_scan_ref(r, k, v, w, u, s0):
    """Exact sequential RWKV-6 recurrence (the oracle).

    r,k,v: (B,T,H,N); w: (B,T,H,N) decay in (0,1); u: (H,N);
    s0: (B,H,N,N) initial state.  Returns (y (B,T,H,N), s_T).

        y_t = (s_{t-1} + (u * k_t) outer v_t)^T r_t
        s_t = diag(w_t) s_{t-1} + k_t outer v_t
    """
    r, k, v, w = (a.astype(jnp.float32) for a in (r, k, v, w))
    u = u.astype(jnp.float32)

    def step(s, inp):
        rt, kt, vt, wt = inp    # (B,H,N)
        kv = kt[..., :, None] * vt[..., None, :]          # (B,H,N,N)
        y = jnp.einsum("bhn,bhnm->bhm", rt, s + u[None, :, :, None] * kv)
        s = wt[..., :, None] * s + kv
        return s, y

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (r, k, v, w))
    s_T, ys = lax.scan(step, s0.astype(jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 1), s_T


def wkv6_scan_chunked(r, k, v, w, u, s0, *, chunk: Optional[int] = None):
    """Chunk-rematerialised scan: O(T/chunk) saved states for backward."""
    B, T, H, N = r.shape
    c = min(chunk if chunk is not None else settings_lib.get().wkv_chunk, T)
    if T % c:
        c = T  # fall back for ragged tails (smoke-test sizes)
    nc = T // c

    def body(s, inp):
        rc, kc, vc, wc = inp
        y, s1 = _wkv6_chunk_remat(rc, kc, vc, wc, u, s)
        return s1, y

    xs = tuple(a.reshape(B, nc, c, H, N).transpose(1, 0, 2, 3, 4)
               for a in (r, k, v, w))
    sT, ys = lax.scan(body, s0.astype(jnp.float32), xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, T, H, N)
    return y, sT


@jax.checkpoint
def _wkv6_chunk_remat(rc, kc, vc, wc, u, s):
    return wkv6_scan_ref(rc, kc, vc, wc, u, s)


def rwkv_time_mix_apply(p, cfg: ModelConfig, x: jax.Array, *,
                        state: Optional[Dict[str, jax.Array]] = None,
                        wkv_fn=None,
                        ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """RWKV-6 time mix.  state = {"shift": (B,d), "wkv": (B,H,N,N)}."""
    B, T, d = x.shape
    N = cfg.rwkv_head_dim
    H = d // N
    prev = state["shift"] if state is not None else None
    x_prev = _token_shift(x, prev)
    mixed = _ddlerp(p, x, x_prev)                        # (B,T,5,d)
    xw, xk, xv, xr, xg = (mixed[:, :, i] for i in range(5))

    r = jnp.einsum("btd,de->bte", xr, p["wr"].astype(x.dtype))
    k = jnp.einsum("btd,de->bte", xk, p["wk"].astype(x.dtype))
    v = jnp.einsum("btd,de->bte", xv, p["wv"].astype(x.dtype))
    g = jnp.einsum("btd,de->bte", xg, p["wg"].astype(x.dtype))

    dd = jnp.einsum("btd,dk->btk", xw, p["td_w1"].astype(x.dtype))
    dd = jnp.einsum("btk,kd->btd", jnp.tanh(dd), p["td_w2"].astype(x.dtype))
    log_w = -jnp.exp(
        (p["decay_base"].astype(jnp.float32) - 4.0) + dd.astype(jnp.float32))
    w = jnp.exp(log_w)                                   # decay in (0,1)

    shp = (B, T, H, N)
    r_, k_, v_, w_ = (a.reshape(shp) for a in (r, k, v, w))
    s0 = state["wkv"] if state is not None else jnp.zeros((B, H, N, N),
                                                          jnp.float32)
    fn = wkv_fn if wkv_fn is not None else (
        wkv6_scan_ref if T == 1 else wkv6_scan_chunked)
    y, sT = fn(r_, k_, v_, w_, p["u"], s0)

    # per-head group norm, then output gate + projection
    y = y.reshape(B, T, H, N).astype(jnp.float32)
    mu = y.mean(-1, keepdims=True)
    var = ((y - mu) ** 2).mean(-1, keepdims=True)
    y = (y - mu) * lax.rsqrt(var + 64e-5)
    y = y.reshape(B, T, d) * p["ln_scale"].astype(jnp.float32) \
        + p["ln_bias"].astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(g)
    y = jnp.einsum("btd,de->bte", y, p["wo"].astype(x.dtype))

    new_state = None
    if state is not None:
        new_state = {"shift": x[:, -1], "wkv": sT}
    return y, new_state


def rwkv_channel_mix_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "mu_k": ParamSpec((d,), (None,), init="zeros"),
        "mu_r": ParamSpec((d,), (None,), init="zeros"),
        "wk": ParamSpec((d, f), ("embed", "mlp")),
        "wv": ParamSpec((f, d), ("mlp", "embed")),
        "wr": ParamSpec((d, d), ("embed", None)),
    }


def rwkv_channel_mix_apply(p, cfg: ModelConfig, x: jax.Array, *,
                           state: Optional[Dict[str, jax.Array]] = None
                           ) -> Tuple[jax.Array, Optional[Dict]]:
    prev = state["shift"] if state is not None else None
    x_prev = _token_shift(x, prev)
    diff = x_prev - x
    xk = x + diff * p["mu_k"].astype(x.dtype)
    xr = x + diff * p["mu_r"].astype(x.dtype)
    kk = jnp.einsum("btd,df->btf", xk, p["wk"].astype(x.dtype))
    kk = constrain(kk, ("batch", "seq", "mlp"))
    kk = jnp.square(jax.nn.relu(kk))
    kv = jnp.einsum("btf,fd->btd", kk, p["wv"].astype(x.dtype))
    rr = jax.nn.sigmoid(
        jnp.einsum("btd,de->bte", xr, p["wr"].astype(x.dtype)))
    y = rr * kv
    new_state = {"shift": x[:, -1]} if state is not None else None
    return y, new_state


def rwkv_state_shapes(cfg: ModelConfig, batch: int) -> Dict[str, Tuple]:
    d = cfg.d_model
    N = cfg.rwkv_head_dim
    H = d // N
    return {
        "tm_shift": ((batch, d), ("batch", None), None),
        "wkv": ((batch, H, N, N), ("batch", "heads", None, None), jnp.float32),
        "cm_shift": ((batch, d), ("batch", None), None),
    }
