"""Mesh construction for the production deployment.

``make_production_mesh`` is a FUNCTION (not module state) so importing this
module never touches jax device state.  The single-pod mesh is 16x16 = 256
chips (v5e pod); multi-pod adds a leading 2-pod axis = 512 chips.

``mesh_options`` enumerates alternative splits of the same chips — the
"scale-out vs scale-up" dimension of the paper mapped onto SPMD: at fixed
chip count, how the (data, model) axes divide determines whether a workload
gets DP bandwidth or TP memory headroom.  These options are the TPU
Flora selector's configuration space (repro.core.tpu_flora).
"""
from __future__ import annotations

from typing import List, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Mesh over the first prod(shape) devices (the dry-run exposes 512
    host devices; the single-pod mesh uses the first 256)."""
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(f"need {n} devices, have {len(jax.devices())} — "
                           "run under launch/dryrun.py or set XLA_FLAGS")
    return jax.make_mesh(shape, axes, devices=devices)


def mesh_options(chips: int = 256) -> List[Tuple[Tuple[int, int], str]]:
    """(data, model) splits of a pod, with names, for the Flora trace."""
    opts = []
    model = 1
    while model <= min(chips, 64):
        data = chips // model
        opts.append(((data, model), f"dp{data}xtp{model}"))
        model *= 4
    return opts
