import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below this line may import jax -------------------------------
import argparse
import dataclasses
import json
import math
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

import repro.configs as configs
from repro.configs import shapes as shapes_lib
from repro.launch import mesh as mesh_lib
from repro.launch import roofline as roof_lib
from repro.models import build_model, count_params
from repro.models import settings as settings_lib
from repro.models.types import param_shapes
from repro.sharding import rules as rules_lib
from repro.sharding import ctx as ctx_lib
from repro.train.train_loop import TrainConfig, make_train_step

# per-arch training memory policy: bf16 moments for the 400B-class config
TRAIN_CFGS: Dict[str, TrainConfig] = {
    "llama4-maverick-400b-a17b": TrainConfig(moment_dtype="bfloat16"),
}
DEFAULT_TRAIN_CFG = TrainConfig()


def _scalar_shardings(tree, mesh):
    return jax.tree_util.tree_map(lambda _: NamedSharding(mesh, P()), tree)


def _active_params(cfg) -> float:
    """Active parameters per token (MoE: routed experts only)."""
    model = build_model(cfg)
    total = count_params(model.param_specs())
    if not cfg.num_experts:
        return float(total)
    f = cfg.moe_d_ff if cfg.moe_d_ff is not None else cfg.d_ff
    per_expert = 3 * cfg.d_model * f
    n_moe_layers = sum(cfg.is_moe_layer(i) for i in range(cfg.num_layers))
    inactive = n_moe_layers * (cfg.num_experts - cfg.experts_per_token) \
        * per_expert
    return float(total - inactive)


def _cycle_info(cfg):
    period = cfg.moe_period if cfg.num_experts else 1
    cyc = math.lcm(len(cfg.block_pattern), period)
    n_cycles, rem = divmod(cfg.num_layers, cyc)
    return cyc, n_cycles, rem


def _depth_variant(cfg, n_cycles_target: int):
    """Same config with only n_cycles_target layer cycles (+ remainder)."""
    cyc, _, rem = _cycle_info(cfg)
    changes = {"num_layers": n_cycles_target * cyc + rem}
    if cfg.encoder_layers:
        enc_cyc, enc_n, enc_rem = 1, cfg.encoder_layers, 0
        changes["encoder_layers"] = n_cycles_target * enc_cyc + enc_rem
    return dataclasses.replace(cfg, **changes)


def build_lowered(cfg, shape, mesh, rules, tcfg, *, settings_kwargs):
    """Lower one cell (no compile)."""
    model = build_model(cfg)
    p_specs = model.param_specs()
    p_sds = param_shapes(p_specs)
    p_sh = rules_lib.tree_shardings(p_specs, rules, mesh)

    if shape.kind == "train":
        step_fn, opt = make_train_step(model, tcfg)
        o_specs = opt.state_specs(p_specs)
        o_sds = param_shapes(o_specs)
        o_sh = rules_lib.tree_shardings(o_specs, rules, mesh)
        b_sds = shapes_lib.batch_specs(cfg, shape, with_labels=True)
        b_sh = rules_lib.batch_shardings(b_sds, rules, mesh)
        m_sds = jax.eval_shape(step_fn, p_sds, o_sds, b_sds)[2]
        jitted = jax.jit(step_fn,
                         in_shardings=(p_sh, o_sh, b_sh),
                         out_shardings=(p_sh, o_sh,
                                        _scalar_shardings(m_sds, mesh)),
                         donate_argnums=(0, 1))
        with mesh, ctx_lib.use(rules, mesh), settings_lib.use(**settings_kwargs):
            return jitted.lower(p_sds, o_sds, b_sds)
    if shape.kind == "prefill":
        b_sds = shapes_lib.batch_specs(cfg, shape, with_labels=False)
        b_sh = rules_lib.batch_shardings(b_sds, rules, mesh)
        if cfg.is_encdec:
            n_text = b_sds["tokens"].shape[1]
            enc_len = b_sds["frontend_embeds"].shape[1]
            s_specs = model.state_specs(shape.global_batch, n_text, enc_len)
        else:
            s_specs = model.state_specs(shape.global_batch, shape.seq_len)
        s_sds = param_shapes(s_specs)
        s_sh = rules_lib.tree_shardings(s_specs, rules, mesh)

        def prefill_fn(params, batch, state):
            return model.prefill(params, batch, state)

        logits_sh = NamedSharding(mesh, rules_lib.spec_for(
            (shape.global_batch, cfg.vocab_size), ("batch", "vocab"),
            rules, mesh))
        jitted = jax.jit(prefill_fn,
                         in_shardings=(p_sh, b_sh, s_sh),
                         out_shardings=(logits_sh, s_sh),
                         donate_argnums=(2,))
        with mesh, ctx_lib.use(rules, mesh), settings_lib.use(**settings_kwargs):
            return jitted.lower(p_sds, b_sds, s_sds)
    # decode
    if cfg.is_encdec:
        s_specs = model.state_specs(shape.global_batch, shape.seq_len,
                                    cfg.frontend_len)
    else:
        s_specs = model.state_specs(shape.global_batch, shape.seq_len)
    s_sds = param_shapes(s_specs)
    s_sh = rules_lib.tree_shardings(s_specs, rules, mesh)
    d_sds = shapes_lib.decode_specs(cfg, shape)
    tok_sh = rules_lib.batch_shardings(
        {"token": d_sds["token"]}, rules, mesh)["token"]

    def serve_step(params, token, pos, state):
        return model.decode_step(params, token, pos, state)

    logits_sh = NamedSharding(mesh, rules_lib.spec_for(
        (shape.global_batch, cfg.vocab_size), ("batch", "vocab"),
        rules, mesh))
    jitted = jax.jit(serve_step,
                     in_shardings=(p_sh, tok_sh, NamedSharding(mesh, P()),
                                   s_sh),
                     out_shardings=(logits_sh, s_sh),
                     donate_argnums=(3,))
    with mesh, ctx_lib.use(rules, mesh), settings_lib.use(**settings_kwargs):
        return jitted.lower(p_sds, d_sds["token"], d_sds["pos"], s_sds)


def _extrapolate(a: roof_lib.Roofline, b: roof_lib.Roofline,
                 n_cycles: int) -> roof_lib.Roofline:
    """total(n) = A + (n-1) * (B - A): A = 1-cycle module, B = 2-cycle."""
    k = n_cycles - 1
    coll = {key: int(a.collectives.get(key, 0)
                     + k * (b.collectives.get(key, 0)
                            - a.collectives.get(key, 0)))
            for key in set(a.collectives) | set(b.collectives)}
    return roof_lib.Roofline(
        flops=a.flops + k * (b.flops - a.flops),
        hbm_bytes=a.hbm_bytes + k * (b.hbm_bytes - a.hbm_bytes),
        wire_bytes=a.wire_bytes + k * (b.wire_bytes - a.wire_bytes),
        collectives=coll)


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               analyze: Optional[bool] = None,
               rule_overrides: Optional[Dict[str, Any]] = None,
               tcfg_override: Optional[TrainConfig] = None,
               mesh_shape: Optional[tuple] = None,
               settings_extra: Optional[Dict[str, Any]] = None,
               quiet: bool = False) -> Dict[str, Any]:
    """Compile one (arch x shape x mesh) cell and report.

    The TRUE config is compiled with rolled loops (this is the deployment
    artifact: memory_analysis + compile proof).  XLA's HloCostAnalysis
    counts while bodies once, so FLOPs/bytes/collectives come from two
    cheap depth-reduced compiles (1 and 2 cycles, attention python-
    unrolled) extrapolated affinely to the real depth.
    """
    cfg = configs.get(arch)
    shape = shapes_lib.SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    cell: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                            "mesh": mesh_name, "ok": False}
    reason = shapes_lib.skip_reason(cfg, shape)
    if reason:
        cell["skipped"] = reason
        return cell
    if analyze is None:
        analyze = not multi_pod   # roofline table is single-pod (§Roofline)

    if mesh_shape is not None:
        mesh = mesh_lib.make_mesh(tuple(mesh_shape), ("data", "model"))
        cell["mesh"] = mesh_name = \
            f"dp{mesh_shape[0]}xtp{mesh_shape[1]}"
    else:
        mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    rules = rules_lib.production_rules(multi_pod=multi_pod)
    tp = mesh.shape["model"]
    rules = rules.with_overrides(
        **rules_lib.arch_overrides(cfg, tp, kind=shape.kind))
    if rule_overrides:
        rules = rules.with_overrides(**rule_overrides)
    tcfg = tcfg_override or TRAIN_CFGS.get(arch, DEFAULT_TRAIN_CFG)

    # --- 1. true-config compile: the deployment proof -----------------------
    t0 = time.time()
    lowered = build_lowered(cfg, shape, mesh, rules, tcfg,
                            settings_kwargs=dict(settings_extra or {}))
    cell["lower_s"] = round(time.time() - t0, 1)
    t1 = time.time()
    compiled = lowered.compile()
    cell["compile_s"] = round(time.time() - t1, 1)
    mem = roof_lib.memory_analysis_dict(compiled)
    if mem:
        cell["memory"] = mem
        if not quiet:
            print(f"memory_analysis[{arch}/{shape_name}/{mesh_name}]: "
                  f"{json.dumps(mem)}", flush=True)

    cell["params_total"] = count_params(build_model(cfg).param_specs())
    cell["params_active"] = _active_params(cfg)

    # --- 2. cost analysis via depth-reduced pair ------------------------------
    if analyze:
        _, n_cycles, _ = _cycle_info(cfg)
        an_kwargs = dict(unroll_attn=True)
        if shape.kind == "prefill":
            an_kwargs.update(q_chunk=2048, kv_chunk=2048)
        an_kwargs.update(settings_extra or {})
        la = build_lowered(_depth_variant(cfg, 1), shape, mesh, rules, tcfg,
                           settings_kwargs=dict(an_kwargs, layer_unroll=1))
        ra = roof_lib.analyze(la.compile())
        lb = build_lowered(_depth_variant(cfg, 2), shape, mesh, rules, tcfg,
                           settings_kwargs=dict(an_kwargs, layer_unroll=2))
        rb = roof_lib.analyze(lb.compile())
        roof = _extrapolate(ra, rb, n_cycles)
        cell["roofline"] = roof.as_dict()
        n_active = _active_params(cfg)
        model_fl = roof_lib.model_flops_per_step(
            n_active, shape.tokens_per_step, training=(shape.kind == "train"))
        chips = 512 if multi_pod else 256
        cell["model_flops"] = model_fl
        cell["model_flops_per_device"] = model_fl / chips
        cell["useful_flops_ratio"] = \
            (model_fl / chips) / roof.flops if roof.flops else None
        if not quiet:
            print(f"cost_analysis[{arch}/{shape_name}/{mesh_name}]: "
                  f"flops/dev={roof.flops:.3e} bytes/dev={roof.hbm_bytes:.3e}"
                  f" wire/dev={roof.wire_bytes:.3e} dominant={roof.dominant}",
                  flush=True)
    cell["ok"] = True
    return cell


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="dryrun_report.json")
    ap.add_argument("--append", action="store_true",
                    help="merge results into an existing report")
    args = ap.parse_args()

    archs = configs.ARCH_NAMES if args.arch == "all" else args.arch.split(",")
    shape_names = list(shapes_lib.SHAPES) if args.shape == "all" \
        else args.shape.split(",")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    report = {"cells": []}
    if args.append and os.path.exists(args.out):
        with open(args.out) as f:
            report = json.load(f)
    done = {(c["arch"], c["shape"], c["mesh"]) for c in report["cells"]
            if c.get("ok") or c.get("skipped")}

    for multi in meshes:
        mesh_name = "2x16x16" if multi else "16x16"
        for arch in archs:
            for shape_name in shape_names:
                key = (arch, shape_name, mesh_name)
                if key in done:
                    continue
                print(f"=== {arch} x {shape_name} x {mesh_name}", flush=True)
                try:
                    cell = lower_cell(arch, shape_name, multi_pod=multi)
                except Exception as e:
                    traceback.print_exc()
                    cell = {"arch": arch, "shape": shape_name,
                            "mesh": mesh_name, "ok": False,
                            "error": f"{type(e).__name__}: {e}"}
                report["cells"].append(cell)
                with open(args.out, "w") as f:
                    json.dump(report, f, indent=1)
    ok = sum(1 for c in report["cells"] if c.get("ok"))
    skip = sum(1 for c in report["cells"] if c.get("skipped"))
    err = sum(1 for c in report["cells"]
              if not c.get("ok") and not c.get("skipped"))
    print(f"dry-run complete: {ok} ok, {skip} skipped, {err} failed")
    if err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
