"""Roofline-term derivation from compiled dry-run artifacts.

Terms (per step, single-pod v5e references):

    compute_s    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
    memory_s     = HLO_bytes_per_device / HBM_bw_per_chip
    collective_s = wire_bytes_per_device / ICI_link_bw

``cost_analysis()`` describes the *partitioned per-device* module, so both
numerator and denominator are per chip.  Collective bytes are not in
cost_analysis — we parse the optimized HLO and sum wire bytes per op kind
with ring-cost factors (all-reduce moves 2x its payload; gather/scatter/
all-to-all/permute move ~1x for group sizes >= 8).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Mapping, Optional, Tuple

# --- hardware constants (TPU v5e, per chip) ----------------------------------
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_LINK_BW = 50e9              # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# matches e.g. "bf16[16,4096,5120]{2,1,0}" (layout suffix optional)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(text: str) -> int:
    """Sum bytes of all dtype[dims] tokens in `text`."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Wire bytes per device by collective kind, from optimized HLO text.

    For each collective instruction we take max(result bytes, operand
    bytes) as the payload (covers both all-gather, whose result is the big
    side, and reduce-scatter, whose operand is), then apply ring factors.
    ``*-start`` variants (async collectives) are counted; ``*-done`` are
    not (same transfer).
    """
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.search(r"=\s*(.+?)\s+(%?)([\w-]+)\(", stripped)
        if not m:
            continue
        op = m.group(3)
        kind = None
        for k in _COLLECTIVES:
            if op == k or op == k + "-start":
                kind = k
                break
        if kind is None:
            continue
        result_bytes = _shape_bytes(m.group(1))
        operand_bytes = _shape_bytes(stripped[m.end():])
        payload = max(result_bytes, operand_bytes)
        if kind == "all-reduce":
            payload *= 2           # reduce-scatter + all-gather phases
        out[kind] += payload
    return out


@dataclasses.dataclass(frozen=True)
class Roofline:
    flops: float                   # per device
    hbm_bytes: float               # per device
    wire_bytes: float              # per device
    collectives: Mapping[str, int]

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS_BF16

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.wire_bytes / ICI_LINK_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Roofline step time: the binding constraint."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def as_dict(self) -> Dict:
        return {
            "flops_per_device": self.flops,
            "hbm_bytes_per_device": self.hbm_bytes,
            "wire_bytes_per_device": self.wire_bytes,
            "collectives": dict(self.collectives),
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "step_s": self.step_s,
        }


def analyze(compiled) -> Roofline:
    """Roofline terms from a jax compiled artifact."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(compiled.as_text())
    wire = float(sum(coll.values()))
    return Roofline(flops=flops, hbm_bytes=hbm, wire_bytes=wire,
                    collectives=coll)


def model_flops_per_step(n_params_active: float, tokens_per_step: float,
                         *, training: bool) -> float:
    """MODEL_FLOPS = 6*N*D for training (fwd+bwd), 2*N*D for inference."""
    factor = 6.0 if training else 2.0
    return factor * n_params_active * tokens_per_step


def memory_analysis_dict(compiled) -> Optional[Dict[str, float]]:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return None
    if ma is None:
        return None
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = float(v)
    if out:
        args = out.get("argument_size_in_bytes", 0.0)
        temp = out.get("temp_size_in_bytes", 0.0)
        outb = out.get("output_size_in_bytes", 0.0)
        alias = out.get("alias_size_in_bytes", 0.0)
        # peak live bytes per device ~ args + temps + (outputs not aliased)
        out["peak_bytes_per_device"] = args + temp + max(outb - alias, 0.0)
    return out or None
