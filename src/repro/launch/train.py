"""Production training launcher.

``--auto-mesh`` runs the paper's pipeline end-to-end: classify the workload
(train -> class B), rank the profiled mesh options from the dry-run trace
under current chip prices, and launch on the winner.  On this CPU container
the launcher runs reduced configs (same code path); on hardware the same
entrypoint drives the full configs.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
        --steps 100 --reduced --auto-mesh --report dryrun_single.json
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.configs import shapes as shapes_lib
from repro.core.costmodel import TpuPriceModel
from repro.core.tpu_flora import service_from_dryrun_report
from repro.data import pipeline as data_lib
from repro.models import build_model, count_params
from repro.models.types import ShapeSpec
from repro.train.checkpoint import Checkpointer
from repro.train.train_loop import (StragglerWatchdog, TrainConfig,
                                    make_train_step, train_loop)


def select_mesh(report_path: str, market: str) -> str:
    """Rank the dry-run-profiled meshes via the selection service."""
    with open(report_path) as f:
        report = json.load(f)
    service = service_from_dryrun_report(report, TpuPriceModel(market))
    decision = service.submit("train_4k")
    print(f"[flora] class {decision.job_class.value} (streaming-compute) "
          f"-> mesh {decision.config_id} at {decision.hourly_cost:.2f} $/h")
    return str(decision.config_id)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced (CPU-sized) config")
    ap.add_argument("--d-model", type=int, default=None,
                    help="override reduced width (e.g. ~100M model)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--auto-mesh", action="store_true")
    ap.add_argument("--report", default="dryrun_single.json")
    ap.add_argument("--market", default="ondemand",
                    choices=["ondemand", "spot"])
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    if args.auto_mesh and os.path.exists(args.report):
        select_mesh(args.report, args.market)

    cfg = configs.get(args.arch)
    if args.reduced:
        kw = {}
        if args.d_model:
            kw["d_model"] = args.d_model
        cfg = configs.reduced(cfg, **kw)
    model = build_model(cfg)
    n = count_params(model.param_specs())
    print(f"[train] {cfg.name}: {n/1e6:.1f}M params, "
          f"{cfg.num_layers} layers, d_model={cfg.d_model}")

    shape = ShapeSpec("cli", args.seq, args.batch, "train")
    stream = data_lib.for_model(cfg, shape)
    tcfg = TrainConfig(peak_lr=args.lr, warmup_steps=10,
                       total_steps=args.steps,
                       microbatches=args.microbatches)
    step_fn, opt = make_train_step(model, tcfg)

    ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    params = model.init(jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    if ckpt and args.resume and ckpt.latest_step() is not None:
        tree, start = ckpt.restore({"params": params,
                                    "opt_state": opt_state})
        params, opt_state = tree["params"], tree["opt_state"]
        print(f"[train] resumed from step {start}")

    watchdog = StragglerWatchdog()
    batches = iter(data_lib.PrefetchIterator(stream, start_step=start))
    params, opt_state, hist = train_loop(
        model, tcfg, params, opt_state, batches, steps=args.steps,
        checkpointer=ckpt, checkpoint_every=args.ckpt_every,
        watchdog=watchdog, start_step=start, train_step=step_fn)
    if ckpt:
        ckpt.save(args.steps, params, opt_state, block=True)
    print(f"[train] done: loss {hist['loss'][0]:.3f} -> "
          f"{hist['loss'][-1]:.3f} over {len(hist['loss'])} steps; "
          f"straggler events: {len(watchdog.events)}")


if __name__ == "__main__":
    main()
