"""Instrumentation-overhead benchmark: the obs registry on the serve path.

    PYTHONPATH=src python benchmarks/obs_bench.py

The claim under test (ISSUE 7 acceptance — the script exits nonzero when
the gate fails, which is the CI gate): the unified telemetry layer
(DESIGN.md §12) costs **< 3%** throughput on the snapshot-serving hot
path.  Both legs run the identical front-end serve loop over the same
submissions; the *uninstrumented* leg has ``spans_enabled=False`` (every
span is the shared no-op, zero clock reads), the *instrumented* leg has
spans on at the default ``span_sample`` (the sampled ``serve.worker``
timing).  Registry counters are live in both legs — they are the
accounting the system reads back, not optional telemetry.

Measuring a ~100 ns effect on a ~5 us path needs care, so the harness
is paired and robust rather than a single stopwatch:

  * the two legs serve the same submissions in *alternating batches*
    milliseconds apart, so clock-frequency drift hits both sides;
  * each batch pair yields one on/off time ratio, and a trial's
    estimate is the **median** ratio over all pairs and repeats
    (medians shrug off scheduler preemptions that a mean or a
    min-of-totals does not);
  * GC is disabled inside the timed region (the journal shards allocate
    ~one dict per serve, and a collection landing inside one leg's
    batch is pure noise);
  * the reported overhead is the **minimum of independent trial
    medians** — noise only ever inflates a ratio estimate, so the
    least-noisy trial is the tightest upper bound on the true cost.

Accounting is gated alongside the overhead: every submission in both
legs must be served from the snapshot (zero forwards, zero shed, all
journaled).

Prints ``name,us_per_call,derived`` CSV rows, writes them as
``BENCH_obs.json`` (override with ``BENCH_OBS_JSON``), and dumps the
instrumented leg's rendered registry to ``BENCH_obs_metrics.prom``
(override with ``OBS_METRICS_DUMP``) — the artifact CI uploads next to
the JSON.
"""
from __future__ import annotations

import gc
import os
import statistics
import sys
import time

from _bench_io import BenchRows, Gates, check_gates
from serve_bench import SELECTIONS, _market_text, _service, _submissions, \
    _universe
from repro.market import RecordedPriceFeed, ServeFrontend
from repro.obs import MetricsRegistry

ROWS = BenchRows("BENCH_OBS_JSON", "BENCH_obs.json")
emit = ROWS.emit
write_json = ROWS.write_json

#: gated claims that failed this run; main() exits nonzero on any.
GATES = Gates()
gate = GATES.gate

#: the DESIGN.md §12 instrumentation budget on the serve hot path.
OVERHEAD_BUDGET = 0.03

#: warmup ticks before timing, so snapshots/caches are in steady state.
N_TICKS = 8

BATCH = 1_000


def _frontend(store, ids, base, market: str, subs,
              spans_enabled: bool) -> ServeFrontend:
    """A warmed inline front-end whose snapshot covers every route."""
    svc = _service(store, ids, base)
    reg = MetricsRegistry(spans_enabled=spans_enabled)
    fe = ServeFrontend(svc, RecordedPriceFeed.loads(market), workers=1,
                       queue_capacity=len(subs) + 1, metrics=reg)
    fe.warm(subs[:len(SELECTIONS)])
    for _ in range(N_TICKS):
        fe.step_tick()
    return fe


def _check_accounting(fe: ServeFrontend, n_subs: int, leg: str) -> None:
    stats = fe.stats()
    gate(f"obs_{leg}", "all submissions served from the snapshot "
         "(zero forwards, zero shed, all journaled)",
         stats.forwarded == 0 and stats.shed == 0 and stats.accounted
         and stats.decisions + stats.rejected == n_subs)


def _trial(store, ids, base, market: str, subs, repeats: int
           ) -> "tuple[float, float, ServeFrontend]":
    """One trial: paired alternating batches over ``repeats`` fresh
    front-end pairs.  Returns (median on/off ratio, best off-leg
    seconds-per-serve, the last instrumented front-end)."""
    n_batches = len(subs) // BATCH
    ratios: "list[float]" = []
    best_off = float("inf")
    fe_on = None
    for r in range(repeats):
        fes = {False: _frontend(store, ids, base, market, subs, False),
               True: _frontend(store, ids, base, market, subs, True)}
        gc.collect()
        gc.disable()
        try:
            for i in range(n_batches):
                chunk = subs[i * BATCH:(i + 1) * BATCH]
                dts = {}
                # flip leg order per pair so drift cancels
                order = (False, True) if (r + i) % 2 == 0 else (True, False)
                for spans in order:
                    fe = fes[spans]
                    for sub in chunk:
                        fe.submit(sub)
                    t0 = time.perf_counter()
                    fe.serve_queued()
                    dts[spans] = time.perf_counter() - t0
                ratios.append(dts[True] / dts[False])
                best_off = min(best_off, dts[False] / BATCH)
        finally:
            gc.enable()
        _check_accounting(fes[False], n_batches * BATCH, "spans_off")
        _check_accounting(fes[True], n_batches * BATCH, "spans_on")
        fe_on = fes[True]
    return statistics.median(ratios), best_off, fe_on


def main(smoke: bool = False) -> None:
    print("name,us_per_call,derived")
    n_subs, repeats, trials = (4_000, 2, 2) if smoke else (20_000, 5, 3)
    store, ids, base = _universe()
    market = _market_text(base, N_TICKS)
    subs = _submissions(n_subs)

    medians = []
    best_off = float("inf")
    fe_on = None
    for _ in range(trials):
        ratio, off, fe = _trial(store, ids, base, market, subs, repeats)
        medians.append(ratio)
        if off < best_off:
            best_off = off
        fe_on = fe

    overhead = min(medians) - 1.0
    us_off = best_off * 1e6
    emit("obs_serve_spans_off", us_off,
         f"subs={n_subs};batch={BATCH};trials={trials}x{repeats};spans=off")
    emit("obs_serve_spans_on", us_off * (1.0 + overhead),
         f"subs={n_subs};span_sample={fe_on.span_sample};"
         f"overhead_pct={overhead * 100:.2f};"
         f"trial_medians={'/'.join(f'{(m - 1) * 100:+.2f}%' for m in medians)}")

    # THE gated claim: instrumented throughput within the budget of the
    # uninstrumented hot path (DESIGN.md §12)
    gate("obs_overhead",
         f"spans-on serve path within {OVERHEAD_BUDGET:.0%} of spans-off "
         f"(got {overhead:+.2%})", overhead < OVERHEAD_BUDGET)

    # the instrumented leg must actually have instrumented: sampled
    # serve spans and tick spans landed in the registry
    snap = fe_on.metrics_registry.snapshot()
    served_spans = snap["histograms"].get("serve.worker", {}).get("count", 0)
    tick_spans = snap["histograms"].get("tick.total", {}).get("count", 0)
    gate("obs_serve_spans_on", "sampled serve.worker spans recorded",
         served_spans >= (n_subs // BATCH * BATCH) // fe_on.span_sample)
    gate("obs_serve_spans_on", "tick.total spans recorded",
         tick_spans == N_TICKS)

    dump_path = os.environ.get("OBS_METRICS_DUMP", "BENCH_obs_metrics.prom")
    with open(dump_path, "w") as f:
        f.write(fe_on.metrics())
    print(f"# wrote {dump_path}", file=sys.stderr)

    write_json()
    check_gates(GATES.failures)


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv)
