"""Perf hillclimbing driver (EXPERIMENTS.md §Perf).

Re-lowers a chosen cell under named variants (sharding rules, mesh split,
microbatching, optimizer dtype, chunk sizes) and reports the roofline-term
deltas vs the baseline — the hypothesis -> change -> measure loop, with
each variant's numbers appended to a JSON log.

    PYTHONPATH=src python -m benchmarks.hillclimb \
        --arch qwen3-moe-30b-a3b --shape train_4k \
        --variants baseline,no_fsdp,mb4 --out hillclimb_qwen3moe.json

NOTE: must run in its own process (sets XLA_FLAGS for 512 host devices via
repro.launch.dryrun import).
"""
from __future__ import annotations

import argparse
import json
import os
import time

from repro.launch.dryrun import lower_cell  # sets XLA_FLAGS on import
from repro.train.train_loop import TrainConfig

#: named variants: kwargs for lower_cell
VARIANTS = {
    "baseline": {},
    # --- sharding / mesh ---------------------------------------------------
    "dp64xtp4": {"mesh_shape": (64, 4)},
    "dp32xtp8": {"mesh_shape": (32, 8)},
    "dp128xtp2": {"mesh_shape": (128, 2)},
    "dp256xtp1": {"mesh_shape": (256, 1)},
    "no_fsdp": {"rule_overrides": {"embed": None}},
    # serving: weights resident (no FSDP gather-per-step); MoE experts
    # sharded over the data axis too (EP) so 400B-class params fit
    "serve_weights": {"rule_overrides": {"embed": None}},
    "serve_ep_data": {"rule_overrides": {"embed": None,
                                         "experts": ("data",)}},
    "serve_ep_2d": {"rule_overrides": {"embed": None,
                                       "experts": ("data", "model"),
                                       "mlp": None}},
    # high-TP serving meshes (weights resident at 400B scale)
    "serve_tp64": {"mesh_shape": (4, 64), "rule_overrides": {"embed": None}},
    "serve_tp128": {"mesh_shape": (2, 128),
                    "rule_overrides": {"embed": None}},
    "seq_shard": {"rule_overrides": {"seq": ("model",)}},
    # --- training config ----------------------------------------------------
    "mb4": {"tcfg_override": TrainConfig(microbatches=4)},
    "mb8": {"tcfg_override": TrainConfig(microbatches=8)},
    "bf16_moments": {"tcfg_override": TrainConfig(moment_dtype="bfloat16")},
    "adafactor": {"tcfg_override": TrainConfig(optimizer="adafactor")},
    "no_remat": {"tcfg_override": TrainConfig(remat=False)},
    # --- kernel/chunk geometry ----------------------------------------------
    "q1024": {"settings_extra": {"q_chunk": 1024, "kv_chunk": 1024}},
    "q256": {"settings_extra": {"q_chunk": 256, "kv_chunk": 256}},
    # fused head+cross-entropy: never materialise (B,T,V) f32 logits
    "fused_loss": {"settings_extra": {"vocab_chunk": 16384}},
    "dp256_fused": {"mesh_shape": (256, 1),
                    "settings_extra": {"vocab_chunk": 16384}},
}


def run_variant(arch: str, shape: str, name: str) -> dict:
    kw = dict(VARIANTS[name])
    t0 = time.time()
    cell = lower_cell(arch, shape, multi_pod=False, analyze=True,
                      quiet=True, **kw)
    cell["variant"] = name
    cell["wall_s"] = round(time.time() - t0, 1)
    return cell


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variants", default="baseline")
    ap.add_argument("--out", default="hillclimb.json")
    args = ap.parse_args()

    log = {"arch": args.arch, "shape": args.shape, "runs": []}
    if os.path.exists(args.out):
        with open(args.out) as f:
            log = json.load(f)
    done = {r["variant"] for r in log["runs"] if r.get("ok")}

    base = None
    for r in log["runs"]:
        if r.get("variant") == "baseline" and r.get("ok"):
            base = r
    for name in args.variants.split(","):
        if name in done:
            print(f"[skip] {name} already done")
            continue
        print(f"[run] {args.arch} x {args.shape} x {name}", flush=True)
        try:
            cell = run_variant(args.arch, args.shape, name)
        except Exception as e:
            import traceback
            traceback.print_exc()
            cell = {"variant": name, "ok": False,
                    "error": f"{type(e).__name__}: {e}"}
        log["runs"].append(cell)
        with open(args.out, "w") as f:
            json.dump(log, f, indent=1)
        if cell.get("ok"):
            r = cell["roofline"]
            line = (f"  {name:14s} comp={r['compute_s']:.4f}s "
                    f"mem={r['memory_s']:.4f}s coll={r['collective_s']:.4f}s"
                    f" dom={r['dominant']} step={r['step_s']:.4f}s")
            if base is not None and base is not cell:
                b = base["roofline"]
                line += f"  (step x{r['step_s']/b['step_s']:.3f} vs baseline)"
            print(line, flush=True)
        if cell.get("variant") == "baseline" and cell.get("ok"):
            base = cell


if __name__ == "__main__":
    main()
