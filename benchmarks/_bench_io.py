"""Shared CSV + machine-readable JSON emission for the benchmark scripts.

Every benchmark prints ``name,us_per_call,derived`` CSV rows to stdout
and mirrors them into a ``BENCH_*.json`` file (path overridable via an
env var) that CI uploads as the perf-trajectory artifact.  Benches also
share the gated-claims contract here: collect failed claims through
:class:`Gates`, then :func:`check_gates` prints them to stderr and
exits nonzero so CI fails the job.
"""
from __future__ import annotations

import json
import os
import sys
from typing import Any, Dict, List, Sequence


class BenchRows:
    """Collects rows and writes them as the benchmark's JSON artifact."""

    def __init__(self, env_var: str, default_path: str):
        self.rows: List[Dict[str, Any]] = []
        self.env_var = env_var
        self.default_path = default_path

    def emit(self, name: str, us_per_call: float, derived: str,
             **extra: Any) -> None:
        row: Dict[str, Any] = {"name": name,
                               "us_per_call": round(us_per_call, 1),
                               "derived": derived}
        row.update(extra)                 # JSON-only fields (curve data)
        self.rows.append(row)
        print(f"{name},{us_per_call:.1f},{derived}")

    def write_json(self) -> None:
        path = os.environ.get(self.env_var, self.default_path)
        with open(path, "w") as f:
            json.dump(self.rows, f, indent=2)
        print(f"# wrote {path}", file=sys.stderr)


class Gates:
    """Collects gated claims that failed this run."""

    def __init__(self) -> None:
        self.failures: List[str] = []

    def gate(self, name: str, claim: str, ok: bool) -> None:
        if not ok:
            self.failures.append(f"{name}: {claim}")


def check_gates(failures: Sequence[str]) -> None:
    """Exit nonzero (after listing them on stderr) if any claim failed."""
    if failures:
        print("GATED CLAIMS FAILED:", file=sys.stderr)
        for failure in failures:
            print(f"  {failure}", file=sys.stderr)
        sys.exit(1)
