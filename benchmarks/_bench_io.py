"""Shared CSV + machine-readable JSON emission for the benchmark scripts.

Every benchmark prints ``name,us_per_call,derived`` CSV rows to stdout
and mirrors them into a ``BENCH_*.json`` file (path overridable via an
env var) that CI uploads as the perf-trajectory artifact.
"""
from __future__ import annotations

import json
import os
import sys
from typing import Any, Dict, List


class BenchRows:
    """Collects rows and writes them as the benchmark's JSON artifact."""

    def __init__(self, env_var: str, default_path: str):
        self.rows: List[Dict[str, Any]] = []
        self.env_var = env_var
        self.default_path = default_path

    def emit(self, name: str, us_per_call: float, derived: str) -> None:
        self.rows.append({"name": name,
                          "us_per_call": round(us_per_call, 1),
                          "derived": derived})
        print(f"{name},{us_per_call:.1f},{derived}")

    def write_json(self) -> None:
        path = os.environ.get(self.env_var, self.default_path)
        with open(path, "w") as f:
            json.dump(self.rows, f, indent=2)
        print(f"# wrote {path}", file=sys.stderr)
